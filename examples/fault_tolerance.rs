//! Fault-tolerance demo (paper §4.4 / Fig. 8): a rail dies mid-training,
//! Nezha detects it, migrates the (ptr, len) window to the surviving rail
//! within the 200 ms budget, and re-admits the rail when it recovers.
//!
//! Run: `cargo run --release --example fault_tolerance`

use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::fault::FaultSchedule;
use nezha::net::topology::parse_combo;
use nezha::util::bytes::fmt_us;

fn main() -> nezha::Result<()> {
    let cfg = Config {
        nodes: 4,
        combo: parse_combo("tcp-tcp")?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    // rail 1 goes down twice during the run
    let faults = FaultSchedule::none()
        .with(1, 0.5e6, 1.2e6) // down from t=0.5s to t=1.2s (virtual)
        .with(1, 2.5e6, 3.0e6);
    let mut mr = MultiRail::new(&cfg)?.with_faults(faults);

    let elems = 2 * 1024 * 1024; // 8MB ops -> hot start, both rails
    let mut ops = 0;
    println!("op | t(virtual) | rails | failovers | note");
    while mr.fab.now_us() < 4.0e6 {
        let mut buf = UnboundBuffer::from_fn(cfg.nodes, elems, |n, i| ((n * 7 + i) % 13) as f32);
        let before = mr.exceptions.failover_count();
        let rep = mr.allreduce(&mut buf)?;
        ops += 1;

        // verify numerics survived the failover
        let expect: f32 = (0..cfg.nodes).map(|n| ((n * 7 + 100) % 13) as f32).sum();
        assert_eq!(buf.node(2)[100], expect, "corrupted payload after failover");

        let active = rep.per_rail.iter().filter(|s| s.bytes > 0).count();
        let note = if rep.failovers > 0 {
            let ev = mr.exceptions.events.last().unwrap();
            format!(
                "FAILOVER rail{} -> rail{} ({} recovery)",
                ev.failed_rail,
                ev.takeover_rail,
                fmt_us(ev.recovery_us)
            )
        } else if active == 2 && before == mr.exceptions.failover_count() {
            String::new()
        } else {
            String::new()
        };
        if rep.failovers > 0 || ops % 20 == 0 {
            println!(
                "{ops:3} | {:>9} | {active}     | {:9} | {note}",
                fmt_us(mr.fab.now_us()),
                mr.exceptions.failover_count(),
            );
        }
    }
    let max_rec = mr
        .exceptions
        .events
        .iter()
        .map(|e| e.recovery_us)
        .fold(0.0f64, f64::max);
    println!(
        "\n{} ops, {} failovers, worst detection+migration {} (budget 200ms)",
        ops,
        mr.exceptions.failover_count(),
        fmt_us(max_rec)
    );
    assert!(max_rec < 200_000.0);
    assert!(mr.exceptions.failover_count() >= 2);
    println!("fault tolerance OK: training never stopped, numerics intact");
    Ok(())
}
