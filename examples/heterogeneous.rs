//! Heterogeneous multi-rail demo (paper §5.2.2): TCP + SHARP planes with
//! the cold/hot state machine visible — small payloads ride the RDMA rail
//! alone, large payloads split with converged α coefficients.
//!
//! Run: `cargo run --release --example heterogeneous`

use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::topology::parse_combo;
use nezha::util::bytes::{fmt_bytes, fmt_us};
use nezha::util::table::Table;

fn main() -> nezha::Result<()> {
    for combo in ["tcp-sharp", "tcp-glex"] {
        println!("\n=== {combo} on 4 nodes ===");
        let cfg = Config {
            nodes: 4,
            combo: parse_combo(combo)?,
            policy: Policy::Nezha,
            deterministic: true,
            ..Config::default()
        };
        let mut mr = MultiRail::new(&cfg)?;
        let mut t = Table::new(&["payload", "state", "alpha(RDMA)", "latency", "GB/s"]);
        for kb in [1u64, 32, 256, 2048, 16384, 65536] {
            let bytes = kb * 1024;
            const ELEMS: usize = 1024;
            let elem_bytes = bytes as f64 / ELEMS as f64;
            // warm the data-length table so alpha converges (paper: <100 it)
            let mut last = None;
            for _ in 0..40 {
                let mut buf =
                    UnboundBuffer::from_fn(cfg.nodes, ELEMS, |n, i| ((n + i) % 9) as f32);
                last = Some(mr.allreduce_scaled(&mut buf, elem_bytes)?);
            }
            let rep = last.unwrap();
            let alphas = mr.partitioner.alphas(bytes);
            let (state, alpha) = match &alphas {
                Some(a) => (
                    "hot",
                    a.iter().find(|(r, _)| *r == 1).map(|(_, f)| *f).unwrap_or(0.0),
                ),
                None => ("cold", 1.0),
            };
            t.row(vec![
                fmt_bytes(bytes),
                state.into(),
                format!("{alpha:.2}"),
                fmt_us(rep.total_us),
                format!("{:.3}", rep.throughput_gbps()),
            ]);
        }
        t.print();
    }
    println!("\n(cold = all data on the low-latency RDMA rail; hot = α-split across planes)");
    Ok(())
}
