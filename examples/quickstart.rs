//! Quickstart: build a dual-rail coordinator, allreduce a gradient
//! buffer, inspect the report.
//!
//! Run: `cargo run --release --example quickstart`

use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::topology::parse_combo;
use nezha::util::bytes::{fmt_bytes, fmt_us};

fn main() -> nezha::Result<()> {
    // 4 nodes, dual-rail TCP on the paper's local testbed, Nezha policy
    let cfg = Config {
        nodes: 4,
        combo: parse_combo("tcp-tcp")?,
        policy: Policy::Nezha,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;

    // 8 MB of "gradients": per-node payloads that must sum elementwise
    let elems = 2 * 1024 * 1024;
    println!("allreduce {} across {} nodes over {:?}", fmt_bytes(4 * elems as u64), cfg.nodes, cfg.combo);

    for round in 0..5 {
        let mut buf = UnboundBuffer::from_fn(cfg.nodes, elems, |node, i| {
            (node + 1) as f32 * ((i % 100) as f32 / 100.0)
        });
        let report = mr.allreduce(&mut buf)?;

        // every node now holds the elementwise sum
        let expect = (1..=cfg.nodes).sum::<usize>() as f32 * (50 % 100) as f32 / 100.0;
        assert!((buf.node(0)[50] - expect).abs() < 1e-4);

        println!(
            "round {round}: {} total, {:.3} GB/s, rails used: {}",
            fmt_us(report.total_us),
            report.throughput_gbps(),
            report
                .per_rail
                .iter()
                .filter(|s| s.bytes > 0)
                .map(|s| format!("#{}({})", s.rail, fmt_bytes(s.bytes)))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }

    // small payloads ride the cold-start single-rail path
    let mut small = UnboundBuffer::from_fn(cfg.nodes, 256, |n, i| (n + i) as f32);
    let report = mr.allreduce(&mut small)?;
    println!(
        "1KB payload: {} (cold start, {} rail(s))",
        fmt_us(report.total_us),
        report.per_rail.iter().filter(|s| s.bytes > 0).count()
    );
    Ok(())
}
