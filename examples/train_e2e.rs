//! End-to-end validation (mandated): data-parallel training of the AOT
//! transformer across a simulated multi-rail cluster, logging the loss
//! curve.
//!
//! All layers compose: Pallas kernels → JAX train step → HLO text → rust
//! PJRT runtime → Nezha coordinator → simulated dual-rail fabric. Python
//! is not involved at runtime.
//!
//! Run (after `make artifacts`):
//!   cargo run --release --example train_e2e                      # small model
//!   cargo run --release --example train_e2e -- --model gpt100m --steps 20
//!   cargo run --release --example train_e2e -- --model tiny --steps 300

use nezha::config::{Config, Policy};
use nezha::net::topology::parse_combo;
use nezha::trainer::{train_e2e, E2EConfig};
use nezha::util::cli::Args;

fn main() -> nezha::Result<()> {
    nezha::util::log::init_from_env();
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "small").to_string();
    let steps = args.get_usize(
        "steps",
        match model.as_str() {
            "tiny" => 300,
            "gpt100m" => 20,
            _ => 200,
        },
    );
    let cfg = Config {
        nodes: args.get_usize("nodes", 4),
        combo: parse_combo(args.get_or("combo", "tcp-tcp"))?,
        policy: Policy::Nezha,
        seed: 42,
        ..Config::default()
    };
    let e2e = E2EConfig {
        model: model.clone(),
        steps,
        lr: args.get_f64("lr", 0.05) as f32,
        momentum: 0.9,
        bucket_elems: args.get_usize("bucket-elems", 4 * 1024 * 1024),
        log_every: args.get_usize("log-every", 10),
        use_pjrt_reducer: !args.has("rust-reducer"),
        seed: 7,
    };
    eprintln!(
        "e2e: model={model} steps={steps} nodes={} combo={:?} (reducer: {})",
        cfg.nodes,
        cfg.combo,
        if e2e.use_pjrt_reducer { "AOT Pallas add_pair" } else { "portable rust" }
    );
    let t0 = std::time::Instant::now();
    let logs = train_e2e(&cfg, &e2e)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nstep,loss,comm_ms,compute_ms");
    for l in &logs {
        println!(
            "{},{:.4},{:.2},{:.1}",
            l.step,
            l.loss,
            l.comm_us / 1e3,
            l.compute_wall_us / 1e3
        );
    }
    let first = logs.first().unwrap().loss;
    let last = logs.last().unwrap().loss;
    let comm_total: f64 = logs.iter().map(|l| l.comm_us).sum();
    eprintln!(
        "\nloss {first:.4} -> {last:.4} over {} steps ({:.1}s wall); modeled comm {:.1}ms total",
        logs.len(),
        wall,
        comm_total / 1e3
    );
    assert!(last < first, "training did not reduce the loss");
    Ok(())
}
