"""AOT exporter: lower the L2/L1 computations to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to artifacts/):
  train_step_<cfg>.hlo.txt   (P_pad f32, (B,T+1) i32) -> (loss f32, P_pad f32)
  sgd_update_<cfg>.hlo.txt   (lr, mu, p, g, v) -> (p', v')        [flat ABI]
  reduce_n<N>_<L>.hlo.txt    (N, L) f32 -> (L,) f32               [sum]
  add_pair_<L>.hlo.txt       (L,) + (L,) -> (L,)                  [ring step]
  manifest.json              shapes/dtypes + model ABI for the rust runtime

`make artifacts` runs this once; Python never executes at training time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import CONFIGS, REDUCE_SHAPES
from .kernels import add_pair, reduce_sum, sgd_update

PAD_BLOCK = 65536  # keep flat param vectors SGD/reduce-kernel block aligned


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def padded_len(n: int) -> int:
    return (n + PAD_BLOCK - 1) // PAD_BLOCK * PAD_BLOCK


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(shape, dtype):
    name = {"float32": "f32", "int32": "i32"}[jnp.dtype(dtype).name]
    return {"shape": list(shape), "dtype": name}


def export_train_step(cfg, out_dir, manifest):
    P = cfg.n_params()
    Pp = padded_len(P)

    def step(p_pad, batch):
        loss, g = M.train_step_flat(cfg, p_pad[:P], batch)
        return loss, jnp.concatenate([g, jnp.zeros(Pp - P, jnp.float32)])

    batch_shape = (cfg.batch, cfg.seq_len + 1)
    lowered = jax.jit(step).lower(
        _spec((Pp,), jnp.float32), _spec(batch_shape, jnp.int32)
    )
    name = f"train_step_{cfg.name}"
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"].append({
        "name": name,
        "path": path,
        "inputs": [_io_entry((Pp,), jnp.float32), _io_entry(batch_shape, jnp.int32)],
        "outputs": [_io_entry((), jnp.float32), _io_entry((Pp,), jnp.float32)],
    })
    print(f"  {name}: P={P} padded={Pp} batch={batch_shape}")


def export_sgd(cfg, out_dir, manifest):
    Pp = padded_len(cfg.n_params())

    def upd(lr, mu, p, g, v):
        return sgd_update(p, g, v, lr, mu)

    s1 = _spec((1,), jnp.float32)
    sv = _spec((Pp,), jnp.float32)
    lowered = jax.jit(upd).lower(s1, s1, sv, sv, sv)
    name = f"sgd_update_{cfg.name}"
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"].append({
        "name": name,
        "path": path,
        "inputs": [_io_entry((1,), jnp.float32)] * 2 + [_io_entry((Pp,), jnp.float32)] * 3,
        "outputs": [_io_entry((Pp,), jnp.float32)] * 2,
    })
    print(f"  {name}: padded={Pp}")


def export_reduce(out_dir, manifest):
    lens = sorted({l for _, l in REDUCE_SHAPES})
    for length in lens:
        lowered = jax.jit(add_pair).lower(
            _spec((length,), jnp.float32), _spec((length,), jnp.float32)
        )
        name = f"add_pair_{length}"
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"].append({
            "name": name,
            "path": path,
            "inputs": [_io_entry((length,), jnp.float32)] * 2,
            "outputs": [_io_entry((length,), jnp.float32)],
        })
        print(f"  {name}")
    for n, length in REDUCE_SHAPES:
        lowered = jax.jit(reduce_sum).lower(_spec((n, length), jnp.float32))
        name = f"reduce_n{n}_{length}"
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"].append({
            "name": name,
            "path": path,
            "inputs": [_io_entry((n, length), jnp.float32)],
            "outputs": [_io_entry((length,), jnp.float32)],
        })
        print(f"  {name}")


def export_init_params(cfg, out_dir, manifest):
    """Materialize deterministic initial parameters as a raw f32 binary so
    the rust trainer starts from the same point as the python reference."""
    params = M.init_params(cfg, seed=0)
    flat = M.flatten_params(cfg, params)
    Pp = padded_len(cfg.n_params())
    import numpy as np

    buf = np.zeros(Pp, np.float32)
    buf[: flat.shape[0]] = np.asarray(flat)
    path = f"init_params_{cfg.name}.f32"
    buf.tofile(os.path.join(out_dir, path))
    manifest["init_params"].append({"model": cfg.name, "path": path, "len": Pp})
    print(f"  init_params_{cfg.name}: {Pp} f32")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small",
                    help="comma-separated model configs (tiny,small,gpt100m)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"artifacts": [], "models": [], "init_params": []}
    names = [n for n in args.configs.split(",") if n]
    for n in names:
        cfg = CONFIGS[n]
        print(f"[aot] exporting model '{cfg.name}' ({cfg.n_params()/1e6:.1f}M params)")
        manifest["models"].append({
            "name": cfg.name,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
            "n_params": cfg.n_params(),
            "padded": padded_len(cfg.n_params()),
            "param_shapes": [[nm, list(s)] for nm, s in cfg.param_shapes()],
        })
        export_train_step(cfg, args.out, manifest)
        export_sgd(cfg, args.out, manifest)
        export_init_params(cfg, args.out, manifest)
    print("[aot] exporting reduce kernels")
    export_reduce(args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
