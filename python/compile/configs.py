"""Model / artifact configurations shared by the L2 model and the AOT exporter.

Every config is a fixed-shape contract: the rust runtime loads the lowered
HLO for a config by name and feeds literals with exactly these shapes, so
all dimensions here must match what `model.py` traces.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration.

    Dimensions are chosen MXU/VMEM-friendly (multiples of 128 where it
    matters) so the Pallas kernels tile cleanly — see DESIGN.md §2.
    """

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def param_shapes(self):
        """Ordered (name, shape) list — the flat-parameter ABI used by the
        AOT artifacts and the rust runtime. Order matters."""
        L, D, F, V, T = self.n_layers, self.d_model, self.d_ff, self.vocab, self.seq_len
        return [
            ("emb", (V, D)),
            ("pos", (T, D)),
            ("ln1_scale", (L, D)),
            ("ln1_bias", (L, D)),
            ("w_qkv", (L, D, 3 * D)),
            ("w_out", (L, D, D)),
            ("ln2_scale", (L, D)),
            ("ln2_bias", (L, D)),
            ("w_ff1", (L, D, F)),
            ("b_ff1", (L, F)),
            ("w_ff2", (L, F, D)),
            ("b_ff2", (L, D)),
            ("lnf_scale", (D,)),
            ("lnf_bias", (D,)),
            ("w_head", (D, V)),
        ]

    def n_params(self) -> int:
        return sum(int(__import__("math").prod(s)) for _, s in self.param_shapes())


# Test-size config: fast to trace, compile and execute; used by pytest and
# the rust integration tests.
TINY = ModelConfig(
    name="tiny", vocab=512, d_model=128, n_layers=2, n_heads=4, d_ff=512,
    seq_len=32, batch=2,
)

# Default end-to-end config (~19M params): trains in minutes on the CPU
# PJRT backend while exercising every code path.
SMALL = ModelConfig(
    name="small", vocab=8192, d_model=512, n_layers=4, n_heads=8, d_ff=2048,
    seq_len=64, batch=4,
)

# ~124M params — the mandated ~100M-parameter e2e model (examples/train_e2e
# with --model gpt100m). d=768, L=12, matching GPT-2-small shapes.
GPT100M = ModelConfig(
    name="gpt100m", vocab=32768, d_model=768, n_layers=12, n_heads=12,
    d_ff=3072, seq_len=128, batch=4,
)

CONFIGS = {c.name: c for c in (TINY, SMALL, GPT100M)}

# Reduce-kernel artifact sizes exported for the coordinator hot path:
# (n_way, elements). Ring allreduce uses n=2 (pairwise accumulate); the
# SHARP in-network path aggregates n inputs at the simulated switch.
REDUCE_SHAPES = [
    (2, 65536),
    (2, 262144),
    (4, 65536),
    (4, 262144),
    (8, 65536),
]
