"""L1 Pallas kernels for the paper's compute hot-spots.

- matmul:   MXU-tiled matmul used by every transformer projection (fwd+bwd).
- reduce:   n-way gradient segment reduction — the allreduce aggregation core.
- sgd:      fused momentum-SGD parameter update.
- ref:      pure-jnp oracles for all of the above.
"""

from .matmul import matmul, matmul_raw  # noqa: F401
from .reduce import add_pair, reduce_sum  # noqa: F401
from .sgd import sgd_update  # noqa: F401
