"""L1 Pallas kernel: tiled matmul shaped for the TPU MXU.

The paper's training compute ran on V100s through cuBLAS; per DESIGN.md §2
(hardware adaptation) we re-express the projection matmuls as a Pallas
kernel tiled for the 128x128 systolic MXU with f32 accumulation, and express
the HBM<->VMEM schedule with a (m, n, k) grid + BlockSpecs instead of CUDA
threadblocks.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example
README). Real-TPU efficiency is estimated in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile edges. Shapes smaller than a tile fall back to
# the full dimension (still a single VMEM-resident block).
TILE_M = 128
TILE_N = 128
TILE_K = 512


def _pick(block: int, dim: int) -> int:
    """Largest divisor of `dim` that is <= block (prefer the block itself)."""
    if dim % block == 0:
        return block
    b = min(block, dim)
    while dim % b != 0:
        b -= 1
    return b


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """Grid = (M/bm, N/bn, K/bk); k is the innermost (sequential) axis so the
    output block stays resident while partial products accumulate."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def matmul_raw(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pallas tiled matmul: (M, K) @ (K, N) -> (M, N), f32 accumulate."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contracting mismatch {x.shape} @ {y.shape}"
    bm, bn, bk = _pick(TILE_M, m), _pick(TILE_N, n), _pick(TILE_K, k)
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable wrapper. The VJP is itself two Pallas matmuls, so the
    backward pass also runs through the L1 kernel (dx = g @ y^T, dy = x^T @ g).
    """
    return matmul_raw(x, y)


def _matmul_fwd(x, y):
    return matmul_raw(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    return matmul_raw(g, y.T), matmul_raw(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
