"""L1 Pallas kernel: n-way gradient segment reduction (the allreduce hot-spot).

This is the compute core of the paper's allreduce: every ring step (and the
SHARP in-network aggregation path) sums gradient segments elementwise. On
the paper's testbed the NIC/switch does this; in our TPU-shaped adaptation
the peer axis is pipelined through VMEM with a BlockSpec over (peer-major)
blocks and accumulated in f32 (DESIGN.md §2).

Exported AOT as `reduce_n{N}_{LEN}.hlo.txt` and executed from the rust
coordinator's hot path (rust/src/runtime/).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128-lane-aligned block: (n, 65536) f32 blocks stream through VMEM;
# a (8, 65536) block is 2 MB — comfortably within a 16 MB VMEM budget
# with double buffering.
BLOCK = 65536


def _reduce_kernel(x_ref, o_ref, *, scale: float):
    acc = jnp.sum(x_ref[...], axis=0, dtype=jnp.float32)
    if scale != 1.0:
        acc = acc * jnp.float32(scale)
    o_ref[...] = acc


def reduce_sum(x: jax.Array, *, average: bool = False) -> jax.Array:
    """Sum (or mean) over the leading peer axis: (n, L) f32 -> (L,) f32."""
    n, length = x.shape
    block = BLOCK if length % BLOCK == 0 else _largest_divisor(length, BLOCK)
    scale = 1.0 / n if average else 1.0
    return pl.pallas_call(
        functools.partial(_reduce_kernel, scale=scale),
        grid=(length // block,),
        in_specs=[pl.BlockSpec((n, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((length,), jnp.float32),
        interpret=True,
    )(x)


def _largest_divisor(length: int, cap: int) -> int:
    b = min(cap, length)
    while length % b != 0:
        b -= 1
    return b


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def add_pair(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise accumulate (the ring-step primitive): (L,)+(L,) -> (L,)."""
    (length,) = a.shape
    block = BLOCK if length % BLOCK == 0 else _largest_divisor(length, BLOCK)
    return pl.pallas_call(
        _add_kernel,
        grid=(length // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((length,), jnp.float32),
        interpret=True,
    )(a, b)
