"""Pure-jnp oracles for every L1 Pallas kernel.

pytest (python/tests/) sweeps shapes/dtypes with hypothesis and asserts
allclose between each kernel and its oracle — this is the core correctness
signal for the compile path.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def reduce_sum_ref(x, average: bool = False):
    s = jnp.sum(x, axis=0, dtype=jnp.float32)
    return s / x.shape[0] if average else s


def add_pair_ref(a, b):
    return a + b


def sgd_update_ref(p, g, v, lr, mu):
    v_new = mu[0] * v + g
    return p - lr[0] * v_new, v_new
