"""L1 Pallas kernel: fused momentum-SGD parameter update.

One pass over (param, grad, momentum) per block — no intermediate HBM
round-trips, replacing the framework optimizer the paper's training stack
used. lr/momentum arrive as (1,) f32 operands so a single AOT artifact
serves any schedule.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536


def _largest_divisor(length: int, cap: int) -> int:
    b = min(cap, length)
    while length % b != 0:
        b -= 1
    return b


def _sgd_kernel(lr_ref, mu_ref, p_ref, g_ref, v_ref, po_ref, vo_ref):
    lr = lr_ref[0]
    mu = mu_ref[0]
    v_new = mu * v_ref[...] + g_ref[...]
    vo_ref[...] = v_new
    po_ref[...] = p_ref[...] - lr * v_new


def sgd_update(p: jax.Array, g: jax.Array, v: jax.Array,
               lr: jax.Array, mu: jax.Array):
    """Fused momentum SGD on flat f32 vectors.

    v' = mu * v + g ;  p' = p - lr * v'.  Returns (p', v').
    """
    (length,) = p.shape
    block = BLOCK if length % BLOCK == 0 else _largest_divisor(length, BLOCK)
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    vec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _sgd_kernel,
        grid=(length // block,),
        in_specs=[scalar, scalar, vec, vec, vec],
        out_specs=(vec, vec),
        out_shape=(
            jax.ShapeDtypeStruct((length,), jnp.float32),
            jax.ShapeDtypeStruct((length,), jnp.float32),
        ),
        interpret=True,
    )(lr, mu, p, g, v)
