"""L2: JAX model — decoder-only transformer fwd/bwd calling the L1 kernels.

The paper trains AlexNet/VGG-11 and (via vTrain) GPT-3; the reproduction's
end-to-end workload is a GPT-2-shaped decoder-only transformer. Every
projection matmul goes through the Pallas `matmul` kernel (with its
kernel-based custom VJP), the per-step optimizer is the Pallas `sgd_update`
kernel, and gradient aggregation on the rust side uses the Pallas
`reduce`/`add_pair` kernels.

Layers are scanned over stacked parameters so the lowered HLO size is
independent of depth.

Everything here is build-time only: `aot.py` lowers `train_step` /
`sgd_update_flat` / reduce kernels to HLO text once, and the rust runtime
executes the artifacts; Python never runs on the training path.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import matmul, sgd_update


def init_params(cfg: ModelConfig, seed: int = 0):
    """Initialize parameters as a dict of stacked arrays (see
    ModelConfig.param_shapes for the ABI order)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_bias") or name.startswith("b_"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = fan_in ** -0.5
            params[name] = (std * jax.random.normal(sub, shape)).astype(jnp.float32)
    return params


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _block(cfg: ModelConfig, x, layer):
    """One pre-LN transformer block. x: (B, T, D); layer: dict of this
    layer's (unstacked) parameters."""
    B, T, D = x.shape
    H, Dh = cfg.n_heads, cfg.d_head

    h = _layernorm(x, layer["ln1_scale"], layer["ln1_bias"])
    qkv = matmul(h.reshape(B * T, D), layer["w_qkv"]).reshape(B, T, 3, H, Dh)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]

    # (B, H, T, T) causal attention. Scores stay in plain jnp (einsum) —
    # the MXU-bound projections are the Pallas hot path.
    scores = jnp.einsum("bthd,bshd->bhts", q, k) * (Dh ** -0.5)
    mask = jnp.tril(jnp.ones((T, T), jnp.bool_))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(B * T, D)
    x = x + matmul(attn, layer["w_out"]).reshape(B, T, D)

    h = _layernorm(x, layer["ln2_scale"], layer["ln2_bias"])
    h1 = matmul(h.reshape(B * T, D), layer["w_ff1"]) + layer["b_ff1"]
    h1 = jax.nn.gelu(h1)
    h2 = matmul(h1, layer["w_ff2"]) + layer["b_ff2"]
    return x + h2.reshape(B, T, D)


_LAYER_KEYS = (
    "ln1_scale", "ln1_bias", "w_qkv", "w_out",
    "ln2_scale", "ln2_bias", "w_ff1", "b_ff1", "w_ff2", "b_ff2",
)


def forward(cfg: ModelConfig, params, tokens):
    """tokens: (B, T) int32 -> logits (B, T, V)."""
    B, T = tokens.shape
    x = params["emb"][tokens] + params["pos"][None, :T, :]

    stacked = {k: params[k] for k in _LAYER_KEYS}

    def body(carry, layer):
        return _block(cfg, carry, layer), None

    x, _ = jax.lax.scan(body, x, stacked)
    x = _layernorm(x, params["lnf_scale"], params["lnf_bias"])
    logits = matmul(x.reshape(B * T, cfg.d_model), params["w_head"])
    return logits.reshape(B, T, cfg.vocab)


def loss_fn(cfg: ModelConfig, params, batch):
    """batch: (B, T+1) int32 — next-token cross-entropy."""
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, params, batch):
    """Returns (loss, grads) — grads as a dict matching param_shapes order.
    This is the function AOT-exported per config as `train_step_<name>`."""
    return jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)


def sgd_update_flat(p_flat, g_flat, v_flat, lr, mu):
    """Fused momentum-SGD over the flat parameter vector (Pallas kernel).
    Exported as `sgd_update_<name>`; the rust trainer keeps params/momentum
    as single flat f32 buffers matching the ABI order."""
    return sgd_update(p_flat, g_flat, v_flat, lr, mu)


def flatten_params(cfg: ModelConfig, params) -> jnp.ndarray:
    """Concatenate params into one flat f32 vector in ABI order."""
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in cfg.param_shapes()]
    )


def unflatten_params(cfg: ModelConfig, flat):
    """Inverse of flatten_params."""
    import math

    params, off = {}, 0
    for name, shape in cfg.param_shapes():
        n = int(math.prod(shape))
        params[name] = flat[off:off + n].reshape(shape)
        off += n
    return params


def train_step_flat(cfg: ModelConfig, p_flat, batch):
    """Flat-ABI train step: (P,) f32 + (B, T+1) i32 -> (loss, (P,) grads).
    This is the exact signature the rust runtime executes."""
    params = unflatten_params(cfg, p_flat)
    loss, grads = train_step(cfg, params, batch)
    g_flat = jnp.concatenate(
        [grads[name].reshape(-1) for name, _ in cfg.param_shapes()]
    )
    return loss, g_flat
