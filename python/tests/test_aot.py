"""AOT export path: HLO-text lowering sanity + manifest consistency.

These tests re-lower small computations in-process (fast) and, when
artifacts/ already exists, validate the manifest contract the rust runtime
relies on.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.configs import CONFIGS
from compile.kernels import add_pair

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_lowering_small():
    lowered = jax.jit(add_pair).lower(
        jax.ShapeDtypeStruct((256,), jnp.float32),
        jax.ShapeDtypeStruct((256,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # 64-bit-id-safe interchange: text form only
    assert "f32[256]" in text


def test_padded_len_block_aligned():
    assert aot.padded_len(1) == aot.PAD_BLOCK
    assert aot.padded_len(aot.PAD_BLOCK) == aot.PAD_BLOCK
    assert aot.padded_len(aot.PAD_BLOCK + 1) == 2 * aot.PAD_BLOCK
    for cfg in CONFIGS.values():
        assert aot.padded_len(cfg.n_params()) % aot.PAD_BLOCK == 0


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_contract():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    names = [a["name"] for a in man["artifacts"]]
    assert len(names) == len(set(names))
    for a in man["artifacts"]:
        path = os.path.join(ART, a["path"])
        assert os.path.exists(path), a["path"]
        assert a["inputs"] and a["outputs"]
        for io in a["inputs"] + a["outputs"]:
            assert io["dtype"] in ("f32", "i32")
    for m in man["models"]:
        cfg = CONFIGS[m["name"]]
        assert m["n_params"] == cfg.n_params()
        assert m["padded"] == aot.padded_len(cfg.n_params())
        assert [tuple(s[1]) for s in m["param_shapes"]] == [
            s for _, s in cfg.param_shapes()
        ]
        # every model has its train_step/sgd_update/init_params artifacts
        assert f"train_step_{m['name']}" in names
        assert f"sgd_update_{m['name']}" in names
    for ip in man["init_params"]:
        p = os.path.join(ART, ip["path"])
        assert os.path.getsize(p) == 4 * ip["len"]
