"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (and payload distributions); assert_allclose
against ref.py is the core correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import add_pair, matmul, matmul_raw, reduce_sum, sgd_update
from compile.kernels import ref

RTOL, ATOL = 1e-4, 1e-5


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape).astype(jnp.float32)


# ---------------------------------------------------------------- matmul

@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([8, 32, 64, 128, 192]),
    k=st.sampled_from([16, 64, 128, 512]),
    n=st.sampled_from([8, 64, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    y = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        matmul_raw(x, y), ref.matmul_ref(x, y), rtol=RTOL, atol=ATOL
    )


def test_matmul_non_tile_aligned():
    # dims with no small divisors force the fallback block search
    x = _rand(0, (6, 10))
    y = _rand(1, (10, 14))
    np.testing.assert_allclose(
        matmul_raw(x, y), ref.matmul_ref(x, y), rtol=RTOL, atol=ATOL
    )


def test_matmul_grad_uses_kernel_vjp():
    x = _rand(2, (32, 64))
    y = _rand(3, (64, 16))
    f = lambda a, b: jnp.sum(matmul(a, b) ** 2)
    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    fr = lambda a, b: jnp.sum(ref.matmul_ref(a, b) ** 2)
    gxr, gyr = jax.grad(fr, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, gxr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(gy, gyr, rtol=RTOL, atol=ATOL)


def test_matmul_large_scale_values():
    x = _rand(4, (64, 128), scale=1e3)
    y = _rand(5, (128, 64), scale=1e-3)
    np.testing.assert_allclose(
        matmul_raw(x, y), ref.matmul_ref(x, y), rtol=1e-3, atol=1e-4
    )


# ---------------------------------------------------------------- reduce

@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([2, 3, 4, 8]),
    length=st.sampled_from([128, 1024, 65536, 70000, 131072]),
    seed=st.integers(0, 2**16),
)
def test_reduce_sum_matches_ref(n, length, seed):
    x = _rand(seed, (n, length))
    np.testing.assert_allclose(
        reduce_sum(x), ref.reduce_sum_ref(x), rtol=RTOL, atol=ATOL
    )


def test_reduce_average():
    x = _rand(7, (4, 4096))
    np.testing.assert_allclose(
        reduce_sum(x, average=True),
        ref.reduce_sum_ref(x, average=True),
        rtol=RTOL, atol=ATOL,
    )


@settings(max_examples=10, deadline=None)
@given(
    length=st.sampled_from([64, 4096, 65536, 65537, 262144]),
    seed=st.integers(0, 2**16),
)
def test_add_pair_matches_ref(length, seed):
    a = _rand(seed, (length,))
    b = _rand(seed + 1, (length,))
    np.testing.assert_allclose(
        add_pair(a, b), ref.add_pair_ref(a, b), rtol=RTOL, atol=ATOL
    )


def test_reduce_associativity_invariant():
    """n-way reduce == fold of pairwise adds (what the ring actually does)."""
    x = _rand(11, (4, 8192))
    folded = x[0]
    for i in range(1, 4):
        folded = add_pair(folded, x[i])
    np.testing.assert_allclose(reduce_sum(x), folded, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------- sgd

@settings(max_examples=10, deadline=None)
@given(
    length=st.sampled_from([256, 65536, 65536 * 2, 100000]),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**16),
)
def test_sgd_matches_ref(length, lr, mu, seed):
    p = _rand(seed, (length,))
    g = _rand(seed + 1, (length,))
    v = _rand(seed + 2, (length,))
    lr_a = jnp.array([lr], jnp.float32)
    mu_a = jnp.array([mu], jnp.float32)
    p2, v2 = sgd_update(p, g, v, lr_a, mu_a)
    pr, vr = ref.sgd_update_ref(p, g, v, lr_a, mu_a)
    np.testing.assert_allclose(p2, pr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(v2, vr, rtol=1e-5, atol=1e-6)


def test_sgd_zero_momentum_is_plain_sgd():
    p = _rand(20, (4096,))
    g = _rand(21, (4096,))
    v = jnp.zeros(4096, jnp.float32)
    p2, v2 = sgd_update(p, g, v, jnp.array([0.5], jnp.float32), jnp.array([0.0], jnp.float32))
    np.testing.assert_allclose(p2, p - 0.5 * g, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(v2, g, rtol=1e-6)


def test_sgd_descends_quadratic():
    """Invariant: repeated updates on f(p)=||p||^2/2 shrink the loss."""
    p = _rand(22, (1024,))
    v = jnp.zeros(1024, jnp.float32)
    lr = jnp.array([0.1], jnp.float32)
    mu = jnp.array([0.9], jnp.float32)
    last = float(jnp.sum(p ** 2))
    for _ in range(20):
        p, v = sgd_update(p, p, v, lr, mu)
    assert float(jnp.sum(p ** 2)) < last
