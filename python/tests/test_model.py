"""L2 correctness: model shapes, loss sanity, flat ABI round-trip,
and a short optimization run (loss must decrease)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import CONFIGS, TINY
from compile.kernels import sgd_update


@pytest.fixture(scope="module")
def tiny_setup():
    params = M.init_params(TINY, seed=0)
    key = jax.random.PRNGKey(42)
    batch = jax.random.randint(key, (TINY.batch, TINY.seq_len + 1), 0, TINY.vocab)
    return params, batch


def test_param_shapes_match_abi(tiny_setup):
    params, _ = tiny_setup
    for name, shape in TINY.param_shapes():
        assert params[name].shape == shape, name
    assert TINY.n_params() == sum(int(np.prod(s)) for _, s in TINY.param_shapes())


def test_forward_shape(tiny_setup):
    params, batch = tiny_setup
    logits = M.forward(TINY, params, batch[:, :-1])
    assert logits.shape == (TINY.batch, TINY.seq_len, TINY.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform(tiny_setup):
    params, batch = tiny_setup
    loss = M.loss_fn(TINY, params, batch)
    # random init => loss close to ln(V) (generous band)
    assert abs(float(loss) - np.log(TINY.vocab)) < 1.5


def test_causality(tiny_setup):
    """Changing a future token must not change earlier logits."""
    params, batch = tiny_setup
    inp = batch[:, :-1]
    logits_a = M.forward(TINY, params, inp)
    perturbed = inp.at[:, -1].set((inp[:, -1] + 1) % TINY.vocab)
    logits_b = M.forward(TINY, params, perturbed)
    np.testing.assert_allclose(
        logits_a[:, :-1], logits_b[:, :-1], rtol=1e-5, atol=1e-5
    )


def test_flat_roundtrip(tiny_setup):
    params, _ = tiny_setup
    flat = M.flatten_params(TINY, params)
    assert flat.shape == (TINY.n_params(),)
    back = M.unflatten_params(TINY, flat)
    for name, _ in TINY.param_shapes():
        np.testing.assert_array_equal(params[name], back[name])


def test_train_step_flat_matches_tree(tiny_setup):
    params, batch = tiny_setup
    loss_t, grads_t = M.train_step(TINY, params, batch)
    flat = M.flatten_params(TINY, params)
    loss_f, g_flat = M.train_step_flat(TINY, flat, batch)
    assert abs(float(loss_t) - float(loss_f)) < 1e-5
    g_tree_flat = jnp.concatenate(
        [grads_t[n].reshape(-1) for n, _ in TINY.param_shapes()]
    )
    np.testing.assert_allclose(g_flat, g_tree_flat, rtol=1e-5, atol=1e-6)


def test_grads_nonzero_everywhere(tiny_setup):
    params, batch = tiny_setup
    _, grads = M.train_step(TINY, params, batch)
    for name, _ in TINY.param_shapes():
        assert float(jnp.max(jnp.abs(grads[name]))) > 0, f"dead grad: {name}"


def test_short_training_run_decreases_loss(tiny_setup):
    params, batch = tiny_setup
    flat = M.flatten_params(TINY, params)
    v = jnp.zeros_like(flat)
    lr = jnp.array([0.05], jnp.float32)
    mu = jnp.array([0.9], jnp.float32)
    step = jax.jit(lambda p, b: M.train_step_flat(TINY, p, b))
    first = None
    for _ in range(8):
        loss, g = step(flat, batch)
        if first is None:
            first = float(loss)
        flat, v = sgd_update(flat, g, v, lr, mu)
    assert float(loss) < first - 0.3, (first, float(loss))


def test_all_configs_abi_consistent():
    for cfg in CONFIGS.values():
        shapes = cfg.param_shapes()
        names = [n for n, _ in shapes]
        assert len(names) == len(set(names))
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.n_params() > 0
