//! `cargo bench --bench bench_allreduce` — end-to-end policy comparison
//! across the paper's payload sweep, on homogeneous and heterogeneous
//! combos: the condensed version of Figs. 9/10 plus Table 1, with
//! wall-clock cost of the simulation itself.

use nezha::bench::harness::bench_wall;
use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::topology::parse_combo;
use nezha::util::bytes::fmt_bytes;
use nezha::util::table::Table;

fn measure(combo: &str, nodes: usize, policy: Policy, bytes: u64) -> nezha::Result<f64> {
    let cfg = Config {
        nodes,
        combo: parse_combo(combo)?,
        policy,
        deterministic: true,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    const ELEMS: usize = 1024;
    let elem_bytes = bytes as f64 / ELEMS as f64;
    let warm = if policy == Policy::Nezha { 30 } else { 3 };
    let mut lat = 0.0;
    for i in 0..warm + 5 {
        let mut buf = UnboundBuffer::from_fn(nodes, ELEMS, |n, j| ((n + j) % 7) as f32);
        let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
        if i >= warm {
            lat += rep.total_us;
        }
    }
    Ok(lat / 5.0)
}

fn main() -> nezha::Result<()> {
    for (combo, nodes) in [("tcp-tcp", 4), ("tcp-tcp", 8), ("tcp-sharp", 8), ("tcp-glex", 8)] {
        println!("\n=== allreduce latency (us), {combo}, {nodes} nodes ===");
        let single_combo = match combo {
            "tcp-sharp" => "sharp",
            "tcp-glex" => "glex",
            _ => "tcp",
        };
        let mut t = Table::new(&["size", "single", "MRIB", "MPTCP", "Nezha"]);
        for &s in &[2u64 << 10, 128 << 10, 2 << 20, 8 << 20, 64 << 20] {
            t.row(vec![
                fmt_bytes(s),
                format!("{:.0}", measure(single_combo, nodes, Policy::SingleRail, s)?),
                format!("{:.0}", measure(combo, nodes, Policy::Mrib, s)?),
                format!("{:.0}", measure(combo, nodes, Policy::Mptcp, s)?),
                format!("{:.0}", measure(combo, nodes, Policy::Nezha, s)?),
            ]);
        }
        t.print();
    }

    // wall-clock cost of the coordinator itself (simulation throughput)
    println!("\n=== simulator wall-clock (coordinator overhead) ===");
    let cfg = Config {
        nodes: 8,
        combo: parse_combo("tcp-tcp")?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    let mut t = Table::new(&nezha::bench::BenchStats::header());
    let s = bench_wall("allreduce_8MB_sim_op", 20, 200, || {
        let mut buf = UnboundBuffer::from_fn(8, 1024, |n, j| ((n + j) % 7) as f32);
        mr.allreduce_scaled(&mut buf, 8192.0).unwrap();
    });
    println!("simulated ops/sec: {:.0}", 1e6 / s.mean_us);
    t.row(s.row());
    t.print();
    Ok(())
}
