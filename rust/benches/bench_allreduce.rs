//! `cargo bench --bench bench_allreduce` — end-to-end policy comparison
//! across the paper's payload sweep, on homogeneous and heterogeneous
//! combos: the condensed version of Figs. 9/10 plus Table 1, with
//! wall-clock cost of the simulation itself — plus the collective-planner
//! vs fixed-dispatch sweep (64 KiB → 256 MiB), emitted in the bench
//! harness's JSON result format.

use nezha::bench::harness::{
    bench_wall, plan_quality_fig, planner_mode_latency, straggler_sweep, straggler_sweep_json,
};
use nezha::config::{Config, PlannerMode, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::topology::{parse_combo, ClusterSpec};
use nezha::util::bytes::fmt_bytes;
use nezha::util::json::Json;
use nezha::util::table::Table;

fn measure(combo: &str, nodes: usize, policy: Policy, bytes: u64) -> nezha::Result<f64> {
    let cfg = Config {
        nodes,
        combo: parse_combo(combo)?,
        policy,
        deterministic: true,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    let warm = if policy == Policy::Nezha { 30 } else { 3 };
    nezha::bench::mean_allreduce_us(&mut mr, bytes, warm, 5)
}

/// Planner-vs-fixed-dispatch sweep, 64 KiB → 256 MiB, on the flat local
/// testbed and the grouped pods topology. Emits one JSON document in the
/// bench result format (`util::json`).
fn planner_vs_fixed_json() -> nezha::Result<()> {
    println!("\n=== collective planner vs fixed dispatch (JSON) ===");
    let cases: [(&str, ClusterSpec, &str, usize); 2] = [
        ("local", ClusterSpec::local(), "tcp-tcp", 8),
        ("pods", ClusterSpec::pods(4), "tcp-tcp-tcp-glex", 16),
    ];
    let sizes: [u64; 7] = [
        64 << 10,
        256 << 10,
        1 << 20,
        8 << 20,
        32 << 20,
        64 << 20,
        256 << 20,
    ];
    let mut rows = Vec::new();
    for (cluster_name, cluster, combo, nodes) in &cases {
        for &bytes in &sizes {
            let (fixed_us, _) =
                planner_mode_latency(cluster, combo, *nodes, PlannerMode::Flat, bytes, 30, 5)?;
            let (planner_us, plan) =
                planner_mode_latency(cluster, combo, *nodes, PlannerMode::Auto, bytes, 30, 5)?;
            rows.push(Json::obj(vec![
                ("cluster", Json::from(*cluster_name)),
                ("combo", Json::from(*combo)),
                ("nodes", Json::from(*nodes)),
                ("bytes", Json::from(bytes as f64)),
                ("size", Json::from(fmt_bytes(bytes))),
                ("fixed_us", Json::from(fixed_us)),
                ("planner_us", Json::from(planner_us)),
                ("speedup", Json::from(fixed_us / planner_us)),
                ("plan", Json::from(plan)),
            ]));
        }
    }
    let doc = Json::obj(vec![
        ("bench", Json::from("planner_vs_fixed_dispatch")),
        ("policy", Json::from("nezha")),
        ("results", Json::Arr(rows)),
    ]);
    println!("{}", doc.to_string());
    Ok(())
}

fn main() -> nezha::Result<()> {
    for (combo, nodes) in [("tcp-tcp", 4), ("tcp-tcp", 8), ("tcp-sharp", 8), ("tcp-glex", 8)] {
        println!("\n=== allreduce latency (us), {combo}, {nodes} nodes ===");
        let single_combo = match combo {
            "tcp-sharp" => "sharp",
            "tcp-glex" => "glex",
            _ => "tcp",
        };
        let mut t = Table::new(&["size", "single", "MRIB", "MPTCP", "Nezha"]);
        for &s in &[2u64 << 10, 128 << 10, 2 << 20, 8 << 20, 64 << 20] {
            t.row(vec![
                fmt_bytes(s),
                format!("{:.0}", measure(single_combo, nodes, Policy::SingleRail, s)?),
                format!("{:.0}", measure(combo, nodes, Policy::Mrib, s)?),
                format!("{:.0}", measure(combo, nodes, Policy::Mptcp, s)?),
                format!("{:.0}", measure(combo, nodes, Policy::Nezha, s)?),
            ]);
        }
        t.print();
    }

    // wall-clock cost of the coordinator itself (simulation throughput)
    println!("\n=== simulator wall-clock (coordinator overhead) ===");
    let cfg = Config {
        nodes: 8,
        combo: parse_combo("tcp-tcp")?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    let mut t = Table::new(&nezha::bench::BenchStats::header());
    let s = bench_wall("allreduce_8MB_sim_op", 20, 200, || {
        let mut buf = UnboundBuffer::from_fn(8, 1024, |n, j| ((n + j) % 7) as f32);
        mr.allreduce_scaled(&mut buf, 8192.0).unwrap();
    });
    println!("simulated ops/sec: {:.0}", 1e6 / s.mean_us);
    t.row(s.row());
    t.print();

    planner_vs_fixed_json()?;
    straggler_corrections_json()?;

    // per-plan predicted vs measured across the deterministic sweeps —
    // the plan-quality dashboard document (CI uploads this artifact)
    plan_quality_fig()
}

/// Corrections-vs-static-cost comparison under a persistent straggler on
/// rail 0 of the pods topology (the straggler-replanning acceptance
/// sweep), in the bench JSON format — the canonical sweep shared with
/// `bench::ablation::ablate_straggler`.
fn straggler_corrections_json() -> nezha::Result<()> {
    println!("\n=== straggler corrections: auto vs static-cost (JSON) ===");
    let rows = straggler_sweep()?;
    println!("{}", straggler_sweep_json(&rows).to_string());
    Ok(())
}
