//! `cargo bench --bench bench_figures` — regenerates EVERY table and
//! figure of the paper's evaluation section (DESIGN.md §5 maps ids to the
//! paper). Individual figures: `cargo bench --bench bench_figures -- fig9`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    let id = args.first().map(|s| s.as_str()).unwrap_or("all");
    if let Err(e) = nezha::bench::figures::run(id) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
