//! `cargo bench --bench bench_hotpath` — wall-clock benchmarks of the L3
//! hot paths: the reduction kernels (portable vs AOT Pallas), ring
//! numerics, the partition planner, and the full per-op coordinator
//! overhead. These are the numbers the §Perf pass in EXPERIMENTS.md
//! optimizes.

use std::sync::Arc;

use nezha::bench::harness::{bench_wall, BenchStats};
use nezha::config::{Config, Policy};
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::collective::ring::ring_numerics;
use nezha::coordinator::collective::{Reducer, RustReducer};
use nezha::coordinator::multirail::MultiRail;
use nezha::net::topology::parse_combo;
use nezha::runtime::{Engine, PjrtReducer};
use nezha::util::table::Table;

fn main() -> nezha::Result<()> {
    let mut t = Table::new(&BenchStats::header());
    let mut thr: Vec<(String, f64)> = Vec::new();

    // 1. portable reducer: 1M-element add (4 MB per operand)
    const N: usize = 1 << 20;
    let mut dst = vec![1.0f32; N];
    let src = vec![2.0f32; N];
    let mut red = RustReducer;
    let s = bench_wall("rust_reducer_add_1M", 5, 50, || {
        red.add_into(&mut dst, &src);
    });
    thr.push(("rust_reducer GB/s".into(), (N * 4) as f64 / s.mean_us / 1e3));
    t.row(s.row());

    // 2. AOT Pallas add_pair kernel (pjrt feature + artifacts built)
    if cfg!(feature = "pjrt") && std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Arc::new(Engine::new("artifacts")?);
        let mut pjrt = PjrtReducer::new(engine)?;
        let mut dst = vec![1.0f32; 262144];
        let src = vec![2.0f32; 262144];
        let s = bench_wall("pallas_add_pair_256K", 3, 30, || {
            pjrt.add_into(&mut dst, &src);
        });
        thr.push(("pallas_add_pair GB/s".into(), (262144 * 4) as f64 / s.mean_us / 1e3));
        t.row(s.row());
    }

    // 3. ring numerics: full 4-node reduce-scatter+allgather on 1M elems
    let mut buf = UnboundBuffer::from_fn(4, N, |n, i| ((n + i) % 5) as f32);
    let w = buf.full_window();
    let s = bench_wall("ring_numerics_4x1M", 2, 20, || {
        ring_numerics(&mut buf, w, &mut RustReducer);
    });
    thr.push((
        "ring_numerics effective GB/s".into(),
        // 2(N-1)/N * S bytes touched per node x N nodes
        (2.0 * 3.0 * (N * 4) as f64) / s.mean_us / 1e3,
    ));
    t.row(s.row());

    // 4. full coordinator op (plan + sim + numerics + feedback), small buf
    let cfg = Config {
        nodes: 8,
        combo: parse_combo("tcp-sharp")?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    let s = bench_wall("coordinator_op_overhead", 50, 500, || {
        let mut buf = UnboundBuffer::from_fn(8, 256, |n, j| ((n + j) % 7) as f32);
        mr.allreduce_scaled(&mut buf, 32768.0).unwrap();
    });
    t.row(s.row());

    // 5. planner alone at steady state
    let s = bench_wall("plan_only_hot_path", 50, 2000, || {
        let healthy = mr.fab.healthy_rails();
        let _ = mr.partitioner.plan(&mr.fab, &mr.timer, &healthy, 8 << 20);
    });
    t.row(s.row());

    t.print();
    println!();
    for (name, v) in thr {
        println!("{name}: {v:.2}");
    }
    Ok(())
}
