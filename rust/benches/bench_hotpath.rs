//! `cargo bench --bench bench_hotpath [-- quick]` — wall-clock benchmark
//! of the collective hot path: before/after ops-per-second of the modeled
//! allreduce sweep (fresh-allocation vs pooled data plane), reduction
//! kernel GB/s (portable `add_into` + fused `reduce_copy`), and the
//! coordinator micro-overheads. Writes the tracked `BENCH_hotpath.json`
//! trajectory at the repo root (uploaded as a CI artifact; see DESIGN.md
//! for the methodology).

use nezha::bench::harness::{bench_wall, BenchStats};
use nezha::bench::hotpath;
use nezha::coordinator::buffer::UnboundBuffer;
use nezha::coordinator::collective::ring::ring_numerics;
use nezha::coordinator::collective::{Reducer, RustReducer};
use nezha::util::table::Table;

fn main() -> nezha::Result<()> {
    let quick = std::env::args().any(|a| a == "quick" || a == "--quick");

    // 1. the tracked sweep + kernel document (writes BENCH_hotpath.json)
    let doc = hotpath::write_report(quick)?;
    let mut t = Table::new(&["size", "before ops/s", "after ops/s", "speedup"]);
    if let Some(rows) = doc.get("sweep").and_then(|s| s.as_arr()) {
        for r in rows {
            t.row(vec![
                r.get("size").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
                format!("{:.0}", r.get("before_ops_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                format!("{:.0}", r.get("after_ops_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                format!("{:.2}x", r.get("speedup").and_then(|v| v.as_f64()).unwrap_or(0.0)),
            ]);
        }
    }
    t.print();
    if let Some(ex) = doc.get("exec") {
        let mut te = Table::new(&["size", "serial ops/s", "parallel ops/s", "speedup"]);
        if let Some(rows) = ex.get("sweep").and_then(|s| s.as_arr()) {
            for r in rows {
                te.row(vec![
                    r.get("size").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
                    format!("{:.1}", r.get("serial_ops_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                    format!("{:.1}", r.get("parallel_ops_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                    format!("{:.2}x", r.get("speedup").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                ]);
            }
        }
        println!("\nserial vs parallel executor (physical payloads):");
        te.print();
    }
    if let Some(k) = doc.get("kernels") {
        println!(
            "kernels ({} lanes): add_into {:.2} GB/s, reduce_copy {:.2} GB/s",
            k.get("lanes").and_then(|v| v.as_f64()).unwrap_or(0.0),
            k.get("add_into_gbps").and_then(|v| v.as_f64()).unwrap_or(0.0),
            k.get("reduce_copy_gbps").and_then(|v| v.as_f64()).unwrap_or(0.0),
        );
        if let Some(ws) = k.get("width_sweep").and_then(|s| s.as_arr()) {
            for r in ws {
                println!(
                    "  {} lanes: add {:.2} GB/s, reduce_copy {:.2} GB/s",
                    r.get("lanes").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    r.get("add_into_gbps").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    r.get("reduce_copy_gbps").and_then(|v| v.as_f64()).unwrap_or(0.0),
                );
            }
        }
    }
    if let Some(p) = doc.get("policy_sim") {
        println!(
            "policy sim: {:.2}s wall, {:.0} modeled ops/s",
            p.get("wall_seconds").and_then(|v| v.as_f64()).unwrap_or(0.0),
            p.get("ops_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0),
        );
    }

    // 2. micro: full 4-node ring numerics on 1M elems (fused kernels)
    const N: usize = 1 << 20;
    let mut micro = Table::new(&BenchStats::header());
    let mut buf = UnboundBuffer::from_fn(4, N, |n, i| ((n + i) % 5) as f32);
    let w = buf.full_window();
    let s = bench_wall("ring_numerics_4x1M", 2, 20, || {
        ring_numerics(&mut buf, w, &mut RustReducer);
    });
    micro.row(s.row());
    let mut dst = vec![1.0f32; N];
    let src = vec![2.0f32; N];
    let mut red = RustReducer;
    let s = bench_wall("rust_reducer_add_1M", 5, 50, || {
        red.add_into(&mut dst, &src);
    });
    micro.row(s.row());
    micro.print();

    println!("\nwrote {}", hotpath::report_path());
    Ok(())
}
