//! Fixed-share partitioner: pins a static (rail, fraction) table.
//!
//! Used by the ablation studies — Table 1's 99/1 and 1/99 splits and
//! Fig. 14's per-member-network latency probes.

use crate::coordinator::control::timer::Timer;
use crate::coordinator::multirail::{Partitioner, Shares};
use crate::net::simnet::Fabric;

#[derive(Debug)]
pub struct FixedShares {
    pub shares: Vec<(usize, f64)>,
}

impl FixedShares {
    pub fn new(shares: Vec<(usize, f64)>) -> FixedShares {
        let total: f64 = shares.iter().map(|(_, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-6, "fractions must sum to 1");
        FixedShares { shares }
    }

    /// Table 1 notation: x% to rail 0, y% to rail 1.
    pub fn percent(x: u32, y: u32) -> FixedShares {
        FixedShares::new(vec![
            (0, x as f64 / 100.0),
            (1, y as f64 / 100.0),
        ])
    }
}

impl Partitioner for FixedShares {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn plan(
        &mut self,
        _fab: &Fabric,
        _timer: &Timer,
        healthy: &[usize],
        _bytes: u64,
        out: &mut Shares,
    ) {
        out.clear();
        out.fracs.extend(
            self.shares
                .iter()
                .filter(|(r, _)| healthy.contains(r))
                .cloned(),
        );
        let total: f64 = out.fracs.iter().map(|(_, f)| f).sum();
        if total <= 0.0 {
            out.set_single(healthy[0]);
        } else {
            for (_, f) in &mut out.fracs {
                *f /= total;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::ProtoKind;
    use crate::net::topology::ClusterSpec;

    #[test]
    fn percent_split() {
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Sharp])
            .unwrap();
        let f = Fabric::new(4, rails, CpuPool::default(), 1);
        let t = Timer::new(10);
        let mut p = FixedShares::percent(99, 1);
        let mut out = Shares::default();
        p.plan(&f, &t, &[0, 1], 1 << 20, &mut out);
        assert!(out.packet_bytes.is_none());
        assert!((out.fracs[0].1 - 0.99).abs() < 1e-9);
    }

    #[test]
    fn renormalizes_on_failure() {
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp])
            .unwrap();
        let f = Fabric::new(4, rails, CpuPool::default(), 1);
        let t = Timer::new(10);
        let mut p = FixedShares::percent(50, 50);
        let mut out = Shares::default();
        p.plan(&f, &t, &[1], 1024, &mut out);
        assert_eq!(out.fracs, vec![(1, 1.0)]);
        // scratch reuse leaves no stale entries behind
        p.plan(&f, &t, &[0, 1], 1024, &mut out);
        assert_eq!(out.fracs.len(), 2);
    }
}
