//! Baseline multi-rail data-distribution policies the paper compares
//! against (§5.1): MPTCP's ECF packet slicing, MRIB's static bandwidth
//! weights, and the single-rail (Gloo-like) baseline.

pub mod fixed;
pub mod mptcp;
pub mod mrib;
pub mod single_rail;

pub use fixed::FixedShares;
pub use mptcp::Mptcp;
pub use mrib::Mrib;
pub use single_rail::SingleRail;
