//! MPTCP baseline with the ECF scheduler (Lim et al., CoNEXT'17).
//!
//! MPTCP aggregates bandwidth by slicing the payload into packets and
//! assigning each to the subflow with the earliest predicted completion
//! (RTT/bandwidth-estimate driven). The paper's criticisms (§2.2.1,
//! Table 1, §5.2): per-slice metadata/reassembly overhead (18–27% extra
//! latency), and completion-time prediction that cannot account for
//! heterogeneous *collective* protocols — the TCP subflow becomes the
//! systemic straggler.
//!
//! The slicing execution (per-packet ECF assignment + overhead) lives in
//! [`crate::coordinator::multirail::MultiRail::allreduce_scaled`]'s
//! `Slices` path; this type only chooses the packet size.

use crate::coordinator::control::timer::Timer;
use crate::coordinator::multirail::{Partitioner, Shares};
use crate::net::simnet::Fabric;

#[derive(Debug)]
pub struct Mptcp {
    /// Slice (packet) size in bytes — 64 KB default, the MSS-coalesced
    /// burst ECF schedules at.
    pub packet_bytes: u64,
}

impl Default for Mptcp {
    fn default() -> Self {
        Mptcp { packet_bytes: 64 * 1024 }
    }
}

impl Partitioner for Mptcp {
    fn name(&self) -> &'static str {
        "MPTCP"
    }

    fn plan(
        &mut self,
        _fab: &Fabric,
        _timer: &Timer,
        _healthy: &[usize],
        bytes: u64,
        out: &mut Shares,
    ) {
        // small payloads still get sliced (one packet) but MPTCP always
        // engages all subflows' machinery — reflected in the sync cost
        // charged for multi-rail ops
        let _ = bytes;
        out.set_slices(self.packet_bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::ProtoKind;
    use crate::net::topology::ClusterSpec;

    #[test]
    fn always_slices() {
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp])
            .unwrap();
        let f = Fabric::new(4, rails, CpuPool::default(), 1);
        let t = Timer::new(100);
        let mut m = Mptcp::default();
        let mut out = Shares::default();
        m.plan(&f, &t, &[0, 1], 1 << 26, &mut out);
        assert_eq!(out.packet_bytes, Some(65536));
        assert!(out.fracs.is_empty());
        m.plan(&f, &t, &[0, 1], 100, &mut out);
        assert_eq!(out.packet_bytes, Some(65536));
    }
}
