//! MRIB baseline (Liu, Vishnu, Panda, SC'04): multi-rail InfiniBand with
//! virtual subchannels and **static bandwidth-proportional** data
//! allocation weights, mildly adjusted on sustained delay imbalance.
//!
//! The paper's criticism (§2.2.1, §5.2): MRIB sets weights from NIC
//! bandwidth alone, so in heterogeneous combos (both NICs 100 Gbps but
//! SHARP/GLEX ≫ TCP in allreduce-effective throughput) it splits ~50/50
//! and the TCP rail drags the op; and it always splits, paying sync
//! overhead on small payloads too.

use crate::coordinator::control::timer::Timer;
use crate::coordinator::multirail::{Partitioner, Shares};
use crate::net::simnet::Fabric;

#[derive(Debug)]
pub struct Mrib {
    /// Static (rail, weight) table set at init from NIC wire bandwidth.
    weights: Vec<(usize, f64)>,
    /// Slow EMA of per-rail delay used for the (bounded) dynamic
    /// adjustment MRIB applies under congestion.
    delay_ema: Vec<(usize, f64)>,
}

impl Mrib {
    /// Initialization-time bandwidth probe: weights ∝ NIC wire speed.
    pub fn from_fabric(fab: &Fabric) -> Mrib {
        let total: f64 = fab.rails.iter().map(|r| r.nic.gbps).sum();
        let weights = fab
            .rails
            .iter()
            .enumerate()
            .map(|(i, r)| (i, r.nic.gbps / total))
            .collect();
        Mrib { weights, delay_ema: Vec::new() }
    }

    fn ema_for(&self, rail: usize) -> Option<f64> {
        self.delay_ema.iter().find(|(r, _)| *r == rail).map(|(_, d)| *d)
    }
}

impl Partitioner for Mrib {
    fn name(&self) -> &'static str {
        "MRIB"
    }

    fn plan(
        &mut self,
        _fab: &Fabric,
        _timer: &Timer,
        healthy: &[usize],
        _bytes: u64,
        out: &mut Shares,
    ) {
        // static weights over the healthy subset, renormalized; bounded
        // delay-based correction (±30% max — MRIB targets transient
        // congestion, not protocol heterogeneity)
        out.clear();
        out.fracs.extend(
            self.weights
                .iter()
                .filter(|(r, _)| healthy.contains(r))
                .map(|&(r, w)| {
                    let adj = match self.ema_for(r) {
                        Some(d) if d > 0.0 => {
                            let avg: f64 = healthy
                                .iter()
                                .filter_map(|&h| self.ema_for(h))
                                .sum::<f64>()
                                / healthy.len() as f64;
                            (avg / d).clamp(0.7, 1.3)
                        }
                        _ => 1.0,
                    };
                    (r, w * adj)
                }),
        );
        let total: f64 = out.fracs.iter().map(|(_, w)| w).sum();
        for (_, w) in &mut out.fracs {
            *w /= total;
        }
    }

    fn feedback(&mut self, _fab: &Fabric, _bytes: u64, shares: &[(usize, u64, f64)]) {
        for &(rail, bytes, t) in shares {
            if bytes == 0 {
                continue;
            }
            // normalize to per-byte delay so sizes don't skew the EMA
            let d = t / bytes as f64;
            match self.delay_ema.iter_mut().find(|(r, _)| *r == rail) {
                Some((_, e)) => *e = 0.95 * *e + 0.05 * d,
                None => self.delay_ema.push((rail, d)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::ProtoKind;
    use crate::net::topology::ClusterSpec;

    fn fab(kinds: &[ProtoKind]) -> Fabric {
        let rails = ClusterSpec::local().build_rails(kinds).unwrap();
        Fabric::new(4, rails, CpuPool::default(), 1).deterministic()
    }

    fn shares_of(m: &mut Mrib, f: &Fabric, healthy: &[usize], bytes: u64) -> Vec<(usize, f64)> {
        let t = Timer::new(100);
        let mut out = Shares::default();
        m.plan(f, &t, healthy, bytes, &mut out);
        assert!(out.packet_bytes.is_none());
        out.fracs
    }

    #[test]
    fn equal_bandwidth_gives_even_split() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp]);
        let mut m = Mrib::from_fabric(&f);
        let s = shares_of(&mut m, &f, &[0, 1], 1 << 20);
        assert!((s[0].1 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn heterogeneous_ignores_protocol_performance() {
        // TCP 100G vs SHARP 100G: MRIB splits 50/50 despite SHARP being
        // far faster in allreduce — the paper's key criticism.
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Sharp]);
        let mut m = Mrib::from_fabric(&f);
        let s = shares_of(&mut m, &f, &[0, 1], 1 << 20);
        assert!((s[0].1 - 0.5).abs() < 0.01, "{s:?}");
    }

    #[test]
    fn glex_combo_weights_by_wire_speed() {
        // TCP Eth 100G vs GLEX TH 128G → 100/228 vs 128/228
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Glex]);
        let mut m = Mrib::from_fabric(&f);
        let s = shares_of(&mut m, &f, &[0, 1], 1 << 20);
        assert!((s[0].1 - 100.0 / 228.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn always_splits_even_small_payloads() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp]);
        let mut m = Mrib::from_fabric(&f);
        let s = shares_of(&mut m, &f, &[0, 1], 2048);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn delay_feedback_is_bounded() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp]);
        let mut m = Mrib::from_fabric(&f);
        // rail 0 persistently 10x slower
        for _ in 0..200 {
            m.feedback(&f, 1 << 20, &[(0, 1 << 19, 100_000.0), (1, 1 << 19, 10_000.0)]);
        }
        let s = shares_of(&mut m, &f, &[0, 1], 1 << 20);
        let w0 = s.iter().find(|(r, _)| *r == 0).unwrap().1;
        // adjusted but clamped: never below ~0.35/(0.35+0.65)
        assert!(w0 > 0.3 && w0 < 0.5, "w0 = {w0}");
    }

    #[test]
    fn failed_rail_excluded() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp]);
        let mut m = Mrib::from_fabric(&f);
        let s = shares_of(&mut m, &f, &[1], 1 << 20);
        assert_eq!(s, vec![(1, 1.0)]);
    }
}
