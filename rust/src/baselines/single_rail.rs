//! Single-rail baseline: the Gloo/NCCL/MPI default of binding the whole
//! allreduce to one network plane (§2's "static single-rail binding").

use crate::coordinator::control::timer::Timer;
use crate::coordinator::multirail::{Partitioner, Shares};
use crate::net::simnet::Fabric;

#[derive(Debug)]
pub enum SingleRail {
    /// Always pick the (estimated) lowest-latency healthy rail — what
    /// frameworks do at init ("default to the lowest-latency single link").
    Best,
    /// Pin to a specific rail regardless of performance.
    Pinned(usize),
}

impl SingleRail {
    pub fn best() -> SingleRail {
        SingleRail::Best
    }

    pub fn pinned(rail: usize) -> SingleRail {
        SingleRail::Pinned(rail)
    }
}

impl Partitioner for SingleRail {
    fn name(&self) -> &'static str {
        "single-rail"
    }

    fn plan(
        &mut self,
        fab: &Fabric,
        _timer: &Timer,
        healthy: &[usize],
        bytes: u64,
        out: &mut Shares,
    ) {
        let rail = match self {
            SingleRail::Pinned(r) if healthy.contains(r) => *r,
            _ => healthy
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    fab.estimate_allreduce_us(a, bytes as f64)
                        .partial_cmp(&fab.estimate_allreduce_us(b, bytes as f64))
                        .unwrap()
                })
                .expect("no healthy rail"),
        };
        out.set_single(rail);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::ProtoKind;
    use crate::net::topology::ClusterSpec;

    fn fab(kinds: &[ProtoKind]) -> Fabric {
        let rails = ClusterSpec::local().build_rails(kinds).unwrap();
        Fabric::new(4, rails, CpuPool::default(), 1).deterministic()
    }

    #[test]
    fn best_picks_fastest() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Glex]);
        let t = Timer::new(100);
        let mut s = SingleRail::best();
        let mut out = Shares::default();
        s.plan(&f, &t, &[0, 1], 8 << 20, &mut out);
        assert_eq!(out.fracs, vec![(1, 1.0)]);
    }

    #[test]
    fn pinned_respects_health() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp]);
        let t = Timer::new(100);
        let mut s = SingleRail::pinned(1);
        let mut out = Shares::default();
        s.plan(&f, &t, &[0], 1024, &mut out);
        assert_eq!(out.fracs, vec![(0, 1.0)]);
    }
}
