//! Ablation studies over Nezha's design choices (DESIGN.md §5 extras):
//! the divergence tolerance τ, the cross-rail sync-overhead charge, the
//! gradient-descent step η, the Timer window, and the collective planner
//! vs the seed's fixed flat-ring dispatch.
//!
//! Run: `cargo run --release -- fig ablate`

use crate::config::{Config, PlannerMode, Policy};
use crate::coordinator::buffer::BufferPool;
use crate::coordinator::multirail::MultiRail;
use crate::net::protocol::ProtoKind;
use crate::net::topology::{parse_combo, ClusterSpec};
use crate::trainer::bucket::Bucketizer;
use crate::util::bytes::fmt_bytes;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

const ELEMS: usize = 1024;

fn mk(combo: &[ProtoKind], nodes: usize, patch: impl Fn(&mut Config)) -> Result<MultiRail> {
    let mut cfg = Config {
        nodes,
        combo: combo.to_vec(),
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    patch(&mut cfg);
    MultiRail::new(&cfg)
}

fn mean_lat(mr: &mut MultiRail, bytes: u64, warm: usize, reps: usize) -> Result<f64> {
    crate::bench::harness::mean_allreduce_us(mr, bytes, warm, reps)
}

/// τ ablation: with τ too small Nezha never splits (loses the large-
/// payload gain); with τ huge it splits across hopeless rails (loses the
/// small-payload RDMA advantage). τ = 5 sits at the knee.
pub fn ablate_tau() -> Result<()> {
    println!("\n=== Ablation: divergence tolerance τ (TCP-SHARP, 4 nodes) ===");
    let mut t = Table::new(&["tau", "64KB (us)", "16MB (us)", "64MB (us)"]);
    for tau in [1.0, 2.0, 5.0, 20.0, 1e9] {
        let mut mr = mk(&[ProtoKind::Tcp, ProtoKind::Sharp], 4, |c| c.control.tau = tau)?;
        let small = mean_lat(&mut mr, 64 << 10, 20, 5)?;
        let mid = mean_lat(&mut mr, 16 << 20, 30, 5)?;
        let large = mean_lat(&mut mr, 64 << 20, 30, 5)?;
        let label = if tau >= 1e9 { "inf".into() } else { format!("{tau:.0}") };
        t.row(vec![
            label,
            format!("{small:.0}"),
            format!("{mid:.0}"),
            format!("{large:.0}"),
        ]);
    }
    t.print();
    println!("(τ=5 keeps the 64KB cold-start fast AND the 64MB split active)");
    Ok(())
}

/// η ablation: convergence speed of the α table vs the learning rate.
pub fn ablate_eta() -> Result<()> {
    println!("\n=== Ablation: balancer step η — ops until scheduling error <10% (TCP-GLEX, 16MB) ===");
    let mut t = Table::new(&["eta", "ops to converge", "final sched err"]);
    for eta in [0.05, 0.1, 0.3, 0.6, 0.9] {
        let mut mr = mk(&[ProtoKind::Tcp, ProtoKind::Glex], 4, |c| c.control.eta = eta)?;
        let elem_bytes = (16u64 << 20) as f64 / ELEMS as f64;
        let mut converged_at = None;
        let mut last_err = 1.0;
        let mut pool = BufferPool::new();
        for op in 0..100 {
            let mut buf = pool.acquire(4, ELEMS, |n, j| ((n + j) % 7) as f32);
            let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
            pool.release(buf);
            let times: Vec<f64> = rep
                .per_rail
                .iter()
                .filter(|s| s.bytes > 0)
                .map(|s| s.time_us)
                .collect();
            if times.len() == 2 {
                last_err = (times[0] - times[1]).abs() / times[0].max(times[1]);
                if last_err < 0.10 && converged_at.is_none() {
                    converged_at = Some(op);
                }
            }
        }
        t.row(vec![
            format!("{eta}"),
            converged_at.map(|o| o.to_string()).unwrap_or(">100".into()),
            format!("{:.1}%", last_err * 100.0),
        ]);
    }
    t.print();
    println!("(paper: convergence within the first 100 iterations — default η=0.3)");
    Ok(())
}

/// Timer-window ablation: the 100-op averaging window damps decision
/// noise; window=1 chases jitter.
pub fn ablate_timer_window() -> Result<()> {
    println!("\n=== Ablation: Timer window (jittered fabric, TCP-TCP, 8MB) ===");
    let mut t = Table::new(&["window", "mean latency (us)"]);
    for window in [1usize, 10, 100] {
        let mut cfg = Config {
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: false, // jitter ON: the window's reason to exist
            seed: 7,
            ..Config::default()
        };
        cfg.control.timer_window = window;
        let mut mr = MultiRail::new(&cfg)?;
        let lat = mean_lat(&mut mr, 8 << 20, 50, 50)?;
        t.row(vec![format!("{window}"), format!("{lat:.0}")]);
    }
    t.print();
    Ok(())
}

/// Adaptive vs static CPU allocation end-to-end (proposition 2).
pub fn ablate_alloc() -> Result<()> {
    println!("\n=== Ablation: adaptive vs static CPU allocation (TCP-GLEX, 8MB, 4 nodes) ===");
    use crate::net::cpu_pool::AllocPolicy;
    let mut t = Table::new(&["alloc", "latency (us)"]);
    for (name, alloc) in [("adaptive", AllocPolicy::Adaptive), ("static", AllocPolicy::StaticEqual)] {
        let mut mr = mk(&[ProtoKind::Tcp, ProtoKind::Glex], 4, |c| c.alloc = alloc)?;
        let lat = mean_lat(&mut mr, 8 << 20, 30, 5)?;
        t.row(vec![name.into(), format!("{lat:.0}")]);
    }
    t.print();
    println!("(paper §2.3.2: static partitioning starves the scalable RDMA planes)");
    Ok(())
}

/// Collective planner ablation: the topology-aware planner against the
/// seed's fixed flat-ring dispatch, on the paper's flat local testbed and
/// on the grouped 16-node × 4-rail pods topology where the hierarchical
/// two-level schedule engages.
pub fn ablate_planner() -> Result<()> {
    println!("\n=== Ablation: collective planner vs fixed flat-ring dispatch ===");
    let mut t = Table::new(&["topology", "size", "fixed (us)", "planner (us)", "gain", "plan"]);
    let cases: [(&str, ClusterSpec, &str, usize); 2] = [
        ("local 8n x 2r", ClusterSpec::local(), "tcp-tcp", 8),
        ("pods 16n x 4r", ClusterSpec::pods(4), "tcp-tcp-tcp-glex", 16),
    ];
    for (label, cluster, combo, nodes) in cases {
        for &bytes in &[512u64 << 10, 8 << 20, 64 << 20] {
            let run = |mode| {
                crate::bench::harness::planner_mode_latency(
                    &cluster, combo, nodes, mode, bytes, 30, 5,
                )
            };
            let (fixed, _) = run(PlannerMode::Flat)?;
            let (auto, plan) = run(PlannerMode::Auto)?;
            t.row(vec![
                label.into(),
                fmt_bytes(bytes),
                format!("{fixed:.0}"),
                format!("{auto:.0}"),
                format!("{:+.0}%", (fixed / auto - 1.0) * 100.0),
                plan,
            ]);
        }
    }
    t.print();

    // bucket plan annotations: what a VGG-sized flat gradient's fusion
    // buckets would each run (pods topology, 4MB buckets)
    let cfg = Config {
        cluster: ClusterSpec::pods(4),
        nodes: 16,
        combo: parse_combo("tcp-tcp-tcp-glex")?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    let buckets = Bucketizer::new(32 << 20, 8 << 20); // 128MB grads, 32MB buckets
    println!("\nbucket plan annotations (128MB flat gradient, 32MB fusion buckets):");
    for bp in buckets.annotate(&mut mr, 4.0) {
        println!(
            "  [{:>9} elems @ {:>9}] multirail={} plan: {}",
            bp.window.len,
            bp.window.offset,
            bp.is_multirail(),
            bp.plan.as_ref().map(|p| p.label()).unwrap_or_else(|| "-".into()),
        );
    }
    println!("(two-level engages on the pods topology; flat clusters keep seed behaviour)");
    Ok(())
}

/// Straggler-correction ablation: planner=auto (Timer-corrected costs,
/// straggler-aware replanning) against planner=static-cost (a-priori α-β
/// model only) with a persistent per-message straggler injected on one
/// rail of the grouped pods topology. Emits the comparison as a JSON doc
/// in the bench result format (the acceptance artifact for the
/// straggler-replanning milestone).
pub fn ablate_straggler() -> Result<()> {
    use crate::bench::harness::{straggler_sweep, straggler_sweep_json};
    println!("\n=== Ablation: measurement-corrected planner vs static cost under a straggler ===");
    println!("(pods 16n x 2r TCP, persistent per-message stall on rail 0)");
    let rows = straggler_sweep()?;
    let mut t = Table::new(&[
        "size", "stall", "static-cost (us)", "auto (us)", "gain", "auto plan",
    ]);
    for r in &rows {
        t.row(vec![
            fmt_bytes(r.bytes),
            format!("{:.0}us", r.stall_us),
            format!("{:.0}", r.static_us),
            format!("{:.0}", r.auto_us),
            format!("{:+.1}%", (r.static_us / r.auto_us - 1.0) * 100.0),
            r.auto_plan.clone(),
        ]);
    }
    t.print();
    println!("{}", straggler_sweep_json(&rows).to_string());
    println!("(corrections shift the straggler rail to fewer-round schedules; static cost cannot)");
    Ok(())
}

/// The canonical multi-level topology sweep: racked-pods supercluster
/// (32 nodes, racks of 4 inside pods of 16), dual TCP rails, `(bytes)`
/// cases spanning latency- to bandwidth-bound payloads. Shared by the
/// ablation table and the JSON artifact so the two cannot drift apart.
pub const MULTILEVEL_SWEEP_NODES: usize = 32;
pub const MULTILEVEL_SWEEP_CASES: [u64; 3] = [4 << 20, 64 << 20, 256 << 20];

/// One multi-level-vs-two-level-vs-flat comparison at a payload size.
#[derive(Debug, Clone)]
pub struct MultiLevelRow {
    pub bytes: u64,
    /// Fixed flat-ring dispatch (`planner = flat`).
    pub flat_us: f64,
    /// Auto planner on the rack-only (one-level) view of the same
    /// cluster — exactly the pre-PR two-level planner's search space.
    pub two_us: f64,
    pub two_plan: String,
    /// Auto planner on the full rack < pod tree.
    pub multi_us: f64,
    pub multi_plan: String,
}

/// Run the canonical multi-level sweep (see [`MULTILEVEL_SWEEP_CASES`]).
pub fn multilevel_sweep() -> Result<Vec<MultiLevelRow>> {
    let full = ClusterSpec::racked_pods(4, 16);
    // the two-level baseline sees only the rack level — the exact search
    // space the planner had before multi-level cuts existed
    let mut rack_only = full.clone();
    rack_only.topo.levels.truncate(1);
    let nodes = MULTILEVEL_SWEEP_NODES;
    let combo = "tcp-tcp";
    let run = crate::bench::harness::planner_mode_latency;
    let mut rows = Vec::new();
    for &bytes in &MULTILEVEL_SWEEP_CASES {
        let (flat_us, _) = run(&full, combo, nodes, PlannerMode::Flat, bytes, 25, 5)?;
        let (two_us, two_plan) = run(&rack_only, combo, nodes, PlannerMode::Auto, bytes, 25, 5)?;
        let (multi_us, multi_plan) = run(&full, combo, nodes, PlannerMode::Auto, bytes, 25, 5)?;
        rows.push(MultiLevelRow { bytes, flat_us, two_us, two_plan, multi_us, multi_plan });
    }
    Ok(rows)
}

/// The multi-level-topology JSON document for a sweep's rows (bench
/// result format; uploaded as a CI artifact).
pub fn multilevel_sweep_json(rows: &[MultiLevelRow]) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("bytes", Json::from(r.bytes as f64)),
                ("size", Json::from(fmt_bytes(r.bytes))),
                ("flat_us", Json::from(r.flat_us)),
                ("two_level_us", Json::from(r.two_us)),
                ("two_level_plan", Json::from(r.two_plan.clone())),
                ("multi_level_us", Json::from(r.multi_us)),
                ("multi_level_plan", Json::from(r.multi_plan.clone())),
                ("speedup_vs_flat", Json::from(r.flat_us / r.multi_us)),
                ("speedup_vs_two_level", Json::from(r.two_us / r.multi_us)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::from("multilevel_topology")),
        ("cluster", Json::from("racked-pods")),
        ("combo", Json::from("tcp-tcp")),
        ("nodes", Json::from(MULTILEVEL_SWEEP_NODES as f64)),
        ("rack", Json::from(4.0)),
        ("pod", Json::from(16.0)),
        ("results", Json::Arr(results)),
    ])
}

/// Multi-level topology ablation: the N-level planner against the
/// two-level (rack-cut-only) planner and the fixed flat ring on the
/// racked-pods supercluster. The JSON document is the last printed line
/// (CI captures it as the `multilevel_ablation.json` artifact).
pub fn ablate_multilevel() -> Result<()> {
    println!("\n=== Ablation: multi-level vs two-level vs flat (racked-pods 32n, racks of 4, pods of 16, TCP-TCP) ===");
    let rows = multilevel_sweep()?;
    let mut t = Table::new(&[
        "size", "flat (us)", "two-level (us)", "multi-level (us)", "vs flat", "vs two-level", "multi plan",
    ]);
    for r in &rows {
        t.row(vec![
            fmt_bytes(r.bytes),
            format!("{:.0}", r.flat_us),
            format!("{:.0}", r.two_us),
            format!("{:.0}", r.multi_us),
            format!("{:+.0}%", (r.flat_us / r.multi_us - 1.0) * 100.0),
            format!("{:+.1}%", (r.two_us / r.multi_us - 1.0) * 100.0),
            r.multi_plan.clone(),
        ]);
    }
    t.print();
    println!("(each extra level moves volume onto a faster local fabric and cuts rail rounds)");
    println!("{}", multilevel_sweep_json(&rows).to_string());
    Ok(())
}

// ---------------------------------------------------------------- tenancy

use crate::coordinator::arbiter::{
    ArbiterMode, ChurnKind, FabricArbiter, JobSpec, PriorityClass,
};
use crate::coordinator::buffer::UnboundBuffer;
use crate::coordinator::control::exception::PAPER_RECOVERY_BUDGET_US;
use crate::net::topology::TopologyTree;

/// Sustained windows per tenancy scenario.
const TENANCY_OPS: usize = 6;
/// Buffer length for the per-cell numerics identity check.
const TENANCY_LEN: usize = 2048;

/// Pods-of-4 cluster with a deliberately *slow* intra-pod fabric
/// (50 MB/s): hierarchical schedules carry a large fixed local-phase
/// cost here, so the solo planner avoids them — until heavy rail
/// contention makes the tiny rail volume of a two-level cut worth that
/// price. The scenario where contended-cost planning genuinely changes
/// the plan (flat clusters cannot shift: the ring family's transfer
/// terms inflate identically).
fn slow_pods() -> ClusterSpec {
    let mut c = ClusterSpec::pods(4);
    c.topo = TopologyTree::uniform(&[("pod", 4, 50.0, 15.0)]);
    c
}

fn tenancy_tenant(cluster: ClusterSpec, nodes: usize, rails: usize) -> Result<MultiRail> {
    MultiRail::new(&Config {
        cluster,
        nodes,
        combo: vec![ProtoKind::Tcp; rails],
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    })
}

/// Foreground tenant (8 MB collectives) squeezed to a 0.02 rail grant by
/// a background tenant saturating the rail (weight 49). Returns
/// (fg mean latency, aggregate goodput, fg plan label).
fn tenancy_pricing_run(blind: bool) -> Result<(f64, f64, String)> {
    let nodes = 16;
    let mut arb = FabricArbiter::new(ArbiterMode::FairShare, 1);
    let mut fg_spec = JobSpec::new("fg", PriorityClass::Standard).payload(8 << 20);
    if blind {
        fg_spec = fg_spec.contention_blind();
    }
    let fg = arb.admit(fg_spec, nodes, tenancy_tenant(slow_pods(), nodes, 1)?);
    arb.admit(
        JobSpec::new("bg", PriorityClass::Scavenger).weight(49.0).payload(64 << 20),
        nodes,
        tenancy_tenant(slow_pods(), nodes, 1)?,
    );
    for _ in 0..TENANCY_OPS {
        arb.step()?;
    }
    let j = arb.job(fg).unwrap();
    let mean = j.mean_us().unwrap();
    let plan = j
        .mr
        .last_plan
        .as_ref()
        .map(|p| p.label())
        .unwrap_or_else(|| "-".into());
    Ok((mean, arb.aggregate_gbps(), plan))
}

/// One priority-matrix cell on the flat dual-TCP testbed: job 0 is the
/// latency-class foreground (4 MB), the rest scavenger bulk (8 MB).
/// Returns (fg p99, numerics bit-identical to solo in this cell).
fn tenancy_cell(jobs: usize, mode: ArbiterMode) -> Result<(f64, bool)> {
    let nodes = 4;
    let mut arb = FabricArbiter::new(mode, 2);
    let mut ids = vec![arb.admit(
        JobSpec::new("fg", PriorityClass::Latency).payload(4 << 20),
        nodes,
        tenancy_tenant(ClusterSpec::local(), nodes, 2)?,
    )];
    for k in 1..jobs {
        ids.push(arb.admit(
            JobSpec::new(&format!("bg{k}"), PriorityClass::Scavenger).payload(8 << 20),
            nodes,
            tenancy_tenant(ClusterSpec::local(), nodes, 2)?,
        ));
    }
    // numerics identity: one explicit op per tenant vs a pristine solo
    // coordinator on an identical buffer
    let mut identical = true;
    for (k, &id) in ids.iter().enumerate() {
        let payload = arb.job(id).unwrap().spec.payload_bytes as f64;
        let elem_bytes = payload / TENANCY_LEN as f64;
        let fill = move |n: usize, i: usize| ((n * 7 + i * 3 + k) % 13) as f32;
        let mut buf = UnboundBuffer::from_fn(nodes, TENANCY_LEN, fill);
        let mut solo_buf = UnboundBuffer::from_fn(nodes, TENANCY_LEN, fill);
        arb.run_op_scaled(id, &mut buf, elem_bytes)?;
        tenancy_tenant(ClusterSpec::local(), nodes, 2)?
            .allreduce_scaled(&mut solo_buf, elem_bytes)?;
        for node in 0..nodes {
            identical &= buf.node(node) == solo_buf.node(node);
        }
    }
    for _ in 0..TENANCY_OPS {
        arb.step()?;
    }
    Ok((arb.p99_us(ids[0]).unwrap(), identical))
}

/// Job-churn scenario on a single shared rail: an incumbent, two bulk
/// arrivals, two departures — every grant migration must replan within
/// the paper's 200 ms recovery budget.
fn tenancy_churn() -> Result<(Vec<Json>, bool)> {
    let nodes = 4;
    let mut arb = FabricArbiter::new(ArbiterMode::FairShare, 1);
    let fg = arb.admit(
        JobSpec::new("fg", PriorityClass::Standard).payload(4 << 20),
        nodes,
        tenancy_tenant(ClusterSpec::local(), nodes, 1)?,
    );
    arb.step()?;
    let bg1 = arb.admit(
        JobSpec::new("bg1", PriorityClass::Scavenger).payload(8 << 20),
        nodes,
        tenancy_tenant(ClusterSpec::local(), nodes, 1)?,
    );
    let bg2 = arb.admit(
        JobSpec::new("bg2", PriorityClass::Scavenger).payload(8 << 20),
        nodes,
        tenancy_tenant(ClusterSpec::local(), nodes, 1)?,
    );
    arb.step()?;
    arb.depart(bg1);
    arb.depart(bg2);
    arb.step()?;
    debug_assert_eq!(arb.job(fg).unwrap().mr.rail_grant(0), 1.0);
    let events: Vec<Json> = arb
        .churn()
        .iter()
        .map(|ev| {
            Json::obj(vec![
                (
                    "kind",
                    Json::from(match ev.kind {
                        ChurnKind::Admit => "admit",
                        ChurnKind::Depart => "depart",
                    }),
                ),
                ("job", Json::from(ev.job.0 as f64)),
                ("jobs_replanned", Json::from(ev.jobs_replanned)),
                ("replan_us", Json::from(ev.replan_us)),
            ])
        })
        .collect();
    Ok((events, arb.all_churn_within(PAPER_RECOVERY_BUDGET_US)))
}

/// The full tenancy study as one JSON document (bench result format;
/// uploaded as the `tenancy_ablation.json` CI artifact).
pub fn tenancy_sweep_json() -> Result<Json> {
    // (a) contended-cost vs contention-blind planning under a saturating
    // background tenant
    let (blind_us, blind_gbps, blind_plan) = tenancy_pricing_run(true)?;
    let (priced_us, priced_gbps, priced_plan) = tenancy_pricing_run(false)?;

    // (b)+(c) the priority matrix, with the 1-job cell as the solo p99
    // baseline
    let (solo_p99, _) = tenancy_cell(1, ArbiterMode::FairShare)?;
    let mut matrix = Vec::new();
    let mut priority = Vec::new();
    for &jobs in &[1usize, 2, 4] {
        let mut ratios = Vec::new();
        for mode in [ArbiterMode::FairShare, ArbiterMode::StrictPriority] {
            let (p99, identical) = tenancy_cell(jobs, mode)?;
            ratios.push(p99 / solo_p99);
            matrix.push(Json::obj(vec![
                ("jobs", Json::from(jobs)),
                ("mode", Json::from(mode.name())),
                ("fg_p99_us", Json::from(p99)),
                ("fg_p99_vs_solo", Json::from(p99 / solo_p99)),
                ("numerics_bit_identical_to_solo", Json::Bool(identical)),
            ]));
        }
        priority.push(Json::obj(vec![
            ("jobs", Json::from(jobs)),
            ("fair_p99_ratio", Json::from(ratios[0])),
            ("strict_p99_ratio", Json::from(ratios[1])),
            ("strict_within_2x_solo", Json::Bool(ratios[1] <= 2.0)),
            ("fair_within_2x_solo", Json::Bool(ratios[0] <= 2.0)),
        ]));
    }

    let (churn_events, churn_ok) = tenancy_churn()?;

    Ok(Json::obj(vec![
        ("bench", Json::from("tenancy")),
        (
            "pricing",
            Json::obj(vec![
                ("cluster", Json::from("slow-pods 16n x 1r TCP")),
                ("fg_grant", Json::from(0.02)),
                ("blind_fg_mean_us", Json::from(blind_us)),
                ("blind_aggregate_gbps", Json::from(blind_gbps)),
                ("blind_fg_plan", Json::from(blind_plan)),
                ("contended_fg_mean_us", Json::from(priced_us)),
                ("contended_aggregate_gbps", Json::from(priced_gbps)),
                ("contended_fg_plan", Json::from(priced_plan)),
                ("contended_beats_blind", Json::Bool(priced_gbps > blind_gbps)),
                ("aggregate_speedup", Json::from(priced_gbps / blind_gbps)),
            ]),
        ),
        ("solo_p99_us", Json::from(solo_p99)),
        ("priority", Json::Arr(priority)),
        ("matrix", Json::Arr(matrix)),
        (
            "churn",
            Json::obj(vec![
                ("events", Json::Arr(churn_events)),
                ("within_recovery_budget", Json::Bool(churn_ok)),
                ("budget_us", Json::from(PAPER_RECOVERY_BUDGET_US)),
            ]),
        ),
    ]))
}

/// Multi-tenancy ablation: contended-cost vs contention-blind planning
/// under a saturating background tenant, fair-share vs strict-priority
/// latency protection, per-cell numerics identity and churn replanning.
/// The JSON document is the last printed line (CI captures it as the
/// `tenancy_ablation.json` artifact).
pub fn ablate_tenancy() -> Result<()> {
    println!("\n=== Ablation: multi-tenant fabric arbiter ===");
    let doc = tenancy_sweep_json()?;

    println!("(a) contended-cost vs contention-blind planning (fg at 0.02 grant, slow-pods 16n):");
    if let Some(p) = doc.get("pricing") {
        let mut t = Table::new(&["planner", "fg mean (us)", "aggregate GB/s", "fg plan"]);
        for (label, us, g, plan) in [
            ("blind", "blind_fg_mean_us", "blind_aggregate_gbps", "blind_fg_plan"),
            ("contended", "contended_fg_mean_us", "contended_aggregate_gbps", "contended_fg_plan"),
        ] {
            t.row(vec![
                label.into(),
                format!("{:.0}", p.get(us).and_then(Json::as_f64).unwrap_or(0.0)),
                format!("{:.4}", p.get(g).and_then(Json::as_f64).unwrap_or(0.0)),
                p.get(plan).and_then(Json::as_str).unwrap_or("-").to_string(),
            ]);
        }
        t.print();
    }

    println!("(b) latency-class p99 vs solo (flat 4n x 2r TCP; scavenger bulk background):");
    if let Some(Json::Arr(rows)) = doc.get("priority") {
        let mut t = Table::new(&["jobs", "fair p99/solo", "strict p99/solo"]);
        for r in rows {
            t.row(vec![
                format!("{:.0}", r.get("jobs").and_then(Json::as_f64).unwrap_or(0.0)),
                format!("{:.2}x", r.get("fair_p99_ratio").and_then(Json::as_f64).unwrap_or(0.0)),
                format!("{:.2}x", r.get("strict_p99_ratio").and_then(Json::as_f64).unwrap_or(0.0)),
            ]);
        }
        t.print();
    }
    println!("(strict priority preempts scavengers at window boundaries; fair-share lets bulk dilute the latency class)");
    println!("{}", doc.to_string());
    Ok(())
}

// ---------------------------------------------------------------------------
// Elastic membership (node churn): the §4.4 self-recovery path extended from
// rails to nodes — leave/rejoin/rack-leave/scheduled-leave across cluster
// shapes and executors, recovery budget at p99, bit-exact numerics.
// ---------------------------------------------------------------------------

use crate::coordinator::arbiter::job::percentile;
use crate::net::cpu_pool::ExecMode;
use crate::net::fault::MembershipSchedule;

const CHURN_LEN: usize = 2048;
/// Modeled 8MB ops on small real buffers.
const CHURN_ELEM_BYTES: f64 = (8 << 20) as f64 / CHURN_LEN as f64;

fn churn_cfg(racked: bool, exec: ExecMode) -> Config {
    let mut c = Config {
        nodes: if racked { 32 } else { 8 },
        combo: parse_combo("tcp-tcp").unwrap(),
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    if racked {
        c.cluster = ClusterSpec::racked_pods(4, 16);
    }
    c.exec = exec;
    c
}

fn churn_fill(n: usize, i: usize) -> f32 {
    ((n + 1) * (i % 13 + 1)) as f32
}

/// One op at the coordinator's CURRENT membership (poll first so the
/// buffer matches the post-churn node count).
fn churn_op(mr: &mut MultiRail) -> Result<()> {
    mr.poll_membership()?;
    let nodes = mr.active_nodes();
    let mut buf = UnboundBuffer::from_fn(nodes, CHURN_LEN, churn_fill);
    mr.allreduce_scaled(&mut buf, CHURN_ELEM_BYTES)?;
    Ok(())
}

/// The four churn scenarios on one (shape, executor) cell. Returns one
/// matrix row per scenario plus every charged recovery time.
fn churn_cell(racked: bool, exec: ExecMode) -> Result<(Vec<Json>, Vec<f64>)> {
    let shape = if racked { "racked-pods 32n" } else { "flat 8n" };
    let row = |scenario: &str, recovery_us: f64, epoch: u64, replanned: bool| {
        Json::obj(vec![
            ("shape", Json::from(shape)),
            ("exec", Json::from(exec.name())),
            ("scenario", Json::from(scenario)),
            ("recovery_us", Json::from(recovery_us)),
            ("epoch", Json::from(epoch as f64)),
            ("replanned", Json::Bool(replanned)),
        ])
    };
    let mut rows = Vec::new();
    let mut samples = Vec::new();

    // single node leave mid-training
    let mut mr = MultiRail::new(&churn_cfg(racked, exec))?;
    churn_op(&mut mr)?;
    let e0 = mr.plan_epoch();
    let rec = mr.node_leave(2)?;
    churn_op(&mut mr)?;
    rows.push(row("leave", rec.recovery_us, rec.epoch, mr.plan_epoch() > e0));
    samples.push(rec.recovery_us);

    // leave then rejoin (round-trip back to the home topology)
    let mut mr = MultiRail::new(&churn_cfg(racked, exec))?;
    churn_op(&mut mr)?;
    let l = mr.node_leave(2)?;
    churn_op(&mut mr)?;
    let e0 = mr.plan_epoch();
    let r = mr.node_rejoin(2)?;
    churn_op(&mut mr)?;
    rows.push(row("rejoin", r.recovery_us, r.epoch, mr.plan_epoch() > e0));
    samples.push(l.recovery_us);
    samples.push(r.recovery_us);

    // a whole rack dying at once: one detection event, one budget
    let mut mr = MultiRail::new(&churn_cfg(racked, exec))?;
    churn_op(&mut mr)?;
    let e0 = mr.plan_epoch();
    let rec = mr.nodes_leave(&[0, 1, 2, 3])?;
    churn_op(&mut mr)?;
    rows.push(row("rack-leave", rec.recovery_us, rec.epoch, mr.plan_epoch() > e0));
    samples.push(rec.recovery_us);

    // leave landing mid-op, applied at the next op boundary
    let mut mr = MultiRail::new(&churn_cfg(racked, exec))?
        .with_membership(MembershipSchedule::none().leave(2, 1.0));
    churn_op(&mut mr)?;
    let e0 = mr.plan_epoch();
    churn_op(&mut mr)?;
    let ev = mr.exceptions.membership[0];
    rows.push(row("scheduled-leave", ev.recovery_us, ev.epoch, mr.plan_epoch() > e0));
    samples.push(ev.recovery_us);

    Ok((rows, samples))
}

/// Bit-exactness probes: the surviving set must reduce exactly like a
/// fresh coordinator born at the survivor count, and a rejoined cluster
/// exactly like one that never lost the node.
fn churn_bit_exact() -> Result<(bool, bool)> {
    let mut churned = MultiRail::new(&churn_cfg(false, ExecMode::Serial))?;
    churn_op(&mut churned)?;
    churned.node_leave(7)?;
    let mut a = UnboundBuffer::from_fn(7, CHURN_LEN, churn_fill);
    churned.allreduce_scaled(&mut a, CHURN_ELEM_BYTES)?;
    let mut cfg7 = churn_cfg(false, ExecMode::Serial);
    cfg7.nodes = 7;
    let mut fresh = MultiRail::new(&cfg7)?;
    let mut b = UnboundBuffer::from_fn(7, CHURN_LEN, churn_fill);
    fresh.allreduce_scaled(&mut b, CHURN_ELEM_BYTES)?;
    let survivors_exact = (0..7).all(|n| a.node(n) == b.node(n));

    let mut roundtrip = MultiRail::new(&churn_cfg(false, ExecMode::Serial))?;
    churn_op(&mut roundtrip)?;
    roundtrip.node_leave(3)?;
    churn_op(&mut roundtrip)?;
    roundtrip.node_rejoin(3)?;
    let mut c = UnboundBuffer::from_fn(8, CHURN_LEN, churn_fill);
    roundtrip.allreduce_scaled(&mut c, CHURN_ELEM_BYTES)?;
    let mut steady = MultiRail::new(&churn_cfg(false, ExecMode::Serial))?;
    let mut d = UnboundBuffer::from_fn(8, CHURN_LEN, churn_fill);
    steady.allreduce_scaled(&mut d, CHURN_ELEM_BYTES)?;
    let rejoin_exact = (0..8).all(|n| c.node(n) == d.node(n));
    Ok((survivors_exact, rejoin_exact))
}

/// The full churn study as one JSON document (bench result format;
/// uploaded as the `churn_ablation.json` CI artifact).
pub fn churn_sweep_json() -> Result<Json> {
    let mut rows = Vec::new();
    let mut samples = Vec::new();
    for racked in [false, true] {
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            let (r, s) = churn_cell(racked, exec)?;
            rows.extend(r);
            samples.extend(s);
        }
    }
    let p99 = percentile(&samples, 0.99).unwrap_or(0.0);
    let max = samples.iter().cloned().fold(0.0, f64::max);
    let (survivors_exact, rejoin_exact) = churn_bit_exact()?;
    Ok(Json::obj(vec![
        ("bench", Json::from("churn")),
        ("budget_us", Json::from(PAPER_RECOVERY_BUDGET_US)),
        ("matrix", Json::Arr(rows)),
        ("recoveries", Json::from(samples.len())),
        ("p99_recovery_us", Json::from(p99)),
        ("max_recovery_us", Json::from(max)),
        ("within_recovery_budget", Json::Bool(max < PAPER_RECOVERY_BUDGET_US)),
        ("survivors_bit_exact_vs_fresh", Json::Bool(survivors_exact)),
        ("rejoin_bit_exact_vs_never_failed", Json::Bool(rejoin_exact)),
    ]))
}

/// Elastic-membership ablation: the churn matrix — {leave, rejoin, rack
/// leave, scheduled leave} × {flat, racked-pods} × {serial, parallel} —
/// with per-event recovery cost, membership-epoch replanning and
/// bit-exactness checks. The JSON document is the last printed line (CI
/// captures it as the `churn_ablation.json` artifact).
pub fn ablate_churn() -> Result<()> {
    println!("\n=== Ablation: elastic membership (node churn) ===");
    let doc = churn_sweep_json()?;
    let mut t = Table::new(&["shape", "exec", "scenario", "recovery (ms)", "epoch", "replanned"]);
    if let Some(Json::Arr(rows)) = doc.get("matrix") {
        for r in rows {
            t.row(vec![
                r.get("shape").and_then(Json::as_str).unwrap_or("-").to_string(),
                r.get("exec").and_then(Json::as_str).unwrap_or("-").to_string(),
                r.get("scenario").and_then(Json::as_str).unwrap_or("-").to_string(),
                format!(
                    "{:.1}",
                    r.get("recovery_us").and_then(Json::as_f64).unwrap_or(0.0) / 1e3
                ),
                format!("{:.0}", r.get("epoch").and_then(Json::as_f64).unwrap_or(0.0)),
                r.get("replanned").map(|j| j.to_string()).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    t.print();
    println!(
        "(p99 recovery {:.1} ms vs the {:.0} ms budget; every membership change rebinds the topology and replans at a fresh epoch)",
        doc.get("p99_recovery_us").and_then(Json::as_f64).unwrap_or(0.0) / 1e3,
        PAPER_RECOVERY_BUDGET_US / 1e3
    );
    println!("{}", doc.to_string());
    Ok(())
}

// ---------------------------------------------------------------------------
// Barrier-free scheduling (DESIGN.md §13): barrier vs priority op-queue
// iteration time on the paper's models — per-iteration gradient
// bit-identity, modeled speedup, and cross-iteration overlap evidence.
// ---------------------------------------------------------------------------

use crate::net::cpu_pool::SchedMode;
use crate::trainer::{CommProfile, DdpSim};

const SCHED_WARMUP: usize = 3;
const SCHED_MEASURED: usize = 4;

/// The paper's DDP models for the scheduler study (model, batch/GPU).
const SCHED_MODELS: [(&str, usize); 2] = [("alexnet", 32), ("vgg11", 64)];

fn sched_cfg(exec: ExecMode, sched: SchedMode) -> Config {
    let mut c = Config {
        nodes: 4,
        combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    c.exec = exec;
    c.sched = sched;
    c
}

/// One {model, exec} cell: warmed barrier/priority twins stepped in
/// lockstep, per-iteration gradient fingerprints compared, mean modeled
/// iteration times and overlap stats recorded.
fn sched_cell(model: &str, batch: usize, exec: ExecMode) -> Result<Json> {
    let profile = || CommProfile::by_name(model).expect("known model");
    let mut barrier =
        DdpSim::new(&sched_cfg(exec, SchedMode::Barrier), profile(), 1, batch)?;
    let mut priority =
        DdpSim::new(&sched_cfg(exec, SchedMode::Priority), profile(), 1, batch)?;
    barrier.warmup(SCHED_WARMUP)?;
    priority.warmup(SCHED_WARMUP)?;
    let mut bt = 0.0;
    let mut pt = 0.0;
    let mut bit_identical = true;
    for _ in 0..SCHED_MEASURED {
        bt += barrier.iter_time_us()?;
        pt += priority.iter_time_us()?;
        bit_identical &= barrier.last_fingerprints() == priority.last_fingerprints();
    }
    bt /= SCHED_MEASURED as f64;
    pt /= SCHED_MEASURED as f64;
    let overlap_max = priority.sched_stats().boundary_in_flight_max;
    let cross_boundary = priority.sched_stats().cross_boundary_ops as usize;
    let preemptions = priority.sched_stats().preemptions as usize;
    let stall_us = priority.sched_stats().stall_us_total;
    let drained = priority.drain_queue();
    Ok(Json::obj(vec![
        ("model", Json::from(model)),
        ("batch_per_gpu", Json::from(batch)),
        ("exec", Json::from(exec.name())),
        ("barrier_iter_us", Json::from(bt)),
        ("priority_iter_us", Json::from(pt)),
        ("speedup", Json::from(bt / pt)),
        ("bit_identical", Json::Bool(bit_identical)),
        ("improved", Json::Bool(pt < bt)),
        ("boundary_in_flight_max", Json::from(overlap_max)),
        ("cross_boundary_ops", Json::from(cross_boundary)),
        ("preemptions", Json::from(preemptions)),
        ("stall_us_total", Json::from(stall_us)),
        ("queue_drained", Json::Bool(drained)),
    ]))
}

/// The full scheduler study as one JSON document (bench result format;
/// uploaded as the `scheduler_ablation.json` CI artifact and embedded as
/// the `scheduler` section of BENCH_hotpath.json).
pub fn scheduler_sweep_json() -> Result<Json> {
    let mut rows = Vec::new();
    let mut all_bit_identical = true;
    let mut all_improved = true;
    let mut all_overlapped = true;
    for &(model, batch) in &SCHED_MODELS {
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            let row = sched_cell(model, batch, exec)?;
            all_bit_identical &= row.get("bit_identical") == Some(&Json::Bool(true));
            all_improved &= row.get("improved") == Some(&Json::Bool(true));
            all_overlapped &= row
                .get("boundary_in_flight_max")
                .and_then(Json::as_f64)
                .unwrap_or(0.0)
                >= 1.0;
            rows.push(row);
        }
    }
    Ok(Json::obj(vec![
        ("bench", Json::from("scheduler")),
        ("warmup_iters", Json::from(SCHED_WARMUP)),
        ("measured_iters", Json::from(SCHED_MEASURED)),
        ("matrix", Json::Arr(rows)),
        ("all_bit_identical", Json::Bool(all_bit_identical)),
        ("all_improved", Json::Bool(all_improved)),
        ("all_overlapped", Json::Bool(all_overlapped)),
    ]))
}

/// Barrier-free scheduler ablation: per-iteration barrier vs the priority
/// op-queue on alexnet/vgg11, both executors — modeled speedup with
/// bit-identical gradients and proof of cross-iteration overlap. The JSON
/// document is the last printed line (CI captures it as the
/// `scheduler_ablation.json` artifact).
pub fn ablate_scheduler() -> Result<()> {
    println!("\n=== Ablation: barrier vs priority op-queue scheduling (4 nodes, TCP-TCP) ===");
    let doc = scheduler_sweep_json()?;
    let mut t = Table::new(&[
        "model", "exec", "barrier (us)", "priority (us)", "speedup", "bit-ident", "overlap",
    ]);
    if let Some(Json::Arr(rows)) = doc.get("matrix") {
        for r in rows {
            t.row(vec![
                r.get("model").and_then(Json::as_str).unwrap_or("-").to_string(),
                r.get("exec").and_then(Json::as_str).unwrap_or("-").to_string(),
                format!(
                    "{:.0}",
                    r.get("barrier_iter_us").and_then(Json::as_f64).unwrap_or(0.0)
                ),
                format!(
                    "{:.0}",
                    r.get("priority_iter_us").and_then(Json::as_f64).unwrap_or(0.0)
                ),
                format!("{:.2}x", r.get("speedup").and_then(Json::as_f64).unwrap_or(0.0)),
                r.get("bit_identical").map(|j| j.to_string()).unwrap_or_else(|| "-".into()),
                format!(
                    "{:.0}",
                    r.get("boundary_in_flight_max").and_then(Json::as_f64).unwrap_or(0.0)
                ),
            ]);
        }
    }
    t.print();
    println!(
        "(priority enqueues at backward, awaits at next forward: gradients stay bit-identical while comm overlaps the iteration boundary)"
    );
    println!("{}", doc.to_string());
    Ok(())
}

/// Run all ablations.
pub fn run_all() -> Result<()> {
    ablate_tau()?;
    ablate_eta()?;
    ablate_timer_window()?;
    ablate_alloc()?;
    ablate_planner()?;
    ablate_straggler()?;
    ablate_multilevel()?;
    ablate_tenancy()?;
    ablate_churn()?;
    ablate_scheduler()?;
    crate::bench::chaos::ablate_grayfault()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_beats_static_end_to_end() {
        use crate::net::cpu_pool::AllocPolicy;
        let mut adaptive =
            mk(&[ProtoKind::Tcp, ProtoKind::Glex], 4, |c| c.alloc = AllocPolicy::Adaptive)
                .unwrap();
        let mut stat =
            mk(&[ProtoKind::Tcp, ProtoKind::Glex], 4, |c| c.alloc = AllocPolicy::StaticEqual)
                .unwrap();
        let a = mean_lat(&mut adaptive, 8 << 20, 30, 5).unwrap();
        let s = mean_lat(&mut stat, 8 << 20, 30, 5).unwrap();
        assert!(a < s, "adaptive {a} vs static {s}");
    }

    /// The three tenancy acceptance criteria, read straight off the
    /// artifact document: (a) contended-cost planning beats
    /// contention-blind on aggregate goodput under a saturating tenant,
    /// (b) strict priority holds the latency class within 2x solo where
    /// 4-way fair-share does not, (c) numerics bit-identical to solo in
    /// every matrix cell.
    #[test]
    fn tenancy_acceptance_criteria_hold() {
        let doc = tenancy_sweep_json().unwrap();
        let pricing = doc.get("pricing").unwrap();
        assert_eq!(
            pricing.get("contended_beats_blind"),
            Some(&Json::Bool(true)),
            "contended-cost planning must out-throughput contention-blind: {}",
            pricing.to_string()
        );
        if let Some(Json::Arr(rows)) = doc.get("priority") {
            for r in rows {
                let jobs = r.get("jobs").and_then(Json::as_f64).unwrap();
                assert_eq!(
                    r.get("strict_within_2x_solo"),
                    Some(&Json::Bool(true)),
                    "strict priority breached 2x solo at {jobs} jobs: {}",
                    r.to_string()
                );
                if jobs as usize == 4 {
                    assert_eq!(
                        r.get("fair_within_2x_solo"),
                        Some(&Json::Bool(false)),
                        "4-way fair-share should breach 2x solo: {}",
                        r.to_string()
                    );
                }
            }
        } else {
            panic!("missing priority rows");
        }
        if let Some(Json::Arr(cells)) = doc.get("matrix") {
            assert_eq!(cells.len(), 6);
            for c in cells {
                assert_eq!(
                    c.get("numerics_bit_identical_to_solo"),
                    Some(&Json::Bool(true)),
                    "numerics diverged from solo: {}",
                    c.to_string()
                );
            }
        } else {
            panic!("missing matrix cells");
        }
        assert_eq!(
            doc.get("churn").unwrap().get("within_recovery_budget"),
            Some(&Json::Bool(true))
        );
    }

    /// The churn acceptance criteria, read straight off the artifact
    /// document: every scenario in the {leave, rejoin, rack-leave,
    /// scheduled-leave} × {flat, racked-pods} × {serial, parallel} matrix
    /// recovers inside the paper's budget, replans at a fresh epoch, and
    /// the bit-exactness probes hold.
    #[test]
    fn churn_acceptance_criteria_hold() {
        let doc = churn_sweep_json().unwrap();
        assert_eq!(
            doc.get("within_recovery_budget"),
            Some(&Json::Bool(true)),
            "recovery over budget: {}",
            doc.to_string()
        );
        assert_eq!(
            doc.get("survivors_bit_exact_vs_fresh"),
            Some(&Json::Bool(true))
        );
        assert_eq!(
            doc.get("rejoin_bit_exact_vs_never_failed"),
            Some(&Json::Bool(true))
        );
        let p99 = doc.get("p99_recovery_us").and_then(Json::as_f64).unwrap();
        assert!(p99 < PAPER_RECOVERY_BUDGET_US, "p99 {p99} over budget");
        if let Some(Json::Arr(rows)) = doc.get("matrix") {
            assert_eq!(rows.len(), 16, "4 scenarios x 2 shapes x 2 executors");
            for r in rows {
                let rec = r.get("recovery_us").and_then(Json::as_f64).unwrap();
                assert!(rec < PAPER_RECOVERY_BUDGET_US, "{}", r.to_string());
                assert!(rec > 0.0, "{}", r.to_string());
                assert_eq!(
                    r.get("replanned"),
                    Some(&Json::Bool(true)),
                    "membership change without a replan: {}",
                    r.to_string()
                );
            }
        } else {
            panic!("missing matrix rows");
        }
    }

    /// The scheduler acceptance criteria (ISSUE: barrier-free
    /// cross-iteration scheduling), read straight off the artifact
    /// document: every {model} × {executor} cell keeps the priority
    /// gradients bit-identical to the barrier baseline, beats its modeled
    /// iteration time, shows real cross-iteration overlap, and drains.
    #[test]
    fn scheduler_acceptance_criteria_hold() {
        let doc = scheduler_sweep_json().unwrap();
        assert_eq!(
            doc.get("all_bit_identical"),
            Some(&Json::Bool(true)),
            "priority diverged from barrier somewhere: {}",
            doc.to_string()
        );
        assert_eq!(
            doc.get("all_improved"),
            Some(&Json::Bool(true)),
            "priority must beat barrier on every comm-bound cell: {}",
            doc.to_string()
        );
        assert_eq!(doc.get("all_overlapped"), Some(&Json::Bool(true)));
        if let Some(Json::Arr(rows)) = doc.get("matrix") {
            assert_eq!(rows.len(), 4, "2 models x 2 executors");
            for r in rows {
                assert_eq!(r.get("queue_drained"), Some(&Json::Bool(true)), "{}", r.to_string());
                let speedup = r.get("speedup").and_then(Json::as_f64).unwrap();
                assert!(speedup > 1.0, "{}", r.to_string());
                assert!(
                    r.get("cross_boundary_ops").and_then(Json::as_f64).unwrap() >= 1.0,
                    "{}",
                    r.to_string()
                );
            }
        } else {
            panic!("missing matrix rows");
        }
    }

    #[test]
    fn tiny_tau_never_splits() {
        let mut mr =
            mk(&[ProtoKind::Tcp, ProtoKind::Sharp], 4, |c| c.control.tau = 1.01).unwrap();
        let _ = mean_lat(&mut mr, 64 << 20, 20, 1).unwrap();
        assert!(mr.partitioner.alphas(64 << 20).is_none(), "tau=1 must stay cold");
    }
}
