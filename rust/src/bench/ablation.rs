//! Ablation studies over Nezha's design choices (DESIGN.md §5 extras):
//! the divergence tolerance τ, the cross-rail sync-overhead charge, the
//! gradient-descent step η, the Timer window, and the collective planner
//! vs the seed's fixed flat-ring dispatch.
//!
//! Run: `cargo run --release -- fig ablate`

use crate::config::{Config, PlannerMode, Policy};
use crate::coordinator::buffer::BufferPool;
use crate::coordinator::multirail::MultiRail;
use crate::net::protocol::ProtoKind;
use crate::net::topology::{parse_combo, ClusterSpec};
use crate::trainer::bucket::Bucketizer;
use crate::util::bytes::fmt_bytes;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::Result;

const ELEMS: usize = 1024;

fn mk(combo: &[ProtoKind], nodes: usize, patch: impl Fn(&mut Config)) -> Result<MultiRail> {
    let mut cfg = Config {
        nodes,
        combo: combo.to_vec(),
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    patch(&mut cfg);
    MultiRail::new(&cfg)
}

fn mean_lat(mr: &mut MultiRail, bytes: u64, warm: usize, reps: usize) -> Result<f64> {
    crate::bench::harness::mean_allreduce_us(mr, bytes, warm, reps)
}

/// τ ablation: with τ too small Nezha never splits (loses the large-
/// payload gain); with τ huge it splits across hopeless rails (loses the
/// small-payload RDMA advantage). τ = 5 sits at the knee.
pub fn ablate_tau() -> Result<()> {
    println!("\n=== Ablation: divergence tolerance τ (TCP-SHARP, 4 nodes) ===");
    let mut t = Table::new(&["tau", "64KB (us)", "16MB (us)", "64MB (us)"]);
    for tau in [1.0, 2.0, 5.0, 20.0, 1e9] {
        let mut mr = mk(&[ProtoKind::Tcp, ProtoKind::Sharp], 4, |c| c.control.tau = tau)?;
        let small = mean_lat(&mut mr, 64 << 10, 20, 5)?;
        let mid = mean_lat(&mut mr, 16 << 20, 30, 5)?;
        let large = mean_lat(&mut mr, 64 << 20, 30, 5)?;
        let label = if tau >= 1e9 { "inf".into() } else { format!("{tau:.0}") };
        t.row(vec![
            label,
            format!("{small:.0}"),
            format!("{mid:.0}"),
            format!("{large:.0}"),
        ]);
    }
    t.print();
    println!("(τ=5 keeps the 64KB cold-start fast AND the 64MB split active)");
    Ok(())
}

/// η ablation: convergence speed of the α table vs the learning rate.
pub fn ablate_eta() -> Result<()> {
    println!("\n=== Ablation: balancer step η — ops until scheduling error <10% (TCP-GLEX, 16MB) ===");
    let mut t = Table::new(&["eta", "ops to converge", "final sched err"]);
    for eta in [0.05, 0.1, 0.3, 0.6, 0.9] {
        let mut mr = mk(&[ProtoKind::Tcp, ProtoKind::Glex], 4, |c| c.control.eta = eta)?;
        let elem_bytes = (16u64 << 20) as f64 / ELEMS as f64;
        let mut converged_at = None;
        let mut last_err = 1.0;
        let mut pool = BufferPool::new();
        for op in 0..100 {
            let mut buf = pool.acquire(4, ELEMS, |n, j| ((n + j) % 7) as f32);
            let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
            pool.release(buf);
            let times: Vec<f64> = rep
                .per_rail
                .iter()
                .filter(|s| s.bytes > 0)
                .map(|s| s.time_us)
                .collect();
            if times.len() == 2 {
                last_err = (times[0] - times[1]).abs() / times[0].max(times[1]);
                if last_err < 0.10 && converged_at.is_none() {
                    converged_at = Some(op);
                }
            }
        }
        t.row(vec![
            format!("{eta}"),
            converged_at.map(|o| o.to_string()).unwrap_or(">100".into()),
            format!("{:.1}%", last_err * 100.0),
        ]);
    }
    t.print();
    println!("(paper: convergence within the first 100 iterations — default η=0.3)");
    Ok(())
}

/// Timer-window ablation: the 100-op averaging window damps decision
/// noise; window=1 chases jitter.
pub fn ablate_timer_window() -> Result<()> {
    println!("\n=== Ablation: Timer window (jittered fabric, TCP-TCP, 8MB) ===");
    let mut t = Table::new(&["window", "mean latency (us)"]);
    for window in [1usize, 10, 100] {
        let mut cfg = Config {
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: false, // jitter ON: the window's reason to exist
            seed: 7,
            ..Config::default()
        };
        cfg.control.timer_window = window;
        let mut mr = MultiRail::new(&cfg)?;
        let lat = mean_lat(&mut mr, 8 << 20, 50, 50)?;
        t.row(vec![format!("{window}"), format!("{lat:.0}")]);
    }
    t.print();
    Ok(())
}

/// Adaptive vs static CPU allocation end-to-end (proposition 2).
pub fn ablate_alloc() -> Result<()> {
    println!("\n=== Ablation: adaptive vs static CPU allocation (TCP-GLEX, 8MB, 4 nodes) ===");
    use crate::net::cpu_pool::AllocPolicy;
    let mut t = Table::new(&["alloc", "latency (us)"]);
    for (name, alloc) in [("adaptive", AllocPolicy::Adaptive), ("static", AllocPolicy::StaticEqual)] {
        let mut mr = mk(&[ProtoKind::Tcp, ProtoKind::Glex], 4, |c| c.alloc = alloc)?;
        let lat = mean_lat(&mut mr, 8 << 20, 30, 5)?;
        t.row(vec![name.into(), format!("{lat:.0}")]);
    }
    t.print();
    println!("(paper §2.3.2: static partitioning starves the scalable RDMA planes)");
    Ok(())
}

/// Collective planner ablation: the topology-aware planner against the
/// seed's fixed flat-ring dispatch, on the paper's flat local testbed and
/// on the grouped 16-node × 4-rail pods topology where the hierarchical
/// two-level schedule engages.
pub fn ablate_planner() -> Result<()> {
    println!("\n=== Ablation: collective planner vs fixed flat-ring dispatch ===");
    let mut t = Table::new(&["topology", "size", "fixed (us)", "planner (us)", "gain", "plan"]);
    let cases: [(&str, ClusterSpec, &str, usize); 2] = [
        ("local 8n x 2r", ClusterSpec::local(), "tcp-tcp", 8),
        ("pods 16n x 4r", ClusterSpec::pods(4), "tcp-tcp-tcp-glex", 16),
    ];
    for (label, cluster, combo, nodes) in cases {
        for &bytes in &[512u64 << 10, 8 << 20, 64 << 20] {
            let run = |mode| {
                crate::bench::harness::planner_mode_latency(
                    &cluster, combo, nodes, mode, bytes, 30, 5,
                )
            };
            let (fixed, _) = run(PlannerMode::Flat)?;
            let (auto, plan) = run(PlannerMode::Auto)?;
            t.row(vec![
                label.into(),
                fmt_bytes(bytes),
                format!("{fixed:.0}"),
                format!("{auto:.0}"),
                format!("{:+.0}%", (fixed / auto - 1.0) * 100.0),
                plan,
            ]);
        }
    }
    t.print();

    // bucket plan annotations: what a VGG-sized flat gradient's fusion
    // buckets would each run (pods topology, 4MB buckets)
    let cfg = Config {
        cluster: ClusterSpec::pods(4),
        nodes: 16,
        combo: parse_combo("tcp-tcp-tcp-glex")?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    let buckets = Bucketizer::new(32 << 20, 8 << 20); // 128MB grads, 32MB buckets
    println!("\nbucket plan annotations (128MB flat gradient, 32MB fusion buckets):");
    for bp in buckets.annotate(&mut mr, 4.0) {
        println!(
            "  [{:>9} elems @ {:>9}] multirail={} plan: {}",
            bp.window.len,
            bp.window.offset,
            bp.is_multirail(),
            bp.plan.as_ref().map(|p| p.label()).unwrap_or_else(|| "-".into()),
        );
    }
    println!("(two-level engages on the pods topology; flat clusters keep seed behaviour)");
    Ok(())
}

/// Straggler-correction ablation: planner=auto (Timer-corrected costs,
/// straggler-aware replanning) against planner=static-cost (a-priori α-β
/// model only) with a persistent per-message straggler injected on one
/// rail of the grouped pods topology. Emits the comparison as a JSON doc
/// in the bench result format (the acceptance artifact for the
/// straggler-replanning milestone).
pub fn ablate_straggler() -> Result<()> {
    use crate::bench::harness::{straggler_sweep, straggler_sweep_json};
    println!("\n=== Ablation: measurement-corrected planner vs static cost under a straggler ===");
    println!("(pods 16n x 2r TCP, persistent per-message stall on rail 0)");
    let rows = straggler_sweep()?;
    let mut t = Table::new(&[
        "size", "stall", "static-cost (us)", "auto (us)", "gain", "auto plan",
    ]);
    for r in &rows {
        t.row(vec![
            fmt_bytes(r.bytes),
            format!("{:.0}us", r.stall_us),
            format!("{:.0}", r.static_us),
            format!("{:.0}", r.auto_us),
            format!("{:+.1}%", (r.static_us / r.auto_us - 1.0) * 100.0),
            r.auto_plan.clone(),
        ]);
    }
    t.print();
    println!("{}", straggler_sweep_json(&rows).to_string());
    println!("(corrections shift the straggler rail to fewer-round schedules; static cost cannot)");
    Ok(())
}

/// The canonical multi-level topology sweep: racked-pods supercluster
/// (32 nodes, racks of 4 inside pods of 16), dual TCP rails, `(bytes)`
/// cases spanning latency- to bandwidth-bound payloads. Shared by the
/// ablation table and the JSON artifact so the two cannot drift apart.
pub const MULTILEVEL_SWEEP_NODES: usize = 32;
pub const MULTILEVEL_SWEEP_CASES: [u64; 3] = [4 << 20, 64 << 20, 256 << 20];

/// One multi-level-vs-two-level-vs-flat comparison at a payload size.
#[derive(Debug, Clone)]
pub struct MultiLevelRow {
    pub bytes: u64,
    /// Fixed flat-ring dispatch (`planner = flat`).
    pub flat_us: f64,
    /// Auto planner on the rack-only (one-level) view of the same
    /// cluster — exactly the pre-PR two-level planner's search space.
    pub two_us: f64,
    pub two_plan: String,
    /// Auto planner on the full rack < pod tree.
    pub multi_us: f64,
    pub multi_plan: String,
}

/// Run the canonical multi-level sweep (see [`MULTILEVEL_SWEEP_CASES`]).
pub fn multilevel_sweep() -> Result<Vec<MultiLevelRow>> {
    let full = ClusterSpec::racked_pods(4, 16);
    // the two-level baseline sees only the rack level — the exact search
    // space the planner had before multi-level cuts existed
    let mut rack_only = full.clone();
    rack_only.topo.levels.truncate(1);
    let nodes = MULTILEVEL_SWEEP_NODES;
    let combo = "tcp-tcp";
    let run = crate::bench::harness::planner_mode_latency;
    let mut rows = Vec::new();
    for &bytes in &MULTILEVEL_SWEEP_CASES {
        let (flat_us, _) = run(&full, combo, nodes, PlannerMode::Flat, bytes, 25, 5)?;
        let (two_us, two_plan) = run(&rack_only, combo, nodes, PlannerMode::Auto, bytes, 25, 5)?;
        let (multi_us, multi_plan) = run(&full, combo, nodes, PlannerMode::Auto, bytes, 25, 5)?;
        rows.push(MultiLevelRow { bytes, flat_us, two_us, two_plan, multi_us, multi_plan });
    }
    Ok(rows)
}

/// The multi-level-topology JSON document for a sweep's rows (bench
/// result format; uploaded as a CI artifact).
pub fn multilevel_sweep_json(rows: &[MultiLevelRow]) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("bytes", Json::from(r.bytes as f64)),
                ("size", Json::from(fmt_bytes(r.bytes))),
                ("flat_us", Json::from(r.flat_us)),
                ("two_level_us", Json::from(r.two_us)),
                ("two_level_plan", Json::from(r.two_plan.clone())),
                ("multi_level_us", Json::from(r.multi_us)),
                ("multi_level_plan", Json::from(r.multi_plan.clone())),
                ("speedup_vs_flat", Json::from(r.flat_us / r.multi_us)),
                ("speedup_vs_two_level", Json::from(r.two_us / r.multi_us)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::from("multilevel_topology")),
        ("cluster", Json::from("racked-pods")),
        ("combo", Json::from("tcp-tcp")),
        ("nodes", Json::from(MULTILEVEL_SWEEP_NODES as f64)),
        ("rack", Json::from(4.0)),
        ("pod", Json::from(16.0)),
        ("results", Json::Arr(results)),
    ])
}

/// Multi-level topology ablation: the N-level planner against the
/// two-level (rack-cut-only) planner and the fixed flat ring on the
/// racked-pods supercluster. The JSON document is the last printed line
/// (CI captures it as the `multilevel_ablation.json` artifact).
pub fn ablate_multilevel() -> Result<()> {
    println!("\n=== Ablation: multi-level vs two-level vs flat (racked-pods 32n, racks of 4, pods of 16, TCP-TCP) ===");
    let rows = multilevel_sweep()?;
    let mut t = Table::new(&[
        "size", "flat (us)", "two-level (us)", "multi-level (us)", "vs flat", "vs two-level", "multi plan",
    ]);
    for r in &rows {
        t.row(vec![
            fmt_bytes(r.bytes),
            format!("{:.0}", r.flat_us),
            format!("{:.0}", r.two_us),
            format!("{:.0}", r.multi_us),
            format!("{:+.0}%", (r.flat_us / r.multi_us - 1.0) * 100.0),
            format!("{:+.1}%", (r.two_us / r.multi_us - 1.0) * 100.0),
            r.multi_plan.clone(),
        ]);
    }
    t.print();
    println!("(each extra level moves volume onto a faster local fabric and cuts rail rounds)");
    println!("{}", multilevel_sweep_json(&rows).to_string());
    Ok(())
}

/// Run all ablations.
pub fn run_all() -> Result<()> {
    ablate_tau()?;
    ablate_eta()?;
    ablate_timer_window()?;
    ablate_alloc()?;
    ablate_planner()?;
    ablate_straggler()?;
    ablate_multilevel()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_beats_static_end_to_end() {
        use crate::net::cpu_pool::AllocPolicy;
        let mut adaptive =
            mk(&[ProtoKind::Tcp, ProtoKind::Glex], 4, |c| c.alloc = AllocPolicy::Adaptive)
                .unwrap();
        let mut stat =
            mk(&[ProtoKind::Tcp, ProtoKind::Glex], 4, |c| c.alloc = AllocPolicy::StaticEqual)
                .unwrap();
        let a = mean_lat(&mut adaptive, 8 << 20, 30, 5).unwrap();
        let s = mean_lat(&mut stat, 8 << 20, 30, 5).unwrap();
        assert!(a < s, "adaptive {a} vs static {s}");
    }

    #[test]
    fn tiny_tau_never_splits() {
        let mut mr =
            mk(&[ProtoKind::Tcp, ProtoKind::Sharp], 4, |c| c.control.tau = 1.01).unwrap();
        let _ = mean_lat(&mut mr, 64 << 20, 20, 1).unwrap();
        assert!(mr.partitioner.alphas(64 << 20).is_none(), "tau=1 must stay cold");
    }
}
