//! Seeded gray-failure chaos campaigns (DESIGN.md §11).
//!
//! A campaign composes every hazard class the fabric can express — packet
//! loss (retry/backoff), brownouts, link flaps, time-varying stragglers,
//! crash-stop windows and node churn — from one deterministic seed, then
//! holds the run to three invariants:
//!
//! 1. **Numerics**: reduced values bit-exact vs a fault-free twin that
//!    shares only the membership churn (timing faults must never touch
//!    data).
//! 2. **Recovery**: every failover, membership change and gray-ledger
//!    action lands inside the paper's 200 ms budget.
//! 3. **Stability**: no demote/readmit oscillation — per-rail health
//!    transitions stay bounded (the quarantine dwell backs off).
//!
//! The corruption family (DESIGN.md §12) composes silent wire corruption
//! with those gray hazards: with integrity on the wire checksums must keep
//! every campaign bit-exact and quarantine the persistently-corrupting
//! rail; with integrity off the same campaigns measure the corruption
//! escape rate against the fault-free twin.
//!
//! Run: `cargo run --release -- fig ablate-grayfault` /
//! `fig ablate-integrity`

use crate::config::{Config, Policy};
use crate::coordinator::buffer::UnboundBuffer;
use crate::coordinator::control::exception::PAPER_RECOVERY_BUDGET_US;
use crate::coordinator::control::HealthMode;
use crate::coordinator::multirail::MultiRail;
use crate::net::cpu_pool::{ExecMode, SchedMode};
use crate::net::fault::{CorruptSchedule, DegradeSchedule, FaultSchedule};
use crate::net::protocol::ProtoKind;
use crate::net::rail::RailHealth;
use crate::trainer::{CommProfile, DdpSim};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::table::Table;
use crate::Result;

/// Nodes per campaign cluster (3 TCP rails; rail 0 stays hazard-free so
/// failover always has a survivor).
const CHAOS_NODES: usize = 4;
const CHAOS_RAILS: usize = 3;
const CHAOS_LEN: usize = 2048;
/// Modeled 8 MB ops on small real buffers.
const CHAOS_ELEM_BYTES: f64 = (8 << 20) as f64 / CHAOS_LEN as f64;
/// Ops per campaign.
const CHAOS_OPS: usize = 12;
/// Oscillation invariant: max health transitions any one rail may make.
pub const CHAOS_OSC_BOUND: usize = 10;

fn chaos_cfg(exec: ExecMode) -> Config {
    let mut c = Config {
        nodes: CHAOS_NODES,
        combo: vec![ProtoKind::Tcp; CHAOS_RAILS],
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    c.exec = exec;
    c
}

fn chaos_fill(n: usize, i: usize) -> f32 {
    ((n + 1) * (i % 13 + 1)) as f32
}

/// One seeded hazard composition. Membership churn is op-indexed (not
/// clock-indexed) so the fault-free twin stays in membership lockstep
/// even though retries and failovers advance the chaotic run's clock
/// faster.
#[derive(Debug, Clone)]
pub struct Campaign {
    pub seed: u64,
    pub faults: FaultSchedule,
    pub degrade: DegradeSchedule,
    pub corrupt: CorruptSchedule,
    pub label: String,
    /// Node that leaves and rejoins, and the op indices where it does.
    pub churn_node: usize,
    pub leave_op: usize,
    pub rejoin_op: usize,
}

/// Generate the campaign for `seed` — a pure function of the seed, so a
/// failing campaign reproduces from its seed alone.
pub fn campaign(seed: u64) -> Campaign {
    let mut rng = Pcg::new(seed ^ 0xC4A0_5EED);
    let mut degrade = DegradeSchedule::none();
    let mut faults = FaultSchedule::none();
    let mut parts: Vec<String> = Vec::new();
    // rails 1..CHAOS_RAILS take hazards; rail 0 is the anchor
    let pick_rail = |rng: &mut Pcg| 1 + rng.below((CHAOS_RAILS - 1) as u64) as usize;

    // sustained loss burst: charged as per-message retransmits
    let rail = pick_rail(&mut rng);
    let rate = rng.range_f64(0.02, 0.15);
    let start = rng.range_f64(0.0, 50_000.0);
    let end = start + rng.range_f64(100_000.0, 400_000.0);
    degrade = degrade.loss(rail, start, end, rate);
    parts.push(format!("loss:{rail}:{rate:.2}"));

    // brownout: transient bandwidth multiplier, invisible to the static
    // cost model
    let rail = pick_rail(&mut rng);
    let factor = rng.range_f64(0.3, 0.8);
    let start = rng.range_f64(0.0, 100_000.0);
    let end = start + rng.range_f64(150_000.0, 500_000.0);
    degrade = degrade.brownout(rail, start, end, factor);
    parts.push(format!("brownout:{rail}:{factor:.2}"));

    // time-varying straggler window (det or stochastic stall)
    let rail = pick_rail(&mut rng);
    let stall = rng.range_f64(2_000.0, 8_000.0);
    let sigma = if rng.f64() < 0.5 { 0.0 } else { 0.2 };
    let start = rng.range_f64(0.0, 150_000.0);
    let end = start + rng.range_f64(100_000.0, 300_000.0);
    degrade = degrade.stall(rail, start, end, stall, sigma);
    parts.push(format!("stall:{rail}:{stall:.0}us"));

    // coin-flip crash-stop window (§4.4 failover + probation readmission)
    if rng.f64() < 0.5 {
        let rail = pick_rail(&mut rng);
        let start = rng.range_f64(20_000.0, 80_000.0);
        let end = start + rng.range_f64(50_000.0, 150_000.0);
        faults = faults.with(rail, start, end);
        parts.push(format!("crash:{rail}"));
    }

    // coin-flip link flap (periodic up/down)
    if rng.f64() < 0.5 {
        let rail = pick_rail(&mut rng);
        let period = rng.range_f64(20_000.0, 60_000.0);
        let start = rng.range_f64(0.0, 60_000.0);
        degrade = degrade.flap(rail, start, start + 4.0 * period, period);
        parts.push(format!("flap:{rail}"));
    }

    // one node leave + rejoin
    let churn_node = 1 + rng.below((CHAOS_NODES - 1) as u64) as usize;
    let leave_op = 2 + rng.below(3) as usize;
    let rejoin_op = leave_op + 2 + rng.below(3) as usize;
    parts.push(format!("churn:n{churn_node}"));

    Campaign {
        seed,
        faults,
        degrade,
        corrupt: CorruptSchedule::none(),
        label: parts.join("+"),
        churn_node,
        leave_op,
        rejoin_op,
    }
}

/// Generate the corruption campaign for `seed`: a persistent bit-flip
/// storm on one rail (strong enough that the suspicion ledger must
/// quarantine it) plus a windowed second corruption of a random kind,
/// composed with the gray hazards — loss, brownout, a coin-flip crash
/// window — and node churn. Pure function of the seed.
pub fn corruption_campaign(seed: u64) -> Campaign {
    let mut rng = Pcg::new(seed ^ 0xC044_B1D5);
    let mut corrupt = CorruptSchedule::none();
    let mut degrade = DegradeSchedule::none();
    let mut faults = FaultSchedule::none();
    let mut parts: Vec<String> = Vec::new();
    let pick_rail = |rng: &mut Pcg| 1 + rng.below((CHAOS_RAILS - 1) as u64) as usize;

    // the persistent storm: rail must walk to Quarantined with integrity on
    let storm_rail = pick_rail(&mut rng);
    let p = rng.range_f64(0.10, 0.20);
    corrupt = corrupt.flip(storm_rail, 0.0, 1e12, p);
    parts.push(format!("flip:{storm_rail}:{p:.2}"));

    // a windowed second corruption of a random kind
    let rail = pick_rail(&mut rng);
    let p2 = rng.range_f64(0.02, 0.08);
    let start = rng.range_f64(0.0, 80_000.0);
    let end = start + rng.range_f64(80_000.0, 250_000.0);
    corrupt = match rng.below(3) {
        0 => {
            parts.push(format!("dup:{rail}:{p2:.2}"));
            corrupt.dup(rail, start, end, p2)
        }
        1 => {
            parts.push(format!("trunc:{rail}:{p2:.2}"));
            corrupt.trunc(rail, start, end, p2)
        }
        _ => {
            parts.push(format!("stuck:{rail}:{p2:.2}"));
            corrupt.stuck(rail, start, end, p2)
        }
    };

    // gray hazards ride along: loss burst + brownout
    let rail = pick_rail(&mut rng);
    let rate = rng.range_f64(0.02, 0.10);
    let start = rng.range_f64(0.0, 50_000.0);
    let end = start + rng.range_f64(100_000.0, 300_000.0);
    degrade = degrade.loss(rail, start, end, rate);
    parts.push(format!("loss:{rail}:{rate:.2}"));

    let rail = pick_rail(&mut rng);
    let factor = rng.range_f64(0.4, 0.8);
    let start = rng.range_f64(0.0, 80_000.0);
    let end = start + rng.range_f64(100_000.0, 300_000.0);
    degrade = degrade.brownout(rail, start, end, factor);
    parts.push(format!("brownout:{rail}:{factor:.2}"));

    // coin-flip crash-stop window
    if rng.f64() < 0.5 {
        let rail = pick_rail(&mut rng);
        let start = rng.range_f64(20_000.0, 80_000.0);
        let end = start + rng.range_f64(50_000.0, 120_000.0);
        faults = faults.with(rail, start, end);
        parts.push(format!("crash:{rail}"));
    }

    // one node leave + rejoin
    let churn_node = 1 + rng.below((CHAOS_NODES - 1) as u64) as usize;
    let leave_op = 2 + rng.below(3) as usize;
    let rejoin_op = leave_op + 2 + rng.below(3) as usize;
    parts.push(format!("churn:n{churn_node}"));

    Campaign {
        seed,
        faults,
        degrade,
        corrupt,
        label: parts.join("+"),
        churn_node,
        leave_op,
        rejoin_op,
    }
}

/// The rail carrying a corruption campaign's persistent storm (the first
/// scheduled window by construction).
pub fn storm_rail(c: &Campaign) -> usize {
    c.corrupt.windows().first().map(|w| w.rail).unwrap_or(0)
}

/// One campaign run's verdicts against the three invariants.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    pub seed: u64,
    pub exec: &'static str,
    pub label: String,
    pub bit_exact: bool,
    pub within_budget: bool,
    pub max_rail_transitions: usize,
    pub failovers: usize,
    pub gray_events: usize,
}

impl CampaignOutcome {
    pub fn passed(&self) -> bool {
        self.bit_exact && self.within_budget && self.max_rail_transitions <= CHAOS_OSC_BOUND
    }
}

/// Run one campaign under `exec`/`mode` next to its fault-free twin.
pub fn run_campaign(c: &Campaign, exec: ExecMode, mode: HealthMode) -> Result<CampaignOutcome> {
    let mut cfg = chaos_cfg(exec);
    cfg.health.mode = mode;
    cfg.faults = c.faults.clone();
    cfg.degrade = c.degrade.clone();
    cfg.corrupt = c.corrupt.clone();
    let mut mr = MultiRail::new(&cfg)?;
    // the twin shares ONLY the membership churn
    let mut twin = MultiRail::new(&chaos_cfg(exec))?;
    let mut bit_exact = true;
    for op in 0..CHAOS_OPS {
        if op == c.leave_op {
            mr.node_leave(c.churn_node)?;
            twin.node_leave(c.churn_node)?;
        }
        if op == c.rejoin_op {
            mr.node_rejoin(c.churn_node)?;
            twin.node_rejoin(c.churn_node)?;
        }
        let nodes = mr.active_nodes();
        bit_exact &= nodes == twin.active_nodes();
        let mut a = UnboundBuffer::from_fn(nodes, CHAOS_LEN, chaos_fill);
        let mut b = UnboundBuffer::from_fn(nodes, CHAOS_LEN, chaos_fill);
        mr.allreduce_scaled(&mut a, CHAOS_ELEM_BYTES)?;
        twin.allreduce_scaled(&mut b, CHAOS_ELEM_BYTES)?;
        for n in 0..nodes {
            bit_exact &= a.node(n) == b.node(n);
        }
    }
    let within_budget = mr.exceptions.all_within_budget()
        && mr.exceptions.membership_within_budget()
        && mr.exceptions.gray_within_budget();
    let max_rail_transitions = (0..CHAOS_RAILS)
        .map(|r| mr.monitor.transition_count(r))
        .max()
        .unwrap_or(0);
    Ok(CampaignOutcome {
        seed: c.seed,
        exec: exec.name(),
        label: c.label.clone(),
        bit_exact,
        within_budget,
        max_rail_transitions,
        failovers: mr.exceptions.failover_count(),
        gray_events: mr.exceptions.gray_count(),
    })
}

/// One corruption campaign run's verdicts (DESIGN.md §12).
#[derive(Debug, Clone)]
pub struct IntegrityOutcome {
    pub seed: u64,
    pub exec: &'static str,
    pub label: String,
    /// Wire checksums on?
    pub integrity: bool,
    pub bit_exact: bool,
    /// Ops whose reduced values diverged from the fault-free twin.
    pub escaped_ops: usize,
    /// Corruption events logged across rails: detected-and-recharged with
    /// integrity on, silently delivered with integrity off.
    pub injected: u64,
    pub within_budget: bool,
    pub max_rail_transitions: usize,
    /// Did the persistent-storm rail reach Quarantined at some point?
    pub storm_quarantined: bool,
}

/// Run one corruption campaign under `exec` with the wire checksums on or
/// off, next to a fault-free twin that shares only the membership churn.
pub fn run_integrity_campaign(
    c: &Campaign,
    exec: ExecMode,
    integrity: bool,
) -> Result<IntegrityOutcome> {
    let mut cfg = chaos_cfg(exec);
    cfg.faults = c.faults.clone();
    cfg.degrade = c.degrade.clone();
    cfg.corrupt = c.corrupt.clone();
    cfg.integrity = integrity;
    let mut mr = MultiRail::new(&cfg)?;
    let mut twin = MultiRail::new(&chaos_cfg(exec))?;
    let mut escaped_ops = 0usize;
    for op in 0..CHAOS_OPS {
        if op == c.leave_op {
            mr.node_leave(c.churn_node)?;
            twin.node_leave(c.churn_node)?;
        }
        if op == c.rejoin_op {
            mr.node_rejoin(c.churn_node)?;
            twin.node_rejoin(c.churn_node)?;
        }
        let nodes = mr.active_nodes();
        let mut same = nodes == twin.active_nodes();
        let mut a = UnboundBuffer::from_fn(nodes, CHAOS_LEN, chaos_fill);
        let mut b = UnboundBuffer::from_fn(nodes, CHAOS_LEN, chaos_fill);
        mr.allreduce_scaled(&mut a, CHAOS_ELEM_BYTES)?;
        twin.allreduce_scaled(&mut b, CHAOS_ELEM_BYTES)?;
        for n in 0..nodes {
            same &= a.node(n) == b.node(n);
        }
        if !same {
            escaped_ops += 1;
        }
    }
    let storm = storm_rail(c);
    let storm_quarantined = mr
        .monitor
        .transitions()
        .iter()
        .any(|t| t.rail == storm && t.to == RailHealth::Quarantined);
    let within_budget = mr.exceptions.all_within_budget()
        && mr.exceptions.membership_within_budget()
        && mr.exceptions.gray_within_budget();
    Ok(IntegrityOutcome {
        seed: c.seed,
        exec: exec.name(),
        label: c.label.clone(),
        integrity,
        bit_exact: escaped_ops == 0,
        escaped_ops,
        injected: (0..CHAOS_RAILS).map(|r| mr.fab.corruptions_on(r)).sum(),
        within_budget,
        max_rail_transitions: (0..CHAOS_RAILS)
            .map(|r| mr.monitor.transition_count(r))
            .max()
            .unwrap_or(0),
        storm_quarantined,
    })
}

/// Training iterations per scheduler-composition campaign.
const SCHED_CHAOS_ITERS: usize = 6;
/// Iterations (not op indices) where the churn node leaves and rejoins —
/// early enough that several iterations train on the shrunken set.
const SCHED_LEAVE_ITER: usize = 1;
const SCHED_REJOIN_ITER: usize = 3;

/// Synthetic DDP model for scheduler chaos: six 8 MB buckets per
/// iteration at a modest compute speed, comm-bound enough that ops are
/// genuinely in flight across iteration boundaries.
fn sched_chaos_profile() -> CommProfile {
    CommProfile::synthetic("chaos-ddp", vec![8 << 20; 6], 400.0)
}

/// One scheduler-composition campaign run's verdicts (DESIGN.md §13):
/// barrier and priority DDP twins trained under the SAME composed hazards
/// and churn. Timing hazards reorder and stretch wire time but never touch
/// program order, so the twins must stay gradient-bit-exact; a hazard
/// hitting a cross-iteration in-flight op must recover in budget and the
/// wire timeline must drain without deadlock.
#[derive(Debug, Clone)]
pub struct SchedulerChaosOutcome {
    pub seed: u64,
    pub exec: &'static str,
    pub label: String,
    /// Priority gradients bit-exact vs the barrier twin, every iteration.
    pub bit_exact: bool,
    /// Failovers, membership changes and gray actions all inside budget
    /// (both twins).
    pub within_budget: bool,
    /// The priority wire timeline fully drained after the campaign.
    pub queue_drained: bool,
    /// At least one op was in flight across an iteration boundary.
    pub overlapped: bool,
    pub failovers: usize,
}

impl SchedulerChaosOutcome {
    pub fn passed(&self) -> bool {
        self.bit_exact && self.within_budget && self.queue_drained && self.overlapped
    }
}

/// Run one campaign's hazards under both trainer scheduling modes:
/// barrier and priority twins share the config (hazards, executor) and
/// the iteration-indexed churn, diverging only in `sched`.
pub fn run_scheduler_campaign(c: &Campaign, exec: ExecMode) -> Result<SchedulerChaosOutcome> {
    let mut cfg = chaos_cfg(exec);
    cfg.faults = c.faults.clone();
    cfg.degrade = c.degrade.clone();
    cfg.corrupt = c.corrupt.clone();
    let mut barrier = DdpSim::new(&cfg, sched_chaos_profile(), 1, 32)?;
    cfg.sched = SchedMode::Priority;
    let mut priority = DdpSim::new(&cfg, sched_chaos_profile(), 1, 32)?;
    let mut bit_exact = true;
    for it in 0..SCHED_CHAOS_ITERS {
        if it == SCHED_LEAVE_ITER {
            barrier.mr.node_leave(c.churn_node)?;
            priority.mr.node_leave(c.churn_node)?;
        }
        if it == SCHED_REJOIN_ITER {
            barrier.mr.node_rejoin(c.churn_node)?;
            priority.mr.node_rejoin(c.churn_node)?;
        }
        let bt = barrier.iter_time_us()?;
        let pt = priority.iter_time_us()?;
        bit_exact &= bt > 0.0 && pt > 0.0;
        bit_exact &= barrier.last_fingerprints() == priority.last_fingerprints();
    }
    let overlapped = priority.sched_stats().cross_boundary_ops >= 1;
    let queue_drained = priority.drain_queue();
    let budget = |mr: &MultiRail| {
        mr.exceptions.all_within_budget()
            && mr.exceptions.membership_within_budget()
            && mr.exceptions.gray_within_budget()
    };
    Ok(SchedulerChaosOutcome {
        seed: c.seed,
        exec: exec.name(),
        label: c.label.clone(),
        bit_exact,
        within_budget: budget(&barrier.mr) && budget(&priority.mr),
        queue_drained,
        overlapped,
        failovers: priority.mr.exceptions.failover_count(),
    })
}

// ------------------------------------------------------------- ablation

/// Ops in the brownout graceful-vs-binary scenario.
const BROWNOUT_OPS: usize = 12;

/// Mean modeled op time (post-detection, ops 2..) under a persistent 0.5
/// brownout on rail 1 with the monitor in `mode`. `dirty_inc` is raised
/// so the very first residual observation crosses the demotion threshold
/// — both modes act after op 1, isolating *what* they do (soft-demote vs
/// quarantine) from *when* they notice.
fn brownout_mode_mean_us(mode: HealthMode) -> Result<f64> {
    let mut cfg = Config {
        nodes: CHAOS_NODES,
        combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    cfg.health.mode = mode;
    cfg.health.dirty_inc = 4.0;
    let mut mr = MultiRail::new(&cfg)?
        .with_degrade(DegradeSchedule::none().brownout(1, 0.0, 1e12, 0.5));
    let elem_bytes = (16u64 << 20) as f64 / CHAOS_LEN as f64;
    let mut total = 0.0;
    let mut counted = 0usize;
    for op in 0..BROWNOUT_OPS {
        let mut buf = UnboundBuffer::from_fn(CHAOS_NODES, CHAOS_LEN, chaos_fill);
        let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
        if op >= 2 {
            total += rep.total_us;
            counted += 1;
        }
    }
    Ok(total / counted as f64)
}

/// Seeds in the bench artifact's campaign matrix (the integration suite
/// runs a wider sweep; CI's chaos job drives both).
pub const CHAOS_SWEEP_SEEDS: [u64; 4] = [1, 2, 3, 4];

/// The full gray-failure study as one JSON document (bench result
/// format; uploaded as the `grayfault_ablation.json` CI artifact).
pub fn grayfault_sweep_json() -> Result<Json> {
    let mut rows = Vec::new();
    let mut all_bit_exact = true;
    let mut all_within_budget = true;
    let mut oscillation_bounded = true;
    for &seed in &CHAOS_SWEEP_SEEDS {
        let c = campaign(seed);
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            let o = run_campaign(&c, exec, HealthMode::Graceful)?;
            all_bit_exact &= o.bit_exact;
            all_within_budget &= o.within_budget;
            oscillation_bounded &= o.max_rail_transitions <= CHAOS_OSC_BOUND;
            rows.push(Json::obj(vec![
                ("seed", Json::from(o.seed as f64)),
                ("exec", Json::from(o.exec)),
                ("hazards", Json::from(o.label.clone())),
                ("bit_exact_vs_fault_free", Json::Bool(o.bit_exact)),
                ("within_recovery_budget", Json::Bool(o.within_budget)),
                ("max_rail_transitions", Json::from(o.max_rail_transitions)),
                ("failovers", Json::from(o.failovers)),
                ("gray_events", Json::from(o.gray_events)),
            ]));
        }
    }

    let graceful_us = brownout_mode_mean_us(HealthMode::Graceful)?;
    let binary_us = brownout_mode_mean_us(HealthMode::Binary)?;
    let off_us = brownout_mode_mean_us(HealthMode::Off)?;

    Ok(Json::obj(vec![
        ("bench", Json::from("grayfault")),
        ("budget_us", Json::from(PAPER_RECOVERY_BUDGET_US)),
        ("ops_per_campaign", Json::from(CHAOS_OPS)),
        ("oscillation_bound", Json::from(CHAOS_OSC_BOUND)),
        ("campaigns", Json::Arr(rows)),
        ("all_bit_exact", Json::Bool(all_bit_exact)),
        ("all_within_budget", Json::Bool(all_within_budget)),
        ("oscillation_bounded", Json::Bool(oscillation_bounded)),
        (
            "brownout",
            Json::obj(vec![
                ("scenario", Json::from("persistent 0.5 brownout on rail 1, 16MB ops")),
                ("graceful_mean_us", Json::from(graceful_us)),
                ("binary_mean_us", Json::from(binary_us)),
                ("off_mean_us", Json::from(off_us)),
                ("graceful_beats_binary", Json::Bool(graceful_us < binary_us)),
                ("graceful_speedup_vs_binary", Json::from(binary_us / graceful_us)),
            ]),
        ),
    ]))
}

/// Gray-failure ablation: the seeded chaos-campaign matrix (numerics /
/// recovery-budget / oscillation invariants per seed × executor) plus
/// graceful soft-demotion vs binary quarantine-everything on a brownout.
/// The JSON document is the last printed line (CI captures it as the
/// `grayfault_ablation.json` artifact).
pub fn ablate_grayfault() -> Result<()> {
    println!("\n=== Ablation: gray-failure chaos campaigns ===");
    let doc = grayfault_sweep_json()?;
    let mut t = Table::new(&[
        "seed", "exec", "hazards", "bit-exact", "budget", "max transitions", "failovers", "gray",
    ]);
    if let Some(Json::Arr(rows)) = doc.get("campaigns") {
        for r in rows {
            t.row(vec![
                format!("{:.0}", r.get("seed").and_then(Json::as_f64).unwrap_or(0.0)),
                r.get("exec").and_then(Json::as_str).unwrap_or("-").to_string(),
                r.get("hazards").and_then(Json::as_str).unwrap_or("-").to_string(),
                r.get("bit_exact_vs_fault_free").map(|j| j.to_string()).unwrap_or_default(),
                r.get("within_recovery_budget").map(|j| j.to_string()).unwrap_or_default(),
                format!("{:.0}", r.get("max_rail_transitions").and_then(Json::as_f64).unwrap_or(0.0)),
                format!("{:.0}", r.get("failovers").and_then(Json::as_f64).unwrap_or(0.0)),
                format!("{:.0}", r.get("gray_events").and_then(Json::as_f64).unwrap_or(0.0)),
            ]);
        }
    }
    t.print();
    if let Some(b) = doc.get("brownout") {
        let mut t = Table::new(&["monitor", "mean op (us)"]);
        for (label, key) in [
            ("graceful", "graceful_mean_us"),
            ("binary", "binary_mean_us"),
            ("off", "off_mean_us"),
        ] {
            t.row(vec![
                label.into(),
                format!("{:.0}", b.get(key).and_then(Json::as_f64).unwrap_or(0.0)),
            ]);
        }
        t.print();
    }
    println!("(soft demotion keeps a browned-out rail limping at reduced share; binary quarantine rides one rail)");
    println!("{}", doc.to_string());
    Ok(())
}

/// Host-side wall clock per clean allreduce with the wire checksums on or
/// off. The modeled time is identical by design (checksums charge no
/// virtual time), so the difference is the real compute cost of the
/// send/verify passes — the clean-path overhead `BENCH_hotpath.json`
/// records alongside this ablation.
fn clean_wall_us(integrity: bool, ops: usize) -> Result<f64> {
    let mut cfg = chaos_cfg(ExecMode::Serial);
    cfg.integrity = integrity;
    let mut mr = MultiRail::new(&cfg)?;
    // untimed warm pass: planner and allocations settle
    let mut warm = UnboundBuffer::from_fn(CHAOS_NODES, CHAOS_LEN, chaos_fill);
    mr.allreduce_scaled(&mut warm, CHAOS_ELEM_BYTES)?;
    let start = std::time::Instant::now();
    for _ in 0..ops {
        let mut buf = UnboundBuffer::from_fn(CHAOS_NODES, CHAOS_LEN, chaos_fill);
        mr.allreduce_scaled(&mut buf, CHAOS_ELEM_BYTES)?;
    }
    Ok(start.elapsed().as_secs_f64() * 1e6 / ops as f64)
}

/// The full data-plane integrity study as one JSON document (uploaded as
/// the `integrity_ablation.json` CI artifact): every corruption campaign
/// in the seed × executor matrix, run with the wire checksums on (must be
/// bit-exact, in budget, storm rail quarantined) and off (measures the
/// corruption escape rate), plus the clean-path checksum overhead.
pub fn integrity_sweep_json() -> Result<Json> {
    let mut rows = Vec::new();
    let mut on_bit_exact = true;
    let mut on_within_budget = true;
    let mut on_quarantined = true;
    let mut oscillation_bounded = true;
    let mut on_detected: u64 = 0;
    let mut on_escaped = 0usize;
    let mut off_silent: u64 = 0;
    let mut off_escaped = 0usize;
    let mut side_ops = 0usize;
    for &seed in &CHAOS_SWEEP_SEEDS {
        let c = corruption_campaign(seed);
        for exec in [ExecMode::Serial, ExecMode::Parallel] {
            for integrity in [true, false] {
                let o = run_integrity_campaign(&c, exec, integrity)?;
                if integrity {
                    on_bit_exact &= o.bit_exact;
                    on_within_budget &= o.within_budget;
                    on_quarantined &= o.storm_quarantined;
                    oscillation_bounded &= o.max_rail_transitions <= CHAOS_OSC_BOUND;
                    on_detected += o.injected;
                    on_escaped += o.escaped_ops;
                } else {
                    off_silent += o.injected;
                    off_escaped += o.escaped_ops;
                    side_ops += CHAOS_OPS;
                }
                rows.push(Json::obj(vec![
                    ("seed", Json::from(o.seed as f64)),
                    ("exec", Json::from(o.exec)),
                    ("hazards", Json::from(o.label.clone())),
                    ("integrity", Json::Bool(o.integrity)),
                    ("bit_exact_vs_fault_free", Json::Bool(o.bit_exact)),
                    ("escaped_ops", Json::from(o.escaped_ops)),
                    ("corruption_events", Json::from(o.injected as f64)),
                    ("within_recovery_budget", Json::Bool(o.within_budget)),
                    ("storm_rail_quarantined", Json::Bool(o.storm_quarantined)),
                    ("max_rail_transitions", Json::from(o.max_rail_transitions)),
                ]));
            }
        }
    }
    let detection_rate = 1.0 - on_escaped as f64 / side_ops as f64;
    let escape_rate = off_escaped as f64 / side_ops as f64;
    let on_wall = clean_wall_us(true, 48)?;
    let off_wall = clean_wall_us(false, 48)?;
    Ok(Json::obj(vec![
        ("bench", Json::from("integrity")),
        ("budget_us", Json::from(PAPER_RECOVERY_BUDGET_US)),
        ("ops_per_campaign", Json::from(CHAOS_OPS)),
        ("oscillation_bound", Json::from(CHAOS_OSC_BOUND)),
        ("campaigns", Json::Arr(rows)),
        (
            "integrity_on",
            Json::obj(vec![
                ("all_bit_exact", Json::Bool(on_bit_exact)),
                ("all_within_budget", Json::Bool(on_within_budget)),
                ("storm_rail_always_quarantined", Json::Bool(on_quarantined)),
                ("oscillation_bounded", Json::Bool(oscillation_bounded)),
                ("corruption_events_detected", Json::from(on_detected as f64)),
                ("detection_rate", Json::from(detection_rate)),
            ]),
        ),
        (
            "integrity_off",
            Json::obj(vec![
                ("corruption_events_silent", Json::from(off_silent as f64)),
                ("escaped_ops", Json::from(off_escaped)),
                ("escape_rate", Json::from(escape_rate)),
            ]),
        ),
        (
            "clean_path",
            Json::obj(vec![
                (
                    "scenario",
                    Json::from("clean modeled-8MB ops, serial executor, host wall clock per op"),
                ),
                ("checksum_on_wall_us", Json::from(on_wall)),
                ("checksum_off_wall_us", Json::from(off_wall)),
                (
                    "overhead_pct",
                    Json::from((on_wall / off_wall - 1.0) * 100.0),
                ),
            ]),
        ),
    ]))
}

/// Data-plane integrity ablation: the corruption-campaign matrix with the
/// wire checksums on vs off — detection rate, escape rate, quarantine and
/// budget verdicts — plus the clean-path checksum overhead. The JSON
/// document is the last printed line (CI captures it as the
/// `integrity_ablation.json` artifact).
pub fn ablate_integrity() -> Result<()> {
    println!("\n=== Ablation: data-plane integrity under corruption campaigns ===");
    let doc = integrity_sweep_json()?;
    let mut t = Table::new(&[
        "seed", "exec", "hazards", "integrity", "bit-exact", "escaped", "events", "quarantined",
    ]);
    if let Some(Json::Arr(rows)) = doc.get("campaigns") {
        for r in rows {
            t.row(vec![
                format!("{:.0}", r.get("seed").and_then(Json::as_f64).unwrap_or(0.0)),
                r.get("exec").and_then(Json::as_str).unwrap_or("-").to_string(),
                r.get("hazards").and_then(Json::as_str).unwrap_or("-").to_string(),
                r.get("integrity").map(|j| j.to_string()).unwrap_or_default(),
                r.get("bit_exact_vs_fault_free").map(|j| j.to_string()).unwrap_or_default(),
                format!("{:.0}", r.get("escaped_ops").and_then(Json::as_f64).unwrap_or(0.0)),
                format!("{:.0}", r.get("corruption_events").and_then(Json::as_f64).unwrap_or(0.0)),
                r.get("storm_rail_quarantined").map(|j| j.to_string()).unwrap_or_default(),
            ]);
        }
    }
    t.print();
    if let (Some(on), Some(off), Some(clean)) = (
        doc.get("integrity_on"),
        doc.get("integrity_off"),
        doc.get("clean_path"),
    ) {
        println!(
            "detection rate (checksums on): {:.3}; escape rate (checksums off): {:.3}",
            on.get("detection_rate").and_then(Json::as_f64).unwrap_or(0.0),
            off.get("escape_rate").and_then(Json::as_f64).unwrap_or(0.0),
        );
        println!(
            "clean-path checksum overhead: {:.1}% wall ({:.0}us vs {:.0}us per op)",
            clean.get("overhead_pct").and_then(Json::as_f64).unwrap_or(0.0),
            clean.get("checksum_on_wall_us").and_then(Json::as_f64).unwrap_or(0.0),
            clean.get("checksum_off_wall_us").and_then(Json::as_f64).unwrap_or(0.0),
        );
    }
    println!("(wire checksums keep every corruption campaign bit-exact and quarantine the storm rail; ablating them lets poison reach the reduction)");
    println!("{}", doc.to_string());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn campaign_generation_is_deterministic_and_spares_rail0() {
        let a = campaign(7);
        let b = campaign(7);
        assert_eq!(a.label, b.label);
        assert_eq!(a.churn_node, b.churn_node);
        assert_eq!((a.leave_op, a.rejoin_op), (b.leave_op, b.rejoin_op));
        assert!(a.rejoin_op > a.leave_op && a.rejoin_op < CHAOS_OPS);
        for seed in 1..=16 {
            let c = campaign(seed);
            for t in [0.0, 1e4, 1e5, 3e5, 1e6] {
                assert!(!c.faults.is_down(0, t), "seed {seed}: rail 0 must stay up");
                assert!(!c.degrade.active_on(0, t), "seed {seed}: rail 0 must stay clean");
            }
        }
        assert_ne!(campaign(1).label, campaign(2).label, "seeds must differ somewhere");
    }

    /// The gray-failure acceptance criteria, read straight off the
    /// artifact document: every campaign in the seed × executor matrix
    /// holds all three invariants, and graceful soft-demotion beats
    /// binary quarantine-everything on the brownout scenario.
    #[test]
    fn grayfault_acceptance_criteria_hold() {
        let doc = grayfault_sweep_json().unwrap();
        assert_eq!(doc.get("all_bit_exact"), Some(&Json::Bool(true)), "{}", doc.to_string());
        assert_eq!(
            doc.get("all_within_budget"),
            Some(&Json::Bool(true)),
            "{}",
            doc.to_string()
        );
        assert_eq!(
            doc.get("oscillation_bounded"),
            Some(&Json::Bool(true)),
            "{}",
            doc.to_string()
        );
        let b = doc.get("brownout").unwrap();
        assert_eq!(
            b.get("graceful_beats_binary"),
            Some(&Json::Bool(true)),
            "soft demotion must out-run binary quarantine on a brownout: {}",
            b.to_string()
        );
    }

    #[test]
    fn corruption_campaign_is_deterministic_and_spares_rail0() {
        let a = corruption_campaign(7);
        let b = corruption_campaign(7);
        assert_eq!(a.label, b.label);
        assert_eq!(storm_rail(&a), storm_rail(&b));
        assert_eq!((a.leave_op, a.rejoin_op), (b.leave_op, b.rejoin_op));
        for seed in 1..=16 {
            let c = corruption_campaign(seed);
            assert!(!c.corrupt.is_empty(), "seed {seed}: corruption is the point");
            assert!(storm_rail(&c) >= 1, "seed {seed}: rail 0 is the anchor");
            for t in [0.0, 1e4, 1e5, 3e5, 1e6] {
                assert!(!c.faults.is_down(0, t), "seed {seed}: rail 0 must stay up");
                assert!(!c.degrade.active_on(0, t), "seed {seed}: rail 0 must stay clean");
                assert_eq!(c.corrupt.corrupt_at(0, t), 0.0, "seed {seed}: rail 0 must stay clean");
            }
            // the storm is persistent: active from the first op to the last
            assert!(c.corrupt.corrupt_at(storm_rail(&c), 0.0) > 0.0);
            assert!(c.corrupt.corrupt_at(storm_rail(&c), 1e9) > 0.0);
        }
        assert_ne!(corruption_campaign(1).label, corruption_campaign(2).label);
    }

    /// The data-plane integrity acceptance criteria, read straight off
    /// the artifact document: with checksums on every corruption campaign
    /// is bit-exact, in budget and quarantines the storm rail; with
    /// checksums off the measured escape rate is nonzero.
    #[test]
    fn integrity_acceptance_criteria_hold() {
        let doc = integrity_sweep_json().unwrap();
        let on = doc.get("integrity_on").unwrap();
        assert_eq!(on.get("all_bit_exact"), Some(&Json::Bool(true)), "{}", doc.to_string());
        assert_eq!(on.get("all_within_budget"), Some(&Json::Bool(true)), "{}", doc.to_string());
        assert_eq!(
            on.get("storm_rail_always_quarantined"),
            Some(&Json::Bool(true)),
            "{}",
            doc.to_string()
        );
        assert_eq!(on.get("oscillation_bounded"), Some(&Json::Bool(true)), "{}", doc.to_string());
        assert_eq!(on.get("detection_rate").and_then(Json::as_f64), Some(1.0));
        assert!(
            on.get("corruption_events_detected").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "storms must actually inject"
        );
        let off = doc.get("integrity_off").unwrap();
        assert!(
            off.get("escape_rate").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "ablated checksums must leak a measurable escape rate: {}",
            off.to_string()
        );
    }
}
