//! Generators for every table & figure in the paper's evaluation
//! (DESIGN.md §5 maps each to the paper).
//!
//! Each generator prints the same rows/series the paper reports and
//! returns a machine-readable summary used by EXPERIMENTS.md. Absolute
//! numbers come from the calibrated fabric; the claims under test are the
//! *shapes*: who wins, by what factor, where the crossovers fall.

use crate::baselines::FixedShares;
use crate::config::{Config, Policy};
use crate::coordinator::buffer::UnboundBuffer;
use crate::coordinator::collective::Algo;
use crate::coordinator::control::load_balancer::LoadBalancer;
use crate::coordinator::control::BalancerState;
use crate::coordinator::multirail::MultiRail;
use crate::net::cpu_pool::{AllocPolicy, CpuPool};
use crate::net::fault::FaultSchedule;
use crate::net::protocol::{ProtoKind, Protocol};
use crate::net::rail::{NicSpec, Rail};
use crate::net::simnet::Fabric;
use crate::net::topology::ClusterSpec;
use crate::trainer::{CommProfile, DdpSim, GptModel, VtrainSim};
use crate::util::bytes::{fmt_bytes, fmt_us, gbps};
use crate::util::table::Table;
use crate::Result;

/// The paper's payload sweep (Figs. 9/10/13): 2 KB – 64 MB.
pub const SIZES: [u64; 9] = [
    2 << 10,
    8 << 10,
    32 << 10,
    128 << 10,
    512 << 10,
    2 << 20,
    8 << 20,
    32 << 20,
    64 << 20,
];

const SIM_ELEMS: usize = 1024;

fn mk_config(combo: &[ProtoKind], nodes: usize, policy: Policy) -> Config {
    Config {
        nodes,
        combo: combo.to_vec(),
        policy,
        deterministic: true,
        ..Config::default()
    }
}

fn mk(combo: &[ProtoKind], nodes: usize, policy: Policy) -> Result<MultiRail> {
    MultiRail::new(&mk_config(combo, nodes, policy))
}

/// Mean completion latency (us) of `reps` allreduce ops of `bytes`
/// (payload buffers small + scaled; numerics still verified by tests).
fn measure(mr: &mut MultiRail, bytes: u64, warm: usize, reps: usize) -> Result<f64> {
    let elem_bytes = bytes as f64 / SIM_ELEMS as f64;
    for _ in 0..warm {
        let mut buf = UnboundBuffer::from_fn(mr.fab.nodes, SIM_ELEMS, |n, i| ((n + i) % 7) as f32);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
    }
    let mut total = 0.0;
    for _ in 0..reps {
        let mut buf = UnboundBuffer::from_fn(mr.fab.nodes, SIM_ELEMS, |n, i| ((n + i) % 7) as f32);
        total += mr.allreduce_scaled(&mut buf, elem_bytes)?.total_us;
    }
    Ok(total / reps as f64)
}

// ------------------------------------------------------------------ fig2

/// Fig. 2: single-rail latency & throughput of GLEX / TCP / SHARP vs size.
pub fn fig2() -> Result<()> {
    println!("\n=== Fig. 2: protocol latency/throughput vs data size (4 nodes, single rail) ===");
    let mut t = Table::new(&[
        "size", "TCP lat", "SHARP lat", "GLEX lat", "TCP GB/s", "SHARP GB/s", "GLEX GB/s",
    ]);
    for &s in &SIZES {
        let mut row = vec![fmt_bytes(s)];
        let mut thr = Vec::new();
        for kind in [ProtoKind::Tcp, ProtoKind::Sharp, ProtoKind::Glex] {
            let mut mr = mk(&[kind], 4, Policy::SingleRail)?;
            let lat = measure(&mut mr, s, 2, 5)?;
            row.push(fmt_us(lat));
            thr.push(format!("{:.3}", gbps(s, lat)));
        }
        row.extend(thr);
        t.row(row);
    }
    t.print();
    println!(
        "(paper: SHARP ultra-low latency <256KB; GLEX top throughput 64KB-64MB; TCP slowest)"
    );
    Ok(())
}

// ------------------------------------------------------------------ fig3

/// Fig. 3: ideal multi-rail throughput improvement vs efficiency ratio ρ.
pub fn fig3() -> Result<()> {
    println!("\n=== Fig. 3: optimal-network throughput improvement vs ρ(S) ===");
    let mut t = Table::new(&["rho", "ideal improvement", "measured (8MB, dual-rail)"]);
    for rho in [1.0, 2.0, 3.0, 5.0, 8.0, 12.0, 20.0] {
        // ideal: adding a second rail of throughput B/ρ to the best rail
        let ideal = 1.0 + 1.0 / rho;
        // measured: dual TCP where the second NIC is wire-throttled so the
        // effective ratio ≈ rho
        let base = Protocol::tcp().peak_mbps;
        let nic_fast = NicSpec::MCX623106AN;
        let slow_gbps = (base / rho) * 8.0 / 1000.0 / 0.92;
        let rails = vec![
            Rail::new(0, nic_fast.clone(), ProtoKind::Tcp),
            Rail::new(1, nic_fast.clone().throttled(slow_gbps), ProtoKind::Tcp),
        ];
        let fab = Fabric::new(4, rails, CpuPool::default(), 1).deterministic();
        let mut cfg = mk_config(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        cfg.control.tau = 1e9; // disable the tau cutoff to see the raw curve
        let mut mr = MultiRail::new(&cfg)?;
        mr.fab = fab;
        let dual = measure(&mut mr, 8 << 20, 30, 10)?;
        let mut single = mk(&[ProtoKind::Tcp], 4, Policy::SingleRail)?;
        let t_single = measure(&mut single, 8 << 20, 2, 5)?;
        t.row(vec![
            format!("{rho:.0}"),
            format!("{ideal:.2}x"),
            format!("{:.2}x", t_single / dual),
        ]);
    }
    t.print();
    println!("(paper: gains slow beyond rho≈5 → tolerance threshold tau = 5)");
    Ok(())
}

// ------------------------------------------------------------------ fig4

/// Fig. 4: single-rail allreduce throughput vs bound CPU cores.
pub fn fig4() -> Result<()> {
    println!("\n=== Fig. 4: throughput vs CPU cores (8MB allreduce, 4 nodes) ===");
    let mut t = Table::new(&["cores", "TCP GB/s", "SHARP GB/s", "GLEX GB/s"]);
    for cores in [2.0, 8.0, 14.0, 20.0, 26.0, 34.0, 42.0, 52.0] {
        let mut row = vec![format!("{cores:.0}")];
        for kind in [ProtoKind::Tcp, ProtoKind::Sharp, ProtoKind::Glex] {
            let rails = ClusterSpec::local().build_rails(&[kind])?;
            let fab =
                Fabric::new(4, rails, CpuPool::new(cores, AllocPolicy::Adaptive), 1)
                    .deterministic();
            let mut cfg = mk_config(&[kind], 4, Policy::SingleRail);
            cfg.deterministic = true;
            let mut mr = MultiRail::new(&cfg)?;
            mr.fab = fab;
            let lat = measure(&mut mr, 8 << 20, 1, 3)?;
            row.push(format!("{:.3}", gbps(8 << 20, lat)));
        }
        t.row(row);
    }
    t.print();
    println!("(paper: TCP saturates at ~26 cores; GLEX/SHARP keep scaling)");
    Ok(())
}

// ---------------------------------------------------------------- table1

/// Table 1: 4-node TCP/SHARP latency under allocation strategies.
pub fn table1() -> Result<()> {
    println!("\n=== Table 1: average allreduce latency on 4 nodes (us), TCP-SHARP ===");
    let combo = [ProtoKind::Tcp, ProtoKind::Sharp];
    let mut t = Table::new(&[
        "data", "SHARP", "TCP", "T/S 1/1", "T/S 99/1", "T/S 1/99", "T/S slic",
    ]);
    for &s in &[1u64 << 10, 8 << 20, 64 << 20] {
        let sharp = measure(&mut mk(&[ProtoKind::Sharp], 4, Policy::SingleRail)?, s, 2, 5)?;
        let tcp = measure(&mut mk(&[ProtoKind::Tcp], 4, Policy::SingleRail)?, s, 2, 5)?;
        let split = |x: u32, y: u32| -> Result<f64> {
            let mut mr = mk(&combo, 4, Policy::Nezha)?;
            mr.partitioner = Box::new(FixedShares::percent(x, y));
            measure(&mut mr, s, 2, 5)
        };
        let even = split(50, 50)?;
        let t99 = split(99, 1)?;
        let s99 = split(1, 99)?;
        let slic = measure(&mut mk(&combo, 4, Policy::Mptcp)?, s, 2, 3)?;
        t.row(vec![
            fmt_bytes(s),
            format!("{sharp:.0}"),
            format!("{tcp:.0}"),
            format!("{even:.0}"),
            format!("{t99:.0}"),
            format!("{s99:.0}"),
            format!("{slic:.0}"),
        ]);
    }
    t.print();
    println!("(paper row for 64MB: SHARP 181484, TCP 316323, 1/1 178373, 99/1 314913, 1/99 188137, slic 257135)");
    Ok(())
}

// ------------------------------------------------------------------ fig8

/// Fig. 8: NIC transfer-rate timeline under injected rail failures
/// (dual-TCP, NIC 2 down during minutes 1–2 and 4–5).
pub fn fig8() -> Result<()> {
    println!("\n=== Fig. 8: per-NIC transfer rate under rail failure (dual TCP, 8MB ops) ===");
    let cfg = mk_config(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
    let mut mr = MultiRail::new(&cfg)?.with_faults(FaultSchedule::fig8());
    const MIN: f64 = 60.0 * 1e6;
    let bytes = 8u64 << 20;
    let elem_bytes = bytes as f64 / SIM_ELEMS as f64;
    // 10-second reporting buckets over 6 virtual minutes
    let mut buckets = vec![[0u64; 2]; 36];
    while mr.fab.now_us() < 6.0 * MIN {
        let mut buf =
            UnboundBuffer::from_fn(mr.fab.nodes, SIM_ELEMS, |n, i| ((n + i) % 7) as f32);
        let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
        let b = ((rep.completed_at_us / 1e7) as usize).min(35);
        for s in &rep.per_rail {
            if s.rail < 2 {
                buckets[b][s.rail] += s.bytes;
            }
        }
    }
    let mut t = Table::new(&["t(min)", "NIC1 MB/s", "NIC2 MB/s", "state"]);
    for (i, b) in buckets.iter().enumerate() {
        let tmin = i as f64 / 6.0;
        let state = if (1.0..2.0).contains(&tmin) || (4.0..5.0).contains(&tmin) {
            "NIC2 DOWN"
        } else {
            ""
        };
        if i % 3 == 0 {
            t.row(vec![
                format!("{tmin:.1}"),
                format!("{:.0}", b[0] as f64 / 10.0 / 1e6),
                format!("{:.0}", b[1] as f64 / 10.0 / 1e6),
                state.into(),
            ]);
        }
    }
    t.print();
    let max_rec = mr
        .exceptions
        .events
        .iter()
        .map(|e| e.recovery_us)
        .fold(0.0f64, f64::max);
    println!(
        "failovers: {}; max detection+migration: {:.0} ms (paper budget: <200 ms)",
        mr.exceptions.failover_count(),
        max_rec / 1e3
    );
    assert!(max_rec < 200_000.0);
    Ok(())
}

// ------------------------------------------------------------- fig9/fig10

fn policy_sweep(combo: &[ProtoKind], nodes: usize, label: &str) -> Result<()> {
    println!(
        "\n=== {label}: latency (us) & best-vs-single-rail throughput gain, {nodes} nodes ==="
    );
    // single-rail baseline = the best member network alone
    let est = |k: ProtoKind| {
        Protocol::of(k).allreduce_time_us(8.0 * 1024.0 * 1024.0, nodes, 52.0, 11500.0)
    };
    // SHARP/GLEX beat TCP at large sizes; pick the best by 8MB estimate
    let best_single: Vec<ProtoKind> = vec![*combo
        .iter()
        .min_by(|a, b| est(**a).partial_cmp(&est(**b)).unwrap())
        .unwrap()];
    let mut t = Table::new(&["size", "single", "MRIB", "MPTCP", "Nezha", "gain(best)"]);
    let mut max_gain = (0.0f64, 0u64);
    for &s in &SIZES {
        let single = measure(&mut mk(&best_single, nodes, Policy::SingleRail)?, s, 2, 5)?;
        let mrib = measure(&mut mk(combo, nodes, Policy::Mrib)?, s, 2, 5)?;
        let mptcp = measure(&mut mk(combo, nodes, Policy::Mptcp)?, s, 2, 3)?;
        let nezha = measure(&mut mk(combo, nodes, Policy::Nezha)?, s, 30, 10)?;
        let gain = single / nezha - 1.0;
        if gain > max_gain.0 {
            max_gain = (gain, s);
        }
        t.row(vec![
            fmt_bytes(s),
            format!("{single:.0}"),
            format!("{mrib:.0}"),
            format!("{mptcp:.0}"),
            format!("{nezha:.0}"),
            format!("{:+.0}%", gain * 100.0),
        ]);
    }
    t.print();
    println!(
        "max Nezha gain over single rail: {:+.0}% at {}",
        max_gain.0 * 100.0,
        fmt_bytes(max_gain.1)
    );
    Ok(())
}

/// Fig. 9: homogeneous dual-rail TCP, 4 and 8 nodes.
pub fn fig9() -> Result<()> {
    for nodes in [4, 8] {
        policy_sweep(&[ProtoKind::Tcp, ProtoKind::Tcp], nodes, "Fig. 9 (TCP-TCP)")?;
    }
    // also report the cold->hot threshold shift with node count
    for nodes in [4, 8] {
        let cfg = mk_config(&[ProtoKind::Tcp, ProtoKind::Tcp], nodes, Policy::Nezha);
        let mr = MultiRail::new(&cfg)?;
        let mut lb = LoadBalancer::new(cfg.control.clone());
        let th = lb.threshold_bytes(&mr.fab, &mr.timer, &[0, 1]);
        println!("cold->hot threshold at {nodes} nodes: {}", fmt_bytes(th));
    }
    println!("(paper: thresholds 256KB @4 nodes, 128KB @8 nodes; gains 84%/87%)");
    Ok(())
}

/// Fig. 10: heterogeneous TCP-SHARP and TCP-GLEX, 4 and 8 nodes.
pub fn fig10() -> Result<()> {
    for nodes in [4, 8] {
        policy_sweep(&[ProtoKind::Tcp, ProtoKind::Sharp], nodes, "Fig. 10 (TCP-SHARP)")?;
        policy_sweep(&[ProtoKind::Tcp, ProtoKind::Glex], nodes, "Fig. 10 (TCP-GLEX)")?;
    }
    println!("(paper: Nezha up to +52%/+63% (SHARP), +46%/+47% (GLEX) vs best single rail)");
    Ok(())
}

// ----------------------------------------------------------------- fig11

/// Fig. 11: data allocation ratio to the non-TCP rail (Nezha vs MRIB).
pub fn fig11() -> Result<()> {
    println!("\n=== Fig. 11: allocation ratio to the RDMA rail (TS=TCP-SHARP, TG=TCP-GLEX) ===");
    let mut t = Table::new(&["size", "TS^4", "TS^8", "TG^4", "TG^8", "MRIB"]);
    let combos: [(&str, [ProtoKind; 2]); 2] = [
        ("TS", [ProtoKind::Tcp, ProtoKind::Sharp]),
        ("TG", [ProtoKind::Tcp, ProtoKind::Glex]),
    ];
    let mut cells: std::collections::BTreeMap<(u64, String), f64> = Default::default();
    for (name, combo) in &combos {
        for nodes in [4usize, 8] {
            let mut mr = mk(combo, nodes, Policy::Nezha)?;
            for &s in &SIZES {
                measure(&mut mr, s, 40, 1)?; // converge the table
                // α of the non-TCP (RDMA) rail = rail id 1 in these combos
                let nezha_p = mr
                    .partitioner
                    .alphas(s)
                    .and_then(|a| a.iter().find(|(r, _)| *r == 1).map(|(_, f)| *f))
                    .unwrap_or(0.0); // cold: all data on the RDMA rail
                cells.insert((s, format!("{name}{nodes}")), nezha_p);
            }
        }
    }
    for &s in &SIZES {
        t.row(vec![
            fmt_bytes(s),
            fmt_ratio(cells.get(&(s, "TS4".into()))),
            fmt_ratio(cells.get(&(s, "TS8".into()))),
            fmt_ratio(cells.get(&(s, "TG4".into()))),
            fmt_ratio(cells.get(&(s, "TG8".into()))),
            "0.50".into(), // MRIB static (both NICs 100G → 50/50)
        ]);
    }
    t.print();
    println!("(cold-state sizes route 100% to the RDMA rail → shown as 1.00)");
    Ok(())
}

fn fmt_ratio(v: Option<&f64>) -> String {
    match v {
        Some(&a) if a > 0.0 => format!("{a:.2}"),
        _ => "1.00*".into(),
    }
}

// ----------------------------------------------------------------- fig13

/// Fig. 13: multi-NIC vs virtual dual-rail vs single NIC, 1 vs 100 Gbps.
pub fn fig13() -> Result<()> {
    println!("\n=== Fig. 13: TCP-TCP(Eth1-Eth2) vs TCP-TCP(Eth1 virtual) vs TCP(Eth1) ===");
    for gbps_nic in [1.0, 100.0] {
        println!("--- {gbps_nic:.0} Gbps NICs ---");
        let nic = if gbps_nic < 10.0 {
            NicSpec::BCM5720
        } else {
            NicSpec::MCX623106AN
        };
        let mut t = Table::new(&["size", "dual-NIC", "virtual dual", "single"]);
        for &s in &[512u64 << 10, 2 << 20, 8 << 20, 32 << 20, 64 << 20] {
            let mk_fab = |rails: Vec<Rail>| {
                Fabric::new(4, rails, CpuPool::default(), 1).deterministic()
            };
            let phys = vec![
                Rail::new(0, nic.clone(), ProtoKind::Tcp),
                Rail::new(1, nic.clone(), ProtoKind::Tcp),
            ];
            let virt = vec![
                Rail::new(0, nic.clone(), ProtoKind::Tcp).virtual_channel(0, 2),
                Rail::new(0, nic.clone(), ProtoKind::Tcp).virtual_channel(1, 2),
            ];
            let single = vec![Rail::new(0, nic.clone(), ProtoKind::Tcp)];
            let mut res = Vec::new();
            for rails in [phys, virt, single] {
                let n_rails = rails.len();
                let combo = vec![ProtoKind::Tcp; n_rails];
                let policy = if n_rails == 1 { Policy::SingleRail } else { Policy::Nezha };
                let mut mr = MultiRail::new(&mk_config(&combo, 4, policy))?;
                mr.fab = mk_fab(rails);
                res.push(measure(&mut mr, s, 25, 5)?);
            }
            t.row(vec![
                fmt_bytes(s),
                fmt_us(res[0]),
                fmt_us(res[1]),
                fmt_us(res[2]),
            ]);
        }
        t.print();
    }
    println!("(paper: at 1 Gbps the wire binds → virtual dual ≈ single; at 100 Gbps CPU binds → virtual dual ≈ dual-NIC < single)");
    Ok(())
}

// ------------------------------------------------------------ dispatcher

/// Run one figure/table by id ("fig2".."fig19", "table1", "all").
pub fn run(id: &str) -> Result<()> {
    match id {
        "fig2" => fig2(),
        "fig3" => fig3(),
        "fig4" => fig4(),
        "table1" => table1(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12" => super::figures_app::fig12(),
        "fig13" => fig13(),
        "fig14" => super::figures_app::fig14(),
        "fig15" => super::figures_app::fig15(),
        "fig16" => super::figures_app::fig16(),
        "fig17" => super::figures_app::fig17(),
        "fig18" => super::figures_app::fig18(),
        "fig19" => super::figures_app::fig19(),
        "headline" => super::figures_app::headline(),
        "ablate" => super::ablation::run_all(),
        "ablate-multilevel" | "ablate_multilevel" | "multilevel" => {
            super::ablation::ablate_multilevel()
        }
        "ablate-tenancy" | "ablate_tenancy" | "tenancy" => super::ablation::ablate_tenancy(),
        "ablate-churn" | "ablate_churn" | "churn" => super::ablation::ablate_churn(),
        "ablate-scheduler" | "ablate_scheduler" | "scheduler" => {
            super::ablation::ablate_scheduler()
        }
        "ablate-grayfault" | "ablate_grayfault" | "grayfault" => super::chaos::ablate_grayfault(),
        "ablate-integrity" | "ablate_integrity" | "integrity" => super::chaos::ablate_integrity(),
        "plan-quality" | "plan_quality" | "planq" => super::harness::plan_quality_fig(),
        "all" => {
            for id in [
                "fig2", "fig3", "fig4", "table1", "fig8", "fig9", "fig10", "fig11", "fig12",
                "fig13", "fig14", "fig15", "fig16", "fig17", "fig18", "fig19", "headline",
                "ablate", "plan-quality",
            ] {
                run(id)?;
            }
            Ok(())
        }
        other => Err(crate::util::error::Error::Config(format!(
            "unknown figure `{other}` (fig2..fig19, table1, headline, plan-quality, \
             ablate-multilevel, ablate-tenancy, ablate-churn, ablate-scheduler, \
             ablate-grayfault, ablate-integrity, all)"
        ))),
    }
}

// keep the DdpSim / trainer imports used (figures_app has the app-level
// generators)
#[allow(unused)]
fn _keep(_: Option<(CommProfile, DdpSim, VtrainSim, GptModel, Algo, BalancerState)>) {}
