//! Application-level figure generators (paper §5.3): model training
//! speed, per-network latency during training, communication profiles,
//! GPU/NIC scaling grids, scalability, and the GPT-3 vTrain replays.

use crate::baselines::FixedShares;
use crate::config::{Config, Policy};
use crate::coordinator::buffer::UnboundBuffer;
use crate::coordinator::multirail::MultiRail;
use crate::net::topology::parse_combo;
use crate::trainer::{CommProfile, DdpSim, GptModel, VtrainSim};
use crate::util::bytes::{fmt_bytes, fmt_us};
use crate::util::table::Table;
use crate::Result;

fn cfg(combo: &str, nodes: usize, policy: Policy) -> Result<Config> {
    Ok(Config {
        nodes,
        combo: parse_combo(combo)?,
        policy,
        deterministic: true,
        ..Config::default()
    })
}

fn speed(combo: &str, nodes: usize, policy: Policy, model: &CommProfile, gpus: usize, bs: usize) -> Result<f64> {
    let mut sim = DdpSim::new(&cfg(combo, nodes, policy)?, model.clone(), gpus, bs)?;
    sim.warmup(5)?;
    sim.samples_per_sec_per_node()
}

// ----------------------------------------------------------------- fig12

/// Fig. 12: AlexNet/VGG-11 training speed per backend×network.
pub fn fig12() -> Result<()> {
    println!("\n=== Fig. 12: average model training speed (samples/s/node) ===");
    let nets: [(&str, &str, Policy); 6] = [
        ("TCP (Gloo)", "tcp", Policy::SingleRail),
        ("SHARP", "sharp", Policy::SingleRail),
        ("GLEX", "glex", Policy::SingleRail),
        ("TCP-TCP", "tcp-tcp", Policy::Nezha),
        ("TCP-SHARP", "tcp-sharp", Policy::Nezha),
        ("TCP-GLEX", "tcp-glex", Policy::Nezha),
    ];
    for (model, bs) in [("alexnet", 32), ("vgg11", 64)] {
        let prof = CommProfile::by_name(model).unwrap();
        println!("--- {} (bs={bs}) ---", prof.name);
        let mut t = Table::new(&["network", "N=4", "N=8"]);
        for (label, combo, policy) in nets {
            let s4 = speed(combo, 4, policy, &prof, 1, bs)?;
            let s8 = speed(combo, 8, policy, &prof, 1, bs)?;
            t.row(vec![label.into(), format!("{s4:.1}"), format!("{s8:.1}")]);
        }
        t.print();
    }
    println!("(paper: TCP-TCP +19.9%/+50.4% over Gloo TCP for VGG-11 bs64 at 4/8 nodes)");
    Ok(())
}

// ----------------------------------------------------------------- fig14

/// Fig. 14: per-member-network allreduce latency during AlexNet training
/// (4 nodes): optimal allocation vs 99:1 probes vs single-rail.
pub fn fig14() -> Result<()> {
    println!("\n=== Fig. 14: member-network latency during AlexNet (4 nodes, 4MB ops) ===");
    let bytes = 4u64 << 20;
    let combos = [("TCP-TCP", "tcp-tcp"), ("TCP-SHARP", "tcp-sharp"), ("TCP-GLEX", "tcp-glex")];
    let mut t = Table::new(&[
        "combo", "rail0 (opt)", "rail1 (opt)", "rail0 (99%)", "rail1 (1%)", "sched err",
    ]);
    for (label, combo) in combos {
        // optimal (Nezha) allocation, converged
        let mut mr = MultiRail::new(&cfg(combo, 4, Policy::Nezha)?)?;
        let mut last = None;
        for _ in 0..40 {
            let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n + i) % 5) as f32);
            last = Some(mr.allreduce_scaled(&mut buf, bytes as f64 / 1024.0)?);
        }
        let rep = last.unwrap();
        let t0 = rep.per_rail.iter().find(|s| s.rail == 0).map(|s| s.time_us).unwrap_or(0.0);
        let t1 = rep.per_rail.iter().find(|s| s.rail == 1).map(|s| s.time_us).unwrap_or(0.0);
        let err = if t0 > 0.0 && t1 > 0.0 {
            (t0 - t1).abs() / t0.max(t1)
        } else {
            0.0
        };
        // 99:1 probe
        let mut mr99 = MultiRail::new(&cfg(combo, 4, Policy::Nezha)?)?;
        mr99.partitioner = Box::new(FixedShares::percent(99, 1));
        let mut buf = UnboundBuffer::from_fn(4, 1024, |n, i| ((n + i) % 5) as f32);
        let rep99 = mr99.allreduce_scaled(&mut buf, bytes as f64 / 1024.0)?;
        let p0 = rep99.per_rail.iter().find(|s| s.rail == 0).map(|s| s.time_us).unwrap_or(0.0);
        let p1 = rep99.per_rail.iter().find(|s| s.rail == 1).map(|s| s.time_us).unwrap_or(0.0);
        t.row(vec![
            label.into(),
            fmt_us(t0),
            fmt_us(t1),
            fmt_us(p0),
            fmt_us(p1),
            format!("{:.1}%", err * 100.0),
        ]);
    }
    t.print();
    println!("(paper: balanced latency across members; average scheduling error within 9.3%)");
    Ok(())
}

// ----------------------------------------------------------------- fig15

/// Fig. 15: allreduce count & data size per training epoch.
pub fn fig15() -> Result<()> {
    println!("\n=== Fig. 15: allreduce count & volume per epoch (global batch 256) ===");
    for prof in [CommProfile::alexnet(), CommProfile::vgg11()] {
        println!("--- {} ({} ops/iter, {} / iter) ---",
            prof.name,
            prof.ops.len(),
            fmt_bytes(prof.bytes_per_iter()),
        );
        let h = prof.epoch_histogram(256);
        let mut t = Table::new(&["size bucket", "count/epoch", "volume/epoch"]);
        for (lb, count, bytes) in h.rows() {
            t.row(vec![
                format!(">={}", fmt_bytes(lb)),
                format!("{count}"),
                fmt_bytes(bytes),
            ]);
        }
        t.print();
    }
    println!("(paper: AlexNet traffic <4MB; VGG-11 intensive in 2–16MB)");
    Ok(())
}

// ----------------------------------------------------------------- fig16

/// Fig. 16: GxNy training-speed grid (GPUs × NICs per node).
pub fn fig16() -> Result<()> {
    println!("\n=== Fig. 16: training speed grid, values = samples/s/node (ratio vs G1N1) ===");
    let grid: [(&str, usize, &str); 5] = [
        ("G1N1", 1, "tcp"),
        ("G1N2", 1, "tcp-tcp"),
        ("G1N3", 1, "tcp-tcp-tcp"),
        ("G2N1", 2, "tcp"),
        ("G2N2", 2, "tcp-tcp"),
    ];
    for nodes in [4usize, 6] {
        println!("--- {nodes} nodes ---");
        let mut t = Table::new(&["model", "G1N1", "G1N2", "G1N3", "G2N1", "G2N2"]);
        for (model, bs) in [("alexnet", 32), ("alexnet", 64), ("vgg11", 32), ("vgg11", 64)] {
            let prof = CommProfile::by_name(model).unwrap();
            let mut row = vec![format!("{}_{bs}", prof.name)];
            let mut base = 0.0;
            for (label, gpus, combo) in grid {
                let policy = if combo == "tcp" { Policy::SingleRail } else { Policy::Nezha };
                let s = speed(combo, nodes, policy, &prof, gpus, bs)?;
                if label == "G1N1" {
                    base = s;
                    row.push(format!("{s:.1}"));
                } else {
                    row.push(format!("{s:.1} ({:.2})", s / base));
                }
            }
            t.row(row);
        }
        t.print();
    }
    println!("(paper: G2N2 ≈ 2.4–2.6× G1N1; G1N2 ≈ 1.4–1.5×; multi-rail complements multi-GPU)");
    Ok(())
}

// ----------------------------------------------------------------- fig17

/// Fig. 17: AlexNet training-speed scalability (TCP-TCP vs TCP).
pub fn fig17() -> Result<()> {
    println!("\n=== Fig. 17: AlexNet scalability: Nezha TCP-TCP vs Gloo TCP ===");
    let prof = CommProfile::alexnet();
    let mut t = Table::new(&["nodes", "TCP (Gloo)", "TCP-TCP (Nezha)", "ratio"]);
    for nodes in [4usize, 6, 8, 10, 12, 16] {
        let single = speed("tcp", nodes, Policy::SingleRail, &prof, 1, 32)?;
        let dual = speed("tcp-tcp", nodes, Policy::Nezha, &prof, 1, 32)?;
        t.row(vec![
            format!("{nodes}"),
            format!("{single:.1}"),
            format!("{dual:.1}"),
            format!("{:.2}x", dual / single),
        ]);
    }
    t.print();
    println!("(paper: improvement ratio grows with node count — 1.51x..1.54x band)");
    Ok(())
}

// ------------------------------------------------------------- fig18/19

fn gpt_figure(chunk: Option<u64>, label: &str) -> Result<()> {
    println!("\n=== {label} ===");
    for model in [GptModel::Gpt2_7B, GptModel::Gpt30B] {
        println!("--- {} ---", model.name());
        let mut t = Table::new(&["nodes", "Gloo TCP (s)", "Nezha TCP-TCP (s)", "speedup"]);
        for nodes in [16usize, 32, 64, 128] {
            let mut gloo = VtrainSim::new(model, nodes, Policy::SingleRail, chunk)?;
            let mut nezha = VtrainSim::new(model, nodes, Policy::Nezha, chunk)?;
            let tg = gloo.iteration_time_s()?;
            let tn = nezha.iteration_time_s()?;
            t.row(vec![
                format!("{nodes}"),
                format!("{tg:.1}"),
                format!("{tn:.1}"),
                format!("{:.2}x", tg / tn),
            ]);
        }
        t.print();
    }
    Ok(())
}

/// Fig. 18: GPT-3 iteration time, Ring allreduce, 16–128 nodes.
pub fn fig18() -> Result<()> {
    gpt_figure(None, "Fig. 18: GPT-3 training iteration time (Ring allreduce)")?;
    println!("(paper: Nezha 2.38x at 128 nodes, exceeding the theoretical 2x)");
    Ok(())
}

/// Fig. 19: same with Ring_Chunked (64 MB pipeline chunks).
pub fn fig19() -> Result<()> {
    gpt_figure(
        Some(64 * 1024 * 1024),
        "Fig. 19: GPT-3 training iteration time (Ring_Chunked allreduce)",
    )?;
    println!("(paper: chunking flattens iteration growth below 128 nodes)");
    Ok(())
}

// ---------------------------------------------------------------- headline

/// The abstract's headline claims, measured on this reproduction.
pub fn headline() -> Result<()> {
    println!("\n=== Headline claims (abstract) ===");
    // throughput claims live at bandwidth-bound sizes (>=512KB); tiny
    // payloads produce degenerate ratios (SHARP 13us vs TCP ~1ms)
    let sizes: Vec<u64> = super::figures::SIZES
        .iter()
        .copied()
        .filter(|s| *s >= 512 << 10)
        .collect();
    // 1. +74% over MPTCP homogeneous (8 nodes)
    let mut best = (0.0f64, 0u64);
    for &s in &sizes {
        let mptcp = probe("tcp-tcp", 8, Policy::Mptcp, s, 3)?;
        let nezha = probe("tcp-tcp", 8, Policy::Nezha, s, 10)?;
        let gain = mptcp / nezha - 1.0;
        if gain > best.0 {
            best = (gain, s);
        }
    }
    println!(
        "Nezha vs MPTCP, homogeneous TCP-TCP, 8 nodes: +{:.0}% (paper: +74%) at {}",
        best.0 * 100.0,
        fmt_bytes(best.1)
    );
    // 2. +80% over MPTCP heterogeneous
    let mut best = (0.0f64, 0u64);
    for &s in &sizes {
        let mptcp = probe("tcp-sharp", 8, Policy::Mptcp, s, 3)?;
        let nezha = probe("tcp-sharp", 8, Policy::Nezha, s, 10)?;
        let gain = mptcp / nezha - 1.0;
        if gain > best.0 {
            best = (gain, s);
        }
    }
    println!(
        "Nezha vs MPTCP, heterogeneous TCP-SHARP, 8 nodes: +{:.0}% (paper: +80%) at {}",
        best.0 * 100.0,
        fmt_bytes(best.1)
    );
    // 3. 2.36x training efficiency vs Gloo at 128 nodes
    let mut gloo = VtrainSim::new(GptModel::Gpt2_7B, 128, Policy::SingleRail, None)?;
    let mut nezha = VtrainSim::new(GptModel::Gpt2_7B, 128, Policy::Nezha, None)?;
    let ratio = gloo.iteration_time_s()? / nezha.iteration_time_s()?;
    println!("Nezha vs Gloo, GPT-3 2.7B @128 nodes: {ratio:.2}x (paper: 2.36x)");
    Ok(())
}

fn probe(combo: &str, nodes: usize, policy: Policy, bytes: u64, reps: usize) -> Result<f64> {
    let mut mr = MultiRail::new(&cfg(combo, nodes, policy)?)?;
    let elem_bytes = bytes as f64 / 1024.0;
    let warm = if policy == Policy::Nezha { 30 } else { 2 };
    for _ in 0..warm {
        let mut buf = UnboundBuffer::from_fn(nodes, 1024, |n, i| ((n + i) % 7) as f32);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
    }
    let mut total = 0.0;
    for _ in 0..reps {
        let mut buf = UnboundBuffer::from_fn(nodes, 1024, |n, i| ((n + i) % 7) as f32);
        total += mr.allreduce_scaled(&mut buf, elem_bytes)?.total_us;
    }
    Ok(total / reps as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speed_helper_runs() {
        let prof = CommProfile::alexnet();
        let s = speed("tcp-tcp", 4, Policy::Nezha, &prof, 1, 32).unwrap();
        assert!(s > 0.0);
    }
}
