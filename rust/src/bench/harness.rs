//! Wall-clock bench harness (criterion is unavailable offline): warmup,
//! fixed-iteration measurement, mean/percentile reporting — plus the
//! shared modeled-latency measurement loop used by the benches, the
//! ablations and the planner tests.

use std::time::Instant;

use crate::config::{Config, PlannerMode, Policy};
use crate::coordinator::buffer::BufferPool;
use crate::coordinator::multirail::MultiRail;
use crate::coordinator::planner::PlanQualityReport;
use crate::net::topology::{parse_combo, ClusterSpec};
use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

/// Committed ceiling for the plan-quality regression: the deterministic
/// sweep's median relative |predicted − measured| / measured error. The
/// tier-1 regression test fails the build when cost-model drift pushes the
/// sweep past this.
pub const PLAN_QUALITY_MEDIAN_ERR_MAX: f64 = 0.05;

/// Payload sweep the plan-quality regression and report run over.
pub const PLAN_QUALITY_SIZES: [u64; 5] = [256 << 10, 1 << 20, 8 << 20, 64 << 20, 256 << 20];

/// Mean modeled completion latency (us) of `reps` allreduces of `bytes`
/// after `warm` warmup ops, on 1024-element scaled buffers. Buffers are
/// pooled: one staging buffer is allocated for the whole measurement loop
/// and re-filled in place per repetition (bit-identical to a fresh
/// allocation — see [`BufferPool`]).
pub fn mean_allreduce_us(
    mr: &mut MultiRail,
    bytes: u64,
    warm: usize,
    reps: usize,
) -> crate::Result<f64> {
    const ELEMS: usize = 1024;
    let elem_bytes = bytes as f64 / ELEMS as f64;
    let mut pool = BufferPool::new();
    let mut total = 0.0;
    for i in 0..warm + reps {
        let mut buf = pool.acquire(mr.fab.nodes, ELEMS, |n, j| ((n + j) % 7) as f32);
        let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
        pool.release(buf);
        if i >= warm {
            total += rep.total_us;
        }
        // hand the report vector back so the measured loop allocates
        // nothing once pool + scratch capacities stabilize
        mr.recycle(rep);
    }
    Ok(total / reps.max(1) as f64)
}

/// Mean Nezha-policy latency of `bytes`-sized allreduces under a planner
/// mode on an explicit cluster, plus the executed plan's label (`"-"`
/// under fixed dispatch, where no planner schedule runs). Shared by the
/// planner-vs-fixed bench sweep and the planner ablation.
#[allow(clippy::too_many_arguments)]
pub fn planner_mode_latency(
    cluster: &ClusterSpec,
    combo: &str,
    nodes: usize,
    mode: PlannerMode,
    bytes: u64,
    warm: usize,
    reps: usize,
) -> crate::Result<(f64, String)> {
    let mut cfg = Config {
        cluster: cluster.clone(),
        nodes,
        combo: parse_combo(combo)?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    cfg.planner = mode;
    let mut mr = MultiRail::new(&cfg)?;
    let lat = mean_allreduce_us(&mut mr, bytes, warm, reps)?;
    let plan = mr
        .last_plan
        .as_ref()
        .map(|p| p.label())
        .unwrap_or_else(|| "-".into());
    Ok((lat, plan))
}

/// Run the deterministic Nezha sweep over [`PLAN_QUALITY_SIZES`] on an
/// explicit cluster and hand back the coordinator's accumulated
/// [`PlanQualityReport`] (per-rail predicted vs measured for every
/// planner-scheduled op).
pub fn plan_quality_sweep(
    cluster: &ClusterSpec,
    combo: &str,
    nodes: usize,
    warm: usize,
    reps: usize,
) -> crate::Result<PlanQualityReport> {
    let cfg = Config {
        cluster: cluster.clone(),
        nodes,
        combo: parse_combo(combo)?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    for &bytes in &PLAN_QUALITY_SIZES {
        mean_allreduce_us(&mut mr, bytes, warm, reps)?;
    }
    Ok(mr.quality.clone())
}

/// The standard plan-quality sweep cases — shared by the JSON report
/// (`plan_quality_json`) and the tier-1 regression test so they can never
/// silently diverge in coverage.
pub fn plan_quality_cases() -> Vec<(&'static str, ClusterSpec, &'static str, usize)> {
    vec![
        ("local", ClusterSpec::local(), "tcp-tcp", 8),
        ("pods", ClusterSpec::pods(4), "tcp-tcp-tcp-glex", 16),
    ]
}

/// The PlanQualityReport JSON document for the standard local + pods
/// sweeps — what `nezha fig plan-quality` and `bench_allreduce` emit (and
/// CI uploads as a workflow artifact).
pub fn plan_quality_json() -> crate::Result<Json> {
    let mut sweeps = Vec::new();
    for (name, cluster, combo, nodes) in plan_quality_cases() {
        let report = plan_quality_sweep(&cluster, combo, nodes, 10, 5)?;
        sweeps.push(Json::obj(vec![
            ("cluster", Json::from(name)),
            ("combo", Json::from(combo)),
            ("nodes", Json::from(nodes as f64)),
            ("quality", report.to_json()),
        ]));
    }
    Ok(Json::obj(vec![
        ("bench", Json::from("plan_quality")),
        ("policy", Json::from("nezha")),
        ("threshold_median_rel_err", Json::from(PLAN_QUALITY_MEDIAN_ERR_MAX)),
        ("sweeps", Json::Arr(sweeps)),
    ]))
}

/// Print the plan-quality report document (the `fig plan-quality` id).
pub fn plan_quality_fig() -> crate::Result<()> {
    println!("\n=== plan quality: predicted vs measured (JSON) ===");
    println!("{}", plan_quality_json()?.to_string());
    Ok(())
}

/// Mean Nezha latency under `mode` with a persistent straggler injected on
/// `rail` (per-message `stall_us`) — the corrections-vs-static-cost
/// comparison the straggler ablation and acceptance tests run. Returns
/// (mean latency, executed plan label).
#[allow(clippy::too_many_arguments)]
pub fn straggler_mode_latency(
    cluster: &ClusterSpec,
    combo: &str,
    nodes: usize,
    mode: PlannerMode,
    rail: usize,
    stall_us: f64,
    bytes: u64,
    warm: usize,
    reps: usize,
) -> crate::Result<(f64, String)> {
    let mut cfg = Config {
        cluster: cluster.clone(),
        nodes,
        combo: parse_combo(combo)?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    cfg.planner = mode;
    cfg.control.timer_window = 5;
    let mut mr = MultiRail::new(&cfg)?.with_straggler(rail, stall_us, 0.0);
    let lat = mean_allreduce_us(&mut mr, bytes, warm, reps)?;
    let plan = mr
        .last_plan
        .as_ref()
        .map(|p| p.label())
        .unwrap_or_else(|| "-".into());
    Ok((lat, plan))
}

/// The canonical straggler-corrections sweep: pods topology, dual TCP,
/// 16 nodes, persistent per-message stall on rail 0, `(bytes, stall_us)`
/// per case. Shared by the ablation table and the bench JSON so the two
/// artifacts cannot drift apart.
pub const STRAGGLER_SWEEP_RAIL: usize = 0;
pub const STRAGGLER_SWEEP_CASES: [(u64, f64); 2] = [(256 << 20, 8_000.0), (1 << 30, 15_000.0)];

/// One straggler-sweep comparison: planner=auto (corrections) vs
/// planner=static-cost (a-priori model only) under the same injected
/// straggler.
#[derive(Debug, Clone)]
pub struct StragglerRow {
    pub bytes: u64,
    pub stall_us: f64,
    pub static_us: f64,
    pub static_plan: String,
    pub auto_us: f64,
    pub auto_plan: String,
}

/// Run the canonical straggler sweep (see [`STRAGGLER_SWEEP_CASES`]).
pub fn straggler_sweep() -> crate::Result<Vec<StragglerRow>> {
    let cluster = ClusterSpec::pods(4);
    let mut rows = Vec::new();
    for &(bytes, stall_us) in &STRAGGLER_SWEEP_CASES {
        let (static_us, static_plan) = straggler_mode_latency(
            &cluster,
            "tcp-tcp",
            16,
            PlannerMode::StaticCost,
            STRAGGLER_SWEEP_RAIL,
            stall_us,
            bytes,
            25,
            5,
        )?;
        let (auto_us, auto_plan) = straggler_mode_latency(
            &cluster,
            "tcp-tcp",
            16,
            PlannerMode::Auto,
            STRAGGLER_SWEEP_RAIL,
            stall_us,
            bytes,
            25,
            5,
        )?;
        rows.push(StragglerRow { bytes, stall_us, static_us, static_plan, auto_us, auto_plan });
    }
    Ok(rows)
}

/// The straggler-corrections JSON document for a sweep's rows (bench
/// result format).
pub fn straggler_sweep_json(rows: &[StragglerRow]) -> Json {
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("bytes", Json::from(r.bytes as f64)),
                ("size", Json::from(crate::util::bytes::fmt_bytes(r.bytes))),
                ("stall_us", Json::from(r.stall_us)),
                ("static_cost_us", Json::from(r.static_us)),
                ("static_plan", Json::from(r.static_plan.clone())),
                ("auto_us", Json::from(r.auto_us)),
                ("auto_plan", Json::from(r.auto_plan.clone())),
                ("speedup", Json::from(r.static_us / r.auto_us)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::from("straggler_corrections")),
        ("cluster", Json::from("pods")),
        ("combo", Json::from("tcp-tcp")),
        ("nodes", Json::from(16.0)),
        ("straggler_rail", Json::from(STRAGGLER_SWEEP_RAIL as f64)),
        ("results", Json::Arr(results)),
    ])
}

/// Aggregated wall-clock statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
}

impl BenchStats {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            format!("{}", self.iters),
            format!("{:.1}", self.mean_us),
            format!("{:.1}", self.p50_us),
            format!("{:.1}", self.p95_us),
            format!("{:.1}", self.min_us),
        ]
    }

    pub fn header() -> Vec<&'static str> {
        vec!["bench", "iters", "mean(us)", "p50(us)", "p95(us)", "min(us)"]
    }

    /// Throughput in MB/s given per-iteration payload bytes.
    pub fn mbps(&self, bytes_per_iter: u64) -> f64 {
        bytes_per_iter as f64 / self.mean_us
    }
}

/// Measure `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench_wall(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    BenchStats {
        name: name.to_string(),
        iters,
        mean_us: mean(&samples),
        p50_us: percentile(&samples, 50.0),
        p95_us: percentile(&samples, 95.0),
        min_us: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let s = bench_wall("spin", 2, 10, || {
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(s.iters, 10);
        assert!(s.mean_us >= 0.0);
        assert!(s.p95_us >= s.p50_us);
        assert!(s.min_us <= s.mean_us + 1e-9);
    }
}
