//! Wall-clock bench harness (criterion is unavailable offline): warmup,
//! fixed-iteration measurement, mean/percentile reporting — plus the
//! shared modeled-latency measurement loop used by the benches, the
//! ablations and the planner tests.

use std::time::Instant;

use crate::config::{Config, PlannerMode, Policy};
use crate::coordinator::buffer::UnboundBuffer;
use crate::coordinator::multirail::MultiRail;
use crate::net::topology::{parse_combo, ClusterSpec};
use crate::util::stats::{mean, percentile};

/// Mean modeled completion latency (us) of `reps` allreduces of `bytes`
/// after `warm` warmup ops, on 1024-element scaled buffers.
pub fn mean_allreduce_us(
    mr: &mut MultiRail,
    bytes: u64,
    warm: usize,
    reps: usize,
) -> crate::Result<f64> {
    const ELEMS: usize = 1024;
    let elem_bytes = bytes as f64 / ELEMS as f64;
    let mut total = 0.0;
    for i in 0..warm + reps {
        let mut buf =
            UnboundBuffer::from_fn(mr.fab.nodes, ELEMS, |n, j| ((n + j) % 7) as f32);
        let t = mr.allreduce_scaled(&mut buf, elem_bytes)?.total_us;
        if i >= warm {
            total += t;
        }
    }
    Ok(total / reps.max(1) as f64)
}

/// Mean Nezha-policy latency of `bytes`-sized allreduces under a planner
/// mode on an explicit cluster, plus the executed plan's label (`"-"`
/// under fixed dispatch, where no planner schedule runs). Shared by the
/// planner-vs-fixed bench sweep and the planner ablation.
#[allow(clippy::too_many_arguments)]
pub fn planner_mode_latency(
    cluster: &ClusterSpec,
    combo: &str,
    nodes: usize,
    mode: PlannerMode,
    bytes: u64,
    warm: usize,
    reps: usize,
) -> crate::Result<(f64, String)> {
    let mut cfg = Config {
        cluster: cluster.clone(),
        nodes,
        combo: parse_combo(combo)?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    cfg.planner = mode;
    let mut mr = MultiRail::new(&cfg)?;
    let lat = mean_allreduce_us(&mut mr, bytes, warm, reps)?;
    let plan = mr
        .last_plan
        .as_ref()
        .map(|p| p.label())
        .unwrap_or_else(|| "-".into());
    Ok((lat, plan))
}

/// Aggregated wall-clock statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
}

impl BenchStats {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            format!("{}", self.iters),
            format!("{:.1}", self.mean_us),
            format!("{:.1}", self.p50_us),
            format!("{:.1}", self.p95_us),
            format!("{:.1}", self.min_us),
        ]
    }

    pub fn header() -> Vec<&'static str> {
        vec!["bench", "iters", "mean(us)", "p50(us)", "p95(us)", "min(us)"]
    }

    /// Throughput in MB/s given per-iteration payload bytes.
    pub fn mbps(&self, bytes_per_iter: u64) -> f64 {
        bytes_per_iter as f64 / self.mean_us
    }
}

/// Measure `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench_wall(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    BenchStats {
        name: name.to_string(),
        iters,
        mean_us: mean(&samples),
        p50_us: percentile(&samples, 50.0),
        p95_us: percentile(&samples, 95.0),
        min_us: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let s = bench_wall("spin", 2, 10, || {
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(s.iters, 10);
        assert!(s.mean_us >= 0.0);
        assert!(s.p95_us >= s.p50_us);
        assert!(s.min_us <= s.mean_us + 1e-9);
    }
}
