//! Tracked hot-path benchmark — the `BENCH_hotpath.json` trajectory.
//!
//! Measures wall-clock ops/sec of the modeled allreduce sweep twice in the
//! same process on the same machine:
//!
//! * **before** — the seed's per-repetition discipline: a fresh
//!   `UnboundBuffer::from_fn` (nodes × elems vector allocations plus a
//!   per-element closure fill) constructed for every op;
//! * **after** — the pooled data plane: one staging buffer recycled
//!   through [`BufferPool`] (template `copy_from_slice` re-fill, zero
//!   steady-state allocation), exercising the same coordinator.
//!
//! Both arms run identically-configured deterministic coordinators, so the
//! recorded `speedup` isolates the hot-path allocation/fill overhead this
//! perf pass removed. Kernel bandwidth (GB/s of `add_into` and the fused
//! `reduce_copy`) rides along in the same document.
//!
//! Record, don't gate: CI uploads the JSON as a workflow artifact and the
//! tier-1 smoke test checks only that the benchmark runs and the document
//! is well-formed — never a wall-clock threshold.

use std::time::Instant;

use crate::bench::harness::bench_wall;
use crate::config::{Config, Policy};
use crate::coordinator::buffer::{BufferPool, UnboundBuffer};
use crate::coordinator::collective::{Reducer, RustReducer};
use crate::coordinator::multirail::MultiRail;
use crate::net::topology::parse_combo;
use crate::util::bytes::fmt_bytes;
use crate::util::json::Json;
use crate::Result;

/// Modeled payload sizes of the sweep — the 1 MiB – 64 MiB span the
/// trajectory's speedup ratio is recorded over.
pub const HOTPATH_SIZES: [u64; 4] = [1 << 20, 4 << 20, 16 << 20, 64 << 20];

/// Real elements per op payload (the canonical scaled-harness size used
/// by `mean_allreduce_us`, the trainers and the ablations).
pub const ELEMS: usize = 1024;

const NODES: usize = 8;
const COMBO: &str = "tcp-tcp";

/// The committed target for the after/before throughput ratio on the
/// sweep sizes (recorded in the document, asserted by the PR's acceptance
/// check — not by CI).
pub const TARGET_SPEEDUP: f64 = 1.5;

fn fill(n: usize, j: usize) -> f32 {
    ((n + j) % 7) as f32
}

fn mk_mr() -> Result<MultiRail> {
    let cfg = Config {
        nodes: NODES,
        combo: parse_combo(COMBO)?,
        policy: Policy::Nezha,
        deterministic: true,
        ..Config::default()
    };
    MultiRail::new(&cfg)
}

/// One sweep row: before/after ops-per-second at one modeled size.
#[derive(Debug, Clone)]
pub struct HotpathRow {
    pub bytes: u64,
    pub before_ops_per_sec: f64,
    pub after_ops_per_sec: f64,
}

impl HotpathRow {
    pub fn speedup(&self) -> f64 {
        self.after_ops_per_sec / self.before_ops_per_sec
    }
}

/// ops/sec of `reps` modeled allreduces with a FRESH from_fn buffer per
/// repetition (the seed discipline).
fn ops_per_sec_fresh(bytes: u64, warm: usize, reps: usize) -> Result<f64> {
    let mut mr = mk_mr()?;
    let elem_bytes = bytes as f64 / ELEMS as f64;
    for _ in 0..warm {
        let mut buf = UnboundBuffer::from_fn(NODES, ELEMS, fill);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
    }
    let t = Instant::now();
    for _ in 0..reps {
        let mut buf = UnboundBuffer::from_fn(NODES, ELEMS, fill);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
    }
    Ok(reps as f64 / t.elapsed().as_secs_f64())
}

/// ops/sec of `reps` modeled allreduces with a pooled, in-place re-filled
/// buffer (the allocation-free data plane).
fn ops_per_sec_pooled(bytes: u64, warm: usize, reps: usize) -> Result<f64> {
    let mut mr = mk_mr()?;
    let mut pool = BufferPool::new();
    let elem_bytes = bytes as f64 / ELEMS as f64;
    for _ in 0..warm {
        let mut buf = pool.acquire(NODES, ELEMS, fill);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
        pool.release(buf);
    }
    let t = Instant::now();
    for _ in 0..reps {
        let mut buf = pool.acquire(NODES, ELEMS, fill);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
        pool.release(buf);
    }
    Ok(reps as f64 / t.elapsed().as_secs_f64())
}

/// Run the before/after ops-per-second sweep over [`HOTPATH_SIZES`].
pub fn sweep(quick: bool) -> Result<Vec<HotpathRow>> {
    let (warm, reps) = if quick { (30, 300) } else { (100, 3000) };
    let mut rows = Vec::with_capacity(HOTPATH_SIZES.len());
    for &bytes in &HOTPATH_SIZES {
        let before_ops_per_sec = ops_per_sec_fresh(bytes, warm, reps)?;
        let after_ops_per_sec = ops_per_sec_pooled(bytes, warm, reps)?;
        rows.push(HotpathRow { bytes, before_ops_per_sec, after_ops_per_sec });
    }
    Ok(rows)
}

/// Reduction-kernel bandwidth in GB/s: (add_into, fused reduce_copy),
/// payload convention = one operand's bytes per iteration.
pub fn kernel_gbps() -> (f64, f64) {
    const N: usize = 1 << 20;
    let mut red = RustReducer;
    let mut dst = vec![1.0f32; N];
    let src = vec![2.0f32; N];
    let s_add = bench_wall("add_into_1M", 5, 50, || red.add_into(&mut dst, &src));
    let mut fwd = vec![0.0f32; N];
    let mut dst2 = vec![1.0f32; N];
    let s_rc = bench_wall("reduce_copy_1M", 5, 50, || {
        red.reduce_copy(&mut dst2, &src, &mut fwd)
    });
    let gbps = |mean_us: f64| (N * 4) as f64 / mean_us / 1e3;
    (gbps(s_add.mean_us), gbps(s_rc.mean_us))
}

/// The full BENCH_hotpath.json document.
pub fn hotpath_json(quick: bool) -> Result<Json> {
    let rows = sweep(quick)?;
    let min_speedup = rows
        .iter()
        .map(HotpathRow::speedup)
        .fold(f64::INFINITY, f64::min);
    let (add_gbps, rc_gbps) = kernel_gbps();
    let sweep_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("bytes", Json::from(r.bytes as f64)),
                ("size", Json::from(fmt_bytes(r.bytes))),
                ("before_ops_per_sec", Json::from(r.before_ops_per_sec)),
                ("after_ops_per_sec", Json::from(r.after_ops_per_sec)),
                ("speedup", Json::from(r.speedup())),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("bench", Json::from("hotpath")),
        ("mode", Json::from(if quick { "quick" } else { "full" })),
        // provenance: the tier-1 smoke test regenerates this document
        // unoptimized, the CI bench step in release — absolute ops/sec
        // differ by profile (the before/after RATIO is meaningful in
        // both), so the document records which build produced it
        (
            "profile",
            Json::from(if cfg!(debug_assertions) { "debug" } else { "release" }),
        ),
        ("nodes", Json::from(NODES)),
        ("combo", Json::from(COMBO)),
        ("elems", Json::from(ELEMS)),
        ("sweep", Json::Arr(sweep_json)),
        ("min_speedup", Json::from(min_speedup)),
        ("target_speedup", Json::from(TARGET_SPEEDUP)),
        (
            "kernels",
            Json::obj(vec![
                ("add_into_gbps", Json::from(add_gbps)),
                ("reduce_copy_gbps", Json::from(rc_gbps)),
            ]),
        ),
    ]))
}

/// Repo-root path of the tracked benchmark artifact.
pub fn report_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_hotpath.json")
}

/// Measure and write `BENCH_hotpath.json` at the repo root; returns the
/// document. Called by the `bench_hotpath` bench binary, the CI artifact
/// step and the tier-1 smoke test (quick mode), so the checked-in
/// trajectory is refreshed by every verified run.
pub fn write_report(quick: bool) -> Result<Json> {
    let doc = hotpath_json(quick)?;
    std::fs::write(report_path(), doc.to_string())?;
    Ok(doc)
}
