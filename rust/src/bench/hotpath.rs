//! Tracked hot-path benchmark — the `BENCH_hotpath.json` trajectory.
//!
//! Measures wall-clock ops/sec of the modeled allreduce sweep twice in the
//! same process on the same machine:
//!
//! * **before** — the seed's per-repetition discipline: a fresh
//!   `UnboundBuffer::from_fn` (nodes × elems vector allocations plus a
//!   per-element closure fill) constructed for every op;
//! * **after** — the pooled data plane: one staging buffer recycled
//!   through [`BufferPool`] (template `copy_from_slice` re-fill, zero
//!   steady-state allocation), exercising the same coordinator.
//!
//! Both arms run identically-configured deterministic coordinators, so the
//! recorded `speedup` isolates the hot-path allocation/fill overhead the
//! PR-3 perf pass removed. Three more trajectories ride along:
//!
//! * **exec sweep** — serial vs parallel cross-rail execution on PHYSICAL
//!   payloads (elem_bytes = 4, real reduction work), the PR-4 engine's
//!   headline number: the parallel executor should beat serial ops/sec on
//!   multi-rail payloads ≥ 8 MiB, where per-rail numerics dominate the
//!   scoped-thread dispatch cost;
//! * **kernel width sweep** — GB/s of `add_into`/`reduce_copy` at 8/16/32
//!   lanes; the shipped [`KERNEL_LANES`] is the swept winner;
//! * **policy sim** — wall-clock of the canonical `bench_allreduce`-style
//!   modeled sweep, so policy-simulation regressions surface in the same
//!   tracked document as kernel ones;
//! * **integrity** — the FNV-1a window-checksum kernel's GB/s and the
//!   clean-path cost of the collective cores' send/verify passes
//!   (checksums on vs off), so the data-plane integrity overhead is
//!   tracked per commit;
//! * **scheduler** — modeled barrier vs priority-op-queue DDP iteration
//!   time on the paper's models with per-iteration gradient bit-identity
//!   (deterministic modeled times, so this section's speedup IS
//!   machine-comparable).
//!
//! Record, don't gate: CI uploads the JSON as a workflow artifact and the
//! tier-1 smoke test checks only that the benchmark runs and the document
//! is well-formed — never a wall-clock threshold.

use std::time::Instant;

use crate::bench::harness::{bench_wall, mean_allreduce_us};
use crate::config::{Config, Policy};
use crate::coordinator::arbiter::{ArbiterMode, FabricArbiter, JobSpec, PriorityClass};
use crate::coordinator::buffer::{BufferPool, UnboundBuffer};
use crate::coordinator::collective::reducer::{
    add_into_lanes, reduce_copy_lanes, KERNEL_LANES,
};
use crate::coordinator::multirail::MultiRail;
use crate::net::cpu_pool::{ExecMode, SchedMode};
use crate::net::topology::parse_combo;
use crate::trainer::{CommProfile, DdpSim};
use crate::util::bytes::fmt_bytes;
use crate::util::json::Json;
use crate::Result;

/// Modeled payload sizes of the sweep — the 1 MiB – 64 MiB span the
/// trajectory's speedup ratio is recorded over.
pub const HOTPATH_SIZES: [u64; 4] = [1 << 20, 4 << 20, 16 << 20, 64 << 20];

/// Real elements per op payload (the canonical scaled-harness size used
/// by `mean_allreduce_us`, the trainers and the ablations).
pub const ELEMS: usize = 1024;

const NODES: usize = 8;
const COMBO: &str = "tcp-tcp";

/// Physical payload sizes of the serial-vs-parallel executor sweep
/// (elem_bytes = 4: the reduction actually chews this much memory, so the
/// sweep measures real cross-rail compute overlap, not just dispatch).
pub const EXEC_SIZES: [u64; 3] = [8 << 20, 16 << 20, 32 << 20];

/// The exec-sweep sizes a given mode runs: quick mode (the tier-1 DEBUG
/// smoke test and the CI quick bench) keeps two ≥ 8 MiB points — enough
/// to record the parallel engine's win above its dispatch-cost crossover
/// without minutes of unoptimized physical reduction work per `cargo
/// test`; the full release bench sweeps all of [`EXEC_SIZES`].
pub fn exec_sizes(quick: bool) -> &'static [u64] {
    if quick {
        &EXEC_SIZES[..2]
    } else {
        &EXEC_SIZES
    }
}

/// Nodes for the executor sweep (kept small so the physical buffers fit
/// comfortably: nodes × 32 MiB × 2 resident copies).
pub const EXEC_NODES: usize = 4;

/// The committed target for the after/before throughput ratio on the
/// sweep sizes (recorded in the document, asserted by the PR's acceptance
/// check — not by CI).
pub const TARGET_SPEEDUP: f64 = 1.5;

fn fill(n: usize, j: usize) -> f32 {
    ((n + j) % 7) as f32
}

fn mk_mr() -> Result<MultiRail> {
    let cfg = Config {
        nodes: NODES,
        combo: parse_combo(COMBO)?,
        policy: Policy::Nezha,
        deterministic: true,
        exec: ExecMode::Serial,
        ..Config::default()
    };
    MultiRail::new(&cfg)
}

/// One sweep row: before/after ops-per-second at one modeled size.
#[derive(Debug, Clone)]
pub struct HotpathRow {
    pub bytes: u64,
    pub before_ops_per_sec: f64,
    pub after_ops_per_sec: f64,
}

impl HotpathRow {
    pub fn speedup(&self) -> f64 {
        self.after_ops_per_sec / self.before_ops_per_sec
    }
}

/// ops/sec of `reps` modeled allreduces with a FRESH from_fn buffer per
/// repetition (the seed discipline).
fn ops_per_sec_fresh(bytes: u64, warm: usize, reps: usize) -> Result<f64> {
    let mut mr = mk_mr()?;
    let elem_bytes = bytes as f64 / ELEMS as f64;
    for _ in 0..warm {
        let mut buf = UnboundBuffer::from_fn(NODES, ELEMS, fill);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
    }
    let t = Instant::now();
    for _ in 0..reps {
        let mut buf = UnboundBuffer::from_fn(NODES, ELEMS, fill);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
    }
    Ok(reps as f64 / t.elapsed().as_secs_f64())
}

/// ops/sec of `reps` modeled allreduces with a pooled, in-place re-filled
/// buffer (the allocation-free data plane, reports recycled).
fn ops_per_sec_pooled(bytes: u64, warm: usize, reps: usize) -> Result<f64> {
    let mut mr = mk_mr()?;
    let mut pool = BufferPool::new();
    let elem_bytes = bytes as f64 / ELEMS as f64;
    for _ in 0..warm {
        let mut buf = pool.acquire(NODES, ELEMS, fill);
        let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
        pool.release(buf);
        mr.recycle(rep);
    }
    let t = Instant::now();
    for _ in 0..reps {
        let mut buf = pool.acquire(NODES, ELEMS, fill);
        let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
        pool.release(buf);
        mr.recycle(rep);
    }
    Ok(reps as f64 / t.elapsed().as_secs_f64())
}

/// Run the before/after ops-per-second sweep over [`HOTPATH_SIZES`].
pub fn sweep(quick: bool) -> Result<Vec<HotpathRow>> {
    let (warm, reps) = if quick { (30, 300) } else { (100, 3000) };
    let mut rows = Vec::with_capacity(HOTPATH_SIZES.len());
    for &bytes in &HOTPATH_SIZES {
        let before_ops_per_sec = ops_per_sec_fresh(bytes, warm, reps)?;
        let after_ops_per_sec = ops_per_sec_pooled(bytes, warm, reps)?;
        rows.push(HotpathRow { bytes, before_ops_per_sec, after_ops_per_sec });
    }
    Ok(rows)
}

/// One executor-sweep row: serial/parallel ops-per-second on one PHYSICAL
/// payload size.
#[derive(Debug, Clone)]
pub struct ExecRow {
    pub bytes: u64,
    pub serial_ops_per_sec: f64,
    pub parallel_ops_per_sec: f64,
}

impl ExecRow {
    pub fn speedup(&self) -> f64 {
        self.parallel_ops_per_sec / self.serial_ops_per_sec
    }
}

/// ops/sec of physical (`elem_bytes = 4`) allreduces under `mode`, with
/// pooled buffers and recycled reports.
fn ops_per_sec_exec(mode: ExecMode, bytes: u64, warm: usize, reps: usize) -> Result<f64> {
    let cfg = Config {
        nodes: EXEC_NODES,
        combo: parse_combo(COMBO)?,
        policy: Policy::Nezha,
        deterministic: true,
        exec: mode,
        ..Config::default()
    };
    let mut mr = MultiRail::new(&cfg)?;
    let elems = (bytes / 4) as usize;
    let mut pool = BufferPool::new();
    for _ in 0..warm {
        let mut buf = pool.acquire(EXEC_NODES, elems, fill);
        let rep = mr.allreduce(&mut buf)?;
        pool.release(buf);
        mr.recycle(rep);
    }
    let t = Instant::now();
    for _ in 0..reps {
        let mut buf = pool.acquire(EXEC_NODES, elems, fill);
        let rep = mr.allreduce(&mut buf)?;
        pool.release(buf);
        mr.recycle(rep);
    }
    Ok(reps as f64 / t.elapsed().as_secs_f64())
}

/// The serial-vs-parallel executor sweep over [`EXEC_SIZES`] — real
/// reduction work on disjoint per-rail windows, so the parallel engine's
/// cross-rail compute overlap (and its scoped-thread dispatch cost) shows
/// up in wall-clock ops/sec.
pub fn exec_sweep(quick: bool) -> Result<Vec<ExecRow>> {
    // quick mode (the tier-1 DEBUG smoke test + CI quick bench) keeps the
    // physical sweep to a handful of reps per size/mode — unlike the rest
    // of the document these ops do real 8–32 MiB reduction work, so rep
    // counts, not sizes, are where quick mode saves its time (the ≥ 8 MiB
    // span itself is the point of the trajectory)
    let (warm, reps) = if quick { (1, 3) } else { (3, 20) };
    let sizes = exec_sizes(quick);
    let mut rows = Vec::with_capacity(sizes.len());
    for &bytes in sizes {
        let serial_ops_per_sec = ops_per_sec_exec(ExecMode::Serial, bytes, warm, reps)?;
        let parallel_ops_per_sec = ops_per_sec_exec(ExecMode::Parallel, bytes, warm, reps)?;
        rows.push(ExecRow { bytes, serial_ops_per_sec, parallel_ops_per_sec });
    }
    Ok(rows)
}

/// Reduction-kernel bandwidth in GB/s at one unroll width:
/// (add_into, fused reduce_copy), payload convention = one operand's
/// bytes per iteration.
fn kernel_gbps_at<const W: usize>() -> (f64, f64) {
    const N: usize = 1 << 20;
    let mut dst = vec![1.0f32; N];
    let src = vec![2.0f32; N];
    let s_add = bench_wall("add_into_1M", 5, 50, || add_into_lanes::<W>(&mut dst, &src));
    let mut fwd = vec![0.0f32; N];
    let mut dst2 = vec![1.0f32; N];
    let s_rc = bench_wall("reduce_copy_1M", 5, 50, || {
        reduce_copy_lanes::<W>(&mut dst2, &src, &mut fwd)
    });
    let gbps = |mean_us: f64| (N * 4) as f64 / mean_us / 1e3;
    (gbps(s_add.mean_us), gbps(s_rc.mean_us))
}

/// Shipped-width kernel bandwidth (GB/s of `add_into` and the fused
/// `reduce_copy` at [`KERNEL_LANES`]).
pub fn kernel_gbps() -> (f64, f64) {
    kernel_gbps_at::<KERNEL_LANES>()
}

/// The 8/16/32-lane width sweep behind [`KERNEL_LANES`]:
/// `(lanes, add_gbps, reduce_copy_gbps)` per width.
pub fn kernel_width_sweep() -> Vec<(usize, f64, f64)> {
    let (a8, r8) = kernel_gbps_at::<8>();
    let (a16, r16) = kernel_gbps_at::<16>();
    let (a32, r32) = kernel_gbps_at::<32>();
    vec![(8, a8, r8), (16, a16, r16), (32, a32, r32)]
}

/// Wall-clock of the canonical policy-simulation sweep (the
/// `bench_allreduce` shape: Nezha, dual TCP, modeled sizes on scaled
/// 1024-element buffers) — `(wall_seconds, modeled ops, ops/sec)`.
/// Tracked alongside the kernel numbers so a policy-sim slowdown (planner,
/// balancer, fabric sampling) regresses visibly in the same trajectory.
pub fn policy_sim_wall(quick: bool) -> Result<(f64, u64, f64)> {
    let (warm, reps) = if quick { (5, 40) } else { (20, 200) };
    let mut mr = mk_mr()?;
    let t = Instant::now();
    for &bytes in &HOTPATH_SIZES {
        mean_allreduce_us(&mut mr, bytes, warm, reps)?;
    }
    let wall = t.elapsed().as_secs_f64();
    let ops = mr.ops_done();
    Ok((wall, ops, ops as f64 / wall))
}

/// Integrity cost probe: `(checksum_gbps, on_ops_per_sec,
/// off_ops_per_sec)` — the FNV-1a window-checksum kernel's bandwidth over
/// a 1M-word payload, and the clean-path cost of the collective cores'
/// send/verify passes measured as pooled modeled-allreduce ops/sec with
/// the wire checksums on vs off (the modeled times are identical by
/// design, so the ratio isolates the real checksum compute). Record,
/// don't gate.
pub fn integrity_overhead(quick: bool) -> Result<(f64, f64, f64)> {
    const N: usize = 1 << 20;
    let data = vec![1.5f32; N];
    let s = bench_wall("checksum_1M", 5, 50, || {
        std::hint::black_box(crate::coordinator::collective::checksum(
            std::hint::black_box(&data),
        ));
    });
    let checksum_gbps = (N * 4) as f64 / s.mean_us / 1e3;
    let ops = |integrity: bool| -> Result<f64> {
        let (warm, reps) = if quick { (10, 100) } else { (50, 1000) };
        let mut cfg = Config {
            nodes: NODES,
            combo: parse_combo(COMBO)?,
            policy: Policy::Nezha,
            deterministic: true,
            exec: ExecMode::Serial,
            ..Config::default()
        };
        cfg.integrity = integrity;
        let mut mr = MultiRail::new(&cfg)?;
        let mut pool = BufferPool::new();
        let elem_bytes = (8u64 << 20) as f64 / ELEMS as f64;
        for _ in 0..warm {
            let mut buf = pool.acquire(NODES, ELEMS, fill);
            let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
            pool.release(buf);
            mr.recycle(rep);
        }
        let t = Instant::now();
        for _ in 0..reps {
            let mut buf = pool.acquire(NODES, ELEMS, fill);
            let rep = mr.allreduce_scaled(&mut buf, elem_bytes)?;
            pool.release(buf);
            mr.recycle(rep);
        }
        Ok(reps as f64 / t.elapsed().as_secs_f64())
    };
    Ok((checksum_gbps, ops(true)?, ops(false)?))
}

/// Models of the scheduler section (model, batch/GPU) — the paper's DDP
/// evaluation pair.
pub const SCHED_MODELS: [(&str, usize); 2] = [("alexnet", 32), ("vgg11", 64)];

/// Barrier-free scheduler section (DESIGN.md §13): modeled barrier vs
/// priority-op-queue iteration time per model on the 4-node dual-TCP
/// fabric, with per-iteration gradient bit-identity. Unlike the
/// wall-clock sections these are deterministic MODELED times, so the
/// recorded speedup is comparable across machines; the smoke test may
/// gate bit-identity (a correctness invariant), never the ratio.
pub fn scheduler_section() -> Result<Json> {
    let mut rows = Vec::new();
    let mut all_bit_identical = true;
    let mut all_improved = true;
    for &(model, batch) in &SCHED_MODELS {
        let mk = |sched: SchedMode| -> Result<DdpSim> {
            let mut cfg = Config {
                nodes: 4,
                combo: parse_combo(COMBO)?,
                policy: Policy::Nezha,
                deterministic: true,
                exec: ExecMode::Serial,
                ..Config::default()
            };
            cfg.sched = sched;
            DdpSim::new(&cfg, CommProfile::by_name(model).expect("known model"), 1, batch)
        };
        let mut barrier = mk(SchedMode::Barrier)?;
        let mut priority = mk(SchedMode::Priority)?;
        barrier.warmup(2)?;
        priority.warmup(2)?;
        let (mut bt, mut pt) = (0.0f64, 0.0f64);
        let mut bit_identical = true;
        const REPS: usize = 3;
        for _ in 0..REPS {
            bt += barrier.iter_time_us()?;
            pt += priority.iter_time_us()?;
            bit_identical &= barrier.last_fingerprints() == priority.last_fingerprints();
        }
        bt /= REPS as f64;
        pt /= REPS as f64;
        let overlap = priority.sched_stats().boundary_in_flight_max;
        let drained = priority.drain_queue();
        all_bit_identical &= bit_identical;
        all_improved &= pt < bt;
        rows.push(Json::obj(vec![
            ("model", Json::from(model)),
            ("batch_per_gpu", Json::from(batch)),
            ("barrier_iter_us", Json::from(bt)),
            ("priority_iter_us", Json::from(pt)),
            ("speedup", Json::from(bt / pt)),
            ("bit_identical", Json::Bool(bit_identical)),
            ("boundary_in_flight_max", Json::from(overlap)),
            ("queue_drained", Json::Bool(drained)),
        ]));
    }
    Ok(Json::obj(vec![
        ("nodes", Json::from(4usize)),
        ("combo", Json::from(COMBO)),
        ("sweep", Json::Arr(rows)),
        ("all_bit_identical", Json::Bool(all_bit_identical)),
        ("all_improved", Json::Bool(all_improved)),
    ]))
}

/// Tenant counts of the multi-tenancy wall-clock sweep.
pub const TENANCY_JOBS: [usize; 3] = [1, 2, 4];

/// Multi-tenant aggregate wall-clock sweep: ops/sec summed over N
/// concurrent tenants sharing the dual-TCP fabric under the arbiter's
/// fair-share grants (solo vs 2-job vs 4-job), each tenant running the
/// canonical 8 MiB modeled payload through its own coordinator. Tracks
/// the arbiter's per-window orchestration overhead — record, don't gate.
pub fn tenancy_wall_sweep(quick: bool) -> Result<Vec<(usize, f64)>> {
    let (warm, reps) = if quick { (5, 40) } else { (20, 200) };
    let mut out = Vec::with_capacity(TENANCY_JOBS.len());
    for &jobs in &TENANCY_JOBS {
        let mut arb = FabricArbiter::new(ArbiterMode::FairShare, 2);
        for k in 0..jobs {
            let cfg = Config {
                nodes: NODES,
                combo: parse_combo(COMBO)?,
                policy: Policy::Nezha,
                deterministic: true,
                exec: ExecMode::Serial,
                ..Config::default()
            };
            arb.admit(
                JobSpec::new(&format!("t{k}"), PriorityClass::Standard).payload(8 << 20),
                NODES,
                MultiRail::new(&cfg)?,
            );
        }
        for _ in 0..warm {
            arb.step()?;
        }
        let t = Instant::now();
        for _ in 0..reps {
            arb.step()?;
        }
        out.push((jobs, (reps * jobs) as f64 / t.elapsed().as_secs_f64()));
    }
    Ok(out)
}

/// The full BENCH_hotpath.json document.
pub fn hotpath_json(quick: bool) -> Result<Json> {
    let rows = sweep(quick)?;
    let min_speedup = rows
        .iter()
        .map(HotpathRow::speedup)
        .fold(f64::INFINITY, f64::min);
    let exec_rows = exec_sweep(quick)?;
    let exec_min_speedup = exec_rows
        .iter()
        .map(ExecRow::speedup)
        .fold(f64::INFINITY, f64::min);
    let widths = kernel_width_sweep();
    let (add_gbps, rc_gbps) = kernel_gbps();
    let (sim_wall_s, sim_ops, sim_ops_per_sec) = policy_sim_wall(quick)?;
    let tenancy_rows = tenancy_wall_sweep(quick)?;
    let (checksum_gbps, on_ops, off_ops) = integrity_overhead(quick)?;
    let scheduler = scheduler_section()?;
    let sweep_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("bytes", Json::from(r.bytes as f64)),
                ("size", Json::from(fmt_bytes(r.bytes))),
                ("before_ops_per_sec", Json::from(r.before_ops_per_sec)),
                ("after_ops_per_sec", Json::from(r.after_ops_per_sec)),
                ("speedup", Json::from(r.speedup())),
            ])
        })
        .collect();
    let exec_json: Vec<Json> = exec_rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("bytes", Json::from(r.bytes as f64)),
                ("size", Json::from(fmt_bytes(r.bytes))),
                ("serial_ops_per_sec", Json::from(r.serial_ops_per_sec)),
                ("parallel_ops_per_sec", Json::from(r.parallel_ops_per_sec)),
                ("speedup", Json::from(r.speedup())),
            ])
        })
        .collect();
    let width_json: Vec<Json> = widths
        .iter()
        .map(|&(lanes, a, r)| {
            Json::obj(vec![
                ("lanes", Json::from(lanes)),
                ("add_into_gbps", Json::from(a)),
                ("reduce_copy_gbps", Json::from(r)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("bench", Json::from("hotpath")),
        ("mode", Json::from(if quick { "quick" } else { "full" })),
        // provenance: the tier-1 smoke test regenerates this document
        // unoptimized, the CI bench step in release — absolute ops/sec
        // differ by profile (the before/after RATIO is meaningful in
        // both), so the document records which build produced it
        (
            "profile",
            Json::from(if cfg!(debug_assertions) { "debug" } else { "release" }),
        ),
        ("nodes", Json::from(NODES)),
        ("combo", Json::from(COMBO)),
        ("elems", Json::from(ELEMS)),
        ("sweep", Json::Arr(sweep_json)),
        ("min_speedup", Json::from(min_speedup)),
        ("target_speedup", Json::from(TARGET_SPEEDUP)),
        // serial-vs-parallel cross-rail execution engine (physical
        // payloads, real reduction work; record, don't gate)
        (
            "exec",
            Json::obj(vec![
                ("nodes", Json::from(EXEC_NODES)),
                ("combo", Json::from(COMBO)),
                ("sweep", Json::Arr(exec_json)),
                ("min_speedup", Json::from(exec_min_speedup)),
            ]),
        ),
        (
            "kernels",
            Json::obj(vec![
                ("add_into_gbps", Json::from(add_gbps)),
                ("reduce_copy_gbps", Json::from(rc_gbps)),
                ("lanes", Json::from(KERNEL_LANES)),
                ("width_sweep", Json::Arr(width_json)),
            ]),
        ),
        // canonical policy-simulation sweep wall-clock (the
        // bench_allreduce shape) — regressions in planner/balancer/fabric
        // sampling surface here alongside the kernel numbers
        (
            "policy_sim",
            Json::obj(vec![
                ("wall_seconds", Json::from(sim_wall_s)),
                ("modeled_ops", Json::from(sim_ops as f64)),
                ("ops_per_sec", Json::from(sim_ops_per_sec)),
            ]),
        ),
        // data-plane integrity: the FNV-1a checksum kernel's bandwidth
        // and the clean-path cost of the collective cores' send/verify
        // passes (checksums on vs off; record, don't gate)
        (
            "integrity",
            Json::obj(vec![
                ("checksum_gbps", Json::from(checksum_gbps)),
                ("clean_on_ops_per_sec", Json::from(on_ops)),
                ("clean_off_ops_per_sec", Json::from(off_ops)),
                ("clean_overhead_pct", Json::from((off_ops / on_ops - 1.0) * 100.0)),
            ]),
        ),
        // barrier-free scheduling: modeled barrier vs priority op-queue
        // iteration time per model (deterministic — the one section whose
        // ratio IS machine-comparable), with gradient bit-identity
        ("scheduler", scheduler),
        // multi-tenant arbiter orchestration overhead: aggregate ops/sec
        // over concurrent fair-share tenants (solo vs 2-job vs 4-job)
        (
            "tenancy",
            Json::obj(vec![
                ("nodes", Json::from(NODES)),
                ("combo", Json::from(COMBO)),
                (
                    "sweep",
                    Json::Arr(
                        tenancy_rows
                            .iter()
                            .map(|&(jobs, ops)| {
                                Json::obj(vec![
                                    ("jobs", Json::from(jobs)),
                                    ("aggregate_ops_per_sec", Json::from(ops)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ]))
}

/// Repo-root path of the tracked benchmark artifact.
pub fn report_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_hotpath.json")
}

/// Measure and write `BENCH_hotpath.json` at the repo root; returns the
/// document. Called by the `bench_hotpath` bench binary, the CI artifact
/// step and the tier-1 smoke test (quick mode), so the checked-in
/// trajectory is refreshed by every verified run.
pub fn write_report(quick: bool) -> Result<Json> {
    let doc = hotpath_json(quick)?;
    std::fs::write(report_path(), doc.to_string())?;
    Ok(doc)
}
