//! Benchmark harness + paper figure/table generators.
//!
//! Every table and figure of the paper's evaluation has a generator in
//! [`figures`] (see DESIGN.md §5 for the index); [`harness`] provides the
//! wall-clock measurement utilities for the hot-path benches
//! (rust/benches/).

pub mod ablation;
pub mod chaos;
pub mod figures;
pub mod figures_app;
pub mod harness;
pub mod hotpath;

pub use harness::{
    bench_wall, mean_allreduce_us, plan_quality_json, plan_quality_sweep, planner_mode_latency,
    straggler_mode_latency, BenchStats, PLAN_QUALITY_MEDIAN_ERR_MAX,
};
