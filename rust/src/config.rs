//! Experiment / system configuration.
//!
//! Configs come from three sources, later overriding earlier: built-in
//! defaults, a `key = value` config file (`--config path`), and CLI
//! options. This is the "real config system" entry point used by the
//! `nezha` binary, the examples and the bench harness.

use std::collections::BTreeMap;

use crate::coordinator::control::{HealthConfig, HealthMode};
use crate::net::cpu_pool::{AllocPolicy, ExecMode, SchedMode};
use crate::net::fault::{
    parse_corrupt, parse_degrade, parse_faults, CorruptSchedule, DegradeSchedule, FaultSchedule,
};
use crate::net::protocol::ProtoKind;
use crate::net::topology::{parse_combo, parse_topology, ClusterSpec};
use crate::util::cli::Args;
use crate::util::error::Error;
use crate::Result;

/// Which data-distribution policy drives the multi-rail allreduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Nezha's cold/hot state machine + dynamic load balancing.
    Nezha,
    /// MRIB: static bandwidth-proportional split.
    Mrib,
    /// MPTCP (ECF): RTT-driven packet slicing across subflows.
    Mptcp,
    /// Best single rail only (Gloo-like baseline).
    SingleRail,
}

impl Policy {
    pub fn parse(s: &str) -> Result<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "nezha" => Ok(Policy::Nezha),
            "mrib" => Ok(Policy::Mrib),
            "mptcp" => Ok(Policy::Mptcp),
            "single" | "single-rail" | "gloo" => Ok(Policy::SingleRail),
            other => Err(Error::Config(format!("unknown policy `{other}`"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Nezha => "Nezha",
            Policy::Mrib => "MRIB",
            Policy::Mptcp => "MPTCP",
            Policy::SingleRail => "single-rail",
        }
    }
}

/// How the coordinator turns Load-Balancer shares into per-rail schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerMode {
    /// Topology-aware collective planner: per-rail schedule chosen by the
    /// α-β cost model (flat/chunked ring, halving-doubling, two-level),
    /// corrected by the Timer's live measurements (straggler-aware
    /// replanning).
    Auto,
    /// The planner with measurement corrections disabled: schedules come
    /// from the a-priori α-β model only — the corrections-ablation
    /// baseline.
    StaticCost,
    /// The seed's fixed dispatch: flat single-level ring on every
    /// ring-capable rail (tree on SHARP) — the planner-ablation baseline.
    Flat,
}

impl PlannerMode {
    pub fn parse(s: &str) -> Result<PlannerMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" | "on" => Ok(PlannerMode::Auto),
            "static-cost" | "static_cost" | "staticcost" => Ok(PlannerMode::StaticCost),
            "flat" | "fixed" | "off" => Ok(PlannerMode::Flat),
            other => Err(Error::Config(format!("unknown planner mode `{other}`"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PlannerMode::Auto => "auto",
            PlannerMode::StaticCost => "static-cost",
            PlannerMode::Flat => "flat",
        }
    }
}

/// Control-module tunables (paper §3.5/§4.3 defaults).
#[derive(Debug, Clone)]
pub struct ControlConfig {
    /// Protocol divergence tolerance threshold τ (paper: 5).
    pub tau: f64,
    /// Gradient-descent step size η for hot-start coefficient updates.
    pub eta: f64,
    /// Timer averaging window (paper: average of every 100 same-size ops).
    pub timer_window: usize,
    /// Heartbeat/detection timeout for rail failure (us). Paper budget:
    /// detection + migration < 200 ms.
    pub detect_timeout_us: f64,
    /// Task-migration handoff cost (us): deregister + pointer handoff.
    pub migrate_cost_us: f64,
    /// Convergence tolerance on α updates.
    pub alpha_tol: f64,
    /// Replan trigger: when a rail's EWMA'd |predicted − measured| /
    /// measured error for a size class exceeds this, the coordinator
    /// re-runs schedule selection between ops (buckets) instead of reusing
    /// the cached plan.
    pub replan_error: f64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            tau: 5.0,
            eta: 0.3,
            timer_window: 100,
            detect_timeout_us: 120_000.0,
            migrate_cost_us: 40_000.0,
            alpha_tol: 1e-3,
            replan_error: 0.25,
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cluster: ClusterSpec,
    pub nodes: usize,
    pub combo: Vec<ProtoKind>,
    pub policy: Policy,
    pub planner: PlannerMode,
    pub alloc: AllocPolicy,
    /// Cross-rail execution engine: `serial` (one rail after another, the
    /// seed behaviour) or `parallel` (all rails' schedules concurrently on
    /// scoped worker threads; numerics and modeled times stay
    /// bit-identical). Ablatable per run; the `NEZHA_EXEC` env var
    /// overrides the default so CI can run whole suites under either.
    pub exec: ExecMode,
    /// Trainer op scheduling: `barrier` (every bucket's allreduce done
    /// before the next forward, the legacy behaviour) or `priority`
    /// (barrier-free cross-iteration scheduling: buckets enqueued at
    /// backward, awaited at the consuming forward step next iteration,
    /// early-forward buckets preempting late ones at window boundaries;
    /// numerics stay bit-identical — see DESIGN.md §13).
    pub sched: SchedMode,
    pub control: ControlConfig,
    /// Crash-stop fault windows injected into the fabric (`faults=` spec:
    /// `rail0:10ms-30ms;rail1:50ms-`).
    pub faults: FaultSchedule,
    /// Gray-failure degradation windows (`degrade=` spec:
    /// `rail0:loss=0.05@10ms-30ms;rail1:brownout=0.5@0-1s`).
    pub degrade: DegradeSchedule,
    /// Silent-corruption windows (`corrupt=` spec:
    /// `flip:1:0.05@100ms-300ms;stuck:0:0.2@1s-2s`).
    pub corrupt: CorruptSchedule,
    /// Checksum-verified data plane (`integrity= on|off`, default on):
    /// when off, corruption events escape the wire checks and poison the
    /// reduction — the ablation baseline.
    pub integrity: bool,
    /// Suspicion-driven rail health tracking (`health= graceful|binary|off`).
    pub health: HealthConfig,
    pub seed: u64,
    pub deterministic: bool,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cluster: ClusterSpec::local(),
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            planner: PlannerMode::Auto,
            alloc: AllocPolicy::Adaptive,
            exec: ExecMode::from_env(ExecMode::Serial),
            sched: SchedMode::Barrier,
            control: ControlConfig::default(),
            faults: FaultSchedule::none(),
            degrade: DegradeSchedule::none(),
            corrupt: CorruptSchedule::none(),
            integrity: true,
            health: HealthConfig::default(),
            seed: 42,
            deterministic: false,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl Config {
    /// Apply a `key = value` map (from file or CLI) over this config.
    pub fn apply(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            match k.as_str() {
                "cluster" => {
                    self.cluster = match v.as_str() {
                        "local" => ClusterSpec::local(),
                        "cloud" => ClusterSpec::cloud(),
                        "supercomputer" | "super" => ClusterSpec::supercomputer(),
                        "pods" => ClusterSpec::pods(4),
                        "racked-pods" | "racked_pods" => ClusterSpec::racked_pods(4, 16),
                        other => return Err(Error::Config(format!("unknown cluster `{other}`"))),
                    }
                }
                // hierarchical grouping override, applied after `cluster`
                // (BTreeMap order): e.g. `topology = rack:4<pod:16`,
                // `topology = group:2+6+4+4`, `topology = flat`
                "topology" => self.cluster.topo = parse_topology(v)?,
                "nodes" => {
                    self.nodes = v
                        .parse()
                        .map_err(|_| Error::Config(format!("bad nodes `{v}`")))?
                }
                "combo" | "network" => self.combo = parse_combo(v)?,
                "policy" => self.policy = Policy::parse(v)?,
                "planner" => self.planner = PlannerMode::parse(v)?,
                "exec" => self.exec = ExecMode::parse(v)?,
                "sched" => self.sched = SchedMode::parse(v)?,
                "alloc" => {
                    self.alloc = match v.as_str() {
                        "static" => AllocPolicy::StaticEqual,
                        "adaptive" => AllocPolicy::Adaptive,
                        other => return Err(Error::Config(format!("unknown alloc `{other}`"))),
                    }
                }
                "tau" => self.control.tau = parse_f64(k, v)?,
                "eta" => self.control.eta = parse_f64(k, v)?,
                "timer_window" => self.control.timer_window = parse_f64(k, v)? as usize,
                "detect_timeout_us" => self.control.detect_timeout_us = parse_f64(k, v)?,
                "migrate_cost_us" => self.control.migrate_cost_us = parse_f64(k, v)?,
                "replan_error" => self.control.replan_error = parse_f64(k, v)?,
                "faults" => self.faults = parse_faults(v)?,
                "degrade" => self.degrade = parse_degrade(v)?,
                "corrupt" => self.corrupt = parse_corrupt(v)?,
                "integrity" => {
                    self.integrity = match v.as_str() {
                        "on" | "true" | "1" => true,
                        "off" | "false" | "0" => false,
                        other => {
                            return Err(Error::Config(format!(
                                "integrity must be on/off, got `{other}`"
                            )))
                        }
                    }
                }
                "health" => self.health.mode = HealthMode::parse(v)?,
                "seed" => self.seed = parse_f64(k, v)? as u64,
                "deterministic" => self.deterministic = v == "true" || v == "1",
                "artifacts_dir" => self.artifacts_dir = v.clone(),
                other => return Err(Error::Config(format!("unknown config key `{other}`"))),
            }
        }
        Ok(())
    }

    /// Parse a `key = value` config file (# comments, blank lines ok).
    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let mut kv = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("{path}:{}: expected `key = value`", lineno + 1))
            })?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        self.apply(&kv)
    }

    /// Build from CLI args (honouring `--config FILE` first).
    pub fn from_args(args: &Args) -> Result<Config> {
        let mut cfg = Config::default();
        if let Some(path) = args.get("config") {
            cfg.load_file(path)?;
        }
        let mut kv = BTreeMap::new();
        for key in [
            "cluster", "topology", "nodes", "combo", "network", "policy", "planner", "exec",
            "sched", "alloc", "tau", "eta",
            "timer_window", "detect_timeout_us", "migrate_cost_us", "replan_error",
            "faults", "degrade", "corrupt", "integrity", "health",
            "seed", "deterministic", "artifacts_dir",
        ] {
            if let Some(v) = args.get(key) {
                kv.insert(key.to_string(), v.to_string());
            }
        }
        if args.has("deterministic") {
            kv.insert("deterministic".into(), "true".into());
        }
        cfg.apply(&kv)?;
        Ok(cfg)
    }
}

fn parse_f64(k: &str, v: &str) -> Result<f64> {
    v.parse()
        .map_err(|_| Error::Config(format!("bad value for `{k}`: `{v}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.nodes, 4);
        assert_eq!(c.control.tau, 5.0);
        assert_eq!(c.policy, Policy::Nezha);
    }

    #[test]
    fn apply_overrides() {
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("nodes".into(), "8".into());
        kv.insert("combo".into(), "tcp-sharp".into());
        kv.insert("policy".into(), "mrib".into());
        kv.insert("tau".into(), "7.5".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.nodes, 8);
        assert_eq!(c.combo, vec![ProtoKind::Tcp, ProtoKind::Sharp]);
        assert_eq!(c.policy, Policy::Mrib);
        assert_eq!(c.control.tau, 7.5);
    }

    #[test]
    fn planner_mode_parses() {
        let mut c = Config::default();
        assert_eq!(c.planner, PlannerMode::Auto);
        let mut kv = BTreeMap::new();
        kv.insert("planner".into(), "flat".into());
        kv.insert("cluster".into(), "pods".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.planner, PlannerMode::Flat);
        assert!(c.cluster.intra().is_some());
        assert!(PlannerMode::parse("bogus").is_err());
        assert_eq!(PlannerMode::parse("on").unwrap(), PlannerMode::Auto);
        assert_eq!(PlannerMode::parse("static-cost").unwrap(), PlannerMode::StaticCost);
        assert_eq!(PlannerMode::StaticCost.name(), "static-cost");
    }

    #[test]
    fn topology_key_parses() {
        use crate::net::topology::GroupShape;
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("cluster".into(), "racked-pods".into());
        kv.insert("nodes".into(), "32".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.cluster.name, "racked-pods");
        assert_eq!(c.cluster.topo.depth(), 2);
        // an explicit topology= overrides the cluster's default tree
        kv.insert("topology".into(), "group:2+6+4+4".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.cluster.topo.depth(), 1);
        assert_eq!(
            c.cluster.topo.levels[0].shape,
            GroupShape::Explicit(vec![2, 6, 4, 4])
        );
        kv.insert("topology".into(), "flat".into());
        c.apply(&kv).unwrap();
        assert!(c.cluster.topo.is_flat());
        kv.insert("topology".into(), "rack:bogus".into());
        assert!(c.apply(&kv).is_err());
    }

    #[test]
    fn replan_error_configurable() {
        let mut c = Config::default();
        assert_eq!(c.control.replan_error, 0.25);
        let mut kv = BTreeMap::new();
        kv.insert("replan_error".into(), "0.1".into());
        kv.insert("planner".into(), "static_cost".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.control.replan_error, 0.1);
        assert_eq!(c.planner, PlannerMode::StaticCost);
    }

    #[test]
    fn exec_mode_key_parses() {
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("exec".into(), "parallel".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.exec, ExecMode::Parallel);
        kv.insert("exec".into(), "serial".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.exec, ExecMode::Serial);
        kv.insert("exec".into(), "sideways".into());
        assert!(c.apply(&kv).is_err());
    }

    #[test]
    fn sched_mode_key_parses() {
        let mut c = Config::default();
        assert_eq!(c.sched, SchedMode::Barrier, "barrier is the default");
        let mut kv = BTreeMap::new();
        kv.insert("sched".into(), "priority".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.sched, SchedMode::Priority);
        kv.insert("sched".into(), "barrier".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.sched, SchedMode::Barrier);
        kv.insert("sched".into(), "sideways".into());
        assert!(c.apply(&kv).is_err());
    }

    #[test]
    fn fault_and_degrade_keys_parse() {
        let mut c = Config::default();
        assert!(c.faults.is_empty() && c.degrade.is_empty());
        let mut kv = BTreeMap::new();
        kv.insert("faults".into(), "1@100ms-200ms;0@2s-3s".into());
        kv.insert(
            "degrade".into(),
            "loss:1:0.05@100ms-300ms;brownout:0:0.5@1s-2s".into(),
        );
        kv.insert("health".into(), "binary".into());
        kv.insert("corrupt".into(), "flip:1:0.05@100ms-300ms".into());
        c.apply(&kv).unwrap();
        assert!(!c.faults.is_empty());
        assert!(c.faults.is_down(1, 150_000.0));
        assert!(!c.faults.is_down(1, 250_000.0));
        assert!(c.degrade.loss_at(1, 200_000.0) > 0.0);
        assert!(c.degrade.brownout_at(0, 1_500_000.0) < 1.0);
        assert!(c.corrupt.corrupt_at(1, 200_000.0) > 0.0);
        assert_eq!(c.corrupt.corrupt_at(1, 400_000.0), 0.0);
        assert!(c.integrity, "integrity defaults on");
        kv.insert("integrity".into(), "off".into());
        c.apply(&kv).unwrap();
        assert!(!c.integrity);
        assert_eq!(c.health.mode, HealthMode::Binary);
        kv.insert("health".into(), "off".into());
        c.apply(&kv).unwrap();
        assert_eq!(c.health.mode, HealthMode::Off);
    }

    #[test]
    fn bad_fault_specs_are_config_errors() {
        let mut c = Config::default();
        for (key, val) in [
            ("faults", "1@300ms-200ms"),     // end before start
            ("faults", "x@100ms-200ms"),     // bad rail
            ("faults", "1:100ms-200ms"),     // missing @
            ("degrade", "loss:0:1.5@0-1s"),  // rate out of range
            ("degrade", "brownout:0:0@0-1s"),// factor must be > 0
            ("degrade", "flap:0:0@0-1s"),    // period must be positive
            ("degrade", "wobble:0:1@0-1s"),  // unknown kind
            ("health", "sideways"),
            ("corrupt", "flip:0:1.5@0-1s"),  // probability out of range
            ("corrupt", "smear:0:0.1@0-1s"), // unknown kind
            ("corrupt", "flip:0:0.1"),       // missing window
            ("integrity", "sideways"),
            // silently-last-wins duplicates are rejected in every family
            ("faults", "1@100ms-200ms;1@100ms-200ms"),
            ("degrade", "loss:1:0.05@0-1s;loss:1:0.05@0-1s"),
            ("corrupt", "flip:1:0.05@0-1s;flip:1:0.05@0-1s"),
        ] {
            let mut kv = BTreeMap::new();
            kv.insert(key.to_string(), val.to_string());
            assert!(c.apply(&kv).is_err(), "{key}={val} should be rejected");
        }
        // still usable after rejected updates
        assert!(c.faults.is_empty());
    }

    #[test]
    fn rejects_unknown_keys() {
        let mut c = Config::default();
        let mut kv = BTreeMap::new();
        kv.insert("bogus".into(), "1".into());
        assert!(c.apply(&kv).is_err());
    }

    #[test]
    fn file_parsing() {
        let dir = std::env::temp_dir().join("nezha_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.conf");
        std::fs::write(&p, "# comment\nnodes = 8\npolicy = mptcp # inline\n\n").unwrap();
        let mut c = Config::default();
        c.load_file(p.to_str().unwrap()).unwrap();
        assert_eq!(c.nodes, 8);
        assert_eq!(c.policy, Policy::Mptcp);
    }
}
