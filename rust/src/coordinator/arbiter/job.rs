//! Tenant jobs: what the [`crate::coordinator::arbiter::FabricArbiter`]
//! admits onto the shared rails.
//!
//! A job is a full [`MultiRail`] coordinator (its own fabric clock, RNG
//! streams, control plane and planner) plus an admission spec: priority
//! class, fair-share weight, payload profile and the rails it may ride.
//! Keeping each tenant's fabric state private is what makes per-job
//! numerics (and, at fixed grants, per-job modeled times) bit-identical
//! to a solo run — contention enters exclusively through the arbiter's
//! granted bandwidth shares, never through shared RNG or clocks.

use crate::coordinator::multirail::MultiRail;

/// BytePS-style consumption priority (SNIPPETS.md §2): what the arbiter
/// protects when rails are oversubscribed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PriorityClass {
    /// Small, deadline-sensitive collectives (parameter broadcasts, the
    /// paper's "heavy traffic" foreground) — preempts everything below.
    Latency,
    /// Ordinary training jobs.
    Standard,
    /// Bulk background transfers (checkpoint shuffles, dataset moves):
    /// first to be squeezed to the preemption residual.
    Scavenger,
}

impl PriorityClass {
    /// Strict-priority rank: lower = more urgent.
    pub fn rank(self) -> u8 {
        match self {
            PriorityClass::Latency => 0,
            PriorityClass::Standard => 1,
            PriorityClass::Scavenger => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PriorityClass::Latency => "latency",
            PriorityClass::Standard => "standard",
            PriorityClass::Scavenger => "scavenger",
        }
    }
}

/// Admission spec for one tenant job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub class: PriorityClass,
    /// Fair-share weight (relative to the other tenants on each rail).
    pub weight: f64,
    /// Modeled payload bytes per collective op — the job's traffic
    /// profile, used by [`super::FabricArbiter::step`] and the tenancy
    /// ablation to synthesize each tenant's op stream.
    pub payload_bytes: u64,
    /// Rails this job may ride (bit `r` = rail `r`); all rails when the
    /// mask covers them.
    pub rail_mask: u64,
    /// Price granted shares through the job's own planner
    /// ([`crate::coordinator::planner::cost::contended_us`]) so plans
    /// shift under contention. Contention-blind tenants (the ablation
    /// baseline) keep static-cost plans and only feel the squeeze
    /// through their corrected-cost EWMA, several ops late.
    pub contended_pricing: bool,
}

impl JobSpec {
    pub fn new(name: &str, class: PriorityClass) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            class,
            weight: 1.0,
            payload_bytes: 4 << 20,
            rail_mask: u64::MAX,
            contended_pricing: true,
        }
    }

    pub fn weight(mut self, w: f64) -> JobSpec {
        self.weight = w.max(1e-6);
        self
    }

    pub fn payload(mut self, bytes: u64) -> JobSpec {
        self.payload_bytes = bytes.max(1);
        self
    }

    pub fn rails(mut self, mask: u64) -> JobSpec {
        self.rail_mask = mask;
        self
    }

    /// Contention-blind static-cost planning (the ablation baseline).
    pub fn contention_blind(mut self) -> JobSpec {
        self.contended_pricing = false;
        self
    }

    /// True when this spec admits `rail`. Rails beyond the 64-bit mask
    /// cannot be expressed and are never admitted (they used to slip past
    /// as "always allowed", bypassing the mask on large fabrics).
    pub fn admits(&self, rail: usize) -> bool {
        rail < 64 && self.rail_mask & (1u64 << rail) != 0
    }
}

/// Stable tenant identity, assigned at admission in arrival order. All
/// ledger iteration is keyed by ascending `JobId` — the determinism
/// anchor for grant recomputation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

/// One admitted tenant: spec + its private coordinator + op history.
pub struct TenantJob {
    pub id: JobId,
    pub spec: JobSpec,
    /// Participating node count (the `Config::nodes` the coordinator was
    /// built with) — needed to synthesize this tenant's op stream.
    pub nodes: usize,
    pub mr: MultiRail,
    /// Completed collective ops.
    pub ops: u64,
    /// Per-op end-to-end modeled latencies (us), op order.
    pub latencies_us: Vec<f64>,
}

impl TenantJob {
    /// p99 op latency (max of the top percentile; None before any op).
    pub fn p99_us(&self) -> Option<f64> {
        percentile(&self.latencies_us, 0.99)
    }

    pub fn mean_us(&self) -> Option<f64> {
        if self.latencies_us.is_empty() {
            return None;
        }
        Some(self.latencies_us.iter().sum::<f64>() / self.latencies_us.len() as f64)
    }
}

/// Nearest-rank percentile over an unsorted sample set.
pub fn percentile(samples: &[f64], q: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q * v.len() as f64).ceil() as usize).clamp(1, v.len());
    Some(v[rank - 1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_order_the_classes() {
        assert!(PriorityClass::Latency.rank() < PriorityClass::Standard.rank());
        assert!(PriorityClass::Standard.rank() < PriorityClass::Scavenger.rank());
    }

    #[test]
    fn spec_builder_and_admission_mask() {
        let s = JobSpec::new("bg", PriorityClass::Scavenger)
            .weight(2.0)
            .payload(1 << 20)
            .rails(0b10);
        assert_eq!(s.weight, 2.0);
        assert_eq!(s.payload_bytes, 1 << 20);
        assert!(!s.admits(0));
        assert!(s.admits(1));
        // rails the u64 mask cannot express are never admitted
        // (regression: used to be treated as always-allowed)
        assert!(!s.admits(64));
        assert!(s.contended_pricing);
        assert!(!s.contention_blind().contended_pricing);
        // defaults admit everything in mask range, never beyond it
        assert!(JobSpec::new("fg", PriorityClass::Latency).admits(7));
        assert!(!JobSpec::new("fg", PriorityClass::Latency).admits(64));
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.99), Some(99.0));
        assert_eq!(percentile(&v, 0.5), Some(50.0));
        assert_eq!(percentile(&[7.0], 0.99), Some(7.0));
        assert_eq!(percentile(&[], 0.99), None);
    }
}
