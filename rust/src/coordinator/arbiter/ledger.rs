//! Grant ledger: per-rail bandwidth-share bookkeeping for the arbiter.
//!
//! The ledger is pure arithmetic over `(JobId, JobSpec)` snapshots — no
//! fabric access, no clocks — so grant recomputation is trivially
//! deterministic: eligible jobs are visited in ascending [`JobId`] and
//! shares are closed-form weight ratios. Two invariants the proptests in
//! `rust/tests/integration_arbiter.rs` hammer on:
//!
//! 1. **Conservation:** grants on a rail sum to ≤ 1.0 (+ε). A rail with
//!    any eligible tenant is fully subscribed (sum == 1.0); an empty
//!    rail grants nothing.
//! 2. **Determinism:** recomputing from the same job set reproduces the
//!    same grants bit-for-bit, independent of arrival history.

use std::collections::HashMap;

use super::job::{JobId, JobSpec};

/// How contended rails are divided between tenants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArbiterMode {
    /// Weighted max-min: every eligible job gets `w_j / Σw` of each rail
    /// regardless of class. Simple and work-conserving, but a scavenger
    /// bulk tenant dilutes latency-class collectives.
    FairShare,
    /// The most urgent class present on a rail splits
    /// `1 − PREEMPTED_RESIDUAL` by weight; all lower classes share the
    /// residual. Window-boundary preemption: grants change only between
    /// collectives (ops are atomic in modeled time), so preemption never
    /// tears an op mid-flight.
    StrictPriority,
}

impl ArbiterMode {
    pub fn name(self) -> &'static str {
        match self {
            ArbiterMode::FairShare => "fair-share",
            ArbiterMode::StrictPriority => "strict-priority",
        }
    }
}

/// Bandwidth fraction left to preempted (lower-class) tenants under
/// [`ArbiterMode::StrictPriority`]. Non-zero so scavengers starve slowly
/// instead of deadlocking, and chosen so that even 3 scavengers splitting
/// it (0.05/3 ≈ 0.017) stay above the fabric's
/// [`crate::net::simnet::MIN_RAIL_SHARE`] floor of 0.01.
pub const PREEMPTED_RESIDUAL: f64 = 0.05;

/// Per-rail grant table, rebuilt on every churn event.
#[derive(Debug, Clone)]
pub struct GrantLedger {
    /// `rails[r]` = (job, share) pairs in ascending JobId order.
    rails: Vec<Vec<(JobId, f64)>>,
    /// Jobs squeezed into the preemption residual on at least one rail
    /// during the latest `recompute` (strict-priority only).
    preempted: Vec<JobId>,
}

impl GrantLedger {
    pub fn new(n_rails: usize) -> GrantLedger {
        GrantLedger { rails: vec![Vec::new(); n_rails], preempted: Vec::new() }
    }

    pub fn n_rails(&self) -> usize {
        self.rails.len()
    }

    /// Rebuild all grants from the current tenant set. `jobs` must be in
    /// ascending [`JobId`] order (the arbiter's invariant); the ledger
    /// preserves that order per rail.
    pub fn recompute(&mut self, mode: ArbiterMode, jobs: &[(JobId, &JobSpec)]) {
        debug_assert!(jobs.windows(2).all(|w| w[0].0 < w[1].0));
        self.preempted.clear();
        let mut preempted: HashMap<JobId, bool> = HashMap::new();
        for rail in 0..self.rails.len() {
            let eligible: Vec<(JobId, &JobSpec)> =
                jobs.iter().filter(|(_, s)| s.admits(rail)).map(|&(id, s)| (id, s)).collect();
            let grants = &mut self.rails[rail];
            grants.clear();
            if eligible.is_empty() {
                continue;
            }
            match mode {
                ArbiterMode::FairShare => {
                    let total: f64 = eligible.iter().map(|(_, s)| s.weight).sum();
                    for (id, s) in &eligible {
                        grants.push((*id, s.weight / total));
                    }
                }
                ArbiterMode::StrictPriority => {
                    let top = eligible.iter().map(|(_, s)| s.class.rank()).min().unwrap();
                    let has_lower = eligible.iter().any(|(_, s)| s.class.rank() > top);
                    let residual = if has_lower { PREEMPTED_RESIDUAL } else { 0.0 };
                    let w_top: f64 = eligible
                        .iter()
                        .filter(|(_, s)| s.class.rank() == top)
                        .map(|(_, s)| s.weight)
                        .sum();
                    let w_low: f64 = eligible
                        .iter()
                        .filter(|(_, s)| s.class.rank() > top)
                        .map(|(_, s)| s.weight)
                        .sum();
                    for (id, s) in &eligible {
                        let g = if s.class.rank() == top {
                            (1.0 - residual) * s.weight / w_top
                        } else {
                            preempted.insert(*id, true);
                            residual * s.weight / w_low
                        };
                        grants.push((*id, g));
                    }
                }
            }
        }
        self.preempted = preempted.into_keys().collect();
        self.preempted.sort();
    }

    /// Granted share of `rail` for `job`; `None` when the job is not
    /// eligible there (the arbiter then leaves that rail's share alone —
    /// the job's own mask already keeps it off the rail).
    pub fn grant(&self, rail: usize, job: JobId) -> Option<f64> {
        self.rails.get(rail)?.iter().find(|(id, _)| *id == job).map(|&(_, g)| g)
    }

    /// Sum of grants on `rail` (conservation check; 0.0 for empty rails).
    pub fn rail_sum(&self, rail: usize) -> f64 {
        self.rails[rail].iter().map(|&(_, g)| g).sum()
    }

    /// Jobs preempted to the residual in the latest recompute, ascending.
    pub fn preempted(&self) -> &[JobId] {
        &self.preempted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arbiter::job::PriorityClass;

    fn specs(list: &[(u64, JobSpec)]) -> Vec<(JobId, JobSpec)> {
        list.iter().map(|(id, s)| (JobId(*id), s.clone())).collect()
    }

    fn refs(owned: &[(JobId, JobSpec)]) -> Vec<(JobId, &JobSpec)> {
        owned.iter().map(|(id, s)| (*id, s)).collect()
    }

    #[test]
    fn fair_share_splits_by_weight_and_conserves() {
        let owned = specs(&[
            (0, JobSpec::new("a", PriorityClass::Standard).weight(1.0)),
            (1, JobSpec::new("b", PriorityClass::Scavenger).weight(3.0)),
        ]);
        let mut l = GrantLedger::new(2);
        l.recompute(ArbiterMode::FairShare, &refs(&owned));
        assert!((l.grant(0, JobId(0)).unwrap() - 0.25).abs() < 1e-12);
        assert!((l.grant(0, JobId(1)).unwrap() - 0.75).abs() < 1e-12);
        for rail in 0..2 {
            assert!((l.rail_sum(rail) - 1.0).abs() < 1e-12);
        }
        assert!(l.preempted().is_empty(), "fair-share never preempts");
    }

    #[test]
    fn strict_priority_preempts_lower_classes_to_residual() {
        let owned = specs(&[
            (0, JobSpec::new("fg", PriorityClass::Latency)),
            (1, JobSpec::new("bg1", PriorityClass::Scavenger)),
            (2, JobSpec::new("bg2", PriorityClass::Scavenger)),
            (3, JobSpec::new("bg3", PriorityClass::Scavenger)),
        ]);
        let mut l = GrantLedger::new(1);
        l.recompute(ArbiterMode::StrictPriority, &refs(&owned));
        let fg = l.grant(0, JobId(0)).unwrap();
        assert!((fg - (1.0 - PREEMPTED_RESIDUAL)).abs() < 1e-12);
        for id in 1..4 {
            let g = l.grant(0, JobId(id)).unwrap();
            assert!((g - PREEMPTED_RESIDUAL / 3.0).abs() < 1e-12);
            // residual splits must stay above the fabric's share floor
            assert!(g >= crate::net::simnet::MIN_RAIL_SHARE);
        }
        assert!((l.rail_sum(0) - 1.0).abs() < 1e-12);
        assert_eq!(l.preempted(), &[JobId(1), JobId(2), JobId(3)]);
    }

    #[test]
    fn strict_priority_sole_class_takes_everything() {
        let owned = specs(&[
            (0, JobSpec::new("a", PriorityClass::Scavenger).weight(1.0)),
            (1, JobSpec::new("b", PriorityClass::Scavenger).weight(1.0)),
        ]);
        let mut l = GrantLedger::new(1);
        l.recompute(ArbiterMode::StrictPriority, &refs(&owned));
        // no lower class present: residual collapses to zero
        assert!((l.grant(0, JobId(0)).unwrap() - 0.5).abs() < 1e-12);
        assert!((l.rail_sum(0) - 1.0).abs() < 1e-12);
        assert!(l.preempted().is_empty());
    }

    #[test]
    fn rail_masks_gate_eligibility() {
        let owned = specs(&[
            (0, JobSpec::new("a", PriorityClass::Standard).rails(0b01)),
            (1, JobSpec::new("b", PriorityClass::Standard).rails(0b10)),
        ]);
        let mut l = GrantLedger::new(2);
        l.recompute(ArbiterMode::FairShare, &refs(&owned));
        assert_eq!(l.grant(0, JobId(0)), Some(1.0));
        assert_eq!(l.grant(0, JobId(1)), None);
        assert_eq!(l.grant(1, JobId(0)), None);
        assert_eq!(l.grant(1, JobId(1)), Some(1.0));
    }

    #[test]
    fn empty_rail_grants_nothing() {
        let owned = specs(&[(0, JobSpec::new("a", PriorityClass::Standard).rails(0b01))]);
        let mut l = GrantLedger::new(2);
        l.recompute(ArbiterMode::FairShare, &refs(&owned));
        assert_eq!(l.rail_sum(1), 0.0);
        assert_eq!(l.grant(1, JobId(0)), None);
    }

    #[test]
    fn recompute_is_deterministic() {
        let owned = specs(&[
            (2, JobSpec::new("a", PriorityClass::Latency).weight(1.7)),
            (5, JobSpec::new("b", PriorityClass::Scavenger).weight(0.3)),
            (9, JobSpec::new("c", PriorityClass::Standard).weight(2.2).rails(0b01)),
        ]);
        let mut a = GrantLedger::new(2);
        let mut b = GrantLedger::new(2);
        a.recompute(ArbiterMode::StrictPriority, &refs(&owned));
        b.recompute(ArbiterMode::StrictPriority, &refs(&owned));
        for rail in 0..2 {
            for (id, _) in &owned {
                assert_eq!(
                    a.grant(rail, *id).map(f64::to_bits),
                    b.grant(rail, *id).map(f64::to_bits),
                    "grant differs at rail {rail} job {id:?}"
                );
            }
        }
    }
}
