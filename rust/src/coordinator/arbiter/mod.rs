//! Multi-tenant fabric arbiter: concurrent [`MultiRail`] jobs sharing
//! the same physical rails under priority classes and fair-share
//! weights.
//!
//! # Architecture
//!
//! Each admitted tenant keeps its **own** coordinator — fabric clock,
//! RNG streams, planner, control plane — exactly as if it ran solo. The
//! arbiter owns only the *admission* state: a [`GrantLedger`] mapping
//! `(rail, job)` to a bandwidth share, recomputed at every churn event
//! (admit/depart). Grants are applied through
//! [`MultiRail::set_rail_grant`], which (a) inflates that tenant's
//! modeled transfer times on the fabric's live sampling paths and
//! (b) — for contended-pricing tenants — feeds the share into the
//! planner's [`crate::coordinator::planner::cost::contended_us`] so the
//! next plan is chosen against *contended* costs, not solo costs.
//!
//! # Window-boundary preemption
//!
//! Collectives are atomic in modeled time: a grant change takes effect
//! at the next op, never mid-op. Under
//! [`ArbiterMode::StrictPriority`] a latency-class arrival therefore
//! preempts scavenger bulk at the next window boundary — the scavenger
//! finishes its in-flight collective at the old share and runs every
//! subsequent one at the [`ledger::PREEMPTED_RESIDUAL`] trickle.
//!
//! # Per-job bit-identity
//!
//! Because tenants share no RNG, no clock and no buffers, a tenant's
//! *numerics* (reduced values) are bit-identical to its solo run in
//! every arbiter configuration — contention scales modeled time only.
//! And since contended predictions algebraically match contended
//! measurements, correction EWMAs stay at 1.0, so restoring a grant to
//! 1.0 reproduces solo modeled times bit-exactly too. The
//! `integration_arbiter` matrix asserts both properties across
//! {1,2,4 jobs} x {fair-share, strict-priority} x {serial, parallel}.

pub mod job;
pub mod ledger;

pub use job::{JobId, JobSpec, PriorityClass, TenantJob};
pub use ledger::{ArbiterMode, GrantLedger, PREEMPTED_RESIDUAL};

use crate::coordinator::buffer::UnboundBuffer;
use crate::coordinator::multirail::{MultiRail, OpReport};
use crate::util::error::Error;
use crate::Result;

/// Modeled cost charged to every tenant whose grants change at a churn
/// event: plan-cache flush + first contended replan + rail window
/// re-registration. Well under the paper's 200 ms recovery budget
/// ([`crate::coordinator::control::exception::PAPER_RECOVERY_BUDGET_US`]),
/// which the churn ledger asserts against.
pub const DEFAULT_MIGRATE_COST_US: f64 = 40_000.0;

/// Buffer length used by [`FabricArbiter::step`]'s synthesized ops; the
/// spec'd payload is modeled through per-element byte scaling.
pub const SYNTH_ELEMS: usize = 4096;

/// What happened at a churn event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnKind {
    Admit,
    Depart,
}

/// One admission-state change and the replan cost it induced.
#[derive(Debug, Clone, Copy)]
pub struct ChurnEvent {
    /// Arbiter wall clock (max tenant fabric clock) after the event.
    pub at_us: f64,
    /// The job that arrived or departed.
    pub job: JobId,
    pub kind: ChurnKind,
    /// Modeled replan cost charged to each re-granted tenant (0.0 when
    /// the event changed no grants, e.g. the first solo admission).
    pub replan_us: f64,
    /// Tenants whose grants actually changed.
    pub jobs_replanned: usize,
}

/// The arbiter: admission control + grant accounting over N tenants.
pub struct FabricArbiter {
    mode: ArbiterMode,
    n_rails: usize,
    /// Ascending [`JobId`] — the determinism anchor shared with the ledger.
    jobs: Vec<TenantJob>,
    next_id: u64,
    ledger: GrantLedger,
    /// Per-tenant modeled cost of a grant migration (see
    /// [`DEFAULT_MIGRATE_COST_US`]); tunable for what-if churn studies.
    pub migrate_cost_us: f64,
    churn: Vec<ChurnEvent>,
}

impl FabricArbiter {
    pub fn new(mode: ArbiterMode, n_rails: usize) -> FabricArbiter {
        FabricArbiter {
            mode,
            n_rails,
            jobs: Vec::new(),
            next_id: 0,
            ledger: GrantLedger::new(n_rails),
            migrate_cost_us: DEFAULT_MIGRATE_COST_US,
            churn: Vec::new(),
        }
    }

    pub fn mode(&self) -> ArbiterMode {
        self.mode
    }

    pub fn n_rails(&self) -> usize {
        self.n_rails
    }

    pub fn jobs(&self) -> &[TenantJob] {
        &self.jobs
    }

    pub fn job(&self, id: JobId) -> Option<&TenantJob> {
        self.jobs.iter().find(|j| j.id == id)
    }

    pub fn job_mut(&mut self, id: JobId) -> Option<&mut TenantJob> {
        self.jobs.iter_mut().find(|j| j.id == id)
    }

    pub fn ledger(&self) -> &GrantLedger {
        &self.ledger
    }

    pub fn churn(&self) -> &[ChurnEvent] {
        &self.churn
    }

    /// Admit a tenant built for `nodes` participants. The coordinator
    /// must ride a fabric with the arbiter's rail count; grants across
    /// all tenants are rebalanced immediately (the new tenant's first
    /// collectives already run at contended shares).
    pub fn admit(&mut self, spec: JobSpec, nodes: usize, mr: MultiRail) -> JobId {
        assert_eq!(
            mr.fab.rails.len(),
            self.n_rails,
            "tenant fabric rail count must match the arbiter"
        );
        let id = JobId(self.next_id);
        self.next_id += 1;
        self.jobs.push(TenantJob { id, spec, nodes, mr, ops: 0, latencies_us: Vec::new() });
        self.rebalance(id, ChurnKind::Admit);
        id
    }

    /// Remove a tenant, restore its grants to solo (so the returned
    /// coordinator behaves standalone) and rebalance the survivors.
    pub fn depart(&mut self, id: JobId) -> Option<TenantJob> {
        let pos = self.jobs.iter().position(|j| j.id == id)?;
        let mut gone = self.jobs.remove(pos);
        for rail in 0..self.n_rails {
            if gone.spec.admits(rail) {
                gone.mr.set_rail_grant(rail, 1.0, gone.spec.contended_pricing);
            }
        }
        self.rebalance(id, ChurnKind::Depart);
        Some(gone)
    }

    /// Recompute the ledger and push changed grants into each tenant.
    /// Tenants whose effective share moved pay `migrate_cost_us` on
    /// their own fabric clock — the modeled price of the plan-cache
    /// flush and first contended replan.
    fn rebalance(&mut self, subject: JobId, kind: ChurnKind) {
        let snapshot: Vec<(JobId, JobSpec)> =
            self.jobs.iter().map(|j| (j.id, j.spec.clone())).collect();
        let refs: Vec<(JobId, &JobSpec)> = snapshot.iter().map(|(id, s)| (*id, s)).collect();
        self.ledger.recompute(self.mode, &refs);
        let mut replanned = 0usize;
        for j in self.jobs.iter_mut() {
            let mut touched = false;
            for rail in 0..self.n_rails {
                if let Some(g) = self.ledger.grant(rail, j.id) {
                    if (g - j.mr.rail_grant(rail)).abs() > 1e-12 {
                        j.mr.set_rail_grant(rail, g, j.spec.contended_pricing);
                        touched = true;
                    }
                }
            }
            if touched {
                j.mr.fab.advance(self.migrate_cost_us);
                replanned += 1;
            }
        }
        let at_us = self.wall_us();
        self.churn.push(ChurnEvent {
            at_us,
            job: subject,
            kind,
            replan_us: if replanned > 0 { self.migrate_cost_us } else { 0.0 },
            jobs_replanned: replanned,
        });
    }

    /// Run one collective for `id` on the caller's buffer, recording the
    /// op latency. The report is returned un-recycled (callers verifying
    /// numerics want `per_rail`; steady-state callers hand it back via
    /// `job_mut(id).mr.recycle(rep)`).
    pub fn run_op(&mut self, id: JobId, buf: &mut UnboundBuffer) -> Result<OpReport> {
        self.run_op_scaled(id, buf, 4.0)
    }

    /// [`Self::run_op`] with the crate's scaled-op idiom: the op models
    /// `buf.len() * elem_bytes` payload bytes while numerics run over the
    /// buffer as-is — big-payload tenancy studies without big buffers.
    pub fn run_op_scaled(
        &mut self,
        id: JobId,
        buf: &mut UnboundBuffer,
        elem_bytes: f64,
    ) -> Result<OpReport> {
        let j = self
            .jobs
            .iter_mut()
            .find(|j| j.id == id)
            .ok_or_else(|| Error::msg(format!("arbiter: unknown job {id:?}")))?;
        let rep = j.mr.allreduce_scaled(buf, elem_bytes)?;
        j.ops += 1;
        j.latencies_us.push(rep.total_us);
        Ok(rep)
    }

    /// One scheduling window: every tenant (ascending id) runs one
    /// collective of its spec'd payload on a synthesized
    /// [`SYNTH_ELEMS`]-element buffer (scaled to the payload). The
    /// bench/ablation driver for sustained multi-tenant load.
    pub fn step(&mut self) -> Result<()> {
        let ids: Vec<JobId> = self.jobs.iter().map(|j| j.id).collect();
        for id in ids {
            let (nodes, payload) = {
                let j = self.job(id).expect("job vanished mid-step");
                (j.nodes, j.spec.payload_bytes as f64)
            };
            let mut buf =
                UnboundBuffer::from_fn(nodes, SYNTH_ELEMS, |n, i| ((n + 1) * (i % 13 + 1)) as f32);
            let rep = self.run_op_scaled(id, &mut buf, payload / SYNTH_ELEMS as f64)?;
            self.job_mut(id).expect("job vanished mid-step").mr.recycle(rep);
        }
        Ok(())
    }

    /// Arbiter wall clock: the furthest tenant fabric clock (tenants
    /// progress concurrently in modeled time).
    pub fn wall_us(&self) -> f64 {
        self.jobs.iter().map(|j| j.mr.fab.now_us()).fold(0.0, f64::max)
    }

    /// Aggregate modeled goodput across all live tenants (payload bytes
    /// reduced per wall-clock microsecond, in GB/s).
    pub fn aggregate_gbps(&self) -> f64 {
        let bytes: u64 = self.jobs.iter().map(|j| j.spec.payload_bytes * j.ops).sum();
        let wall = self.wall_us();
        if wall <= 0.0 {
            0.0
        } else {
            crate::util::bytes::gbps(bytes, wall)
        }
    }

    /// p99 op latency for one tenant (None before its first op).
    pub fn p99_us(&self, id: JobId) -> Option<f64> {
        self.job(id).and_then(|j| j.p99_us())
    }

    /// True when every churn event replanned within `budget_us` — the
    /// paper's recovery-budget check applied to tenancy churn.
    pub fn all_churn_within(&self, budget_us: f64) -> bool {
        self.churn.iter().all(|e| e.replan_us <= budget_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, Policy};
    use crate::net::protocol::ProtoKind;

    fn tenant(nodes: usize) -> MultiRail {
        let cfg = Config {
            nodes,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: true,
            ..Config::default()
        };
        MultiRail::new(&cfg).unwrap()
    }

    #[test]
    fn admission_rebalances_and_departure_restores_solo_grants() {
        let mut arb = FabricArbiter::new(ArbiterMode::FairShare, 2);
        let a = arb.admit(JobSpec::new("a", PriorityClass::Standard), 4, tenant(4));
        // solo admission: grants are already 1.0, nothing replans
        assert_eq!(arb.churn()[0].jobs_replanned, 0);
        assert_eq!(arb.job(a).unwrap().mr.rail_grant(0), 1.0);

        let b = arb.admit(JobSpec::new("b", PriorityClass::Standard), 4, tenant(4));
        // two equal-weight tenants: both replan to 0.5 on every rail
        assert_eq!(arb.churn()[1].jobs_replanned, 2);
        for rail in 0..2 {
            assert!((arb.job(a).unwrap().mr.rail_grant(rail) - 0.5).abs() < 1e-12);
            assert!((arb.job(b).unwrap().mr.rail_grant(rail) - 0.5).abs() < 1e-12);
            assert!((arb.ledger().rail_sum(rail) - 1.0).abs() < 1e-12);
        }

        let gone = arb.depart(a).unwrap();
        // departing tenant leaves with solo grants; survivor regains the rail
        assert_eq!(gone.mr.rail_grant(0), 1.0);
        assert_eq!(arb.job(b).unwrap().mr.rail_grant(0), 1.0);
        assert!(arb.job(a).is_none());
        assert!(arb.all_churn_within(
            crate::coordinator::control::exception::PAPER_RECOVERY_BUDGET_US
        ));
    }

    #[test]
    fn strict_priority_preempts_scavenger_at_window_boundary() {
        let mut arb = FabricArbiter::new(ArbiterMode::StrictPriority, 2);
        let bg = arb.admit(
            JobSpec::new("bg", PriorityClass::Scavenger).payload(8 << 20),
            4,
            tenant(4),
        );
        arb.step().unwrap();
        let t_solo = arb.job(bg).unwrap().latencies_us[0];

        let fg = arb.admit(
            JobSpec::new("fg", PriorityClass::Latency).payload(1 << 20),
            4,
            tenant(4),
        );
        assert_eq!(arb.ledger().preempted(), &[bg]);
        assert!(
            (arb.job(fg).unwrap().mr.rail_grant(0) - (1.0 - PREEMPTED_RESIDUAL)).abs() < 1e-12
        );
        arb.step().unwrap();
        let t_contended = arb.job(bg).unwrap().latencies_us[1];
        assert!(
            t_contended > t_solo * 2.0,
            "preempted scavenger op should slow well past solo: {t_solo} -> {t_contended}"
        );
        assert!(arb.wall_us() > 0.0);
        assert!(arb.aggregate_gbps() > 0.0);
    }

    #[test]
    fn run_op_rejects_unknown_job() {
        let mut arb = FabricArbiter::new(ArbiterMode::FairShare, 2);
        let mut buf = UnboundBuffer::from_fn(4, 64, |n, i| (n + i) as f32);
        assert!(arb.run_op(JobId(7), &mut buf).is_err());
    }
}
