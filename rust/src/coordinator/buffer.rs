//! Cross-protocol shared buffer (paper §3.2).
//!
//! Data to be allreduced is staged in an `UnboundBuffer`; each member
//! network receives a `(ptr, data_length)` window — here a typed
//! [`Window`] — reads its slice, processes it, and returns results in
//! place. Once every window completes, the buffer releases the data to the
//! requester. The window arithmetic below is exactly what the Load
//! Balancer's pointer calculation (§3.5) produces and what failover hands
//! between rails (§4.4).
//!
//! The splitting APIs come in two forms: the original allocating methods
//! (`split_fractions`, `split_chunks`) and `*_into` scratch-reuse variants
//! that write into caller-owned vectors — the per-op hot path uses the
//! latter so steady-state collective execution allocates nothing. The
//! [`BufferPool`] closes the remaining per-repetition allocation: harness,
//! trainer and ablation loops recycle staging buffers instead of
//! constructing `from_fn` (nodes × elems allocations plus a per-element
//! closure) for every op.

use crate::util::error::Error;

/// Windowed access to per-node payload slices — the buffer abstraction
/// every collective's numerics run over. Implemented by the full
/// [`UnboundBuffer`] (global coordinates) and by [`RailView`], the
/// disjoint per-rail view the parallel executor hands each worker thread.
/// Windows are always given in GLOBAL buffer coordinates; views translate
/// internally, so the same segment lists drive both implementations.
pub trait NodeWindows {
    /// Number of node payloads.
    fn nodes(&self) -> usize;
    /// Node `n`'s slice of window `w` (global coordinates).
    fn window(&self, n: usize, w: Window) -> &[f32];
    /// Mutable form of [`NodeWindows::window`].
    fn window_mut(&mut self, n: usize, w: Window) -> &mut [f32];
    /// Borrow two distinct nodes' windows simultaneously (ring exchange).
    fn pair_windows_mut(&mut self, a: usize, b: usize, w: Window)
        -> (&mut [f32], &mut [f32]);
    /// Borrow three distinct nodes' windows simultaneously (the fused
    /// reduce-scatter + allgather hop).
    fn tri_windows_mut(
        &mut self,
        a: usize,
        b: usize,
        c: usize,
        w: Window,
    ) -> (&mut [f32], &mut [f32], &mut [f32]);
}

/// Split two distinct per-node slices out of `data` — the shared pair-
/// borrow core behind both [`NodeWindows`] implementations.
fn pair_split<S: AsMut<[f32]>>(
    data: &mut [S],
    a: usize,
    b: usize,
    w: Window,
) -> (&mut [f32], &mut [f32]) {
    assert_ne!(a, b);
    let (lo, hi, swap) = if a < b { (a, b, false) } else { (b, a, true) };
    let (left, right) = data.split_at_mut(hi);
    let sa = &mut left[lo].as_mut()[w.offset..w.end()];
    let sb = &mut right[0].as_mut()[w.offset..w.end()];
    if swap { (sb, sa) } else { (sa, sb) }
}

/// Split three distinct per-node slices out of `data` (see
/// [`pair_split`]): order the indices, split the outer slice twice, then
/// un-permute.
fn tri_split<S: AsMut<[f32]>>(
    data: &mut [S],
    a: usize,
    b: usize,
    c: usize,
    w: Window,
) -> (&mut [f32], &mut [f32], &mut [f32]) {
    assert!(a != b && b != c && a != c, "tri-borrow needs distinct nodes");
    let mut idx = [(a, 0usize), (b, 1), (c, 2)];
    idx.sort_unstable_by_key(|&(node, _)| node);
    let (lo, mid, hi) = (idx[0].0, idx[1].0, idx[2].0);
    let (left, rest) = data.split_at_mut(mid);
    let (mid_part, right) = rest.split_at_mut(hi - mid);
    let s_lo = &mut left[lo].as_mut()[w.offset..w.end()];
    let s_mid = &mut mid_part[0].as_mut()[w.offset..w.end()];
    let s_hi = &mut right[0].as_mut()[w.offset..w.end()];
    let mut out: [Option<&mut [f32]>; 3] = [None, None, None];
    out[idx[0].1] = Some(s_lo);
    out[idx[1].1] = Some(s_mid);
    out[idx[2].1] = Some(s_hi);
    let [x, y, z] = out;
    (x.unwrap(), y.unwrap(), z.unwrap())
}

/// A `(ptr, data_length)` view into the shared buffer, in f32 elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    pub offset: usize,
    pub len: usize,
}

impl Window {
    pub fn new(offset: usize, len: usize) -> Window {
        Window { offset, len }
    }

    pub fn bytes(&self) -> u64 {
        self.len as u64 * 4
    }

    pub fn end(&self) -> usize {
        self.offset + self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Split this window into `parts` contiguous sub-windows proportional
    /// to `fractions` (which must sum to ~1). Every element lands in
    /// exactly one sub-window; rounding drift is absorbed by the last part.
    pub fn split_fractions(&self, fractions: &[f64]) -> Vec<Window> {
        let mut out = Vec::with_capacity(fractions.len());
        self.split_fractions_into(fractions, &mut out);
        out
    }

    /// The canonical share-split loop behind every proportional splitting
    /// API (fractions, uniform ring segments, plan windows): `k`
    /// contiguous parts, part `i` sized `round(len · share(i))` clamped to
    /// the remainder, the last part absorbing rounding drift. ONE
    /// implementation so plan windows and ring segments can never
    /// desynchronize.
    pub fn split_shares_into(
        &self,
        k: usize,
        share: impl Fn(usize) -> f64,
        out: &mut Vec<Window>,
    ) {
        assert!(k > 0);
        out.clear();
        let mut off = self.offset;
        for i in 0..k {
            let len = if i + 1 == k {
                self.end() - off
            } else {
                ((self.len as f64 * share(i)).round() as usize).min(self.end() - off)
            };
            out.push(Window::new(off, len));
            off += len;
        }
        debug_assert_eq!(out.last().unwrap().end(), self.end());
    }

    /// Scratch-reuse form of [`Window::split_fractions`]: identical
    /// arithmetic, writing into `out` (cleared first) so steady-state
    /// callers allocate only until `out`'s capacity stabilizes.
    pub fn split_fractions_into(&self, fractions: &[f64], out: &mut Vec<Window>) {
        assert!(!fractions.is_empty());
        self.split_shares_into(fractions.len(), |i| fractions[i], out);
    }

    /// Equal `parts`-way split with the exact arithmetic of
    /// `split_fractions(&[1.0 / parts as f64; parts])`, minus the
    /// fractions vector — the ring segment computation on the hot path.
    pub fn split_uniform_into(&self, parts: usize, out: &mut Vec<Window>) {
        self.split_shares_into(parts, |_| 1.0 / parts as f64, out);
    }

    /// Split into fixed-size chunks (the ring-chunked pipeline and MPTCP's
    /// packet slicing both use this).
    pub fn split_chunks(&self, chunk_elems: usize) -> Vec<Window> {
        let mut out = Vec::new();
        self.split_chunks_into(chunk_elems, &mut out);
        out
    }

    /// Scratch-reuse form of [`Window::split_chunks`]: identical
    /// arithmetic, writing into `out` (cleared first).
    pub fn split_chunks_into(&self, chunk_elems: usize, out: &mut Vec<Window>) {
        assert!(chunk_elems > 0);
        out.clear();
        let mut off = self.offset;
        while off < self.end() {
            let len = chunk_elems.min(self.end() - off);
            out.push(Window::new(off, len));
            off += len;
        }
        if out.is_empty() {
            out.push(*self);
        }
    }
}

/// The staging buffer shared by all member networks: one payload slice per
/// node (the in-process stand-in for each node's pinned gradient buffer).
#[derive(Debug)]
pub struct UnboundBuffer {
    /// data[node] — all nodes' payloads, equal length.
    data: Vec<Vec<f32>>,
    /// Completion mask per registered window.
    pending: Vec<(Window, bool)>,
}

impl UnboundBuffer {
    pub fn new(data: Vec<Vec<f32>>) -> UnboundBuffer {
        assert!(!data.is_empty());
        let len = data[0].len();
        assert!(data.iter().all(|d| d.len() == len), "ragged node buffers");
        UnboundBuffer { data, pending: Vec::new() }
    }

    pub fn from_fn(nodes: usize, len: usize, f: impl Fn(usize, usize) -> f32) -> UnboundBuffer {
        UnboundBuffer::new(
            (0..nodes)
                .map(|n| (0..len).map(|i| f(n, i)).collect())
                .collect(),
        )
    }

    pub fn nodes(&self) -> usize {
        self.data.len()
    }

    pub fn len(&self) -> usize {
        self.data[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn full_window(&self) -> Window {
        Window::new(0, self.len())
    }

    /// Register a window a member network is responsible for.
    pub fn register(&mut self, w: Window) {
        assert!(w.end() <= self.len(), "window out of bounds");
        self.pending.push((w, false));
    }

    /// Mark a registered window done. A window that was never registered
    /// (or was migrated/cleared by a concurrent failover) surfaces as a
    /// recoverable [`Error::UnregisteredWindow`], not a panic.
    pub fn complete(&mut self, w: Window) -> crate::Result<()> {
        for (pw, done) in &mut self.pending {
            if *pw == w {
                *done = true;
                return Ok(());
            }
        }
        Err(Error::UnregisteredWindow { offset: w.offset, len: w.len })
    }

    /// All registered windows done — data may be released to the requester.
    pub fn all_complete(&self) -> bool {
        self.pending.iter().all(|(_, d)| *d)
    }

    pub fn clear_pending(&mut self) {
        self.pending.clear();
    }

    pub fn node(&self, n: usize) -> &[f32] {
        &self.data[n]
    }

    pub fn node_mut(&mut self, n: usize) -> &mut [f32] {
        &mut self.data[n]
    }

    /// Borrow two nodes' windows simultaneously (ring-step exchange).
    pub fn pair_windows_mut(
        &mut self,
        a: usize,
        b: usize,
        w: Window,
    ) -> (&mut [f32], &mut [f32]) {
        pair_split(&mut self.data, a, b, w)
    }

    /// Borrow three distinct nodes' windows simultaneously — the fused
    /// final reduce-scatter + first allgather hop (`Reducer::reduce_copy`)
    /// needs sender, receiver and the receiver's ring successor in one
    /// pass.
    pub fn tri_windows_mut(
        &mut self,
        a: usize,
        b: usize,
        c: usize,
        w: Window,
    ) -> (&mut [f32], &mut [f32], &mut [f32]) {
        tri_split(&mut self.data, a, b, c, w)
    }

    /// Disjoint per-rail views over `windows` (which must be sorted,
    /// non-overlapping sub-windows of this buffer — exactly what
    /// [`crate::coordinator::planner::CollectivePlan::windows_into`]
    /// produces). Each view covers ONE window across every node's payload,
    /// so the parallel executor can hand rails to worker threads with the
    /// borrow checker proving the rails' numerics never alias. Empty
    /// windows yield empty views (kept so indices line up with the plan's
    /// assignment order).
    pub fn rail_views(&mut self, windows: &[Window]) -> Vec<RailView<'_>> {
        let nodes = self.data.len();
        let total = self.len();
        let mut per_window: Vec<Vec<&mut [f32]>> =
            windows.iter().map(|_| Vec::with_capacity(nodes)).collect();
        for node in self.data.iter_mut() {
            let mut rest: &mut [f32] = node.as_mut_slice();
            let mut cursor = 0usize;
            for (i, w) in windows.iter().enumerate() {
                assert!(
                    w.offset >= cursor && w.end() <= total,
                    "rail views need sorted, non-overlapping windows"
                );
                let (_gap, tail) = rest.split_at_mut(w.offset - cursor);
                let (slice, tail) = tail.split_at_mut(w.len);
                per_window[i].push(slice);
                rest = tail;
                cursor = w.end();
            }
        }
        windows
            .iter()
            .zip(per_window)
            .map(|(w, nodes)| RailView { base: w.offset, len: w.len, nodes })
            .collect()
    }

    /// Overwrite every node's payload from `template` (shapes must match)
    /// and clear completion state — the pool's in-place re-fill: one
    /// `copy_from_slice` per node instead of per-element closure calls.
    pub fn restore_from(&mut self, template: &[Vec<f32>]) {
        assert_eq!(self.data.len(), template.len(), "pool template node mismatch");
        for (d, t) in self.data.iter_mut().zip(template) {
            d.copy_from_slice(t);
        }
        self.pending.clear();
    }

    pub fn into_data(self) -> Vec<Vec<f32>> {
        self.data
    }
}

impl NodeWindows for UnboundBuffer {
    fn nodes(&self) -> usize {
        self.data.len()
    }

    fn window(&self, n: usize, w: Window) -> &[f32] {
        &self.data[n][w.offset..w.end()]
    }

    fn window_mut(&mut self, n: usize, w: Window) -> &mut [f32] {
        &mut self.data[n][w.offset..w.end()]
    }

    fn pair_windows_mut(&mut self, a: usize, b: usize, w: Window)
        -> (&mut [f32], &mut [f32]) {
        pair_split(&mut self.data, a, b, w)
    }

    fn tri_windows_mut(
        &mut self,
        a: usize,
        b: usize,
        c: usize,
        w: Window,
    ) -> (&mut [f32], &mut [f32], &mut [f32]) {
        tri_split(&mut self.data, a, b, c, w)
    }
}

/// One rail's disjoint view of the shared buffer: the rail's window slice
/// of EVERY node's payload, borrow-split out of the [`UnboundBuffer`] by
/// [`UnboundBuffer::rail_views`]. Implements [`NodeWindows`] in global
/// coordinates (translating internally), so collective numerics run
/// unchanged over a view — and the borrow checker proves concurrent rails
/// can never touch each other's elements.
#[derive(Debug)]
pub struct RailView<'a> {
    /// Global offset of this view's window.
    base: usize,
    /// Window length in elements.
    len: usize,
    /// `nodes[n]` = node n's `[base, base + len)` slice.
    nodes: Vec<&'a mut [f32]>,
}

impl RailView<'_> {
    /// Translate a global window into view-local coordinates (bounds-
    /// checked: the window must lie inside this view).
    fn local(&self, w: Window) -> Window {
        debug_assert!(
            w.offset >= self.base && w.end() <= self.base + self.len,
            "window {w:?} escapes rail view [{}, {})",
            self.base,
            self.base + self.len
        );
        Window::new(w.offset - self.base, w.len)
    }

    /// The view's own window in global coordinates.
    pub fn window_of_view(&self) -> Window {
        Window::new(self.base, self.len)
    }
}

impl NodeWindows for RailView<'_> {
    fn nodes(&self) -> usize {
        self.nodes.len()
    }

    fn window(&self, n: usize, w: Window) -> &[f32] {
        let lw = self.local(w);
        &self.nodes[n][lw.offset..lw.end()]
    }

    fn window_mut(&mut self, n: usize, w: Window) -> &mut [f32] {
        let lw = self.local(w);
        &mut self.nodes[n][lw.offset..lw.end()]
    }

    fn pair_windows_mut(&mut self, a: usize, b: usize, w: Window)
        -> (&mut [f32], &mut [f32]) {
        let lw = self.local(w);
        pair_split(&mut self.nodes, a, b, lw)
    }

    fn tri_windows_mut(
        &mut self,
        a: usize,
        b: usize,
        c: usize,
        w: Window,
    ) -> (&mut [f32], &mut [f32], &mut [f32]) {
        let lw = self.local(w);
        tri_split(&mut self.nodes, a, b, c, lw)
    }
}

/// Reusable staging buffers for the collective hot path.
///
/// The harness/trainer/ablation loops used to construct a fresh
/// [`UnboundBuffer::from_fn`] — nodes × elems vector allocations plus a
/// per-element closure evaluation — for every repetition. The pool keeps
/// returned buffers together with a pristine *template* per
/// (nodes, len, fill) shape: [`BufferPool::acquire`] restores a recycled
/// buffer with per-node `copy_from_slice` from the template. A sampled
/// fingerprint guards against a different fill function silently reusing a
/// stale template (a full template is rebuilt on mismatch), and debug
/// builds assert the restored buffer is bit-identical to a fresh
/// allocation.
#[derive(Debug, Default)]
pub struct BufferPool {
    shapes: Vec<PoolShape>,
}

#[derive(Debug)]
struct PoolShape {
    nodes: usize,
    len: usize,
    /// Pristine fill pattern: `template[n][i] = f(n, i)`.
    template: Vec<Vec<f32>>,
    /// Sampled `(n, i, f(n, i))` probes: cheap fill-function identity
    /// check on every acquire (bit-compared, so NaN-safe).
    probes: Vec<(usize, usize, f32)>,
    free: Vec<UnboundBuffer>,
    /// Debug builds fully verify the first recycled buffer per shape
    /// against a fresh allocation; later recycles copy the same template
    /// bytes, so one check proves the invariant without making every
    /// debug-mode acquire pay a from_fn reconstruction.
    #[cfg(debug_assertions)]
    verified: bool,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Hand out a buffer filled exactly as `UnboundBuffer::from_fn(nodes,
    /// len, f)` would fill it, recycling a returned buffer when one of the
    /// matching shape exists.
    pub fn acquire(
        &mut self,
        nodes: usize,
        len: usize,
        f: impl Fn(usize, usize) -> f32,
    ) -> UnboundBuffer {
        assert!(nodes > 0, "pool buffers need at least one node");
        let idx = self.shape_index(nodes, len, &f);
        let shape = &mut self.shapes[idx];
        match shape.free.pop() {
            Some(mut b) => {
                b.restore_from(&shape.template);
                #[cfg(debug_assertions)]
                if !shape.verified {
                    shape.verified = true;
                    let fresh = UnboundBuffer::from_fn(nodes, len, &f);
                    for n in 0..nodes {
                        debug_assert_eq!(
                            b.node(n),
                            fresh.node(n),
                            "pooled buffer diverged from fresh allocation (node {n})"
                        );
                    }
                }
                b
            }
            None => UnboundBuffer::new(shape.template.clone()),
        }
    }

    /// Return a buffer for reuse. Buffers of a shape the pool never served
    /// are simply dropped.
    pub fn release(&mut self, buf: UnboundBuffer) {
        if let Some(s) = self
            .shapes
            .iter_mut()
            .find(|s| s.nodes == buf.nodes() && s.len == buf.len())
        {
            s.free.push(buf);
        }
    }

    /// Buffers currently parked in the pool (tests/metrics).
    pub fn pooled(&self) -> usize {
        self.shapes.iter().map(|s| s.free.len()).sum()
    }

    fn shape_index(&mut self, nodes: usize, len: usize, f: &impl Fn(usize, usize) -> f32) -> usize {
        if let Some(i) = self.shapes.iter().position(|s| {
            s.nodes == nodes
                && s.len == len
                && s.probes
                    .iter()
                    .all(|&(n, j, v)| f(n, j).to_bits() == v.to_bits())
        }) {
            return i;
        }
        let template: Vec<Vec<f32>> = (0..nodes)
            .map(|n| (0..len).map(|j| f(n, j)).collect())
            .collect();
        // fingerprint = the three corners plus 13 pseudo-random positions
        // (deterministically derived from the shape), bit-compared on
        // every acquire: two honest fill functions of the same shape that
        // agree on all 16 sampled values but differ elsewhere is not a
        // realistic collision, so a stale template cannot be served for a
        // different fill.
        let probes = if len > 0 {
            let mut rng = crate::util::rng::Pcg::new(
                0x9E3779B9 ^ ((nodes as u64) << 32) ^ len as u64,
            );
            let mut pts = vec![(0, 0), (nodes - 1, len - 1), (nodes / 2, len / 2)];
            for _ in 0..13 {
                pts.push((
                    rng.below(nodes as u64) as usize,
                    rng.below(len as u64) as usize,
                ));
            }
            pts.into_iter().map(|(n, j)| (n, j, f(n, j))).collect()
        } else {
            Vec::new()
        };
        self.shapes.push(PoolShape {
            nodes,
            len,
            template,
            probes,
            free: Vec::new(),
            #[cfg(debug_assertions)]
            verified: false,
        });
        self.shapes.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_fractions_covers_exactly() {
        let w = Window::new(10, 1000);
        let parts = w.split_fractions(&[0.3, 0.7]);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].offset, 10);
        assert_eq!(parts[0].len + parts[1].len, 1000);
        assert_eq!(parts[1].end(), 1010);
        // ~30/70 split
        assert!((parts[0].len as f64 - 300.0).abs() <= 1.0);
    }

    #[test]
    fn split_fractions_rounding_edge() {
        let w = Window::new(0, 7);
        let parts = w.split_fractions(&[1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0]);
        assert_eq!(parts.iter().map(|p| p.len).sum::<usize>(), 7);
        assert_eq!(parts[2].end(), 7);
    }

    #[test]
    fn split_chunks() {
        let w = Window::new(4, 10);
        let chunks = w.split_chunks(4);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], Window::new(4, 4));
        assert_eq!(chunks[2], Window::new(12, 2));
    }

    #[test]
    fn split_into_variants_match_allocating_on_edges() {
        let mut out = Vec::new();
        for w in [
            Window::new(0, 0),
            Window::new(9, 0),
            Window::new(0, 1),
            Window::new(3, 5),
            Window::new(0, 7),
            Window::new(2, 1003),
        ] {
            for parts in [1usize, 2, 3, 8, 16] {
                let fracs = vec![1.0 / parts as f64; parts];
                let alloc = w.split_fractions(&fracs);
                w.split_fractions_into(&fracs, &mut out);
                assert_eq!(alloc, out, "{w:?} fractions x{parts}");
                w.split_uniform_into(parts, &mut out);
                assert_eq!(alloc, out, "{w:?} uniform x{parts}");
            }
            for chunk in [1usize, 4, 1000] {
                let alloc = w.split_chunks(chunk);
                w.split_chunks_into(chunk, &mut out);
                assert_eq!(alloc, out, "{w:?} chunks of {chunk}");
            }
        }
    }

    #[test]
    fn zero_fraction_windows_allowed() {
        let w = Window::new(0, 100);
        let parts = w.split_fractions(&[0.0, 1.0]);
        assert_eq!(parts[0].len, 0);
        assert!(parts[0].is_empty());
        assert_eq!(parts[1].len, 100);
    }

    #[test]
    fn completion_tracking() {
        let mut b = UnboundBuffer::from_fn(2, 8, |n, i| (n * 8 + i) as f32);
        let w1 = Window::new(0, 4);
        let w2 = Window::new(4, 4);
        b.register(w1);
        b.register(w2);
        assert!(!b.all_complete());
        b.complete(w1).unwrap();
        assert!(!b.all_complete());
        b.complete(w2).unwrap();
        assert!(b.all_complete());
    }

    #[test]
    fn completing_unregistered_window_is_recoverable() {
        let mut b = UnboundBuffer::from_fn(2, 8, |_, _| 0.0);
        b.register(Window::new(0, 4));
        let err = b.complete(Window::new(4, 4)).unwrap_err();
        assert!(err.to_string().contains("unregistered window"), "{err}");
        // the registered window still completes fine afterwards
        b.complete(Window::new(0, 4)).unwrap();
        assert!(b.all_complete());
    }

    #[test]
    fn pair_windows_disjoint_borrow() {
        let mut b = UnboundBuffer::from_fn(3, 4, |n, i| (n * 4 + i) as f32);
        let (a, c) = b.pair_windows_mut(2, 0, Window::new(1, 2));
        assert_eq!(a, &[9.0, 10.0]);
        assert_eq!(c, &[1.0, 2.0]);
        a[0] = 99.0;
        assert_eq!(b.node(2)[1], 99.0);
    }

    #[test]
    fn tri_windows_disjoint_borrow_all_orders() {
        for (a, b, c) in [(0usize, 1usize, 2usize), (2, 0, 1), (1, 2, 0), (2, 1, 0)] {
            let mut buf = UnboundBuffer::from_fn(4, 4, |n, i| (n * 4 + i) as f32);
            let (sa, sb, sc) = buf.tri_windows_mut(a, b, c, Window::new(1, 2));
            assert_eq!(sa[0], (a * 4 + 1) as f32, "({a},{b},{c})");
            assert_eq!(sb[0], (b * 4 + 1) as f32, "({a},{b},{c})");
            assert_eq!(sc[0], (c * 4 + 1) as f32, "({a},{b},{c})");
            sb[1] = -5.0;
            assert_eq!(buf.node(b)[2], -5.0);
        }
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_window_rejected() {
        let mut b = UnboundBuffer::from_fn(2, 8, |_, _| 0.0);
        b.register(Window::new(5, 10));
    }

    #[test]
    fn rail_views_are_disjoint_and_translate_globals() {
        let mut b = UnboundBuffer::from_fn(3, 12, |n, i| (n * 12 + i) as f32);
        let windows = [Window::new(0, 5), Window::new(5, 0), Window::new(5, 7)];
        let mut views = b.rail_views(&windows);
        assert_eq!(views.len(), 3);
        assert_eq!(views[1].nodes(), 3);
        // global-coordinate access through the trait
        assert_eq!(views[0].window(1, Window::new(2, 2)), &[14.0, 15.0]);
        assert_eq!(views[2].window(2, Window::new(6, 3)), &[30.0, 31.0, 32.0]);
        // mutations land in the right global positions
        views[2].window_mut(0, Window::new(5, 1))[0] = -1.0;
        let (x, y) = views[0].pair_windows_mut(2, 0, Window::new(1, 2));
        assert_eq!(x, &[25.0, 26.0]);
        assert_eq!(y, &[1.0, 2.0]);
        x[0] = 99.0;
        drop(views);
        assert_eq!(b.node(0)[5], -1.0);
        assert_eq!(b.node(2)[1], 99.0);
    }

    #[test]
    fn rail_view_tri_borrow_matches_buffer() {
        let mut a = UnboundBuffer::from_fn(4, 10, |n, i| (n * 10 + i) as f32);
        let mut b = UnboundBuffer::from_fn(4, 10, |n, i| (n * 10 + i) as f32);
        let w = Window::new(4, 3);
        {
            let mut views = a.rail_views(&[Window::new(2, 8)]);
            let (x, y, z) = views[0].tri_windows_mut(3, 1, 2, w);
            x[0] += 1.0;
            y[1] += 2.0;
            z[2] += 3.0;
        }
        {
            let (x, y, z) = b.tri_windows_mut(3, 1, 2, w);
            x[0] += 1.0;
            y[1] += 2.0;
            z[2] += 3.0;
        }
        for n in 0..4 {
            assert_eq!(a.node(n), b.node(n), "node {n}");
        }
    }

    #[test]
    #[should_panic]
    fn rail_views_reject_overlap() {
        let mut b = UnboundBuffer::from_fn(2, 8, |_, _| 0.0);
        let _ = b.rail_views(&[Window::new(0, 5), Window::new(4, 4)]);
    }

    #[test]
    fn pool_recycles_and_restores_bit_identical() {
        let fill = |n: usize, i: usize| ((n * 3 + i) % 7) as f32 * 0.5;
        let mut pool = BufferPool::new();
        let mut b1 = pool.acquire(3, 16, fill);
        let fresh = UnboundBuffer::from_fn(3, 16, fill);
        for n in 0..3 {
            assert_eq!(b1.node(n), fresh.node(n));
        }
        // dirty the buffer (as an allreduce would), return it, re-acquire
        b1.node_mut(0)[0] = 1234.0;
        b1.register(Window::new(0, 4));
        pool.release(b1);
        assert_eq!(pool.pooled(), 1);
        let b2 = pool.acquire(3, 16, fill);
        assert_eq!(pool.pooled(), 0, "recycled, not re-allocated");
        for n in 0..3 {
            assert_eq!(b2.node(n), fresh.node(n), "restore not bit-identical");
        }
        assert!(b2.all_complete(), "pending state must be cleared");
    }

    #[test]
    fn pool_distinguishes_fill_functions() {
        let mut pool = BufferPool::new();
        let a = pool.acquire(2, 8, |_, i| i as f32);
        pool.release(a);
        // same shape, different fill: the probe mismatch forces a fresh
        // template rather than serving stale contents
        let b = pool.acquire(2, 8, |_, i| -(i as f32));
        assert_eq!(b.node(0)[3], -3.0);
    }
}
