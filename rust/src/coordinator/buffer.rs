//! Cross-protocol shared buffer (paper §3.2).
//!
//! Data to be allreduced is staged in an `UnboundBuffer`; each member
//! network receives a `(ptr, data_length)` window — here a typed
//! [`Window`] — reads its slice, processes it, and returns results in
//! place. Once every window completes, the buffer releases the data to the
//! requester. The window arithmetic below is exactly what the Load
//! Balancer's pointer calculation (§3.5) produces and what failover hands
//! between rails (§4.4).

/// A `(ptr, data_length)` view into the shared buffer, in f32 elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    pub offset: usize,
    pub len: usize,
}

impl Window {
    pub fn new(offset: usize, len: usize) -> Window {
        Window { offset, len }
    }

    pub fn bytes(&self) -> u64 {
        self.len as u64 * 4
    }

    pub fn end(&self) -> usize {
        self.offset + self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Split this window into `parts` contiguous sub-windows proportional
    /// to `fractions` (which must sum to ~1). Every element lands in
    /// exactly one sub-window; rounding drift is absorbed by the last part.
    pub fn split_fractions(&self, fractions: &[f64]) -> Vec<Window> {
        assert!(!fractions.is_empty());
        let mut out = Vec::with_capacity(fractions.len());
        let mut off = self.offset;
        for (i, &f) in fractions.iter().enumerate() {
            let len = if i + 1 == fractions.len() {
                self.end() - off
            } else {
                ((self.len as f64 * f).round() as usize).min(self.end() - off)
            };
            out.push(Window::new(off, len));
            off += len;
        }
        debug_assert_eq!(out.last().unwrap().end(), self.end());
        out
    }

    /// Split into fixed-size chunks (the ring-chunked pipeline and MPTCP's
    /// packet slicing both use this).
    pub fn split_chunks(&self, chunk_elems: usize) -> Vec<Window> {
        assert!(chunk_elems > 0);
        let mut out = Vec::new();
        let mut off = self.offset;
        while off < self.end() {
            let len = chunk_elems.min(self.end() - off);
            out.push(Window::new(off, len));
            off += len;
        }
        if out.is_empty() {
            out.push(*self);
        }
        out
    }
}

/// The staging buffer shared by all member networks: one payload slice per
/// node (the in-process stand-in for each node's pinned gradient buffer).
#[derive(Debug)]
pub struct UnboundBuffer {
    /// data[node] — all nodes' payloads, equal length.
    data: Vec<Vec<f32>>,
    /// Completion mask per registered window.
    pending: Vec<(Window, bool)>,
}

impl UnboundBuffer {
    pub fn new(data: Vec<Vec<f32>>) -> UnboundBuffer {
        assert!(!data.is_empty());
        let len = data[0].len();
        assert!(data.iter().all(|d| d.len() == len), "ragged node buffers");
        UnboundBuffer { data, pending: Vec::new() }
    }

    pub fn from_fn(nodes: usize, len: usize, f: impl Fn(usize, usize) -> f32) -> UnboundBuffer {
        UnboundBuffer::new(
            (0..nodes)
                .map(|n| (0..len).map(|i| f(n, i)).collect())
                .collect(),
        )
    }

    pub fn nodes(&self) -> usize {
        self.data.len()
    }

    pub fn len(&self) -> usize {
        self.data[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn full_window(&self) -> Window {
        Window::new(0, self.len())
    }

    /// Register a window a member network is responsible for.
    pub fn register(&mut self, w: Window) {
        assert!(w.end() <= self.len(), "window out of bounds");
        self.pending.push((w, false));
    }

    pub fn complete(&mut self, w: Window) {
        for (pw, done) in &mut self.pending {
            if *pw == w {
                *done = true;
                return;
            }
        }
        panic!("completing unregistered window {w:?}");
    }

    /// All registered windows done — data may be released to the requester.
    pub fn all_complete(&self) -> bool {
        self.pending.iter().all(|(_, d)| *d)
    }

    pub fn clear_pending(&mut self) {
        self.pending.clear();
    }

    pub fn node(&self, n: usize) -> &[f32] {
        &self.data[n]
    }

    pub fn node_mut(&mut self, n: usize) -> &mut [f32] {
        &mut self.data[n]
    }

    /// Borrow two nodes' windows simultaneously (ring-step exchange).
    pub fn pair_windows_mut(
        &mut self,
        a: usize,
        b: usize,
        w: Window,
    ) -> (&mut [f32], &mut [f32]) {
        assert_ne!(a, b);
        let (lo, hi, swap) = if a < b { (a, b, false) } else { (b, a, true) };
        let (left, right) = self.data.split_at_mut(hi);
        let sa = &mut left[lo][w.offset..w.end()];
        let sb = &mut right[0][w.offset..w.end()];
        if swap { (sb, sa) } else { (sa, sb) }
    }

    pub fn into_data(self) -> Vec<Vec<f32>> {
        self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_fractions_covers_exactly() {
        let w = Window::new(10, 1000);
        let parts = w.split_fractions(&[0.3, 0.7]);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].offset, 10);
        assert_eq!(parts[0].len + parts[1].len, 1000);
        assert_eq!(parts[1].end(), 1010);
        // ~30/70 split
        assert!((parts[0].len as f64 - 300.0).abs() <= 1.0);
    }

    #[test]
    fn split_fractions_rounding_edge() {
        let w = Window::new(0, 7);
        let parts = w.split_fractions(&[1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0]);
        assert_eq!(parts.iter().map(|p| p.len).sum::<usize>(), 7);
        assert_eq!(parts[2].end(), 7);
    }

    #[test]
    fn split_chunks() {
        let w = Window::new(4, 10);
        let chunks = w.split_chunks(4);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0], Window::new(4, 4));
        assert_eq!(chunks[2], Window::new(12, 2));
    }

    #[test]
    fn zero_fraction_windows_allowed() {
        let w = Window::new(0, 100);
        let parts = w.split_fractions(&[0.0, 1.0]);
        assert_eq!(parts[0].len, 0);
        assert!(parts[0].is_empty());
        assert_eq!(parts[1].len, 100);
    }

    #[test]
    fn completion_tracking() {
        let mut b = UnboundBuffer::from_fn(2, 8, |n, i| (n * 8 + i) as f32);
        let w1 = Window::new(0, 4);
        let w2 = Window::new(4, 4);
        b.register(w1);
        b.register(w2);
        assert!(!b.all_complete());
        b.complete(w1);
        assert!(!b.all_complete());
        b.complete(w2);
        assert!(b.all_complete());
    }

    #[test]
    fn pair_windows_disjoint_borrow() {
        let mut b = UnboundBuffer::from_fn(3, 4, |n, i| (n * 4 + i) as f32);
        let (a, c) = b.pair_windows_mut(2, 0, Window::new(1, 2));
        assert_eq!(a, &[9.0, 10.0]);
        assert_eq!(c, &[1.0, 2.0]);
        a[0] = 99.0;
        assert_eq!(b.node(2)[1], 99.0);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_window_rejected() {
        let mut b = UnboundBuffer::from_fn(2, 8, |_, _| 0.0);
        b.register(Window::new(5, 10));
    }
}
