//! Data-plane integrity: window checksums and poison containment.
//!
//! The corruption hazard family ([`crate::net::fault::CorruptSchedule`])
//! models silent wire corruption — the one fault class latency- and
//! retry-based detectors cannot see. The defense is a per-window checksum
//! computed on send and verified on merge by every collective core:
//!
//! * **Integrity ON** (default): every corrupted delivery is caught by the
//!   wire checksum inside the timer layer and recharged as a retransmit on
//!   the unified retry ledger (same accounting path as loss), so a
//!   persistently-corrupting rail raises `HealthMonitor` suspicion and
//!   walks the existing Healthy → Degraded → Quarantined → Probation
//!   machine. The cores' send/verify checksum passes here are the *real
//!   compute* whose clean-path overhead `BENCH_hotpath.json` records; the
//!   merge-side verify doubles as a §4.4 atomicity guard (the timing phase
//!   must never touch payload).
//! * **Integrity OFF** (ablation): corrupted deliveries arrive silently and
//!   are queued as pending poison on the rail context; the cores drain the
//!   queue between timing and numerics and flip payload bits
//!   deterministically, so the corruption reaches the reduction and the
//!   fault-free-twin comparison measures the escape rate.
//!
//! The checksum is 64-bit FNV-1a over the window's `f32::to_bits` words.
//! For equal-length windows every absorb step `h -> (h ^ w) * p` is a
//! bijection in `h` (odd prime, invertible mod 2^64) and in `w`, so two
//! windows differing in exactly one word — in particular by any single bit
//! flip — hash differently. That detection guarantee is property-tested up
//! to 64 MiB windows.

use crate::coordinator::buffer::{NodeWindows, Window};
use crate::net::simnet::RailTimer;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// 64-bit FNV-1a over the slice's `f32::to_bits` words. Detects every
/// single-bit flip between equal-length slices (see module docs).
pub fn checksum(data: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for &x in data {
        h = (h ^ x.to_bits() as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Send-side checksum of window `w` across every node's payload: the
/// per-node sums are absorbed in node order, so any single-bit flip in any
/// node's window changes the result.
pub fn window_checksum<V: NodeWindows + ?Sized>(buf: &V, w: Window) -> u64 {
    let mut h = FNV_OFFSET;
    for n in 0..buf.nodes() {
        h = (h ^ checksum(buf.window(n, w))).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Merge-side verification: the pre-reduction payload must hash to the
/// send-side checksum. With integrity on this cannot fail in-model (every
/// detected corruption was already recharged on the wire), so a mismatch
/// here means the timing phase mutated payload — a §4.4 atomicity
/// violation worth crashing on in any build.
pub fn verify_window<V: NodeWindows + ?Sized>(buf: &V, w: Window, sent: u64) {
    let got = window_checksum(buf, w);
    assert_eq!(
        got, sent,
        "integrity violation: window payload changed between send and merge"
    );
}

/// The mantissa bit silent poison flips: the top fraction bit, so the
/// upset perturbs any nonzero value by ≥25% of its magnitude and can
/// never round away below the accumulation ulp of a later reduction —
/// escapes stay observable at the fault-free-twin comparison.
const POISON_BIT: u32 = 22;

/// Drain the rail's pending silent-corruption events (nonzero only when
/// fabric integrity is OFF) and apply them to the window as deterministic
/// single-bit flips of [`POISON_BIT`], spread across nodes and elements so
/// repeated events never cancel on the same bit twice in a row. Called by
/// every collective core between timing and numerics, per §4.4: an aborted
/// op has already returned before any poison lands.
pub fn apply_pending_poison<T: RailTimer, V: NodeWindows + ?Sized>(
    t: &mut T,
    buf: &mut V,
    w: Window,
) {
    let events = t.drain_corruption();
    if events == 0 || w.is_empty() {
        return;
    }
    let nodes = buf.nodes();
    for k in 0..events {
        let node = (k as usize) % nodes;
        let idx = (k as usize).wrapping_mul(7919) % w.len;
        let win = buf.window_mut(node, w);
        win[idx] = f32::from_bits(win[idx].to_bits() ^ (1 << POISON_BIT));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::buffer::UnboundBuffer;

    #[test]
    fn checksum_detects_any_single_bit_flip() {
        let data: Vec<f32> = (0..257).map(|i| (i % 13 + 1) as f32).collect();
        let base = checksum(&data);
        for elem in [0, 1, 100, 256] {
            for bit in [0u32, 1, 7, 22, 31] {
                let mut d = data.clone();
                d[elem] = f32::from_bits(d[elem].to_bits() ^ (1 << bit));
                assert_ne!(checksum(&d), base, "flip elem {elem} bit {bit} undetected");
            }
        }
    }

    #[test]
    fn checksum_is_length_and_position_sensitive() {
        assert_ne!(checksum(&[1.0, 2.0]), checksum(&[2.0, 1.0]));
        assert_ne!(checksum(&[1.0]), checksum(&[1.0, 1.0]));
        assert_eq!(checksum(&[]), FNV_OFFSET);
    }

    #[test]
    fn window_checksum_covers_every_node() {
        let mk = || UnboundBuffer::from_fn(4, 32, |n, i| ((n + 1) * (i % 13 + 1)) as f32);
        let a = mk();
        let w = a.full_window();
        let base = window_checksum(&a, w);
        for node in 0..4 {
            let mut b = mk();
            let v = b.node_mut(node)[17];
            b.node_mut(node)[17] = f32::from_bits(v.to_bits() ^ 1);
            assert_ne!(window_checksum(&b, w), base, "node {node} flip undetected");
        }
        // outside the window: invisible
        let mut c = mk();
        let sub = Window::new(0, 16);
        let subsum = window_checksum(&c, sub);
        c.node_mut(0)[20] = 999.0;
        assert_eq!(window_checksum(&c, sub), subsum);
    }
}
