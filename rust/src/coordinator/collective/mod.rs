//! Collective Operations Module (paper §3.4).
//!
//! Allreduce implementations over one rail of the fabric. Payload numerics
//! are real (the reduction actually executes, by default through the
//! portable [`RustReducer`], or through the AOT-compiled Pallas reduce
//! kernel via [`crate::runtime::PjrtReducer`]); completion time comes from
//! the fabric's calibrated protocol models.
//!
//! `elem_bytes` decouples modeled wire bytes from in-memory payload size so
//! large-payload *timing* sweeps (benches) can run on small real buffers;
//! the default of 4.0 (f32) keeps time and data exactly coupled.

pub mod integrity;
pub mod reducer;
pub mod ring;
pub mod tree;

pub use integrity::{checksum, window_checksum};
pub use reducer::{Reducer, RustReducer};
pub use ring::{ring_allreduce, ring_chunked_allreduce};
pub use tree::tree_allreduce;

use crate::coordinator::buffer::{NodeWindows, UnboundBuffer, Window};
use crate::net::protocol::CollectiveKind;
use crate::net::simnet::{Fabric, RailDown, RailTimer};

/// Outcome of one collective operation on one rail.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpOutcome {
    /// Modeled completion time (us).
    pub time_us: f64,
    /// Modeled bytes this rail moved per node.
    pub bytes_moved: u64,
    /// Number of lockstep communication rounds.
    pub steps: usize,
}

/// Which allreduce algorithm to run on ring-capable rails.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    Ring,
    /// Gloo's Ring_Chunked: segments pipelined in `chunk_elems` chunks.
    RingChunked { chunk_elems: usize },
}

/// Reusable scratch for one rail-collective execution: ring segment
/// windows, chunk windows and the tree switch-aggregation buffer. The
/// coordinator owns one instance and threads it through every op, so the
/// steady-state collective path performs no per-op allocation; the
/// scratch-free public wrappers (tests, examples, replays) create a
/// throwaway instance instead.
#[derive(Debug, Default, Clone)]
pub struct OpScratch {
    /// Ring segment windows (one per node).
    pub segs: Vec<Window>,
    /// Chunk windows for chunked/pipelined schedules.
    pub chunks: Vec<Window>,
    /// Tree (SHARP) switch-aggregation buffer.
    pub agg: Vec<f32>,
}

/// Run the native collective for `rail` (tree for SHARP, ring otherwise)
/// on `buf[w]`, reducing across all nodes.
pub fn run_allreduce(
    algo: Algo,
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    run_allreduce_with(algo, fab, rail, buf, w, red, elem_bytes, &mut scratch)
}

/// Scratch-reuse form of [`run_allreduce`] — the coordinator's per-op
/// path.
#[allow(clippy::too_many_arguments)]
pub fn run_allreduce_with(
    algo: Algo,
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    run_allreduce_on(algo, &mut fab.rail_ctx(rail), buf, w, red, elem_bytes, scratch)
}

/// The generic core of the fixed dispatch: the rail's native collective
/// (tree for SHARP, the forced ring variant otherwise) over any
/// ([`RailTimer`], [`NodeWindows`]) pair — shared by the serial path and
/// the parallel executor's worker threads.
pub fn run_allreduce_on<T: RailTimer, V: NodeWindows + ?Sized>(
    algo: Algo,
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    if w.is_empty() {
        return Ok(OpOutcome::default());
    }
    match t.collective_kind() {
        CollectiveKind::Tree => tree::tree_allreduce_on(t, buf, w, red, elem_bytes, scratch),
        CollectiveKind::Ring => match algo {
            Algo::Ring => ring::ring_allreduce_on(t, buf, w, red, elem_bytes, scratch),
            Algo::RingChunked { chunk_elems } => ring::ring_chunked_allreduce_on(
                t, buf, w, red, elem_bytes, chunk_elems, scratch,
            ),
        },
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::ProtoKind;
    use crate::net::topology::ClusterSpec;

    pub fn fabric(nodes: usize, kinds: &[ProtoKind]) -> Fabric {
        let rails = ClusterSpec::local().build_rails(kinds).unwrap();
        Fabric::new(nodes, rails, CpuPool::default(), 9).deterministic()
    }

    /// Node n's element i starts as n+1 scaled pattern; expected reduced
    /// value at i = sum over nodes.
    pub fn make_buf(nodes: usize, len: usize) -> (UnboundBuffer, Vec<f32>) {
        let buf = UnboundBuffer::from_fn(nodes, len, |n, i| ((n + 1) * (i % 13 + 1)) as f32);
        let expect: Vec<f32> = (0..len)
            .map(|i| (1..=nodes).map(|n| (n * (i % 13 + 1)) as f32).sum())
            .collect();
        (buf, expect)
    }

    pub fn assert_reduced(buf: &UnboundBuffer, w: Window, expect: &[f32]) {
        for n in 0..buf.nodes() {
            for i in w.offset..w.end() {
                assert_eq!(
                    buf.node(n)[i],
                    expect[i],
                    "node {n} elem {i}"
                );
            }
        }
    }
}
