//! Reduction backends: the compute core of every allreduce step.
//!
//! [`RustReducer`] is the portable hot-path implementation (auto-vectorized
//! slice add). The PJRT-backed reducer executing the AOT-compiled Pallas
//! `add_pair` kernel lives in [`crate::runtime::PjrtReducer`] so the `net`/
//! `coordinator` layers stay usable without artifacts.
//!
//! The kernels are width-parameterized ([`add_into_lanes`],
//! [`reduce_copy_lanes`]): the exact-size inner block is a const-generic
//! `W`-lane unroll, so the hot-path bench can sweep 8/16/32 lanes on the
//! build machine (`kernel_width_sweep` in `BENCH_hotpath.json`) and the
//! shipped width ([`KERNEL_LANES`]) is the swept winner rather than a
//! guess. 16 lanes lets LLVM emit two full 256-bit (or one 512-bit)
//! packed-add chains per iteration with no bounds checks in the body —
//! ahead of the seed's 8-lane unroll on AVX2-class hardware, while 32
//! starts to spill on narrower machines; the sweep records all three.

/// Unroll width of the shipped reduction kernels (f32 lanes per exact-size
/// block). Chosen by the `kernel_width_sweep` recorded in
/// `BENCH_hotpath.json`.
pub const KERNEL_LANES: usize = 16;

/// `dst += src` with a `W`-lane exact-size unroll body plus scalar tail.
/// Results are bit-identical for every `W` (same per-element f32 adds in
/// the same order); only the instruction mix changes.
#[inline]
pub fn add_into_lanes<const W: usize>(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let (dc, dr) = dst.split_at_mut(n - n % W);
    let (sc, sr) = src.split_at(n - n % W);
    for (dw, sw) in dc.chunks_exact_mut(W).zip(sc.chunks_exact(W)) {
        for k in 0..W {
            dw[k] += sw[k];
        }
    }
    for (d, s) in dr.iter_mut().zip(sr) {
        *d += s;
    }
}

/// Fused `dst += src; fwd = dst` single pass with a `W`-lane unroll —
/// bit-identical to [`add_into_lanes`] followed by a copy, in one read-
/// modify-write sweep over memory.
#[inline]
pub fn reduce_copy_lanes<const W: usize>(dst: &mut [f32], src: &[f32], fwd: &mut [f32]) {
    assert_eq!(dst.len(), src.len());
    assert_eq!(dst.len(), fwd.len());
    let n = dst.len();
    let (dc, dr) = dst.split_at_mut(n - n % W);
    let (sc, sr) = src.split_at(n - n % W);
    let (fc, fr) = fwd.split_at_mut(n - n % W);
    for ((dw, sw), fw) in dc
        .chunks_exact_mut(W)
        .zip(sc.chunks_exact(W))
        .zip(fc.chunks_exact_mut(W))
    {
        for k in 0..W {
            dw[k] += sw[k];
            fw[k] = dw[k];
        }
    }
    for ((d, s), fo) in dr.iter_mut().zip(sr).zip(fr) {
        *d += s;
        *fo = *d;
    }
}

/// Elementwise accumulate: `dst += src`.
pub trait Reducer {
    fn add_into(&mut self, dst: &mut [f32], src: &[f32]);

    /// n-way accumulate used by the in-network (SHARP) path:
    /// `dst = sum(srcs)`. Default: fold of pairwise adds.
    fn reduce_n(&mut self, dst: &mut [f32], srcs: &[&[f32]]) {
        if let Some((first, rest)) = srcs.split_first() {
            dst.copy_from_slice(first);
            for s in rest {
                self.add_into(dst, s);
            }
        }
    }

    /// Fused reduce + forward: `dst += src` AND `fwd = dst` (the updated
    /// values). The ring's final reduce-scatter hop and first allgather
    /// hop collapse into this single pass over memory where the three
    /// windows are distinct. The default is the safe two-pass form —
    /// results are bit-identical either way, so backends may fuse freely.
    fn reduce_copy(&mut self, dst: &mut [f32], src: &[f32], fwd: &mut [f32]) {
        self.add_into(dst, src);
        fwd.copy_from_slice(dst);
    }

    /// An independent, `Send` clone of this reducer for a parallel-
    /// executor worker thread, or `None` when the backend holds state
    /// that cannot be shared (the coordinator then falls back to serial
    /// execution for the op). Forks must be numerically identical to the
    /// parent — the parallel/serial bit-identity guarantee depends on it.
    fn fork(&self) -> Option<Box<dyn Reducer + Send>> {
        None
    }

    fn name(&self) -> &'static str;
}

/// Portable reducer: width-parameterized exact-size loops the compiler
/// auto-vectorizes (see [`KERNEL_LANES`]).
#[derive(Debug, Default, Clone)]
pub struct RustReducer;

impl Reducer for RustReducer {
    #[inline]
    fn add_into(&mut self, dst: &mut [f32], src: &[f32]) {
        add_into_lanes::<KERNEL_LANES>(dst, src);
    }

    /// Truly fused single pass: one load of `src`, one read-modify-write
    /// of `dst`, one store to `fwd`.
    fn reduce_copy(&mut self, dst: &mut [f32], src: &[f32], fwd: &mut [f32]) {
        reduce_copy_lanes::<KERNEL_LANES>(dst, src, fwd);
    }

    fn fork(&self) -> Option<Box<dyn Reducer + Send>> {
        Some(Box::new(RustReducer))
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_into_matches_scalar() {
        let mut r = RustReducer;
        let mut dst: Vec<f32> = (0..1003).map(|i| i as f32).collect();
        let src: Vec<f32> = (0..1003).map(|i| (i * 2) as f32).collect();
        let expect: Vec<f32> = (0..1003).map(|i| (i * 3) as f32).collect();
        r.add_into(&mut dst, &src);
        assert_eq!(dst, expect);
    }

    #[test]
    fn reduce_n_matches_fold() {
        let mut r = RustReducer;
        let a: Vec<f32> = (0..77).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..77).map(|i| (i + 1) as f32).collect();
        let c: Vec<f32> = (0..77).map(|i| (i + 2) as f32).collect();
        let mut dst = vec![0.0; 77];
        r.reduce_n(&mut dst, &[&a, &b, &c]);
        for i in 0..77 {
            assert_eq!(dst[i], (3 * i + 3) as f32);
        }
    }

    #[test]
    fn empty_slices_ok() {
        let mut r = RustReducer;
        let mut dst: Vec<f32> = vec![];
        r.add_into(&mut dst, &[]);
        r.reduce_n(&mut dst, &[]);
        r.reduce_copy(&mut dst, &[], &mut []);
    }

    #[test]
    fn reduce_copy_matches_add_then_copy() {
        // fused vs two-pass, including non-multiple-of-width tails
        for len in [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 32, 33, 64, 1003] {
            let mut r = RustReducer;
            let src: Vec<f32> = (0..len).map(|i| (i % 19) as f32 * 0.25).collect();
            let mut d_fused: Vec<f32> = (0..len).map(|i| (i % 11) as f32).collect();
            let mut d_plain = d_fused.clone();
            let mut fwd = vec![0.0f32; len];
            r.reduce_copy(&mut d_fused, &src, &mut fwd);
            r.add_into(&mut d_plain, &src);
            assert_eq!(d_fused, d_plain, "len {len}");
            assert_eq!(fwd, d_plain, "len {len}: forward copy diverged");
        }
    }

    #[test]
    fn all_widths_bit_identical() {
        // the sweep's promise: width changes instruction mix, never values
        for len in [0usize, 1, 7, 15, 16, 17, 33, 255, 1003] {
            let src: Vec<f32> = (0..len).map(|i| (i % 23) as f32 * 0.125 - 1.0).collect();
            let base: Vec<f32> = (0..len).map(|i| (i % 13) as f32 * 0.5).collect();
            let mut d8 = base.clone();
            let mut d16 = base.clone();
            let mut d32 = base.clone();
            add_into_lanes::<8>(&mut d8, &src);
            add_into_lanes::<16>(&mut d16, &src);
            add_into_lanes::<32>(&mut d32, &src);
            assert_eq!(d8, d16, "len {len}: 8 vs 16");
            assert_eq!(d8, d32, "len {len}: 8 vs 32");
            let mut f8 = vec![0.0f32; len];
            let mut f32buf = vec![0.0f32; len];
            let mut e8 = base.clone();
            let mut e32 = base.clone();
            reduce_copy_lanes::<8>(&mut e8, &src, &mut f8);
            reduce_copy_lanes::<32>(&mut e32, &src, &mut f32buf);
            assert_eq!(e8, e32, "len {len}: fused 8 vs 32");
            assert_eq!(f8, f32buf, "len {len}: forwarded 8 vs 32");
        }
    }

    #[test]
    fn fork_is_numerically_identical() {
        let mut parent = RustReducer;
        let mut fork = parent.fork().expect("RustReducer forks");
        let mut a: Vec<f32> = (0..257).map(|i| i as f32 * 0.5).collect();
        let mut b = a.clone();
        let src: Vec<f32> = (0..257).map(|i| (i % 7) as f32).collect();
        parent.add_into(&mut a, &src);
        fork.add_into(&mut b, &src);
        assert_eq!(a, b);
        assert_eq!(fork.name(), "rust");
    }
}
