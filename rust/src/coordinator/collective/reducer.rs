//! Reduction backends: the compute core of every allreduce step.
//!
//! [`RustReducer`] is the portable hot-path implementation (auto-vectorized
//! slice add). The PJRT-backed reducer executing the AOT-compiled Pallas
//! `add_pair` kernel lives in [`crate::runtime::PjrtReducer`] so the `net`/
//! `coordinator` layers stay usable without artifacts.

/// Elementwise accumulate: `dst += src`.
pub trait Reducer {
    fn add_into(&mut self, dst: &mut [f32], src: &[f32]);

    /// n-way accumulate used by the in-network (SHARP) path:
    /// `dst = sum(srcs)`. Default: fold of pairwise adds.
    fn reduce_n(&mut self, dst: &mut [f32], srcs: &[&[f32]]) {
        if let Some((first, rest)) = srcs.split_first() {
            dst.copy_from_slice(first);
            for s in rest {
                self.add_into(dst, s);
            }
        }
    }

    /// Fused reduce + forward: `dst += src` AND `fwd = dst` (the updated
    /// values). The ring's final reduce-scatter hop and first allgather
    /// hop collapse into this single pass over memory where the three
    /// windows are distinct. The default is the safe two-pass form —
    /// results are bit-identical either way, so backends may fuse freely.
    fn reduce_copy(&mut self, dst: &mut [f32], src: &[f32], fwd: &mut [f32]) {
        self.add_into(dst, src);
        fwd.copy_from_slice(dst);
    }

    fn name(&self) -> &'static str;
}

/// Portable reducer: a plain indexed loop the compiler auto-vectorizes.
#[derive(Debug, Default, Clone)]
pub struct RustReducer;

impl Reducer for RustReducer {
    #[inline]
    fn add_into(&mut self, dst: &mut [f32], src: &[f32]) {
        assert_eq!(dst.len(), src.len());
        // chunked exact-size loop: lets LLVM emit packed adds without
        // bounds checks in the body
        let n = dst.len();
        let (dc, dr) = dst.split_at_mut(n - n % 8);
        let (sc, sr) = src.split_at(n - n % 8);
        for (d8, s8) in dc.chunks_exact_mut(8).zip(sc.chunks_exact(8)) {
            for k in 0..8 {
                d8[k] += s8[k];
            }
        }
        for (d, s) in dr.iter_mut().zip(sr) {
            *d += s;
        }
    }

    /// Truly fused single pass: one load of `src`, one read-modify-write
    /// of `dst`, one store to `fwd` — same chunked exact-size shape as
    /// `add_into` so LLVM emits packed adds without bounds checks.
    fn reduce_copy(&mut self, dst: &mut [f32], src: &[f32], fwd: &mut [f32]) {
        assert_eq!(dst.len(), src.len());
        assert_eq!(dst.len(), fwd.len());
        let n = dst.len();
        let (dc, dr) = dst.split_at_mut(n - n % 8);
        let (sc, sr) = src.split_at(n - n % 8);
        let (fc, fr) = fwd.split_at_mut(n - n % 8);
        for ((d8, s8), f8) in dc
            .chunks_exact_mut(8)
            .zip(sc.chunks_exact(8))
            .zip(fc.chunks_exact_mut(8))
        {
            for k in 0..8 {
                d8[k] += s8[k];
                f8[k] = d8[k];
            }
        }
        for ((d, s), fo) in dr.iter_mut().zip(sr).zip(fr) {
            *d += s;
            *fo = *d;
        }
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_into_matches_scalar() {
        let mut r = RustReducer;
        let mut dst: Vec<f32> = (0..1003).map(|i| i as f32).collect();
        let src: Vec<f32> = (0..1003).map(|i| (i * 2) as f32).collect();
        let expect: Vec<f32> = (0..1003).map(|i| (i * 3) as f32).collect();
        r.add_into(&mut dst, &src);
        assert_eq!(dst, expect);
    }

    #[test]
    fn reduce_n_matches_fold() {
        let mut r = RustReducer;
        let a: Vec<f32> = (0..77).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..77).map(|i| (i + 1) as f32).collect();
        let c: Vec<f32> = (0..77).map(|i| (i + 2) as f32).collect();
        let mut dst = vec![0.0; 77];
        r.reduce_n(&mut dst, &[&a, &b, &c]);
        for i in 0..77 {
            assert_eq!(dst[i], (3 * i + 3) as f32);
        }
    }

    #[test]
    fn empty_slices_ok() {
        let mut r = RustReducer;
        let mut dst: Vec<f32> = vec![];
        r.add_into(&mut dst, &[]);
        r.reduce_n(&mut dst, &[]);
        r.reduce_copy(&mut dst, &[], &mut []);
    }

    #[test]
    fn reduce_copy_matches_add_then_copy() {
        // fused vs two-pass, including non-multiple-of-8 tails
        for len in [0usize, 1, 7, 8, 9, 64, 1003] {
            let mut r = RustReducer;
            let src: Vec<f32> = (0..len).map(|i| (i % 19) as f32 * 0.25).collect();
            let mut d_fused: Vec<f32> = (0..len).map(|i| (i % 11) as f32).collect();
            let mut d_plain = d_fused.clone();
            let mut fwd = vec![0.0f32; len];
            r.reduce_copy(&mut d_fused, &src, &mut fwd);
            r.add_into(&mut d_plain, &src);
            assert_eq!(d_fused, d_plain, "len {len}");
            assert_eq!(fwd, d_plain, "len {len}: forward copy diverged");
        }
    }
}
