//! Ring and Ring_Chunked allreduce (paper §5.3.4, Fig. 18/19 algorithms).
//!
//! Classic bandwidth-optimal ring: the window is split into N segments;
//! N-1 reduce-scatter rounds accumulate each segment at one node, N-1
//! allgather rounds circulate the results. Communication volume per node is
//! `2(N-1)/N * S` (paper Eq. 1).
//!
//! Ring_Chunked (Gloo's recommended variant for large payloads) splits the
//! window into chunks and pipelines them through the ring, trading more
//! rounds for smaller per-round messages — which also keeps per-message
//! sizes below NIC-crashing thresholds (the paper's >1 GB segfault).
//!
//! Every collective here has a generic `*_on` core over
//! ([`RailTimer`], [`NodeWindows`]): the serial coordinator path drives it
//! through a throwaway [`crate::net::simnet::RailCtx`] on the full
//! [`UnboundBuffer`], the parallel executor through a long-lived worker
//! `RailCtx` on a disjoint [`crate::coordinator::buffer::RailView`] — one
//! implementation, so the two paths cannot diverge.

use crate::coordinator::buffer::{NodeWindows, UnboundBuffer, Window};
use crate::coordinator::collective::integrity;
use crate::coordinator::collective::reducer::Reducer;
use crate::coordinator::collective::{OpOutcome, OpScratch};
use crate::net::simnet::{Fabric, RailDown, RailTimer};

/// Pure data movement of a ring allreduce over `w` (no timing): real
/// reduce-scatter + allgather across the node buffers. Convenience
/// wrapper over [`ring_numerics_segs`] that computes the segment split
/// itself (allocating); hot paths precompute segments into reusable
/// scratch via [`Window::split_uniform_into`].
pub fn ring_numerics(
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
) {
    let mut segs = Vec::new();
    w.split_uniform_into(buf.nodes(), &mut segs);
    ring_numerics_segs(buf, &segs, red);
}

/// Ring numerics over precomputed segments (one per node, from
/// [`Window::split_uniform_into`]) — the allocation-free core, generic
/// over the buffer access so full buffers and disjoint per-rail views run
/// the identical exchange. When `n ≥ 3` the final reduce-scatter hop is
/// fused with the first allgather hop through [`Reducer::reduce_copy`]:
/// the completed segment sum is forwarded to the next ring neighbour in
/// the same pass over memory. Results are bit-identical to the unfused
/// two-pass form.
pub fn ring_numerics_segs<V: NodeWindows + ?Sized>(
    buf: &mut V,
    segs: &[Window],
    red: &mut dyn Reducer,
) {
    let n = buf.nodes();
    if n < 2 {
        return;
    }
    debug_assert_eq!(segs.len(), n, "one ring segment per node");
    let fused = n >= 3;
    // reduce-scatter: at step s, segment j flows (j+s)%n -> (j+s+1)%n.
    // The final step lands the complete sum at (j+n-1)%n; sender, receiver
    // and the receiver's successor are pairwise distinct for n >= 3, so
    // that step can forward the sum one hop in the same pass (reduce_copy)
    for s in 0..n - 1 {
        let fuse_step = fused && s + 1 == n - 1;
        for (j, seg) in segs.iter().enumerate() {
            if seg.is_empty() {
                continue;
            }
            let sender = (j + s) % n;
            let receiver = (sender + 1) % n;
            if fuse_step {
                let next = (receiver + 1) % n;
                let (src, dst, fwd) = buf.tri_windows_mut(sender, receiver, next, *seg);
                red.reduce_copy(dst, src, fwd);
            } else {
                let (src, dst) = buf.pair_windows_mut(sender, receiver, *seg);
                red.add_into(dst, src);
            }
        }
    }
    // allgather: segment j is complete at node (j + n - 1) % n; hop 0 was
    // already executed by the fused reduce-scatter pass when n >= 3
    let start = if fused { 1 } else { 0 };
    for s in start..n - 1 {
        for (j, seg) in segs.iter().enumerate() {
            if seg.is_empty() {
                continue;
            }
            let holder = (j + n - 1 + s) % n;
            let receiver = (holder + 1) % n;
            let (src, dst) = buf.pair_windows_mut(holder, receiver, *seg);
            dst.copy_from_slice(src);
        }
    }
}

/// Ring allreduce with modeled lockstep timing.
pub fn ring_allreduce(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    ring_allreduce_with(fab, rail, buf, w, red, elem_bytes, &mut scratch)
}

/// Scratch-reuse form of [`ring_allreduce`].
#[allow(clippy::too_many_arguments)]
pub fn ring_allreduce_with(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    ring_allreduce_on(&mut fab.rail_ctx(rail), buf, w, red, elem_bytes, scratch)
}

/// The generic core of the flat ring (see module docs).
pub fn ring_allreduce_on<T: RailTimer, V: NodeWindows + ?Sized>(
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    let n = t.nodes();
    debug_assert_eq!(buf.nodes(), n);
    let steps = 2 * (n - 1);
    let seg_bytes = (w.len as f64 / n as f64).ceil() * elem_bytes;
    let sent = t.integrity_on().then(|| integrity::window_checksum(buf, w));
    // time first: if the rail dies mid-operation the payload must NOT have
    // been half-reduced (packet-level atomicity, §4.4)
    let mut total = 0.0;
    for _ in 0..steps {
        let dt = t.ring_step(seg_bytes)?;
        total += dt;
    }
    integrity::apply_pending_poison(t, buf, w);
    if let Some(sum) = sent {
        integrity::verify_window(buf, w, sum);
    }
    w.split_uniform_into(n, &mut scratch.segs);
    ring_numerics_segs(buf, &scratch.segs, red);
    Ok(OpOutcome {
        time_us: total,
        bytes_moved: (seg_bytes * steps as f64) as u64,
        steps,
    })
}

/// Pipelined chunked ring: `chunk_elems`-sized chunks stream through the
/// ring back-to-back; total rounds = 2(N-1) + (chunks-1).
pub fn ring_chunked_allreduce(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    chunk_elems: usize,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    ring_chunked_allreduce_with(fab, rail, buf, w, red, elem_bytes, chunk_elems, &mut scratch)
}

/// Scratch-reuse form of [`ring_chunked_allreduce`].
#[allow(clippy::too_many_arguments)]
pub fn ring_chunked_allreduce_with(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    chunk_elems: usize,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    ring_chunked_allreduce_on(
        &mut fab.rail_ctx(rail),
        buf,
        w,
        red,
        elem_bytes,
        chunk_elems,
        scratch,
    )
}

/// The generic core of the chunked ring.
///
/// Byte accounting is per-chunk: the pipeline's critical path is chunk 0's
/// full `2(N-1)` rounds plus one extra round per later chunk, each priced
/// at that chunk's OWN segment size — a window not divisible by the chunk
/// size ends in a smaller chunk, and charging every round at `chunks[0]`
/// overstated both `bytes_moved` and the modeled time. For evenly divided
/// windows the schedule is identical to the uniform pricing.
#[allow(clippy::too_many_arguments)]
pub fn ring_chunked_allreduce_on<T: RailTimer, V: NodeWindows + ?Sized>(
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    chunk_elems: usize,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    let n = t.nodes();
    w.split_chunks_into(chunk_elems.max(1), &mut scratch.chunks);
    let rounds = 2 * (n - 1) + scratch.chunks.len() - 1;
    let seg_bytes = |c: Window| (c.len as f64 / n as f64).ceil() * elem_bytes;
    let sent = t.integrity_on().then(|| integrity::window_checksum(buf, w));
    let mut total = 0.0;
    let mut moved = 0.0;
    let first = seg_bytes(scratch.chunks[0]);
    for _ in 0..2 * (n - 1) {
        total += t.ring_step(first)?;
        moved += first;
    }
    for c in &scratch.chunks[1..] {
        let b = seg_bytes(*c);
        total += t.ring_step(b)?;
        moved += b;
    }
    integrity::apply_pending_poison(t, buf, w);
    if let Some(sum) = sent {
        integrity::verify_window(buf, w, sum);
    }
    for c in &scratch.chunks {
        c.split_uniform_into(n, &mut scratch.segs);
        ring_numerics_segs(buf, &scratch.segs, red);
    }
    Ok(OpOutcome {
        time_us: total,
        bytes_moved: moved as u64,
        steps: rounds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collective::testutil::{assert_reduced, fabric, make_buf};
    use crate::coordinator::collective::RustReducer;
    use crate::net::fault::FaultSchedule;
    use crate::net::protocol::{ProtoKind, MB};

    #[test]
    fn ring_numerics_correct() {
        for nodes in [2, 3, 4, 8] {
            let (mut buf, expect) = make_buf(nodes, 103);
            let w = buf.full_window();
            ring_numerics(&mut buf, w, &mut RustReducer);
            assert_reduced(&buf, w, &expect);
        }
    }

    #[test]
    fn ring_numerics_subwindow_untouched_outside() {
        let (mut buf, expect) = make_buf(4, 64);
        let w = Window::new(16, 32);
        let before0 = buf.node(0)[0];
        ring_numerics(&mut buf, w, &mut RustReducer);
        assert_reduced(&buf, w, &expect);
        assert_eq!(buf.node(0)[0], before0, "outside window modified");
    }

    #[test]
    fn ring_numerics_on_rail_view_matches_full_buffer() {
        // the parallel executor's guarantee at the numerics level: a ring
        // run over a disjoint RailView is bit-identical to the same ring
        // run over the full buffer
        let (mut a, expect) = make_buf(4, 91);
        let (mut b, _) = make_buf(4, 91);
        let w = Window::new(13, 57);
        let mut segs = Vec::new();
        w.split_uniform_into(4, &mut segs);
        ring_numerics_segs(&mut a, &segs, &mut RustReducer);
        {
            let mut views = b.rail_views(&[w]);
            ring_numerics_segs(&mut views[0], &segs, &mut RustReducer);
        }
        assert_reduced(&a, w, &expect);
        for n in 0..4 {
            assert_eq!(a.node(n), b.node(n), "node {n} diverged");
        }
    }

    #[test]
    fn ring_allreduce_times_scale_with_size() {
        let mut fab = fabric(4, &[ProtoKind::Tcp]);
        let (mut b1, _) = make_buf(4, 256);
        let w1 = b1.full_window();
        let t1 = ring_allreduce(&mut fab, 0, &mut b1, w1, &mut RustReducer, 4.0)
            .unwrap()
            .time_us;
        let (mut b2, _) = make_buf(4, 256);
        let w2 = b2.full_window();
        // same real buffer, modeled as 1 MB elements
        let t2 = ring_allreduce(&mut fab, 0, &mut b2, w2, &mut RustReducer, MB / 256.0 * 4.0)
            .unwrap()
            .time_us;
        assert!(t2 > t1);
    }

    #[test]
    fn ring_matches_analytic_estimate() {
        let mut fab = fabric(4, &[ProtoKind::Tcp]);
        let (mut buf, _) = make_buf(4, 2048);
        let w = buf.full_window();
        let est = fab.estimate_allreduce_us(0, 2048.0 * 4.0);
        let got = ring_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, 4.0)
            .unwrap()
            .time_us;
        assert!((got - est).abs() / est < 0.05, "got {got} est {est}");
    }

    #[test]
    fn chunked_has_more_rounds_smaller_messages() {
        let mut fab = fabric(4, &[ProtoKind::Glex]);
        let (mut buf, expect) = make_buf(4, 4096);
        let w = buf.full_window();
        let out =
            ring_chunked_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, 4.0, 512)
                .unwrap();
        assert_eq!(out.steps, 2 * 3 + 8 - 1);
        assert_reduced(&buf, w, &expect);
    }

    #[test]
    fn chunked_beats_plain_for_huge_payload_on_slow_rail() {
        // pipelining amortizes: for large S the per-round message is S/(N*k)
        // and rounds only grow additively
        let mut fab = fabric(8, &[ProtoKind::Tcp]);
        let (mut b1, _) = make_buf(8, 1024);
        let w = b1.full_window();
        let scale = 256.0 * MB / 1024.0; // model 256MB payload
        let plain = ring_allreduce(&mut fab, 0, &mut b1, w, &mut RustReducer, scale)
            .unwrap()
            .time_us;
        let (mut b2, _) = make_buf(8, 1024);
        let chunked = ring_chunked_allreduce(&mut fab, 0, &mut b2, w, &mut RustReducer, scale, 64)
            .unwrap()
            .time_us;
        assert!(chunked < plain, "chunked {chunked} plain {plain}");
    }

    #[test]
    fn fault_aborts_before_numerics() {
        let mut fab =
            fabric(4, &[ProtoKind::Tcp]).with_faults(FaultSchedule::none().with(0, 0.0, 1e9));
        let (mut buf, _) = make_buf(4, 64);
        let w = buf.full_window();
        let orig = buf.node(0).to_vec();
        assert!(ring_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, 4.0).is_err());
        assert_eq!(buf.node(0), &orig[..], "payload mutated despite abort");
    }

    #[test]
    fn two_node_ring() {
        let (mut buf, expect) = make_buf(2, 10);
        let w = buf.full_window();
        ring_numerics(&mut buf, w, &mut RustReducer);
        assert_reduced(&buf, w, &expect);
    }

    #[test]
    fn window_smaller_than_nodes() {
        let (mut buf, expect) = make_buf(8, 3);
        let w = buf.full_window();
        ring_numerics(&mut buf, w, &mut RustReducer);
        assert_reduced(&buf, w, &expect);
    }
}
