//! In-network aggregation allreduce (the SHARP path, paper §2.2.2).
//!
//! Nodes push their window up the switch aggregation tree; the switch
//! reduces on the fly and multicasts the result back down. End-host CPU
//! work is minimal (which is why SHARP's core-scaling curve matters less),
//! and completion time is nearly node-count independent.

use crate::coordinator::buffer::{NodeWindows, UnboundBuffer, Window};
use crate::coordinator::collective::integrity;
use crate::coordinator::collective::reducer::Reducer;
use crate::coordinator::collective::{OpOutcome, OpScratch};
use crate::net::simnet::{Fabric, RailDown, RailTimer};

/// SHARP-style tree allreduce: switch-level aggregation of all node
/// windows, then broadcast of the reduced result.
pub fn tree_allreduce(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    tree_allreduce_with(fab, rail, buf, w, red, elem_bytes, &mut scratch)
}

/// Scratch-reuse form of [`tree_allreduce`]: the switch-aggregation
/// buffer lives in the caller's [`OpScratch`] instead of a per-op `vec!`.
#[allow(clippy::too_many_arguments)]
pub fn tree_allreduce_with(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    tree_allreduce_on(&mut fab.rail_ctx(rail), buf, w, red, elem_bytes, scratch)
}

/// The generic core of the tree allreduce: timing through any
/// [`RailTimer`], numerics over any [`NodeWindows`] buffer (full buffer or
/// a disjoint per-rail view).
pub fn tree_allreduce_on<T: RailTimer, V: NodeWindows + ?Sized>(
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    let bytes = w.len as f64 * elem_bytes;
    let sent = t.integrity_on().then(|| integrity::window_checksum(buf, w));
    // timing first — atomicity on failure (§4.4)
    let time = t.tree_round(bytes)?;
    integrity::apply_pending_poison(t, buf, w);
    if let Some(sum) = sent {
        integrity::verify_window(buf, w, sum);
    }

    // switch aggregation: reduce all node windows into the scratch buffer
    // (copy-then-fold, bit-identical to the Reducer::reduce_n default)...
    let n = buf.nodes();
    let agg = &mut scratch.agg;
    agg.clear();
    agg.extend_from_slice(buf.window(0, w));
    for i in 1..n {
        red.add_into(agg, buf.window(i, w));
    }
    // ...then multicast down-tree
    for i in 0..n {
        buf.window_mut(i, w).copy_from_slice(agg);
    }
    Ok(OpOutcome { time_us: time, bytes_moved: 2 * bytes as u64, steps: 2 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collective::testutil::{assert_reduced, fabric, make_buf};
    use crate::coordinator::collective::RustReducer;
    use crate::net::protocol::{ProtoKind, KB, MB};

    #[test]
    fn tree_numerics_correct() {
        for nodes in [2, 4, 8] {
            let mut fab = fabric(nodes, &[ProtoKind::Tcp, ProtoKind::Sharp]);
            let (mut buf, expect) = make_buf(nodes, 129);
            let w = buf.full_window();
            tree_allreduce(&mut fab, 1, &mut buf, w, &mut RustReducer, 4.0).unwrap();
            assert_reduced(&buf, w, &expect);
        }
    }

    #[test]
    fn tree_time_nearly_node_independent() {
        let t4 = {
            let mut fab = fabric(4, &[ProtoKind::Tcp, ProtoKind::Sharp]);
            let (mut buf, _) = make_buf(4, 64);
            let w = buf.full_window();
            tree_allreduce(&mut fab, 1, &mut buf, w, &mut RustReducer, 8.0 * MB / 64.0)
                .unwrap()
                .time_us
        };
        let t16 = {
            let mut fab = fabric(16, &[ProtoKind::Tcp, ProtoKind::Sharp]);
            let (mut buf, _) = make_buf(16, 64);
            let w = buf.full_window();
            tree_allreduce(&mut fab, 1, &mut buf, w, &mut RustReducer, 8.0 * MB / 64.0)
                .unwrap()
                .time_us
        };
        assert!(t16 / t4 < 1.3, "t4={t4} t16={t16}");
    }

    #[test]
    fn sharp_small_message_latency_is_microseconds() {
        let mut fab = fabric(4, &[ProtoKind::Sharp]);
        let (mut buf, _) = make_buf(4, 256);
        let w = buf.full_window();
        // 1KB modeled payload: paper Table 1 says 9us
        let t = tree_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, KB / 256.0)
            .unwrap()
            .time_us;
        assert!(t < 20.0, "SHARP 1KB latency {t}us");
    }

    #[test]
    fn subwindow_only() {
        let mut fab = fabric(4, &[ProtoKind::Sharp]);
        let (mut buf, expect) = make_buf(4, 100);
        let w = Window::new(10, 50);
        let before = buf.node(2)[5];
        tree_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, 4.0).unwrap();
        assert_reduced(&buf, w, &expect);
        assert_eq!(buf.node(2)[5], before);
    }
}
