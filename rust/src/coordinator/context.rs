//! Context Module (paper §3.2): unified per-protocol communication
//! contexts.
//!
//! Each member network gets a context object owning its private resources:
//! NIC device binding, buffer bookkeeping, and protocol-specific machinery
//! (SHARP's aggregation tree, GLEX's memory-registration table). The
//! [`Context`] trait is the hardware-agnostic abstraction layer the rest
//! of the system programs against.

use crate::net::protocol::{CollectiveKind, ProtoKind};
use crate::net::rail::Rail;

/// Unified interface over TCPContext / SHARPContext / GLEXContext.
pub trait Context: std::fmt::Debug {
    fn kind(&self) -> ProtoKind;
    fn rail_id(&self) -> usize;
    fn collective(&self) -> CollectiveKind;
    /// Transport label used by the rendezvous layer (§3.3).
    fn transport(&self) -> &'static str;
    /// Protocol-specific setup performed when the context joins a
    /// communication domain of `nodes` members.
    fn join_domain(&mut self, nodes: usize);
    fn ready(&self) -> bool;
}

/// Create the right context for a rail (the NIC Selector calls this).
pub fn context_for(rail: &Rail, nodes: usize) -> Box<dyn Context> {
    let mut ctx: Box<dyn Context> = match rail.kind() {
        ProtoKind::Tcp => Box::new(TcpContext::new(rail.id)),
        ProtoKind::Sharp => Box::new(SharpContext::new(rail.id)),
        ProtoKind::Glex => Box::new(GlexContext::new(rail.id)),
    };
    ctx.join_domain(nodes);
    ctx
}

/// Plain TCP sockets context.
#[derive(Debug)]
pub struct TcpContext {
    rail: usize,
    nodes: usize,
}

impl TcpContext {
    pub fn new(rail: usize) -> Self {
        TcpContext { rail, nodes: 0 }
    }
}

impl Context for TcpContext {
    fn kind(&self) -> ProtoKind {
        ProtoKind::Tcp
    }
    fn rail_id(&self) -> usize {
        self.rail
    }
    fn collective(&self) -> CollectiveKind {
        CollectiveKind::Ring
    }
    fn transport(&self) -> &'static str {
        "tcp"
    }
    fn join_domain(&mut self, nodes: usize) {
        self.nodes = nodes;
    }
    fn ready(&self) -> bool {
        self.nodes >= 2
    }
}

/// SHARP context: verifies the collective domain and builds the switch
/// aggregation tree (§3.3: "the ibverbs segment is tailored for SHARP").
#[derive(Debug)]
pub struct SharpContext {
    rail: usize,
    nodes: usize,
    /// Aggregation tree: parent index per node (node 0 is the root's
    /// attachment point; switches are implicit interior vertices).
    pub tree_parent: Vec<Option<usize>>,
}

impl SharpContext {
    pub fn new(rail: usize) -> Self {
        SharpContext { rail, nodes: 0, tree_parent: vec![] }
    }

    /// Binary aggregation tree depth (switch hops one way).
    pub fn tree_depth(&self) -> usize {
        if self.nodes <= 1 {
            0
        } else {
            (usize::BITS - (self.nodes - 1).leading_zeros()) as usize
        }
    }
}

impl Context for SharpContext {
    fn kind(&self) -> ProtoKind {
        ProtoKind::Sharp
    }
    fn rail_id(&self) -> usize {
        self.rail
    }
    fn collective(&self) -> CollectiveKind {
        CollectiveKind::Tree
    }
    fn transport(&self) -> &'static str {
        "ibverbs"
    }
    fn join_domain(&mut self, nodes: usize) {
        self.nodes = nodes;
        // binary reduction tree over node ranks
        self.tree_parent = (0..nodes)
            .map(|i| if i == 0 { None } else { Some((i - 1) / 2) })
            .collect();
    }
    fn ready(&self) -> bool {
        !self.tree_parent.is_empty()
    }
}

/// GLEX context: RDMA with explicit memory registration (§3.2: "GLEX's
/// memory registration module").
#[derive(Debug)]
pub struct GlexContext {
    rail: usize,
    nodes: usize,
    /// Registered memory regions: (offset_elems, len_elems) windows pinned
    /// for RDMA.
    registered: Vec<(usize, usize)>,
}

impl GlexContext {
    pub fn new(rail: usize) -> Self {
        GlexContext { rail, nodes: 0, registered: vec![] }
    }

    /// Register a memory window for zero-copy transfer; returns an rkey.
    pub fn register_memory(&mut self, offset: usize, len: usize) -> usize {
        self.registered.push((offset, len));
        self.registered.len() - 1
    }

    pub fn deregister_all(&mut self) {
        self.registered.clear();
    }

    pub fn is_registered(&self, offset: usize, len: usize) -> bool {
        self.registered
            .iter()
            .any(|&(o, l)| offset >= o && offset + len <= o + l)
    }
}

impl Context for GlexContext {
    fn kind(&self) -> ProtoKind {
        ProtoKind::Glex
    }
    fn rail_id(&self) -> usize {
        self.rail
    }
    fn collective(&self) -> CollectiveKind {
        CollectiveKind::Ring
    }
    fn transport(&self) -> &'static str {
        "glex_rdma"
    }
    fn join_domain(&mut self, nodes: usize) {
        self.nodes = nodes;
    }
    fn ready(&self) -> bool {
        self.nodes >= 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::rail::NicSpec;

    #[test]
    fn context_factory_matches_protocol() {
        for (kind, transport) in [
            (ProtoKind::Tcp, "tcp"),
            (ProtoKind::Sharp, "ibverbs"),
            (ProtoKind::Glex, "glex_rdma"),
        ] {
            let rail = Rail::new(0, NicSpec::CONNECTX5, kind);
            let ctx = context_for(&rail, 4);
            assert_eq!(ctx.kind(), kind);
            assert_eq!(ctx.transport(), transport);
            assert!(ctx.ready());
        }
    }

    #[test]
    fn sharp_tree_structure() {
        let mut s = SharpContext::new(0);
        s.join_domain(8);
        assert_eq!(s.tree_parent[0], None);
        assert_eq!(s.tree_parent[1], Some(0));
        assert_eq!(s.tree_parent[7], Some(3));
        assert_eq!(s.tree_depth(), 3);
    }

    #[test]
    fn glex_memory_registration() {
        let mut g = GlexContext::new(1);
        g.join_domain(4);
        let _rkey = g.register_memory(0, 1024);
        assert!(g.is_registered(0, 1024));
        assert!(g.is_registered(100, 100));
        assert!(!g.is_registered(512, 1024));
        g.deregister_all();
        assert!(!g.is_registered(0, 1));
    }
}
