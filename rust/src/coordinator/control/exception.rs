//! Exception Handler (paper §3.5, §4.4): fault detection, rail
//! deregistration and (ptr, data_length) task migration.
//!
//! On a member-network failure the handler: detects it (heartbeat/transfer
//! timeout), records the faulty network object and deregisters its
//! operation handle, picks the optimal surviving member network (the one
//! the Load Balancer had trusted with the most data), and hands the failed
//! window over. The paper's budget — detection + migration — is under
//! 200 ms; our defaults (120 ms detect + 40 ms migrate) keep every
//! recovery inside it.

use crate::coordinator::buffer::Window;
use crate::config::ControlConfig;
use crate::net::simnet::Fabric;

/// One recorded failover, for the metrics/Fig. 8 timeline.
#[derive(Debug, Clone, Copy)]
pub struct FailoverEvent {
    /// Virtual time the failure surfaced (us).
    pub at_us: f64,
    pub failed_rail: usize,
    pub takeover_rail: usize,
    /// Window migrated to the takeover rail.
    pub window: Window,
    /// Detection + migration cost charged (us).
    pub recovery_us: f64,
}

/// The paper's end-to-end self-recovery budget (§4.4): detection plus
/// task migration must complete within 200 ms.
pub const PAPER_RECOVERY_BUDGET_US: f64 = 200_000.0;

/// A gray-failure health action taken by the control plane (the
/// `HealthMonitor`'s decisions, executed through the Exception Handler's
/// budget accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GrayAction {
    /// Healthy → Degraded: soft share demotion, rail keeps serving.
    Demote,
    /// Degraded → Healthy: suspicion cleared, full share restored.
    Restore,
    /// → Quarantined: deregistered, windows migrated (charges migration).
    Quarantine,
    /// Quarantined → Probation: canary readmission at reduced share.
    Probation,
    /// Probation → Healthy: clean canary streak, full readmission.
    Readmit,
}

impl GrayAction {
    pub fn name(self) -> &'static str {
        match self {
            GrayAction::Demote => "demote",
            GrayAction::Restore => "restore",
            GrayAction::Quarantine => "quarantine",
            GrayAction::Probation => "probation",
            GrayAction::Readmit => "readmit",
        }
    }
}

/// One recorded gray-failure health transition, for the chaos-campaign
/// invariants (bounded transitions, recovery budget) and ablation plots.
#[derive(Debug, Clone, Copy)]
pub struct GrayEvent {
    /// Virtual time the action completed (us).
    pub at_us: f64,
    pub rail: usize,
    pub action: GrayAction,
    /// Modeled cost charged for the action (us) — only quarantines pay
    /// migration; soft demotions/restores are control-plane-free.
    pub recovery_us: f64,
    /// Suspicion score at decision time.
    pub suspicion: f64,
}

/// One recorded node-level membership recovery (leave or rejoin) — the
/// elastic counterpart of [`FailoverEvent`].
#[derive(Debug, Clone, Copy)]
pub struct MembershipRecovery {
    /// Virtual time the recovery completed (us).
    pub at_us: f64,
    /// First departed/rejoined node of the batch (original numbering).
    pub node: usize,
    /// Nodes in the batch (a rack leave is one recovery, one budget).
    pub count: usize,
    /// False = leave, true = rejoin.
    pub rejoin: bool,
    /// Detection + migration cost charged (us).
    pub recovery_us: f64,
    /// Membership epoch after this recovery.
    pub epoch: u64,
}

/// The Exception Handler.
#[derive(Debug)]
pub struct ExceptionHandler {
    cfg: ControlConfig,
    pub events: Vec<FailoverEvent>,
    /// Node-level membership recoveries (leave/rejoin), same budget
    /// accounting as rail failovers.
    pub membership: Vec<MembershipRecovery>,
    /// Gray-failure health actions (demote/restore/quarantine/probation/
    /// readmit), same budget accounting as rail failovers.
    pub gray: Vec<GrayEvent>,
    /// Rails the topology's per-group affinity masks allow (all-ones
    /// without affinity constraints): failover takeover targets must
    /// respect them — migrating a window to a rail some group excludes
    /// would violate the affinity the planner honoured.
    rail_mask: u64,
}

impl ExceptionHandler {
    pub fn new(cfg: ControlConfig) -> ExceptionHandler {
        ExceptionHandler {
            cfg,
            events: Vec::new(),
            membership: Vec::new(),
            gray: Vec::new(),
            rail_mask: u64::MAX,
        }
    }

    /// Restrict takeover targets to `mask` (0 = unconstrained).
    pub fn set_rail_mask(&mut self, mask: u64) {
        self.rail_mask = if mask == 0 { u64::MAX } else { mask };
    }

    /// Total detection + migration budget charged per failover (us).
    pub fn recovery_cost_us(&self) -> f64 {
        self.cfg.detect_timeout_us + self.cfg.migrate_cost_us
    }

    /// True when every recorded recovery stayed inside the paper's 200 ms
    /// self-recovery budget.
    pub fn all_within_budget(&self) -> bool {
        self.events
            .iter()
            .all(|ev| ev.recovery_us < PAPER_RECOVERY_BUDGET_US)
    }

    /// Handle a failure of `failed` while processing `window`: deregister
    /// the rail, pick the optimal survivor and record the event.
    ///
    /// `allocated_bytes` is the Load Balancer's per-rail allocation for
    /// this op — per §4.4 the optimal member network is the one handling
    /// the most data ("typically more performant").
    pub fn handle_failure(
        &mut self,
        fab: &mut Fabric,
        failed: usize,
        window: Window,
        allocated_bytes: &[(usize, u64)],
    ) -> Option<FailoverEvent> {
        fab.deregister(failed);
        let mask = self.rail_mask;
        let takeover = fab
            .healthy_rails_iter()
            .filter(|&r| mask & (1u64 << r) != 0)
            .max_by_key(|&r| {
                allocated_bytes
                    .iter()
                    .find(|(rr, _)| *rr == r)
                    .map(|(_, b)| *b)
                    .unwrap_or(0)
            })?;
        let recovery = self.recovery_cost_us();
        fab.advance(recovery);
        let ev = FailoverEvent {
            at_us: fab.now_us(),
            failed_rail: failed,
            takeover_rail: takeover,
            window,
            recovery_us: recovery,
        };
        self.events.push(ev);
        Some(ev)
    }

    /// Record a gray-failure health action: quarantines charge the
    /// migration cost (windows move exactly like a crash failover's, but
    /// detection already happened — that's what the suspicion score *is*);
    /// soft demotions, restores and probation canaries are free.
    pub fn record_gray(
        &mut self,
        fab: &mut Fabric,
        rail: usize,
        action: GrayAction,
        suspicion: f64,
    ) -> GrayEvent {
        let recovery = match action {
            GrayAction::Quarantine => self.cfg.migrate_cost_us,
            _ => 0.0,
        };
        if recovery > 0.0 {
            fab.advance(recovery);
        }
        let ev = GrayEvent {
            at_us: fab.now_us(),
            rail,
            action,
            recovery_us: recovery,
            suspicion,
        };
        self.gray.push(ev);
        ev
    }

    /// True when every gray-failure action stayed inside the paper's
    /// 200 ms self-recovery budget.
    pub fn gray_within_budget(&self) -> bool {
        self.gray.iter().all(|ev| ev.recovery_us < PAPER_RECOVERY_BUDGET_US)
    }

    pub fn gray_count(&self) -> usize {
        self.gray.len()
    }

    /// Probe quarantined rails; re-admit any whose fault window has
    /// passed (trust-on-readmit — the legacy `HealthMode::Off` path; with
    /// the monitor on, `MultiRail::probe_readmitted` routes readmission
    /// through Probation instead). Returns re-admitted rail ids.
    pub fn probe_recovery(&mut self, fab: &mut Fabric) -> Vec<usize> {
        let mut back = Vec::new();
        for r in 0..fab.rails.len() {
            if fab.rails[r].health == crate::net::rail::RailHealth::Quarantined
                && !fab.faults.is_down(r, fab.now_us())
                && !fab.degrade.flap_down(r, fab.now_us())
            {
                fab.readmit(r);
                back.push(r);
            }
        }
        back
    }

    /// Handle the departure of `count` nodes (first id `node`, original
    /// numbering): the coordinator has already rebound topology, fabric
    /// and rendezvous over the surviving set — this records the recovery
    /// and charges ONE detection + migration budget for the whole batch
    /// (a rack dying is one detection event, exactly like one rail dying;
    /// the migrated work is every window the departed nodes touched, but
    /// migration is a bulk (ptr, len) handoff whose cost the paper models
    /// per event, not per byte).
    pub fn handle_node_failure(
        &mut self,
        fab: &mut Fabric,
        node: usize,
        count: usize,
        epoch: u64,
    ) -> MembershipRecovery {
        let recovery = self.recovery_cost_us();
        fab.advance(recovery);
        let ev = MembershipRecovery {
            at_us: fab.now_us(),
            node,
            count,
            rejoin: false,
            recovery_us: recovery,
            epoch,
        };
        self.membership.push(ev);
        ev
    }

    /// Handle a node rejoining: no detection phase (the join is announced,
    /// not discovered by timeout), so only the migration/reprime cost is
    /// charged before the restored member carries traffic again.
    pub fn handle_node_rejoin(
        &mut self,
        fab: &mut Fabric,
        node: usize,
        epoch: u64,
    ) -> MembershipRecovery {
        let recovery = self.cfg.migrate_cost_us;
        fab.advance(recovery);
        let ev = MembershipRecovery {
            at_us: fab.now_us(),
            node,
            count: 1,
            rejoin: true,
            recovery_us: recovery,
            epoch,
        };
        self.membership.push(ev);
        ev
    }

    /// True when every membership recovery stayed inside the paper's
    /// 200 ms self-recovery budget.
    pub fn membership_within_budget(&self) -> bool {
        self.membership
            .iter()
            .all(|ev| ev.recovery_us < PAPER_RECOVERY_BUDGET_US)
    }

    pub fn membership_count(&self) -> usize {
        self.membership.len()
    }

    pub fn failover_count(&self) -> usize {
        self.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::fault::FaultSchedule;
    use crate::net::protocol::ProtoKind;
    use crate::net::topology::ClusterSpec;

    fn dual_tcp() -> Fabric {
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp])
            .unwrap();
        Fabric::new(4, rails, CpuPool::default(), 5).deterministic()
    }

    #[test]
    fn recovery_under_200ms_budget() {
        let h = ExceptionHandler::new(ControlConfig::default());
        assert!(h.recovery_cost_us() < PAPER_RECOVERY_BUDGET_US, "paper budget violated");
        assert!(h.all_within_budget(), "no events yet");
    }

    #[test]
    fn failover_picks_biggest_allocation() {
        let mut fab = dual_tcp();
        let mut h = ExceptionHandler::new(ControlConfig::default());
        let ev = h
            .handle_failure(&mut fab, 0, Window::new(0, 100), &[(0, 600), (1, 400)])
            .unwrap();
        assert_eq!(ev.takeover_rail, 1);
        assert_eq!(fab.healthy_rails(), vec![1]);
        assert_eq!(h.failover_count(), 1);
    }

    #[test]
    fn takeover_respects_affinity_rail_mask() {
        // three TCP rails; the mask excludes rail 1, so even though rail 1
        // holds the biggest allocation the takeover must go to rail 2
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp, ProtoKind::Tcp])
            .unwrap();
        let mut fab = Fabric::new(4, rails, CpuPool::default(), 5).deterministic();
        let mut h = ExceptionHandler::new(ControlConfig::default());
        h.set_rail_mask(0b101);
        let ev = h
            .handle_failure(&mut fab, 0, Window::new(0, 100), &[(0, 600), (1, 500), (2, 400)])
            .unwrap();
        assert_eq!(ev.takeover_rail, 2, "mask must exclude rail 1");
        // rail 2 failing next leaves only the masked-out rail 1: no target
        assert!(h
            .handle_failure(&mut fab, 2, Window::new(0, 10), &[(1, 1)])
            .is_none());
    }

    #[test]
    fn no_survivor_returns_none() {
        let mut fab = dual_tcp();
        let mut h = ExceptionHandler::new(ControlConfig::default());
        fab.deregister(1);
        assert!(h
            .handle_failure(&mut fab, 0, Window::new(0, 10), &[])
            .is_none());
    }

    #[test]
    fn probe_readmits_after_window() {
        let mut fab = dual_tcp().with_faults(FaultSchedule::none().with(1, 0.0, 1000.0));
        let mut h = ExceptionHandler::new(ControlConfig::default());
        fab.advance(10.0);
        h.handle_failure(&mut fab, 1, Window::new(0, 10), &[(0, 1), (1, 1)]);
        // handle_failure advanced the clock past the fault window end
        assert!(fab.now_us() > 1000.0);
        let back = h.probe_recovery(&mut fab);
        assert_eq!(back, vec![1]);
        assert_eq!(fab.healthy_rails(), vec![0, 1]);
    }

    #[test]
    fn probe_keeps_still_faulty_rail_out() {
        let mut fab = dual_tcp().with_faults(FaultSchedule::none().with(1, 0.0, 1e9));
        let mut h = ExceptionHandler::new(ControlConfig::default());
        h.handle_failure(&mut fab, 1, Window::new(0, 10), &[(0, 1), (1, 1)]);
        assert!(h.probe_recovery(&mut fab).is_empty());
        assert_eq!(fab.healthy_rails(), vec![0]);
    }

    #[test]
    fn node_failure_charges_one_budget_per_batch() {
        let mut fab = dual_tcp();
        let mut h = ExceptionHandler::new(ControlConfig::default());
        // a whole 4-node rack leaving is ONE detection + migration charge
        let ev = h.handle_node_failure(&mut fab, 0, 4, 1);
        assert!(!ev.rejoin);
        assert_eq!(ev.count, 4);
        assert_eq!(ev.epoch, 1);
        assert_eq!(ev.recovery_us, h.recovery_cost_us());
        assert!(ev.recovery_us < PAPER_RECOVERY_BUDGET_US);
        assert_eq!(fab.now_us(), ev.recovery_us);
        assert_eq!(h.membership_count(), 1);
        assert!(h.membership_within_budget());
        // rail-failover ledger untouched
        assert_eq!(h.failover_count(), 0);
    }

    #[test]
    fn gray_ledger_charges_only_quarantine() {
        let mut fab = dual_tcp();
        let mut h = ExceptionHandler::new(ControlConfig::default());
        let d = h.record_gray(&mut fab, 1, GrayAction::Demote, 3.2);
        assert_eq!(d.recovery_us, 0.0);
        assert_eq!(fab.now_us(), 0.0, "soft demotion is control-plane-free");
        let q = h.record_gray(&mut fab, 1, GrayAction::Quarantine, 8.5);
        assert!(q.recovery_us > 0.0 && q.recovery_us < PAPER_RECOVERY_BUDGET_US);
        assert_eq!(fab.now_us(), q.recovery_us, "quarantine charges migration");
        assert_eq!(h.gray_count(), 2);
        assert!(h.gray_within_budget());
        assert_eq!(GrayAction::Readmit.name(), "readmit");
    }

    #[test]
    fn node_rejoin_skips_detection_phase() {
        let mut fab = dual_tcp();
        let mut h = ExceptionHandler::new(ControlConfig::default());
        let leave = h.handle_node_failure(&mut fab, 2, 1, 1);
        let rejoin = h.handle_node_rejoin(&mut fab, 2, 2);
        assert!(rejoin.rejoin);
        assert_eq!(rejoin.epoch, 2);
        // announced joins skip the detection timeout
        assert!(rejoin.recovery_us < leave.recovery_us);
        assert!(h.membership_within_budget());
    }
}
