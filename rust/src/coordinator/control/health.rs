//! Health Monitor: gray-failure detection via per-rail suspicion scores.
//!
//! Crash-stop failures announce themselves (a transfer errors, §4.4 takes
//! over). Gray failures don't: a lossy link retransmits, a brownout
//! stretches transfers, a flapping NIC wobbles — the rail keeps "working",
//! just worse. The monitor watches the two signals the control plane
//! already carries — the Timer's observed-vs-predicted residuals (the
//! `CorrectedCost` plumbing) and the fabric's retransmit ledger — and
//! folds them into a per-rail *suspicion score* with hysteresis:
//!
//! - score ≥ `degrade_enter` → **Degraded**: soft share demotion + replan
//!   (graceful degradation; the rail keeps carrying reduced traffic)
//! - score ≥ `quarantine_enter` → **Quarantined**: deregistered, windows
//!   migrated via the §4.4 path
//! - score ≤ `degrade_clear` → back to **Healthy** (full share)
//!
//! Quarantined rails re-enter through **Probation**: a dwell time gates
//! readmission (doubling on every failed probation, so a flapping rail
//! can't oscillate), then the rail carries canary traffic at
//! `probation_weight` share; `probation_ops` consecutive clean ops promote
//! it to Healthy, any dirty op sends it straight back.
//!
//! Residual-only suspicion saturates at `residual_cap`, *below* the
//! quarantine threshold: a pure brownout or straggler — slow but
//! delivering — demotes and never quarantines in [`HealthMode::Graceful`].
//! Retry-driven suspicion is uncapped: a loss storm escalates all the way.
//! [`HealthMode::Binary`] is the ablation baseline that quarantines at the
//! demotion threshold instead of degrading gracefully.

use crate::net::rail::RailHealth;
use crate::net::simnet::Fabric;

/// Monitor policy: how suspicion maps to actions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthMode {
    /// Demote first (soft share), quarantine only on escalation.
    Graceful,
    /// Quarantine at the demotion threshold — the binary-failover
    /// ablation baseline (`fig ablate-grayfault`).
    Binary,
    /// Monitor disabled: legacy trust-on-readmit behaviour.
    Off,
}

impl HealthMode {
    pub fn parse(s: &str) -> crate::Result<HealthMode> {
        match s.to_ascii_lowercase().as_str() {
            "graceful" | "on" => Ok(HealthMode::Graceful),
            "binary" => Ok(HealthMode::Binary),
            "off" | "none" => Ok(HealthMode::Off),
            other => Err(crate::util::error::Error::Config(format!(
                "unknown health mode `{other}` (graceful|binary|off)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            HealthMode::Graceful => "graceful",
            HealthMode::Binary => "binary",
            HealthMode::Off => "off",
        }
    }
}

/// Suspicion scoring and hysteresis tunables.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    pub mode: HealthMode,
    /// Measured/predicted ratio above which an op counts as dirty.
    pub residual_trigger: f64,
    /// Suspicion added per retransmit attempt (per-op contribution is
    /// capped at 3.0 so one pathological op can't instantly quarantine).
    pub retry_weight: f64,
    /// Suspicion added per dirty residual observation.
    pub dirty_inc: f64,
    /// Multiplicative decay per clean observation (snaps to 0 < 1e-3).
    pub clean_decay: f64,
    /// Ceiling for residual-only suspicion — kept below
    /// `quarantine_enter` so slow-but-delivering rails never quarantine
    /// in Graceful mode.
    pub residual_cap: f64,
    /// Healthy → Degraded threshold.
    pub degrade_enter: f64,
    /// Degraded → Healthy threshold (hysteresis gap vs `degrade_enter`).
    pub degrade_clear: f64,
    /// → Quarantined threshold (reachable only via retries in Graceful).
    pub quarantine_enter: f64,
    /// Load-Balancer share multiplier for Degraded rails.
    pub degraded_weight: f64,
    /// Load-Balancer share multiplier for Probation canaries.
    pub probation_weight: f64,
    /// Consecutive clean probation ops required for full readmission.
    pub probation_ops: usize,
    /// Dwell before the first re-probation after a probation failure;
    /// doubles per failure (bounded oscillation under flapping).
    pub requarantine_dwell_us: f64,
    /// Dwell growth factor per failed probation.
    pub dwell_backoff: f64,
    /// Dwell ceiling.
    pub max_dwell_us: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            mode: HealthMode::Graceful,
            residual_trigger: 1.4,
            retry_weight: 0.5,
            dirty_inc: 1.0,
            clean_decay: 0.5,
            residual_cap: 6.0,
            degrade_enter: 3.0,
            degrade_clear: 0.5,
            quarantine_enter: 8.0,
            degraded_weight: 0.35,
            probation_weight: 0.25,
            probation_ops: 3,
            requarantine_dwell_us: 50_000.0,
            dwell_backoff: 2.0,
            max_dwell_us: 10_000_000.0,
        }
    }
}

/// Per-rail monitor state.
#[derive(Debug, Clone, Default)]
struct RailStat {
    suspicion: f64,
    /// This op looked dirty (retries or residual blow-up).
    dirty: bool,
    /// The rail carried traffic this op (only observed rails are decided).
    observed: bool,
    /// Consecutive clean probation ops.
    clean_streak: usize,
    /// No re-probation before this virtual time.
    dwell_until_us: f64,
    /// Current dwell length (0 until the first failed probation).
    dwell_us: f64,
}

/// A decided action, to be executed by the coordinator (share demotion,
/// §4.4 quarantine, probation promotion).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthAction {
    /// Healthy → Degraded: demote the Load-Balancer share and replan.
    Demote(usize),
    /// Degraded → Healthy or Probation → Healthy: restore the full share.
    Restore(usize),
    /// → Quarantined: deregister and migrate via the §4.4 path.
    Quarantine(usize),
}

/// One recorded state-machine transition (oscillation-bound invariant).
#[derive(Debug, Clone, Copy)]
pub struct HealthTransition {
    pub at_us: f64,
    pub rail: usize,
    pub from: RailHealth,
    pub to: RailHealth,
    /// Suspicion at transition time.
    pub suspicion: f64,
}

/// The monitor: suspicion scores in, [`HealthAction`]s out.
#[derive(Debug)]
pub struct HealthMonitor {
    pub cfg: HealthConfig,
    stats: Vec<RailStat>,
    transitions: Vec<HealthTransition>,
}

impl HealthMonitor {
    pub fn new(cfg: HealthConfig, n_rails: usize) -> HealthMonitor {
        HealthMonitor {
            cfg,
            stats: vec![RailStat::default(); n_rails],
            transitions: Vec::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.mode != HealthMode::Off
    }

    pub fn suspicion(&self, rail: usize) -> f64 {
        self.stats[rail].suspicion
    }

    /// Load-Balancer share multiplier for a rail in `health` state.
    pub fn weight_for(&self, health: RailHealth) -> f64 {
        match health {
            RailHealth::Degraded => self.cfg.degraded_weight,
            RailHealth::Probation => self.cfg.probation_weight,
            _ => 1.0,
        }
    }

    /// Fold one op's observation for `rail` into its suspicion score.
    /// `predicted_us <= 0` skips the residual check (no prediction
    /// available — e.g. corrections disabled, or the rail wasn't
    /// planned); retries always count.
    pub fn observe(&mut self, rail: usize, predicted_us: f64, measured_us: f64, retries: u64) {
        let st = &mut self.stats[rail];
        st.observed = true;
        let mut inc = 0.0;
        let mut dirty = false;
        if retries > 0 {
            dirty = true;
            inc += (retries as f64 * self.cfg.retry_weight).min(3.0);
        }
        if predicted_us > 0.0 && measured_us > predicted_us * self.cfg.residual_trigger {
            dirty = true;
            // saturating: residual evidence alone can't cross the
            // quarantine threshold
            inc += self.cfg.dirty_inc.min((self.cfg.residual_cap - st.suspicion).max(0.0));
        }
        if dirty {
            st.dirty = true;
            st.suspicion += inc;
        } else {
            st.suspicion *= self.cfg.clean_decay;
            if st.suspicion < 1e-3 {
                st.suspicion = 0.0;
            }
        }
    }

    /// Decide actions for every rail observed since the last call; clears
    /// the per-op observation flags. Quarantined rails are readmission's
    /// job ([`Self::probation_eligible`]), not decide's.
    pub fn decide(&mut self, fab: &Fabric, out: &mut Vec<HealthAction>) {
        out.clear();
        if !self.enabled() {
            return;
        }
        for (r, rail) in fab.rails.iter().enumerate() {
            let st = &mut self.stats[r];
            if !st.observed {
                continue;
            }
            st.observed = false;
            let dirty = std::mem::take(&mut st.dirty);
            let s = st.suspicion;
            match rail.health {
                RailHealth::Healthy => {
                    if s >= self.cfg.quarantine_enter
                        || (self.cfg.mode == HealthMode::Binary && s >= self.cfg.degrade_enter)
                    {
                        out.push(HealthAction::Quarantine(r));
                    } else if s >= self.cfg.degrade_enter {
                        out.push(HealthAction::Demote(r));
                    }
                }
                RailHealth::Degraded => {
                    if s >= self.cfg.quarantine_enter {
                        out.push(HealthAction::Quarantine(r));
                    } else if s <= self.cfg.degrade_clear {
                        out.push(HealthAction::Restore(r));
                    }
                }
                RailHealth::Probation => {
                    if dirty {
                        out.push(HealthAction::Quarantine(r));
                    } else {
                        st.clean_streak += 1;
                        if st.clean_streak >= self.cfg.probation_ops {
                            out.push(HealthAction::Restore(r));
                        }
                    }
                }
                RailHealth::Quarantined => {}
            }
        }
    }

    /// Note that `rail` was quarantined (by decide, or by a §4.4 crash
    /// failover). A failed probation escalates the readmission dwell —
    /// doubling, clamped — so a flapping rail's transition count is
    /// logarithmic in campaign length, not linear.
    pub fn note_quarantined(&mut self, rail: usize, now_us: f64, from_probation: bool) {
        let st = &mut self.stats[rail];
        if from_probation {
            st.dwell_us = (st.dwell_us * self.cfg.dwell_backoff)
                .clamp(self.cfg.requarantine_dwell_us, self.cfg.max_dwell_us);
        }
        st.dwell_until_us = now_us + st.dwell_us;
        st.suspicion = 0.0;
        st.clean_streak = 0;
        st.dirty = false;
        st.observed = false;
    }

    /// May `rail` start probation at `now_us`? (Its quarantine dwell has
    /// passed. The caller still checks the physical schedules.)
    pub fn probation_eligible(&self, rail: usize, now_us: f64) -> bool {
        now_us >= self.stats[rail].dwell_until_us
    }

    /// Note that `rail` entered probation: a fresh canary record.
    pub fn note_probation(&mut self, rail: usize) {
        let st = &mut self.stats[rail];
        st.suspicion = 0.0;
        st.clean_streak = 0;
        st.dirty = false;
        st.observed = false;
    }

    /// Record a state-machine transition for the oscillation invariant.
    pub fn record_transition(&mut self, at_us: f64, rail: usize, from: RailHealth, to: RailHealth) {
        let suspicion = self.stats[rail].suspicion;
        self.transitions.push(HealthTransition { at_us, rail, from, to, suspicion });
    }

    pub fn transitions(&self) -> &[HealthTransition] {
        &self.transitions
    }

    /// Transition count for one rail (bounded-oscillation assertions).
    pub fn transition_count(&self, rail: usize) -> usize {
        self.transitions.iter().filter(|t| t.rail == rail).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::ProtoKind;
    use crate::net::topology::ClusterSpec;

    fn dual_tcp() -> Fabric {
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp])
            .unwrap();
        Fabric::new(4, rails, CpuPool::default(), 9).deterministic()
    }

    fn monitor() -> HealthMonitor {
        HealthMonitor::new(HealthConfig::default(), 2)
    }

    #[test]
    fn residual_demotes_then_clean_restores() {
        let mut fab = dual_tcp();
        let mut m = monitor();
        let mut out = Vec::new();
        // three dirty residual ops cross degrade_enter = 3.0
        for _ in 0..3 {
            m.observe(1, 100.0, 200.0, 0);
            m.decide(&fab, &mut out);
        }
        assert_eq!(out, vec![HealthAction::Demote(1)]);
        assert!(fab.rails[1].transition(RailHealth::Degraded));
        // clean ops decay ×0.5: 3.0 → 0.375 ≤ degrade_clear after 3
        for _ in 0..2 {
            m.observe(1, 100.0, 100.0, 0);
            m.decide(&fab, &mut out);
            assert!(out.is_empty(), "hysteresis holds mid-decay");
        }
        m.observe(1, 100.0, 100.0, 0);
        m.decide(&fab, &mut out);
        assert_eq!(out, vec![HealthAction::Restore(1)]);
    }

    #[test]
    fn residual_alone_never_quarantines_in_graceful() {
        let fab = dual_tcp();
        let mut m = monitor();
        let mut out = Vec::new();
        for _ in 0..50 {
            m.observe(0, 100.0, 1000.0, 0);
        }
        assert!(m.suspicion(0) <= m.cfg.residual_cap);
        assert!(m.suspicion(0) < m.cfg.quarantine_enter);
        m.decide(&fab, &mut out);
        assert_eq!(out, vec![HealthAction::Demote(0)], "slow-but-delivering demotes only");
    }

    #[test]
    fn retry_storm_escalates_to_quarantine() {
        let fab = dual_tcp();
        let mut m = monitor();
        let mut out = Vec::new();
        // 3.0 per op (capped per-op retry contribution), uncapped total
        for _ in 0..3 {
            m.observe(0, 0.0, 0.0, 40);
        }
        assert!(m.suspicion(0) >= m.cfg.quarantine_enter);
        m.decide(&fab, &mut out);
        assert_eq!(out, vec![HealthAction::Quarantine(0)]);
    }

    #[test]
    fn binary_mode_quarantines_at_demotion_threshold() {
        let fab = dual_tcp();
        let cfg = HealthConfig { mode: HealthMode::Binary, ..HealthConfig::default() };
        let mut m = HealthMonitor::new(cfg, 2);
        let mut out = Vec::new();
        for _ in 0..3 {
            m.observe(1, 100.0, 200.0, 0);
        }
        m.decide(&fab, &mut out);
        assert_eq!(out, vec![HealthAction::Quarantine(1)], "binary skips Degraded");
    }

    #[test]
    fn probation_promotes_on_clean_streak_and_requarantines_on_dirt() {
        let mut fab = dual_tcp();
        let mut m = monitor();
        let mut out = Vec::new();
        fab.rails[1].health = RailHealth::Probation;
        m.note_probation(1);
        for i in 0..3 {
            m.observe(1, 100.0, 100.0, 0);
            m.decide(&fab, &mut out);
            if i < 2 {
                assert!(out.is_empty(), "streak not complete at op {i}");
            }
        }
        assert_eq!(out, vec![HealthAction::Restore(1)], "3 clean ops promote");
        // a dirty canary goes straight back
        m.note_probation(1);
        m.observe(1, 100.0, 100.0, 2);
        m.decide(&fab, &mut out);
        assert_eq!(out, vec![HealthAction::Quarantine(1)]);
    }

    #[test]
    fn dwell_escalates_only_on_failed_probation() {
        let mut m = monitor();
        // crash failover: immediate readmission allowed (dwell 0)
        m.note_quarantined(0, 1000.0, false);
        assert!(m.probation_eligible(0, 1000.0));
        // failed probation: dwell jumps to the floor, then doubles
        m.note_quarantined(0, 1000.0, true);
        assert!(!m.probation_eligible(0, 1000.0 + 49_999.0));
        assert!(m.probation_eligible(0, 1000.0 + 50_000.0));
        m.note_quarantined(0, 2000.0, true);
        assert!(!m.probation_eligible(0, 2000.0 + 99_999.0));
        assert!(m.probation_eligible(0, 2000.0 + 100_000.0));
    }

    #[test]
    fn off_mode_decides_nothing() {
        let fab = dual_tcp();
        let cfg = HealthConfig { mode: HealthMode::Off, ..HealthConfig::default() };
        let mut m = HealthMonitor::new(cfg, 2);
        assert!(!m.enabled());
        let mut out = vec![HealthAction::Demote(0)];
        for _ in 0..10 {
            m.observe(0, 100.0, 1000.0, 50);
        }
        m.decide(&fab, &mut out);
        assert!(out.is_empty(), "decide clears and stays empty when off");
    }

    #[test]
    fn transition_ledger_counts_per_rail() {
        let mut m = monitor();
        m.record_transition(0.0, 1, RailHealth::Healthy, RailHealth::Degraded);
        m.record_transition(5.0, 1, RailHealth::Degraded, RailHealth::Healthy);
        m.record_transition(9.0, 0, RailHealth::Healthy, RailHealth::Quarantined);
        assert_eq!(m.transition_count(1), 2);
        assert_eq!(m.transition_count(0), 1);
        assert_eq!(m.transitions().len(), 3);
        assert!(HealthMode::parse("bogus").is_err());
        assert_eq!(HealthMode::parse("binary").unwrap().name(), "binary");
    }

    #[test]
    fn weights_follow_state() {
        let m = monitor();
        assert_eq!(m.weight_for(RailHealth::Healthy), 1.0);
        assert!(m.weight_for(RailHealth::Degraded) < 1.0);
        assert!(m.weight_for(RailHealth::Probation) < m.weight_for(RailHealth::Degraded));
    }
}
