//! Load Balancer (paper §4.3): the dual-state (cold/hot) transition
//! latency-minimization scheme.
//!
//! * **Cold start** (small payloads, Eq. 4): route the whole window through
//!   the single lowest-latency network — multi-rail splitting would only
//!   add synchronization overhead.
//! * **Hot start** (large payloads, Eq. 5): partition across rails with
//!   coefficients α, initialized per Eq. 8 and refined by (sub)gradient
//!   descent on `T_hot = max_i(T_setup_i + α_i·S/B_i)` (Eq. 7) using the
//!   Timer's live measurements.
//! * The transition threshold `S_threshold` is where cold and hot latency
//!   estimates cross (Eq. 6), recomputed from live estimates — and data
//!   partitioning is only activated at all when the real-time efficiency
//!   ratio ρ(S) (Eq. 3) stays within the divergence tolerance τ (= 5).
//!
//! State is kept per payload size class — the paper's "data length table".

use std::collections::HashMap;

use crate::config::ControlConfig;
use crate::coordinator::control::size_bucket;
use crate::coordinator::control::timer::Timer;
use crate::net::simnet::Fabric;

/// Cross-rail synchronization overhead: thread join + window registration
/// + result collection for one multi-rail op. Calibrated so the cold→hot
/// threshold lands at the paper's 128–256 KB for dual-rail TCP (Fig. 9).
pub const SYNC_BASE_US: f64 = 380.0;
pub const SYNC_PER_RAIL_US: f64 = 70.0;

/// Synchronization penalty when `k` rails participate in one op.
pub fn sync_overhead_us(k: usize) -> f64 {
    if k <= 1 {
        0.0
    } else {
        SYNC_BASE_US + SYNC_PER_RAIL_US * (k - 1) as f64
    }
}

/// A partitioning decision for one allreduce (the allocating form, kept
/// for tests/introspection — the per-op hot path uses
/// [`LoadBalancer::plan_into`] and caller-owned scratch instead).
#[derive(Debug, Clone, PartialEq)]
pub enum Plan {
    /// Cold start: the whole window goes to this rail.
    Cold { rail: usize },
    /// Hot start: (rail, fraction) shares, fractions sum to 1.
    Hot { shares: Vec<(usize, f64)> },
}

/// What kind of decision [`LoadBalancer::plan_into`] wrote into the output
/// buffer (the shares themselves land in the buffer: a cold decision is a
/// single `(rail, 1.0)` entry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    Cold,
    Hot,
}

impl Plan {
    pub fn n_rails(&self) -> usize {
        match self {
            Plan::Cold { .. } => 1,
            Plan::Hot { shares } => shares.len(),
        }
    }

    pub fn fraction_for(&self, rail: usize) -> f64 {
        match self {
            Plan::Cold { rail: r } => {
                if *r == rail {
                    1.0
                } else {
                    0.0
                }
            }
            Plan::Hot { shares } => shares
                .iter()
                .find(|(r, _)| *r == rail)
                .map(|(_, f)| *f)
                .unwrap_or(0.0),
        }
    }
}

/// Observable balancer state for a size class (metrics / Fig. 11).
#[derive(Debug, Clone, PartialEq)]
pub enum BalancerState {
    Cold,
    Hot { alphas: Vec<(usize, f64)>, converged: bool },
}

#[derive(Debug, Clone)]
struct Bucket {
    /// α per rail id.
    alphas: HashMap<usize, f64>,
    converged_streak: usize,
    iters: u64,
    last_state_hot: bool,
}

/// Reusable planning-pass scratch (estimates, τ-filtered candidates,
/// waterfill inputs/working set) — the balancer plans EVERY op, so these
/// intermediates must not allocate per call.
#[derive(Debug, Default)]
struct LbScratch {
    ests: Vec<(usize, f64)>,
    candidates: Vec<(usize, f64)>,
    parts: Vec<(usize, f64, f64)>,
    active: Vec<(usize, f64, f64)>,
}

/// The Load Balancer: per-size-class cold/hot state machine + α table.
#[derive(Debug)]
pub struct LoadBalancer {
    cfg: ControlConfig,
    buckets: HashMap<u32, Bucket>,
    /// Measurement correction per (rail, bucket): measured/model EMA the
    /// planner applies to the analytic estimates.
    corr: HashMap<(usize, u32), f64>,
    /// Soft-affinity weight per rail: the fraction of topology groups
    /// that admit it (absent = 1.0 = universally admitted). A rail only
    /// some groups can use effectively serves that fraction of the
    /// cluster, so its estimates inflate by the reciprocal — waterfill
    /// then hands it proportionally less payload, and a nearly-banned
    /// rail falls out through the τ efficiency filter instead of the
    /// all-or-nothing mask intersection.
    rail_weights: HashMap<usize, f64>,
    scratch: LbScratch,
}

impl LoadBalancer {
    pub fn new(cfg: ControlConfig) -> LoadBalancer {
        LoadBalancer {
            cfg,
            buckets: HashMap::new(),
            corr: HashMap::new(),
            rail_weights: HashMap::new(),
            scratch: LbScratch::default(),
        }
    }

    /// Install soft-affinity weights (see `rail_weights`); entries at (or
    /// above) 1.0 reset their rail to unweighted. Replaces the previous
    /// weight set wholesale.
    pub fn set_rail_weights(&mut self, weights: &[(usize, f64)]) {
        self.rail_weights.clear();
        for &(r, w) in weights {
            if w < 1.0 {
                self.rail_weights.insert(r, w.max(1e-3));
            }
        }
    }

    fn rail_weight(&self, rail: usize) -> f64 {
        self.rail_weights.get(&rail).copied().unwrap_or(1.0)
    }

    /// Corrected estimate of the FULL-payload single-rail allreduce time.
    fn est_full(&self, fab: &Fabric, rail: usize, bytes: u64) -> f64 {
        let model = fab.estimate_allreduce_us(rail, bytes as f64);
        let c = self
            .corr
            .get(&(rail, size_bucket(bytes)))
            .copied()
            .unwrap_or(1.0);
        model * c / self.rail_weight(rail)
    }

    /// Setup-dominated component (payload → 0) of a rail's allreduce.
    fn est_setup(&self, fab: &Fabric, rail: usize) -> f64 {
        fab.estimate_allreduce_us(rail, 1.0)
    }

    /// Eq. 3: real-time efficiency ratio across candidate rails at S.
    pub fn efficiency_ratio(&self, fab: &Fabric, rails: &[usize], bytes: u64) -> f64 {
        let thpts: Vec<f64> = rails
            .iter()
            .map(|&r| bytes as f64 / self.est_full(fab, r, bytes))
            .collect();
        let max = thpts.iter().cloned().fold(f64::MIN, f64::max);
        let min = thpts.iter().cloned().fold(f64::MAX, f64::min);
        if min <= 0.0 {
            f64::INFINITY
        } else {
            max / min
        }
    }

    /// Water-filling optimum of Eq. 5: α equalizing per-rail finish times,
    /// given (setup_i, transfer_full_i) per rail. Writes the alphas into
    /// `out` (cleared first) using `active` as the working set, returns
    /// T_hot — allocation-free once scratch capacities stabilize.
    fn waterfill_into(
        parts: &[(usize, f64, f64)],
        active: &mut Vec<(usize, f64, f64)>,
        out: &mut Vec<(usize, f64)>,
    ) -> f64 {
        // T* = (1 + Σ setup_i / X_i) / (Σ 1 / X_i); rails whose setup
        // exceeds T* get α = 0 and we re-solve without them.
        active.clear();
        active.extend_from_slice(parts);
        loop {
            let sum_inv: f64 = active.iter().map(|(_, _, x)| 1.0 / x).sum();
            let sum_s: f64 = active.iter().map(|(_, s, x)| s / x).sum();
            let t_star = (1.0 + sum_s) / sum_inv;
            if let Some(pos) = active.iter().position(|(_, s, _)| *s >= t_star) {
                if active.len() == 1 {
                    let (r, s, x) = active[0];
                    out.clear();
                    out.push((r, 1.0));
                    return s + x;
                }
                active.remove(pos);
                continue;
            }
            out.clear();
            out.extend(active.iter().map(|(r, s, x)| (*r, (t_star - s) / x)));
            return t_star;
        }
    }

    /// Decide the partitioning for one op of `bytes` over `healthy` rails
    /// — the allocating form (tests / threshold probing). The per-op path
    /// is [`LoadBalancer::plan_into`].
    pub fn plan(&mut self, fab: &Fabric, timer: &Timer, healthy: &[usize], bytes: u64) -> Plan {
        let mut out = Vec::new();
        match self.plan_into(fab, timer, healthy, bytes, &mut out) {
            PlanKind::Cold => Plan::Cold { rail: out[0].0 },
            PlanKind::Hot => Plan::Hot { shares: out },
        }
    }

    /// Decide the partitioning for one op, writing the shares into `out`
    /// (cleared first; a cold decision is a single `(rail, 1.0)` entry).
    /// All intermediates live in the balancer's own scratch, so the
    /// steady-state planning pass performs no allocation.
    pub fn plan_into(
        &mut self,
        fab: &Fabric,
        timer: &Timer,
        healthy: &[usize],
        bytes: u64,
        out: &mut Vec<(usize, f64)>,
    ) -> PlanKind {
        assert!(!healthy.is_empty());
        let _ = timer; // estimates are measurement-corrected via feedback()
        out.clear();
        let bucket_key = size_bucket(bytes);

        // full-payload estimates per rail (scratch-resident)
        let mut ests = std::mem::take(&mut self.scratch.ests);
        ests.clear();
        ests.extend(healthy.iter().map(|&r| (r, self.est_full(fab, r, bytes))));
        let (best_rail, t_cold) = ests
            .iter()
            .copied()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();

        if healthy.len() == 1 {
            self.scratch.ests = ests;
            out.push((best_rail, 1.0));
            return PlanKind::Cold;
        }

        // Proposition 1 (Eq. 3): drop rails whose real-time efficiency is
        // more than τ below the best.
        let best_thpt = bytes as f64 / t_cold;
        let mut candidates = std::mem::take(&mut self.scratch.candidates);
        candidates.clear();
        candidates.extend(
            ests.iter()
                .filter(|&&(_, t)| best_thpt / (bytes as f64 / t) <= self.cfg.tau)
                .copied(),
        );
        if candidates.len() < 2 {
            self.scratch.ests = ests;
            self.scratch.candidates = candidates;
            self.note_cold(bucket_key);
            out.push((best_rail, 1.0));
            return PlanKind::Cold;
        }

        // Eq. 6 crossing test: hot optimum (incl. sync overhead) vs cold.
        let mut parts = std::mem::take(&mut self.scratch.parts);
        parts.clear();
        parts.extend(candidates.iter().map(|&(r, t_full)| {
            let setup = self.est_setup(fab, r).min(t_full);
            (r, setup, (t_full - setup).max(1e-6))
        }));
        let mut active = std::mem::take(&mut self.scratch.active);
        // the waterfill optimum lands directly in `out` (overwritten below
        // when the stored α table takes precedence)
        let t_hot_opt = Self::waterfill_into(&parts, &mut active, out);
        if t_hot_opt + sync_overhead_us(out.len()) >= t_cold {
            self.scratch.ests = ests;
            self.scratch.candidates = candidates;
            self.scratch.parts = parts;
            self.scratch.active = active;
            self.note_cold(bucket_key);
            out.clear();
            out.push((best_rail, 1.0));
            return PlanKind::Cold;
        }

        // Hot start: use (and create) the data-length-table entry.
        let bucket = self.buckets.entry(bucket_key).or_insert_with(|| {
            // Eq. 8 initialization: α_i0 = (T - T_i) / (T (N-1)), computed
            // over the candidate full-payload estimates...
            let t_sum: f64 = candidates.iter().map(|(_, t)| t).sum();
            let n = candidates.len() as f64;
            let mut alphas: HashMap<usize, f64> = candidates
                .iter()
                .map(|&(r, t)| (r, ((t_sum - t) / (t_sum * (n - 1.0))).max(0.01)))
                .collect();
            normalize(&mut alphas);
            Bucket { alphas, converged_streak: 0, iters: 0, last_state_hot: true }
        });
        bucket.last_state_hot = true;

        // restrict stored α to currently-healthy candidates, renormalize;
        // if the stored table had none of these rails, keep the waterfill
        // optimum already sitting in `out`
        let total: f64 = candidates
            .iter()
            .map(|&(r, _)| bucket.alphas.get(&r).copied().unwrap_or(0.0))
            .sum();
        if total >= 1e-9 {
            out.clear();
            out.extend(
                candidates
                    .iter()
                    .map(|&(r, _)| (r, bucket.alphas.get(&r).copied().unwrap_or(0.0) / total)),
            );
        }
        self.scratch.ests = ests;
        self.scratch.candidates = candidates;
        self.scratch.parts = parts;
        self.scratch.active = active;
        PlanKind::Hot
    }

    fn note_cold(&mut self, bucket_key: u32) {
        if let Some(b) = self.buckets.get_mut(&bucket_key) {
            b.last_state_hot = false;
        }
    }

    /// Feed back one completed multi-rail op: per-rail (bytes, time_us).
    /// Updates measurement corrections and performs one Eq. 7 subgradient
    /// step on the α table.
    pub fn feedback(&mut self, fab: &Fabric, bytes: u64, shares: &[(usize, u64, f64)]) {
        let key = size_bucket(bytes);
        // measurement correction: measured/model per rail for its share
        for &(rail, b, t) in shares {
            if b == 0 || t <= 0.0 {
                continue;
            }
            let model = fab.estimate_allreduce_us(rail, b as f64);
            if model > 0.0 {
                let ratio = (t / model).clamp(0.2, 5.0);
                let c = self.corr.entry((rail, key)).or_insert(1.0);
                *c = 0.8 * *c + 0.2 * ratio;
            }
        }
        if shares.len() < 2 {
            return;
        }
        let Some(bucket) = self.buckets.get_mut(&key) else {
            return;
        };
        bucket.iters += 1;
        // subgradient of T_hot = max_i(...): move allocation from the
        // slowest rail toward the fastest, step ∝ relative imbalance
        let (slow, t_slow) = shares
            .iter()
            .map(|&(r, _, t)| (r, t))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let (fast, t_fast) = shares
            .iter()
            .map(|&(r, _, t)| (r, t))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        if t_slow <= 0.0 {
            return;
        }
        let imbalance = (t_slow - t_fast) / t_slow;
        if imbalance < 0.05 {
            bucket.converged_streak += 1;
            return;
        }
        bucket.converged_streak = 0;
        let a_slow = bucket.alphas.entry(slow).or_insert(0.5);
        let delta = (self.cfg.eta * imbalance * *a_slow).min(*a_slow - 0.005);
        if delta <= self.cfg.alpha_tol {
            bucket.converged_streak += 1;
            return;
        }
        *a_slow -= delta;
        *bucket.alphas.entry(fast).or_insert(0.5) += delta;
        normalize(&mut bucket.alphas);
    }

    /// The balancer's own measured/model correction for a (rail, size
    /// class), 1.0 until feedback arrives — exposed so reports and the
    /// straggler tests can see that a slow rail's estimates inflated
    /// (share adaptation), independently of the planner's schedule-level
    /// corrections.
    pub fn correction(&self, rail: usize, bytes: u64) -> f64 {
        self.corr
            .get(&(rail, size_bucket(bytes)))
            .copied()
            .unwrap_or(1.0)
    }

    /// Observable state for a size class (Fig. 11's allocation ratios).
    pub fn state(&self, bytes: u64) -> BalancerState {
        match self.buckets.get(&size_bucket(bytes)) {
            Some(b) if b.last_state_hot => {
                let mut alphas: Vec<(usize, f64)> =
                    b.alphas.iter().map(|(&r, &a)| (r, a)).collect();
                alphas.sort_by_key(|(r, _)| *r);
                BalancerState::Hot { alphas, converged: b.converged_streak >= 3 }
            }
            _ => BalancerState::Cold,
        }
    }

    /// Smallest payload (scanning power-of-two sizes) for which the plan
    /// goes hot — the live S_threshold of Eq. 6.
    pub fn threshold_bytes(&mut self, fab: &Fabric, timer: &Timer, healthy: &[usize]) -> u64 {
        for p in 10..=26 {
            let s = 1u64 << p;
            if matches!(self.plan(fab, timer, healthy, s), Plan::Hot { .. }) {
                return s;
            }
        }
        u64::MAX
    }

    pub fn iterations(&self, bytes: u64) -> u64 {
        self.buckets.get(&size_bucket(bytes)).map(|b| b.iters).unwrap_or(0)
    }
}

fn normalize(alphas: &mut HashMap<usize, f64>) {
    let total: f64 = alphas.values().sum();
    if total > 0.0 {
        for a in alphas.values_mut() {
            *a /= total;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::{ProtoKind, KB, MB};
    use crate::net::topology::ClusterSpec;

    fn fab(kinds: &[ProtoKind], nodes: usize) -> Fabric {
        let rails = ClusterSpec::local().build_rails(kinds).unwrap();
        Fabric::new(nodes, rails, CpuPool::default(), 3).deterministic()
    }

    fn lb() -> LoadBalancer {
        LoadBalancer::new(ControlConfig::default())
    }

    #[test]
    fn small_payloads_go_cold() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4);
        let t = Timer::new(100);
        let mut b = lb();
        let plan = b.plan(&f, &t, &[0, 1], 2 * KB as u64);
        assert!(matches!(plan, Plan::Cold { .. }), "{plan:?}");
    }

    #[test]
    fn large_payloads_go_hot_evenly_on_homogeneous_rails() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4);
        let t = Timer::new(100);
        let mut b = lb();
        match b.plan(&f, &t, &[0, 1], 8 * MB as u64) {
            Plan::Hot { shares } => {
                assert_eq!(shares.len(), 2);
                for (_, a) in &shares {
                    assert!((a - 0.5).abs() < 0.05, "{shares:?}");
                }
            }
            p => panic!("expected hot: {p:?}"),
        }
    }

    #[test]
    fn threshold_in_paper_band_for_dual_tcp() {
        // paper Fig. 9: 256 KB at 4 nodes, 128 KB at 8 nodes
        let f4 = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4);
        let t = Timer::new(100);
        let mut b = lb();
        let th4 = b.threshold_bytes(&f4, &t, &[0, 1]);
        assert!(
            (64 * KB as u64..=512 * KB as u64).contains(&th4),
            "threshold {th4}"
        );
    }

    #[test]
    fn cold_start_picks_rdma_for_small_heterogeneous() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Sharp], 4);
        let t = Timer::new(100);
        let mut b = lb();
        match b.plan(&f, &t, &[0, 1], 4 * KB as u64) {
            Plan::Cold { rail } => assert_eq!(rail, 1, "should pick SHARP"),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn tau_filter_excludes_very_slow_rail() {
        // At tiny sizes SHARP vs TCP throughput ratio >> τ=5 → no split.
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Sharp], 4);
        let t = Timer::new(100);
        let b = lb();
        let rho = b.efficiency_ratio(&f, &[0, 1], 32 * KB as u64);
        assert!(rho > 5.0, "rho {rho}");
        let mut b = lb();
        assert!(matches!(
            b.plan(&f, &t, &[0, 1], 32 * KB as u64),
            Plan::Cold { .. }
        ));
    }

    #[test]
    fn heterogeneous_hot_shares_favor_faster_rail() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Glex], 4);
        let t = Timer::new(100);
        let mut b = lb();
        match b.plan(&f, &t, &[0, 1], 16 * MB as u64) {
            Plan::Hot { shares } => {
                let tcp = shares.iter().find(|(r, _)| *r == 0).unwrap().1;
                let glex = shares.iter().find(|(r, _)| *r == 1).unwrap().1;
                assert!(glex > tcp, "glex {glex} tcp {tcp}");
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn feedback_rebalances_toward_fast_rail() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4);
        let t = Timer::new(100);
        let mut b = lb();
        let bytes = 8 * MB as u64;
        let Plan::Hot { shares } = b.plan(&f, &t, &[0, 1], bytes) else {
            panic!()
        };
        let a0_before = shares.iter().find(|(r, _)| *r == 0).unwrap().1;
        // pretend rail 0 is consistently 2x slower than rail 1
        for _ in 0..20 {
            b.feedback(&f, bytes, &[(0, bytes / 2, 20_000.0), (1, bytes / 2, 10_000.0)]);
        }
        let Plan::Hot { shares } = b.plan(&f, &t, &[0, 1], bytes) else {
            panic!()
        };
        let a0_after = shares.iter().find(|(r, _)| *r == 0).unwrap().1;
        assert!(a0_after < a0_before - 0.1, "before {a0_before} after {a0_after}");
    }

    #[test]
    fn correction_learns_slow_rail() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4);
        let mut b = lb();
        let bytes = 8 * MB as u64;
        assert_eq!(b.correction(0, bytes), 1.0, "no feedback yet");
        // rail 0 consistently measures 2x its model estimate
        let model = f.estimate_allreduce_us(0, (bytes / 2) as f64);
        for _ in 0..30 {
            b.feedback(&f, bytes, &[(0, bytes / 2, 2.0 * model), (1, bytes / 2, model)]);
        }
        assert!(b.correction(0, bytes) > 1.5, "c0 {}", b.correction(0, bytes));
        assert!((b.correction(1, bytes) - 1.0).abs() < 0.1, "c1 {}", b.correction(1, bytes));
    }

    #[test]
    fn feedback_converges_when_balanced() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4);
        let t = Timer::new(100);
        let mut b = lb();
        let bytes = 8 * MB as u64;
        let _ = b.plan(&f, &t, &[0, 1], bytes);
        for _ in 0..5 {
            b.feedback(&f, bytes, &[(0, bytes / 2, 10_000.0), (1, bytes / 2, 10_100.0)]);
        }
        match b.state(bytes) {
            BalancerState::Hot { converged, .. } => assert!(converged),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn alpha_fractions_always_normalized() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Glex], 8);
        let t = Timer::new(100);
        let mut b = lb();
        for p in 19..=26 {
            if let Plan::Hot { shares } = b.plan(&f, &t, &[0, 1], 1 << p) {
                let sum: f64 = shares.iter().map(|(_, a)| a).sum();
                assert!((sum - 1.0).abs() < 1e-9, "p={p} sum={sum}");
            }
        }
    }

    #[test]
    fn soft_affinity_weights_shift_hot_shares() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4);
        let t = Timer::new(100);
        let bytes = 8 * MB as u64;
        // rail 1 admitted by half the groups: estimates double, the
        // waterfill/Eq. 8 split shifts toward the universal rail
        let mut b = lb();
        b.set_rail_weights(&[(0, 1.0), (1, 0.5)]);
        match b.plan(&f, &t, &[0, 1], bytes) {
            Plan::Hot { shares } => {
                let a0 = shares.iter().find(|(r, _)| *r == 0).unwrap().1;
                let a1 = shares.iter().find(|(r, _)| *r == 1).unwrap().1;
                assert!(a0 > a1 + 0.1, "{shares:?}");
            }
            p => panic!("expected hot: {p:?}"),
        }
        // weight 1.0 entries clear back to the unweighted even split
        let mut c = lb();
        c.set_rail_weights(&[(0, 1.0), (1, 0.5)]);
        c.set_rail_weights(&[(0, 1.0), (1, 1.0)]);
        match c.plan(&f, &t, &[0, 1], bytes) {
            Plan::Hot { shares } => {
                for (_, a) in &shares {
                    assert!((a - 0.5).abs() < 0.05, "{shares:?}");
                }
            }
            p => panic!("expected hot: {p:?}"),
        }
        // a nearly-banned rail (5% of groups) exits through the τ filter
        let mut d = lb();
        d.set_rail_weights(&[(1, 0.05)]);
        assert_eq!(d.plan(&f, &t, &[0, 1], bytes), Plan::Cold { rail: 0 });
    }

    #[test]
    fn single_rail_is_always_cold() {
        let f = fab(&[ProtoKind::Tcp], 4);
        let t = Timer::new(100);
        let mut b = lb();
        assert_eq!(b.plan(&f, &t, &[0], 64 * MB as u64), Plan::Cold { rail: 0 });
    }

    #[test]
    fn waterfill_equalizes() {
        let mut active = Vec::new();
        let mut alphas = Vec::new();
        let t = LoadBalancer::waterfill_into(
            &[(0, 100.0, 10000.0), (1, 50.0, 5000.0)],
            &mut active,
            &mut alphas,
        );
        for (r, a) in &alphas {
            let (s, x) = if *r == 0 { (100.0, 10000.0) } else { (50.0, 5000.0) };
            assert!((s + a * x - t).abs() < 1e-6);
        }
        let sum: f64 = alphas.iter().map(|(_, a)| a).sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn plan_into_matches_allocating_plan() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Glex], 4);
        let t = Timer::new(100);
        let mut a = lb();
        let mut b = lb();
        let mut out = Vec::new();
        for p in 11..=26 {
            let bytes = 1u64 << p;
            let plan = a.plan(&f, &t, &[0, 1], bytes);
            let kind = b.plan_into(&f, &t, &[0, 1], bytes, &mut out);
            match (plan, kind) {
                (Plan::Cold { rail }, PlanKind::Cold) => {
                    assert_eq!(out, vec![(rail, 1.0)], "bytes {bytes}");
                }
                (Plan::Hot { shares }, PlanKind::Hot) => {
                    assert_eq!(out, shares, "bytes {bytes}");
                }
                (p, k) => panic!("bytes {bytes}: kind mismatch {p:?} vs {k:?}"),
            }
        }
    }
}
