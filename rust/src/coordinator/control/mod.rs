//! Control Module (paper §3.5): NIC Selector, Timer, Load Balancer and
//! Exception Handler — the control plane coordinating multi-rail
//! collaboration.

pub mod exception;
pub mod health;
pub mod load_balancer;
pub mod nic_selector;
pub mod timer;

pub use exception::{ExceptionHandler, FailoverEvent, GrayAction, GrayEvent, MembershipRecovery};
pub use health::{HealthAction, HealthConfig, HealthMode, HealthMonitor, HealthTransition};
pub use load_balancer::{BalancerState, LoadBalancer, Plan, PlanKind};
pub use nic_selector::NicSelector;
pub use timer::Timer;

/// Size bucket key: per-bucket state tables (the paper's "data length
/// table") are keyed by power-of-two payload class.
pub fn size_bucket(bytes: u64) -> u32 {
    63 - bytes.max(1).leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets() {
        assert_eq!(size_bucket(1024), 10);
        assert_eq!(size_bucket(1025), 10);
        assert_eq!(size_bucket(2048), 11);
        assert_eq!(size_bucket(0), 0);
    }
}
