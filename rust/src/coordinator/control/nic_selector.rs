//! NIC Selector (paper §3.5): maps the requested protocol combination to
//! concrete NIC devices and creates the member-network contexts.

use crate::coordinator::context::{context_for, Context};
use crate::net::protocol::ProtoKind;
use crate::net::rail::Rail;
use crate::net::topology::ClusterSpec;
use crate::Result;

/// Device selection + context creation for a multi-rail combination.
#[derive(Debug)]
pub struct NicSelector {
    pub cluster: ClusterSpec,
}

impl NicSelector {
    pub fn new(cluster: ClusterSpec) -> NicSelector {
        NicSelector { cluster }
    }

    /// Select devices for `combo` and build (rails, contexts) for a
    /// communication domain of `nodes` members. Falls back to virtual
    /// channels when the node has fewer NICs than requested rails
    /// (paper §4.1's virtual multi-rail).
    pub fn select(
        &self,
        combo: &[ProtoKind],
        nodes: usize,
    ) -> Result<(Vec<Rail>, Vec<Box<dyn Context>>)> {
        let rails = match self.cluster.build_rails(combo) {
            Ok(r) => r,
            Err(e) => {
                // virtual multi-rail fallback: homogeneous TCP combos can
                // multiplex one physical NIC
                let all_tcp = combo.iter().all(|k| *k == ProtoKind::Tcp);
                if all_tcp && combo.len() > 1 {
                    self.cluster.build_virtual_rails(ProtoKind::Tcp, combo.len())?
                } else {
                    return Err(e);
                }
            }
        };
        let contexts = rails.iter().map(|r| context_for(r, nodes)).collect();
        Ok((rails, contexts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_physical_rails_on_local() {
        let s = NicSelector::new(ClusterSpec::local());
        let (rails, ctxs) = s.select(&[ProtoKind::Tcp, ProtoKind::Sharp], 4).unwrap();
        assert_eq!(rails.len(), 2);
        assert_eq!(ctxs.len(), 2);
        assert_eq!(ctxs[1].transport(), "ibverbs");
        assert!(ctxs.iter().all(|c| c.ready()));
    }

    #[test]
    fn cloud_dual_tcp_falls_back_to_virtual() {
        // cloud nodes have a single Ethernet NIC: dual TCP must multiplex
        let s = NicSelector::new(ClusterSpec::cloud());
        let (rails, _) = s.select(&[ProtoKind::Tcp, ProtoKind::Tcp], 4).unwrap();
        assert_eq!(rails.len(), 2);
        assert_eq!(rails[0].nic_sharing, 2);
    }

    #[test]
    fn impossible_combo_rejected() {
        let s = NicSelector::new(ClusterSpec::local());
        assert!(s.select(&[ProtoKind::Sharp, ProtoKind::Sharp], 4).is_err());
    }
}
