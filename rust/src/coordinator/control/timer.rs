//! Timer (paper §3.5/§4.2): monitors per-network operation cost.
//!
//! Records the cost of every member network's share of each allreduce,
//! keyed by (rail, size bucket). To damp fluctuation-driven decision
//! errors, the Timer reports the average of every `window` (paper: 100)
//! same-size operations to the Load Balancer; until a window completes it
//! serves the running average.

use std::collections::HashMap;

use crate::coordinator::control::size_bucket;

#[derive(Debug, Clone, Default)]
struct Acc {
    /// Completed-window average (what the Load Balancer sees).
    reported: Option<f64>,
    /// Current window accumulation.
    sum: f64,
    count: usize,
    /// Lifetime totals for metrics.
    total_ops: u64,
}

/// Per-(rail, size-bucket) cost tracker.
#[derive(Debug, Clone)]
pub struct Timer {
    window: usize,
    accs: HashMap<(usize, u32), Acc>,
}

impl Timer {
    pub fn new(window: usize) -> Timer {
        Timer { window: window.max(1), accs: HashMap::new() }
    }

    /// Record one operation: `rail` processed `bytes` in `us`.
    pub fn record(&mut self, rail: usize, bytes: u64, us: f64) {
        let acc = self
            .accs
            .entry((rail, size_bucket(bytes)))
            .or_default();
        acc.sum += us;
        acc.count += 1;
        acc.total_ops += 1;
        if acc.count >= self.window {
            acc.reported = Some(acc.sum / acc.count as f64);
            acc.sum = 0.0;
            acc.count = 0;
        }
    }

    /// Cost estimate for `rail` at this payload class: the last completed
    /// window average, else the running average, else None.
    pub fn cost(&self, rail: usize, bytes: u64) -> Option<f64> {
        let acc = self.accs.get(&(rail, size_bucket(bytes)))?;
        match acc.reported {
            Some(r) => Some(r),
            None if acc.count > 0 => Some(acc.sum / acc.count as f64),
            None => None,
        }
    }

    /// True once a full window has been reported for this class.
    pub fn warmed_up(&self, rail: usize, bytes: u64) -> bool {
        self.accs
            .get(&(rail, size_bucket(bytes)))
            .map(|a| a.reported.is_some())
            .unwrap_or(false)
    }

    pub fn total_ops(&self, rail: usize) -> u64 {
        self.accs
            .iter()
            .filter(|((r, _), _)| *r == rail)
            .map(|(_, a)| a.total_ops)
            .sum()
    }

    /// Forget a rail's history (after failover the channel's behaviour may
    /// have changed; §4.4).
    pub fn forget_rail(&mut self, rail: usize) {
        self.accs.retain(|(r, _), _| *r != rail);
    }

    /// Forget one (rail, size-class) history — used when a replan switches
    /// the rail's schedule for that class: the old schedule's window
    /// averages no longer describe what will run, so the class re-warms
    /// under the new schedule before corrections re-engage.
    pub fn forget_class(&mut self, rail: usize, bytes: u64) {
        self.accs.remove(&(rail, size_bucket(bytes)));
    }

    /// Warm-start repricing through a membership rebind: the collective
    /// round count scales with the node count (a ring runs `2(n-1)`
    /// rounds), so carried windows are rescaled by `factor` (new rounds /
    /// old rounds) instead of being wiped — every surviving rail keeps a
    /// live prior priced for the new membership and re-converges from it
    /// rather than from cold. Both the reported window averages and the
    /// in-flight accumulation scale; lifetime op counts are history and
    /// stay.
    pub fn rescale(&mut self, factor: f64) {
        debug_assert!(factor.is_finite() && factor > 0.0);
        for acc in self.accs.values_mut() {
            acc.sum *= factor;
            if let Some(r) = acc.reported.as_mut() {
                *r *= factor;
            }
        }
    }

    /// The averaging window length (paper default: 100).
    pub fn window(&self) -> usize {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_average_until_window() {
        let mut t = Timer::new(4);
        t.record(0, 1024, 100.0);
        t.record(0, 1024, 200.0);
        assert_eq!(t.cost(0, 1024), Some(150.0));
        assert!(!t.warmed_up(0, 1024));
    }

    #[test]
    fn window_average_reported() {
        let mut t = Timer::new(3);
        for us in [100.0, 200.0, 300.0] {
            t.record(0, 1024, us);
        }
        assert_eq!(t.cost(0, 1024), Some(200.0));
        assert!(t.warmed_up(0, 1024));
        // new window in progress doesn't disturb the reported value
        t.record(0, 1024, 1000.0);
        assert_eq!(t.cost(0, 1024), Some(200.0));
    }

    #[test]
    fn buckets_are_independent() {
        let mut t = Timer::new(2);
        t.record(0, 1024, 10.0);
        t.record(0, 4096, 99.0);
        assert_eq!(t.cost(0, 1500), Some(10.0)); // same 1K bucket
        assert_eq!(t.cost(0, 4096), Some(99.0));
        assert_eq!(t.cost(1, 1024), None);
    }

    #[test]
    fn forget_rail_clears() {
        let mut t = Timer::new(1);
        t.record(2, 1024, 5.0);
        assert!(t.cost(2, 1024).is_some());
        t.forget_rail(2);
        assert!(t.cost(2, 1024).is_none());
    }

    #[test]
    fn forget_class_clears_only_that_class() {
        let mut t = Timer::new(1);
        t.record(0, 1024, 5.0);
        t.record(0, 4096, 9.0);
        t.forget_class(0, 1500); // same 1K bucket as the first record
        assert!(t.cost(0, 1024).is_none());
        assert_eq!(t.cost(0, 4096), Some(9.0));
        assert_eq!(t.window(), 1);
    }

    #[test]
    fn rescale_reprices_reported_and_running_windows() {
        let mut t = Timer::new(2);
        t.record(0, 1024, 100.0);
        t.record(0, 1024, 200.0); // reported = 150
        t.record(0, 4096, 80.0); // running only
        t.rescale(0.5);
        assert_eq!(t.cost(0, 1024), Some(75.0));
        assert_eq!(t.cost(0, 4096), Some(40.0));
        assert!(t.warmed_up(0, 1024), "warm state survives the repricing");
        assert_eq!(t.total_ops(0), 3, "lifetime counts are history, not priced");
    }

    #[test]
    fn total_ops_counts_lifetime() {
        let mut t = Timer::new(2);
        for _ in 0..5 {
            t.record(1, 64, 1.0);
        }
        assert_eq!(t.total_ops(1), 5);
    }
}
