//! The Nezha coordinator (paper §3–§4): the four system modules plus the
//! multi-rail orchestrator.
//!
//! * [`context`] — per-protocol Context objects + the cross-protocol
//!   `UnboundBuffer` shared-buffer mechanism (§3.2).
//! * [`transport`] — rendezvous + Pair point-to-point endpoints with
//!   GLEX-style pending-request queues (§3.3).
//! * [`collective`] — allreduce implementations: ring, ring-chunked,
//!   in-network tree (§3.4).
//! * [`control`] — NIC Selector, Timer, Load Balancer (cold/hot state
//!   machine, Eqs. 4–8) and Exception Handler (§3.5, §4.3, §4.4).
//! * [`planner`] — the topology-aware collective planner: turns the Load
//!   Balancer's per-rail shares into an executable [`CollectivePlan`]
//!   (flat ring / chunk-pipelined ring / halving-doubling / hierarchical
//!   two-level / tree) via an α-β cost model.
//! * [`multirail`] — the orchestrator that partitions each allreduce
//!   across rails, runs member-network collectives, handles failover and
//!   feeds measurements back to the control plane (§4.2, Fig. 7).
//! * [`arbiter`] — the multi-tenant fabric arbiter: admits concurrent
//!   coordinators onto shared rails with priority classes, fair-share
//!   grants and window-boundary preemption (DESIGN.md §9).

pub mod arbiter;
pub mod buffer;
pub mod collective;
pub mod context;
pub mod control;
pub mod multirail;
pub mod planner;
pub mod transport;

pub use arbiter::{ArbiterMode, FabricArbiter, JobId, JobSpec, PriorityClass};
pub use buffer::{UnboundBuffer, Window};
pub use multirail::{MultiRail, OpReport};
pub use planner::{CollectivePlan, CorrectedCost, PlanQualityReport, Planner, Schedule};
