//! Multi-rail allreduce orchestrator (paper §4.2, Fig. 7).
//!
//! One [`MultiRail`] instance owns the fabric, the member-network contexts
//! and the control plane. Each `allreduce` call:
//!
//! 1. probes deregistered rails for recovery,
//! 2. asks the partitioning policy (Nezha's Load Balancer or a baseline)
//!    for the per-rail shares (written into reusable [`Shares`] scratch),
//! 3. hands the shares to the topology-aware collective planner, which
//!    emits an executable [`CollectivePlan`] (per-rail schedule: flat or
//!    chunk-pipelined ring, halving-doubling, hierarchical two-level, or
//!    in-network tree),
//! 4. registers per-rail `(ptr, data_length)` windows on the
//!    `UnboundBuffer` and runs each member network's planned collective —
//!    serially, or (under `exec = parallel`) concurrently on scoped
//!    worker threads, each driving a borrow-split `RailCtx` timing view
//!    and a disjoint `RailView` of the buffer,
//! 5. on a rail failure, lets the Exception Handler deregister the rail
//!    and migrate the window to the optimal survivor (re-planned for the
//!    takeover rail),
//! 6. charges cross-rail synchronization overhead, advances the virtual
//!    clock, and feeds measurements back to the Timer + policy.
//!
//! Parallel execution is bit-identical to serial: per-rail windows are
//! disjoint slices (the borrow checker proves the numerics never alias),
//! per-rail RNG streams are reseeded from `(seed, rail, op_epoch)` at
//! every [`crate::net::simnet::Fabric::begin_op`] so modeled times cannot
//! depend on cross-rail execution order, and results are merged in fixed
//! assignment order.
//!
//! `with_algo` / `force_algo` pin the seed's fixed `Algo` dispatch instead
//! of the planner — the planner-ablation baseline and the legacy
//! Ring/Ring_Chunked API used by the GPT replays.

use std::collections::HashMap;

use crate::config::{Config, PlannerMode, Policy};
use crate::coordinator::buffer::{UnboundBuffer, Window};
use crate::coordinator::collective::{
    run_allreduce_on, run_allreduce_with, Algo, OpOutcome, OpScratch, Reducer, RustReducer,
};
use crate::coordinator::context::Context;
use crate::coordinator::control::load_balancer::sync_overhead_us;
use crate::coordinator::control::{
    size_bucket, ExceptionHandler, GrayAction, HealthAction, HealthMonitor, LoadBalancer,
    MembershipRecovery, NicSelector, Timer,
};
use crate::coordinator::planner::{
    run_plan_on, run_plan_with, CollectivePlan, PlanQualityReport, Planner, RailPlan, Schedule,
};
use crate::coordinator::transport::Rendezvous;
use crate::net::cpu_pool::{CpuPool, ExecMode, RailExecutor};
use crate::net::fault::{
    CorruptSchedule, DegradeSchedule, FaultSchedule, MembershipEvent, MembershipSchedule,
};
use crate::net::rail::RailHealth;
use crate::net::simnet::{Fabric, RailDown};
use crate::net::topology::TopologyTree;
use crate::util::error::Error;
use crate::Result;

/// Reusable partitioning-decision buffer threaded through
/// [`Partitioner::plan`]: policies write their decision into caller-owned
/// scratch instead of returning a fresh vector per op, closing the last
/// planning-side allocation on the steady-state path.
#[derive(Debug, Clone, Default)]
pub struct Shares {
    /// Contiguous fractional shares per rail (fractions sum to 1).
    pub fracs: Vec<(usize, f64)>,
    /// When set, MPTCP-style fixed-size packet slicing overrides `fracs`.
    pub packet_bytes: Option<u64>,
}

impl Shares {
    pub fn clear(&mut self) {
        self.fracs.clear();
        self.packet_bytes = None;
    }

    /// The whole window on one rail (cold start / single survivor).
    pub fn set_single(&mut self, rail: usize) {
        self.clear();
        self.fracs.push((rail, 1.0));
    }

    /// MPTCP-style slicing decision.
    pub fn set_slices(&mut self, packet_bytes: u64) {
        self.clear();
        self.packet_bytes = Some(packet_bytes);
    }
}

/// A partitioning policy: Nezha's Load Balancer or one of the baselines
/// (`crate::baselines`).
pub trait Partitioner: std::fmt::Debug {
    fn name(&self) -> &'static str;
    /// Decide how `bytes` are spread over the healthy rails, writing the
    /// decision into `out` (cleared first). Allocation-free once `out`'s
    /// capacity has stabilized.
    fn plan(
        &mut self,
        fab: &Fabric,
        timer: &Timer,
        healthy: &[usize],
        bytes: u64,
        out: &mut Shares,
    );
    /// Completed-op feedback: per-rail (rail, bytes, time_us).
    fn feedback(&mut self, _fab: &Fabric, _bytes: u64, _shares: &[(usize, u64, f64)]) {}

    /// Soft-affinity rail weights — the fraction of topology groups
    /// admitting each rail (see [`MultiRail::soft_affinity`]). Policies
    /// without a weighting notion ignore it.
    fn set_rail_weights(&mut self, _weights: &[(usize, f64)]) {}

    /// Current (rail, α) table for this payload class, if the policy keeps
    /// one (Nezha's data-length table; used by the Fig. 11 report).
    fn alphas(&self, _bytes: u64) -> Option<Vec<(usize, f64)>> {
        None
    }
}

/// Nezha's partitioner: the Load Balancer state machine.
#[derive(Debug)]
pub struct NezhaPartitioner {
    pub balancer: LoadBalancer,
}

impl Partitioner for NezhaPartitioner {
    fn name(&self) -> &'static str {
        "Nezha"
    }

    fn plan(
        &mut self,
        fab: &Fabric,
        timer: &Timer,
        healthy: &[usize],
        bytes: u64,
        out: &mut Shares,
    ) {
        out.clear();
        self.balancer
            .plan_into(fab, timer, healthy, bytes, &mut out.fracs);
    }

    fn feedback(&mut self, fab: &Fabric, bytes: u64, shares: &[(usize, u64, f64)]) {
        self.balancer.feedback(fab, bytes, shares);
    }

    fn set_rail_weights(&mut self, weights: &[(usize, f64)]) {
        self.balancer.set_rail_weights(weights);
    }

    fn alphas(&self, bytes: u64) -> Option<Vec<(usize, f64)>> {
        match self.balancer.state(bytes) {
            crate::coordinator::control::BalancerState::Hot { alphas, .. } => Some(alphas),
            crate::coordinator::control::BalancerState::Cold => None,
        }
    }
}

/// Per-rail share of one completed op.
#[derive(Debug, Clone, Copy)]
pub struct RailShare {
    pub rail: usize,
    pub bytes: u64,
    pub time_us: f64,
}

/// Report for one multi-rail allreduce.
///
/// The `per_rail` vector is drawn from the coordinator's report pool;
/// steady-state callers hand it back through [`MultiRail::recycle`] so
/// the per-op path performs no allocation once capacities stabilize
/// (dropping the report instead is always safe — the pool just refills).
#[derive(Debug, Clone)]
pub struct OpReport {
    /// End-to-end modeled completion time (us), incl. sync + failover.
    pub total_us: f64,
    /// Modeled payload bytes.
    pub bytes: u64,
    pub per_rail: Vec<RailShare>,
    /// Number of failovers handled during this op.
    pub failovers: usize,
    /// Virtual time at op completion.
    pub completed_at_us: f64,
}

impl OpReport {
    /// Effective allreduce throughput in GB/s (payload / completion time).
    pub fn throughput_gbps(&self) -> f64 {
        crate::util::bytes::gbps(self.bytes, self.total_us)
    }
}

/// The coordinator facade: fabric + contexts + control plane + policy.
pub struct MultiRail {
    pub fab: Fabric,
    pub contexts: Vec<Box<dyn Context>>,
    pub rendezvous: Vec<Rendezvous>,
    pub timer: Timer,
    pub exceptions: ExceptionHandler,
    /// Gray-failure detector: per-rail suspicion from residuals + retry
    /// counts, hysteresis-thresholded into demote/quarantine/readmit
    /// actions applied at op boundaries.
    pub monitor: HealthMonitor,
    /// Soft-affinity base weights per rail (1.0 unconstrained). The Load
    /// Balancer receives the PRODUCT of these and the monitor's health
    /// weights — `set_rail_weights` is wholesale-replace, so both signals
    /// must be pushed together.
    affinity_weights: Vec<f64>,
    pub partitioner: Box<dyn Partitioner>,
    pub reducer: Box<dyn Reducer>,
    /// The topology-aware collective planner (schedules per-rail windows).
    pub planner: Planner,
    /// The cross-rail execution engine (`exec = serial | parallel`).
    pub executor: RailExecutor,
    /// Host-pool drain priority for the NEXT op's per-rail jobs (0 =
    /// drain first). The trainer's barrier-free scheduler sets it to the
    /// bucket's next-forward consumption priority before each collective;
    /// it reorders worker pickup only — results stay submission-ordered,
    /// so numerics and modeled times are unaffected.
    pub op_priority: u32,
    /// When set, bypasses the planner with the seed's fixed dispatch
    /// (`Algo::Ring` / `Algo::RingChunked`) on every ring-capable rail.
    forced_algo: Option<Algo>,
    /// The plan behind the most recent planner-scheduled op (None after
    /// MPTCP slicing ops and after forced-dispatch ops, where no planner
    /// schedule executed) — for benches, ablation reports and tests.
    pub last_plan: Option<CollectivePlan>,
    /// Per-plan predicted-vs-measured samples (planner-scheduled rail-ops
    /// only) — the plan-quality dashboard source.
    pub quality: PlanQualityReport,
    /// Cached schedule selections keyed by (membership epoch, size
    /// bucket, participating rail bitmask). Reused until a replan trigger
    /// fires: prediction error above `replan_error`, a failover changing
    /// the rail set, or a membership change making the epoch component
    /// stale (entries from older epochs describe a cluster that no longer
    /// exists and are dropped on rebind). (The rail set is a u64 bitmask
    /// so the per-op cache lookup builds no key vector.)
    plan_cache: HashMap<(u64, u32, u64), Vec<(usize, Schedule)>>,
    /// The `replan_error` config threshold.
    replan_error: f64,
    /// Rails allowed by every topology group's affinity mask (all-ones
    /// without affinity constraints). Rails outside it never carry
    /// collective payload and are never failover takeover targets: every
    /// rail-borne schedule spans all nodes, so a rail one group excludes
    /// is excluded for the whole op.
    rail_allow_mask: u64,
    /// Reusable per-op scratch (healthy rails, partitioner shares, plan
    /// windows, assignments, per-rail allocations, collective
    /// segment/chunk/aggregation lists, per-rail parallel scratch, pooled
    /// report vectors) — taken and restored around execution so the
    /// steady-state op path performs no per-op allocation.
    scratch: ExecScratch,
    ops_done: u64,
    /// Scheduled node join/leave churn, polled at op boundaries (an event
    /// landing mid-op is detected — like a rail fault — when the next op
    /// begins).
    membership: MembershipSchedule,
    /// Events of `membership` already applied (cursor).
    membership_applied: usize,
    /// Bumped on every applied membership change; the plan-cache key's
    /// epoch component and the planner's rebind coordinate.
    membership_epoch: u64,
    /// Currently-departed nodes, original (home) numbering.
    departed: Vec<usize>,
    /// The configured full-cluster node count (rebind baseline).
    home_nodes: usize,
    /// The configured full-cluster topology (rebind baseline — rebinding
    /// is always computed from the home tree over the current departed
    /// set, so leave→rejoin round-trips restore it exactly).
    home_topo: TopologyTree,
}

/// The coordinator's reusable per-op scratch space.
#[derive(Debug, Default)]
struct ExecScratch {
    healthy: Vec<usize>,
    shares: Shares,
    windows: Vec<Window>,
    assigns: Vec<RailPlan>,
    allocated: Vec<(usize, u64)>,
    feedback: Vec<(usize, u64, f64)>,
    /// Parallel path: non-empty windows/assignments/rails in assignment
    /// order (what the worker jobs are built from).
    live_windows: Vec<Window>,
    live_assigns: Vec<RailPlan>,
    live_rails: Vec<usize>,
    /// Per-rail retransmit-ledger snapshot at op start (the monitor
    /// consumes per-op deltas).
    retry_base: Vec<u64>,
    /// Reusable monitor-decision buffer.
    health_actions: Vec<HealthAction>,
    /// Serial-path collective scratch (also the failover takeover's).
    op: OpScratch,
    /// One collective scratch per parallel worker slot.
    rail_ops: Vec<OpScratch>,
    /// Recycled `OpReport::per_rail` vectors (see [`MultiRail::recycle`]).
    report_pool: Vec<Vec<RailShare>>,
}

/// Bitmask over the rails a share split touches — the allocation-free
/// plan-cache key component.
fn rail_mask(fracs: &[(usize, f64)]) -> u64 {
    let mut mask = 0u64;
    for &(r, _) in fracs {
        debug_assert!(r < 64, "rail index {r} exceeds the cache-key mask");
        mask |= 1u64 << r;
    }
    mask
}

impl std::fmt::Debug for MultiRail {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MultiRail")
            .field("nodes", &self.fab.nodes)
            .field("rails", &self.fab.rails.len())
            .field("policy", &self.partitioner.name())
            .field("exec", &self.executor.mode.name())
            .finish()
    }
}

impl MultiRail {
    /// Build the full coordinator from a [`Config`].
    pub fn new(cfg: &Config) -> Result<MultiRail> {
        let selector = NicSelector::new(cfg.cluster.clone());
        let (rails, contexts) = selector.select(&cfg.combo, cfg.nodes)?;
        let n_rails = rails.len();
        // bind the topology tree to the concrete cluster: non-dividing
        // group sizes, broken nesting and rail-emptying affinity masks are
        // construction errors, not silent flat fallbacks
        cfg.cluster.topo.validate(cfg.nodes, n_rails)?;
        // all-ones (not rails_mask-wide) when unconstrained, so the per-op
        // filter's fast path actually skips on affinity-free clusters
        let rail_allow_mask = if cfg.cluster.topo.has_affinity() {
            cfg.cluster.topo.allowed_rail_mask(n_rails)
        } else {
            u64::MAX
        };
        let mut exceptions = ExceptionHandler::new(cfg.control.clone());
        exceptions.set_rail_mask(rail_allow_mask);
        let cpu = CpuPool::new(cfg.cluster.node.cores, cfg.alloc);
        let mut fab = Fabric::new(cfg.nodes, rails, cpu, cfg.seed);
        if cfg.deterministic {
            fab = fab.deterministic();
        }
        if !cfg.faults.is_empty() {
            fab = fab.with_faults(cfg.faults.clone());
        }
        if !cfg.degrade.is_empty() {
            fab = fab.with_degrade(cfg.degrade.clone());
        }
        if !cfg.corrupt.is_empty() {
            fab = fab.with_corrupt(cfg.corrupt.clone());
        }
        fab = fab.with_integrity(cfg.integrity);
        let rendezvous = (0..n_rails)
            .map(|r| Rendezvous::full_mesh(r, cfg.nodes))
            .collect();
        let partitioner: Box<dyn Partitioner> = match cfg.policy {
            Policy::Nezha => Box::new(NezhaPartitioner {
                balancer: LoadBalancer::new(cfg.control.clone()),
            }),
            Policy::Mrib => Box::new(crate::baselines::Mrib::from_fabric(&fab)),
            Policy::Mptcp => Box::new(crate::baselines::Mptcp::default()),
            Policy::SingleRail => Box::new(crate::baselines::SingleRail::best()),
        };
        let forced_algo = match cfg.planner {
            PlannerMode::Auto | PlannerMode::StaticCost => None,
            PlannerMode::Flat => Some(Algo::Ring),
        };
        let mut planner = Planner::from_cluster(&cfg.cluster);
        planner.use_corrections = cfg.planner != PlannerMode::StaticCost;
        Ok(MultiRail {
            fab,
            contexts,
            rendezvous,
            timer: Timer::new(cfg.control.timer_window),
            exceptions,
            monitor: HealthMonitor::new(cfg.health.clone(), n_rails),
            affinity_weights: vec![1.0; n_rails],
            partitioner,
            reducer: Box::new(RustReducer),
            planner,
            executor: RailExecutor::new(cfg.exec),
            op_priority: 0,
            forced_algo,
            last_plan: None,
            quality: PlanQualityReport::default(),
            plan_cache: HashMap::new(),
            replan_error: cfg.control.replan_error,
            rail_allow_mask,
            scratch: ExecScratch::default(),
            ops_done: 0,
            membership: MembershipSchedule::none(),
            membership_applied: 0,
            membership_epoch: 0,
            departed: Vec::new(),
            home_nodes: cfg.nodes,
            home_topo: cfg.cluster.topo.clone(),
        })
    }

    /// Healthy rails that every topology group's affinity mask admits —
    /// the rail set partitioning and planning operate over.
    fn healthy_allowed_into(&self, out: &mut Vec<usize>) {
        self.fab.healthy_rails_into(out);
        if self.rail_allow_mask != u64::MAX {
            let mask = self.rail_allow_mask;
            out.retain(|&r| mask & (1u64 << r) != 0);
        }
    }

    pub fn with_faults(mut self, faults: FaultSchedule) -> Self {
        self.fab = self.fab.with_faults(faults);
        self
    }

    /// Attach a gray-failure degradation schedule (loss / brownout /
    /// flap / windowed-stall windows — see
    /// [`crate::net::fault::DegradeSchedule`]).
    pub fn with_degrade(mut self, degrade: DegradeSchedule) -> Self {
        self.fab.set_degrade(degrade);
        self
    }

    /// Attach a silent-corruption schedule (bit-flip / duplicate /
    /// truncate / stuck-at windows — see
    /// [`crate::net::fault::CorruptSchedule`]).
    pub fn with_corrupt(mut self, corrupt: CorruptSchedule) -> Self {
        self.fab.set_corrupt(corrupt);
        self
    }

    /// Enable or disable the checksum-verified data plane (default on);
    /// off is the escape-rate ablation baseline.
    pub fn with_integrity(mut self, on: bool) -> Self {
        self.fab = self.fab.with_integrity(on);
        self
    }

    /// Attach a node join/leave schedule (builder form). Events are
    /// applied at op boundaries as the virtual clock passes them.
    pub fn with_membership(mut self, schedule: MembershipSchedule) -> Self {
        self.set_membership(schedule);
        self
    }

    /// Replace the membership schedule (resets the applied-event cursor;
    /// already-applied changes are NOT undone).
    pub fn set_membership(&mut self, schedule: MembershipSchedule) {
        self.membership = schedule;
        self.membership_applied = 0;
    }

    /// The current membership epoch (bumps on every applied join/leave).
    pub fn membership_epoch(&self) -> u64 {
        self.membership_epoch
    }

    /// Nodes currently participating (home count minus departures).
    pub fn active_nodes(&self) -> usize {
        self.fab.nodes
    }

    /// Nodes currently departed, original numbering (sorted not
    /// guaranteed; insertion order).
    pub fn departed_nodes(&self) -> &[usize] {
        &self.departed
    }

    /// Apply the departure of one node (original numbering) right now:
    /// rebind the topology over the survivors, bump the membership epoch,
    /// drop stale cached plans, reprime the measurement layer and charge
    /// one detection + migration budget.
    pub fn node_leave(&mut self, node: usize) -> Result<MembershipRecovery> {
        self.nodes_leave(&[node])
    }

    /// Apply the simultaneous departure of several nodes (a rack dying is
    /// ONE detection event): one rebind, one epoch bump, one recovery
    /// budget for the whole batch. On error (unknown/duplicate node, or
    /// the departures leave the topology unbindable) nothing changes.
    pub fn nodes_leave(&mut self, nodes: &[usize]) -> Result<MembershipRecovery> {
        if nodes.is_empty() {
            return Err(Error::Topology("empty departure batch".into()));
        }
        for &n in nodes {
            if n >= self.home_nodes {
                return Err(Error::Topology(format!(
                    "node {n} outside the {}-node cluster",
                    self.home_nodes
                )));
            }
            if self.departed.contains(&n) || nodes.iter().filter(|&&m| m == n).count() > 1 {
                return Err(Error::Topology(format!("node {n} already departed")));
            }
        }
        let restore = self.departed.len();
        self.departed.extend_from_slice(nodes);
        if let Err(e) = self.rebind_surviving_set() {
            self.departed.truncate(restore);
            return Err(e);
        }
        Ok(self.exceptions.handle_node_failure(
            &mut self.fab,
            nodes[0],
            nodes.len(),
            self.membership_epoch,
        ))
    }

    /// A departed node rejoins: rebind back toward the home topology
    /// (a full round-trip restores it exactly), bump the epoch, reprime,
    /// and charge the migration (no detection — joins are announced)
    /// budget. On error nothing changes.
    pub fn node_rejoin(&mut self, node: usize) -> Result<MembershipRecovery> {
        let pos = self
            .departed
            .iter()
            .position(|&n| n == node)
            .ok_or_else(|| Error::Topology(format!("node {node} is not departed")))?;
        let removed = self.departed.remove(pos);
        if let Err(e) = self.rebind_surviving_set() {
            self.departed.insert(pos, removed);
            return Err(e);
        }
        Ok(self
            .exceptions
            .handle_node_rejoin(&mut self.fab, node, self.membership_epoch))
    }

    /// Recompute every membership-dependent structure from the home
    /// topology and the current departed set. Pure until the rebind
    /// succeeds — a failed rebind mutates nothing, so callers can roll
    /// back their `departed` edit and keep running on the old membership.
    fn rebind_surviving_set(&mut self) -> Result<()> {
        let survivors = self.home_nodes - self.departed.len();
        if survivors < 2 {
            return Err(Error::Topology(format!(
                "membership change leaves {survivors} node(s); a collective needs 2"
            )));
        }
        let n_rails = self.fab.rails.len();
        let topo = self
            .home_topo
            .rebind(self.home_nodes, &self.departed, n_rails)?;
        // -- validated: mutate --
        self.rail_allow_mask = if topo.has_affinity() {
            topo.allowed_rail_mask(n_rails)
        } else {
            u64::MAX
        };
        self.exceptions.set_rail_mask(self.rail_allow_mask);
        let prev_nodes = self.fab.nodes;
        self.fab.set_nodes(survivors);
        self.rendezvous = (0..n_rails)
            .map(|r| Rendezvous::full_mesh(r, survivors))
            .collect();
        self.membership_epoch += 1;
        // Blink-style re-pack: the planner re-selects over the surviving
        // links/groups at the next op instead of replaying stale
        // candidates
        self.planner.rebind_membership(topo, self.membership_epoch);
        // warm-start rebinding: the per-(rail, size-class) round count
        // scaled with the node count (a ring runs 2(n-1) rounds), so the
        // carried Timer windows are repriced by the round ratio instead of
        // being wiped — surviving rails keep live priors through the
        // rebind and re-converge from them. Corrections are
        // model-vs-measured residuals against a baseline that just
        // changed, so those still clear and re-learn.
        if prev_nodes > 1 {
            self.timer
                .rescale((survivors - 1) as f64 / (prev_nodes - 1) as f64);
        }
        self.planner.corrections.clear();
        // epoch-keyed invalidation: only current-epoch entries survive
        // (none do right after a bump — the keying also bounds cache
        // growth across long churn histories)
        let epoch = self.membership_epoch;
        self.plan_cache.retain(|&(ep, _, _), _| ep == epoch);
        self.last_plan = None;
        Ok(())
    }

    /// Apply every scheduled membership event the virtual clock has
    /// passed (op-boundary detection: an event landing mid-op is applied
    /// when the op completes and the next one starts). The allreduce
    /// entry point calls this itself; it is public so callers that size
    /// payload buffers by [`MultiRail::active_nodes`] (the trainers) can
    /// synchronize BEFORE building the next op's buffer — polling twice
    /// is harmless (the cursor only moves once per event).
    pub fn poll_membership(&mut self) -> Result<()> {
        while self.membership_applied < self.membership.len() {
            let ev = self.membership.event(self.membership_applied);
            if ev.at_us() > self.fab.now_us() {
                break;
            }
            self.membership_applied += 1;
            match ev {
                MembershipEvent::Leave { node, .. } => self.node_leave(node)?,
                MembershipEvent::Join { node, .. } => self.node_rejoin(node)?,
            };
        }
        Ok(())
    }

    /// Push the composed per-rail weights (soft-affinity fraction ×
    /// health-state multiplier) to the partitioner. `set_rail_weights` is
    /// wholesale-replace, so every transition re-pushes the full product
    /// vector.
    fn push_rail_weights(&mut self) {
        let weights: Vec<(usize, f64)> = (0..self.fab.rails.len())
            .map(|r| {
                let h = self.monitor.weight_for(self.fab.rails[r].health);
                (r, self.affinity_weights[r] * h)
            })
            .collect();
        self.partitioner.set_rail_weights(&weights);
    }

    /// Probe quarantined rails and clear a readmitted rail's failure-era
    /// state: Timer windows, cost corrections and injected straggler
    /// stalls all described the broken rail, and keeping them meant a
    /// healed rail never re-earned round-heavy schedules (it stayed
    /// priced as broken forever). A readmission also flushes cached
    /// selections and starts a fresh selection epoch — the rail set
    /// changed just as it does on failover.
    ///
    /// With the monitor on, readmission goes through **Probation**: the
    /// quarantine dwell must have passed (doubling after every failed
    /// probation) and the rail comes back as a canary at
    /// `probation_weight` share — promoted to Healthy only after
    /// `probation_ops` clean ops. With the monitor off this is the legacy
    /// trust-on-readmit path.
    fn probe_readmitted(&mut self) -> Vec<usize> {
        let back = if self.monitor.enabled() {
            let now = self.fab.now_us();
            let mut back = Vec::new();
            for r in 0..self.fab.rails.len() {
                if self.fab.rails[r].health == RailHealth::Quarantined
                    && !self.fab.faults.is_down(r, now)
                    && !self.fab.degrade.flap_down(r, now)
                    && self.monitor.probation_eligible(r, now)
                {
                    self.fab.readmit_probation(r);
                    self.monitor.note_probation(r);
                    self.monitor
                        .record_transition(now, r, RailHealth::Quarantined, RailHealth::Probation);
                    self.exceptions
                        .record_gray(&mut self.fab, r, GrayAction::Probation, 0.0);
                    back.push(r);
                }
            }
            back
        } else {
            self.exceptions.probe_recovery(&mut self.fab)
        };
        if !back.is_empty() {
            for &r in &back {
                self.timer.forget_rail(r);
                self.planner.corrections.forget_rail(r);
                self.fab.clear_straggler(r);
            }
            self.push_rail_weights();
            self.plan_cache.clear();
            self.planner.bump_epoch();
        }
        back
    }

    /// Execute one monitor decision: soft demotion / restoration adjusts
    /// the Load-Balancer weights and replans; quarantine rides the §4.4
    /// deregistration path (charging migration). A quarantine that would
    /// take out the last usable allowed rail falls back to demotion —
    /// limping beats dead.
    fn apply_health_action(&mut self, action: HealthAction) {
        match action {
            HealthAction::Demote(r) => self.demote_rail(r),
            HealthAction::Restore(r) => {
                let from = self.fab.rails[r].health;
                if self.fab.rails[r].transition(RailHealth::Healthy) {
                    let now = self.fab.now_us();
                    let gray = if from == RailHealth::Probation {
                        GrayAction::Readmit
                    } else {
                        GrayAction::Restore
                    };
                    self.monitor.record_transition(now, r, from, RailHealth::Healthy);
                    let s = self.monitor.suspicion(r);
                    self.exceptions.record_gray(&mut self.fab, r, gray, s);
                    self.push_rail_weights();
                    self.plan_cache.clear();
                    self.planner.bump_epoch();
                }
            }
            HealthAction::Quarantine(r) => {
                let mask = self.rail_allow_mask;
                let survivors = self
                    .fab
                    .healthy_rails_iter()
                    .filter(|&o| o != r && mask & (1u64 << o) != 0)
                    .count();
                if survivors == 0 {
                    self.demote_rail(r);
                    return;
                }
                let from = self.fab.rails[r].health;
                let s = self.monitor.suspicion(r);
                self.fab.deregister(r);
                self.exceptions
                    .record_gray(&mut self.fab, r, GrayAction::Quarantine, s);
                let now = self.fab.now_us();
                self.monitor.record_transition(now, r, from, RailHealth::Quarantined);
                self.monitor
                    .note_quarantined(r, now, from == RailHealth::Probation);
                self.timer.forget_rail(r);
                self.planner.corrections.forget_rail(r);
                self.push_rail_weights();
                self.plan_cache.clear();
                self.planner.bump_epoch();
            }
        }
    }

    /// Healthy → Degraded (also the last-rail quarantine fallback).
    fn demote_rail(&mut self, r: usize) {
        if self.fab.rails[r].transition(RailHealth::Degraded) {
            let now = self.fab.now_us();
            self.monitor
                .record_transition(now, r, RailHealth::Healthy, RailHealth::Degraded);
            let s = self.monitor.suspicion(r);
            self.exceptions
                .record_gray(&mut self.fab, r, GrayAction::Demote, s);
            self.push_rail_weights();
            self.plan_cache.clear();
            self.planner.bump_epoch();
        }
    }

    /// Inject a persistent straggler on `rail` (see
    /// [`Fabric::inject_straggler`]).
    pub fn with_straggler(mut self, rail: usize, stall_us: f64, sigma: f64) -> Self {
        self.fab.inject_straggler(rail, stall_us, sigma);
        self
    }

    /// Pin the seed's fixed dispatch (bypasses the planner).
    pub fn with_algo(mut self, algo: Algo) -> Self {
        self.forced_algo = Some(algo);
        self
    }

    /// Pin (`Some`) or release (`None`) the fixed dispatch at runtime.
    pub fn force_algo(&mut self, algo: Option<Algo>) {
        self.forced_algo = algo;
    }

    pub fn with_reducer(mut self, reducer: Box<dyn Reducer>) -> Self {
        self.reducer = reducer;
        self
    }

    /// Switch the cross-rail execution engine at runtime (ablation).
    pub fn with_exec(mut self, mode: ExecMode) -> Self {
        self.executor = RailExecutor::new(mode);
        self
    }

    pub fn ops_done(&self) -> u64 {
        self.ops_done
    }

    /// Current schedule-selection epoch: bumps on every fresh selection
    /// pass, including mid-op failover replans. Stable while cached plans
    /// are reused.
    pub fn plan_epoch(&self) -> u64 {
        self.planner.epoch()
    }

    /// Rail-round count of the most recent planner-scheduled op (the max
    /// across its payload-carrying rails) — the preemption-window count
    /// the trainer's barrier-free wire timeline uses (an op yields the
    /// wire only at round boundaries). 1 after forced-dispatch or sliced
    /// ops, where no planner schedule executed.
    pub fn last_plan_rounds(&self) -> usize {
        self.last_plan
            .as_ref()
            .and_then(|p| {
                p.assignments
                    .iter()
                    .filter(|a| a.bytes > 0)
                    .map(|a| a.rounds)
                    .max()
            })
            .unwrap_or(1)
            .max(1)
    }

    /// Arbiter hook: this job now holds `share` of `rail`'s bandwidth
    /// (window-boundary grant — takes effect at the next op, never
    /// mid-collective).
    ///
    /// The fabric share always applies (measured transfers stretch by
    /// `1/share` past their setup term). When `contended_pricing` is set
    /// the planner is told too, so its cost model prices the contention
    /// directly and every cached selection made under the old grant is
    /// flushed — the ISSUE's replan-on-share-change. A contention-blind
    /// job skips that and only discovers the squeeze through its
    /// corrected-cost EWMA, several ops late.
    pub fn set_rail_grant(&mut self, rail: usize, share: f64, contended_pricing: bool) {
        self.fab.set_rail_share(rail, share);
        if contended_pricing && self.planner.set_grant(rail, share) {
            self.plan_cache.clear();
            self.planner.bump_epoch();
        }
    }

    /// The fabric-side share currently granted on `rail`.
    pub fn rail_grant(&self, rail: usize) -> f64 {
        self.fab.rail_share(rail)
    }

    /// Opt into (or out of) soft affinity on affinity-constrained
    /// topologies. Strict mode (the default) only runs rails EVERY
    /// group's mask admits; soft mode runs any rail SOME group admits,
    /// down-weighted in the Load Balancer by the fraction of groups
    /// admitting it ([`crate::net::topology::TopologyTree::rail_admit_fraction`]) —
    /// so a rail one pod lacks still carries the rest of the cluster's
    /// traffic instead of being banned outright. No-op on unconstrained
    /// trees.
    pub fn soft_affinity(&mut self, enable: bool) {
        let n_rails = self.fab.rails.len();
        let topo = &self.planner.topo;
        if !topo.has_affinity() {
            return;
        }
        let mask = if enable {
            topo.union_rail_mask(n_rails)
        } else {
            topo.allowed_rail_mask(n_rails)
        };
        let fracs: Vec<f64> = (0..n_rails)
            .map(|r| if enable { topo.rail_admit_fraction(r) } else { 1.0 })
            .collect();
        self.affinity_weights = fracs;
        self.rail_allow_mask = mask;
        self.exceptions.set_rail_mask(mask);
        // the partitioner sees affinity × health as one product vector
        self.push_rail_weights();
        // cached selections assumed the old rail set / weights
        self.plan_cache.clear();
    }

    /// Return a finished report's `per_rail` vector to the coordinator's
    /// pool. Steady-state loops (benches, trainers) recycle reports so the
    /// per-op path allocates nothing; dropping a report instead is always
    /// correct — the pool simply refills from fresh vectors.
    pub fn recycle(&mut self, rep: OpReport) {
        let mut v = rep.per_rail;
        v.clear();
        if self.scratch.report_pool.len() < 8 {
            self.scratch.report_pool.push(v);
        }
    }

    /// Take a pooled (or fresh) report vector.
    fn take_report_vec(&mut self) -> Vec<RailShare> {
        let mut v = self.scratch.report_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// The collective plan the coordinator would execute for a `bytes`-
    /// sized op right now (None when the policy slices MPTCP-style or no
    /// rail is healthy). Used by bucket annotation and the benches.
    ///
    /// Nothing executes, the clock does not advance and no selection epoch
    /// starts, but the policy IS consulted for real: for Nezha this warms
    /// the Load Balancer's data-length table for this size class exactly
    /// as the planning phase of a real op would (later real ops refine it
    /// through feedback).
    pub fn plan_for(&mut self, bytes: u64) -> Option<CollectivePlan> {
        let mut healthy = std::mem::take(&mut self.scratch.healthy);
        self.healthy_allowed_into(&mut healthy);
        if healthy.is_empty() {
            self.scratch.healthy = healthy;
            return None;
        }
        let mut sh = std::mem::take(&mut self.scratch.shares);
        self.partitioner
            .plan(&self.fab, &self.timer, &healthy, bytes, &mut sh);
        let res = if sh.packet_bytes.is_some() {
            None
        } else {
            Some(self.planner.preview(&self.fab, &self.timer, &sh.fracs, bytes))
        };
        self.scratch.shares = sh;
        self.scratch.healthy = healthy;
        res
    }

    /// Schedule selection with plan caching: reuse the cached selection
    /// for this (size class, rail set) unless a participating rail's
    /// predicted-vs-measured error exceeded `replan_error` — the
    /// straggler-aware replan trigger that fires *between* ops/buckets.
    fn plan_shares(&mut self, fracs: &[(usize, f64)], bytes: u64) -> CollectivePlan {
        let key = (self.membership_epoch, size_bucket(bytes), rail_mask(fracs));
        // Timer/correction classes are keyed by each rail's OWN share
        // size (that's what it measures), so the trigger checks per-rail
        // byte counts, not the op total.
        if let Some(cached) = self.plan_cache.get(&key) {
            let trigger = fracs.iter().any(|&(r, share)| {
                let rail_bytes = (bytes as f64 * share) as u64;
                self.planner
                    .needs_replan(&self.timer, r, rail_bytes, self.replan_error)
            });
            if !trigger {
                return self
                    .planner
                    .plan_cached(&self.fab, &self.timer, fracs, bytes, cached);
            }
        }
        let plan = self.planner.plan(&self.fab, &self.timer, fracs, bytes);
        // a replan that switches a rail's schedule invalidates that
        // class's Timer history: the old schedule's window averages no
        // longer describe what will run
        if let Some(old) = self.plan_cache.get(&key) {
            for a in &plan.assignments {
                let switched = old
                    .iter()
                    .any(|&(r, s)| r == a.rail && s != a.schedule);
                if switched {
                    self.timer.forget_class(a.rail, a.bytes);
                }
            }
        }
        self.plan_cache.insert(
            key,
            plan.assignments.iter().map(|a| (a.rail, a.schedule)).collect(),
        );
        plan
    }

    /// Allreduce the full buffer (f32 payload; modeled bytes = 4×elems).
    pub fn allreduce(&mut self, buf: &mut UnboundBuffer) -> Result<OpReport> {
        self.allreduce_scaled(buf, 4.0)
    }

    /// Allreduce with decoupled modeled element size (timing sweeps on
    /// small real buffers; `elem_bytes = 4.0` is the physical case).
    pub fn allreduce_scaled(&mut self, buf: &mut UnboundBuffer, elem_bytes: f64) -> Result<OpReport> {
        let full = buf.full_window();
        self.allreduce_window_scaled(buf, full, elem_bytes)
    }

    /// Allreduce only `w` of the buffer (gradient-fusion buckets).
    pub fn allreduce_window(&mut self, buf: &mut UnboundBuffer, w: Window) -> Result<OpReport> {
        self.allreduce_window_scaled(buf, w, 4.0)
    }

    /// The general entry point: window + modeled element size.
    pub fn allreduce_window_scaled(
        &mut self,
        buf: &mut UnboundBuffer,
        full: Window,
        elem_bytes: f64,
    ) -> Result<OpReport> {
        // op-boundary membership churn first: the node count the buffer
        // must match is the post-churn surviving set
        self.poll_membership()?;
        assert_eq!(buf.nodes(), self.fab.nodes, "buffer/fabric node mismatch");
        // fresh per-rail sampling streams for this op epoch — the
        // serial/parallel bit-identity anchor
        self.fab.begin_op();
        self.probe_readmitted();
        // retransmit-ledger snapshot: the monitor scores this op's deltas
        let mut retry_base = std::mem::take(&mut self.scratch.retry_base);
        retry_base.clear();
        retry_base.extend((0..self.fab.rails.len()).map(|r| self.fab.retries_on(r)));
        // reusable healthy-rail scratch: taken for the op, restored below
        // (error paths drop it; the next op simply re-allocates capacity)
        let mut healthy = std::mem::take(&mut self.scratch.healthy);
        self.healthy_allowed_into(&mut healthy);
        if healthy.is_empty() {
            self.scratch.healthy = healthy;
            return Err(Error::AllRailsDown(0));
        }
        let bytes = (full.len as f64 * elem_bytes) as u64;
        let mut sh = std::mem::take(&mut self.scratch.shares);
        self.partitioner
            .plan(&self.fab, &self.timer, &healthy, bytes, &mut sh);

        let exec = if let Some(packet_bytes) = sh.packet_bytes {
            self.last_plan = None;
            self.exec_slices(buf, full, packet_bytes, elem_bytes, &healthy)
        } else if self.forced_algo.is_some() {
            // fixed dispatch: no cost-model work, and last_plan is
            // cleared so nobody mistakes a planner prediction for
            // what actually ran
            let cplan = CollectivePlan::unplanned(&sh.fracs, bytes);
            let res = self.exec_plan(buf, full, &cplan, elem_bytes);
            if res.is_ok() {
                self.last_plan = None;
            }
            res
        } else {
            // the balancer's split is the planner's input, not the
            // final word on execution: each rail's window gets the
            // schedule the (measurement-corrected) cost model
            // picks for it, cached until a replan trigger fires
            let cplan = self.plan_shares(&sh.fracs, bytes);
            let res = self.exec_plan(buf, full, &cplan, elem_bytes);
            if res.is_ok() {
                self.last_plan = Some(cplan);
            }
            res
        };
        self.scratch.shares = sh;
        self.scratch.healthy = healthy;
        let (mut shares, failovers) = exec?;

        let active = shares.iter().filter(|s| s.bytes > 0).count();
        let sync = sync_overhead_us(active);
        let worst = shares.iter().fold(0.0f64, |m, s| m.max(s.time_us));
        let total = worst + sync;
        self.fab.advance(total);

        for s in &shares {
            if s.bytes == 0 {
                continue;
            }
            // Planner-scheduled ops key the Timer by the plan's share-based
            // byte count — the exact value `plan_shares`' replan trigger
            // and the corrections warm-up gate look up. (Window-derived
            // bytes can round across a power-of-two bucket boundary and
            // strand the gate in a class that never warms.)
            let key_bytes = self
                .last_plan
                .as_ref()
                .and_then(|p| p.assignments.iter().find(|a| a.rail == s.rail && a.bytes > 0))
                .map(|a| a.bytes)
                .unwrap_or(s.bytes);
            self.timer.record(s.rail, key_bytes, s.time_us);
        }
        // pooled feedback vector: the last planning-side per-op allocation
        let mut fb = std::mem::take(&mut self.scratch.feedback);
        fb.clear();
        fb.extend(shares.iter().map(|s| (s.rail, s.bytes, s.time_us)));
        self.partitioner.feedback(&self.fab, bytes, &fb);
        self.scratch.feedback = fb;
        if self.monitor.enabled() {
            // Residuals only flow when the corrections layer is live:
            // static-cost mode must stay measurement-blind end to end (the
            // ablation baseline), and its raw model predictions would
            // flag every unmodeled slowdown as suspicion. Retry counts
            // are a hard dataplane signal and always count.
            let corrections_on = self.planner.use_corrections;
            for s in &shares {
                if s.bytes == 0 {
                    continue;
                }
                let retries = self.fab.retries_on(s.rail).saturating_sub(retry_base[s.rail]);
                let predicted = if corrections_on {
                    self.last_plan
                        .as_ref()
                        .and_then(|p| {
                            p.assignments.iter().find(|a| a.rail == s.rail && a.bytes > 0)
                        })
                        .map(|a| a.predicted_us)
                        .unwrap_or(0.0)
                } else {
                    0.0
                };
                self.monitor.observe(s.rail, predicted, s.time_us, retries);
            }
            let mut actions = std::mem::take(&mut self.scratch.health_actions);
            self.monitor.decide(&self.fab, &mut actions);
            for &a in &actions {
                self.apply_health_action(a);
            }
            self.scratch.health_actions = actions;
        }
        self.scratch.retry_base = retry_base;
        self.ops_done += 1;
        shares.sort_by_key(|s| s.rail);
        Ok(OpReport {
            total_us: total,
            bytes,
            per_rail: shares,
            failovers,
            completed_at_us: self.fab.now_us(),
        })
    }

    /// Run one rail's slice under either the forced seed dispatch or the
    /// planned schedule. `scratch` is the op's reusable segment/chunk/
    /// aggregation space (taken out of `self.scratch` by the caller).
    fn run_rail(
        &mut self,
        schedule: Schedule,
        rail: usize,
        buf: &mut UnboundBuffer,
        w: Window,
        elem_bytes: f64,
        scratch: &mut OpScratch,
    ) -> std::result::Result<OpOutcome, RailDown> {
        match self.forced_algo {
            Some(algo) => run_allreduce_with(
                algo,
                &mut self.fab,
                rail,
                buf,
                w,
                self.reducer.as_mut(),
                elem_bytes,
                scratch,
            ),
            None => run_plan_with(
                schedule,
                &mut self.fab,
                rail,
                buf,
                w,
                self.reducer.as_mut(),
                elem_bytes,
                &self.planner.topo,
                scratch,
            ),
        }
    }

    /// Schedule to run on a failover's takeover rail (corrected costs at
    /// the post-failover fabric state).
    fn takeover_schedule(&self, rail: usize, w: Window, elem_bytes: f64) -> Schedule {
        self.planner
            .schedule_for(&self.fab, &self.timer, rail, w.len as f64 * elem_bytes)
            .0
    }

    /// The §4.4 failover core shared by BOTH executors (the serial/
    /// parallel parity invariant depends on there being exactly one
    /// implementation): deregister the failed rail and forget its
    /// Timer/correction state, flush every cached selection (fresh
    /// epoch), re-plan the migrated window for the optimal survivor at
    /// the post-failover fabric state, run it there, and merge recovery +
    /// re-run time into that survivor's share. Returns the event; the
    /// serial loop additionally replans the surviving rails' still-
    /// pending windows (in the parallel engine they have already run).
    #[allow(clippy::too_many_arguments)]
    fn failover_rail(
        &mut self,
        failed: usize,
        w: Window,
        buf: &mut UnboundBuffer,
        elem_bytes: f64,
        allocated: &[(usize, u64)],
        op_scratch: &mut OpScratch,
        shares: &mut Vec<RailShare>,
    ) -> Result<crate::coordinator::control::FailoverEvent> {
        let prior = self.fab.rails[failed].health;
        let ev = self
            .exceptions
            .handle_failure(&mut self.fab, failed, w, allocated)
            .ok_or(Error::AllRailsDown(failed))?;
        if self.monitor.enabled() {
            // a crash failover IS a quarantine: same state machine, and a
            // rail that died while on probation earns the escalated dwell
            let now = self.fab.now_us();
            self.monitor.record_transition(now, failed, prior, RailHealth::Quarantined);
            self.monitor
                .note_quarantined(failed, now, prior == RailHealth::Probation);
            self.push_rail_weights();
        }
        self.timer.forget_rail(failed);
        self.planner.corrections.forget_rail(failed);
        // every cached selection assumed the old rail set
        self.plan_cache.clear();
        self.planner.bump_epoch();
        // re-plan the migrated window for the takeover rail
        let sched = self.takeover_schedule(ev.takeover_rail, w, elem_bytes);
        let out = self
            .run_rail(sched, ev.takeover_rail, buf, w, elem_bytes, op_scratch)
            .map_err(|RailDown(r2)| Error::AllRailsDown(r2))?;
        buf.complete(w)?;
        // takeover rail absorbs its own share elsewhere in this same op;
        // account serially on that rail
        let extra = ev.recovery_us + out.time_us;
        let bytes = (w.len as f64 * elem_bytes) as u64;
        if let Some(s) = shares.iter_mut().find(|s| s.rail == ev.takeover_rail) {
            s.time_us += extra;
            s.bytes += bytes;
        } else {
            shares.push(RailShare { rail: ev.takeover_rail, bytes, time_us: extra });
        }
        Ok(ev)
    }

    /// Execute a collective plan's per-rail windows; handles failover.
    ///
    /// Dispatches to the serial loop or, when `exec = parallel`, at least
    /// two rails carry payload and the reducer can fork, to the scoped-
    /// thread engine. Both paths produce bit-identical numerics AND
    /// modeled times (disjoint windows, per-rail RNG streams, fixed merge
    /// order).
    fn exec_plan(
        &mut self,
        buf: &mut UnboundBuffer,
        full: Window,
        cplan: &CollectivePlan,
        elem_bytes: f64,
    ) -> Result<(Vec<RailShare>, usize)> {
        // take the reusable scratch for the duration of the op (restored
        // on the success path; error paths drop it and the next op
        // re-grows capacity — errors here are terminal for the op anyway)
        let mut windows = std::mem::take(&mut self.scratch.windows);
        cplan.windows_into(full, &mut windows);
        let mut assigns = std::mem::take(&mut self.scratch.assigns);
        assigns.clear();
        assigns.extend_from_slice(&cplan.assignments);
        let mut allocated = std::mem::take(&mut self.scratch.allocated);
        allocated.clear();
        allocated.extend(
            assigns
                .iter()
                .zip(&windows)
                .map(|(a, w)| (a.rail, (w.len as f64 * elem_bytes) as u64)),
        );
        let mut shares = self.take_report_vec();

        // parallel eligibility: ≥2 payload-carrying rails, all distinct,
        // and a forkable reducer (each worker needs its own)
        let mut live = 0usize;
        let mut mask = 0u64;
        let mut distinct = true;
        for (a, w) in assigns.iter().zip(&windows) {
            if w.is_empty() {
                continue;
            }
            live += 1;
            if a.rail < 64 {
                if mask & (1u64 << a.rail) != 0 {
                    distinct = false;
                }
                mask |= 1u64 << a.rail;
            } else {
                // beyond the mask width we cannot prove distinctness —
                // route to the (always-correct) serial path
                distinct = false;
            }
        }
        let forks = if self.executor.mode == ExecMode::Parallel && live >= 2 && distinct {
            (0..live)
                .map(|_| self.reducer.fork())
                .collect::<Option<Vec<_>>>()
        } else {
            None
        };

        let res = match forks {
            Some(forks) => {
                self.exec_plan_parallel(buf, &windows, &assigns, &allocated, elem_bytes, forks, &mut shares)
            }
            None => self.exec_plan_serial(buf, &windows, &mut assigns, &allocated, elem_bytes, &mut shares),
        };
        self.scratch.windows = windows;
        self.scratch.assigns = assigns;
        self.scratch.allocated = allocated;
        let failovers = res?;
        debug_assert!(buf.all_complete());
        buf.clear_pending();
        Ok((shares, failovers))
    }

    /// The serial execution loop (the seed path).
    ///
    /// On a mid-op failover the Exception Handler migrates the failed
    /// window to the optimal survivor AND the not-yet-executed windows of
    /// the surviving rails are re-planned at the post-failover fabric
    /// state (freed cores change contention, hence optimal schedules) — a
    /// fresh selection epoch, not just a re-schedule of the migrated
    /// window.
    fn exec_plan_serial(
        &mut self,
        buf: &mut UnboundBuffer,
        windows: &[Window],
        assigns: &mut [RailPlan],
        allocated: &[(usize, u64)],
        elem_bytes: f64,
        shares: &mut Vec<RailShare>,
    ) -> Result<usize> {
        let mut op_scratch = std::mem::take(&mut self.scratch.op);
        let mut failovers = 0usize;
        let planner_scheduled = self.forced_algo.is_none();

        for idx in 0..assigns.len() {
            let assign = assigns[idx];
            let w = windows[idx];
            let rail = assign.rail;
            if w.is_empty() {
                shares.push(RailShare { rail, bytes: 0, time_us: 0.0 });
                continue;
            }
            buf.register(w);
            match self.run_rail(assign.schedule, rail, buf, w, elem_bytes, &mut op_scratch) {
                Ok(out) => {
                    buf.complete(w)?;
                    let rail_bytes = (w.len as f64 * elem_bytes) as u64;
                    shares.push(RailShare { rail, bytes: rail_bytes, time_us: out.time_us });
                    if planner_scheduled {
                        // feed the corrected-cost layer and the plan-
                        // quality dashboard. Corrections EWMA the raw
                        // samples themselves; the Timer's completed
                        // averaging window is the activation gate
                        // (`Planner::corrections_active`), so decisions
                        // stay damped the way the paper's Timer damps the
                        // Load Balancer's. Keyed by the plan's share-based
                        // byte count — the exact value the replan trigger
                        // in `plan_shares` looks up.
                        self.planner.observe(
                            rail,
                            assign.bytes,
                            assign.rounds,
                            assign.model_us,
                            assign.predicted_us,
                            out.time_us,
                        );
                        // current epoch, not the plan's: a mid-op failover
                        // earlier in this loop bumped it and re-selected
                        // the remaining schedules
                        self.quality.record(
                            rail,
                            assign.bytes,
                            assign.schedule,
                            assign.predicted_us,
                            out.time_us,
                            self.planner.epoch(),
                        );
                    }
                }
                Err(RailDown(r)) => {
                    // §4.4: deregister, hand (ptr,len) to optimal survivor
                    failovers += 1;
                    self.failover_rail(r, w, buf, elem_bytes, allocated, &mut op_scratch, shares)?;
                    // ... and the surviving rails' pending windows at the
                    // post-failover fabric state
                    for j in idx + 1..assigns.len() {
                        let wj = windows[j];
                        let (rail_j, share_j) = (assigns[j].rail, assigns[j].share);
                        if wj.is_empty() || rail_j == r {
                            continue;
                        }
                        // keep the plan's share-based byte count as the
                        // sizing/keying value so the replanned assignment
                        // observes into the same class the replan trigger
                        // and warm-up gate consult
                        let rail_bytes = assigns[j].bytes as f64;
                        assigns[j] = self.planner.rail_plan(
                            &self.fab,
                            &self.timer,
                            rail_j,
                            share_j,
                            rail_bytes,
                        );
                    }
                }
            }
        }
        self.scratch.op = op_scratch;
        Ok(failovers)
    }

    /// The parallel execution engine: every payload-carrying rail's
    /// schedule runs concurrently on a scoped worker thread, driving its
    /// borrow-split [`crate::net::simnet::RailCtx`] (timing) over its
    /// disjoint [`crate::coordinator::buffer::RailView`] (numerics) with
    /// a forked reducer and its own collective scratch.
    ///
    /// Failovers surface at the merge: a failed rail's window never ran
    /// numerics (timing precedes numerics inside every collective), so it
    /// migrates to the optimal survivor and re-runs serially after the
    /// join — the cache/epoch replan state updates exactly as in the
    /// serial path. Concurrent rails have already completed by then, so
    /// (unlike serial) there are no pending windows to re-plan mid-op.
    #[allow(clippy::too_many_arguments)]
    fn exec_plan_parallel(
        &mut self,
        buf: &mut UnboundBuffer,
        windows: &[Window],
        assigns: &[RailPlan],
        allocated: &[(usize, u64)],
        elem_bytes: f64,
        mut forks: Vec<Box<dyn Reducer + Send>>,
        shares: &mut Vec<RailShare>,
    ) -> Result<usize> {
        let mut live_w = std::mem::take(&mut self.scratch.live_windows);
        let mut live_a = std::mem::take(&mut self.scratch.live_assigns);
        let mut live_r = std::mem::take(&mut self.scratch.live_rails);
        live_w.clear();
        live_a.clear();
        live_r.clear();
        for (a, w) in assigns.iter().zip(windows) {
            if !w.is_empty() {
                live_w.push(*w);
                live_a.push(*a);
                live_r.push(a.rail);
            }
        }
        debug_assert_eq!(forks.len(), live_a.len());
        for w in &live_w {
            buf.register(*w);
        }
        let forced = self.forced_algo;
        let planner_scheduled = forced.is_none();

        let prio = self.op_priority;
        let results: Vec<std::result::Result<OpOutcome, RailDown>> = {
            // borrow-split the coordinator: fabric → per-rail timing
            // contexts, buffer → disjoint per-rail views, scratch → one
            // collective scratch per worker
            let MultiRail { fab, scratch, planner, executor, .. } = self;
            while scratch.rail_ops.len() < live_a.len() {
                scratch.rail_ops.push(OpScratch::default());
            }
            let topo = &planner.topo;
            let views = buf.rail_views(&live_w);
            let mut ctxs = fab.rail_ctxs(&live_r);
            // rail_ctxs returns ascending rail order; re-order to match
            // the assignment order the views/forks/results use
            let mut ordered = Vec::with_capacity(live_r.len());
            for &rail in &live_r {
                let pos = ctxs
                    .iter()
                    .position(|c| c.rail == rail)
                    .expect("one ctx per live rail");
                ordered.push(ctxs.swap_remove(pos));
            }
            let mut jobs = Vec::with_capacity(live_a.len());
            for ((((mut view, mut ctx), scr), mut red), a) in views
                .into_iter()
                .zip(ordered)
                .zip(scratch.rail_ops.iter_mut())
                .zip(forks.drain(..))
                .zip(live_a.iter().copied())
            {
                let w = view.window_of_view();
                jobs.push((prio, move || match forced {
                    Some(algo) => run_allreduce_on(
                        algo,
                        &mut ctx,
                        &mut view,
                        w,
                        red.as_mut(),
                        elem_bytes,
                        scr,
                    ),
                    None => run_plan_on(
                        a.schedule,
                        &mut ctx,
                        &mut view,
                        w,
                        red.as_mut(),
                        elem_bytes,
                        topo,
                        scr,
                    ),
                }));
            }
            executor.run_prioritized(jobs)
        };

        // deterministic merge in assignment order (thread scheduling can
        // never reorder results — the executor returns submission order).
        // Empty-window shares are pushed in assignment POSITION, exactly
        // as the serial loop interleaves them, so both executors emit
        // identically-shaped per_rail vectors even when a failover merges
        // into a zero-share takeover rail.
        let mut failovers = 0usize;
        let mut op_scratch = std::mem::take(&mut self.scratch.op);
        let mut results_it = results.into_iter();
        for (a, w) in assigns.iter().zip(windows) {
            let (a, w) = (*a, *w);
            if w.is_empty() {
                shares.push(RailShare { rail: a.rail, bytes: 0, time_us: 0.0 });
                continue;
            }
            let res = results_it.next().expect("one result per live rail");
            match res {
                Ok(out) => {
                    buf.complete(w)?;
                    let rail_bytes = (w.len as f64 * elem_bytes) as u64;
                    shares.push(RailShare { rail: a.rail, bytes: rail_bytes, time_us: out.time_us });
                    if planner_scheduled {
                        self.planner.observe(
                            a.rail,
                            a.bytes,
                            a.rounds,
                            a.model_us,
                            a.predicted_us,
                            out.time_us,
                        );
                        self.quality.record(
                            a.rail,
                            a.bytes,
                            a.schedule,
                            a.predicted_us,
                            out.time_us,
                            self.planner.epoch(),
                        );
                    }
                }
                Err(RailDown(r)) => {
                    failovers += 1;
                    self.failover_rail(r, w, buf, elem_bytes, allocated, &mut op_scratch, shares)?;
                }
            }
        }
        self.scratch.op = op_scratch;
        self.scratch.live_windows = live_w;
        self.scratch.live_assigns = live_a;
        self.scratch.live_rails = live_r;
        Ok(failovers)
    }

    /// Execute MPTCP-style packet slicing with ECF-like earliest-
    /// completion-first scheduling.
    ///
    /// Packets are assigned to the subflow with the earliest predicted
    /// completion (per-subflow RTT/bandwidth estimate); each subflow then
    /// streams its assigned packets through one collective pass. Slicing
    /// costs show up as (a) an 18–27% transfer-time inflation (metadata,
    /// reassembly, out-of-order buffering — paper §4.3 measures 18–27%;
    /// we charge the midpoint) and (b) a fixed per-packet scheduling cost.
    fn exec_slices(
        &mut self,
        buf: &mut UnboundBuffer,
        full: Window,
        packet_bytes: u64,
        elem_bytes: f64,
        healthy: &[usize],
    ) -> Result<(Vec<RailShare>, usize)> {
        const SLICE_OVERHEAD: f64 = 1.22;
        const PER_PACKET_US: f64 = 4.0;
        let packet_elems = ((packet_bytes as f64 / elem_bytes).max(1.0)) as usize;
        let packets = full.split_chunks(packet_elems);
        // ECF assignment pass. MPTCP's completion-time prediction is
        // RTT/queue-depth based and PROTOCOL-BLIND (the paper's §2.2.1
        // criticism: "they cannot understand the completion time
        // differences between heterogeneous protocols") — so the scheduler
        // balances outstanding BYTES per subflow, which evens the split
        // regardless of each plane's collective throughput.
        let mut assigned: Vec<(usize, Vec<Window>, f64)> =
            healthy.iter().map(|&r| (r, Vec::new(), 0.0)).collect();
        for &p in &packets {
            let pbytes = p.len as f64 * elem_bytes;
            let idx = assigned
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .2.partial_cmp(&b.1 .2).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            assigned[idx].1.push(p);
            assigned[idx].2 += pbytes;
        }

        let mut shares = self.take_report_vec();
        let mut failovers = 0usize;
        // per-packet numerics scratch, reused across every packet/subflow
        let mut op_scratch = std::mem::take(&mut self.scratch.op);
        let alloc_bytes: Vec<(usize, u64)> = assigned
            .iter()
            .map(|(r, ps, _)| {
                (*r, ps.iter().map(|w| (w.len as f64 * elem_bytes) as u64).sum())
            })
            .collect();

        // Phase 1 — per-subflow stream timing: one collective pass over
        // each subflow's contiguous-equivalent transfer. Subflows ride
        // the RailExecutor like planned rails do (concurrent scoped
        // workers under `exec = parallel`, inline otherwise); per-rail
        // RNG streams make the modeled times independent of worker
        // interleaving, so both modes are bit-identical.
        #[derive(Clone, Copy)]
        enum SubflowPass {
            Ring { steps: usize, seg_bytes: f64 },
            Tree { bytes: f64 },
        }
        let nodes = self.fab.nodes;
        let live: Vec<usize> = assigned
            .iter()
            .filter(|(_, ps, _)| !ps.is_empty())
            .map(|(r, _, _)| *r)
            .collect();
        let passes: Vec<SubflowPass> = assigned
            .iter()
            .filter(|(_, ps, _)| !ps.is_empty())
            .map(|(r, ps, _)| {
                let total_elems: usize = ps.iter().map(|w| w.len).sum();
                match self.fab.rails[*r].protocol.collective {
                    crate::net::protocol::CollectiveKind::Ring => SubflowPass::Ring {
                        steps: 2 * (nodes - 1),
                        seg_bytes: (total_elems as f64 * elem_bytes / nodes as f64).ceil(),
                    },
                    crate::net::protocol::CollectiveKind::Tree => SubflowPass::Tree {
                        bytes: total_elems as f64 * elem_bytes,
                    },
                }
            })
            .collect();
        let prio = self.op_priority;
        let timings: Vec<std::result::Result<f64, RailDown>> = {
            let MultiRail { fab, executor, .. } = self;
            let mut ctxs = fab.rail_ctxs(&live);
            // rail_ctxs returns ascending rail order; re-order to match
            // the subflow assignment order the results iterator uses
            let mut ordered = Vec::with_capacity(live.len());
            for &rail in &live {
                let pos = ctxs
                    .iter()
                    .position(|c| c.rail == rail)
                    .expect("one ctx per live subflow");
                ordered.push(ctxs.swap_remove(pos));
            }
            let mut jobs = Vec::with_capacity(live.len());
            for (mut ctx, pass) in ordered.into_iter().zip(passes.iter().copied()) {
                jobs.push((prio, move || match pass {
                    SubflowPass::Ring { steps, seg_bytes } => {
                        let mut t = 0.0;
                        for _ in 0..steps {
                            t += ctx.ring_step(seg_bytes)?;
                        }
                        Ok(t)
                    }
                    SubflowPass::Tree { bytes } => ctx.tree_round(bytes),
                }));
            }
            executor.run_prioritized(jobs)
        };

        // Phase 2 — numerics, shares and failover, in assignment order
        // (numerics never touch the RNG, so running them after the join
        // changes nothing).
        let mut timing_it = timings.into_iter();
        for (rail, ps, _) in &assigned {
            if ps.is_empty() {
                shares.push(RailShare { rail: *rail, bytes: 0, time_us: 0.0 });
                continue;
            }
            let rail_bytes: u64 = ps.iter().map(|w| (w.len as f64 * elem_bytes) as u64).sum();
            match timing_it.next().expect("one timing per live subflow") {
                Ok(stream_time) => {
                    // numerics per packet (reassembly order)
                    for p in ps {
                        buf.register(*p);
                        p.split_uniform_into(buf.nodes(), &mut op_scratch.segs);
                        crate::coordinator::collective::ring::ring_numerics_segs(
                            buf,
                            &op_scratch.segs,
                            self.reducer.as_mut(),
                        );
                        buf.complete(*p)?;
                    }
                    shares.push(RailShare {
                        rail: *rail,
                        bytes: rail_bytes,
                        time_us: stream_time * SLICE_OVERHEAD
                            + PER_PACKET_US * ps.len() as f64,
                    });
                }
                Err(RailDown(r)) => {
                    // uncoordinated failover: packets re-run on survivor
                    failovers += 1;
                    let w_all = Window::new(
                        ps[0].offset,
                        ps.iter().map(|w| w.len).sum(),
                    );
                    let ev = self
                        .exceptions
                        .handle_failure(&mut self.fab, r, w_all, &alloc_bytes)
                        .ok_or(Error::AllRailsDown(r))?;
                    let mut t_extra = ev.recovery_us;
                    let algo = self.forced_algo.unwrap_or(Algo::Ring);
                    for p in ps {
                        buf.register(*p);
                        let out = run_allreduce_with(
                            algo,
                            &mut self.fab,
                            ev.takeover_rail,
                            buf,
                            *p,
                            self.reducer.as_mut(),
                            elem_bytes,
                            &mut op_scratch,
                        )
                        .map_err(|RailDown(r2)| Error::AllRailsDown(r2))?;
                        buf.complete(*p)?;
                        t_extra += out.time_us * SLICE_OVERHEAD;
                    }
                    if let Some(s) = shares.iter_mut().find(|s| s.rail == ev.takeover_rail) {
                        s.time_us += t_extra;
                        s.bytes += rail_bytes;
                    } else {
                        shares.push(RailShare {
                            rail: ev.takeover_rail,
                            bytes: rail_bytes,
                            time_us: t_extra,
                        });
                    }
                }
            }
        }
        buf.clear_pending();
        self.scratch.op = op_scratch;
        Ok((shares, failovers))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::protocol::{ProtoKind, MB};

    fn cfg(combo: &[ProtoKind], nodes: usize, policy: Policy) -> Config {
        Config {
            nodes,
            combo: combo.to_vec(),
            policy,
            deterministic: true,
            ..Config::default()
        }
    }

    fn reduced_ok(buf: &UnboundBuffer, nodes: usize, len: usize) {
        for n in 0..nodes {
            for i in 0..len {
                let expect: f32 = (1..=nodes).map(|m| (m * (i % 13 + 1)) as f32).sum();
                assert_eq!(buf.node(n)[i], expect, "node {n} elem {i}");
            }
        }
    }

    fn make(nodes: usize, len: usize) -> UnboundBuffer {
        UnboundBuffer::from_fn(nodes, len, |n, i| ((n + 1) * (i % 13 + 1)) as f32)
    }

    #[test]
    fn nezha_allreduce_correct_small_and_large() {
        for &len in &[512usize, 100_000] {
            let mut mr =
                MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha))
                    .unwrap();
            let mut buf = make(4, len);
            let rep = mr.allreduce(&mut buf).unwrap();
            assert!(rep.total_us > 0.0);
            reduced_ok(&buf, 4, len);
        }
    }

    #[test]
    fn small_op_is_cold_single_rail() {
        let mut mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha)).unwrap();
        let mut buf = make(4, 256); // 1KB
        let rep = mr.allreduce(&mut buf).unwrap();
        assert_eq!(rep.per_rail.iter().filter(|s| s.bytes > 0).count(), 1);
        reduced_ok(&buf, 4, 256);
    }

    #[test]
    fn large_op_uses_both_rails() {
        let mut mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha)).unwrap();
        let mut buf = make(4, 4 * 1024 * 1024); // 16MB
        let rep = mr.allreduce(&mut buf).unwrap();
        assert_eq!(rep.per_rail.iter().filter(|s| s.bytes > 0).count(), 2);
        reduced_ok(&buf, 4, 4 * 1024 * 1024);
    }

    #[test]
    fn dual_rail_beats_single_for_large_payloads() {
        let big = 4 * 1024 * 1024; // 16MB of f32
        let mut dual =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha)).unwrap();
        let mut single =
            MultiRail::new(&cfg(&[ProtoKind::Tcp], 4, Policy::SingleRail)).unwrap();
        let t_dual = dual.allreduce(&mut make(4, big)).unwrap().total_us;
        let t_single = single.allreduce(&mut make(4, big)).unwrap().total_us;
        assert!(
            t_dual < 0.75 * t_single,
            "dual {t_dual} single {t_single}"
        );
    }

    #[test]
    fn mptcp_slices_across_rails() {
        let mut mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Mptcp)).unwrap();
        let len = 1024 * 1024;
        let mut buf = make(4, len);
        let rep = mr.allreduce(&mut buf).unwrap();
        assert!(rep.per_rail.iter().all(|s| s.bytes > 0), "{rep:?}");
        reduced_ok(&buf, 4, len);
    }

    #[test]
    fn failover_preserves_correctness_and_budget() {
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv)
            .unwrap()
            .with_faults(FaultSchedule::none().with(1, 0.0, 1e12));
        let len = 2 * 1024 * 1024; // 8MB → hot → both rails → failover
        let mut buf = make(4, len);
        let rep = mr.allreduce(&mut buf).unwrap();
        assert_eq!(rep.failovers, 1);
        reduced_ok(&buf, 4, len);
        assert_eq!(mr.fab.healthy_rails(), vec![0]);
        // next op proceeds single-rail
        let mut buf2 = make(4, len);
        let rep2 = mr.allreduce(&mut buf2).unwrap();
        assert_eq!(rep2.failovers, 0);
        reduced_ok(&buf2, 4, len);
    }

    #[test]
    fn all_rails_down_is_an_error() {
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv).unwrap().with_faults(
            FaultSchedule::none().with(0, 0.0, 1e12).with(1, 0.0, 1e12),
        );
        let mut buf = make(4, 1024 * 1024);
        assert!(mr.allreduce(&mut buf).is_err());
    }

    #[test]
    fn timer_accumulates_measurements() {
        let mut mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha)).unwrap();
        for _ in 0..5 {
            let mut buf = make(4, 1024 * 1024);
            mr.allreduce(&mut buf).unwrap();
        }
        assert!(mr.timer.cost(0, 2 * MB as u64).is_some());
    }

    #[test]
    fn scaled_timing_matches_physical() {
        // a 1M-elem physical buffer and a 256-elem buffer modeled at the
        // same byte size must report (nearly) the same time
        let mk = || MultiRail::new(&cfg(&[ProtoKind::Tcp], 4, Policy::SingleRail)).unwrap();
        let t_phys = mk().allreduce(&mut make(4, 1 << 20)).unwrap().total_us;
        let t_scaled = mk()
            .allreduce_scaled(&mut make(4, 256), (1u64 << 22) as f64 / 256.0)
            .unwrap()
            .total_us;
        assert!((t_phys - t_scaled).abs() / t_phys < 0.02, "{t_phys} {t_scaled}");
    }

    #[test]
    fn sharp_combo_small_payload_fast() {
        let mut mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Sharp], 4, Policy::Nezha)).unwrap();
        let mut buf = make(4, 256); // 1KB
        let rep = mr.allreduce(&mut buf).unwrap();
        // cold start on SHARP: microseconds, not the ~1ms TCP ring
        assert!(rep.total_us < 100.0, "{}", rep.total_us);
        reduced_ok(&buf, 4, 256);
    }

    #[test]
    fn plan_epoch_stable_while_predictions_hold() {
        // clean deterministic fabric: the model matches measurements, so
        // the cached plan is reused and no replan epochs start
        let mut mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha)).unwrap();
        let elem_bytes = (16u64 << 20) as f64 / 1024.0;
        let mut buf = make(4, 1024);
        mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
        let e = mr.plan_epoch();
        assert!(e >= 1);
        for _ in 0..8 {
            let mut buf = make(4, 1024);
            mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
        }
        assert_eq!(mr.plan_epoch(), e, "replanned without a trigger");
        assert!(!mr.quality.is_empty());
        assert!(mr.quality.median_rel_error().unwrap() < 0.05);
    }

    #[test]
    fn straggler_triggers_replan_and_cuts_rounds() {
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.control.timer_window = 3;
        c.control.replan_error = 0.1;
        // per-message stalls on rail 0; fixed 50/50 shares keep the size
        // class stable so the test isolates the schedule-level response
        let mut mr = MultiRail::new(&c).unwrap().with_straggler(0, 5_000.0, 0.0);
        mr.partitioner = Box::new(crate::baselines::FixedShares::percent(50, 50));
        let elem_bytes = (256u64 << 20) as f64 / 1024.0;
        let mut buf = make(4, 1024);
        mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
        let first = mr.last_plan.clone().unwrap();
        let rounds_before = first.assignments.iter().find(|a| a.rail == 0).unwrap().rounds;
        let e_before = mr.plan_epoch();
        for _ in 0..14 {
            let mut buf = make(4, 1024);
            mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
        }
        assert!(mr.plan_epoch() > e_before, "straggler must trigger a replan");
        let last = mr.last_plan.clone().unwrap();
        let rounds_after = last.assignments.iter().find(|a| a.rail == 0).unwrap().rounds;
        assert!(
            rounds_after < rounds_before,
            "straggler rail should drop to a fewer-round schedule: {rounds_before} -> {rounds_after}"
        );
    }

    #[test]
    fn static_cost_mode_never_reacts_to_stragglers() {
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.control.timer_window = 3;
        c.planner = PlannerMode::StaticCost;
        let mut mr = MultiRail::new(&c).unwrap().with_straggler(0, 5_000.0, 0.0);
        mr.partitioner = Box::new(crate::baselines::FixedShares::percent(50, 50));
        let elem_bytes = (256u64 << 20) as f64 / 1024.0;
        let mut schedules = Vec::new();
        for _ in 0..10 {
            let mut buf = make(4, 1024);
            mr.allreduce_scaled(&mut buf, elem_bytes).unwrap();
            let p = mr.last_plan.as_ref().unwrap();
            schedules.push(p.assignments.iter().find(|a| a.rail == 0).unwrap().schedule);
        }
        assert!(
            schedules.windows(2).all(|w| w[0] == w[1]),
            "static-cost schedules must not drift: {schedules:?}"
        );
    }

    #[test]
    fn recovery_readmits_rail_after_fault_window() {
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        // rail 1 down only for the first 50ms of virtual time
        let mut mr = MultiRail::new(&cfgv)
            .unwrap()
            .with_faults(FaultSchedule::none().with(1, 0.0, 50_000.0));
        let len = 2 * 1024 * 1024;
        let rep = mr.allreduce(&mut make(4, len)).unwrap();
        assert_eq!(rep.failovers, 1);
        // failover advanced the clock past the window; next op re-admits
        let rep2 = mr.allreduce(&mut make(4, len)).unwrap();
        assert_eq!(rep2.failovers, 0);
        assert_eq!(rep2.per_rail.iter().filter(|s| s.bytes > 0).count(), 2);
    }

    #[test]
    fn parallel_exec_bit_identical_to_serial_with_jitter() {
        // jitter ON: per-rail streams make even the sampled modeled times
        // identical across executors, not just the numerics
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.deterministic = false;
        c.exec = ExecMode::Serial;
        let mut serial = MultiRail::new(&c).unwrap();
        c.exec = ExecMode::Parallel;
        let mut parallel = MultiRail::new(&c).unwrap();
        let len = 1 << 20; // 4MB: hot → both rails
        for op in 0..4 {
            let mut bs = make(4, len);
            let mut bp = make(4, len);
            let rs = serial.allreduce(&mut bs).unwrap();
            let rp = parallel.allreduce(&mut bp).unwrap();
            assert_eq!(rs.total_us, rp.total_us, "op {op}: modeled time diverged");
            assert_eq!(rs.per_rail.len(), rp.per_rail.len(), "op {op}");
            for (a, b) in rs.per_rail.iter().zip(&rp.per_rail) {
                assert_eq!(a.rail, b.rail, "op {op}");
                assert_eq!(a.bytes, b.bytes, "op {op}");
                assert_eq!(a.time_us, b.time_us, "op {op} rail {}", a.rail);
            }
            for n in 0..4 {
                assert_eq!(bs.node(n), bp.node(n), "op {op} node {n} numerics diverged");
            }
            reduced_ok(&bp, 4, len);
        }
    }

    #[test]
    fn parallel_exec_correct_on_heterogeneous_combo() {
        // ring + tree rails concurrently (different schedule families);
        // fixed 50/50 shares force both planes to carry payload
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Sharp], 4, Policy::Nezha);
        c.exec = ExecMode::Parallel;
        let mut mr = MultiRail::new(&c).unwrap();
        mr.partitioner = Box::new(crate::baselines::FixedShares::percent(50, 50));
        let len = 1024 * 1024; // 4MB split across both planes
        let mut buf = make(4, len);
        let rep = mr.allreduce(&mut buf).unwrap();
        assert_eq!(rep.per_rail.iter().filter(|s| s.bytes > 0).count(), 2);
        reduced_ok(&buf, 4, len);
    }

    #[test]
    fn recycled_reports_pool_their_vectors() {
        let mut mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha)).unwrap();
        let mut buf = make(4, 1024 * 1024);
        let rep = mr.allreduce(&mut buf).unwrap();
        let cap = rep.per_rail.capacity();
        assert!(cap >= 2);
        mr.recycle(rep);
        // the next op draws the same vector back out of the pool
        let mut buf2 = make(4, 1024 * 1024);
        let rep2 = mr.allreduce(&mut buf2).unwrap();
        assert!(rep2.per_rail.capacity() >= 2);
        assert_eq!(rep2.per_rail.iter().filter(|s| s.bytes > 0).count(), 2);
        mr.recycle(rep2);
    }

    #[test]
    fn soft_affinity_admits_partially_allowed_rails() {
        use crate::net::topology::ClusterSpec;
        // pod 0 admits both rails, pod 1 only rail 0: the strict
        // intersection bans rail 1 for every op
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 8, Policy::Nezha);
        c.cluster = ClusterSpec::pods(4).with_affinity(0, vec![0b11, 0b01]);
        let mut mr = MultiRail::new(&c).unwrap();
        let len = 1 << 21; // 8MB: far into the hot band
        let rep = mr.allreduce(&mut make(8, len)).unwrap();
        assert_eq!(
            rep.per_rail.iter().filter(|s| s.bytes > 0).count(),
            1,
            "strict affinity must keep the op off rail 1: {rep:?}"
        );
        // soft mode re-admits rail 1 at half weight: it carries payload
        // again, but less than the universally-admitted rail
        mr.soft_affinity(true);
        let mut buf = make(8, len);
        let rep2 = mr.allreduce(&mut buf).unwrap();
        let r0 = rep2.per_rail.iter().find(|s| s.rail == 0).unwrap();
        let r1 = rep2.per_rail.iter().find(|s| s.rail == 1).unwrap();
        assert!(r1.bytes > 0, "soft affinity must re-admit rail 1: {rep2:?}");
        assert!(r0.bytes > r1.bytes, "half-admitted rail must carry less: {rep2:?}");
        reduced_ok(&buf, 8, len);
        // strict mode restores the ban
        mr.soft_affinity(false);
        let rep3 = mr.allreduce(&mut make(8, len)).unwrap();
        assert_eq!(rep3.per_rail.iter().filter(|s| s.bytes > 0).count(), 1, "{rep3:?}");
    }

    #[test]
    fn mptcp_parallel_bit_identical_to_serial_with_jitter() {
        // subflow stream timing rides the RailExecutor; per-rail RNG
        // streams keep the sampled times independent of worker
        // interleaving, so the MPTCP baseline is exec-mode invariant too
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Mptcp);
        c.deterministic = false;
        c.exec = ExecMode::Serial;
        let mut serial = MultiRail::new(&c).unwrap();
        c.exec = ExecMode::Parallel;
        let mut parallel = MultiRail::new(&c).unwrap();
        let len = 1 << 20;
        for op in 0..3 {
            let mut bs = make(4, len);
            let mut bp = make(4, len);
            let rs = serial.allreduce(&mut bs).unwrap();
            let rp = parallel.allreduce(&mut bp).unwrap();
            assert_eq!(rs.total_us, rp.total_us, "op {op}: modeled time diverged");
            assert_eq!(rs.per_rail.len(), rp.per_rail.len(), "op {op}");
            for (a, b) in rs.per_rail.iter().zip(&rp.per_rail) {
                assert_eq!(a.rail, b.rail, "op {op}");
                assert_eq!(a.bytes, b.bytes, "op {op}");
                assert_eq!(a.time_us, b.time_us, "op {op} rail {}", a.rail);
            }
            for n in 0..4 {
                assert_eq!(bs.node(n), bp.node(n), "op {op} node {n} numerics diverged");
            }
            reduced_ok(&bp, 4, len);
        }
    }

    #[test]
    fn rail_grants_throttle_ops_and_restore_bit_exactly() {
        let c = cfg(&[ProtoKind::Tcp], 4, Policy::SingleRail);
        let mut mr = MultiRail::new(&c).unwrap();
        let len = 1 << 20;
        let t_solo = mr.allreduce(&mut make(4, len)).unwrap().total_us;
        let e = mr.plan_epoch();
        mr.set_rail_grant(0, 0.5, true);
        assert_eq!(mr.rail_grant(0), 0.5);
        assert!(mr.plan_epoch() > e, "a grant change must flush cached plans");
        let t_half = mr.allreduce(&mut make(4, len)).unwrap().total_us;
        assert!(t_half > t_solo, "half a rail cannot be as fast: {t_solo} vs {t_half}");
        // the whole rail back: modeled times return bit-exactly
        mr.set_rail_grant(0, 1.0, true);
        let t_back = mr.allreduce(&mut make(4, len)).unwrap().total_us;
        assert_eq!(t_back, t_solo);
    }

    #[test]
    fn probe_readmitted_clears_failure_era_state() {
        // regression (heal-then-replan): a readmitted rail used to keep
        // its failure-era Timer windows, cost corrections and straggler
        // stall table, so it stayed priced as broken and never re-earned
        // round-heavy schedules
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv)
            .unwrap()
            .with_faults(FaultSchedule::none().with(1, 0.0, 50_000.0))
            .with_straggler(1, 5_000.0, 0.0);
        let len = 2 * 1024 * 1024; // 8MB → hot → both rails → failover
        let rep = mr.allreduce(&mut make(4, len)).unwrap();
        assert_eq!(rep.failovers, 1);
        assert!(mr.fab.has_straggler(1), "failure-era stall entry installed");
        assert!(mr.fab.now_us() > 50_000.0, "recovery advanced past the window");
        let e = mr.plan_epoch();
        let back = mr.probe_readmitted();
        assert_eq!(back, vec![1]);
        assert!(!mr.fab.has_straggler(1), "stall table must be cleared on readmit");
        assert_eq!(mr.timer.total_ops(1), 0, "Timer history must be forgotten");
        assert_eq!(mr.planner.corrections.observations(1, (len as u64) * 4), 0);
        assert!(mr.plan_epoch() > e, "readmission must start a fresh selection epoch");
        // the healed rail carries payload again
        let mut buf = make(4, len);
        let rep2 = mr.allreduce(&mut buf).unwrap();
        assert_eq!(rep2.per_rail.iter().filter(|s| s.bytes > 0).count(), 2);
        reduced_ok(&buf, 4, len);
    }

    #[test]
    fn node_leave_bumps_epochs_and_invalidates_cache() {
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 8, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv).unwrap();
        let len = 1 << 20; // 4MB
        mr.allreduce(&mut make(8, len)).unwrap();
        assert_eq!(mr.membership_epoch(), 0);
        assert!(mr.plan_cache.keys().all(|k| k.0 == 0));
        let e_plan = mr.plan_epoch();
        let ev = mr.node_leave(7).unwrap();
        assert_eq!(mr.membership_epoch(), 1);
        assert_eq!(ev.epoch, 1);
        assert!(!ev.rejoin);
        assert_eq!(mr.active_nodes(), 7);
        assert!(mr.exceptions.membership_within_budget());
        assert!(mr.plan_epoch() > e_plan, "rebind must start a fresh selection epoch");
        assert!(mr.plan_cache.is_empty(), "stale-epoch entries must be dropped");
        // surviving-set op plans under the new epoch, numerics bit-exact
        // vs a fresh 7-node coordinator (numerics are plan-independent)
        let mut survivors = make(7, len);
        mr.allreduce(&mut survivors).unwrap();
        assert!(mr.plan_cache.keys().all(|k| k.0 == 1), "cache keys carry the epoch");
        reduced_ok(&survivors, 7, len);
        let mut fresh_mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 7, Policy::Nezha)).unwrap();
        let mut fresh = make(7, len);
        fresh_mr.allreduce(&mut fresh).unwrap();
        for n in 0..7 {
            assert_eq!(survivors.node(n), fresh.node(n), "node {n} numerics diverged");
        }
    }

    #[test]
    fn node_rejoin_restores_membership_bit_exactly() {
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 8, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv).unwrap();
        let len = 1 << 20;
        mr.node_leave(3).unwrap();
        assert_eq!(mr.active_nodes(), 7);
        let ev = mr.node_rejoin(3).unwrap();
        assert!(ev.rejoin);
        assert_eq!(mr.membership_epoch(), 2);
        assert_eq!(mr.active_nodes(), 8);
        assert!(mr.departed_nodes().is_empty());
        assert_eq!(mr.planner.topo, mr.home_topo, "round-trip restores the home tree");
        assert!(mr.exceptions.membership_within_budget());
        // post-rejoin numerics bit-exact vs a never-failed run
        let mut buf = make(8, len);
        mr.allreduce(&mut buf).unwrap();
        reduced_ok(&buf, 8, len);
        let mut fresh_mr = MultiRail::new(&cfgv).unwrap();
        let mut fresh = make(8, len);
        fresh_mr.allreduce(&mut fresh).unwrap();
        for n in 0..8 {
            assert_eq!(buf.node(n), fresh.node(n), "node {n} numerics diverged");
        }
    }

    #[test]
    fn scheduled_leave_applies_at_next_op_boundary() {
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv)
            .unwrap()
            .with_membership(MembershipSchedule::none().leave(3, 1.0));
        let len = 1 << 20;
        // the event lands mid-first-op (at 1us): detected like a rail
        // fault when the op completes and the next one begins, never
        // retroactively
        let mut buf = make(4, len);
        mr.allreduce(&mut buf).unwrap();
        assert_eq!(mr.active_nodes(), 4);
        assert_eq!(mr.membership_epoch(), 0);
        reduced_ok(&buf, 4, len);
        // next op: the clock passed the event, the leave applies before
        // the node-count assert, so the surviving-set buffer matches
        let mut buf2 = make(3, len);
        mr.allreduce(&mut buf2).unwrap();
        assert_eq!(mr.active_nodes(), 3);
        assert_eq!(mr.membership_epoch(), 1);
        reduced_ok(&buf2, 3, len);
    }

    #[test]
    fn membership_errors_leave_state_untouched() {
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv).unwrap();
        assert!(mr.node_leave(9).is_err(), "unknown node");
        assert!(mr.node_rejoin(0).is_err(), "not departed");
        mr.node_leave(0).unwrap();
        mr.node_leave(1).unwrap();
        // dropping below 2 survivors must fail and change nothing
        let before = mr.membership_epoch();
        assert!(mr.node_leave(2).is_err());
        assert_eq!(mr.membership_epoch(), before);
        assert_eq!(mr.active_nodes(), 2);
        assert_eq!(mr.departed_nodes(), &[0, 1]);
        // a batch with an in-batch duplicate is rejected atomically
        assert!(mr.nodes_leave(&[2, 2]).is_err());
        assert_eq!(mr.active_nodes(), 2);
        // ops keep running on the unchanged membership
        let mut buf = make(2, 1 << 20);
        mr.allreduce(&mut buf).unwrap();
        reduced_ok(&buf, 2, 1 << 20);
    }

    #[test]
    fn brownout_demotes_rail_then_restores() {
        // a brownout is a gray failure: the monitor soft-demotes the rail
        // (it keeps carrying payload at reduced share) and restores it
        // once corrections absorb the slowdown — it never quarantines
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.health.dirty_inc = 4.0; // one dirty residual crosses degrade_enter
        let mut mr = MultiRail::new(&c)
            .unwrap()
            .with_degrade(DegradeSchedule::none().brownout(1, 0.0, 1e12, 0.45));
        // fixed shares keep rail 1's size class stable so the clean-decay
        // sequence (4 → 2 → 1 → 0.5 → restore) is exact
        mr.partitioner = Box::new(crate::baselines::FixedShares::percent(50, 50));
        let elem_bytes = (16u64 << 20) as f64 / 1024.0;
        let mut last = None;
        for _ in 0..8 {
            let mut buf = make(4, 1024);
            last = Some(mr.allreduce_scaled(&mut buf, elem_bytes).unwrap());
            reduced_ok(&buf, 4, 1024);
        }
        let gray = &mr.exceptions.gray;
        assert!(
            gray.iter().any(|g| g.rail == 1 && g.action == GrayAction::Demote),
            "brownout must soft-demote rail 1: {gray:?}"
        );
        assert!(
            !gray.iter().any(|g| g.action == GrayAction::Quarantine),
            "residual evidence alone must never quarantine in graceful mode: {gray:?}"
        );
        assert!(
            mr.monitor
                .transitions()
                .iter()
                .any(|t| t.rail == 1 && t.from == RailHealth::Degraded && t.to == RailHealth::Healthy),
            "clean ops must restore the demoted rail: {:?}",
            mr.monitor.transitions()
        );
        assert_eq!(mr.fab.rails[1].health, RailHealth::Healthy);
        // the restored rail carries payload on the final op
        let rep = last.unwrap();
        assert_eq!(rep.per_rail.iter().filter(|s| s.bytes > 0).count(), 2, "{rep:?}");
    }

    #[test]
    fn crash_failover_readmits_through_probation() {
        // with the monitor on, a recovered rail is a canary first: Q → P
        // at probation_weight share, promoted H only after probation_ops
        // clean ops — replacing the legacy trust-on-readmit probe
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.faults = FaultSchedule::none().with(1, 0.0, 50_000.0);
        let mut mr = MultiRail::new(&c).unwrap();
        let len = 2 * 1024 * 1024; // 8MB → hot → both rails → failover
        let rep = mr.allreduce(&mut make(4, len)).unwrap();
        assert_eq!(rep.failovers, 1);
        assert_eq!(mr.fab.rails[1].health, RailHealth::Quarantined);
        for _ in 0..4 {
            let mut buf = make(4, len);
            let rep = mr.allreduce(&mut buf).unwrap();
            assert_eq!(rep.failovers, 0);
            reduced_ok(&buf, 4, len);
        }
        let ts = mr.monitor.transitions();
        let hops: Vec<(RailHealth, RailHealth)> = ts
            .iter()
            .filter(|t| t.rail == 1)
            .map(|t| (t.from, t.to))
            .collect();
        assert!(
            hops.contains(&(RailHealth::Healthy, RailHealth::Quarantined)),
            "failover must register as a quarantine: {hops:?}"
        );
        assert!(
            hops.contains(&(RailHealth::Quarantined, RailHealth::Probation)),
            "readmission must pass through probation: {hops:?}"
        );
        assert!(
            hops.contains(&(RailHealth::Probation, RailHealth::Healthy)),
            "a clean streak must promote the canary: {hops:?}"
        );
        assert_eq!(mr.fab.rails[1].health, RailHealth::Healthy);
        let gray = &mr.exceptions.gray;
        assert!(gray.iter().any(|g| g.action == GrayAction::Probation));
        assert!(gray.iter().any(|g| g.action == GrayAction::Readmit));
        assert!(mr.exceptions.gray_within_budget());
    }

    #[test]
    fn loss_storm_quarantines_noisy_rail() {
        // sustained heavy loss: retry suspicion is uncapped in total, so
        // the rail escalates Degraded → Quarantined (or blows the retry
        // cap and rides the §4.4 failover — same terminal state); the
        // loss-free rail never transitions
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv)
            .unwrap()
            .with_degrade(DegradeSchedule::none().loss(1, 0.0, 1e12, 0.2));
        let len = 2 * 1024 * 1024;
        for _ in 0..8 {
            let mut buf = make(4, len);
            mr.allreduce(&mut buf).unwrap();
            reduced_ok(&buf, 4, len);
        }
        assert!(
            mr.monitor
                .transitions()
                .iter()
                .any(|t| t.rail == 1 && t.to == RailHealth::Quarantined),
            "a loss storm must quarantine the rail: {:?}",
            mr.monitor.transitions()
        );
        assert_eq!(mr.monitor.transition_count(0), 0, "the clean rail must not flap");
        assert!(
            mr.monitor.transition_count(1) <= 12,
            "dwell backoff must bound oscillation: {:?}",
            mr.monitor.transitions()
        );
    }

    #[test]
    fn last_usable_rail_is_demoted_not_quarantined() {
        // quarantining the only remaining allowed rail would kill the job;
        // the monitor falls back to demotion — limping beats dead
        let mut mr =
            MultiRail::new(&cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha)).unwrap();
        mr.fab.deregister(1);
        let mut actions = Vec::new();
        for _ in 0..4 {
            mr.monitor.observe(0, 100.0, 10_000.0, 20);
            mr.monitor.decide(&mr.fab, &mut actions);
            for &a in &actions {
                mr.apply_health_action(a);
            }
        }
        assert!(mr.monitor.suspicion(0) >= mr.monitor.cfg.quarantine_enter);
        assert_eq!(mr.fab.rails[0].health, RailHealth::Degraded, "fallback is demotion");
        assert!(mr.fab.rails[0].is_usable());
        let mut buf = make(4, 1 << 20);
        mr.allreduce(&mut buf).unwrap();
        reduced_ok(&buf, 4, 1 << 20);
    }

    #[test]
    fn corruption_storm_quarantines_rail_and_stays_bit_exact() {
        // integrity ON: persistent corruption is recharged on the unified
        // retry ledger, so suspicion escalates the rail through the SAME
        // Healthy → Degraded → Quarantined machine a loss storm rides —
        // no corruption-specific recovery path — while numerics stay
        // bit-exact vs a fault-free twin
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv)
            .unwrap()
            .with_corrupt(CorruptSchedule::none().flip(1, 0.0, 1e12, 0.2));
        let mut twin = MultiRail::new(&cfgv).unwrap();
        let len = 2 * 1024 * 1024;
        for op in 0..8 {
            let mut buf = make(4, len);
            let mut clean = make(4, len);
            mr.allreduce(&mut buf).unwrap();
            twin.allreduce(&mut clean).unwrap();
            for n in 0..4 {
                assert_eq!(buf.node(n), clean.node(n), "op {op} node {n} diverged");
            }
            reduced_ok(&buf, 4, len);
        }
        assert!(mr.fab.corruptions_on(1) > 0, "the injector must actually fire");
        assert!(
            mr.monitor
                .transitions()
                .iter()
                .any(|t| t.rail == 1 && t.to == RailHealth::Quarantined),
            "a corruption storm must quarantine the rail: {:?}",
            mr.monitor.transitions()
        );
        assert_eq!(mr.monitor.transition_count(0), 0, "the clean rail must not flap");
        assert!(
            mr.monitor.transition_count(1) <= 12,
            "dwell backoff must bound oscillation: {:?}",
            mr.monitor.transitions()
        );
    }

    #[test]
    fn corruption_without_integrity_poisons_the_reduction() {
        // integrity OFF: the same schedule escapes the wire checks and
        // reaches the numerics — the ablation's measurable escape
        let cfgv = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        let mut mr = MultiRail::new(&cfgv)
            .unwrap()
            .with_corrupt(CorruptSchedule::none().flip(1, 0.0, 1e12, 0.5))
            .with_integrity(false);
        let mut twin = MultiRail::new(&cfgv).unwrap();
        let len = 2 * 1024 * 1024;
        let mut diverged = false;
        for _ in 0..4 {
            let mut buf = make(4, len);
            let mut clean = make(4, len);
            mr.allreduce(&mut buf).unwrap();
            twin.allreduce(&mut clean).unwrap();
            if (0..4).any(|n| buf.node(n) != clean.node(n)) {
                diverged = true;
            }
        }
        assert!(mr.fab.corruptions_on(1) > 0, "the injector must actually fire");
        assert!(diverged, "unchecked corruption must reach the reduced values");
        // silent: nothing hit the unified retry ledger, so the monitor
        // never saw the rail misbehave
        assert_eq!(mr.fab.retries_on(1), 0);
    }

    #[test]
    fn rebind_carries_timer_windows_warm() {
        // warm-start rebinding (PR 7 follow-on): a membership rebind
        // reprices the carried Timer windows by the round ratio instead of
        // wiping them — the surviving set keeps live priors
        let cfgv = cfg(&[ProtoKind::Tcp], 8, Policy::SingleRail);
        let mut mr = MultiRail::new(&cfgv).unwrap();
        let len = 1 << 20; // 4MB, all on rail 0
        for _ in 0..4 {
            mr.allreduce(&mut make(8, len)).unwrap();
        }
        let class = (len as u64) * 4;
        let before = mr.timer.cost(0, class).expect("warm-up must price the class");
        let ops = mr.timer.total_ops(0);
        assert!(ops > 0);
        mr.node_leave(7).unwrap();
        let after = mr
            .timer
            .cost(0, class)
            .expect("the window must survive the rebind");
        let expect = before * 6.0 / 7.0; // 2(n-1)-round ratio: 8 -> 7 nodes
        assert!(
            (after - expect).abs() < 1e-6 * before,
            "carried window must be repriced by the round ratio: before {before} after {after}"
        );
        assert_eq!(mr.timer.total_ops(0), ops, "history carried, not wiped");
        // the carried prior keeps pricing ops for the surviving set
        let mut buf = make(7, len);
        mr.allreduce(&mut buf).unwrap();
        reduced_ok(&buf, 7, len);
    }

    #[test]
    fn monitor_off_keeps_legacy_trust_on_readmit() {
        let mut c = cfg(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, Policy::Nezha);
        c.health.mode = crate::coordinator::control::HealthMode::Off;
        c.faults = FaultSchedule::none().with(1, 0.0, 50_000.0);
        let mut mr = MultiRail::new(&c).unwrap();
        let len = 2 * 1024 * 1024;
        let rep = mr.allreduce(&mut make(4, len)).unwrap();
        assert_eq!(rep.failovers, 1);
        // legacy path: straight back to Healthy, no probation canary
        let rep2 = mr.allreduce(&mut make(4, len)).unwrap();
        assert_eq!(rep2.failovers, 0);
        assert_eq!(mr.fab.rails[1].health, RailHealth::Healthy);
        assert!(mr.monitor.transitions().is_empty(), "monitor off records nothing");
        assert!(mr.exceptions.gray.is_empty());
    }
}
