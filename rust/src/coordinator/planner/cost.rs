//! α-β (latency/bandwidth) cost model for candidate schedules, plus the
//! measurement-corrected layer on top of it.
//!
//! The base model is calibrated from the same per-protocol tables the
//! fabric uses (`net/protocol.rs`: setup latency α, size-dependent
//! effective bandwidth β(S), core-scaling and cross-member contention), so
//! cost-model predictions and deterministic fabric measurements agree by
//! construction. All estimates are jitter-free: the planner must be
//! deterministic for a given fabric state.
//!
//! [`CorrectedCost`] blends that a-priori model with the Timer's live
//! observations ("Is Network the Bottleneck?" shows measured link
//! performance routinely diverges from nominal specs): each completed
//! rail-op feeds back (a) a per-round additive excess — the signature of a
//! straggling rail stalling every lockstep round — and (b) a multiplicative
//! residual of measured over corrected-predicted time. Candidate schedules
//! then pay `rounds × round_extra`, so a persistently slow rail changes
//! not just its share (Load Balancer) but its *schedule*: round-heavy
//! deep-chunk pipelines lose to few-round schedules once per-round stalls
//! dominate. With zero observations the corrected cost IS the pure α-β
//! model, exactly (property-tested).

use std::collections::HashMap;

use crate::coordinator::control::size_bucket;
use crate::coordinator::planner::plan::Schedule;
use crate::net::simnet::Fabric;
use crate::net::topology::{IntraLink, TopologyTree};

/// Deterministic point-to-point message time on `rail` (us) at the current
/// core allocation and contention — the α + S/β kernel every schedule cost
/// composes. Delegates to the fabric's own jitter-free transfer kernel so
/// predictions match deterministic measurements by construction.
pub fn msg_us(fab: &Fabric, rail: usize, bytes: f64) -> f64 {
    fab.transfer_det_us(rail, bytes)
}

/// Single-level flat ring: `2(N-1)` rounds of `S/N`-byte messages.
pub fn flat_ring_us(fab: &Fabric, rail: usize, bytes: f64, n: usize) -> f64 {
    let steps = 2 * (n - 1);
    steps as f64 * msg_us(fab, rail, bytes / n as f64)
}

/// Chunk-pipelined ring: `2(N-1) + chunks - 1` rounds. Pipelining hides
/// latency, never volume — the per-node wire volume stays the ring's
/// `2(N-1)·S/N` and is spread evenly over the pipeline rounds, so deeper
/// pipelines pay more setups but move smaller messages that ride the
/// pre-decline part of the bandwidth curve (and stay below NIC-crashing
/// sizes, the paper's >1 GB segfault).
pub fn ring_chunked_us(fab: &Fabric, rail: usize, bytes: f64, n: usize, chunks: usize) -> f64 {
    let chunks = chunks.max(1);
    if chunks == 1 {
        // exact flat-ring degenerate (avoids (k*x)/k float round-trip)
        return flat_ring_us(fab, rail, bytes, n);
    }
    let rounds = 2 * (n - 1) + chunks - 1;
    let volume = 2.0 * (n - 1) as f64 * (bytes / n as f64);
    rounds as f64 * msg_us(fab, rail, volume / rounds as f64)
}

/// Recursive halving/doubling: `log2(N)` reduce-scatter rounds of
/// `S/2, S/4, …, S/N` bytes plus the mirrored allgather — same `2S(N-1)/N`
/// volume as the ring in `2*log2(N)` rounds. Caller guarantees `N` is a
/// power of two ≥ 2.
pub fn halving_doubling_us(fab: &Fabric, rail: usize, bytes: f64, n: usize) -> f64 {
    debug_assert!(n.is_power_of_two() && n >= 2);
    let mut total = 0.0;
    let mut divisor = 2.0;
    for _ in 0..n.trailing_zeros() {
        total += 2.0 * msg_us(fab, rail, bytes / divisor);
        divisor *= 2.0;
    }
    total
}

/// One intra-group phase (reduce-scatter or allgather): a `(g-1)`-step
/// ring over `S/g`-byte segments on the local fabric. Zero when grouping
/// is degenerate — the two-level cost then collapses to the flat/chunked
/// ring exactly.
pub fn intra_phase_us(intra: &IntraLink, bytes: f64) -> f64 {
    if intra.group_size <= 1 {
        return 0.0;
    }
    let g = intra.group_size as f64;
    (g - 1.0) * (intra.setup_us + (bytes / g) / intra.bw_mbps)
}

/// Hierarchical two-level schedule on one rail:
/// intra-group reduce-scatter + `2(N/g - 1) + chunks - 1` chunk-pipelined
/// inter-group rounds + intra-group allgather.
///
/// The win: `2S(g-1)/g` of the volume moves on the intra-group fabric and
/// the rail only carries `~2S/g`, in `g×` fewer rounds than the flat ring.
/// With `group_size == 1` this is bit-for-bit the (chunked) flat ring.
pub fn two_level_us(
    fab: &Fabric,
    rail: usize,
    bytes: f64,
    n: usize,
    intra: &IntraLink,
    chunks: usize,
) -> f64 {
    let g = intra.group_size.max(1);
    if g == 1 {
        return ring_chunked_us(fab, rail, bytes, n, chunks);
    }
    debug_assert!(n % g == 0 && n / g >= 2, "caller must validate grouping");
    let groups = n / g;
    let chunks = chunks.max(1);
    let rounds = 2 * (groups - 1) + chunks - 1;
    // per-node inter-group wire volume: 2(G-1)/G of the S/g slice
    let volume = 2.0 * (groups - 1) as f64 * (bytes / n as f64);
    let inter = rounds as f64 * msg_us(fab, rail, volume / rounds as f64);
    2.0 * intra_phase_us(intra, bytes) + inter
}

/// One lockstep phase (reduce-scatter or allgather) at `level` of a
/// multi-level topology: a ring among each group's subgroups on that
/// level's local fabric. Same algebra as [`intra_phase_us`] applied per
/// level — `(m − 1) · (setup + (S/C)/bw)` with `m` the largest subgroup
/// count per group and `C` the largest group (non-uniform levels are
/// lockstep, so the biggest group is the critical path). Zero for
/// degenerate levels, so a one-level uniform tree prices bit-identically
/// to the legacy two-level intra phase.
pub fn tree_phase_us(tree: &TopologyTree, level: usize, nodes: usize, bytes: f64) -> f64 {
    let lv = &tree.levels[level];
    let m = tree.max_subgroups(level, nodes) as f64;
    if m <= 1.0 {
        return 0.0;
    }
    let c = tree.max_group(level) as f64;
    (m - 1.0) * (lv.setup_us + (bytes / c) / lv.bw_mbps)
}

/// N-level hierarchical schedule on one rail, cutting the topology tree
/// after its innermost `depth` levels: one reduce-scatter + allgather
/// phase pair per engaged level (local fabrics), with a chunk-pipelined
/// `2(G−1) + chunks − 1`-round inter-group ring across the `G` outermost
/// engaged groups on the rail in between.
///
/// The win over the two-level cut: each extra level moves another slice
/// of the volume onto a fabric faster than the rail AND shrinks the
/// rail's round count (`G` drops from `n/g_rack` to `n/g_pod`). Cut
/// depth 0 is bit-for-bit the (chunked) flat ring; depth 1 on a uniform
/// level is bit-for-bit [`two_level_us`]. Caller validates the cut
/// (`TopologyTree::valid_cut_depth`); invalid cuts fall back to the flat
/// ring exactly as `run_plan` executes them.
pub fn multi_level_us(
    fab: &Fabric,
    rail: usize,
    bytes: f64,
    n: usize,
    tree: &TopologyTree,
    depth: usize,
    chunks: usize,
) -> f64 {
    if depth == 0 || tree.is_flat() {
        return ring_chunked_us(fab, rail, bytes, n, chunks);
    }
    let depth = depth.min(tree.depth());
    debug_assert!(tree.valid_cut_depth(depth, n), "caller must validate the cut");
    let groups = tree.group_count(depth - 1, n);
    if groups < 2 {
        return ring_chunked_us(fab, rail, bytes, n, chunks);
    }
    let mut total = 0.0;
    for lv in 0..depth {
        total += 2.0 * tree_phase_us(tree, lv, n, bytes);
    }
    let chunks = chunks.max(1);
    let rounds = 2 * (groups - 1) + chunks - 1;
    let volume = 2.0 * (groups - 1) as f64 * (bytes / n as f64);
    total + rounds as f64 * msg_us(fab, rail, volume / rounds as f64)
}

/// In-network tree aggregation (SHARP): the fabric's analytic estimate.
pub fn tree_us(fab: &Fabric, rail: usize, bytes: f64) -> f64 {
    fab.estimate_allreduce_us(rail, bytes)
}

/// Contended cost of a schedule the pure model prices at `model_us`, of
/// which `fixed_us` is rail-setup and local-fabric time: under an
/// arbiter grant of `share` of the rail, only the rail's transfer
/// component — `model_us - fixed_us` — stretches by `1/share`. This is
/// exactly how the fabric charges contended rounds (setup-preserving
/// inflation per message), so contended predictions still match
/// deterministic contended measurements. A whole-rail grant returns
/// `model_us` bit-exactly, keeping solo pricing byte-identical to the
/// uncontended planner.
///
/// Because the fixed component is round-count-proportional while the
/// stretched component is volume-proportional, shrinking `share` shifts
/// the candidate ranking: round-heavy deep-chunk pipelines (whose cost
/// is setup-rich) fade more slowly than bandwidth-bound flat rings, so
/// plans genuinely move under contention.
pub fn contended_us(model_us: f64, fixed_us: f64, share: f64) -> f64 {
    let share = share.clamp(crate::net::simnet::MIN_RAIL_SHARE, 1.0);
    if share >= 1.0 {
        return model_us;
    }
    fixed_us + (model_us - fixed_us) / share
}

/// Lockstep fabric rounds a schedule executes **on the rail** for `n`
/// nodes — the unit the per-round straggler correction multiplies. Matches
/// the executable schedules exactly: two-level counts only its inter-group
/// rounds (intra phases ride the local fabric, not the rail), and
/// halving-doubling on a non-power-of-two falls back to the flat ring just
/// like `run_plan` does.
pub fn schedule_rounds(s: Schedule, n: usize) -> usize {
    match s.normalized() {
        Schedule::Tree => 1,
        Schedule::FlatRing => 2 * (n - 1),
        Schedule::RingChunked { chunks } => 2 * (n - 1) + chunks - 1,
        Schedule::HalvingDoubling => {
            if n.is_power_of_two() {
                2 * n.trailing_zeros() as usize
            } else {
                2 * (n - 1)
            }
        }
        Schedule::TwoLevel { group, chunks } => {
            let g = group.max(1);
            if g > 1 && n % g == 0 && n / g >= 2 {
                2 * (n / g - 1) + chunks.max(1) - 1
            } else {
                // invalid grouping executes as the seed's flat ring
                2 * (n - 1)
            }
        }
        Schedule::MultiLevel { groups, chunks, .. } => {
            // inner-level phases ride local fabrics, not the rail
            if groups >= 2 && groups <= n {
                2 * (groups - 1) + chunks.max(1) - 1
            } else {
                // invalid grouping executes as the seed's flat ring
                2 * (n - 1)
            }
        }
    }
}

/// EWMA weight for new correction observations.
const CORR_EWMA: f64 = 0.25;
/// Clamp band for the multiplicative residual (measured / predicted).
const RATIO_MIN: f64 = 0.2;
const RATIO_MAX: f64 = 10.0;
/// Corrected costs never drop below this fraction of the pure model (a
/// rail can measure faster than spec, but not implausibly so).
const FLOOR_FRAC: f64 = 0.1;

#[derive(Debug, Clone)]
struct ClassCorr {
    /// Additive per-round excess (us/round): straggler stalls.
    round_extra_us: f64,
    /// Multiplicative residual of measured over corrected-predicted time.
    ratio: f64,
    /// EWMA of the relative |predicted − measured| / measured error — the
    /// replan trigger signal.
    rel_err: f64,
    obs: u64,
}

impl Default for ClassCorr {
    fn default() -> Self {
        ClassCorr { round_extra_us: 0.0, ratio: 1.0, rel_err: 0.0, obs: 0 }
    }
}

/// Measurement-corrected cost layer: per-(rail, size-bucket) EWMA
/// corrections over the pure α-β model, learned from completed rail-ops.
#[derive(Debug, Clone, Default)]
pub struct CorrectedCost {
    classes: HashMap<(usize, u32), ClassCorr>,
}

impl CorrectedCost {
    pub fn new() -> CorrectedCost {
        CorrectedCost::default()
    }

    /// Feed back one completed rail-op: the schedule ran `rounds` fabric
    /// rounds, the pure model said `model_us`, the (then-current) corrected
    /// prediction said `predicted_us`, and the fabric measured
    /// `measured_us`.
    pub fn observe(
        &mut self,
        rail: usize,
        bytes: u64,
        rounds: usize,
        model_us: f64,
        predicted_us: f64,
        measured_us: f64,
    ) {
        if rounds == 0 || model_us <= 0.0 || measured_us <= 0.0 {
            return;
        }
        let c = self.classes.entry((rail, size_bucket(bytes))).or_default();
        let extra = (measured_us - model_us) / rounds as f64;
        c.round_extra_us += CORR_EWMA * (extra - c.round_extra_us);
        if predicted_us > 0.0 {
            let r = (measured_us / predicted_us).clamp(RATIO_MIN, RATIO_MAX);
            c.ratio += CORR_EWMA * (r - c.ratio);
            let e = (predicted_us - measured_us).abs() / measured_us;
            c.rel_err += CORR_EWMA * (e - c.rel_err);
        }
        c.obs += 1;
    }

    /// Corrected cost of a candidate that the pure model prices at
    /// `model_us` over `rounds` rail rounds. Exactly `model_us` when this
    /// class has no observations.
    pub fn corrected_us(&self, rail: usize, bytes: u64, rounds: usize, model_us: f64) -> f64 {
        match self.classes.get(&(rail, size_bucket(bytes))) {
            None => model_us,
            Some(c) => {
                let t = (model_us + rounds as f64 * c.round_extra_us) * c.ratio;
                t.max(FLOOR_FRAC * model_us)
            }
        }
    }

    /// Learned per-round excess for this class (0 with no observations).
    pub fn round_extra_us(&self, rail: usize, bytes: u64) -> f64 {
        self.classes
            .get(&(rail, size_bucket(bytes)))
            .map(|c| c.round_extra_us)
            .unwrap_or(0.0)
    }

    /// Learned multiplicative residual (1 with no observations).
    pub fn ratio(&self, rail: usize, bytes: u64) -> f64 {
        self.classes
            .get(&(rail, size_bucket(bytes)))
            .map(|c| c.ratio)
            .unwrap_or(1.0)
    }

    /// EWMA'd relative prediction error for this class — the replan
    /// trigger signal. `None` until the class has observations.
    pub fn error(&self, rail: usize, bytes: u64) -> Option<f64> {
        self.classes
            .get(&(rail, size_bucket(bytes)))
            .filter(|c| c.obs > 0)
            .map(|c| c.rel_err)
    }

    pub fn observations(&self, rail: usize, bytes: u64) -> u64 {
        self.classes
            .get(&(rail, size_bucket(bytes)))
            .map(|c| c.obs)
            .unwrap_or(0)
    }

    /// Forget a rail's corrections (after failover the channel's behaviour
    /// may have changed; §4.4 — mirrors `Timer::forget_rail`).
    pub fn forget_rail(&mut self, rail: usize) {
        self.classes.retain(|(r, _), _| *r != rail);
    }

    /// Drop every class (membership churn re-primes the whole corrected
    /// layer: the surviving set's round counts changed on every rail, so
    /// stale per-class excesses would mis-price every candidate).
    pub fn clear(&mut self) {
        self.classes.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::{ProtoKind, MB};
    use crate::net::topology::ClusterSpec;

    fn fab(kinds: &[ProtoKind], nodes: usize) -> Fabric {
        let rails = ClusterSpec::local().build_rails(kinds).unwrap();
        Fabric::new(nodes, rails, CpuPool::default(), 3).deterministic()
    }

    #[test]
    fn flat_ring_matches_fabric_estimate() {
        let f = fab(&[ProtoKind::Tcp], 4);
        let est = f.estimate_allreduce_us(0, 8.0 * MB);
        let got = flat_ring_us(&f, 0, 8.0 * MB, 4);
        assert!((got - est).abs() / est < 0.01, "got {got} est {est}");
    }

    #[test]
    fn chunked_with_one_chunk_is_flat() {
        let f = fab(&[ProtoKind::Tcp], 8);
        let s = 16.0 * MB;
        assert_eq!(ring_chunked_us(&f, 0, s, 8, 1), flat_ring_us(&f, 0, s, 8));
    }

    #[test]
    fn halving_doubling_beats_flat_on_latency_bound_payloads() {
        let f = fab(&[ProtoKind::Tcp], 8);
        let s = 256.0 * 1024.0;
        assert!(halving_doubling_us(&f, 0, s, 8) < flat_ring_us(&f, 0, s, 8));
    }

    #[test]
    fn two_level_degenerates_to_flat_ring_exactly() {
        let f = fab(&[ProtoKind::Tcp], 8);
        let link = IntraLink { group_size: 1, bw_mbps: 5000.0, setup_us: 15.0 };
        for s in [64.0 * 1024.0, 8.0 * MB] {
            assert_eq!(two_level_us(&f, 0, s, 8, &link, 1), flat_ring_us(&f, 0, s, 8));
            assert_eq!(intra_phase_us(&link, s), 0.0);
        }
    }

    #[test]
    fn two_level_beats_flat_on_grouped_16_nodes() {
        let f = fab(&[ProtoKind::Tcp], 16);
        let link = IntraLink { group_size: 4, bw_mbps: 5000.0, setup_us: 15.0 };
        let s = 16.0 * MB;
        let flat = flat_ring_us(&f, 0, s, 16);
        let two = two_level_us(&f, 0, s, 16, &link, 1);
        assert!(two < 0.6 * flat, "two-level {two} vs flat {flat}");
    }

    #[test]
    fn multi_level_depth1_is_exactly_two_level() {
        use crate::net::topology::ClusterSpec;
        let f = fab(&[ProtoKind::Tcp], 16);
        let tree = &ClusterSpec::pods(4).topo;
        let link = tree.level_link(0).unwrap();
        for s in [64.0 * 1024.0, 8.0 * MB, 256.0 * MB] {
            for chunks in [1usize, 4, 16] {
                assert_eq!(
                    multi_level_us(&f, 0, s, 16, tree, 1, chunks),
                    two_level_us(&f, 0, s, 16, &link, chunks),
                    "S={s} chunks={chunks}"
                );
            }
            // depth 0 is the (chunked) flat ring, bit-for-bit
            assert_eq!(multi_level_us(&f, 0, s, 16, tree, 0, 1), flat_ring_us(&f, 0, s, 16));
        }
    }

    #[test]
    fn deeper_cut_beats_two_level_on_racked_pods() {
        use crate::net::topology::ClusterSpec;
        let f = fab(&[ProtoKind::Tcp], 32);
        let tree = &ClusterSpec::racked_pods(4, 16).topo;
        let s = 64.0 * MB;
        let flat = flat_ring_us(&f, 0, s, 32);
        let d1 = multi_level_us(&f, 0, s, 32, tree, 1, 1);
        let d2 = multi_level_us(&f, 0, s, 32, tree, 2, 1);
        assert!(d1 < flat, "rack cut {d1} vs flat {flat}");
        assert!(d2 < d1, "pod cut {d2} vs rack cut {d1}");
    }

    #[test]
    fn non_uniform_phase_priced_by_largest_group() {
        use crate::net::topology::{TopoLevel, TopologyTree};
        let uneven = TopologyTree {
            levels: vec![TopoLevel::explicit("group", vec![2, 6, 4, 4], 5000.0, 15.0)],
        };
        let even = TopologyTree {
            levels: vec![TopoLevel::uniform("group", 6, 5000.0, 15.0)],
        };
        let s = 8.0 * MB;
        // lockstep: the 6-node group dominates, so the phase prices as a
        // uniform 6-node group's would
        assert_eq!(tree_phase_us(&uneven, 0, 16, s), tree_phase_us(&even, 0, 36, s));
        assert!(tree_phase_us(&uneven, 0, 16, s) > 0.0);
    }

    #[test]
    fn tree_cost_is_fabric_estimate() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Sharp], 4);
        assert_eq!(tree_us(&f, 1, MB), f.estimate_allreduce_us(1, MB));
    }

    #[test]
    fn schedule_rounds_match_executable_schedules() {
        assert_eq!(schedule_rounds(Schedule::FlatRing, 8), 14);
        assert_eq!(schedule_rounds(Schedule::RingChunked { chunks: 4 }, 8), 17);
        assert_eq!(schedule_rounds(Schedule::HalvingDoubling, 8), 6);
        // non-power-of-two halving-doubling executes as the flat ring
        assert_eq!(schedule_rounds(Schedule::HalvingDoubling, 6), 10);
        // two-level counts only inter-group rail rounds
        assert_eq!(schedule_rounds(Schedule::TwoLevel { group: 4, chunks: 1 }, 16), 6);
        assert_eq!(schedule_rounds(Schedule::TwoLevel { group: 4, chunks: 16 }, 16), 21);
        // degenerate grouping normalizes to the (chunked) flat ring
        assert_eq!(schedule_rounds(Schedule::TwoLevel { group: 1, chunks: 1 }, 8), 14);
        assert_eq!(schedule_rounds(Schedule::Tree, 8), 1);
        // multi-level counts only its inter-group rail rounds
        assert_eq!(
            schedule_rounds(Schedule::MultiLevel { depth: 2, groups: 2, chunks: 1 }, 32),
            2
        );
        assert_eq!(
            schedule_rounds(Schedule::MultiLevel { depth: 2, groups: 2, chunks: 8 }, 32),
            9
        );
        // degenerate/invalid groupings execute as the flat ring
        assert_eq!(
            schedule_rounds(Schedule::MultiLevel { depth: 2, groups: 1, chunks: 1 }, 8),
            14
        );
        assert_eq!(
            schedule_rounds(Schedule::MultiLevel { depth: 1, groups: 64, chunks: 1 }, 8),
            14
        );
    }

    #[test]
    fn contended_cost_stretches_transfer_only() {
        // share 1.0 is the identity, bit-exactly
        assert_eq!(contended_us(10_000.0, 1_500.0, 1.0), 10_000.0);
        assert_eq!(contended_us(10_000.0, 1_500.0, 2.0), 10_000.0);
        // half the rail: transfer doubles, the fixed part does not
        let t = contended_us(10_000.0, 1_500.0, 0.5);
        assert!((t - (1_500.0 + 8_500.0 / 0.5)).abs() < 1e-9, "t {t}");
        // shares clamp at the preemption floor instead of diverging
        let floor = contended_us(10_000.0, 1_500.0, 0.0);
        assert_eq!(floor, contended_us(10_000.0, 1_500.0, crate::net::simnet::MIN_RAIL_SHARE));
        assert!(floor.is_finite());
    }

    #[test]
    fn contention_reranks_setup_heavy_vs_bandwidth_heavy_schedules() {
        // two candidates equal at solo price: one setup-rich, one
        // bandwidth-rich — contention must prefer the setup-rich one
        let setup_rich = contended_us(10_000.0, 6_000.0, 0.25);
        let bw_rich = contended_us(10_000.0, 1_000.0, 0.25);
        assert!(setup_rich < bw_rich, "{setup_rich} vs {bw_rich}");
    }

    #[test]
    fn corrections_start_as_the_pure_model() {
        let c = CorrectedCost::new();
        for (rounds, model) in [(1usize, 42.0), (14, 9_000.0), (29, 1.5e6)] {
            assert_eq!(c.corrected_us(0, 8 << 20, rounds, model), model);
        }
        assert_eq!(c.round_extra_us(0, 1024), 0.0);
        assert_eq!(c.ratio(0, 1024), 1.0);
        assert!(c.error(0, 1024).is_none());
    }

    #[test]
    fn straggler_stalls_learned_as_per_round_excess() {
        let mut c = CorrectedCost::new();
        // 14-round schedule, model 10ms, measured 10ms + 14×500us stalls
        for _ in 0..40 {
            c.observe(0, 8 << 20, 14, 10_000.0, 10_000.0, 17_000.0);
        }
        let extra = c.round_extra_us(0, 8 << 20);
        assert!((extra - 500.0).abs() < 10.0, "extra {extra}");
        // a 6-round candidate is now penalized far less than a 29-round one
        let few = c.corrected_us(0, 8 << 20, 6, 10_000.0);
        let many = c.corrected_us(0, 8 << 20, 29, 10_000.0);
        assert!(many - few > 10_000.0, "few {few} many {many}");
        // other classes stay pure
        assert_eq!(c.corrected_us(1, 8 << 20, 14, 10_000.0), 10_000.0);
        assert_eq!(c.corrected_us(0, 1 << 10, 14, 10_000.0), 10_000.0);
    }

    #[test]
    fn error_tracks_prediction_quality_and_forgets() {
        let mut c = CorrectedCost::new();
        c.observe(2, 1 << 20, 10, 1_000.0, 1_000.0, 1_500.0);
        let e = c.error(2, 1 << 20).unwrap();
        assert!(e > 0.0, "err {e}");
        assert_eq!(c.observations(2, 1 << 20), 1);
        // accurate predictions drive the error back down
        for _ in 0..60 {
            c.observe(2, 1 << 20, 10, 1_000.0, 1_500.0, 1_500.0);
        }
        assert!(c.error(2, 1 << 20).unwrap() < 0.01);
        c.forget_rail(2);
        assert!(c.error(2, 1 << 20).is_none());
    }
}
