//! α-β (latency/bandwidth) cost model for candidate schedules.
//!
//! Calibrated from the same per-protocol tables the fabric uses
//! (`net/protocol.rs`: setup latency α, size-dependent effective bandwidth
//! β(S), core-scaling and cross-member contention), so cost-model
//! predictions and deterministic fabric measurements agree by
//! construction. All estimates are jitter-free: the planner must be
//! deterministic for a given fabric state.

use crate::net::simnet::Fabric;
use crate::net::topology::IntraLink;

/// Deterministic point-to-point message time on `rail` (us) at the current
/// core allocation and contention — the α + S/β kernel every schedule cost
/// composes. Delegates to the fabric's own jitter-free transfer kernel so
/// predictions match deterministic measurements by construction.
pub fn msg_us(fab: &Fabric, rail: usize, bytes: f64) -> f64 {
    fab.transfer_det_us(rail, bytes)
}

/// Single-level flat ring: `2(N-1)` rounds of `S/N`-byte messages.
pub fn flat_ring_us(fab: &Fabric, rail: usize, bytes: f64, n: usize) -> f64 {
    let steps = 2 * (n - 1);
    steps as f64 * msg_us(fab, rail, bytes / n as f64)
}

/// Chunk-pipelined ring: `2(N-1) + chunks - 1` rounds. Pipelining hides
/// latency, never volume — the per-node wire volume stays the ring's
/// `2(N-1)·S/N` and is spread evenly over the pipeline rounds, so deeper
/// pipelines pay more setups but move smaller messages that ride the
/// pre-decline part of the bandwidth curve (and stay below NIC-crashing
/// sizes, the paper's >1 GB segfault).
pub fn ring_chunked_us(fab: &Fabric, rail: usize, bytes: f64, n: usize, chunks: usize) -> f64 {
    let chunks = chunks.max(1);
    if chunks == 1 {
        // exact flat-ring degenerate (avoids (k*x)/k float round-trip)
        return flat_ring_us(fab, rail, bytes, n);
    }
    let rounds = 2 * (n - 1) + chunks - 1;
    let volume = 2.0 * (n - 1) as f64 * (bytes / n as f64);
    rounds as f64 * msg_us(fab, rail, volume / rounds as f64)
}

/// Recursive halving/doubling: `log2(N)` reduce-scatter rounds of
/// `S/2, S/4, …, S/N` bytes plus the mirrored allgather — same `2S(N-1)/N`
/// volume as the ring in `2*log2(N)` rounds. Caller guarantees `N` is a
/// power of two ≥ 2.
pub fn halving_doubling_us(fab: &Fabric, rail: usize, bytes: f64, n: usize) -> f64 {
    debug_assert!(n.is_power_of_two() && n >= 2);
    let mut total = 0.0;
    let mut divisor = 2.0;
    for _ in 0..n.trailing_zeros() {
        total += 2.0 * msg_us(fab, rail, bytes / divisor);
        divisor *= 2.0;
    }
    total
}

/// One intra-group phase (reduce-scatter or allgather): a `(g-1)`-step
/// ring over `S/g`-byte segments on the local fabric. Zero when grouping
/// is degenerate — the two-level cost then collapses to the flat/chunked
/// ring exactly.
pub fn intra_phase_us(intra: &IntraLink, bytes: f64) -> f64 {
    if intra.group_size <= 1 {
        return 0.0;
    }
    let g = intra.group_size as f64;
    (g - 1.0) * (intra.setup_us + (bytes / g) / intra.bw_mbps)
}

/// Hierarchical two-level schedule on one rail:
/// intra-group reduce-scatter + `2(N/g - 1) + chunks - 1` chunk-pipelined
/// inter-group rounds + intra-group allgather.
///
/// The win: `2S(g-1)/g` of the volume moves on the intra-group fabric and
/// the rail only carries `~2S/g`, in `g×` fewer rounds than the flat ring.
/// With `group_size == 1` this is bit-for-bit the (chunked) flat ring.
pub fn two_level_us(
    fab: &Fabric,
    rail: usize,
    bytes: f64,
    n: usize,
    intra: &IntraLink,
    chunks: usize,
) -> f64 {
    let g = intra.group_size.max(1);
    if g == 1 {
        return ring_chunked_us(fab, rail, bytes, n, chunks);
    }
    debug_assert!(n % g == 0 && n / g >= 2, "caller must validate grouping");
    let groups = n / g;
    let chunks = chunks.max(1);
    let rounds = 2 * (groups - 1) + chunks - 1;
    // per-node inter-group wire volume: 2(G-1)/G of the S/g slice
    let volume = 2.0 * (groups - 1) as f64 * (bytes / n as f64);
    let inter = rounds as f64 * msg_us(fab, rail, volume / rounds as f64);
    2.0 * intra_phase_us(intra, bytes) + inter
}

/// In-network tree aggregation (SHARP): the fabric's analytic estimate.
pub fn tree_us(fab: &Fabric, rail: usize, bytes: f64) -> f64 {
    fab.estimate_allreduce_us(rail, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::{ProtoKind, MB};
    use crate::net::topology::ClusterSpec;

    fn fab(kinds: &[ProtoKind], nodes: usize) -> Fabric {
        let rails = ClusterSpec::local().build_rails(kinds).unwrap();
        Fabric::new(nodes, rails, CpuPool::default(), 3).deterministic()
    }

    #[test]
    fn flat_ring_matches_fabric_estimate() {
        let f = fab(&[ProtoKind::Tcp], 4);
        let est = f.estimate_allreduce_us(0, 8.0 * MB);
        let got = flat_ring_us(&f, 0, 8.0 * MB, 4);
        assert!((got - est).abs() / est < 0.01, "got {got} est {est}");
    }

    #[test]
    fn chunked_with_one_chunk_is_flat() {
        let f = fab(&[ProtoKind::Tcp], 8);
        let s = 16.0 * MB;
        assert_eq!(ring_chunked_us(&f, 0, s, 8, 1), flat_ring_us(&f, 0, s, 8));
    }

    #[test]
    fn halving_doubling_beats_flat_on_latency_bound_payloads() {
        let f = fab(&[ProtoKind::Tcp], 8);
        let s = 256.0 * 1024.0;
        assert!(halving_doubling_us(&f, 0, s, 8) < flat_ring_us(&f, 0, s, 8));
    }

    #[test]
    fn two_level_degenerates_to_flat_ring_exactly() {
        let f = fab(&[ProtoKind::Tcp], 8);
        let link = IntraLink { group_size: 1, bw_mbps: 5000.0, setup_us: 15.0 };
        for s in [64.0 * 1024.0, 8.0 * MB] {
            assert_eq!(two_level_us(&f, 0, s, 8, &link, 1), flat_ring_us(&f, 0, s, 8));
            assert_eq!(intra_phase_us(&link, s), 0.0);
        }
    }

    #[test]
    fn two_level_beats_flat_on_grouped_16_nodes() {
        let f = fab(&[ProtoKind::Tcp], 16);
        let link = IntraLink { group_size: 4, bw_mbps: 5000.0, setup_us: 15.0 };
        let s = 16.0 * MB;
        let flat = flat_ring_us(&f, 0, s, 16);
        let two = two_level_us(&f, 0, s, 16, &link, 1);
        assert!(two < 0.6 * flat, "two-level {two} vs flat {flat}");
    }

    #[test]
    fn tree_cost_is_fabric_estimate() {
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Sharp], 4);
        assert_eq!(tree_us(&f, 1, MB), f.estimate_allreduce_us(1, MB));
    }
}
