//! Executable hierarchical / logarithmic schedules.
//!
//! Timing runs through the fabric (so jitter, contention and the fault
//! schedule apply round by round, and a mid-operation rail death aborts
//! BEFORE numerics — the §4.4 atomicity rule the seed collectives follow);
//! payload numerics always run the seed's `ring_numerics` over the whole
//! rail window, so results are bit-identical to the seed reducer for every
//! schedule family.

use crate::coordinator::buffer::{NodeWindows, UnboundBuffer, Window};
use crate::coordinator::collective::integrity;
use crate::coordinator::collective::reducer::Reducer;
use crate::coordinator::collective::ring::ring_numerics_segs;
use crate::coordinator::collective::{OpOutcome, OpScratch};
use crate::coordinator::planner::{cost, pipeline};
use crate::net::simnet::{Fabric, RailDown, RailTimer};
use crate::net::topology::{IntraLink, TopologyTree};

/// Recursive halving/doubling allreduce: `log2(N)` reduce-scatter rounds
/// with geometrically shrinking exchanges plus the mirrored allgather.
/// Caller guarantees `fab.nodes` is a power of two ≥ 2.
pub fn halving_doubling_allreduce(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    halving_doubling_allreduce_with(fab, rail, buf, w, red, elem_bytes, &mut scratch)
}

/// Scratch-reuse form of [`halving_doubling_allreduce`].
#[allow(clippy::too_many_arguments)]
pub fn halving_doubling_allreduce_with(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    halving_doubling_allreduce_on(&mut fab.rail_ctx(rail), buf, w, red, elem_bytes, scratch)
}

/// The generic core of recursive halving/doubling (timing through any
/// [`RailTimer`], numerics over any [`NodeWindows`] buffer).
pub fn halving_doubling_allreduce_on<T: RailTimer, V: NodeWindows + ?Sized>(
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    let n = t.nodes();
    debug_assert!(n.is_power_of_two() && n >= 2);
    if w.is_empty() {
        return Ok(OpOutcome::default());
    }
    let bytes = w.len as f64 * elem_bytes;
    let sent = t.integrity_on().then(|| integrity::window_checksum(buf, w));
    let mut total = 0.0;
    let mut moved = 0.0;
    let mut steps = 0;
    let mut divisor = 2.0;
    // time first: reduce-scatter halving, then allgather doubling (same
    // per-round byte ladder, mirrored)
    for _ in 0..n.trailing_zeros() {
        let b = bytes / divisor;
        total += t.ring_step(b)?;
        total += t.ring_step(b)?;
        moved += 2.0 * b;
        steps += 2;
        divisor *= 2.0;
    }
    integrity::apply_pending_poison(t, buf, w);
    if let Some(sum) = sent {
        integrity::verify_window(buf, w, sum);
    }
    w.split_uniform_into(n, &mut scratch.segs);
    ring_numerics_segs(buf, &scratch.segs, red);
    Ok(OpOutcome { time_us: total, bytes_moved: moved as u64, steps })
}

/// Hierarchical two-level allreduce: intra-group reduce-scatter on the
/// local fabric, chunk-pipelined inter-group ring over the rail (every
/// node leads the ring for its own `1/g` slice, so all nodes stay active
/// each round), intra-group allgather.
pub fn two_level_allreduce(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    intra: &IntraLink,
    chunks: usize,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    two_level_allreduce_with(fab, rail, buf, w, red, elem_bytes, intra, chunks, &mut scratch)
}

/// Scratch-reuse form of [`two_level_allreduce`].
#[allow(clippy::too_many_arguments)]
pub fn two_level_allreduce_with(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    intra: &IntraLink,
    chunks: usize,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    two_level_allreduce_on(&mut fab.rail_ctx(rail), buf, w, red, elem_bytes, intra, chunks, scratch)
}

/// The generic core of the two-level schedule (timing through any
/// [`RailTimer`], numerics over any [`NodeWindows`] buffer).
#[allow(clippy::too_many_arguments)]
pub fn two_level_allreduce_on<T: RailTimer, V: NodeWindows + ?Sized>(
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    intra: &IntraLink,
    chunks: usize,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    let n = t.nodes();
    let g = intra.group_size.max(1);
    debug_assert!(g > 1 && n % g == 0 && n / g >= 2, "caller must validate grouping");
    if w.is_empty() {
        return Ok(OpOutcome::default());
    }
    let groups = n / g;
    let chunks = chunks.max(1);
    let bytes = w.len as f64 * elem_bytes;
    let sent = t.integrity_on().then(|| integrity::window_checksum(buf, w));

    // intra-group phases are local-fabric only: deterministic, cannot fail
    let mut total = 2.0 * cost::intra_phase_us(intra, bytes);

    // inter-group rounds on the rail — fallible, timed before numerics.
    // Chunk pipelining spreads the phase's full wire volume over the
    // extended round count (latency hiding, volume preserved).
    let rounds = 2 * (groups - 1) + (chunks - 1);
    let volume = 2.0 * (groups - 1) as f64 * (bytes / n as f64);
    let msg = volume / rounds as f64;
    for _ in 0..rounds {
        total += t.ring_step(msg)?;
    }
    integrity::apply_pending_poison(t, buf, w);
    if let Some(sum) = sent {
        integrity::verify_window(buf, w, sum);
    }
    w.split_uniform_into(n, &mut scratch.segs);
    ring_numerics_segs(buf, &scratch.segs, red);
    Ok(OpOutcome {
        time_us: total,
        bytes_moved: (msg * rounds as f64) as u64,
        steps: rounds + 2 * (g - 1),
    })
}

/// N-level hierarchical allreduce over a validated topology tree cut at
/// its innermost `depth` levels: per-level reduce-scatter phases ride the
/// local fabrics (deterministic, cannot fail), the inter-group ring rides
/// the rail (fallible, chunk-pipelined, timed before numerics — §4.4
/// atomicity), then the mirrored allgather phases. Degenerates bit-exactly
/// to [`two_level_allreduce`] at depth 1 on a uniform level.
#[allow(clippy::too_many_arguments)]
pub fn multi_level_allreduce(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    tree: &TopologyTree,
    depth: usize,
    chunks: usize,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    multi_level_allreduce_with(fab, rail, buf, w, red, elem_bytes, tree, depth, chunks, &mut scratch)
}

/// Scratch-reuse form of [`multi_level_allreduce`].
#[allow(clippy::too_many_arguments)]
pub fn multi_level_allreduce_with(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    tree: &TopologyTree,
    depth: usize,
    chunks: usize,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    multi_level_allreduce_on(
        &mut fab.rail_ctx(rail),
        buf,
        w,
        red,
        elem_bytes,
        tree,
        depth,
        chunks,
        scratch,
    )
}

/// The generic core of the N-level schedule (timing through any
/// [`RailTimer`], numerics over any [`NodeWindows`] buffer). Numerics run
/// the seed's `ring_numerics` over the whole rail window, as every other
/// schedule family does, so results stay bit-identical across plan types.
#[allow(clippy::too_many_arguments)]
pub fn multi_level_allreduce_on<T: RailTimer, V: NodeWindows + ?Sized>(
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    tree: &TopologyTree,
    depth: usize,
    chunks: usize,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    let n = t.nodes();
    if w.is_empty() {
        return Ok(OpOutcome::default());
    }
    // mirror `cost::multi_level_us`: a zero-depth cut or a flat tree is
    // the (chunked) ring, never a panic
    if depth == 0 || tree.is_flat() {
        return pipeline::pipelined_ring_allreduce_on(t, buf, w, red, elem_bytes, chunks, scratch);
    }
    debug_assert!(tree.valid_cut_depth(depth, n), "caller must validate the cut");
    let depth = depth.min(tree.depth());
    let bytes = w.len as f64 * elem_bytes;
    let sent = t.integrity_on().then(|| integrity::window_checksum(buf, w));
    // per-level phases ride the local fabrics: deterministic, cannot fail
    let mut total = 0.0;
    let mut steps = 0usize;
    for lv in 0..depth {
        total += 2.0 * cost::tree_phase_us(tree, lv, n, bytes);
        steps += 2 * tree.max_subgroups(lv, n).saturating_sub(1);
    }
    // inter-group rounds on the rail — fallible, timed before numerics,
    // same volume-preserving chunk pipelining as the two-level schedule
    let groups = tree.group_count(depth - 1, n);
    let mut moved = 0.0f64;
    if groups >= 2 {
        let chunks = chunks.max(1);
        let rounds = 2 * (groups - 1) + (chunks - 1);
        let volume = 2.0 * (groups - 1) as f64 * (bytes / n as f64);
        let msg = volume / rounds as f64;
        for _ in 0..rounds {
            total += t.ring_step(msg)?;
        }
        moved = msg * rounds as f64;
        steps += rounds;
    }
    integrity::apply_pending_poison(t, buf, w);
    if let Some(sum) = sent {
        integrity::verify_window(buf, w, sum);
    }
    w.split_uniform_into(n, &mut scratch.segs);
    ring_numerics_segs(buf, &scratch.segs, red);
    Ok(OpOutcome { time_us: total, bytes_moved: moved as u64, steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collective::ring::ring_allreduce;
    use crate::coordinator::collective::testutil::{assert_reduced, fabric, make_buf};
    use crate::coordinator::collective::RustReducer;
    use crate::net::fault::FaultSchedule;
    use crate::net::protocol::{ProtoKind, MB};

    fn link(g: usize) -> IntraLink {
        IntraLink { group_size: g, bw_mbps: 5000.0, setup_us: 15.0 }
    }

    #[test]
    fn halving_doubling_numerics_correct() {
        for nodes in [2usize, 4, 8, 16] {
            let mut fab = fabric(nodes, &[ProtoKind::Tcp]);
            let (mut buf, expect) = make_buf(nodes, 257);
            let w = buf.full_window();
            let out =
                halving_doubling_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, 4.0)
                    .unwrap();
            assert_eq!(out.steps, 2 * nodes.trailing_zeros() as usize);
            assert_reduced(&buf, w, &expect);
        }
    }

    #[test]
    fn two_level_numerics_correct_and_faster_than_flat_at_16() {
        let scale = 16.0 * MB / 1024.0;
        let t_two = {
            let mut fab = fabric(16, &[ProtoKind::Tcp]);
            let (mut buf, expect) = make_buf(16, 1024);
            let w = buf.full_window();
            let out = two_level_allreduce(
                &mut fab,
                0,
                &mut buf,
                w,
                &mut RustReducer,
                scale,
                &link(4),
                1,
            )
            .unwrap();
            assert_reduced(&buf, w, &expect);
            out.time_us
        };
        let t_flat = {
            let mut fab = fabric(16, &[ProtoKind::Tcp]);
            let (mut buf, _) = make_buf(16, 1024);
            let w = buf.full_window();
            ring_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, scale)
                .unwrap()
                .time_us
        };
        assert!(t_two < 0.6 * t_flat, "two-level {t_two} vs flat {t_flat}");
    }

    #[test]
    fn two_level_matches_numerics_of_flat_bitwise() {
        // same window, same reducer, same data → identical f32 results
        let mut fab_a = fabric(8, &[ProtoKind::Tcp]);
        let mut fab_b = fabric(8, &[ProtoKind::Tcp]);
        let (mut a, _) = make_buf(8, 333);
        let (mut b, _) = make_buf(8, 333);
        let w = a.full_window();
        two_level_allreduce(&mut fab_a, 0, &mut a, w, &mut RustReducer, 4.0, &link(2), 4)
            .unwrap();
        ring_allreduce(&mut fab_b, 0, &mut b, w, &mut RustReducer, 4.0).unwrap();
        for n in 0..8 {
            assert_eq!(a.node(n), b.node(n), "node {n} diverged");
        }
    }

    #[test]
    fn multi_level_depth1_bitwise_matches_two_level() {
        use crate::net::topology::ClusterSpec;
        let tree = ClusterSpec::pods(4).topo.clone();
        let l = tree.level_link(0).unwrap();
        for chunks in [1usize, 4] {
            let mut fab_a = fabric(16, &[ProtoKind::Tcp]);
            let mut fab_b = fabric(16, &[ProtoKind::Tcp]);
            let (mut a, expect) = make_buf(16, 513);
            let (mut b, _) = make_buf(16, 513);
            let w = a.full_window();
            let scale = 8.0 * MB / 513.0;
            let oa =
                multi_level_allreduce(&mut fab_a, 0, &mut a, w, &mut RustReducer, scale, &tree, 1, chunks)
                    .unwrap();
            let ob =
                two_level_allreduce(&mut fab_b, 0, &mut b, w, &mut RustReducer, scale, &l, chunks)
                    .unwrap();
            assert_eq!(oa.time_us, ob.time_us, "chunks {chunks}: modeled time diverged");
            assert_eq!(oa.bytes_moved, ob.bytes_moved, "chunks {chunks}");
            assert_eq!(oa.steps, ob.steps, "chunks {chunks}");
            for n in 0..16 {
                assert_eq!(a.node(n), b.node(n), "chunks {chunks}: node {n} diverged");
            }
            assert_reduced(&a, w, &expect);
        }
    }

    #[test]
    fn multi_level_numerics_correct_and_beats_shallower_cuts_at_32() {
        use crate::net::topology::ClusterSpec;
        let tree = ClusterSpec::racked_pods(4, 16).topo.clone();
        let scale = 64.0 * MB / 1024.0;
        let run = |depth: usize| {
            let mut fab = fabric(32, &[ProtoKind::Tcp]);
            let (mut buf, expect) = make_buf(32, 1024);
            let w = buf.full_window();
            let out = multi_level_allreduce(
                &mut fab,
                0,
                &mut buf,
                w,
                &mut RustReducer,
                scale,
                &tree,
                depth,
                1,
            )
            .unwrap();
            assert_reduced(&buf, w, &expect);
            out.time_us
        };
        let t1 = run(1);
        let t2 = run(2);
        let t_flat = {
            let mut fab = fabric(32, &[ProtoKind::Tcp]);
            let (mut buf, _) = make_buf(32, 1024);
            let w = buf.full_window();
            ring_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, scale)
                .unwrap()
                .time_us
        };
        assert!(t1 < t_flat, "rack cut {t1} vs flat {t_flat}");
        assert!(t2 < t1, "pod cut {t2} vs rack cut {t1}");
    }

    #[test]
    fn multi_level_fault_aborts_before_numerics() {
        use crate::net::topology::ClusterSpec;
        let tree = ClusterSpec::racked_pods(4, 16).topo.clone();
        let mut fab = fabric(32, &[ProtoKind::Tcp])
            .with_faults(FaultSchedule::none().with(0, 0.0, 1e9));
        let (mut buf, _) = make_buf(32, 64);
        let w = buf.full_window();
        let orig = buf.node(0).to_vec();
        assert!(multi_level_allreduce(
            &mut fab,
            0,
            &mut buf,
            w,
            &mut RustReducer,
            4.0,
            &tree,
            2,
            2
        )
        .is_err());
        assert_eq!(buf.node(0), &orig[..], "payload mutated despite abort");
    }

    #[test]
    fn fault_aborts_before_numerics() {
        let mut fab = fabric(16, &[ProtoKind::Tcp])
            .with_faults(FaultSchedule::none().with(0, 0.0, 1e9));
        let (mut buf, _) = make_buf(16, 64);
        let w = buf.full_window();
        let orig = buf.node(0).to_vec();
        assert!(two_level_allreduce(
            &mut fab,
            0,
            &mut buf,
            w,
            &mut RustReducer,
            4.0,
            &link(4),
            2
        )
        .is_err());
        assert_eq!(buf.node(0), &orig[..], "payload mutated despite abort");
        let (mut buf2, _) = make_buf(16, 64);
        let orig2 = buf2.node(0).to_vec();
        assert!(
            halving_doubling_allreduce(&mut fab, 0, &mut buf2, w, &mut RustReducer, 4.0)
                .is_err()
        );
        assert_eq!(buf2.node(0), &orig2[..]);
    }
}
