//! Topology-aware collective planner.
//!
//! The Load Balancer (paper §4.3) decides *how much* of each allreduce
//! rides each rail; this subsystem decides *how* each rail should move its
//! slice. Given the fabric state, the cluster's (optional) intra-group
//! interconnect and the balancer's shares, [`Planner::plan`] emits an
//! executable [`CollectivePlan`] choosing per rail among:
//!
//! * flat ring (the seed's fixed dispatch),
//! * chunk-pipelined ring ([`pipeline`]),
//! * recursive halving/doubling ([`hierarchical`]),
//! * hierarchical two-level intra/inter-group schedule ([`hierarchical`]),
//! * in-network tree (SHARP rails).
//!
//! Selection is by the deterministic α-β cost model ([`cost`]), calibrated
//! from the same protocol tables as the fabric. Numerics are schedule
//! independent: every ring-rail schedule executes the seed's
//! `ring_numerics` over the same windows, so results stay bit-identical to
//! the seed reducer across all plan types.

pub mod cost;
pub mod hierarchical;
pub mod pipeline;
pub mod plan;

pub use plan::{CollectivePlan, RailPlan, Schedule};

use crate::coordinator::buffer::{UnboundBuffer, Window};
use crate::coordinator::collective::reducer::Reducer;
use crate::coordinator::collective::ring::ring_allreduce;
use crate::coordinator::collective::tree::tree_allreduce;
use crate::coordinator::collective::OpOutcome;
use crate::coordinator::control::load_balancer::sync_overhead_us;
use crate::net::protocol::CollectiveKind;
use crate::net::simnet::{Fabric, RailDown};
use crate::net::topology::{ClusterSpec, IntraLink};

/// Pipeline depths the planner evaluates for chunked schedules.
pub const CHUNK_CANDIDATES: [usize; 4] = [2, 4, 8, 16];

/// The collective planner: stateless apart from the topology description.
#[derive(Debug, Clone, Default)]
pub struct Planner {
    /// Intra-group interconnect, when the cluster declares one. `None`
    /// (all the paper's flat testbeds) disables two-level candidates.
    pub intra: Option<IntraLink>,
}

impl Planner {
    pub fn new(intra: Option<IntraLink>) -> Planner {
        Planner { intra }
    }

    pub fn from_cluster(cluster: &ClusterSpec) -> Planner {
        Planner { intra: cluster.intra.clone() }
    }

    /// Valid grouping for `n` nodes, if any: >1 nodes per group and ≥2
    /// groups.
    fn grouping(&self, n: usize) -> Option<&IntraLink> {
        let link = self.intra.as_ref()?;
        let g = link.group_size;
        if g > 1 && n % g == 0 && n / g >= 2 {
            Some(link)
        } else {
            None
        }
    }

    /// Best (schedule, predicted time) for `bytes` modeled bytes on
    /// `rail`, at the fabric's current resource state.
    pub fn schedule_for(&self, fab: &Fabric, rail: usize, bytes: f64) -> (Schedule, f64) {
        if bytes <= 0.0 {
            return (Schedule::FlatRing, 0.0);
        }
        match fab.rails[rail].protocol.collective {
            CollectiveKind::Tree => (Schedule::Tree, cost::tree_us(fab, rail, bytes)),
            CollectiveKind::Ring => {
                let n = fab.nodes;
                let mut best = (Schedule::FlatRing, cost::flat_ring_us(fab, rail, bytes, n));
                for &c in &CHUNK_CANDIDATES {
                    let t = cost::ring_chunked_us(fab, rail, bytes, n, c);
                    if t < best.1 {
                        best = (Schedule::RingChunked { chunks: c }, t);
                    }
                }
                if n.is_power_of_two() && n >= 4 {
                    let t = cost::halving_doubling_us(fab, rail, bytes, n);
                    if t < best.1 {
                        best = (Schedule::HalvingDoubling, t);
                    }
                }
                if let Some(link) = self.grouping(n) {
                    for c in std::iter::once(1).chain(CHUNK_CANDIDATES) {
                        let t = cost::two_level_us(fab, rail, bytes, n, link, c);
                        if t < best.1 {
                            best = (
                                Schedule::TwoLevel { group: link.group_size, chunks: c },
                                t,
                            );
                        }
                    }
                }
                (best.0.normalized(), best.1)
            }
        }
    }

    /// Build the executable plan from the Load Balancer's `(rail, α)`
    /// shares — the balancer's split is the input; the planner picks each
    /// rail's schedule and predicts the op's completion time.
    pub fn plan(&self, fab: &Fabric, shares: &[(usize, f64)], bytes: u64) -> CollectivePlan {
        assert!(!shares.is_empty(), "planner needs at least one share");
        let mut assignments = Vec::with_capacity(shares.len());
        for &(rail, share) in shares {
            let rail_bytes = bytes as f64 * share;
            let (schedule, predicted_us) = self.schedule_for(fab, rail, rail_bytes);
            assignments.push(RailPlan {
                rail,
                share,
                bytes: rail_bytes as u64,
                schedule,
                predicted_us,
            });
        }
        let active = assignments.iter().filter(|a| a.bytes > 0).count();
        let worst = assignments.iter().fold(0.0f64, |m, a| m.max(a.predicted_us));
        CollectivePlan {
            bytes,
            assignments,
            predicted_us: worst + sync_overhead_us(active),
        }
    }
}

/// Execute one rail's schedule on `buf[w]`.
///
/// Timing follows the schedule (through the fabric, so jitter/faults
/// apply); numerics follow the seed paths (`ring_numerics` for every
/// ring-family schedule, switch aggregation for trees), keeping results
/// bit-identical to the seed reducer across plan types.
#[allow(clippy::too_many_arguments)]
pub fn run_plan(
    schedule: Schedule,
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    intra: Option<&IntraLink>,
) -> Result<OpOutcome, RailDown> {
    if w.is_empty() {
        return Ok(OpOutcome::default());
    }
    match schedule.normalized() {
        Schedule::Tree => tree_allreduce(fab, rail, buf, w, red, elem_bytes),
        Schedule::FlatRing => ring_allreduce(fab, rail, buf, w, red, elem_bytes),
        Schedule::RingChunked { chunks } => {
            pipeline::pipelined_ring_allreduce(fab, rail, buf, w, red, elem_bytes, chunks)
        }
        Schedule::HalvingDoubling => {
            if fab.nodes.is_power_of_two() {
                hierarchical::halving_doubling_allreduce(fab, rail, buf, w, red, elem_bytes)
            } else {
                ring_allreduce(fab, rail, buf, w, red, elem_bytes)
            }
        }
        Schedule::TwoLevel { group, chunks } => match intra {
            Some(link)
                if link.group_size == group
                    && group > 1
                    && fab.nodes % group == 0
                    && fab.nodes / group >= 2 =>
            {
                hierarchical::two_level_allreduce(
                    fab, rail, buf, w, red, elem_bytes, link, chunks,
                )
            }
            // defensive: an invalid grouping falls back to the seed ring
            _ => ring_allreduce(fab, rail, buf, w, red, elem_bytes),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::{ProtoKind, KB, MB};

    fn fab(kinds: &[ProtoKind], nodes: usize, cluster: &ClusterSpec) -> Fabric {
        let rails = cluster.build_rails(kinds).unwrap();
        Fabric::new(nodes, rails, CpuPool::default(), 5).deterministic()
    }

    #[test]
    fn sharp_rail_always_schedules_tree() {
        let c = ClusterSpec::local();
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Sharp], 4, &c);
        let p = Planner::from_cluster(&c);
        let (s, t) = p.schedule_for(&f, 1, 8.0 * MB);
        assert_eq!(s, Schedule::Tree);
        assert!(t > 0.0);
    }

    #[test]
    fn flat_cluster_never_schedules_two_level() {
        let c = ClusterSpec::local();
        let p = Planner::from_cluster(&c);
        assert!(p.intra.is_none());
        let f = fab(&[ProtoKind::Tcp], 16, &c);
        for kb in [4.0, 256.0, 16384.0, 262144.0] {
            let (s, _) = p.schedule_for(&f, 0, kb * KB);
            assert!(
                !matches!(s, Schedule::TwoLevel { .. }),
                "{kb}KB chose {s:?} on a flat cluster"
            );
        }
    }

    #[test]
    fn pods_cluster_schedules_two_level_for_large_payloads() {
        let c = ClusterSpec::pods(4);
        let p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp], 16, &c);
        let (s, t_two) = p.schedule_for(&f, 0, 16.0 * MB);
        assert!(matches!(s, Schedule::TwoLevel { group: 4, .. }), "{s:?}");
        let flat = cost::flat_ring_us(&f, 0, 16.0 * MB, 16);
        assert!(t_two < flat, "two-level {t_two} vs flat {flat}");
    }

    #[test]
    fn grouping_rejects_non_divisible_node_counts() {
        let c = ClusterSpec::pods(4);
        let p = Planner::from_cluster(&c);
        // 6 nodes don't divide into groups of 4 → no two-level candidates
        let f = fab(&[ProtoKind::Tcp], 6, &c);
        let (s, _) = p.schedule_for(&f, 0, 64.0 * MB);
        assert!(!matches!(s, Schedule::TwoLevel { .. }), "{s:?}");
    }

    #[test]
    fn plan_covers_shares_and_predicts_sync() {
        let c = ClusterSpec::local();
        let p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Glex], 8, &c);
        let shares = vec![(0usize, 0.4), (1usize, 0.6)];
        let plan = p.plan(&f, &shares, 16 << 20);
        assert_eq!(plan.rails(), vec![0, 1]);
        assert_eq!(plan.active_rails(), 2);
        assert!(plan.conserves(Window::new(0, 4096)));
        let worst = plan
            .assignments
            .iter()
            .fold(0.0f64, |m, a| m.max(a.predicted_us));
        assert!((plan.predicted_us - worst - sync_overhead_us(2)).abs() < 1e-9);
    }

    #[test]
    fn zero_share_assignment_is_inert() {
        let c = ClusterSpec::local();
        let p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, &c);
        let plan = p.plan(&f, &[(0, 1.0), (1, 0.0)], 1 << 20);
        assert_eq!(plan.active_rails(), 1);
        assert_eq!(plan.assignments[1].bytes, 0);
        assert_eq!(plan.assignments[1].predicted_us, 0.0);
    }

    #[test]
    fn schedule_choice_is_size_dependent_on_ring_rails() {
        // latency-bound sizes prefer fewer rounds (halving/doubling);
        // bandwidth-bound sizes prefer chunked/flat rings
        let c = ClusterSpec::local();
        let p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp], 8, &c);
        let (s_small, _) = p.schedule_for(&f, 0, 256.0 * KB);
        assert_eq!(s_small, Schedule::HalvingDoubling, "256KB");
        let (s_big, _) = p.schedule_for(&f, 0, 256.0 * MB);
        assert!(
            matches!(s_big, Schedule::RingChunked { .. } | Schedule::FlatRing),
            "256MB chose {s_big:?}"
        );
    }
}
