//! Topology-aware collective planner.
//!
//! The Load Balancer (paper §4.3) decides *how much* of each allreduce
//! rides each rail; this subsystem decides *how* each rail should move its
//! slice. Given the fabric state, the cluster's hierarchical
//! [`TopologyTree`] (node < rack < pod levels, possibly non-uniform,
//! possibly rail-affine) and the balancer's shares, [`Planner::plan`]
//! emits an executable [`CollectivePlan`] choosing per rail among:
//!
//! * flat ring (the seed's fixed dispatch),
//! * chunk-pipelined ring ([`pipeline`]),
//! * recursive halving/doubling ([`hierarchical`]),
//! * hierarchical two-level intra/inter-group schedule ([`hierarchical`]),
//! * N-level multi-level schedule — one reduce-scatter/allgather phase
//!   pair per engaged topology level around the inter-group rail ring,
//!   with the cut depth selected per payload size class ([`hierarchical`]),
//! * in-network tree (SHARP rails).
//!
//! Selection is by the deterministic α-β cost model ([`cost`]), calibrated
//! from the same protocol tables as the fabric — *corrected* by the
//! Timer's live measurements through [`cost::CorrectedCost`] once a
//! (rail, size-class) has warmed up, so a persistently slow rail changes
//! not just its share but its schedule (ROADMAP: straggler-aware
//! replanning). Numerics are schedule independent: every ring-rail
//! schedule executes the seed's `ring_numerics` over the same windows, so
//! results stay bit-identical to the seed reducer across all plan types.

pub mod cost;
pub mod hierarchical;
pub mod pipeline;
pub mod plan;
pub mod quality;

pub use cost::CorrectedCost;
pub use plan::{CollectivePlan, RailPlan, Schedule};
pub use quality::PlanQualityReport;

use crate::coordinator::buffer::{NodeWindows, UnboundBuffer, Window};
use crate::coordinator::collective::reducer::Reducer;
use crate::coordinator::collective::ring::ring_allreduce_on;
use crate::coordinator::collective::tree::tree_allreduce_on;
use crate::coordinator::collective::{OpOutcome, OpScratch};
use crate::coordinator::control::load_balancer::sync_overhead_us;
use crate::coordinator::control::Timer;
use crate::net::protocol::CollectiveKind;
use crate::net::simnet::{Fabric, RailDown, RailTimer, MIN_RAIL_SHARE};
use crate::net::topology::{ClusterSpec, IntraLink, TopologyTree};

use std::collections::HashMap;

/// Pipeline depths the planner evaluates for chunked schedules.
pub const CHUNK_CANDIDATES: [usize; 4] = [2, 4, 8, 16];

/// The collective planner: topology description + the measurement-
/// corrected cost state fed back from completed ops.
#[derive(Debug, Clone)]
pub struct Planner {
    /// The cluster's hierarchical topology. No levels (all the paper's
    /// flat testbeds) disables hierarchical candidates entirely; a single
    /// uniform level reproduces the legacy two-level candidate set
    /// bit-for-bit; deeper or non-uniform trees add multi-level
    /// candidates, one family per valid cut depth.
    pub topo: TopologyTree,
    /// Timer-fed measurement corrections over the α-β model.
    pub corrections: CorrectedCost,
    /// `false` under `planner = static-cost`: schedules stick to the
    /// a-priori model (the corrections ablation baseline).
    pub use_corrections: bool,
    /// Monotone count of schedule-selection passes (plan epochs).
    epoch: u64,
    /// Arbiter-granted bandwidth share per rail (absent = whole rail).
    /// Candidate pricing composes these through [`cost::contended_us`], so
    /// schedule selection shifts under contention; a planner that is never
    /// told its grants prices contention-blind.
    grants: HashMap<usize, f64>,
    /// Bumped whenever a grant materially changes — the coordinator's
    /// plan-cache invalidation coordinate (stale schedules were selected
    /// under different contention).
    share_epoch: u64,
    /// The membership epoch this planner's topology was bound under
    /// (bumped by [`Planner::rebind_membership`]; plans selected under an
    /// older epoch describe a cluster that no longer exists).
    membership_epoch: u64,
}

impl Default for Planner {
    fn default() -> Planner {
        Planner::new(None)
    }
}

impl Planner {
    /// Legacy constructor: an optional single uniform grouping level.
    pub fn new(intra: Option<IntraLink>) -> Planner {
        Planner::with_tree(TopologyTree::from_intra(intra))
    }

    /// The general constructor over a full multi-level topology tree.
    pub fn with_tree(topo: TopologyTree) -> Planner {
        Planner {
            topo,
            corrections: CorrectedCost::new(),
            use_corrections: true,
            epoch: 0,
            grants: HashMap::new(),
            share_epoch: 0,
            membership_epoch: 0,
        }
    }

    pub fn from_cluster(cluster: &ClusterSpec) -> Planner {
        Planner::with_tree(cluster.topo.clone())
    }

    /// Current schedule-selection epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Start a new selection epoch (fresh plan, or mid-op failover
    /// replan). Returns the new epoch.
    pub fn bump_epoch(&mut self) -> u64 {
        self.epoch += 1;
        self.epoch
    }

    /// The granted bandwidth share this planner prices `rail` at
    /// (1.0 = sole owner, the uncontended planner bit-exactly).
    pub fn grant(&self, rail: usize) -> f64 {
        self.grants.get(&rail).copied().unwrap_or(1.0)
    }

    /// Record an arbiter grant for `rail`. Returns true (and bumps the
    /// share epoch) when the grant materially changed — the caller's cue
    /// to flush cached schedule selections and replan.
    pub fn set_grant(&mut self, rail: usize, share: f64) -> bool {
        let share = share.clamp(MIN_RAIL_SHARE, 1.0);
        if (share - self.grant(rail)).abs() < 1e-12 {
            return false;
        }
        if share >= 1.0 {
            self.grants.remove(&rail);
        } else {
            self.grants.insert(rail, share);
        }
        self.share_epoch += 1;
        true
    }

    /// Monotone count of material grant changes (cache invalidation
    /// coordinate).
    pub fn share_epoch(&self) -> u64 {
        self.share_epoch
    }

    /// Rebind the planner onto a membership-rebound topology (Blink-style
    /// re-packing: the next selection pass re-prices every candidate
    /// family over whatever links and groups survive instead of replaying
    /// stale candidates). Bumps the selection epoch so cached schedules
    /// from the old membership never win again.
    pub fn rebind_membership(&mut self, topo: TopologyTree, epoch: u64) {
        self.topo = topo;
        self.membership_epoch = epoch;
        self.bump_epoch();
    }

    /// The membership epoch the current topology was bound under.
    pub fn membership_epoch(&self) -> u64 {
        self.membership_epoch
    }

    /// True once this (rail, size-class) applies measurement corrections:
    /// corrections enabled, the Timer's averaging window has completed
    /// (warm-up gate), and observations exist.
    pub fn corrections_active(&self, timer: &Timer, rail: usize, bytes: u64) -> bool {
        self.use_corrections
            && timer.warmed_up(rail, bytes)
            && self.corrections.observations(rail, bytes) > 0
    }

    /// Feed back one completed rail-op into the corrected-cost layer.
    pub fn observe(
        &mut self,
        rail: usize,
        bytes: u64,
        rounds: usize,
        model_us: f64,
        predicted_us: f64,
        measured_us: f64,
    ) {
        self.corrections
            .observe(rail, bytes, rounds, model_us, predicted_us, measured_us);
    }

    /// Replan trigger: the EWMA'd predicted-vs-measured error for this
    /// (rail, size-class) exceeds `threshold` (the `replan_error` config
    /// key) while corrections are active.
    pub fn needs_replan(&self, timer: &Timer, rail: usize, bytes: u64, threshold: f64) -> bool {
        if !self.corrections_active(timer, rail, bytes) {
            return false;
        }
        match self.corrections.error(rail, bytes) {
            Some(e) => e > threshold,
            None => false,
        }
    }

    /// Valid single-level grouping for `n` nodes, if any: a uniform
    /// innermost level with >1 nodes per group and ≥2 groups — the legacy
    /// two-level schedule family's domain. Non-uniform innermost levels
    /// (and deeper cuts) go through the multi-level family instead.
    fn grouping(&self, n: usize) -> Option<IntraLink> {
        let link = self.topo.level_link(0)?;
        if link.group_size > 1 && self.topo.valid_cut_depth(1, n) {
            Some(link)
        } else {
            None
        }
    }

    /// Pure α-β model cost of one *specific* schedule for `bytes` on
    /// `rail` — matching `run_plan`'s execution (incl. its defensive
    /// fallbacks), so predictions and deterministic measurements agree.
    pub fn model_us(&self, fab: &Fabric, rail: usize, bytes: f64, schedule: Schedule) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let n = fab.nodes;
        match schedule.normalized() {
            Schedule::Tree => cost::tree_us(fab, rail, bytes),
            Schedule::FlatRing => cost::flat_ring_us(fab, rail, bytes, n),
            Schedule::RingChunked { chunks } => cost::ring_chunked_us(fab, rail, bytes, n, chunks),
            Schedule::HalvingDoubling => {
                if n.is_power_of_two() {
                    cost::halving_doubling_us(fab, rail, bytes, n)
                } else {
                    cost::flat_ring_us(fab, rail, bytes, n)
                }
            }
            Schedule::TwoLevel { group, chunks } => match self.grouping(n) {
                Some(link) if link.group_size == group => {
                    cost::two_level_us(fab, rail, bytes, n, &link, chunks)
                }
                _ => cost::flat_ring_us(fab, rail, bytes, n),
            },
            Schedule::MultiLevel { depth, groups, chunks } => {
                if depth >= 1
                    && self.topo.valid_cut_depth(depth, n)
                    && self.topo.group_count(depth - 1, n) == groups
                {
                    cost::multi_level_us(fab, rail, bytes, n, &self.topo, depth, chunks)
                } else {
                    cost::flat_ring_us(fab, rail, bytes, n)
                }
            }
        }
    }

    /// The share-insensitive component of `schedule`'s model cost: the
    /// rail rounds' fixed per-message setup plus any intra-group phases
    /// (which ride local fabrics, not the contended rail). Mirrors the
    /// fabric's execution exactly — every `ring_step`/`tree_round` pays
    /// its setup undiluted regardless of the granted share — so contended
    /// predictions match deterministic contended measurements.
    fn fixed_us(&self, fab: &Fabric, rail: usize, bytes: f64, schedule: Schedule) -> f64 {
        let n = fab.nodes;
        let s = schedule.normalized();
        if let Schedule::Tree = s {
            return fab.estimate_allreduce_us(rail, 0.0);
        }
        let rail_setup =
            cost::schedule_rounds(s, n) as f64 * fab.rails[rail].protocol.setup_us;
        let local = match s {
            Schedule::TwoLevel { group, .. } => match self.grouping(n) {
                Some(link) if link.group_size == group => 2.0 * cost::intra_phase_us(&link, bytes),
                _ => 0.0,
            },
            Schedule::MultiLevel { depth, groups, .. } => {
                if depth >= 1
                    && self.topo.valid_cut_depth(depth, n)
                    && self.topo.group_count(depth - 1, n) == groups
                {
                    (0..depth.min(self.topo.depth()))
                        .map(|lv| 2.0 * cost::tree_phase_us(&self.topo, lv, n, bytes))
                        .sum()
                } else {
                    0.0
                }
            }
            _ => 0.0,
        };
        rail_setup + local
    }

    /// Contention-priced model cost of `schedule`: the pure α-β model
    /// composed with the rail's arbiter grant through
    /// [`cost::contended_us`]. With a whole-rail grant this IS the pure
    /// model, bit-exactly.
    pub fn priced_model_us(
        &self,
        fab: &Fabric,
        rail: usize,
        bytes: f64,
        schedule: Schedule,
    ) -> f64 {
        let model = self.model_us(fab, rail, bytes, schedule);
        let share = self.grant(rail);
        if share >= 1.0 || bytes <= 0.0 {
            return model;
        }
        cost::contended_us(model, self.fixed_us(fab, rail, bytes, schedule), share)
    }

    /// Measurement-corrected cost of `schedule`, given its pure model cost
    /// — the pure model verbatim until the class's corrections are active.
    fn corrected_us(
        &self,
        timer: &Timer,
        fab: &Fabric,
        rail: usize,
        bytes: f64,
        schedule: Schedule,
        model_us: f64,
    ) -> f64 {
        let b = bytes as u64;
        if !self.corrections_active(timer, rail, b) {
            return model_us;
        }
        let rounds = cost::schedule_rounds(schedule, fab.nodes);
        self.corrections.corrected_us(rail, b, rounds, model_us)
    }

    /// Best (schedule, corrected predicted time) for `bytes` modeled bytes
    /// on `rail`, at the fabric's current resource state and the current
    /// measurement corrections.
    pub fn schedule_for(
        &self,
        fab: &Fabric,
        timer: &Timer,
        rail: usize,
        bytes: f64,
    ) -> (Schedule, f64) {
        if bytes <= 0.0 {
            return (Schedule::FlatRing, 0.0);
        }
        match fab.rails[rail].protocol.collective {
            CollectiveKind::Tree => {
                let m = self.priced_model_us(fab, rail, bytes, Schedule::Tree);
                let t = self.corrected_us(timer, fab, rail, bytes, Schedule::Tree, m);
                (Schedule::Tree, t)
            }
            CollectiveKind::Ring => {
                let n = fab.nodes;
                let mut candidates: Vec<Schedule> = Vec::with_capacity(10);
                candidates.push(Schedule::FlatRing);
                for &c in &CHUNK_CANDIDATES {
                    candidates.push(Schedule::RingChunked { chunks: c });
                }
                if n.is_power_of_two() && n >= 4 {
                    candidates.push(Schedule::HalvingDoubling);
                }
                let two_level = self.grouping(n);
                if let Some(link) = &two_level {
                    for c in std::iter::once(1).chain(CHUNK_CANDIDATES) {
                        candidates.push(Schedule::TwoLevel { group: link.group_size, chunks: c });
                    }
                }
                // deeper cuts (and non-uniform innermost levels, which the
                // two-level family cannot describe): one candidate family
                // per additional valid cut depth — the best cut per size
                // class falls out of ordinary α-β cost comparison
                for d in 1..=self.topo.depth() {
                    if d == 1 && two_level.is_some() {
                        continue; // covered by the two-level family above
                    }
                    if !self.topo.valid_cut_depth(d, n) {
                        continue;
                    }
                    let groups = self.topo.group_count(d - 1, n);
                    for c in std::iter::once(1).chain(CHUNK_CANDIDATES) {
                        candidates.push(Schedule::MultiLevel { depth: d, groups, chunks: c });
                    }
                }
                let mut best: Option<(Schedule, f64)> = None;
                for s in candidates {
                    let m = self.priced_model_us(fab, rail, bytes, s);
                    let t = self.corrected_us(timer, fab, rail, bytes, s, m);
                    let better = match best {
                        Some((_, bt)) => t < bt,
                        None => true,
                    };
                    if better {
                        best = Some((s, t));
                    }
                }
                let (s, t) = best.expect("ring rails always have candidates");
                (s.normalized(), t)
            }
        }
    }

    /// Full [`RailPlan`] for one rail's slice: selected schedule, corrected
    /// prediction, pure model estimate and rail round count.
    pub fn rail_plan(
        &self,
        fab: &Fabric,
        timer: &Timer,
        rail: usize,
        share: f64,
        rail_bytes: f64,
    ) -> RailPlan {
        let (schedule, predicted_us) = self.schedule_for(fab, timer, rail, rail_bytes);
        let model_us = self.priced_model_us(fab, rail, rail_bytes, schedule);
        let rounds = if rail_bytes <= 0.0 {
            0
        } else {
            cost::schedule_rounds(schedule, fab.nodes)
        };
        RailPlan {
            rail,
            share,
            bytes: rail_bytes as u64,
            schedule,
            predicted_us,
            model_us,
            rounds,
        }
    }

    fn finish(bytes: u64, assignments: Vec<RailPlan>, epoch: u64) -> CollectivePlan {
        let active = assignments.iter().filter(|a| a.bytes > 0).count();
        let worst = assignments.iter().fold(0.0f64, |m, a| m.max(a.predicted_us));
        CollectivePlan {
            bytes,
            assignments,
            predicted_us: worst + sync_overhead_us(active),
            epoch,
        }
    }

    /// What a fresh selection pass would pick right now, WITHOUT starting
    /// a new epoch — introspection/annotation (`MultiRail::plan_for`).
    pub fn preview(
        &self,
        fab: &Fabric,
        timer: &Timer,
        shares: &[(usize, f64)],
        bytes: u64,
    ) -> CollectivePlan {
        assert!(!shares.is_empty(), "planner needs at least one share");
        let assignments = shares
            .iter()
            .map(|&(rail, share)| self.rail_plan(fab, timer, rail, share, bytes as f64 * share))
            .collect();
        Self::finish(bytes, assignments, self.epoch)
    }

    /// Build the executable plan from the Load Balancer's `(rail, α)`
    /// shares — the balancer's split is the input; the planner picks each
    /// rail's schedule (under the corrected cost model) and predicts the
    /// op's completion time. Starts a new selection epoch.
    pub fn plan(
        &mut self,
        fab: &Fabric,
        timer: &Timer,
        shares: &[(usize, f64)],
        bytes: u64,
    ) -> CollectivePlan {
        self.bump_epoch();
        self.preview(fab, timer, shares, bytes)
    }

    /// Re-price a previously selected schedule set against fresh shares
    /// and the current corrections, without re-running selection (the
    /// coordinator's plan-cache fast path). Rails missing from `cached`
    /// fall back to fresh selection.
    pub fn plan_cached(
        &self,
        fab: &Fabric,
        timer: &Timer,
        shares: &[(usize, f64)],
        bytes: u64,
        cached: &[(usize, Schedule)],
    ) -> CollectivePlan {
        assert!(!shares.is_empty(), "planner needs at least one share");
        let assignments = shares
            .iter()
            .map(|&(rail, share)| {
                let rail_bytes = bytes as f64 * share;
                match cached.iter().find(|&&(r, _)| r == rail) {
                    Some(&(_, schedule)) if rail_bytes > 0.0 => {
                        let model_us = self.priced_model_us(fab, rail, rail_bytes, schedule);
                        let predicted_us =
                            self.corrected_us(timer, fab, rail, rail_bytes, schedule, model_us);
                        RailPlan {
                            rail,
                            share,
                            bytes: rail_bytes as u64,
                            schedule,
                            predicted_us,
                            model_us,
                            rounds: cost::schedule_rounds(schedule, fab.nodes),
                        }
                    }
                    _ => self.rail_plan(fab, timer, rail, share, rail_bytes),
                }
            })
            .collect();
        Self::finish(bytes, assignments, self.epoch)
    }
}

/// Execute one rail's schedule on `buf[w]`.
///
/// Timing follows the schedule (through the fabric, so jitter/faults
/// apply); numerics follow the seed paths (`ring_numerics` for every
/// ring-family schedule, switch aggregation for trees), keeping results
/// bit-identical to the seed reducer across plan types.
#[allow(clippy::too_many_arguments)]
pub fn run_plan(
    schedule: Schedule,
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    topo: &TopologyTree,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    run_plan_with(schedule, fab, rail, buf, w, red, elem_bytes, topo, &mut scratch)
}

/// Scratch-reuse form of [`run_plan`] — the coordinator's serial per-op
/// path.
#[allow(clippy::too_many_arguments)]
pub fn run_plan_with(
    schedule: Schedule,
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    topo: &TopologyTree,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    run_plan_on(schedule, &mut fab.rail_ctx(rail), buf, w, red, elem_bytes, topo, scratch)
}

/// The generic core of schedule execution: timing through any
/// [`RailTimer`], numerics over any [`NodeWindows`] buffer — what the
/// parallel executor's worker threads run against their borrow-split
/// `RailCtx` + `RailView` pairs (and what [`run_plan_with`] drives
/// serially through a throwaway context).
#[allow(clippy::too_many_arguments)]
pub fn run_plan_on<T: RailTimer, V: NodeWindows + ?Sized>(
    schedule: Schedule,
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    topo: &TopologyTree,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    if w.is_empty() {
        return Ok(OpOutcome::default());
    }
    let nodes = t.nodes();
    match schedule.normalized() {
        Schedule::Tree => tree_allreduce_on(t, buf, w, red, elem_bytes, scratch),
        Schedule::FlatRing => ring_allreduce_on(t, buf, w, red, elem_bytes, scratch),
        Schedule::RingChunked { chunks } => pipeline::pipelined_ring_allreduce_on(
            t, buf, w, red, elem_bytes, chunks, scratch,
        ),
        Schedule::HalvingDoubling => {
            if nodes.is_power_of_two() {
                hierarchical::halving_doubling_allreduce_on(t, buf, w, red, elem_bytes, scratch)
            } else {
                ring_allreduce_on(t, buf, w, red, elem_bytes, scratch)
            }
        }
        Schedule::TwoLevel { group, chunks } => match topo.level_link(0) {
            Some(link)
                if link.group_size == group
                    && group > 1
                    && nodes % group == 0
                    && nodes / group >= 2 =>
            {
                hierarchical::two_level_allreduce_on(
                    t, buf, w, red, elem_bytes, &link, chunks, scratch,
                )
            }
            // defensive: an invalid grouping falls back to the seed ring
            _ => ring_allreduce_on(t, buf, w, red, elem_bytes, scratch),
        },
        Schedule::MultiLevel { depth, groups, chunks } => {
            if depth >= 1
                && topo.valid_cut_depth(depth, nodes)
                && topo.group_count(depth - 1, nodes) == groups
            {
                hierarchical::multi_level_allreduce_on(
                    t, buf, w, red, elem_bytes, topo, depth, chunks, scratch,
                )
            } else {
                // defensive: an invalid cut falls back to the seed ring
                ring_allreduce_on(t, buf, w, red, elem_bytes, scratch)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::cpu_pool::CpuPool;
    use crate::net::protocol::{ProtoKind, KB, MB};

    fn fab(kinds: &[ProtoKind], nodes: usize, cluster: &ClusterSpec) -> Fabric {
        let rails = cluster.build_rails(kinds).unwrap();
        Fabric::new(nodes, rails, CpuPool::default(), 5).deterministic()
    }

    fn cold_timer() -> Timer {
        Timer::new(100)
    }

    #[test]
    fn sharp_rail_always_schedules_tree() {
        let c = ClusterSpec::local();
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Sharp], 4, &c);
        let p = Planner::from_cluster(&c);
        let (s, t) = p.schedule_for(&f, &cold_timer(), 1, 8.0 * MB);
        assert_eq!(s, Schedule::Tree);
        assert!(t > 0.0);
    }

    #[test]
    fn flat_cluster_never_schedules_two_level() {
        let c = ClusterSpec::local();
        let p = Planner::from_cluster(&c);
        assert!(p.topo.is_flat());
        let f = fab(&[ProtoKind::Tcp], 16, &c);
        for kb in [4.0, 256.0, 16384.0, 262144.0] {
            let (s, _) = p.schedule_for(&f, &cold_timer(), 0, kb * KB);
            assert!(
                !matches!(s, Schedule::TwoLevel { .. }),
                "{kb}KB chose {s:?} on a flat cluster"
            );
        }
    }

    #[test]
    fn pods_cluster_schedules_two_level_for_large_payloads() {
        let c = ClusterSpec::pods(4);
        let p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp], 16, &c);
        let (s, t_two) = p.schedule_for(&f, &cold_timer(), 0, 16.0 * MB);
        assert!(matches!(s, Schedule::TwoLevel { group: 4, .. }), "{s:?}");
        let flat = cost::flat_ring_us(&f, 0, 16.0 * MB, 16);
        assert!(t_two < flat, "two-level {t_two} vs flat {flat}");
    }

    #[test]
    fn grouping_rejects_non_divisible_node_counts() {
        let c = ClusterSpec::pods(4);
        let p = Planner::from_cluster(&c);
        // 6 nodes don't divide into groups of 4 → no hierarchical candidates
        let f = fab(&[ProtoKind::Tcp], 6, &c);
        let (s, _) = p.schedule_for(&f, &cold_timer(), 0, 64.0 * MB);
        assert!(
            !matches!(s, Schedule::TwoLevel { .. } | Schedule::MultiLevel { .. }),
            "{s:?}"
        );
    }

    #[test]
    fn racked_pods_selects_deeper_cut_for_large_payloads() {
        let c = ClusterSpec::racked_pods(4, 16);
        let p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp], 32, &c);
        let (s, t_multi) = p.schedule_for(&f, &cold_timer(), 0, 64.0 * MB);
        assert!(
            matches!(s, Schedule::MultiLevel { depth: 2, groups: 2, .. }),
            "64MB on racked pods chose {s:?}"
        );
        // the selected cut must beat both the rack-only cut and the flat ring
        let link = c.topo.level_link(0).unwrap();
        let two = cost::two_level_us(&f, 0, 64.0 * MB, 32, &link, 1);
        let flat = cost::flat_ring_us(&f, 0, 64.0 * MB, 32);
        assert!(t_multi < two, "multi {t_multi} vs two-level {two}");
        assert!(t_multi < flat, "multi {t_multi} vs flat {flat}");
    }

    #[test]
    fn one_level_tree_selection_is_bitwise_identical_to_intralink_planner() {
        // the pre-PR planner is exactly Planner::new(Some(link)); a
        // one-level uniform tree must reproduce its plans bit-for-bit
        let c = ClusterSpec::pods(4);
        let f = fab(&[ProtoKind::Tcp], 16, &c);
        let link = IntraLink { group_size: 4, bw_mbps: 5000.0, setup_us: 15.0 };
        let legacy = Planner::new(Some(link));
        let tree = Planner::from_cluster(&c);
        let t = cold_timer();
        for kb in [4.0, 256.0, 16384.0, 262144.0] {
            let (sa, ta) = legacy.schedule_for(&f, &t, 0, kb * KB);
            let (sb, tb) = tree.schedule_for(&f, &t, 0, kb * KB);
            assert_eq!(sa, sb, "{kb}KB");
            assert_eq!(ta, tb, "{kb}KB: predicted time diverged");
        }
    }

    #[test]
    fn non_uniform_groups_use_the_multi_level_family() {
        let c = ClusterSpec::grouped(vec![2, 6, 4, 4]);
        let p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp], 16, &c);
        let (s, t) = p.schedule_for(&f, &cold_timer(), 0, 64.0 * MB);
        assert!(
            matches!(s, Schedule::MultiLevel { depth: 1, groups: 4, .. }),
            "non-uniform grouping chose {s:?}"
        );
        assert!(t < cost::flat_ring_us(&f, 0, 64.0 * MB, 16));
        // and never the two-level family, which cannot describe it
        for kb in [4.0, 256.0, 65536.0] {
            let (s, _) = p.schedule_for(&f, &cold_timer(), 0, kb * KB);
            assert!(!matches!(s, Schedule::TwoLevel { .. }), "{kb}KB chose {s:?}");
        }
    }

    #[test]
    fn plan_covers_shares_and_predicts_sync() {
        let c = ClusterSpec::local();
        let mut p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Glex], 8, &c);
        let shares = vec![(0usize, 0.4), (1usize, 0.6)];
        let plan = p.plan(&f, &cold_timer(), &shares, 16 << 20);
        assert_eq!(plan.rails(), vec![0, 1]);
        assert_eq!(plan.active_rails(), 2);
        assert!(plan.conserves(Window::new(0, 4096)));
        let worst = plan
            .assignments
            .iter()
            .fold(0.0f64, |m, a| m.max(a.predicted_us));
        assert!((plan.predicted_us - worst - sync_overhead_us(2)).abs() < 1e-9);
        // each fresh selection pass starts a new epoch
        assert_eq!(plan.epoch, 1);
        assert_eq!(p.plan(&f, &cold_timer(), &shares, 16 << 20).epoch, 2);
    }

    #[test]
    fn zero_share_assignment_is_inert() {
        let c = ClusterSpec::local();
        let mut p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 4, &c);
        let plan = p.plan(&f, &cold_timer(), &[(0, 1.0), (1, 0.0)], 1 << 20);
        assert_eq!(plan.active_rails(), 1);
        assert_eq!(plan.assignments[1].bytes, 0);
        assert_eq!(plan.assignments[1].predicted_us, 0.0);
        assert_eq!(plan.assignments[1].rounds, 0);
    }

    #[test]
    fn schedule_choice_is_size_dependent_on_ring_rails() {
        // latency-bound sizes prefer fewer rounds (halving/doubling);
        // bandwidth-bound sizes prefer chunked/flat rings
        let c = ClusterSpec::local();
        let p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp], 8, &c);
        let (s_small, _) = p.schedule_for(&f, &cold_timer(), 0, 256.0 * KB);
        assert_eq!(s_small, Schedule::HalvingDoubling, "256KB");
        let (s_big, _) = p.schedule_for(&f, &cold_timer(), 0, 256.0 * MB);
        assert!(
            matches!(s_big, Schedule::RingChunked { .. } | Schedule::FlatRing),
            "256MB chose {s_big:?}"
        );
    }

    #[test]
    fn corrections_switch_schedule_once_warmed() {
        // per-round stalls on a straggler rail must push selection toward
        // fewer-round schedules — but only after the Timer warm-up gate
        let c = ClusterSpec::local();
        let mut p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp], 4, &c);
        let mut timer = Timer::new(2);
        let bytes = 256.0 * MB;
        let (s0, t0) = p.schedule_for(&f, &timer, 0, bytes);
        let rounds0 = cost::schedule_rounds(s0, 4);
        // report huge per-round stalls for this class
        let model = p.model_us(&f, 0, bytes, s0);
        let measured = model + rounds0 as f64 * 200_000.0;
        for _ in 0..6 {
            p.observe(0, bytes as u64, rounds0, model, model, measured);
            timer.record(0, bytes as u64, measured);
        }
        assert!(p.corrections_active(&timer, 0, bytes as u64));
        let (s1, t1) = p.schedule_for(&f, &timer, 0, bytes);
        let rounds1 = cost::schedule_rounds(s1, 4);
        assert!(
            rounds1 < rounds0,
            "straggler correction should cut rounds: {s0:?}({rounds0}) -> {s1:?}({rounds1})"
        );
        assert!(t1 > t0, "corrected cost must reflect the stalls");
        // static-cost mode ignores the corrections entirely
        p.use_corrections = false;
        let (s2, t2) = p.schedule_for(&f, &timer, 0, bytes);
        assert_eq!(s2, s0);
        assert_eq!(t2, t0);
    }

    #[test]
    fn grants_price_contention_and_shift_schedules() {
        // A slow intra-group fabric gives the hierarchical candidate a
        // large share-INsensitive cost for a tiny rail volume: solo
        // pricing rejects it for the ring family, while a heavily
        // contended rail (transfer stretched by 1/share) must flock to
        // the schedule that keeps volume off the rail.
        use crate::net::topology::TopoLevel;
        let tree = TopologyTree {
            levels: vec![TopoLevel::uniform("pod", 4, 50.0, 15.0)],
        };
        let mut p = Planner::with_tree(tree);
        let c = ClusterSpec::local();
        let f = fab(&[ProtoKind::Tcp], 16, &c);
        let t = cold_timer();
        let bytes = 8.0 * MB;
        let (s0, t0) = p.schedule_for(&f, &t, 0, bytes);
        assert!(
            !matches!(s0, Schedule::TwoLevel { .. }),
            "solo pricing should stay on the ring family, got {s0:?}"
        );
        // a whole-rail grant is not a change and must not bump the epoch
        assert!(!p.set_grant(0, 1.0));
        assert_eq!(p.share_epoch(), 0);
        assert!(p.set_grant(0, 0.02));
        assert_eq!(p.share_epoch(), 1);
        assert!(!p.set_grant(0, 0.02), "unchanged grant bumped the epoch");
        let (s1, t1) = p.schedule_for(&f, &t, 0, bytes);
        assert!(t1 > t0, "contended prediction must be slower: {t0} vs {t1}");
        assert!(
            matches!(s1, Schedule::TwoLevel { .. }),
            "contention should shift {s0:?} to the hierarchical schedule, got {s1:?}"
        );
        // restoring the whole rail restores solo pricing bit-exactly
        assert!(p.set_grant(0, 1.0));
        let (s2, t2) = p.schedule_for(&f, &t, 0, bytes);
        assert_eq!(s0, s2);
        assert_eq!(t0, t2);
    }

    #[test]
    fn contended_predictions_match_contended_measurements() {
        use crate::coordinator::collective::RustReducer;
        let c = ClusterSpec::local();
        let mut p = Planner::from_cluster(&c);
        let share = 0.3;
        for schedule in [
            Schedule::FlatRing,
            Schedule::RingChunked { chunks: 8 },
            Schedule::HalvingDoubling,
        ] {
            let mut f = fab(&[ProtoKind::Tcp], 8, &c);
            f.set_rail_share(0, share);
            assert!(p.set_grant(0, share) || p.grant(0) == share);
            let elems = 1024usize;
            let elem_bytes = 8.0 * MB / elems as f64;
            let mut buf = UnboundBuffer::from_fn(8, elems, |n, i| (n + i) as f32);
            let w = buf.full_window();
            buf.register(w);
            let out = run_plan(
                schedule,
                &mut f,
                0,
                &mut buf,
                w,
                &mut RustReducer,
                elem_bytes,
                &p.topo,
            )
            .unwrap();
            buf.complete(w).unwrap();
            let predicted = p.priced_model_us(&f, 0, 8.0 * MB, schedule);
            let rel = (predicted - out.time_us).abs() / out.time_us;
            assert!(rel < 1e-9, "{schedule:?}: predicted {predicted} measured {}", out.time_us);
        }
    }

    #[test]
    fn plan_cached_repricing_keeps_schedules() {
        let c = ClusterSpec::local();
        let mut p = Planner::from_cluster(&c);
        let f = fab(&[ProtoKind::Tcp, ProtoKind::Tcp], 8, &c);
        let t = cold_timer();
        let shares = vec![(0usize, 0.5), (1usize, 0.5)];
        let plan = p.plan(&f, &t, &shares, 32 << 20);
        let cached: Vec<(usize, Schedule)> =
            plan.assignments.iter().map(|a| (a.rail, a.schedule)).collect();
        // re-price under shifted shares: schedules stay, bytes/costs move
        let shifted = vec![(0usize, 0.25), (1usize, 0.75)];
        let re = p.plan_cached(&f, &t, &shifted, 32 << 20, &cached);
        assert_eq!(re.epoch, plan.epoch, "repricing must not start an epoch");
        for (a, b) in plan.assignments.iter().zip(&re.assignments) {
            assert_eq!(a.schedule, b.schedule);
        }
        assert!(re.conserves(Window::new(0, 4096)));
        assert_eq!(re.assignments[1].bytes, 24 << 20);
    }
}
