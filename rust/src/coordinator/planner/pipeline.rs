//! Chunk pipelining: per-rail chunk streaming and cross-bucket overlap.
//!
//! Within one rail, chunk *k+1* streams while chunk *k* is still reducing:
//! a `chunks`-deep pipeline over a `base_rounds`-round collective costs
//! `base_rounds + chunks - 1` rounds of `1/chunks`-size messages instead
//! of `base_rounds` full-size ones. Across gradient-fusion buckets, the
//! same mechanism lets bucket *i+1*'s transfer phase overlap bucket *i*'s
//! tail reduce when both buckets run multi-rail chunked plans — the
//! trainer models that with a bounded overlap credit.

use crate::coordinator::buffer::{NodeWindows, UnboundBuffer, Window};
use crate::coordinator::collective::integrity;
use crate::coordinator::collective::reducer::Reducer;
use crate::coordinator::collective::ring::ring_numerics_segs;
use crate::coordinator::collective::{OpOutcome, OpScratch};
use crate::net::simnet::{Fabric, RailDown, RailTimer};

/// Rounds of a `chunks`-deep pipeline over a `base_rounds`-round schedule.
pub fn pipelined_rounds(base_rounds: usize, chunks: usize) -> usize {
    base_rounds + chunks.max(1) - 1
}

/// Fraction of the shorter neighbour op hidden by cross-bucket chunk
/// pipelining (tail reduce of bucket *i* overlaps head transfer of *i+1*).
pub const BUCKET_OVERLAP: f64 = 0.30;

/// Planner-scheduled chunk-pipelined ring allreduce on one rail.
///
/// Timing: `2(N-1) + chunks - 1` fabric rounds carrying the ring's full
/// `2(N-1)·S/N` per-node wire volume in equal slices — pipelining hides
/// latency, never volume (fallible, timed before numerics per the §4.4
/// atomicity rule). Numerics: the seed's whole-window `ring_numerics`, so
/// results are bit-identical to the flat ring for any payload.
pub fn pipelined_ring_allreduce(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    chunks: usize,
) -> Result<OpOutcome, RailDown> {
    let mut scratch = OpScratch::default();
    pipelined_ring_allreduce_with(fab, rail, buf, w, red, elem_bytes, chunks, &mut scratch)
}

/// Scratch-reuse form of [`pipelined_ring_allreduce`].
#[allow(clippy::too_many_arguments)]
pub fn pipelined_ring_allreduce_with(
    fab: &mut Fabric,
    rail: usize,
    buf: &mut UnboundBuffer,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    chunks: usize,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    pipelined_ring_allreduce_on(&mut fab.rail_ctx(rail), buf, w, red, elem_bytes, chunks, scratch)
}

/// The generic core of the chunk-pipelined ring (timing through any
/// [`RailTimer`], numerics over any [`NodeWindows`] buffer).
#[allow(clippy::too_many_arguments)]
pub fn pipelined_ring_allreduce_on<T: RailTimer, V: NodeWindows + ?Sized>(
    t: &mut T,
    buf: &mut V,
    w: Window,
    red: &mut dyn Reducer,
    elem_bytes: f64,
    chunks: usize,
    scratch: &mut OpScratch,
) -> Result<OpOutcome, RailDown> {
    if w.is_empty() {
        return Ok(OpOutcome::default());
    }
    let n = t.nodes();
    let chunks = chunks.max(1);
    let rounds = pipelined_rounds(2 * (n - 1), chunks);
    let bytes = w.len as f64 * elem_bytes;
    let volume = 2.0 * (n - 1) as f64 * (bytes / n as f64);
    let msg = volume / rounds as f64;
    let sent = t.integrity_on().then(|| integrity::window_checksum(buf, w));
    let mut total = 0.0;
    for _ in 0..rounds {
        total += t.ring_step(msg)?;
    }
    integrity::apply_pending_poison(t, buf, w);
    if let Some(sum) = sent {
        integrity::verify_window(buf, w, sum);
    }
    w.split_uniform_into(n, &mut scratch.segs);
    ring_numerics_segs(buf, &scratch.segs, red);
    Ok(OpOutcome {
        time_us: total,
        bytes_moved: (msg * rounds as f64) as u64,
        steps: rounds,
    })
}

/// Total communication time of a sequence of bucket ops under cross-bucket
/// pipelining. Each op is `(time_us, multi_rail)`; consecutive multi-rail
/// ops earn an `overlap` credit bounded by the shorter of the pair, and
/// the result can never drop below the longest single op.
pub fn pipelined_total_us(ops: &[(f64, bool)], overlap: f64) -> f64 {
    if ops.is_empty() {
        return 0.0;
    }
    let sum: f64 = ops.iter().map(|(t, _)| *t).sum();
    let mut credit = 0.0;
    for pair in ops.windows(2) {
        if pair[0].1 && pair[1].1 {
            credit += overlap * pair[0].0.min(pair[1].0);
        }
    }
    let floor = ops.iter().map(|(t, _)| *t).fold(0.0f64, f64::max);
    (sum - credit).max(floor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collective::ring::ring_allreduce;
    use crate::coordinator::collective::testutil::{assert_reduced, fabric, make_buf};
    use crate::coordinator::collective::RustReducer;
    use crate::net::protocol::{ProtoKind, MB};

    #[test]
    fn rounds_arithmetic() {
        assert_eq!(pipelined_rounds(6, 1), 6);
        assert_eq!(pipelined_rounds(6, 8), 13);
        assert_eq!(pipelined_rounds(6, 0), 6);
    }

    #[test]
    fn pipelined_ring_numerics_match_flat_bitwise() {
        let mut fa = fabric(4, &[ProtoKind::Tcp]);
        let mut fb = fabric(4, &[ProtoKind::Tcp]);
        let (mut a, expect) = make_buf(4, 1003);
        let (mut b, _) = make_buf(4, 1003);
        let w = a.full_window();
        pipelined_ring_allreduce(&mut fa, 0, &mut a, w, &mut RustReducer, 4.0, 8).unwrap();
        ring_allreduce(&mut fb, 0, &mut b, w, &mut RustReducer, 4.0).unwrap();
        assert_reduced(&a, w, &expect);
        for n in 0..4 {
            assert_eq!(a.node(n), b.node(n));
        }
    }

    #[test]
    fn pipelining_helps_huge_payloads() {
        let scale = 256.0 * MB / 1024.0;
        let t_flat = {
            let mut fab = fabric(8, &[ProtoKind::Tcp]);
            let (mut buf, _) = make_buf(8, 1024);
            let w = buf.full_window();
            ring_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, scale)
                .unwrap()
                .time_us
        };
        let t_pipe = {
            let mut fab = fabric(8, &[ProtoKind::Tcp]);
            let (mut buf, _) = make_buf(8, 1024);
            let w = buf.full_window();
            pipelined_ring_allreduce(&mut fab, 0, &mut buf, w, &mut RustReducer, scale, 16)
                .unwrap()
                .time_us
        };
        assert!(t_pipe < t_flat, "pipelined {t_pipe} vs flat {t_flat}");
    }

    #[test]
    fn bucket_pipeline_credit_bounded() {
        let ops = [(100.0, true), (50.0, true), (80.0, false), (40.0, true)];
        let t = pipelined_total_us(&ops, BUCKET_OVERLAP);
        let serial: f64 = ops.iter().map(|(t, _)| *t).sum();
        // only the first adjacent multi-rail pair earns credit
        assert!((t - (serial - 0.30 * 50.0)).abs() < 1e-9, "t={t}");
        assert!(t >= 100.0);
        assert_eq!(pipelined_total_us(&[], BUCKET_OVERLAP), 0.0);
        // single-rail sequences get no credit
        let ops1 = [(10.0, false), (20.0, false)];
        assert_eq!(pipelined_total_us(&ops1, BUCKET_OVERLAP), 30.0);
    }
}
