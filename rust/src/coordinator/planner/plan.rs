//! Executable collective plans.
//!
//! A [`CollectivePlan`] is what the planner hands the orchestrator: one
//! [`RailPlan`] per rail the Load Balancer assigned data to, each carrying
//! the schedule the member network should run for its window plus the cost
//! model's predicted completion time. Window arithmetic reuses the shared
//! buffer's `split_fractions`, so plan windows are exactly the windows the
//! seed's share execution produced — numerics stay on the same code path
//! regardless of the schedule chosen (see `planner::run_plan`).

use crate::coordinator::buffer::Window;

/// The per-rail schedule families the planner chooses among.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Single-level bandwidth-optimal ring (the seed's fixed dispatch).
    FlatRing,
    /// Ring with `chunks` pipelined chunks streaming back-to-back:
    /// `2(N-1) + chunks - 1` rounds of `S/(N*chunks)`-byte messages.
    RingChunked { chunks: usize },
    /// Recursive halving/doubling: `2*log2(N)` rounds with geometrically
    /// shrinking messages — fewer setups than the ring for latency-bound
    /// payloads (power-of-two node counts only).
    HalvingDoubling,
    /// Hierarchical two-level schedule over an intra-group interconnect:
    /// intra-group reduce-scatter → inter-group ring allreduce of the
    /// rail-partitioned slice (chunk-pipelined) → intra-group allgather.
    TwoLevel { group: usize, chunks: usize },
    /// N-level hierarchical schedule over a multi-level topology tree:
    /// one reduce-scatter phase per engaged level (innermost `depth`
    /// levels, local fabrics), a chunk-pipelined ring across the `groups`
    /// outermost engaged groups on the rail, then the mirrored allgather
    /// phases back down. `depth = 1` on a uniform level is the two-level
    /// schedule; non-uniform (explicit-size) levels are only expressible
    /// here.
    MultiLevel { depth: usize, groups: usize, chunks: usize },
    /// In-network aggregation (SHARP rails).
    Tree,
}

impl Schedule {
    pub fn label(&self) -> &'static str {
        match self {
            Schedule::FlatRing => "flat-ring",
            Schedule::RingChunked { .. } => "ring-chunked",
            Schedule::HalvingDoubling => "halving-doubling",
            Schedule::TwoLevel { .. } => "two-level",
            Schedule::MultiLevel { .. } => "multi-level",
            Schedule::Tree => "tree",
        }
    }

    /// Collapse degenerate parameterisations: a two-level schedule over
    /// single-node groups IS a (possibly chunked) flat ring, a multi-level
    /// schedule with no engaged levels or a single top group likewise, and
    /// one chunk is no pipeline at all.
    pub fn normalized(self) -> Schedule {
        match self {
            Schedule::TwoLevel { group: 0 | 1, chunks: 0 | 1 } => Schedule::FlatRing,
            Schedule::TwoLevel { group: 0 | 1, chunks } => Schedule::RingChunked { chunks },
            Schedule::TwoLevel { group, chunks: 0 } => Schedule::TwoLevel { group, chunks: 1 },
            Schedule::MultiLevel { depth: 0, groups: _, chunks }
            | Schedule::MultiLevel { depth: _, groups: 0 | 1, chunks } => {
                Schedule::RingChunked { chunks }.normalized()
            }
            Schedule::MultiLevel { depth, groups, chunks: 0 } => {
                Schedule::MultiLevel { depth, groups, chunks: 1 }
            }
            Schedule::RingChunked { chunks: 0 | 1 } => Schedule::FlatRing,
            s => s,
        }
    }
}

/// One rail's slice of the op: fraction of the window, modeled bytes, and
/// the schedule + predicted time the cost model selected. `Copy` so the
/// orchestrator's reusable assignment scratch never clones heap state.
#[derive(Debug, Clone, Copy)]
pub struct RailPlan {
    pub rail: usize,
    /// Fraction of the op window (the Load Balancer's α for this rail).
    pub share: f64,
    /// Modeled payload bytes on this rail.
    pub bytes: u64,
    pub schedule: Schedule,
    /// Measurement-corrected completion estimate for this rail alone (us)
    /// — what the plan-quality report scores against the measurement.
    pub predicted_us: f64,
    /// Pure (uncorrected) α-β model estimate for this rail (us).
    pub model_us: f64,
    /// Lockstep fabric rounds the schedule runs on the rail.
    pub rounds: usize,
}

/// The full multi-rail plan for one allreduce.
#[derive(Debug, Clone)]
pub struct CollectivePlan {
    /// Total modeled payload bytes.
    pub bytes: u64,
    pub assignments: Vec<RailPlan>,
    /// Predicted end-to-end time: slowest rail + cross-rail sync (us).
    pub predicted_us: f64,
    /// Schedule-selection epoch this plan was built at (bumps on every
    /// fresh selection pass, incl. mid-op failover replans).
    pub epoch: u64,
}

impl CollectivePlan {
    /// A window-carrier plan for forced fixed-dispatch execution: shares
    /// only, no schedule selection or cost prediction (the orchestrator
    /// ignores the schedules and runs the forced `Algo`).
    pub fn unplanned(shares: &[(usize, f64)], bytes: u64) -> CollectivePlan {
        assert!(!shares.is_empty(), "plan needs at least one share");
        let assignments = shares
            .iter()
            .map(|&(rail, share)| RailPlan {
                rail,
                share,
                bytes: (bytes as f64 * share) as u64,
                schedule: Schedule::FlatRing,
                predicted_us: 0.0,
                model_us: 0.0,
                rounds: 0,
            })
            .collect();
        CollectivePlan { bytes, assignments, predicted_us: 0.0, epoch: 0 }
    }

    /// Carve the op window into per-assignment windows — identical
    /// arithmetic to the seed's share execution (contiguous, exact cover).
    pub fn windows(&self, full: Window) -> Vec<Window> {
        let mut out = Vec::with_capacity(self.assignments.len());
        self.windows_into(full, &mut out);
        out
    }

    /// Scratch-reuse form of [`CollectivePlan::windows`]: delegates to the
    /// canonical `Window::split_shares_into` loop over the assignment
    /// shares, without building a fractions vector.
    pub fn windows_into(&self, full: Window, out: &mut Vec<Window>) {
        assert!(!self.assignments.is_empty(), "plan with no assignments");
        full.split_shares_into(self.assignments.len(), |i| self.assignments[i].share, out);
    }

    /// Rails this plan claims (in assignment order).
    pub fn rails(&self) -> Vec<usize> {
        self.assignments.iter().map(|a| a.rail).collect()
    }

    /// Rails that actually carry payload.
    pub fn active_rails(&self) -> usize {
        self.assignments.iter().filter(|a| a.bytes > 0).count()
    }

    /// Human-readable summary, e.g. `"0:two-level 1:tree"`.
    pub fn label(&self) -> String {
        self.assignments
            .iter()
            .filter(|a| a.bytes > 0)
            .map(|a| format!("{}:{}", a.rail, a.schedule.label()))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Invariant check used by the property tests: the plan's windows
    /// partition `full` exactly and its shares are a distribution.
    pub fn conserves(&self, full: Window) -> bool {
        let ws = self.windows(full);
        let mut cursor = full.offset;
        for w in &ws {
            if w.offset != cursor {
                return false;
            }
            cursor = w.end();
        }
        if cursor != full.end() {
            return false;
        }
        let sum: f64 = self.assignments.iter().map(|a| a.share).sum();
        (sum - 1.0).abs() < 1e-6 && self.assignments.iter().all(|a| a.share >= 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan2() -> CollectivePlan {
        CollectivePlan {
            bytes: 1000,
            assignments: vec![
                RailPlan {
                    rail: 0,
                    share: 0.25,
                    bytes: 250,
                    schedule: Schedule::FlatRing,
                    predicted_us: 10.0,
                    model_us: 10.0,
                    rounds: 6,
                },
                RailPlan {
                    rail: 1,
                    share: 0.75,
                    bytes: 750,
                    schedule: Schedule::TwoLevel { group: 4, chunks: 2 },
                    predicted_us: 20.0,
                    model_us: 20.0,
                    rounds: 7,
                },
            ],
            predicted_us: 20.0,
            epoch: 1,
        }
    }

    #[test]
    fn windows_partition_exactly() {
        let p = plan2();
        let full = Window::new(8, 1001);
        assert!(p.conserves(full));
        let ws = p.windows(full);
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].offset, 8);
        assert_eq!(ws[1].end(), 1009);
    }

    #[test]
    fn labels_and_counters() {
        let p = plan2();
        assert_eq!(p.rails(), vec![0, 1]);
        assert_eq!(p.active_rails(), 2);
        assert_eq!(p.label(), "0:flat-ring 1:two-level");
    }

    #[test]
    fn degenerate_schedules_normalize() {
        assert_eq!(
            Schedule::TwoLevel { group: 1, chunks: 1 }.normalized(),
            Schedule::FlatRing
        );
        assert_eq!(
            Schedule::TwoLevel { group: 1, chunks: 4 }.normalized(),
            Schedule::RingChunked { chunks: 4 }
        );
        assert_eq!(Schedule::RingChunked { chunks: 1 }.normalized(), Schedule::FlatRing);
        assert_eq!(
            Schedule::TwoLevel { group: 4, chunks: 2 }.normalized(),
            Schedule::TwoLevel { group: 4, chunks: 2 }
        );
        assert_eq!(Schedule::Tree.normalized(), Schedule::Tree);
        // multi-level degenerates like two-level
        assert_eq!(
            Schedule::MultiLevel { depth: 0, groups: 8, chunks: 1 }.normalized(),
            Schedule::FlatRing
        );
        assert_eq!(
            Schedule::MultiLevel { depth: 2, groups: 1, chunks: 4 }.normalized(),
            Schedule::RingChunked { chunks: 4 }
        );
        assert_eq!(
            Schedule::MultiLevel { depth: 2, groups: 2, chunks: 0 }.normalized(),
            Schedule::MultiLevel { depth: 2, groups: 2, chunks: 1 }
        );
        assert_eq!(
            Schedule::MultiLevel { depth: 2, groups: 2, chunks: 4 }.normalized(),
            Schedule::MultiLevel { depth: 2, groups: 2, chunks: 4 }
        );
        assert_eq!(Schedule::MultiLevel { depth: 2, groups: 2, chunks: 4 }.label(), "multi-level");
    }
}
