//! Plan-quality tracking: per-plan predicted vs measured completion time.
//!
//! Every planner-scheduled rail-op contributes one sample (the corrected
//! prediction the plan carried vs the time the fabric measured). The
//! report closes the ROADMAP's "plan quality dashboard" item: the harness
//! and `bench_allreduce` emit it in the `util::json` bench result format,
//! and CI regresses the deterministic sweep's median relative error
//! against a committed ceiling so cost-model drift fails the build.

use std::collections::BTreeMap;

use crate::coordinator::planner::plan::Schedule;
use crate::util::json::Json;
use crate::util::stats::percentile;

/// One executed rail-op's prediction vs measurement.
#[derive(Debug, Clone, Copy)]
pub struct QualitySample {
    pub rail: usize,
    /// Modeled payload bytes on the rail.
    pub bytes: u64,
    /// Label of the schedule that executed.
    pub schedule: &'static str,
    /// Corrected cost-model prediction at plan time (us).
    pub predicted_us: f64,
    /// Fabric-measured completion time (us).
    pub measured_us: f64,
    /// Schedule-selection epoch of the plan.
    pub epoch: u64,
}

impl QualitySample {
    /// Relative prediction error |predicted − measured| / measured.
    pub fn rel_error(&self) -> f64 {
        if self.measured_us <= 0.0 {
            0.0
        } else {
            (self.predicted_us - self.measured_us).abs() / self.measured_us
        }
    }
}

/// Bounded ring buffer of [`QualitySample`]s plus aggregate accessors.
#[derive(Debug, Clone)]
pub struct PlanQualityReport {
    samples: Vec<QualitySample>,
    cursor: usize,
    cap: usize,
    total: u64,
}

impl Default for PlanQualityReport {
    fn default() -> Self {
        PlanQualityReport::new(16384)
    }
}

impl PlanQualityReport {
    pub fn new(cap: usize) -> PlanQualityReport {
        PlanQualityReport { samples: Vec::new(), cursor: 0, cap: cap.max(1), total: 0 }
    }

    pub fn record(
        &mut self,
        rail: usize,
        bytes: u64,
        schedule: Schedule,
        predicted_us: f64,
        measured_us: f64,
        epoch: u64,
    ) {
        let s = QualitySample {
            rail,
            bytes,
            schedule: schedule.label(),
            predicted_us,
            measured_us,
            epoch,
        };
        if self.samples.len() < self.cap {
            self.samples.push(s);
        } else {
            self.samples[self.cursor] = s;
            self.cursor = (self.cursor + 1) % self.cap;
        }
        self.total += 1;
    }

    /// Samples currently retained (≤ cap).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Lifetime sample count (including evicted ones).
    pub fn total_recorded(&self) -> u64 {
        self.total
    }

    pub fn samples(&self) -> &[QualitySample] {
        &self.samples
    }

    pub fn clear(&mut self) {
        self.samples.clear();
        self.cursor = 0;
        self.total = 0;
    }

    fn rel_errors(&self) -> Vec<f64> {
        self.samples.iter().map(|s| s.rel_error()).collect()
    }

    /// Median |predicted − measured| / measured over retained samples —
    /// the number the CI regression guards.
    pub fn median_rel_error(&self) -> Option<f64> {
        let errs = self.rel_errors();
        if errs.is_empty() {
            None
        } else {
            Some(percentile(&errs, 50.0))
        }
    }

    pub fn p95_rel_error(&self) -> Option<f64> {
        let errs = self.rel_errors();
        if errs.is_empty() {
            None
        } else {
            Some(percentile(&errs, 95.0))
        }
    }

    /// The report document (`util::json` bench result format): overall
    /// aggregates plus a per-schedule breakdown.
    pub fn to_json(&self) -> Json {
        let mut by_schedule: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
        for s in &self.samples {
            by_schedule.entry(s.schedule).or_default().push(s.rel_error());
        }
        let schedules: Vec<Json> = by_schedule
            .iter()
            .map(|(label, errs)| {
                Json::obj(vec![
                    ("schedule", Json::Str((*label).to_string())),
                    ("n", Json::from(errs.len() as f64)),
                    ("median_rel_err", Json::from(percentile(errs, 50.0))),
                    ("p95_rel_err", Json::from(percentile(errs, 95.0))),
                ])
            })
            .collect();
        Json::obj(vec![
            ("report", Json::Str("plan_quality".to_string())),
            ("n", Json::from(self.len() as f64)),
            ("total_recorded", Json::from(self.total as f64)),
            (
                "median_rel_err",
                self.median_rel_error().map(Json::from).unwrap_or(Json::Null),
            ),
            (
                "p95_rel_err",
                self.p95_rel_error().map(Json::from).unwrap_or(Json::Null),
            ),
            ("schedules", Json::Arr(schedules)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let mut r = PlanQualityReport::new(8);
        r.record(0, 1 << 20, Schedule::FlatRing, 100.0, 100.0, 1);
        r.record(1, 1 << 20, Schedule::HalvingDoubling, 150.0, 100.0, 1);
        assert_eq!(r.len(), 2);
        let med = r.median_rel_error().unwrap();
        assert!(med <= 0.5 && med >= 0.0, "med {med}");
        let j = r.to_json();
        assert_eq!(j.get("report").and_then(|v| v.as_str()), Some("plan_quality"));
        assert_eq!(j.get("n").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("schedules").and_then(|v| v.as_arr()).unwrap().len(), 2);
    }

    #[test]
    fn ring_buffer_caps_retained_samples() {
        let mut r = PlanQualityReport::new(4);
        for i in 0..10 {
            r.record(0, 1024, Schedule::FlatRing, i as f64, 1.0, 0);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total_recorded(), 10);
    }

    #[test]
    fn empty_report_has_no_aggregates() {
        let r = PlanQualityReport::default();
        assert!(r.is_empty());
        assert!(r.median_rel_error().is_none());
        assert_eq!(r.to_json().get("median_rel_err"), Some(&Json::Null));
    }
}
