//! Transport Module (paper §3.3): rendezvous + Pair endpoints.
//!
//! The rendezvous mechanism establishes the global communication domain:
//! a full mesh of [`Pair`]s per rail. GLEX-style non-blocking operation is
//! modelled with `send_req` pending-request queues: when a buffer operation
//! cannot complete immediately, its (address, sequence, incomplete-flag)
//! triple is parked in `send_reqs` and drained by the monitoring side.

use std::collections::VecDeque;

use crate::coordinator::buffer::Window;

/// A pending non-blocking send request (paper §3.3's `send_req`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SendReq {
    /// Initiating memory window (the paper's memory address + length).
    pub window: Window,
    /// Communication sequence number.
    pub seq: u64,
    /// Uncompleted flag.
    pub done: bool,
}

/// Point-to-point endpoint between two ranks on one rail.
#[derive(Debug)]
pub struct Pair {
    pub rail: usize,
    pub local: usize,
    pub remote: usize,
    next_seq: u64,
    /// Pending request queue (`send_reqs`).
    send_reqs: VecDeque<SendReq>,
    /// Lifetime counters for metrics.
    pub msgs_sent: u64,
    pub bytes_sent: u64,
}

impl Pair {
    pub fn new(rail: usize, local: usize, remote: usize) -> Pair {
        Pair {
            rail,
            local,
            remote,
            next_seq: 0,
            send_reqs: VecDeque::new(),
            msgs_sent: 0,
            bytes_sent: 0,
        }
    }

    /// Enqueue a non-blocking send of `window`; returns its sequence no.
    pub fn post_send(&mut self, window: Window) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.send_reqs.push_back(SendReq { window, seq, done: false });
        seq
    }

    /// Mark a posted request complete (remote finished its buffer op).
    pub fn complete(&mut self, seq: u64) {
        if let Some(req) = self.send_reqs.iter_mut().find(|r| r.seq == seq) {
            req.done = true;
            self.msgs_sent += 1;
            self.bytes_sent += req.window.bytes();
        }
        // drain the head-of-line completed prefix
        while matches!(self.send_reqs.front(), Some(r) if r.done) {
            self.send_reqs.pop_front();
        }
    }

    pub fn pending(&self) -> usize {
        self.send_reqs.len()
    }

    pub fn idle(&self) -> bool {
        self.send_reqs.is_empty()
    }
}

/// Rendezvous: builds the full communication mesh for one rail across
/// `nodes` ranks. Pairs are stored per (local, remote) ordered pair.
#[derive(Debug)]
pub struct Rendezvous {
    pub rail: usize,
    pub nodes: usize,
    pairs: Vec<Pair>,
}

impl Rendezvous {
    /// Full-mesh connection establishment (each rank connects to every
    /// other rank — ring collectives use the neighbour subset).
    pub fn full_mesh(rail: usize, nodes: usize) -> Rendezvous {
        assert!(nodes >= 2);
        let mut pairs = Vec::with_capacity(nodes * (nodes - 1));
        for a in 0..nodes {
            for b in 0..nodes {
                if a != b {
                    pairs.push(Pair::new(rail, a, b));
                }
            }
        }
        Rendezvous { rail, nodes, pairs }
    }

    pub fn pair_mut(&mut self, local: usize, remote: usize) -> &mut Pair {
        assert_ne!(local, remote);
        let idx = local * (self.nodes - 1) + if remote > local { remote - 1 } else { remote };
        &mut self.pairs[idx]
    }

    pub fn pair(&self, local: usize, remote: usize) -> &Pair {
        assert_ne!(local, remote);
        let idx = local * (self.nodes - 1) + if remote > local { remote - 1 } else { remote };
        &self.pairs[idx]
    }

    pub fn n_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Total bytes sent across all pairs (metrics).
    pub fn total_bytes(&self) -> u64 {
        self.pairs.iter().map(|p| p.bytes_sent).sum()
    }

    /// All pairs idle — the domain is quiescent.
    pub fn quiescent(&self) -> bool {
        self.pairs.iter().all(|p| p.idle())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_size() {
        let r = Rendezvous::full_mesh(0, 4);
        assert_eq!(r.n_pairs(), 12);
    }

    #[test]
    fn pair_indexing_bijective() {
        let mut r = Rendezvous::full_mesh(0, 5);
        for a in 0..5 {
            for b in 0..5 {
                if a != b {
                    let p = r.pair_mut(a, b);
                    assert_eq!((p.local, p.remote), (a, b));
                }
            }
        }
    }

    #[test]
    fn send_req_lifecycle() {
        let mut p = Pair::new(0, 0, 1);
        let w = Window::new(0, 256);
        let s0 = p.post_send(w);
        let s1 = p.post_send(w);
        assert_eq!(p.pending(), 2);
        // out-of-order completion: s1 first — queue drains only after s0
        p.complete(s1);
        assert_eq!(p.pending(), 2);
        p.complete(s0);
        assert_eq!(p.pending(), 0);
        assert!(p.idle());
        assert_eq!(p.msgs_sent, 2);
        assert_eq!(p.bytes_sent, 2 * 1024);
    }

    #[test]
    fn quiescence() {
        let mut r = Rendezvous::full_mesh(1, 3);
        assert!(r.quiescent());
        let seq = r.pair_mut(0, 1).post_send(Window::new(0, 8));
        assert!(!r.quiescent());
        r.pair_mut(0, 1).complete(seq);
        assert!(r.quiescent());
    }
}
