//! # Nezha — protocol-agnostic multi-rail allreduce for distributed DNN training
//!
//! Reproduction of *"Nezha: Breaking Multi-Rail Network Barriers for
//! Distributed DNN Training"* (Yu, Dong, Liao, 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the Nezha coordinator: [`coordinator`]
//!   (Context / Transport / Collective / Control modules plus the
//!   topology-aware collective planner), the simulated multi-rail fabric
//!   ([`net`]), baseline policies ([`baselines`]), the data-parallel
//!   trainer ([`trainer`]) and the PJRT runtime ([`runtime`], behind the
//!   `pjrt` feature).
//! * **Layer 2 (python/compile/model.py)** — JAX transformer fwd/bwd, lowered
//!   once to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (tiled matmul,
//!   n-way gradient reduce, fused SGD) called from the L2 graph.
//!
//! Python never runs on the training path: `make artifacts` exports HLO once
//! and the rust binary is self-contained afterwards.
//!
//! See `DESIGN.md` for the module inventory and the paper-experiment index,
//! and `EXPERIMENTS.md` for measured results.

pub mod baselines;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod net;
pub mod runtime;
pub mod trainer;
pub mod util;

/// Crate-wide result type (thiserror-backed error enum in [`util::error`]).
pub type Result<T> = std::result::Result<T, util::error::Error>;
