//! `nezha` — CLI for the Nezha multi-rail allreduce reproduction.
//!
//! Subcommands:
//!   fig <id>        regenerate a paper figure/table (fig2..fig19, table1,
//!                   headline, all)
//!   bench           one allreduce benchmark (--combo tcp-sharp --nodes 8
//!                   --size 8MB --policy nezha --reps 10)
//!   train           end-to-end data-parallel training over the multi-rail
//!                   fabric (--model tiny|small|gpt100m --steps N)
//!   info            show clusters, protocols and artifact inventory
//!
//! Global options: --config FILE, plus any config key as --key value
//! (see rust/src/config.rs).

use nezha::bench::figures;
use nezha::config::Config;
use nezha::coordinator::buffer::BufferPool;
use nezha::coordinator::multirail::MultiRail;
use nezha::net::topology::ClusterSpec;
use nezha::trainer::{train_e2e, E2EConfig};
use nezha::util::bytes::{fmt_bytes, fmt_us};
use nezha::util::cli::Args;
use nezha::util::log;
use nezha::util::table::Table;

fn main() {
    log::init_from_env();
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> nezha::Result<()> {
    match args.subcommand.as_deref() {
        Some("fig") => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            figures::run(id)
        }
        Some("bench") => bench(args),
        Some("train") => train(args),
        Some("info") => info(),
        other => {
            if other.is_some() {
                eprintln!("unknown subcommand {other:?}\n");
            }
            println!(
                "usage: nezha <fig|bench|train|info> [options]\n\n\
                 nezha fig all                       # every paper figure/table\n\
                 nezha fig fig9                      # one figure\n\
                 nezha bench --combo tcp-sharp --nodes 8 --size 8MB --policy nezha\n\
                 nezha train --model small --steps 100 --nodes 4 --combo tcp-tcp\n\
                 nezha info"
            );
            Ok(())
        }
    }
}

fn bench(args: &Args) -> nezha::Result<()> {
    let cfg = Config::from_args(args)?;
    let size = args.get_bytes("size", 8 << 20);
    let reps = args.get_usize("reps", 10);
    let warm = args.get_usize("warm", 30);
    let mut mr = MultiRail::new(&cfg)?;
    const ELEMS: usize = 1024;
    let elem_bytes = size as f64 / ELEMS as f64;
    let mut pool = BufferPool::new();
    for _ in 0..warm {
        let mut buf = pool.acquire(cfg.nodes, ELEMS, |n, i| ((n + i) % 7) as f32);
        mr.allreduce_scaled(&mut buf, elem_bytes)?;
        pool.release(buf);
    }
    let mut lat = Vec::with_capacity(reps);
    for _ in 0..reps {
        let mut buf = pool.acquire(cfg.nodes, ELEMS, |n, i| ((n + i) % 7) as f32);
        lat.push(mr.allreduce_scaled(&mut buf, elem_bytes)?.total_us);
        pool.release(buf);
    }
    let mean = nezha::util::stats::mean(&lat);
    println!(
        "{} allreduce of {} over {:?} x{} nodes: {} mean ({:.3} GB/s)",
        mr.partitioner.name(),
        fmt_bytes(size),
        cfg.combo,
        cfg.nodes,
        fmt_us(mean),
        nezha::util::bytes::gbps(size, mean),
    );
    Ok(())
}

fn train(args: &Args) -> nezha::Result<()> {
    let cfg = Config::from_args(args)?;
    let e2e = E2EConfig {
        model: args.get_or("model", "tiny").to_string(),
        steps: args.get_usize("steps", 50),
        lr: args.get_f64("lr", 0.05) as f32,
        momentum: args.get_f64("momentum", 0.9) as f32,
        bucket_elems: args.get_usize("bucket-elems", 4 * 1024 * 1024),
        log_every: args.get_usize("log-every", 10),
        use_pjrt_reducer: !args.has("rust-reducer"),
        seed: args.get_usize("seed", 7) as u64,
    };
    println!(
        "training model={} steps={} nodes={} combo={:?} policy={}",
        e2e.model, e2e.steps, cfg.nodes, cfg.combo, cfg.policy.name()
    );
    let logs = train_e2e(&cfg, &e2e)?;
    let mut t = Table::new(&["step", "loss", "comm(ms)", "compute(ms)"]);
    for l in logs.iter().filter(|l| l.step % e2e.log_every.max(1) == 0) {
        t.row(vec![
            format!("{}", l.step),
            format!("{:.4}", l.loss),
            format!("{:.1}", l.comm_us / 1e3),
            format!("{:.0}", l.compute_wall_us / 1e3),
        ]);
    }
    t.print();
    let first = logs.first().map(|l| l.loss).unwrap_or(0.0);
    let last = logs.last().map(|l| l.loss).unwrap_or(0.0);
    println!("loss: {first:.4} -> {last:.4} over {} steps", logs.len());
    Ok(())
}

fn info() -> nezha::Result<()> {
    println!("clusters (paper Table 2):");
    for c in [ClusterSpec::local(), ClusterSpec::cloud(), ClusterSpec::supercomputer()] {
        println!(
            "  {:14} {} cores={} gpus={} nics={:?}",
            c.name,
            c.node.cpu,
            c.node.cores,
            c.node.gpus,
            c.node.nics.iter().map(|n| format!("{}@{}G", n.model, n.gbps)).collect::<Vec<_>>()
        );
    }
    match nezha::runtime::Manifest::load("artifacts") {
        Ok(m) => {
            println!("\nartifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {:24} in={:?} out={:?}",
                    a.name,
                    a.inputs.iter().map(|i| i.shape.clone()).collect::<Vec<_>>(),
                    a.outputs.iter().map(|o| o.shape.clone()).collect::<Vec<_>>()
                );
            }
            println!("\nmodels:");
            for m in &m.models {
                println!(
                    "  {:10} {:.1}M params, d={} L={} V={} T={} B={}",
                    m.name,
                    m.n_params as f64 / 1e6,
                    m.d_model,
                    m.n_layers,
                    m.vocab,
                    m.seq_len,
                    m.batch
                );
            }
        }
        Err(_) => println!("\nartifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}
