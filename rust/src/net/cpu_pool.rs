//! CPU-core pool and cross-protocol resource contention (paper §2.3.2).
//!
//! GLEX/SHARP throughput scales with allocated cores while TCP saturates at
//! ~26 (Fig. 4); co-deployed protocols additionally contend for shared
//! resources (memory bandwidth, interrupts): dual GLEX+TCP at 26 cores each
//! reaches only ~68% of combined peak. The pool implements the paper's
//! *second design proposition*: adaptive phase-based allocation that grants
//! the computation phase full cores and releases them during I/O and
//! transfer phases.

//!
//! The bottom half of this module is the *host-side* execution engine: the
//! persistent [`WorkerPool`] behind [`RailExecutor`] (DESIGN.md §13) that
//! runs one op's per-rail schedule jobs, optionally priority-ordered so the
//! trainer's barrier-free scheduler can drain early-consumed buckets first.

use std::collections::{BTreeMap, BinaryHeap};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::net::protocol::ProtoKind;

/// Multiplicative efficiency penalty per *additional* co-resident member
/// network sharing the socket (cache/memory-bandwidth/IRQ contention).
/// Calibrated to the paper's §5.3.2 member-degradation measurements:
/// TCP(99%) loses 9.7%, SHARP(99%) 15.6%, GLEX(99%) 17.5% vs single-rail
/// (the protocol core curves add the protocol-specific part on top).
pub const CO_RESIDENT_PENALTY: f64 = 0.88;

/// Fraction of the pool each member network effectively sees under the
/// adaptive time-multiplexed schedule (phase-based allocate/release lets
/// every member's computation phase use most of the socket).
pub const ADAPTIVE_TIMESLICE: f64 = 0.85;

/// Allreduce task phases (paper §4.2): only computation needs many cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    DataLoading,
    Transfer,
    Computation,
}

/// Allocation strategy across co-scheduled protocol threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Static equal partitioning (the strawman the paper shows degrades
    /// SHARP/GLEX by 35–42%).
    StaticEqual,
    /// Nezha's adaptive policy: proportional to runtime protocol demand,
    /// with phase-based release.
    Adaptive,
}

/// A node-local pool of CPU cores shared by the member-network threads.
#[derive(Debug, Clone)]
pub struct CpuPool {
    pub total_cores: f64,
    pub policy: AllocPolicy,
    /// Per protocol: (demand weight, number of resident member-network
    /// threads of this protocol). Two TCP rails = two residents.
    demand: BTreeMap<ProtoKind, (f64, usize)>,
}

impl CpuPool {
    pub fn new(total_cores: f64, policy: AllocPolicy) -> Self {
        CpuPool { total_cores, policy, demand: BTreeMap::new() }
    }

    /// Register one member-network thread of `kind` on this node.
    pub fn register(&mut self, kind: ProtoKind) {
        // Demand weights reflect Fig. 4: TCP gains nothing past 26 cores,
        // RDMA control planes keep scaling.
        let w = match kind {
            ProtoKind::Tcp => 1.0,
            ProtoKind::Sharp => 1.6,
            ProtoKind::Glex => 1.8,
        };
        let e = self.demand.entry(kind).or_insert((w, 0));
        e.1 += 1;
    }

    /// Remove one member-network thread of `kind`.
    pub fn unregister(&mut self, kind: ProtoKind) {
        if let Some(e) = self.demand.get_mut(&kind) {
            e.1 = e.1.saturating_sub(1);
            if e.1 == 0 {
                self.demand.remove(&kind);
            }
        }
    }

    /// Total resident member-network threads (rails), not protocols.
    pub fn n_resident(&self) -> usize {
        self.demand.values().map(|(_, c)| c).sum()
    }

    /// Cores granted to ONE member thread of `kind` during `phase`.
    ///
    /// Adaptive policy (§4.2): only the computation (aggregation) phase
    /// needs many cores, and members' computation phases interleave, so
    /// each member's compute burst sees most of the pool
    /// ([`ADAPTIVE_TIMESLICE`]); transfer/I-O phases run on a skeleton
    /// allocation (cores released back). Static policy: hard equal
    /// partition — the strawman that degrades SHARP/GLEX by 35–42%
    /// (§2.3.2) because a partition can never exploit idle neighbours.
    pub fn cores_for(&self, kind: ProtoKind, phase: Phase) -> f64 {
        let n = self.n_resident().max(1) as f64;
        match self.policy {
            AllocPolicy::StaticEqual => self.total_cores / n,
            AllocPolicy::Adaptive => {
                let share = if self.n_resident() <= 1 {
                    self.total_cores
                } else {
                    self.total_cores * ADAPTIVE_TIMESLICE
                };
                match phase {
                    // paper: "most cores released in other phases"; the
                    // protocol control loop keeps a skeleton slice whose
                    // size follows the protocol's control-plane demand.
                    Phase::DataLoading | Phase::Transfer => {
                        let w = self.demand.get(&kind).map(|(w, _)| *w).unwrap_or(1.0);
                        (share * 0.25 * w).max(2.0)
                    }
                    Phase::Computation => share,
                }
            }
        }
        .min(self.total_cores)
    }

    /// Contention efficiency multiplier applied to protocol bandwidth when
    /// k member threads are co-resident (paper §5.3.2: member networks in
    /// multi-rail lose 8–18% transmission rate vs single-rail configs).
    pub fn contention_factor(&self) -> f64 {
        let k = self.n_resident().max(1) as u32;
        CO_RESIDENT_PENALTY.powi(k as i32 - 1)
    }
}

impl Default for CpuPool {
    fn default() -> Self {
        // paper testbed: Xeon Gold 6230R = 26 cores / 52 threads per node
        CpuPool::new(52.0, AllocPolicy::Adaptive)
    }
}

/// How the coordinator drives the per-rail schedules of one op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One rail after another on the calling thread (the seed behaviour,
    /// and the fallback when a reducer cannot fork).
    Serial,
    /// All healthy rails' schedules run concurrently on scoped worker
    /// threads — per-rail windows are disjoint buffer slices and per-rail
    /// RNG streams are independent, so results (numerics AND modeled
    /// times) are bit-identical to serial execution.
    Parallel,
}

impl ExecMode {
    pub fn parse(s: &str) -> crate::Result<ExecMode> {
        match s.to_ascii_lowercase().as_str() {
            "serial" | "seq" | "off" => Ok(ExecMode::Serial),
            "parallel" | "par" | "on" => Ok(ExecMode::Parallel),
            other => Err(crate::util::error::Error::Config(format!(
                "unknown exec mode `{other}`"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Serial => "serial",
            ExecMode::Parallel => "parallel",
        }
    }

    /// Resolve the default mode, honouring the `NEZHA_EXEC` environment
    /// override — how CI runs the whole test suite under the parallel
    /// executor without per-test plumbing. An invalid value panics (just
    /// as the `exec` config key errors): a typo'd override silently
    /// falling back to serial would fake parallel coverage.
    pub fn from_env(default: ExecMode) -> ExecMode {
        match std::env::var("NEZHA_EXEC") {
            Ok(v) => ExecMode::parse(&v).unwrap_or_else(|e| panic!("NEZHA_EXEC: {e}")),
            Err(_) => default,
        }
    }
}

/// How the trainer sequences collective ops across iterations
/// (`sched = barrier | priority`, DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// The legacy per-iteration barrier: every bucket's allreduce must
    /// finish before the next forward pass starts.
    Barrier,
    /// Barrier-free cross-iteration scheduling: buckets are enqueued as
    /// the backward pass produces them and awaited only at the forward
    /// step that consumes them next iteration, priority-ordered by
    /// consumption order so early-forward buckets preempt late ones at
    /// window boundaries.
    Priority,
}

impl SchedMode {
    pub fn parse(s: &str) -> crate::Result<SchedMode> {
        match s.to_ascii_lowercase().as_str() {
            "barrier" | "sync" => Ok(SchedMode::Barrier),
            "priority" | "async" => Ok(SchedMode::Priority),
            other => Err(crate::util::error::Error::Config(format!(
                "unknown sched mode `{other}`"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedMode::Barrier => "barrier",
            SchedMode::Priority => "priority",
        }
    }
}

/// One queued pool task: a lifetime-erased job plus its (priority, FIFO
/// sequence) drain key. The heap is a max-heap, so `Ord` is inverted to
/// pop the *lowest* (priority, seq) pair first — priority 0 drains before
/// priority 1, submission order breaks ties.
struct PoolTask {
    prio: u32,
    seq: u64,
    job: Box<dyn FnOnce() + Send + 'static>,
}

impl PartialEq for PoolTask {
    fn eq(&self, other: &Self) -> bool {
        (self.prio, self.seq) == (other.prio, other.seq)
    }
}
impl Eq for PoolTask {}
impl PartialOrd for PoolTask {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PoolTask {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // inverted: BinaryHeap pops max, we want min-(prio, seq)
        (other.prio, other.seq).cmp(&(self.prio, self.seq))
    }
}

struct PoolState {
    queue: BinaryHeap<PoolTask>,
    next_seq: u64,
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    /// Signalled when tasks are enqueued (workers) or shutdown is set.
    available: Condvar,
}

/// Completion latch for one `run_prioritized` batch: the caller blocks
/// until every job has run (so borrows into its stack frame stay valid),
/// and learns whether any job panicked.
struct Latch {
    state: Mutex<(usize, bool)>,
    all_done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new((n, false)), all_done: Condvar::new() }
    }

    fn arrive(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        st.1 |= panicked;
        if st.0 == 0 {
            self.all_done.notify_all();
        }
    }

    /// Block until every job arrived; true if any panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.all_done.wait(st).unwrap();
        }
        st.1
    }
}

/// Raw result-slot pointer smuggled into a worker job. Soundness comes
/// from `run_prioritized`: slots are disjoint, outlive the batch (the
/// caller blocks on the latch), and each is written by exactly one job.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}

fn worker_loop(inner: Arc<PoolInner>) {
    loop {
        let task = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if let Some(t) = st.queue.pop() {
                    break t;
                }
                if st.shutdown {
                    return;
                }
                st = inner.available.wait(st).unwrap();
            }
        };
        // the job itself catches panics and reports through its latch
        (task.job)();
    }
}

/// A persistent priority worker pool: worker threads live for the process
/// (amortizing the old per-op `thread::scope` spawn) and drain a shared
/// queue in ascending (priority, submission) order.
///
/// Deadlock freedom: jobs are plain closures that never enqueue further
/// work or block on other jobs, the caller enqueues its whole batch under
/// one lock hold *before* waiting, and workers always drain the queue
/// ahead of checking shutdown — so every enqueued job is eventually run by
/// some worker and every latch is eventually released (DESIGN.md §13).
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let inner = Arc::new(PoolInner {
            state: Mutex::new(PoolState {
                queue: BinaryHeap::new(),
                next_seq: 0,
                shutdown: false,
            }),
            available: Condvar::new(),
        });
        let handles = (0..workers.max(1))
            .map(|k| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("nezha-rail-{k}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn rail worker")
            })
            .collect();
        WorkerPool { inner, handles }
    }

    /// The process-wide pool every parallel `RailExecutor` shares. Sized
    /// to the host (clamped to [2, 8] — rails, the unit of parallelism
    /// here, never exceed a handful) and never torn down.
    pub fn shared() -> &'static WorkerPool {
        static SHARED: OnceLock<WorkerPool> = OnceLock::new();
        SHARED.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            WorkerPool::new(n.clamp(2, 8))
        })
    }

    /// Run one batch of `(priority, job)` pairs on the pool and return
    /// the results in **submission order** (priorities reorder execution,
    /// never results). Blocks until the whole batch has run; if any job
    /// panicked, panics with the executor's message after the rest of the
    /// batch drained (workers survive — panics are caught per job).
    pub fn run_prioritized<T, F>(&self, jobs: Vec<(u32, F)>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let mut results: Vec<Option<T>> = Vec::with_capacity(n);
        results.resize_with(n, || None);
        let latch = Arc::new(Latch::new(n));
        {
            let mut st = self.inner.state.lock().unwrap();
            for (i, (prio, f)) in jobs.into_iter().enumerate() {
                let slot = SendPtr(&mut results[i] as *mut Option<T>);
                let latch = Arc::clone(&latch);
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                    match out {
                        Ok(v) => {
                            // SAFETY: `slot` points into `results`, which
                            // the caller keeps alive (and unmoved) until
                            // the latch releases; slot `i` is written by
                            // this job only.
                            unsafe { *slot.0 = Some(v) };
                            latch.arrive(false);
                        }
                        Err(_) => latch.arrive(true),
                    }
                });
                // SAFETY: the closure borrows only `results` slots; the
                // latch wait below keeps this stack frame alive until
                // every job has finished, so erasing the lifetime never
                // lets a borrow dangle.
                let job: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(job) };
                let seq = st.next_seq;
                st.next_seq += 1;
                st.queue.push(PoolTask { prio, seq, job });
            }
            self.inner.available.notify_all();
        }
        if latch.wait() {
            panic!("rail worker panicked");
        }
        results
            .into_iter()
            .map(|r| r.expect("every pool job fills its slot"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The cross-rail execution engine: runs one op's per-rail jobs either
/// in order on the calling thread or concurrently on the persistent
/// [`WorkerPool`] (one job per participating rail — rails are the unit of
/// hardware parallelism here, mirroring the paper's one-protocol-thread-
/// per-member-network deployment).
///
/// Results always come back in job submission order, so the coordinator's
/// merge (shares, Timer feedback, failover handling) is deterministic
/// regardless of thread scheduling — and regardless of the priorities the
/// barrier-free scheduler attaches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RailExecutor {
    pub mode: ExecMode,
}

impl RailExecutor {
    pub fn new(mode: ExecMode) -> RailExecutor {
        RailExecutor { mode }
    }

    /// Run the jobs and collect their results in submission order. A
    /// single job never pays queue overhead, even in parallel mode.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        self.run_prioritized(jobs.into_iter().map(|j| (0, j)).collect())
    }

    /// Run `(priority, job)` pairs: parallel mode drains them through the
    /// shared pool in ascending (priority, submission) order, serial mode
    /// runs them inline in submission order (priorities only ever reorder
    /// *execution start*, never results — both modes return submission
    /// order, keeping serial/parallel bit-identity).
    pub fn run_prioritized<T, F>(&self, jobs: Vec<(u32, F)>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        match self.mode {
            _ if jobs.len() <= 1 => jobs.into_iter().map(|(_, j)| j()).collect(),
            ExecMode::Serial => jobs.into_iter().map(|(_, j)| j()).collect(),
            ExecMode::Parallel => WorkerPool::shared().run_prioritized(jobs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_equal_split() {
        let mut p = CpuPool::new(52.0, AllocPolicy::StaticEqual);
        p.register(ProtoKind::Tcp);
        p.register(ProtoKind::Glex);
        assert!((p.cores_for(ProtoKind::Tcp, Phase::Computation) - 26.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_timeslice_beats_static_partition() {
        // §2.3.2: the adaptive schedule must grant a co-resident scalable
        // protocol far more compute-phase cores than a hard equal split
        let mut adap = CpuPool::new(52.0, AllocPolicy::Adaptive);
        let mut stat = CpuPool::new(52.0, AllocPolicy::StaticEqual);
        for p in [&mut adap, &mut stat] {
            p.register(ProtoKind::Tcp);
            p.register(ProtoKind::Glex);
            p.register(ProtoKind::Sharp);
        }
        let a = adap.cores_for(ProtoKind::Glex, Phase::Computation);
        let s = stat.cores_for(ProtoKind::Glex, Phase::Computation);
        assert!((a - 52.0 * ADAPTIVE_TIMESLICE).abs() < 1e-9);
        assert!((s - 52.0 / 3.0).abs() < 1e-9);
        assert!(a > 2.0 * s);
    }

    #[test]
    fn static_equal_split_matches_paper_degradation() {
        // paper: equal 3-way split degrades SHARP by ~42%, GLEX by ~35%
        use crate::net::protocol::Protocol;
        let mut stat = CpuPool::new(52.0, AllocPolicy::StaticEqual);
        stat.register(ProtoKind::Tcp);
        stat.register(ProtoKind::Glex);
        stat.register(ProtoKind::Sharp);
        let sharp_m = Protocol::sharp()
            .core_curve
            .multiplier(stat.cores_for(ProtoKind::Sharp, Phase::Computation));
        let glex_m = Protocol::glex()
            .core_curve
            .multiplier(stat.cores_for(ProtoKind::Glex, Phase::Computation));
        assert!((1.0 - sharp_m - 0.42).abs() < 0.1, "sharp degradation {}", 1.0 - sharp_m);
        assert!((1.0 - glex_m - 0.35).abs() < 0.1, "glex degradation {}", 1.0 - glex_m);
    }

    #[test]
    fn phases_release_cores() {
        let mut p = CpuPool::new(52.0, AllocPolicy::Adaptive);
        p.register(ProtoKind::Glex);
        let compute = p.cores_for(ProtoKind::Glex, Phase::Computation);
        let xfer = p.cores_for(ProtoKind::Glex, Phase::Transfer);
        assert!(xfer < compute);
        assert!(xfer >= 2.0);
    }

    #[test]
    fn contention_grows_with_residents() {
        let mut p = CpuPool::default();
        p.register(ProtoKind::Tcp);
        assert!((p.contention_factor() - 1.0).abs() < 1e-12);
        p.register(ProtoKind::Glex);
        assert!((p.contention_factor() - CO_RESIDENT_PENALTY).abs() < 1e-12);
        p.register(ProtoKind::Sharp);
        assert!((p.contention_factor() - CO_RESIDENT_PENALTY * CO_RESIDENT_PENALTY).abs() < 1e-12);
    }

    #[test]
    fn executor_preserves_submission_order() {
        for mode in [ExecMode::Serial, ExecMode::Parallel] {
            let ex = RailExecutor::new(mode);
            let jobs: Vec<_> = (0..6)
                .map(|i| move || i * 10)
                .collect();
            assert_eq!(ex.run(jobs), vec![0, 10, 20, 30, 40, 50], "{mode:?}");
        }
        // empty and single-job cases short-circuit
        let ex = RailExecutor::new(ExecMode::Parallel);
        assert!(ex.run(Vec::<fn() -> i32>::new()).is_empty());
        assert_eq!(ex.run(vec![|| 7]), vec![7]);
    }

    #[test]
    fn executor_jobs_can_mutate_disjoint_borrows() {
        // the coordinator's use: each job owns &mut into a distinct slice
        let mut data = vec![0u64; 4];
        {
            let ex = RailExecutor::new(ExecMode::Parallel);
            let jobs: Vec<_> = data
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    move || {
                        *slot = i as u64 + 1;
                        i
                    }
                })
                .collect();
            assert_eq!(ex.run(jobs), vec![0, 1, 2, 3]);
        }
        assert_eq!(data, vec![1, 2, 3, 4]);
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("serial").unwrap(), ExecMode::Serial);
        assert_eq!(ExecMode::parse("parallel").unwrap(), ExecMode::Parallel);
        assert_eq!(ExecMode::parse("on").unwrap(), ExecMode::Parallel);
        assert!(ExecMode::parse("bogus").is_err());
        assert_eq!(ExecMode::Parallel.name(), "parallel");
    }

    #[test]
    fn sched_mode_parses() {
        assert_eq!(SchedMode::parse("barrier").unwrap(), SchedMode::Barrier);
        assert_eq!(SchedMode::parse("priority").unwrap(), SchedMode::Priority);
        assert_eq!(SchedMode::parse("async").unwrap(), SchedMode::Priority);
        assert!(SchedMode::parse("bogus").is_err());
        assert_eq!(SchedMode::Priority.name(), "priority");
        assert_eq!(SchedMode::Barrier.name(), "barrier");
    }

    #[test]
    fn pool_drains_by_priority_but_returns_submission_order() {
        // one worker → execution order IS heap order: the whole batch is
        // enqueued under a single lock hold before the worker can pop
        let pool = WorkerPool::new(1);
        let ran = Arc::new(Mutex::new(Vec::new()));
        let prios = [3u32, 0, 2, 1];
        let jobs: Vec<_> = prios
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                let ran = Arc::clone(&ran);
                (p, move || {
                    ran.lock().unwrap().push(p);
                    i * 10
                })
            })
            .collect();
        let out = pool.run_prioritized(jobs);
        // results in submission order, regardless of drain order
        assert_eq!(out, vec![0, 10, 20, 30]);
        // execution in ascending priority order
        assert_eq!(*ran.lock().unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn pool_equal_priorities_drain_fifo() {
        let pool = WorkerPool::new(1);
        let ran = Arc::new(Mutex::new(Vec::new()));
        let jobs: Vec<_> = (0..5)
            .map(|i| {
                let ran = Arc::clone(&ran);
                (7u32, move || ran.lock().unwrap().push(i))
            })
            .collect();
        pool.run_prioritized(jobs);
        assert_eq!(*ran.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn pool_results_are_deterministic_across_runs() {
        let pool = WorkerPool::new(3);
        for _ in 0..10 {
            let jobs: Vec<_> = (0..8u64)
                .map(|i| (((i * 13) % 5) as u32, move || i * i + 1))
                .collect();
            let out = pool.run_prioritized(jobs);
            assert_eq!(out, (0..8u64).map(|i| i * i + 1).collect::<Vec<_>>());
        }
    }

    #[test]
    fn executor_prioritized_matches_plain_run() {
        for mode in [ExecMode::Serial, ExecMode::Parallel] {
            let ex = RailExecutor::new(mode);
            let jobs: Vec<_> = (0..6).map(|i| (5 - i as u32, move || i * 10)).collect();
            assert_eq!(ex.run_prioritized(jobs), vec![0, 10, 20, 30, 40, 50], "{mode:?}");
        }
    }

    #[test]
    fn pool_jobs_can_mutate_disjoint_borrows() {
        // same contract as the executor test: jobs hold &mut into the
        // caller's stack; the latch keeps the frame alive until all ran
        let pool = WorkerPool::new(2);
        let mut data = vec![0u64; 4];
        {
            let jobs: Vec<_> = data
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    (i as u32, move || {
                        *slot = i as u64 + 1;
                        i
                    })
                })
                .collect();
            assert_eq!(pool.run_prioritized(jobs), vec![0, 1, 2, 3]);
        }
        assert_eq!(data, vec![1, 2, 3, 4]);
    }

    #[test]
    fn pool_survives_a_panicked_job_and_stays_usable() {
        let pool = WorkerPool::new(2);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_prioritized(vec![
                (0u32, Box::new(|| 1) as Box<dyn FnOnce() -> i32 + Send>),
                (1u32, Box::new(|| panic!("job blew up"))),
            ])
        }));
        assert!(boom.is_err(), "batch with a panicking job must panic");
        // workers caught the panic; the pool still runs new batches
        let out = pool.run_prioritized(vec![(0u32, || 41), (0u32, || 42)]);
        assert_eq!(out, vec![41, 42]);
    }

    #[test]
    fn unregister_restores() {
        let mut p = CpuPool::default();
        p.register(ProtoKind::Tcp);
        p.register(ProtoKind::Glex);
        p.unregister(ProtoKind::Glex);
        assert_eq!(p.n_resident(), 1);
        assert!((p.contention_factor() - 1.0).abs() < 1e-12);
    }
}
