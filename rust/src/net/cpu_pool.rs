//! CPU-core pool and cross-protocol resource contention (paper §2.3.2).
//!
//! GLEX/SHARP throughput scales with allocated cores while TCP saturates at
//! ~26 (Fig. 4); co-deployed protocols additionally contend for shared
//! resources (memory bandwidth, interrupts): dual GLEX+TCP at 26 cores each
//! reaches only ~68% of combined peak. The pool implements the paper's
//! *second design proposition*: adaptive phase-based allocation that grants
//! the computation phase full cores and releases them during I/O and
//! transfer phases.

use std::collections::BTreeMap;

use crate::net::protocol::ProtoKind;

/// Multiplicative efficiency penalty per *additional* co-resident member
/// network sharing the socket (cache/memory-bandwidth/IRQ contention).
/// Calibrated to the paper's §5.3.2 member-degradation measurements:
/// TCP(99%) loses 9.7%, SHARP(99%) 15.6%, GLEX(99%) 17.5% vs single-rail
/// (the protocol core curves add the protocol-specific part on top).
pub const CO_RESIDENT_PENALTY: f64 = 0.88;

/// Fraction of the pool each member network effectively sees under the
/// adaptive time-multiplexed schedule (phase-based allocate/release lets
/// every member's computation phase use most of the socket).
pub const ADAPTIVE_TIMESLICE: f64 = 0.85;

/// Allreduce task phases (paper §4.2): only computation needs many cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    DataLoading,
    Transfer,
    Computation,
}

/// Allocation strategy across co-scheduled protocol threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// Static equal partitioning (the strawman the paper shows degrades
    /// SHARP/GLEX by 35–42%).
    StaticEqual,
    /// Nezha's adaptive policy: proportional to runtime protocol demand,
    /// with phase-based release.
    Adaptive,
}

/// A node-local pool of CPU cores shared by the member-network threads.
#[derive(Debug, Clone)]
pub struct CpuPool {
    pub total_cores: f64,
    pub policy: AllocPolicy,
    /// Per protocol: (demand weight, number of resident member-network
    /// threads of this protocol). Two TCP rails = two residents.
    demand: BTreeMap<ProtoKind, (f64, usize)>,
}

impl CpuPool {
    pub fn new(total_cores: f64, policy: AllocPolicy) -> Self {
        CpuPool { total_cores, policy, demand: BTreeMap::new() }
    }

    /// Register one member-network thread of `kind` on this node.
    pub fn register(&mut self, kind: ProtoKind) {
        // Demand weights reflect Fig. 4: TCP gains nothing past 26 cores,
        // RDMA control planes keep scaling.
        let w = match kind {
            ProtoKind::Tcp => 1.0,
            ProtoKind::Sharp => 1.6,
            ProtoKind::Glex => 1.8,
        };
        let e = self.demand.entry(kind).or_insert((w, 0));
        e.1 += 1;
    }

    /// Remove one member-network thread of `kind`.
    pub fn unregister(&mut self, kind: ProtoKind) {
        if let Some(e) = self.demand.get_mut(&kind) {
            e.1 = e.1.saturating_sub(1);
            if e.1 == 0 {
                self.demand.remove(&kind);
            }
        }
    }

    /// Total resident member-network threads (rails), not protocols.
    pub fn n_resident(&self) -> usize {
        self.demand.values().map(|(_, c)| c).sum()
    }

    /// Cores granted to ONE member thread of `kind` during `phase`.
    ///
    /// Adaptive policy (§4.2): only the computation (aggregation) phase
    /// needs many cores, and members' computation phases interleave, so
    /// each member's compute burst sees most of the pool
    /// ([`ADAPTIVE_TIMESLICE`]); transfer/I-O phases run on a skeleton
    /// allocation (cores released back). Static policy: hard equal
    /// partition — the strawman that degrades SHARP/GLEX by 35–42%
    /// (§2.3.2) because a partition can never exploit idle neighbours.
    pub fn cores_for(&self, kind: ProtoKind, phase: Phase) -> f64 {
        let n = self.n_resident().max(1) as f64;
        match self.policy {
            AllocPolicy::StaticEqual => self.total_cores / n,
            AllocPolicy::Adaptive => {
                let share = if self.n_resident() <= 1 {
                    self.total_cores
                } else {
                    self.total_cores * ADAPTIVE_TIMESLICE
                };
                match phase {
                    // paper: "most cores released in other phases"; the
                    // protocol control loop keeps a skeleton slice whose
                    // size follows the protocol's control-plane demand.
                    Phase::DataLoading | Phase::Transfer => {
                        let w = self.demand.get(&kind).map(|(w, _)| *w).unwrap_or(1.0);
                        (share * 0.25 * w).max(2.0)
                    }
                    Phase::Computation => share,
                }
            }
        }
        .min(self.total_cores)
    }

    /// Contention efficiency multiplier applied to protocol bandwidth when
    /// k member threads are co-resident (paper §5.3.2: member networks in
    /// multi-rail lose 8–18% transmission rate vs single-rail configs).
    pub fn contention_factor(&self) -> f64 {
        let k = self.n_resident().max(1) as u32;
        CO_RESIDENT_PENALTY.powi(k as i32 - 1)
    }
}

impl Default for CpuPool {
    fn default() -> Self {
        // paper testbed: Xeon Gold 6230R = 26 cores / 52 threads per node
        CpuPool::new(52.0, AllocPolicy::Adaptive)
    }
}

/// How the coordinator drives the per-rail schedules of one op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One rail after another on the calling thread (the seed behaviour,
    /// and the fallback when a reducer cannot fork).
    Serial,
    /// All healthy rails' schedules run concurrently on scoped worker
    /// threads — per-rail windows are disjoint buffer slices and per-rail
    /// RNG streams are independent, so results (numerics AND modeled
    /// times) are bit-identical to serial execution.
    Parallel,
}

impl ExecMode {
    pub fn parse(s: &str) -> crate::Result<ExecMode> {
        match s.to_ascii_lowercase().as_str() {
            "serial" | "seq" | "off" => Ok(ExecMode::Serial),
            "parallel" | "par" | "on" => Ok(ExecMode::Parallel),
            other => Err(crate::util::error::Error::Config(format!(
                "unknown exec mode `{other}`"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Serial => "serial",
            ExecMode::Parallel => "parallel",
        }
    }

    /// Resolve the default mode, honouring the `NEZHA_EXEC` environment
    /// override — how CI runs the whole test suite under the parallel
    /// executor without per-test plumbing. An invalid value panics (just
    /// as the `exec` config key errors): a typo'd override silently
    /// falling back to serial would fake parallel coverage.
    pub fn from_env(default: ExecMode) -> ExecMode {
        match std::env::var("NEZHA_EXEC") {
            Ok(v) => ExecMode::parse(&v).unwrap_or_else(|e| panic!("NEZHA_EXEC: {e}")),
            Err(_) => default,
        }
    }
}

/// The cross-rail execution engine: runs one op's per-rail jobs either
/// in order on the calling thread or concurrently on scoped worker
/// threads (one thread per participating rail — rails are the unit of
/// hardware parallelism here, mirroring the paper's one-protocol-thread-
/// per-member-network deployment).
///
/// Results always come back in job submission order, so the coordinator's
/// merge (shares, Timer feedback, failover handling) is deterministic
/// regardless of thread scheduling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RailExecutor {
    pub mode: ExecMode,
}

impl RailExecutor {
    pub fn new(mode: ExecMode) -> RailExecutor {
        RailExecutor { mode }
    }

    /// Run the jobs and collect their results in submission order. A
    /// single job never pays thread-spawn overhead, even in parallel mode.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        match self.mode {
            _ if jobs.len() <= 1 => jobs.into_iter().map(|j| j()).collect(),
            ExecMode::Serial => jobs.into_iter().map(|j| j()).collect(),
            ExecMode::Parallel => std::thread::scope(|s| {
                let handles: Vec<_> = jobs.into_iter().map(|j| s.spawn(j)).collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("rail worker panicked"))
                    .collect()
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_equal_split() {
        let mut p = CpuPool::new(52.0, AllocPolicy::StaticEqual);
        p.register(ProtoKind::Tcp);
        p.register(ProtoKind::Glex);
        assert!((p.cores_for(ProtoKind::Tcp, Phase::Computation) - 26.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_timeslice_beats_static_partition() {
        // §2.3.2: the adaptive schedule must grant a co-resident scalable
        // protocol far more compute-phase cores than a hard equal split
        let mut adap = CpuPool::new(52.0, AllocPolicy::Adaptive);
        let mut stat = CpuPool::new(52.0, AllocPolicy::StaticEqual);
        for p in [&mut adap, &mut stat] {
            p.register(ProtoKind::Tcp);
            p.register(ProtoKind::Glex);
            p.register(ProtoKind::Sharp);
        }
        let a = adap.cores_for(ProtoKind::Glex, Phase::Computation);
        let s = stat.cores_for(ProtoKind::Glex, Phase::Computation);
        assert!((a - 52.0 * ADAPTIVE_TIMESLICE).abs() < 1e-9);
        assert!((s - 52.0 / 3.0).abs() < 1e-9);
        assert!(a > 2.0 * s);
    }

    #[test]
    fn static_equal_split_matches_paper_degradation() {
        // paper: equal 3-way split degrades SHARP by ~42%, GLEX by ~35%
        use crate::net::protocol::Protocol;
        let mut stat = CpuPool::new(52.0, AllocPolicy::StaticEqual);
        stat.register(ProtoKind::Tcp);
        stat.register(ProtoKind::Glex);
        stat.register(ProtoKind::Sharp);
        let sharp_m = Protocol::sharp()
            .core_curve
            .multiplier(stat.cores_for(ProtoKind::Sharp, Phase::Computation));
        let glex_m = Protocol::glex()
            .core_curve
            .multiplier(stat.cores_for(ProtoKind::Glex, Phase::Computation));
        assert!((1.0 - sharp_m - 0.42).abs() < 0.1, "sharp degradation {}", 1.0 - sharp_m);
        assert!((1.0 - glex_m - 0.35).abs() < 0.1, "glex degradation {}", 1.0 - glex_m);
    }

    #[test]
    fn phases_release_cores() {
        let mut p = CpuPool::new(52.0, AllocPolicy::Adaptive);
        p.register(ProtoKind::Glex);
        let compute = p.cores_for(ProtoKind::Glex, Phase::Computation);
        let xfer = p.cores_for(ProtoKind::Glex, Phase::Transfer);
        assert!(xfer < compute);
        assert!(xfer >= 2.0);
    }

    #[test]
    fn contention_grows_with_residents() {
        let mut p = CpuPool::default();
        p.register(ProtoKind::Tcp);
        assert!((p.contention_factor() - 1.0).abs() < 1e-12);
        p.register(ProtoKind::Glex);
        assert!((p.contention_factor() - CO_RESIDENT_PENALTY).abs() < 1e-12);
        p.register(ProtoKind::Sharp);
        assert!((p.contention_factor() - CO_RESIDENT_PENALTY * CO_RESIDENT_PENALTY).abs() < 1e-12);
    }

    #[test]
    fn executor_preserves_submission_order() {
        for mode in [ExecMode::Serial, ExecMode::Parallel] {
            let ex = RailExecutor::new(mode);
            let jobs: Vec<_> = (0..6)
                .map(|i| move || i * 10)
                .collect();
            assert_eq!(ex.run(jobs), vec![0, 10, 20, 30, 40, 50], "{mode:?}");
        }
        // empty and single-job cases short-circuit
        let ex = RailExecutor::new(ExecMode::Parallel);
        assert!(ex.run(Vec::<fn() -> i32>::new()).is_empty());
        assert_eq!(ex.run(vec![|| 7]), vec![7]);
    }

    #[test]
    fn executor_jobs_can_mutate_disjoint_borrows() {
        // the coordinator's use: each job owns &mut into a distinct slice
        let mut data = vec![0u64; 4];
        {
            let ex = RailExecutor::new(ExecMode::Parallel);
            let jobs: Vec<_> = data
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    move || {
                        *slot = i as u64 + 1;
                        i
                    }
                })
                .collect();
            assert_eq!(ex.run(jobs), vec![0, 1, 2, 3]);
        }
        assert_eq!(data, vec![1, 2, 3, 4]);
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("serial").unwrap(), ExecMode::Serial);
        assert_eq!(ExecMode::parse("parallel").unwrap(), ExecMode::Parallel);
        assert_eq!(ExecMode::parse("on").unwrap(), ExecMode::Parallel);
        assert!(ExecMode::parse("bogus").is_err());
        assert_eq!(ExecMode::Parallel.name(), "parallel");
    }

    #[test]
    fn unregister_restores() {
        let mut p = CpuPool::default();
        p.register(ProtoKind::Tcp);
        p.register(ProtoKind::Glex);
        p.unregister(ProtoKind::Glex);
        assert_eq!(p.n_resident(), 1);
        assert!((p.contention_factor() - 1.0).abs() < 1e-12);
    }
}
