//! Fault injection (paper §2.3.3 / §4.4 / Fig. 8).
//!
//! Models the paper's observed failure modes — thermal NIC power-off,
//! protocol-induced connection failures — as rail-down windows on the
//! virtual clock. The Exception Handler (coordinator/control) detects a
//! failed rail through transfer errors/heartbeat timeout and migrates its
//! (ptr, len) work to the surviving optimal rail within the 200 ms budget.

/// One rail-down window in virtual time.
#[derive(Debug, Clone, Copy)]
pub struct FaultWindow {
    pub rail: usize,
    pub start_us: f64,
    pub end_us: f64,
}

/// Schedule of injected faults.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    windows: Vec<FaultWindow>,
}

impl FaultSchedule {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with(mut self, rail: usize, start_us: f64, end_us: f64) -> Self {
        assert!(end_us > start_us);
        self.windows.push(FaultWindow { rail, start_us, end_us });
        self
    }

    /// Fig. 8's scenario: NIC 2 (rail 1) disconnected during minutes 1–2
    /// and 4–5 of a 6-minute run.
    pub fn fig8() -> Self {
        const MIN: f64 = 60.0 * 1e6;
        FaultSchedule::none()
            .with(1, 1.0 * MIN, 2.0 * MIN)
            .with(1, 4.0 * MIN, 5.0 * MIN)
    }

    /// Is `rail` down at virtual time `t_us`?
    pub fn is_down(&self, rail: usize, t_us: f64) -> bool {
        self.windows
            .iter()
            .any(|w| w.rail == rail && t_us >= w.start_us && t_us < w.end_us)
    }

    /// Next instant strictly after `t_us` at which [`FaultSchedule::is_down`]
    /// for `rail` actually flips (used by recovery probing).
    ///
    /// Windows may overlap or touch (`[0,100)` + `[50,150)`, `[0,100)` +
    /// `[100,200)`): interior edges inside the union of down-time are not
    /// transitions, so the walk skips every edge at which the rail's state
    /// equals its state at `t_us` and returns the first edge where it
    /// differs. `None` when the state never changes again.
    pub fn next_transition(&self, rail: usize, t_us: f64) -> Option<f64> {
        let state = self.is_down(rail, t_us);
        let mut t = t_us;
        loop {
            let edge = self
                .windows
                .iter()
                .filter(|w| w.rail == rail)
                .flat_map(|w| [w.start_us, w.end_us])
                .filter(|&e| e > t)
                .min_by(|a, b| a.partial_cmp(b).unwrap())?;
            if self.is_down(rail, edge) != state {
                return Some(edge);
            }
            t = edge;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }
}

/// One node-level membership change on the virtual clock — the elastic
/// counterpart of a rail-down [`FaultWindow`]. Node ids always refer to
/// the configured (full) cluster numbering; the coordinator compacts the
/// surviving set itself.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MembershipEvent {
    /// `node` departs (crash, drain, thermal power-off) at `at_us`.
    Leave { node: usize, at_us: f64 },
    /// `node` comes back at `at_us` (must have departed earlier).
    Join { node: usize, at_us: f64 },
}

impl MembershipEvent {
    pub fn at_us(&self) -> f64 {
        match *self {
            MembershipEvent::Leave { at_us, .. } | MembershipEvent::Join { at_us, .. } => at_us,
        }
    }

    pub fn node(&self) -> usize {
        match *self {
            MembershipEvent::Leave { node, .. } | MembershipEvent::Join { node, .. } => node,
        }
    }
}

/// Schedule of node join/leave churn, kept sorted by event time. The
/// coordinator polls it at op boundaries: an event landing mid-op is
/// detected — like a rail fault — when the op completes and the next one
/// begins.
#[derive(Debug, Clone, Default)]
pub struct MembershipSchedule {
    events: Vec<MembershipEvent>,
}

impl MembershipSchedule {
    pub fn none() -> Self {
        Self::default()
    }

    /// Add a leave event (builder form).
    pub fn leave(mut self, node: usize, at_us: f64) -> Self {
        self.push(MembershipEvent::Leave { node, at_us });
        self
    }

    /// Add a join event (builder form).
    pub fn join(mut self, node: usize, at_us: f64) -> Self {
        self.push(MembershipEvent::Join { node, at_us });
        self
    }

    fn push(&mut self, ev: MembershipEvent) {
        assert!(ev.at_us().is_finite() && ev.at_us() >= 0.0);
        self.events.push(ev);
        // stable by insertion order at equal times
        self.events
            .sort_by(|a, b| a.at_us().partial_cmp(&b.at_us()).unwrap());
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The `i`-th event in time order.
    pub fn event(&self, i: usize) -> MembershipEvent {
        self.events[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_membership() {
        let f = FaultSchedule::none().with(1, 100.0, 200.0);
        assert!(!f.is_down(1, 99.0));
        assert!(f.is_down(1, 100.0));
        assert!(f.is_down(1, 199.9));
        assert!(!f.is_down(1, 200.0));
        assert!(!f.is_down(0, 150.0));
    }

    #[test]
    fn fig8_shape() {
        let f = FaultSchedule::fig8();
        let min = 60.0 * 1e6;
        assert!(f.is_down(1, 1.5 * min));
        assert!(!f.is_down(1, 3.0 * min));
        assert!(f.is_down(1, 4.5 * min));
        assert!(!f.is_down(0, 4.5 * min));
    }

    #[test]
    fn transitions() {
        let f = FaultSchedule::none().with(0, 10.0, 20.0);
        assert_eq!(f.next_transition(0, 0.0), Some(10.0));
        assert_eq!(f.next_transition(0, 10.0), Some(20.0));
        assert_eq!(f.next_transition(0, 20.0), None);
    }

    #[test]
    fn transitions_skip_interior_edges_of_overlapping_windows() {
        // [0,100) + [50,150): down over the whole union [0,150). The edge
        // at 100 is inside the union — the rail is still down there, so it
        // must NOT be reported as a transition (regression: it used to be).
        let f = FaultSchedule::none().with(0, 0.0, 100.0).with(0, 50.0, 150.0);
        assert!(f.is_down(0, 100.0), "still down at the interior edge");
        assert_eq!(f.next_transition(0, 40.0), Some(150.0));
        assert_eq!(f.next_transition(0, 100.0), Some(150.0));
        // from healthy time before the union: first flip is the union start
        let g = FaultSchedule::none().with(0, 10.0, 100.0).with(0, 50.0, 150.0);
        assert_eq!(g.next_transition(0, 0.0), Some(10.0));
        assert_eq!(g.next_transition(0, 10.0), Some(150.0));
    }

    #[test]
    fn transitions_merge_adjacent_windows() {
        // [0,100) + [100,200) form one continuous down span: the shared
        // edge at 100 flips nothing (is_down(100) is true via window 2).
        let f = FaultSchedule::none().with(1, 0.0, 100.0).with(1, 100.0, 200.0);
        assert!(f.is_down(1, 100.0));
        assert_eq!(f.next_transition(1, 0.0), Some(200.0));
        assert_eq!(f.next_transition(1, 100.0), Some(200.0));
        assert_eq!(f.next_transition(1, 200.0), None);
        // other rails are untouched by rail 1's windows
        assert_eq!(f.next_transition(0, 0.0), None);
    }

    #[test]
    fn transitions_with_disjoint_windows_report_each_flip() {
        let f = FaultSchedule::none().with(0, 10.0, 20.0).with(0, 40.0, 50.0);
        assert_eq!(f.next_transition(0, 0.0), Some(10.0));
        assert_eq!(f.next_transition(0, 15.0), Some(20.0));
        assert_eq!(f.next_transition(0, 20.0), Some(40.0));
        assert_eq!(f.next_transition(0, 45.0), Some(50.0));
        assert_eq!(f.next_transition(0, 50.0), None);
    }

    #[test]
    fn membership_schedule_sorts_and_exposes_events() {
        let s = MembershipSchedule::none()
            .join(3, 500.0)
            .leave(3, 100.0)
            .leave(1, 250.0);
        assert!(!s.is_empty());
        assert_eq!(s.len(), 3);
        // events come back in time order regardless of builder order
        assert_eq!(s.event(0), MembershipEvent::Leave { node: 3, at_us: 100.0 });
        assert_eq!(s.event(1), MembershipEvent::Leave { node: 1, at_us: 250.0 });
        assert_eq!(s.event(2), MembershipEvent::Join { node: 3, at_us: 500.0 });
        assert_eq!(s.event(2).node(), 3);
        assert_eq!(s.event(2).at_us(), 500.0);
        assert!(MembershipSchedule::none().is_empty());
    }
}
