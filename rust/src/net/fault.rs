//! Fault injection (paper §2.3.3 / §4.4 / Fig. 8).
//!
//! Models the paper's observed failure modes — thermal NIC power-off,
//! protocol-induced connection failures — as rail-down windows on the
//! virtual clock. The Exception Handler (coordinator/control) detects a
//! failed rail through transfer errors/heartbeat timeout and migrates its
//! (ptr, len) work to the surviving optimal rail within the 200 ms budget.
//!
//! Beyond crash-stop [`FaultWindow`]s, [`DegradeWindow`]s model *gray*
//! failures — the dominant production mode on the paper's aging
//! Ethernet/IB fabrics: lossy links that retransmit, bandwidth brownouts,
//! flapping NICs and time-varying stragglers. These never announce
//! themselves: the fabric charges their cost into modeled time and the
//! `HealthMonitor` (coordinator/control/health) has to *detect* them from
//! residuals and retry counts.

use crate::util::error::Error;

/// One rail-down window in virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultWindow {
    pub rail: usize,
    pub start_us: f64,
    pub end_us: f64,
}

/// Schedule of injected faults.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    windows: Vec<FaultWindow>,
}

impl FaultSchedule {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with(mut self, rail: usize, start_us: f64, end_us: f64) -> Self {
        assert!(end_us > start_us);
        self.windows.push(FaultWindow { rail, start_us, end_us });
        self
    }

    /// Fig. 8's scenario: NIC 2 (rail 1) disconnected during minutes 1–2
    /// and 4–5 of a 6-minute run.
    pub fn fig8() -> Self {
        const MIN: f64 = 60.0 * 1e6;
        FaultSchedule::none()
            .with(1, 1.0 * MIN, 2.0 * MIN)
            .with(1, 4.0 * MIN, 5.0 * MIN)
    }

    /// Is `rail` down at virtual time `t_us`?
    pub fn is_down(&self, rail: usize, t_us: f64) -> bool {
        self.windows
            .iter()
            .any(|w| w.rail == rail && t_us >= w.start_us && t_us < w.end_us)
    }

    /// Next instant strictly after `t_us` at which [`FaultSchedule::is_down`]
    /// for `rail` actually flips (used by recovery probing).
    ///
    /// Windows may overlap or touch (`[0,100)` + `[50,150)`, `[0,100)` +
    /// `[100,200)`): interior edges inside the union of down-time are not
    /// transitions, so the walk skips every edge at which the rail's state
    /// equals its state at `t_us` and returns the first edge where it
    /// differs. `None` when the state never changes again.
    pub fn next_transition(&self, rail: usize, t_us: f64) -> Option<f64> {
        let state = self.is_down(rail, t_us);
        let mut t = t_us;
        loop {
            let edge = self
                .windows
                .iter()
                .filter(|w| w.rail == rail)
                .flat_map(|w| [w.start_us, w.end_us])
                .filter(|&e| e > t)
                .min_by(|a, b| a.partial_cmp(b).unwrap())?;
            if self.is_down(rail, edge) != state {
                return Some(edge);
            }
            t = edge;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }
}

/// What a [`DegradeWindow`] does to its rail while active.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DegradeKind {
    /// Per-message packet-loss probability in `[0, 1)`: every lost
    /// attempt is recharged as a retransmit with exponential backoff.
    Loss { rate: f64 },
    /// Bandwidth brownout: wire throughput multiplied by `factor` in
    /// `(0, 1]`, composed with rail shares like `set_rail_share` —
    /// invisible to the static cost model.
    Brownout { factor: f64 },
    /// Flapping NIC: alternates up/down half-periods of `period_us`,
    /// starting up at the window's start. Down phases behave like a
    /// crash-stop fault (transfer errors → §4.4 failover).
    Flap { period_us: f64 },
    /// Time-varying straggler: per-message stall of `stall_us`
    /// (log-normal jitter of `sigma` when > 0), the windowed form of
    /// `Fabric::inject_straggler`.
    Stall { stall_us: f64, sigma: f64 },
}

/// One gray-degradation window in virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradeWindow {
    pub rail: usize,
    pub start_us: f64,
    pub end_us: f64,
    pub kind: DegradeKind,
}

impl DegradeWindow {
    fn active(&self, rail: usize, t_us: f64) -> bool {
        self.rail == rail && t_us >= self.start_us && t_us < self.end_us
    }
}

/// Schedule of gray-failure degradations, queried by the fabric at the
/// (frozen, per-op) virtual clock. Overlapping windows compose: loss
/// rates combine as independent drops, brownout factors multiply, any
/// active down half-period of a flap wins.
#[derive(Debug, Clone, Default)]
pub struct DegradeSchedule {
    windows: Vec<DegradeWindow>,
}

impl DegradeSchedule {
    pub fn none() -> Self {
        Self::default()
    }

    /// Add a packet-loss window (builder form).
    pub fn loss(mut self, rail: usize, start_us: f64, end_us: f64, rate: f64) -> Self {
        assert!((0.0..1.0).contains(&rate), "loss rate must be in [0,1)");
        self.push(rail, start_us, end_us, DegradeKind::Loss { rate });
        self
    }

    /// Add a bandwidth-brownout window (builder form).
    pub fn brownout(mut self, rail: usize, start_us: f64, end_us: f64, factor: f64) -> Self {
        assert!(factor > 0.0 && factor <= 1.0, "brownout factor must be in (0,1]");
        self.push(rail, start_us, end_us, DegradeKind::Brownout { factor });
        self
    }

    /// Add a flapping-NIC window (builder form).
    pub fn flap(mut self, rail: usize, start_us: f64, end_us: f64, period_us: f64) -> Self {
        assert!(period_us > 0.0, "flap period must be positive");
        self.push(rail, start_us, end_us, DegradeKind::Flap { period_us });
        self
    }

    /// Add a time-varying straggler window (builder form).
    pub fn stall(
        mut self,
        rail: usize,
        start_us: f64,
        end_us: f64,
        stall_us: f64,
        sigma: f64,
    ) -> Self {
        assert!(stall_us >= 0.0 && sigma >= 0.0);
        self.push(rail, start_us, end_us, DegradeKind::Stall { stall_us, sigma });
        self
    }

    fn push(&mut self, rail: usize, start_us: f64, end_us: f64, kind: DegradeKind) {
        assert!(end_us > start_us, "degrade window must be non-empty");
        self.windows.push(DegradeWindow { rail, start_us, end_us, kind });
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    pub fn windows(&self) -> &[DegradeWindow] {
        &self.windows
    }

    /// Effective packet-loss probability on `rail` at `t_us` — overlapping
    /// loss windows drop independently: `1 - Π(1 - rate)`.
    pub fn loss_at(&self, rail: usize, t_us: f64) -> f64 {
        let mut keep = 1.0;
        for w in &self.windows {
            if let DegradeKind::Loss { rate } = w.kind {
                if w.active(rail, t_us) {
                    keep *= 1.0 - rate;
                }
            }
        }
        1.0 - keep
    }

    /// Effective brownout bandwidth multiplier on `rail` at `t_us`
    /// (product of active factors, floored so modeled time stays finite).
    pub fn brownout_at(&self, rail: usize, t_us: f64) -> f64 {
        let mut f = 1.0;
        for w in &self.windows {
            if let DegradeKind::Brownout { factor } = w.kind {
                if w.active(rail, t_us) {
                    f *= factor;
                }
            }
        }
        f.max(0.01)
    }

    /// Is `rail` inside the down half-period of an active flap at `t_us`?
    /// Pure function of the clock: the first half-period after a flap
    /// window opens is up, the second down, alternating.
    pub fn flap_down(&self, rail: usize, t_us: f64) -> bool {
        self.windows.iter().any(|w| {
            if let DegradeKind::Flap { period_us } = w.kind {
                w.active(rail, t_us)
                    && (((t_us - w.start_us) / period_us).floor() as u64) % 2 == 1
            } else {
                false
            }
        })
    }

    /// Sum of deterministic (sigma == 0) stall windows active on `rail`.
    pub fn stall_det_us(&self, rail: usize, t_us: f64) -> f64 {
        self.windows
            .iter()
            .filter(|w| w.active(rail, t_us))
            .filter_map(|w| match w.kind {
                DegradeKind::Stall { stall_us, sigma } if sigma == 0.0 => Some(stall_us),
                _ => None,
            })
            .sum()
    }

    /// The stochastic (sigma > 0) stall windows active on `rail` — each
    /// contributes `stall_us * lognormal(sigma)` per message, drawn from
    /// the rail's own stream.
    pub fn stall_stoch_at(
        &self,
        rail: usize,
        t_us: f64,
    ) -> impl Iterator<Item = (f64, f64)> + '_ {
        self.windows
            .iter()
            .filter(move |w| w.active(rail, t_us))
            .filter_map(|w| match w.kind {
                DegradeKind::Stall { stall_us, sigma } if sigma > 0.0 => Some((stall_us, sigma)),
                _ => None,
            })
    }

    /// Any window (of any kind) active on `rail` at `t_us`?
    pub fn active_on(&self, rail: usize, t_us: f64) -> bool {
        self.windows.iter().any(|w| w.active(rail, t_us))
    }
}

/// What a [`CorruptWindow`] does to messages on its rail while active.
///
/// Every kind is a *silent* correctness fault: the message arrives on
/// time (no latency signal, no retry signal of its own) but carries wrong
/// payload. In the simulation all kinds manifest as per-message
/// corruption events sampled at `prob` on the rail's deterministic
/// stream; the kinds exist so campaigns can mix hazard flavors and the
/// spec layer can audit them precisely. With integrity verification ON
/// the wire checksum catches the event and charges a retransmit; OFF,
/// the poisoned payload reaches the reduction (the measurable escape).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CorruptKind {
    /// Random single-bit flip in the payload, probability per message.
    BitFlip { prob: f64 },
    /// Payload duplication (a stale segment replayed over a fresh one).
    Duplicate { prob: f64 },
    /// Payload truncation (tail of the message dropped, junk merged).
    Truncate { prob: f64 },
    /// Stuck-at corruption (a lane wedged at a constant value).
    StuckAt { prob: f64 },
}

impl CorruptKind {
    /// Per-message corruption probability of this kind.
    pub fn prob(&self) -> f64 {
        match *self {
            CorruptKind::BitFlip { prob }
            | CorruptKind::Duplicate { prob }
            | CorruptKind::Truncate { prob }
            | CorruptKind::StuckAt { prob } => prob,
        }
    }
}

/// One silent-corruption window in virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CorruptWindow {
    pub rail: usize,
    pub start_us: f64,
    pub end_us: f64,
    pub kind: CorruptKind,
}

impl CorruptWindow {
    fn active(&self, rail: usize, t_us: f64) -> bool {
        self.rail == rail && t_us >= self.start_us && t_us < self.end_us
    }
}

/// Schedule of silent-corruption windows, queried by the fabric at the
/// (frozen, per-op) virtual clock exactly like [`DegradeSchedule`].
/// Overlapping windows compose as independent corruption sources:
/// `1 - Π(1 - prob)`.
#[derive(Debug, Clone, Default)]
pub struct CorruptSchedule {
    windows: Vec<CorruptWindow>,
}

impl CorruptSchedule {
    pub fn none() -> Self {
        Self::default()
    }

    /// Add a bit-flip window (builder form).
    pub fn flip(mut self, rail: usize, start_us: f64, end_us: f64, prob: f64) -> Self {
        self.push(rail, start_us, end_us, CorruptKind::BitFlip { prob });
        self
    }

    /// Add a payload-duplication window (builder form).
    pub fn dup(mut self, rail: usize, start_us: f64, end_us: f64, prob: f64) -> Self {
        self.push(rail, start_us, end_us, CorruptKind::Duplicate { prob });
        self
    }

    /// Add a payload-truncation window (builder form).
    pub fn trunc(mut self, rail: usize, start_us: f64, end_us: f64, prob: f64) -> Self {
        self.push(rail, start_us, end_us, CorruptKind::Truncate { prob });
        self
    }

    /// Add a stuck-at window (builder form).
    pub fn stuck(mut self, rail: usize, start_us: f64, end_us: f64, prob: f64) -> Self {
        self.push(rail, start_us, end_us, CorruptKind::StuckAt { prob });
        self
    }

    fn push(&mut self, rail: usize, start_us: f64, end_us: f64, kind: CorruptKind) {
        assert!(end_us > start_us, "corrupt window must be non-empty");
        assert!(
            (0.0..1.0).contains(&kind.prob()),
            "corruption probability must be in [0,1)"
        );
        self.windows.push(CorruptWindow { rail, start_us, end_us, kind });
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    pub fn windows(&self) -> &[CorruptWindow] {
        &self.windows
    }

    /// Effective per-message corruption probability on `rail` at `t_us` —
    /// overlapping windows corrupt independently: `1 - Π(1 - prob)`.
    pub fn corrupt_at(&self, rail: usize, t_us: f64) -> f64 {
        let mut keep = 1.0;
        for w in &self.windows {
            if w.active(rail, t_us) {
                keep *= 1.0 - w.kind.prob();
            }
        }
        1.0 - keep
    }

    /// Any corruption window active on `rail` at `t_us`?
    pub fn active_on(&self, rail: usize, t_us: f64) -> bool {
        self.windows.iter().any(|w| w.active(rail, t_us))
    }
}

/// Parse a duration with `us`/`ms`/`s`/`min` suffix (plain numbers are
/// microseconds): `"150ms"` → `150_000.0`.
pub fn parse_duration_us(s: &str) -> crate::Result<f64> {
    let s = s.trim();
    let (num, mult) = if let Some(p) = s.strip_suffix("us") {
        (p, 1.0)
    } else if let Some(p) = s.strip_suffix("ms") {
        (p, 1e3)
    } else if let Some(p) = s.strip_suffix("min") {
        (p, 60.0 * 1e6)
    } else if let Some(p) = s.strip_suffix('s') {
        (p, 1e6)
    } else {
        (s, 1.0)
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| Error::Config(format!("bad duration '{s}'")))?;
    if !v.is_finite() || v < 0.0 {
        return Err(Error::Config(format!("duration '{s}' must be finite and >= 0")));
    }
    Ok(v * mult)
}

fn parse_span(span: &str, spec: &str) -> crate::Result<(f64, f64)> {
    let (a, b) = span
        .split_once('-')
        .ok_or_else(|| Error::Config(format!("'{spec}': window must be start-end")))?;
    let (start, end) = (parse_duration_us(a)?, parse_duration_us(b)?);
    if end <= start {
        return Err(Error::Config(format!("'{spec}': window end must be after start")));
    }
    Ok((start, end))
}

fn parse_rail(s: &str, spec: &str) -> crate::Result<usize> {
    s.trim()
        .parse()
        .map_err(|_| Error::Config(format!("'{spec}': bad rail index '{s}'")))
}

/// Parse a crash-stop fault spec string (the `faults=` config key):
/// `"rail@start-end[;...]"`, e.g. `"1@100ms-200ms;0@2s-3s"`. Also accepts
/// `"fig8"` (the paper's Fig. 8 scenario) and `"none"`/`""`.
pub fn parse_faults(spec: &str) -> crate::Result<FaultSchedule> {
    let spec = spec.trim();
    match spec {
        "" | "none" => return Ok(FaultSchedule::none()),
        "fig8" => return Ok(FaultSchedule::fig8()),
        _ => {}
    }
    let mut out = FaultSchedule::none();
    for part in spec.split(';').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        let (rail, span) = part
            .split_once('@')
            .ok_or_else(|| Error::Config(format!("'{part}': fault must be rail@start-end")))?;
        let rail = parse_rail(rail, part)?;
        let (start, end) = parse_span(span, part)?;
        let wdw = FaultWindow { rail, start_us: start, end_us: end };
        // a repeated identical term is almost always a copy-paste slip in
        // a long spec; silently accepting it would double nothing here but
        // would silently last-win in keyed stores — reject it precisely
        if out.windows.contains(&wdw) {
            return Err(Error::Config(format!(
                "'{part}': duplicate fault window for rail {rail} (identical rail and span \
                 already declared earlier in the spec)"
            )));
        }
        out = out.with(rail, start, end);
    }
    Ok(out)
}

/// Parse a gray-degradation spec string (the `degrade=` config key):
/// `kind:rail:params@start-end` terms joined by `;`, where kind is one of
/// - `loss:RAIL:RATE` — packet-loss probability,
/// - `brownout:RAIL:FACTOR` — bandwidth multiplier,
/// - `flap:RAIL:PERIOD` — up/down half-period (duration),
/// - `stall:RAIL:STALL[:SIGMA]` — per-message straggler stall (duration).
///
/// Example: `"loss:1:0.05@100ms-300ms;brownout:0:0.5@1s-2s"`.
pub fn parse_degrade(spec: &str) -> crate::Result<DegradeSchedule> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "none" {
        return Ok(DegradeSchedule::none());
    }
    let mut out = DegradeSchedule::none();
    for part in spec.split(';').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        let (head, span) = part
            .split_once('@')
            .ok_or_else(|| Error::Config(format!("'{part}': degrade must be kind:rail:params@start-end")))?;
        let (start, end) = parse_span(span, part)?;
        let fields: Vec<&str> = head.split(':').map(str::trim).collect();
        let bad = |what: &str| Error::Config(format!("'{part}': {what}"));
        let (rail, kind) = match fields.as_slice() {
            ["loss", rail, rate] => {
                let rail = parse_rail(rail, part)?;
                let rate: f64 = rate.parse().map_err(|_| bad("bad loss rate"))?;
                if !(0.0..1.0).contains(&rate) {
                    return Err(bad("loss rate must be in [0,1)"));
                }
                (rail, DegradeKind::Loss { rate })
            }
            ["brownout", rail, factor] => {
                let rail = parse_rail(rail, part)?;
                let factor: f64 = factor.parse().map_err(|_| bad("bad brownout factor"))?;
                if !(factor > 0.0 && factor <= 1.0) {
                    return Err(bad("brownout factor must be in (0,1]"));
                }
                (rail, DegradeKind::Brownout { factor })
            }
            ["flap", rail, period] => {
                let rail = parse_rail(rail, part)?;
                let period = parse_duration_us(period)?;
                if period <= 0.0 {
                    return Err(bad("flap period must be positive"));
                }
                (rail, DegradeKind::Flap { period_us: period })
            }
            ["stall", rail, stall] => {
                let rail = parse_rail(rail, part)?;
                (rail, DegradeKind::Stall { stall_us: parse_duration_us(stall)?, sigma: 0.0 })
            }
            ["stall", rail, stall, sigma] => {
                let rail = parse_rail(rail, part)?;
                let sigma: f64 = sigma.parse().map_err(|_| bad("bad stall sigma"))?;
                if sigma < 0.0 {
                    return Err(bad("stall sigma must be >= 0"));
                }
                (rail, DegradeKind::Stall { stall_us: parse_duration_us(stall)?, sigma })
            }
            _ => return Err(bad("unknown degrade kind (loss/brownout/flap/stall)")),
        };
        let wdw = DegradeWindow { rail, start_us: start, end_us: end, kind };
        // overlapping DISTINCT windows compose by design; an identical
        // repeated term is a spec slip — the compose rules would silently
        // square its effect (loss/brownout) instead of last-winning
        if out.windows.contains(&wdw) {
            return Err(Error::Config(format!(
                "'{part}': duplicate degrade term for rail {rail} (identical kind, params \
                 and span already declared earlier in the spec)"
            )));
        }
        out.windows.push(wdw);
    }
    Ok(out)
}

/// Parse a silent-corruption spec string (the `corrupt=` config key):
/// `kind:rail:prob@start-end` terms joined by `;`, where kind is one of
/// - `flip:RAIL:PROB` — per-message single-bit-flip probability,
/// - `dup:RAIL:PROB` — payload duplication (stale replay),
/// - `trunc:RAIL:PROB` — payload truncation,
/// - `stuck:RAIL:PROB` — stuck-at lane corruption.
///
/// Example: `"flip:1:0.05@100ms-300ms;stuck:2:0.2@1s-2s"`.
pub fn parse_corrupt(spec: &str) -> crate::Result<CorruptSchedule> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "none" {
        return Ok(CorruptSchedule::none());
    }
    let mut out = CorruptSchedule::none();
    for part in spec.split(';').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        let (head, span) = part.split_once('@').ok_or_else(|| {
            Error::Config(format!("'{part}': corrupt must be kind:rail:prob@start-end"))
        })?;
        let (start, end) = parse_span(span, part)?;
        let fields: Vec<&str> = head.split(':').map(str::trim).collect();
        let bad = |what: &str| Error::Config(format!("'{part}': {what}"));
        let (rail, kind) = match fields.as_slice() {
            [kind @ ("flip" | "dup" | "trunc" | "stuck"), rail, prob] => {
                let rail = parse_rail(rail, part)?;
                let prob: f64 = prob.parse().map_err(|_| bad("bad corruption probability"))?;
                if !(0.0..1.0).contains(&prob) {
                    return Err(bad("corruption probability must be in [0,1)"));
                }
                let kind = match *kind {
                    "flip" => CorruptKind::BitFlip { prob },
                    "dup" => CorruptKind::Duplicate { prob },
                    "trunc" => CorruptKind::Truncate { prob },
                    _ => CorruptKind::StuckAt { prob },
                };
                (rail, kind)
            }
            _ => return Err(bad("unknown corrupt kind (flip/dup/trunc/stuck)")),
        };
        let wdw = CorruptWindow { rail, start_us: start, end_us: end, kind };
        if out.windows.contains(&wdw) {
            return Err(Error::Config(format!(
                "'{part}': duplicate corrupt term for rail {rail} (identical kind, prob \
                 and span already declared earlier in the spec)"
            )));
        }
        out.windows.push(wdw);
    }
    Ok(out)
}

/// One node-level membership change on the virtual clock — the elastic
/// counterpart of a rail-down [`FaultWindow`]. Node ids always refer to
/// the configured (full) cluster numbering; the coordinator compacts the
/// surviving set itself.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MembershipEvent {
    /// `node` departs (crash, drain, thermal power-off) at `at_us`.
    Leave { node: usize, at_us: f64 },
    /// `node` comes back at `at_us` (must have departed earlier).
    Join { node: usize, at_us: f64 },
}

impl MembershipEvent {
    pub fn at_us(&self) -> f64 {
        match *self {
            MembershipEvent::Leave { at_us, .. } | MembershipEvent::Join { at_us, .. } => at_us,
        }
    }

    pub fn node(&self) -> usize {
        match *self {
            MembershipEvent::Leave { node, .. } | MembershipEvent::Join { node, .. } => node,
        }
    }
}

/// Schedule of node join/leave churn, kept sorted by event time. The
/// coordinator polls it at op boundaries: an event landing mid-op is
/// detected — like a rail fault — when the op completes and the next one
/// begins.
#[derive(Debug, Clone, Default)]
pub struct MembershipSchedule {
    events: Vec<MembershipEvent>,
}

impl MembershipSchedule {
    pub fn none() -> Self {
        Self::default()
    }

    /// Add a leave event (builder form).
    pub fn leave(mut self, node: usize, at_us: f64) -> Self {
        self.push(MembershipEvent::Leave { node, at_us });
        self
    }

    /// Add a join event (builder form).
    pub fn join(mut self, node: usize, at_us: f64) -> Self {
        self.push(MembershipEvent::Join { node, at_us });
        self
    }

    fn push(&mut self, ev: MembershipEvent) {
        assert!(ev.at_us().is_finite() && ev.at_us() >= 0.0);
        self.events.push(ev);
        // stable by insertion order at equal times
        self.events
            .sort_by(|a, b| a.at_us().partial_cmp(&b.at_us()).unwrap());
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The `i`-th event in time order.
    pub fn event(&self, i: usize) -> MembershipEvent {
        self.events[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_membership() {
        let f = FaultSchedule::none().with(1, 100.0, 200.0);
        assert!(!f.is_down(1, 99.0));
        assert!(f.is_down(1, 100.0));
        assert!(f.is_down(1, 199.9));
        assert!(!f.is_down(1, 200.0));
        assert!(!f.is_down(0, 150.0));
    }

    #[test]
    fn fig8_shape() {
        let f = FaultSchedule::fig8();
        let min = 60.0 * 1e6;
        assert!(f.is_down(1, 1.5 * min));
        assert!(!f.is_down(1, 3.0 * min));
        assert!(f.is_down(1, 4.5 * min));
        assert!(!f.is_down(0, 4.5 * min));
    }

    #[test]
    fn transitions() {
        let f = FaultSchedule::none().with(0, 10.0, 20.0);
        assert_eq!(f.next_transition(0, 0.0), Some(10.0));
        assert_eq!(f.next_transition(0, 10.0), Some(20.0));
        assert_eq!(f.next_transition(0, 20.0), None);
    }

    #[test]
    fn transitions_skip_interior_edges_of_overlapping_windows() {
        // [0,100) + [50,150): down over the whole union [0,150). The edge
        // at 100 is inside the union — the rail is still down there, so it
        // must NOT be reported as a transition (regression: it used to be).
        let f = FaultSchedule::none().with(0, 0.0, 100.0).with(0, 50.0, 150.0);
        assert!(f.is_down(0, 100.0), "still down at the interior edge");
        assert_eq!(f.next_transition(0, 40.0), Some(150.0));
        assert_eq!(f.next_transition(0, 100.0), Some(150.0));
        // from healthy time before the union: first flip is the union start
        let g = FaultSchedule::none().with(0, 10.0, 100.0).with(0, 50.0, 150.0);
        assert_eq!(g.next_transition(0, 0.0), Some(10.0));
        assert_eq!(g.next_transition(0, 10.0), Some(150.0));
    }

    #[test]
    fn transitions_merge_adjacent_windows() {
        // [0,100) + [100,200) form one continuous down span: the shared
        // edge at 100 flips nothing (is_down(100) is true via window 2).
        let f = FaultSchedule::none().with(1, 0.0, 100.0).with(1, 100.0, 200.0);
        assert!(f.is_down(1, 100.0));
        assert_eq!(f.next_transition(1, 0.0), Some(200.0));
        assert_eq!(f.next_transition(1, 100.0), Some(200.0));
        assert_eq!(f.next_transition(1, 200.0), None);
        // other rails are untouched by rail 1's windows
        assert_eq!(f.next_transition(0, 0.0), None);
    }

    #[test]
    fn transitions_with_disjoint_windows_report_each_flip() {
        let f = FaultSchedule::none().with(0, 10.0, 20.0).with(0, 40.0, 50.0);
        assert_eq!(f.next_transition(0, 0.0), Some(10.0));
        assert_eq!(f.next_transition(0, 15.0), Some(20.0));
        assert_eq!(f.next_transition(0, 20.0), Some(40.0));
        assert_eq!(f.next_transition(0, 45.0), Some(50.0));
        assert_eq!(f.next_transition(0, 50.0), None);
    }

    #[test]
    fn degrade_windows_compose_and_expire() {
        let d = DegradeSchedule::none()
            .loss(1, 100.0, 200.0, 0.1)
            .loss(1, 150.0, 250.0, 0.5)
            .brownout(0, 0.0, 100.0, 0.5)
            .brownout(0, 50.0, 100.0, 0.4);
        assert_eq!(d.loss_at(1, 99.0), 0.0);
        assert!((d.loss_at(1, 120.0) - 0.1).abs() < 1e-12);
        // overlapping losses drop independently: 1 - 0.9*0.5
        assert!((d.loss_at(1, 180.0) - 0.55).abs() < 1e-12);
        assert!((d.loss_at(1, 220.0) - 0.5).abs() < 1e-12);
        assert_eq!(d.loss_at(1, 250.0), 0.0);
        assert_eq!(d.loss_at(0, 180.0), 0.0);
        // brownout factors multiply inside the overlap
        assert!((d.brownout_at(0, 25.0) - 0.5).abs() < 1e-12);
        assert!((d.brownout_at(0, 75.0) - 0.2).abs() < 1e-12);
        assert_eq!(d.brownout_at(0, 100.0), 1.0);
        assert!(d.active_on(0, 25.0) && !d.active_on(0, 100.0));
    }

    #[test]
    fn flap_alternates_half_periods() {
        let d = DegradeSchedule::none().flap(2, 1000.0, 5000.0, 500.0);
        // up for the first half-period, down for the second, alternating
        assert!(!d.flap_down(2, 999.0), "outside the window");
        assert!(!d.flap_down(2, 1000.0));
        assert!(!d.flap_down(2, 1499.0));
        assert!(d.flap_down(2, 1500.0));
        assert!(d.flap_down(2, 1999.0));
        assert!(!d.flap_down(2, 2000.0));
        assert!(d.flap_down(2, 2600.0));
        assert!(!d.flap_down(2, 5000.0), "window over");
        assert!(!d.flap_down(1, 1500.0), "other rails untouched");
    }

    #[test]
    fn stall_windows_split_det_and_stoch() {
        let d = DegradeSchedule::none()
            .stall(0, 0.0, 100.0, 500.0, 0.0)
            .stall(0, 50.0, 150.0, 200.0, 0.0)
            .stall(0, 0.0, 100.0, 300.0, 0.4);
        assert_eq!(d.stall_det_us(0, 25.0), 500.0);
        assert_eq!(d.stall_det_us(0, 75.0), 700.0);
        assert_eq!(d.stall_det_us(0, 120.0), 200.0);
        assert_eq!(d.stall_det_us(0, 150.0), 0.0);
        let stoch: Vec<_> = d.stall_stoch_at(0, 25.0).collect();
        assert_eq!(stoch, vec![(300.0, 0.4)]);
        assert!(d.stall_stoch_at(0, 120.0).next().is_none());
    }

    #[test]
    fn duration_suffixes() {
        assert_eq!(parse_duration_us("150").unwrap(), 150.0);
        assert_eq!(parse_duration_us("150us").unwrap(), 150.0);
        assert_eq!(parse_duration_us("1.5ms").unwrap(), 1500.0);
        assert_eq!(parse_duration_us("2s").unwrap(), 2e6);
        assert_eq!(parse_duration_us("1min").unwrap(), 60e6);
        assert!(parse_duration_us("abc").is_err());
        assert!(parse_duration_us("-5ms").is_err());
    }

    #[test]
    fn fault_spec_round_trip() {
        let f = parse_faults("1@100ms-200ms; 0@2s-3s").unwrap();
        assert!(f.is_down(1, 150_000.0));
        assert!(!f.is_down(1, 250_000.0));
        assert!(f.is_down(0, 2.5e6));
        assert!(parse_faults("none").unwrap().is_empty());
        assert!(parse_faults("").unwrap().is_empty());
        assert!(parse_faults("fig8").unwrap().is_down(1, 90e6));
        assert!(parse_faults("1@200ms-100ms").is_err(), "inverted window");
        assert!(parse_faults("x@1-2").is_err(), "bad rail");
        assert!(parse_faults("1:100-200").is_err(), "missing @");
        // identical repeated terms are rejected, overlap of distinct ones is fine
        assert!(parse_faults("1@100ms-200ms;1@100ms-200ms").is_err(), "duplicate term");
        assert!(parse_faults("1@100ms-200ms;1@150ms-250ms").is_ok(), "overlap is legal");
        assert!(parse_faults("1@100ms-200ms;0@100ms-200ms").is_ok(), "other rail is legal");
    }

    #[test]
    fn degrade_spec_round_trip() {
        let d = parse_degrade(
            "loss:1:0.05@100ms-300ms;brownout:0:0.5@1s-2s;flap:1:50ms@3s-5s;stall:0:500us:0.3@1s-2s",
        )
        .unwrap();
        assert!((d.loss_at(1, 200_000.0) - 0.05).abs() < 1e-12);
        assert!((d.brownout_at(0, 1.5e6) - 0.5).abs() < 1e-12);
        assert!(d.flap_down(1, 3.05e6 + 25_000.0));
        assert_eq!(d.stall_stoch_at(0, 1.5e6).collect::<Vec<_>>(), vec![(500.0, 0.3)]);
        assert!(parse_degrade("none").unwrap().is_empty());
        assert!(parse_degrade("loss:1:1.5@0-1").is_err(), "rate out of range");
        assert!(parse_degrade("brownout:0:0@0-1").is_err(), "zero factor");
        assert!(parse_degrade("fade:0:0.5@0-1").is_err(), "unknown kind");
        assert!(parse_degrade("loss:1:0.1").is_err(), "missing window");
        // identical repeated terms are rejected, distinct overlaps compose
        assert!(parse_degrade("loss:1:0.05@0-1s;loss:1:0.05@0-1s").is_err(), "duplicate");
        assert!(parse_degrade("loss:1:0.05@0-1s;loss:1:0.1@0-1s").is_ok(), "distinct rate");
        assert!(parse_degrade("loss:1:0.05@0-1s;brownout:1:0.5@0-1s").is_ok(), "distinct kind");
    }

    #[test]
    fn corrupt_windows_compose_and_expire() {
        let c = CorruptSchedule::none()
            .flip(1, 100.0, 200.0, 0.1)
            .stuck(1, 150.0, 250.0, 0.5)
            .dup(0, 0.0, 100.0, 0.2);
        assert_eq!(c.corrupt_at(1, 99.0), 0.0);
        assert!((c.corrupt_at(1, 120.0) - 0.1).abs() < 1e-12);
        // overlapping windows corrupt independently: 1 - 0.9*0.5
        assert!((c.corrupt_at(1, 180.0) - 0.55).abs() < 1e-12);
        assert!((c.corrupt_at(1, 220.0) - 0.5).abs() < 1e-12);
        assert_eq!(c.corrupt_at(1, 250.0), 0.0);
        assert!((c.corrupt_at(0, 50.0) - 0.2).abs() < 1e-12);
        assert!(c.active_on(0, 50.0) && !c.active_on(0, 100.0));
        assert_eq!(c.windows().len(), 3);
        assert!(CorruptSchedule::none().is_empty());
    }

    #[test]
    fn corrupt_spec_round_trip() {
        let c = parse_corrupt(
            "flip:1:0.05@100ms-300ms;dup:0:0.2@1s-2s;trunc:2:0.1@0-1s;stuck:1:0.3@3s-4s",
        )
        .unwrap();
        assert!((c.corrupt_at(1, 200_000.0) - 0.05).abs() < 1e-12);
        assert!((c.corrupt_at(0, 1.5e6) - 0.2).abs() < 1e-12);
        assert!((c.corrupt_at(2, 500_000.0) - 0.1).abs() < 1e-12);
        assert!((c.corrupt_at(1, 3.5e6) - 0.3).abs() < 1e-12);
        assert_eq!(
            c.windows()[0].kind,
            CorruptKind::BitFlip { prob: 0.05 },
            "kinds survive the round trip"
        );
        assert!(parse_corrupt("none").unwrap().is_empty());
        assert!(parse_corrupt("").unwrap().is_empty());
        assert!(parse_corrupt("flip:1:1.5@0-1").is_err(), "prob out of range");
        assert!(parse_corrupt("smear:1:0.5@0-1").is_err(), "unknown kind");
        assert!(parse_corrupt("flip:1:0.1").is_err(), "missing window");
        assert!(parse_corrupt("flip:x:0.1@0-1").is_err(), "bad rail");
        assert!(parse_corrupt("flip:1:0.1@2s-1s").is_err(), "inverted window");
        // identical repeated terms are rejected, distinct overlaps compose
        assert!(parse_corrupt("flip:1:0.1@0-1s;flip:1:0.1@0-1s").is_err(), "duplicate");
        assert!(parse_corrupt("flip:1:0.1@0-1s;flip:1:0.2@0-1s").is_ok(), "distinct prob");
        assert!(parse_corrupt("flip:1:0.1@0-1s;stuck:1:0.1@0-1s").is_ok(), "distinct kind");
    }

    #[test]
    fn membership_schedule_sorts_and_exposes_events() {
        let s = MembershipSchedule::none()
            .join(3, 500.0)
            .leave(3, 100.0)
            .leave(1, 250.0);
        assert!(!s.is_empty());
        assert_eq!(s.len(), 3);
        // events come back in time order regardless of builder order
        assert_eq!(s.event(0), MembershipEvent::Leave { node: 3, at_us: 100.0 });
        assert_eq!(s.event(1), MembershipEvent::Leave { node: 1, at_us: 250.0 });
        assert_eq!(s.event(2), MembershipEvent::Join { node: 3, at_us: 500.0 });
        assert_eq!(s.event(2).node(), 3);
        assert_eq!(s.event(2).at_us(), 500.0);
        assert!(MembershipSchedule::none().is_empty());
    }
}
