//! Fault injection (paper §2.3.3 / §4.4 / Fig. 8).
//!
//! Models the paper's observed failure modes — thermal NIC power-off,
//! protocol-induced connection failures — as rail-down windows on the
//! virtual clock. The Exception Handler (coordinator/control) detects a
//! failed rail through transfer errors/heartbeat timeout and migrates its
//! (ptr, len) work to the surviving optimal rail within the 200 ms budget.

/// One rail-down window in virtual time.
#[derive(Debug, Clone, Copy)]
pub struct FaultWindow {
    pub rail: usize,
    pub start_us: f64,
    pub end_us: f64,
}

/// Schedule of injected faults.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    windows: Vec<FaultWindow>,
}

impl FaultSchedule {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with(mut self, rail: usize, start_us: f64, end_us: f64) -> Self {
        assert!(end_us > start_us);
        self.windows.push(FaultWindow { rail, start_us, end_us });
        self
    }

    /// Fig. 8's scenario: NIC 2 (rail 1) disconnected during minutes 1–2
    /// and 4–5 of a 6-minute run.
    pub fn fig8() -> Self {
        const MIN: f64 = 60.0 * 1e6;
        FaultSchedule::none()
            .with(1, 1.0 * MIN, 2.0 * MIN)
            .with(1, 4.0 * MIN, 5.0 * MIN)
    }

    /// Is `rail` down at virtual time `t_us`?
    pub fn is_down(&self, rail: usize, t_us: f64) -> bool {
        self.windows
            .iter()
            .any(|w| w.rail == rail && t_us >= w.start_us && t_us < w.end_us)
    }

    /// Next state-change time strictly after `t_us` for `rail` (used by
    /// recovery probing).
    pub fn next_transition(&self, rail: usize, t_us: f64) -> Option<f64> {
        self.windows
            .iter()
            .filter(|w| w.rail == rail)
            .flat_map(|w| [w.start_us, w.end_us])
            .filter(|&t| t > t_us)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_membership() {
        let f = FaultSchedule::none().with(1, 100.0, 200.0);
        assert!(!f.is_down(1, 99.0));
        assert!(f.is_down(1, 100.0));
        assert!(f.is_down(1, 199.9));
        assert!(!f.is_down(1, 200.0));
        assert!(!f.is_down(0, 150.0));
    }

    #[test]
    fn fig8_shape() {
        let f = FaultSchedule::fig8();
        let min = 60.0 * 1e6;
        assert!(f.is_down(1, 1.5 * min));
        assert!(!f.is_down(1, 3.0 * min));
        assert!(f.is_down(1, 4.5 * min));
        assert!(!f.is_down(0, 4.5 * min));
    }

    #[test]
    fn transitions() {
        let f = FaultSchedule::none().with(0, 10.0, 20.0);
        assert_eq!(f.next_transition(0, 0.0), Some(10.0));
        assert_eq!(f.next_transition(0, 10.0), Some(20.0));
        assert_eq!(f.next_transition(0, 20.0), None);
    }
}
