//! Simulated multi-rail network fabric.
//!
//! The paper's testbed (multi-NIC nodes with TCP / SHARP / GLEX planes) is
//! reproduced as a calibrated simulation: real gradient bytes move through
//! in-memory rails whose delivery *time* follows per-protocol latency and
//! bandwidth models fitted to the paper's own measurements (Fig. 2,
//! Table 1, Fig. 4). See DESIGN.md §1 for the substitution rationale.

pub mod cpu_pool;
pub mod fault;
pub mod protocol;
pub mod rail;
pub mod simnet;
pub mod topology;

pub use cpu_pool::CpuPool;
pub use fault::{FaultSchedule, FaultWindow};
pub use protocol::{CollectiveKind, ProtoKind, Protocol};
pub use rail::{NicSpec, Rail, RailHealth};
pub use simnet::Fabric;
pub use topology::{ClusterSpec, GroupShape, IntraLink, NodeSpec, TopoLevel, TopologyTree};
