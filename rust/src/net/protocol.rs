//! Per-protocol latency/bandwidth models (TCP, SHARP, GLEX).
//!
//! ## Calibration
//!
//! Fitted against the paper's own measurements on 4 nodes (Table 1,
//! averages over 10,000 allreduce ops) plus the qualitative curves of
//! Fig. 2:
//!
//! | data  | SHARP (us) | TCP (us) |
//! |-------|-----------|----------|
//! | 1 KB  | 9         | 982      |
//! | 8 MB  | 22 140    | 37 137   |
//! | 64 MB | 181 484   | 316 323  |
//!
//! TCP and GLEX run ring allreduce (2(N-1) point-to-point steps over S/N
//! segments); SHARP aggregates in-network (one up/down tree traversal), so
//! its completion time is nearly node-count independent. Back-solving the
//! per-message model `T(S) = T_setup + S / B_eff(S)` with
//! `B_eff(S) = B_peak / (1 + S/S_decline)` gives:
//!
//! * TCP:   T_setup = 160 us, B_peak = 353 MB/s, S_decline = 152 MB
//! * SHARP: T_setup = 9 us,   B_peak = 380 MB/s, S_decline = 2300 MB
//! * GLEX:  T_setup = 25 us,  B_peak = 600 MB/s, S_decline = 1600 MB
//!
//! (B_peak values are *allreduce-effective* CPU-bound bandwidths on the
//! paper's Xeon 6230R + 100 Gbps NICs, far below wire speed — exactly the
//! "legacy infrastructure" regime the paper targets.) GLEX's higher peak
//! and SHARP's tiny setup reproduce the paper's protocol ordering: SHARP
//! fastest below ~256 KB–1 MB, GLEX fastest for 1–64 MB, TCP always the
//! slow plane.

/// Which collective algorithm a protocol natively runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveKind {
    /// Point-to-point ring (TCP, GLEX).
    Ring,
    /// In-network aggregation tree (SHARP).
    Tree,
}

/// Protocol family tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ProtoKind {
    Tcp,
    Sharp,
    Glex,
}

impl ProtoKind {
    pub fn name(self) -> &'static str {
        match self {
            ProtoKind::Tcp => "TCP",
            ProtoKind::Sharp => "SHARP",
            ProtoKind::Glex => "GLEX",
        }
    }
}

impl std::fmt::Display for ProtoKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Calibrated protocol model. All times in microseconds, bandwidth in MB/s.
#[derive(Debug, Clone)]
pub struct Protocol {
    pub kind: ProtoKind,
    /// Fixed per-message startup latency (protocol processing + queuing).
    pub setup_us: f64,
    /// Peak effective bandwidth at reference core allocation (MB/s).
    pub peak_mbps: f64,
    /// Bandwidth decline constant (bytes): B_eff = peak / (1 + S/decline).
    pub decline_bytes: f64,
    /// Core-scaling curve (paper Fig. 4): multiplier in (0,1] given cores.
    pub core_curve: CoreCurve,
    pub collective: CollectiveKind,
    /// True for RDMA planes (affects the Control module's cold-start pick).
    pub rdma: bool,
}

/// Core-sensitivity of protocol throughput (paper Fig. 4 / §2.3.2).
#[derive(Debug, Clone, Copy)]
pub enum CoreCurve {
    /// Linear ramp saturating at `sat` cores (TCP: insensitive beyond 26).
    Saturating { sat: f64 },
    /// Power law up to `max` cores (GLEX/SHARP keep scaling; exponent < 1).
    Power { max: f64, exp: f64 },
}

impl CoreCurve {
    /// Throughput multiplier for `cores` allocated cores.
    pub fn multiplier(&self, cores: f64) -> f64 {
        match *self {
            CoreCurve::Saturating { sat } => (cores / sat).clamp(0.02, 1.0),
            CoreCurve::Power { max, exp } => (cores / max).clamp(0.005, 1.0).powf(exp),
        }
    }
}

impl Protocol {
    pub fn tcp() -> Protocol {
        Protocol {
            kind: ProtoKind::Tcp,
            setup_us: 160.0,
            peak_mbps: 353.0,
            decline_bytes: 152.0 * MB,
            core_curve: CoreCurve::Saturating { sat: 26.0 },
            collective: CollectiveKind::Ring,
            rdma: false,
        }
    }

    pub fn sharp() -> Protocol {
        Protocol {
            kind: ProtoKind::Sharp,
            setup_us: 6.3,
            peak_mbps: 380.0,
            decline_bytes: 2300.0 * MB,
            core_curve: CoreCurve::Power { max: 52.0, exp: 0.43 },
            collective: CollectiveKind::Tree,
            rdma: true,
        }
    }

    pub fn glex() -> Protocol {
        Protocol {
            kind: ProtoKind::Glex,
            setup_us: 25.0,
            peak_mbps: 600.0,
            decline_bytes: 1600.0 * MB,
            core_curve: CoreCurve::Power { max: 52.0, exp: 0.39 },
            collective: CollectiveKind::Ring,
            rdma: true,
        }
    }

    pub fn of(kind: ProtoKind) -> Protocol {
        match kind {
            ProtoKind::Tcp => Protocol::tcp(),
            ProtoKind::Sharp => Protocol::sharp(),
            ProtoKind::Glex => Protocol::glex(),
        }
    }

    /// Size-dependent effective bandwidth in MB/s at full reference cores.
    pub fn bw_eff_mbps(&self, bytes: f64) -> f64 {
        self.peak_mbps / (1.0 + bytes / self.decline_bytes)
    }

    /// Point-to-point message time (us) for `bytes`, given `cores` and a
    /// wire-bandwidth cap in MB/s (from the NIC, possibly shared between
    /// virtual channels).
    pub fn msg_time_us(&self, bytes: f64, cores: f64, wire_cap_mbps: f64) -> f64 {
        let bw = self
            .bw_eff_mbps(bytes)
            .min(wire_cap_mbps)
            .max(1e-9)
            * self.core_curve.multiplier(cores);
        self.setup_us + bytes / bw
    }

    /// Full allreduce completion time (us) on a single rail of this
    /// protocol for payload `bytes` over `n` nodes — the analytic model the
    /// Control module's Load Balancer uses for its initial guesses (the
    /// Timer then replaces it with live measurements).
    pub fn allreduce_time_us(&self, bytes: f64, n: usize, cores: f64, wire_cap_mbps: f64) -> f64 {
        match self.collective {
            CollectiveKind::Ring => {
                let steps = 2 * (n - 1);
                let seg = bytes / n as f64;
                steps as f64 * self.msg_time_us(seg, cores, wire_cap_mbps)
            }
            CollectiveKind::Tree => {
                // Switch aggregation: one up+down traversal, mild log(N)
                // growth in the setup component.
                let depth_factor = 1.0 + 0.2 * ((n as f64 / 4.0).log2().max(0.0));
                let bw = self.bw_eff_mbps(bytes).min(wire_cap_mbps).max(1e-9)
                    * self.core_curve.multiplier(cores);
                self.setup_us * depth_factor + bytes / bw
            }
        }
    }
}

pub const KB: f64 = 1024.0;
pub const MB: f64 = 1024.0 * 1024.0;

#[cfg(test)]
mod tests {
    use super::*;

    const FULL_CORES: f64 = 52.0;
    const WIRE_100G: f64 = 11500.0; // ~100 Gbps usable in MB/s

    fn ar(p: &Protocol, bytes: f64) -> f64 {
        p.allreduce_time_us(bytes, 4, FULL_CORES, WIRE_100G)
    }

    /// The model must land near the paper's Table 1 anchors (±25%).
    #[test]
    fn tcp_matches_table1() {
        let tcp = Protocol::tcp();
        for (bytes, expect) in [(KB, 982.0), (8.0 * MB, 37137.0), (64.0 * MB, 316323.0)] {
            let got = ar(&tcp, bytes);
            assert!(
                (got - expect).abs() / expect < 0.25,
                "TCP {bytes}B: got {got:.0} expect {expect}"
            );
        }
    }

    #[test]
    fn sharp_matches_table1() {
        let sharp = Protocol::sharp();
        for (bytes, expect) in [(KB, 9.0), (8.0 * MB, 22140.0), (64.0 * MB, 181484.0)] {
            let got = ar(&sharp, bytes);
            assert!(
                (got - expect).abs() / expect < 0.25,
                "SHARP {bytes}B: got {got:.0} expect {expect}"
            );
        }
    }

    /// Protocol ordering from Fig. 2: SHARP fastest for small messages,
    /// GLEX fastest in the 2–64 MB band, TCP slowest everywhere.
    #[test]
    fn protocol_ordering() {
        let (tcp, sharp, glex) = (Protocol::tcp(), Protocol::sharp(), Protocol::glex());
        for kb in [1.0, 32.0, 128.0] {
            let s = kb * KB;
            assert!(ar(&sharp, s) < ar(&glex, s), "{kb}KB");
            assert!(ar(&glex, s) < ar(&tcp, s), "{kb}KB");
        }
        for mb in [2.0, 8.0, 64.0] {
            let s = mb * MB;
            assert!(ar(&glex, s) < ar(&sharp, s), "{mb}MB glex vs sharp");
            assert!(ar(&glex, s) < ar(&tcp, s), "{mb}MB glex vs tcp");
        }
    }

    /// Fig. 4: TCP is core-insensitive beyond 26; GLEX/SHARP keep scaling.
    #[test]
    fn core_scaling_shapes() {
        let tcp = Protocol::tcp();
        assert_eq!(tcp.core_curve.multiplier(26.0), 1.0);
        assert_eq!(tcp.core_curve.multiplier(52.0), 1.0);
        assert!(tcp.core_curve.multiplier(13.0) < 0.6);

        let glex = Protocol::glex();
        let m26 = glex.core_curve.multiplier(26.0);
        let m52 = glex.core_curve.multiplier(52.0);
        assert!(m26 < m52 && m52 == 1.0);
        assert!(m26 > 0.5 && m26 < 0.9, "glex m(26)={m26}");
    }

    /// Tree collectives are nearly node-count independent; rings are not.
    #[test]
    fn tree_vs_ring_node_scaling() {
        let sharp = Protocol::sharp();
        let tcp = Protocol::tcp();
        let s = 8.0 * MB;
        let sharp_ratio = sharp.allreduce_time_us(s, 16, FULL_CORES, WIRE_100G)
            / sharp.allreduce_time_us(s, 4, FULL_CORES, WIRE_100G);
        let tcp_ratio = tcp.allreduce_time_us(s, 16, FULL_CORES, WIRE_100G)
            / tcp.allreduce_time_us(s, 4, FULL_CORES, WIRE_100G);
        // ring cost ~ 2(N-1)/N·S/B → 4→16 nodes is a ~1.25× factor plus
        // 5× the per-step setups; the tree only grows its setup term.
        assert!(sharp_ratio < 1.1, "sharp {sharp_ratio}");
        assert!(tcp_ratio > 1.25, "tcp {tcp_ratio}");
        assert!(sharp_ratio < tcp_ratio);
    }

    /// Wire cap binds on slow NICs (1 Gbps) but not on 100 Gbps.
    #[test]
    fn wire_cap() {
        let tcp = Protocol::tcp();
        let fast = tcp.msg_time_us(MB, 52.0, 11500.0);
        let slow = tcp.msg_time_us(MB, 52.0, 112.0); // 1 Gbps usable
        assert!(slow > 2.0 * fast);
    }

    #[test]
    fn bw_declines_with_size() {
        let tcp = Protocol::tcp();
        assert!(tcp.bw_eff_mbps(64.0 * MB) < tcp.bw_eff_mbps(MB));
    }
}
