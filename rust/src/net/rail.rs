//! Rails: a NIC + protocol instance forming one plane of the multi-rail
//! fabric, including virtual channels (several rails multiplexed onto one
//! physical NIC — paper §4.1 / Fig. 13).

use crate::net::protocol::{ProtoKind, Protocol};

/// Physical NIC description (paper Table 2).
#[derive(Debug, Clone)]
pub struct NicSpec {
    pub model: &'static str,
    /// Wire speed in Gbps.
    pub gbps: f64,
    pub rdma: bool,
}

impl NicSpec {
    pub const MCX623106AN: NicSpec = NicSpec { model: "MCX623106AN", gbps: 100.0, rdma: false };
    pub const CONNECTX5: NicSpec = NicSpec { model: "ConnectX-5", gbps: 100.0, rdma: true };
    pub const TH_NIC: NicSpec = NicSpec { model: "TH-NIC", gbps: 128.0, rdma: true };
    pub const BCM5720: NicSpec = NicSpec { model: "BCM5720", gbps: 1.0, rdma: false };
    pub const CONNECTX3: NicSpec = NicSpec { model: "ConnectX-3", gbps: 56.0, rdma: true };

    /// Usable wire bandwidth in MB/s (~92% of line rate after framing).
    pub fn usable_mbps(&self) -> f64 {
        self.gbps * 1000.0 / 8.0 * 0.92
    }

    /// A NIC throttled to `gbps` (the paper throttles 56 Gbps IB to 1 Gbps
    /// for the GPT-3 experiments).
    pub fn throttled(mut self, gbps: f64) -> NicSpec {
        self.gbps = gbps;
        self
    }
}

/// Health state of a rail — the gray-failure state machine driven by the
/// `HealthMonitor` (coordinator/control/health) and the §4.4 Exception
/// Handler. This unifies the old dead `Failed` vs `Deregistered` split
/// (`Failed` was set on transfer errors but never read by the exception
/// path, which keyed everything off `Deregistered`).
///
/// ```text
///  Healthy ⇄ Degraded          (suspicion hysteresis, soft-demoted share)
///     │         │
///     └────┬────┘
///          ▼
///    Quarantined  ⇄  Probation (canary traffic at reduced share)
///          ▲              │
///          └──────────────┘    (dirty canary → back, with dwell backoff)
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RailHealth {
    /// Full trust, full Load-Balancer share.
    Healthy,
    /// Suspicious but serviceable: soft-demoted share, still carrying
    /// payload (graceful degradation instead of binary failover).
    Degraded,
    /// Removed from service (crash failover or suspicion escalation);
    /// windows migrated via the §4.4 path.
    Quarantined,
    /// Readmission canary: carries reduced-share traffic; promoted to
    /// `Healthy` only after a clean streak, re-quarantined on any dirt.
    Probation,
}

impl RailHealth {
    /// May the rail carry traffic in this state? Degraded and Probation
    /// rails still serve (at reduced share); only Quarantined rails are
    /// out of the dataplane.
    pub fn usable(self) -> bool {
        self != RailHealth::Quarantined
    }

    /// Is `self -> to` a legal edge of the state machine?
    pub fn can_transition(self, to: RailHealth) -> bool {
        use RailHealth::*;
        matches!(
            (self, to),
            (Healthy, Degraded)
                | (Healthy, Quarantined)
                | (Degraded, Healthy)
                | (Degraded, Quarantined)
                | (Quarantined, Probation)
                | (Quarantined, Healthy) // legacy trust-on-readmit (HealthMode::Off)
                | (Probation, Healthy)
                | (Probation, Quarantined)
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            RailHealth::Healthy => "healthy",
            RailHealth::Degraded => "degraded",
            RailHealth::Quarantined => "quarantined",
            RailHealth::Probation => "probation",
        }
    }
}

/// One plane of the multi-rail network: a protocol bound to (a share of) a
/// physical NIC.
#[derive(Debug, Clone)]
pub struct Rail {
    pub id: usize,
    pub name: String,
    pub nic: NicSpec,
    pub protocol: Protocol,
    /// Number of virtual channels sharing the same physical NIC (1 = the
    /// rail owns the NIC). Wire bandwidth divides by this; protocol/CPU
    /// resources do not — which is exactly why virtual dual-rail TCP wins
    /// on fast NICs (Fig. 13).
    pub nic_sharing: usize,
    pub health: RailHealth,
}

impl Rail {
    pub fn new(id: usize, nic: NicSpec, kind: ProtoKind) -> Rail {
        Rail {
            id,
            name: format!("{}#{}", kind.name(), id),
            nic,
            protocol: Protocol::of(kind),
            nic_sharing: 1,
            health: RailHealth::Healthy,
        }
    }

    pub fn virtual_channel(mut self, id: usize, sharing: usize) -> Rail {
        self.id = id;
        self.nic_sharing = sharing.max(1);
        self.name = format!("{}#{}v", self.protocol.kind.name(), id);
        self
    }

    pub fn kind(&self) -> ProtoKind {
        self.protocol.kind
    }

    pub fn is_healthy(&self) -> bool {
        self.health == RailHealth::Healthy
    }

    /// May this rail carry traffic (anything but Quarantined)?
    pub fn is_usable(&self) -> bool {
        self.health.usable()
    }

    /// Apply a state-machine transition; returns `false` (and leaves the
    /// rail untouched) on an illegal edge, so callers can treat repeated
    /// quarantines/readmits as idempotent.
    pub fn transition(&mut self, to: RailHealth) -> bool {
        if self.health.can_transition(to) {
            self.health = to;
            true
        } else {
            false
        }
    }

    /// Wire cap available to this rail in MB/s.
    pub fn wire_cap_mbps(&self) -> f64 {
        self.nic.usable_mbps() / self.nic_sharing as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_caps() {
        let r = Rail::new(0, NicSpec::MCX623106AN, ProtoKind::Tcp);
        assert!((r.wire_cap_mbps() - 11500.0).abs() < 1.0);
        let v = r.clone().virtual_channel(1, 2);
        assert!((v.wire_cap_mbps() - 5750.0).abs() < 1.0);
    }

    #[test]
    fn one_gbps_is_tight() {
        let r = Rail::new(0, NicSpec::BCM5720, ProtoKind::Tcp);
        // 1 Gbps usable ≈ 115 MB/s — below TCP's CPU-bound 353 MB/s peak,
        // so the wire is the bottleneck (Fig. 13's 1 Gbps case).
        assert!(r.wire_cap_mbps() < r.protocol.peak_mbps);
    }

    #[test]
    fn health_transitions() {
        let mut r = Rail::new(0, NicSpec::CONNECTX5, ProtoKind::Sharp);
        assert!(r.is_healthy() && r.is_usable());
        // the full gray-failure round trip
        assert!(r.transition(RailHealth::Degraded));
        assert!(!r.is_healthy() && r.is_usable(), "degraded rails still serve");
        assert!(r.transition(RailHealth::Quarantined));
        assert!(!r.is_usable());
        assert!(r.transition(RailHealth::Probation));
        assert!(r.is_usable() && !r.is_healthy(), "canary carries traffic");
        assert!(r.transition(RailHealth::Quarantined), "dirty canary goes back");
        assert!(r.transition(RailHealth::Healthy), "legacy trust-on-readmit edge");
    }

    #[test]
    fn illegal_transitions_are_rejected() {
        let mut r = Rail::new(0, NicSpec::CONNECTX5, ProtoKind::Tcp);
        assert!(!r.transition(RailHealth::Probation), "healthy can't enter probation");
        assert!(!r.transition(RailHealth::Healthy), "self-transition is not an edge");
        assert_eq!(r.health, RailHealth::Healthy);
        r.health = RailHealth::Quarantined;
        assert!(!r.transition(RailHealth::Degraded), "quarantine exits via probation");
        assert!(!r.transition(RailHealth::Quarantined));
        assert_eq!(r.health, RailHealth::Quarantined);
        assert_eq!(r.health.name(), "quarantined");
    }
}
