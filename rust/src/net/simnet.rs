//! The fabric: virtual-clock multi-rail network simulation.
//!
//! Real payload bytes flow through the coordinator; the fabric supplies the
//! *time* each transfer takes, combining the calibrated protocol model,
//! NIC wire caps (incl. virtual-channel sharing), CPU-core allocation and
//! contention, per-message jitter, and the fault schedule.
//!
//! Collectives are executed in lockstep rounds (all nodes symmetric, as in
//! the paper's ring/tree algorithms): a step's duration is the max over
//! per-node sampled message times. This gives deterministic, fast policy
//! simulation while keeping the data path real.

use crate::net::cpu_pool::{CpuPool, Phase};
use crate::net::fault::FaultSchedule;
use crate::net::rail::{Rail, RailHealth};
use crate::util::rng::Pcg;

/// Error surfaced to the Exception Handler when a rail dies mid-transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RailDown(pub usize);

/// Persistent per-rail straggler: every message on the rail pays an extra
/// stall (paper §2.3.3's slow-NIC/incast pathologies). `sigma > 0` samples
/// the stall log-normally around `stall_us`; `sigma == 0` charges it
/// exactly (reproducible in `deterministic` mode). Deliberately invisible
/// to the analytic model paths (`transfer_det_us`,
/// `estimate_allreduce_us`) — stragglers are exactly the measured-vs-
/// predicted divergence the planner's `CorrectedCost` layer must learn.
#[derive(Debug, Clone, Copy)]
pub struct Straggler {
    pub rail: usize,
    pub stall_us: f64,
    pub sigma: f64,
}

/// Per-rail precomputed straggler stall state, maintained on
/// inject/clear: the deterministic (`sigma == 0`) component is pre-summed
/// and the stochastic entries are kept per rail, so the per-message path
/// is O(stragglers on this rail) — O(1) table reads for healthy rails —
/// instead of a linear scan over every injected straggler per message.
#[derive(Debug, Clone, Default)]
struct RailStall {
    /// Sum of sigma == 0 stalls (charged exactly).
    det_us: f64,
    /// `(stall_us, sigma)` entries with sigma > 0 (sampled per message).
    stoch: Vec<(f64, f64)>,
}

/// Multi-rail fabric across `nodes` symmetric nodes.
#[derive(Debug, Clone)]
pub struct Fabric {
    pub nodes: usize,
    pub rails: Vec<Rail>,
    pub cpu: CpuPool,
    pub faults: FaultSchedule,
    /// Injected per-rail stragglers (unmodeled per-message stalls) — the
    /// source of truth behind `stall_table`.
    stragglers: Vec<Straggler>,
    /// Per-rail precomputed stall state (see [`RailStall`]).
    stall_table: Vec<RailStall>,
    /// Virtual clock (us).
    clock_us: f64,
    /// Log-normal per-message jitter sigma (0 disables jitter).
    pub jitter_sigma: f64,
    rng: Pcg,
    /// Reusable per-round jitter multipliers (batched sampling scratch).
    jitter_buf: Vec<f64>,
}

impl Fabric {
    pub fn new(nodes: usize, rails: Vec<Rail>, mut cpu: CpuPool, seed: u64) -> Fabric {
        assert!(nodes >= 2, "need at least 2 nodes");
        for r in &rails {
            cpu.register(r.kind());
        }
        let n_rails = rails.len();
        Fabric {
            nodes,
            rails,
            cpu,
            faults: FaultSchedule::none(),
            stragglers: Vec::new(),
            stall_table: vec![RailStall::default(); n_rails],
            clock_us: 0.0,
            jitter_sigma: 0.03,
            rng: Pcg::new(seed),
            jitter_buf: Vec::new(),
        }
    }

    pub fn with_faults(mut self, faults: FaultSchedule) -> Fabric {
        self.faults = faults;
        self
    }

    /// Builder form of [`Fabric::inject_straggler`].
    pub fn with_straggler(mut self, rail: usize, stall_us: f64, sigma: f64) -> Fabric {
        self.inject_straggler(rail, stall_us, sigma);
        self
    }

    /// Make `rail` a persistent straggler: every message pays an extra
    /// `stall_us` stall (log-normal around it when `sigma > 0`). The
    /// analytic cost model does NOT see the stall — only measurements do.
    pub fn inject_straggler(&mut self, rail: usize, stall_us: f64, sigma: f64) {
        self.stragglers.push(Straggler { rail, stall_us, sigma });
        self.rebuild_stall(rail);
    }

    /// Remove all injected stragglers from `rail` (the fault healed).
    pub fn clear_straggler(&mut self, rail: usize) {
        self.stragglers.retain(|s| s.rail != rail);
        self.rebuild_stall(rail);
    }

    /// Recompute `rail`'s precomputed stall entry from the straggler list
    /// (runs on inject/clear only, never on the per-message path).
    fn rebuild_stall(&mut self, rail: usize) {
        let entry = &mut self.stall_table[rail];
        entry.det_us = 0.0;
        entry.stoch.clear();
        for s in self.stragglers.iter().filter(|s| s.rail == rail) {
            if s.sigma > 0.0 {
                entry.stoch.push((s.stall_us, s.sigma));
            } else {
                entry.det_us += s.stall_us;
            }
        }
    }

    /// Sampled extra stall for one message on `rail` (0 when healthy):
    /// table read for the deterministic part, one draw per stochastic
    /// entry on this rail.
    fn straggler_stall_us(&mut self, rail: usize) -> f64 {
        let mut stall = self.stall_table[rail].det_us;
        // indexed loop: sampling needs `&mut self.rng` while reading the table
        let mut k = 0;
        while k < self.stall_table[rail].stoch.len() {
            let (stall_us, sigma) = self.stall_table[rail].stoch[k];
            stall += stall_us * self.rng.jitter(sigma);
            k += 1;
        }
        stall
    }

    /// Disable stochastic jitter (deterministic analytic times).
    pub fn deterministic(mut self) -> Fabric {
        self.jitter_sigma = 0.0;
        self
    }

    pub fn now_us(&self) -> f64 {
        self.clock_us
    }

    pub fn advance(&mut self, dt_us: f64) {
        debug_assert!(dt_us >= 0.0);
        self.clock_us += dt_us;
    }

    pub fn reset_clock(&mut self) {
        self.clock_us = 0.0;
    }

    /// Cores effectively granted to `rail` during `phase`.
    pub fn cores_for_rail(&self, rail: usize, phase: Phase) -> f64 {
        self.cpu.cores_for(self.rails[rail].kind(), phase)
    }

    /// Check the fault schedule and update the rail's health. Returns true
    /// if the rail is usable at the current virtual time.
    pub fn poll_health(&mut self, rail: usize) -> bool {
        if self.rails[rail].health == RailHealth::Deregistered {
            return false;
        }
        if self.faults.is_down(rail, self.clock_us) {
            self.rails[rail].health = RailHealth::Failed;
            false
        } else {
            if self.rails[rail].health == RailHealth::Failed {
                // fault window passed; rail is physically back (the Control
                // module decides when to re-admit it)
                self.rails[rail].health = RailHealth::Healthy;
            }
            self.rails[rail].health == RailHealth::Healthy
        }
    }

    pub fn deregister(&mut self, rail: usize) {
        self.rails[rail].health = RailHealth::Deregistered;
        // free this member thread's cores for the survivors
        self.cpu.unregister(self.rails[rail].kind());
    }

    pub fn readmit(&mut self, rail: usize) {
        self.rails[rail].health = RailHealth::Healthy;
        self.cpu.register(self.rails[rail].kind());
    }

    /// Allocation-free form of [`Fabric::healthy_rails`] — the
    /// coordinator's per-op loop uses this (or
    /// [`Fabric::healthy_rails_into`] when a slice is needed).
    pub fn healthy_rails_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.rails
            .iter()
            .enumerate()
            .filter(|(_, r)| r.health == RailHealth::Healthy)
            .map(|(i, _)| i)
    }

    /// Collect the healthy rails into caller-owned scratch (cleared
    /// first).
    pub fn healthy_rails_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.healthy_rails_iter());
    }

    pub fn healthy_rails(&self) -> Vec<usize> {
        self.healthy_rails_iter().collect()
    }

    /// Deterministic (jitter-free) point-to-point message time on `rail`
    /// (us) at the current resource state — the α-β kernel shared by live
    /// transfers and the collective planner's cost model, so predictions
    /// and deterministic measurements agree by construction.
    ///
    /// The aggregation (computation-phase) share is what bounds the
    /// protocol's effective bandwidth; transfer-phase skeleton cores only
    /// drive the DMA engines. Cross-member contention (§5.3.2) inflates
    /// the TRANSFER component (memory-bandwidth/IRQ sharing), not the
    /// fixed setup.
    pub fn transfer_det_us(&self, rail: usize, bytes: f64) -> f64 {
        let r = &self.rails[rail];
        let cores = self.cpu.cores_for(r.kind(), Phase::Computation);
        let contention = self.cpu.contention_factor();
        let raw = r.protocol.msg_time_us(bytes, cores, r.wire_cap_mbps());
        r.protocol.setup_us + (raw - r.protocol.setup_us) / contention
    }

    /// Single point-to-point message time on `rail` (us), with jitter.
    /// Fails if the rail is down at the current virtual time.
    pub fn transfer(&mut self, rail: usize, bytes: f64) -> Result<f64, RailDown> {
        if !self.poll_health(rail) {
            return Err(RailDown(rail));
        }
        let base = self.transfer_det_us(rail, bytes);
        let j = if self.jitter_sigma > 0.0 {
            self.rng.jitter(self.jitter_sigma)
        } else {
            1.0
        };
        Ok(base * j + self.straggler_stall_us(rail))
    }

    /// One lockstep collective round on `rail`: every node sends a message
    /// of `bytes`; the round lasts as long as the slowest node (straggler
    /// max over per-node jitter).
    ///
    /// Batched sampling: health is polled and the deterministic base time
    /// computed ONCE per round (they cannot change mid-round — the clock
    /// only advances between rounds), all `nodes` jitter multipliers are
    /// drawn through one [`Pcg::fill_jitter`] pass, and a fully
    /// deterministic round (no jitter, no stochastic straggler) samples
    /// nothing at all: its max over identical per-node times IS the single
    /// deterministic message time.
    pub fn ring_step(&mut self, rail: usize, bytes: f64) -> Result<f64, RailDown> {
        if !self.poll_health(rail) {
            return Err(RailDown(rail));
        }
        let base = self.transfer_det_us(rail, bytes);
        let det_stall = self.stall_table[rail].det_us;
        let n_stoch = self.stall_table[rail].stoch.len();
        if self.jitter_sigma == 0.0 && n_stoch == 0 {
            return Ok(base + det_stall);
        }
        let nodes = self.nodes;
        let mut jit = std::mem::take(&mut self.jitter_buf);
        jit.clear();
        jit.resize(nodes, 1.0);
        if self.jitter_sigma > 0.0 {
            self.rng.fill_jitter(self.jitter_sigma, &mut jit);
        }
        let mut worst = 0.0f64;
        for &j in jit.iter() {
            let mut t = base * j + det_stall;
            // indexed loop: sampling needs `&mut self.rng` while reading
            // the table
            let mut k = 0;
            while k < n_stoch {
                let (stall_us, sigma) = self.stall_table[rail].stoch[k];
                t += stall_us * self.rng.jitter(sigma);
                k += 1;
            }
            worst = worst.max(t);
        }
        self.jitter_buf = jit;
        Ok(worst)
    }

    /// In-network aggregation round (SHARP-style): one tree traversal of
    /// `bytes`, node-count dependence handled by the protocol model.
    pub fn tree_round(&mut self, rail: usize, bytes: f64) -> Result<f64, RailDown> {
        if !self.poll_health(rail) {
            return Err(RailDown(rail));
        }
        let base = self.estimate_allreduce_us(rail, bytes);
        let j = if self.jitter_sigma > 0.0 {
            self.rng.jitter(self.jitter_sigma)
        } else {
            1.0
        };
        Ok(base * j + self.straggler_stall_us(rail))
    }

    /// Analytic single-rail allreduce estimate at current resources (used
    /// by the Load Balancer for cold-start decisions before the Timer has
    /// live data). Contention inflates the transfer component only.
    pub fn estimate_allreduce_us(&self, rail: usize, bytes: f64) -> f64 {
        let r = &self.rails[rail];
        let cores = self.cpu.cores_for(r.kind(), Phase::Computation);
        let contention = self.cpu.contention_factor();
        let raw = r
            .protocol
            .allreduce_time_us(bytes, self.nodes, cores, r.wire_cap_mbps());
        let setup = r
            .protocol
            .allreduce_time_us(0.0, self.nodes, cores, r.wire_cap_mbps());
        setup + (raw - setup) / contention
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::protocol::{ProtoKind, MB};
    use crate::net::rail::NicSpec;
    use crate::net::topology::ClusterSpec;

    fn dual_tcp(nodes: usize) -> Fabric {
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp])
            .unwrap();
        Fabric::new(nodes, rails, CpuPool::default(), 42).deterministic()
    }

    #[test]
    fn transfer_time_positive_and_monotone() {
        let mut f = dual_tcp(4);
        let t1 = f.transfer(0, 1024.0).unwrap();
        let t2 = f.transfer(0, MB).unwrap();
        assert!(t1 > 0.0 && t2 > t1);
    }

    #[test]
    fn fault_interrupts_transfer() {
        let mut f = dual_tcp(4).with_faults(FaultSchedule::none().with(1, 0.0, 1000.0));
        assert!(f.transfer(1, 1024.0).is_err());
        assert!(f.transfer(0, 1024.0).is_ok());
        f.advance(2000.0);
        // window over: rail physically back
        assert!(f.transfer(1, 1024.0).is_ok());
    }

    #[test]
    fn deregistered_rail_stays_down() {
        let mut f = dual_tcp(4);
        f.deregister(1);
        f.advance(1e9);
        assert!(f.transfer(1, 1024.0).is_err());
        assert_eq!(f.healthy_rails(), vec![0]);
        f.readmit(1);
        assert!(f.transfer(1, 1024.0).is_ok());
    }

    #[test]
    fn jitter_reproducible() {
        let mk = || {
            let rails = ClusterSpec::local()
                .build_rails(&[ProtoKind::Tcp])
                .unwrap();
            Fabric::new(4, rails, CpuPool::default(), 7)
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..10 {
            assert_eq!(a.transfer(0, MB).unwrap(), b.transfer(0, MB).unwrap());
        }
    }

    #[test]
    fn virtual_channels_halve_wire_not_time_on_fast_nic() {
        // On 100 Gbps the CPU-bound protocol peak (353 MB/s) is far below
        // even half the wire, so virtual sharing must not change times.
        let spec = ClusterSpec::local();
        let vrails = spec.build_virtual_rails(ProtoKind::Tcp, 2).unwrap();
        let prails = spec.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp]).unwrap();
        let mut fv = Fabric::new(4, vrails, CpuPool::default(), 1).deterministic();
        let mut fp = Fabric::new(4, prails, CpuPool::default(), 1).deterministic();
        let tv = fv.transfer(0, 4.0 * MB).unwrap();
        let tp = fp.transfer(0, 4.0 * MB).unwrap();
        assert!((tv - tp).abs() / tp < 0.01, "tv={tv} tp={tp}");
    }

    #[test]
    fn one_gbps_virtual_channels_do_bottleneck() {
        let nic = NicSpec::BCM5720;
        let r0 = Rail::new(0, nic.clone(), ProtoKind::Tcp).virtual_channel(0, 2);
        let r1 = Rail::new(0, nic.clone(), ProtoKind::Tcp).virtual_channel(1, 2);
        let single = Rail::new(0, nic, ProtoKind::Tcp);
        let mut fv = Fabric::new(4, vec![r0, r1], CpuPool::default(), 1).deterministic();
        let mut fs = Fabric::new(4, vec![single], CpuPool::default(), 1).deterministic();
        let tv = fv.transfer(0, 4.0 * MB).unwrap();
        let ts = fs.transfer(0, 4.0 * MB).unwrap();
        assert!(tv > 1.8 * ts, "tv={tv} ts={ts}");
    }

    #[test]
    fn straggler_slows_measurements_but_not_the_model() {
        let mut f = dual_tcp(4).with_straggler(1, 500.0, 0.0);
        let clean = f.transfer(0, MB).unwrap();
        let slow = f.transfer(1, MB).unwrap();
        // rails are identical TCP planes: the stall is the whole gap
        assert!((slow - clean - 500.0).abs() < 1e-6, "clean {clean} slow {slow}");
        // the deterministic model path stays blind to the straggler
        assert_eq!(f.transfer_det_us(0, MB), f.transfer_det_us(1, MB));
        assert_eq!(
            f.estimate_allreduce_us(0, 8.0 * MB),
            f.estimate_allreduce_us(1, 8.0 * MB)
        );
        f.clear_straggler(1);
        assert_eq!(f.transfer(0, MB).unwrap(), f.transfer(1, MB).unwrap());
    }

    #[test]
    fn lognormal_straggler_is_reproducible() {
        let mk = || dual_tcp(4).with_straggler(0, 300.0, 0.4);
        let (mut a, mut b) = (mk(), mk());
        let mut widened = false;
        for _ in 0..16 {
            let ta = a.transfer(0, MB).unwrap();
            assert_eq!(ta, b.transfer(0, MB).unwrap());
            if (ta - a.transfer_det_us(0, MB) - 300.0).abs() > 1.0 {
                widened = true; // sigma actually spreads the stall
            }
        }
        assert!(widened);
    }

    #[test]
    fn ring_step_batched_sampling_reproducible() {
        // jitter ON: the batched per-round fill must be reproducible
        // across identically-seeded fabrics
        let mk = || {
            let rails = ClusterSpec::local().build_rails(&[ProtoKind::Tcp]).unwrap();
            Fabric::new(4, rails, CpuPool::default(), 21)
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..10 {
            assert_eq!(a.ring_step(0, MB).unwrap(), b.ring_step(0, MB).unwrap());
        }
        // deterministic mode: the no-sampling fast path equals the
        // analytic per-message time exactly
        let mut d = mk().deterministic();
        let base = d.transfer_det_us(0, MB);
        assert_eq!(d.ring_step(0, MB).unwrap(), base);
    }

    #[test]
    fn straggler_table_tracks_inject_and_clear() {
        let mut f = dual_tcp(4);
        f.inject_straggler(1, 200.0, 0.0);
        f.inject_straggler(1, 300.0, 0.0);
        let clean = f.transfer(0, MB).unwrap();
        // stalls stack: the precomputed table sums the deterministic parts
        assert!((f.transfer(1, MB).unwrap() - clean - 500.0).abs() < 1e-6);
        // the batched ring step pays the same stall
        let r0 = f.ring_step(0, MB).unwrap();
        let r1 = f.ring_step(1, MB).unwrap();
        assert!((r1 - r0 - 500.0).abs() < 1e-6, "r0={r0} r1={r1}");
        f.clear_straggler(1);
        assert_eq!(f.transfer(0, MB).unwrap(), f.transfer(1, MB).unwrap());
    }

    #[test]
    fn estimates_match_measured_when_deterministic() {
        let mut f = dual_tcp(4);
        let est = f.estimate_allreduce_us(0, 8.0 * MB);
        // reconstruct via ring steps
        let seg = 8.0 * MB / 4.0;
        let mut total = 0.0;
        for _ in 0..6 {
            total += f.ring_step(0, seg).unwrap();
        }
        assert!((est - total).abs() / est < 0.05, "est={est} total={total}");
    }
}
