//! The fabric: virtual-clock multi-rail network simulation.
//!
//! Real payload bytes flow through the coordinator; the fabric supplies the
//! *time* each transfer takes, combining the calibrated protocol model,
//! NIC wire caps (incl. virtual-channel sharing), CPU-core allocation and
//! contention, per-message jitter, and the fault schedule.
//!
//! Collectives are executed in lockstep rounds (all nodes symmetric, as in
//! the paper's ring/tree algorithms): a step's duration is the max over
//! per-node sampled message times. This gives deterministic, fast policy
//! simulation while keeping the data path real.
//!
//! ## Per-rail sampling streams
//!
//! All mutable per-rail sampling state — rail health, the straggler stall
//! table and the jitter RNG — is split per rail: each rail draws from its
//! own [`Pcg`] stream reseeded from `(seed, rail, op_epoch)` at every
//! [`Fabric::begin_op`]. Concurrent rails therefore sample independent,
//! deterministic sequences whose values cannot depend on cross-rail
//! execution order, which is what lets the coordinator's parallel executor
//! produce modeled times bit-identical to serial execution. The
//! [`RailCtx`] borrow-split view hands one rail's complete timing state to
//! a worker thread; every [`Fabric`] sampling method delegates to it, so
//! serial and parallel paths share one implementation by construction.

use crate::net::cpu_pool::{CpuPool, Phase};
use crate::net::fault::{CorruptSchedule, DegradeSchedule, FaultSchedule};
use crate::net::protocol::CollectiveKind;
use crate::net::rail::{Rail, RailHealth};
use crate::util::rng::Pcg;

/// Error surfaced to the Exception Handler when a rail dies mid-transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RailDown(pub usize);

/// Smallest bandwidth share a rail grant can be clamped to — keeps
/// contended transfer times finite even for fully preempted tenants.
pub const MIN_RAIL_SHARE: f64 = 0.01;

/// Max retransmit attempts per message on a lossy link before the rail is
/// declared dead (surfaces as [`RailDown`] → §4.4 crash failover).
pub const RETRY_CAP: u32 = 5;

/// Base exponential-backoff pause (us) charged per retransmit attempt —
/// doubles with each further attempt on the same message.
pub const RETRY_BACKOFF_US: f64 = 50.0;

/// Persistent per-rail straggler: every message on the rail pays an extra
/// stall (paper §2.3.3's slow-NIC/incast pathologies). `sigma > 0` samples
/// the stall log-normally around `stall_us`; `sigma == 0` charges it
/// exactly (reproducible in `deterministic` mode). Deliberately invisible
/// to the analytic model paths (`transfer_det_us`,
/// `estimate_allreduce_us`) — stragglers are exactly the measured-vs-
/// predicted divergence the planner's `CorrectedCost` layer must learn.
#[derive(Debug, Clone, Copy)]
pub struct Straggler {
    pub rail: usize,
    pub stall_us: f64,
    pub sigma: f64,
}

/// Per-rail precomputed straggler stall state, maintained on
/// inject/clear: the deterministic (`sigma == 0`) component is pre-summed
/// and the stochastic entries are kept per rail, so the per-message path
/// is O(stragglers on this rail) — O(1) table reads for healthy rails —
/// instead of a linear scan over every injected straggler per message.
#[derive(Debug, Clone, Default)]
struct RailStall {
    /// Sum of sigma == 0 stalls (charged exactly).
    det_us: f64,
    /// `(stall_us, sigma)` entries with sigma > 0 (sampled per message).
    stoch: Vec<(f64, f64)>,
}

/// One rail's private sampling stream: jitter RNG plus the reusable
/// per-round jitter-multiplier scratch. Reseeded from
/// `(seed, rail, op_epoch)` at every op so draws are a pure function of
/// that triple, independent of other rails and of prior ops' draw counts.
#[derive(Debug, Clone)]
struct RailStream {
    rng: Pcg,
    jitter_buf: Vec<f64>,
}

/// Multi-rail fabric across `nodes` symmetric nodes.
#[derive(Debug, Clone)]
pub struct Fabric {
    pub nodes: usize,
    pub rails: Vec<Rail>,
    pub cpu: CpuPool,
    pub faults: FaultSchedule,
    /// Gray-failure schedule: loss/brownout/flap/windowed-stall windows.
    /// Like the fault schedule it is environmental — queried at the per-op
    /// frozen clock, invisible to the analytic model paths.
    pub degrade: DegradeSchedule,
    /// Silent-corruption schedule: bit-flip/duplicate/truncate/stuck-at
    /// windows. Environmental like the degrade schedule; sampled on the
    /// per-rail streams at the per-op frozen clock.
    pub corrupt: CorruptSchedule,
    /// Checksum-verified data plane on/off (default ON). With integrity
    /// on, every corrupted arrival is caught at the merge and recharged as
    /// a retransmit on the unified retry ledger; off, corruption is silent
    /// — it arrives on time and the poisoned payload reaches the
    /// reduction (the measurable escape the ablation quantifies).
    pub integrity: bool,
    /// Injected per-rail stragglers (unmodeled per-message stalls) — the
    /// source of truth behind `stall_table`.
    stragglers: Vec<Straggler>,
    /// Per-rail precomputed stall state (see [`RailStall`]).
    stall_table: Vec<RailStall>,
    /// Virtual clock (us).
    clock_us: f64,
    /// Log-normal per-message jitter sigma (0 disables jitter).
    pub jitter_sigma: f64,
    /// Base seed the per-rail streams derive from.
    seed: u64,
    /// Bumped by [`Fabric::begin_op`]; stream-derivation coordinate.
    op_epoch: u64,
    /// One independent sampling stream per rail.
    streams: Vec<RailStream>,
    /// Arbiter-granted bandwidth share per rail (1.0 = whole rail). The
    /// fixed per-message setup is paid regardless of the share; only the
    /// transfer component stretches by `1/share` — same convention as the
    /// CPU contention factor. Shares never touch the RNG streams, so a
    /// job's draw sequences (and therefore its payload numerics) are
    /// identical at every grant level.
    shares: Vec<f64>,
    /// Cumulative modeled busy time charged per rail (the arbiter's
    /// occupancy ledger input). Deterministic sums of the returned
    /// per-round times, so serial and parallel execution agree.
    occupancy: Vec<f64>,
    /// Cumulative retransmit attempts charged per rail by the loss model —
    /// the `HealthMonitor`'s per-op suspicion input (it consumes deltas).
    /// Deterministic per-rail counts, so serial and parallel agree.
    retries: Vec<u64>,
    /// Cumulative corruption events sampled per rail (detected or not) —
    /// the injection ledger the ablation's detection rate divides by.
    /// Deterministic per-rail counts, so serial and parallel agree.
    corruptions: Vec<u64>,
}

impl Fabric {
    pub fn new(nodes: usize, rails: Vec<Rail>, mut cpu: CpuPool, seed: u64) -> Fabric {
        assert!(nodes >= 2, "need at least 2 nodes");
        // Affinity masks are u64 bitmasks; rails beyond bit 63 used to slip
        // past every mask check as "always allowed".
        assert!(rails.len() <= 64, "at most 64 rails (affinity-mask limit)");
        for r in &rails {
            cpu.register(r.kind());
        }
        let n_rails = rails.len();
        Fabric {
            nodes,
            rails,
            cpu,
            faults: FaultSchedule::none(),
            degrade: DegradeSchedule::none(),
            corrupt: CorruptSchedule::none(),
            integrity: true,
            stragglers: Vec::new(),
            stall_table: vec![RailStall::default(); n_rails],
            clock_us: 0.0,
            jitter_sigma: 0.03,
            seed,
            op_epoch: 0,
            streams: (0..n_rails)
                .map(|r| RailStream {
                    rng: Pcg::for_stream(seed, r as u64, 0),
                    jitter_buf: Vec::new(),
                })
                .collect(),
            shares: vec![1.0; n_rails],
            occupancy: vec![0.0; n_rails],
            retries: vec![0; n_rails],
            corruptions: vec![0; n_rails],
        }
    }

    /// Grant `rail` a bandwidth share in `(0, 1]` (1.0 restores sole
    /// ownership). Live transfer times stretch their transfer component by
    /// `1/share`; the deterministic model paths (`transfer_det_us`,
    /// `estimate_allreduce_us`) stay share-blind — pricing contention is
    /// the planner's `cost::contended_us` job, so a contention-blind
    /// planner genuinely mispredicts.
    pub fn set_rail_share(&mut self, rail: usize, share: f64) {
        self.shares[rail] = share.clamp(MIN_RAIL_SHARE, 1.0);
    }

    /// The currently granted bandwidth share of `rail`.
    pub fn rail_share(&self, rail: usize) -> f64 {
        self.shares[rail]
    }

    /// Cumulative modeled busy time charged on `rail` since construction
    /// (or the last [`Fabric::reset_occupancy`]).
    pub fn occupancy_us(&self, rail: usize) -> f64 {
        self.occupancy[rail]
    }

    /// Zero the per-rail occupancy ledger.
    pub fn reset_occupancy(&mut self) {
        self.occupancy.iter_mut().for_each(|o| *o = 0.0);
    }

    pub fn with_faults(mut self, faults: FaultSchedule) -> Fabric {
        self.faults = faults;
        self
    }

    /// Builder form of [`Fabric::set_degrade`].
    pub fn with_degrade(mut self, degrade: DegradeSchedule) -> Fabric {
        self.degrade = degrade;
        self
    }

    /// Install a gray-degradation schedule (loss, brownouts, flaps,
    /// windowed stalls).
    pub fn set_degrade(&mut self, degrade: DegradeSchedule) {
        self.degrade = degrade;
    }

    /// Builder form of [`Fabric::set_corrupt`].
    pub fn with_corrupt(mut self, corrupt: CorruptSchedule) -> Fabric {
        self.corrupt = corrupt;
        self
    }

    /// Install a silent-corruption schedule (bit flips, duplication,
    /// truncation, stuck-at lanes).
    pub fn set_corrupt(&mut self, corrupt: CorruptSchedule) {
        self.corrupt = corrupt;
    }

    /// Builder: enable/disable the checksum-verified data plane
    /// (default on).
    pub fn with_integrity(mut self, on: bool) -> Fabric {
        self.integrity = on;
        self
    }

    /// Cumulative retransmit attempts charged on `rail` by the loss
    /// model since construction.
    pub fn retries_on(&self, rail: usize) -> u64 {
        self.retries[rail]
    }

    /// Cumulative corruption events sampled on `rail` since construction
    /// (detected-and-recharged under integrity, silently escaped without).
    pub fn corruptions_on(&self, rail: usize) -> u64 {
        self.corruptions[rail]
    }

    /// Builder form of [`Fabric::inject_straggler`].
    pub fn with_straggler(mut self, rail: usize, stall_us: f64, sigma: f64) -> Fabric {
        self.inject_straggler(rail, stall_us, sigma);
        self
    }

    /// Make `rail` a persistent straggler: every message pays an extra
    /// `stall_us` stall (log-normal around it when `sigma > 0`). The
    /// analytic cost model does NOT see the stall — only measurements do.
    pub fn inject_straggler(&mut self, rail: usize, stall_us: f64, sigma: f64) {
        self.stragglers.push(Straggler { rail, stall_us, sigma });
        self.rebuild_stall(rail);
    }

    /// Remove all injected stragglers from `rail` (the fault healed).
    pub fn clear_straggler(&mut self, rail: usize) {
        self.stragglers.retain(|s| s.rail != rail);
        self.rebuild_stall(rail);
    }

    /// Time-varying straggler: like [`Fabric::inject_straggler`] but only
    /// active while the virtual clock is inside `[start_us, end_us)` —
    /// sugar over a [`crate::net::fault::DegradeKind::Stall`] window, so
    /// it expires on its own instead of needing `clear_straggler`.
    pub fn inject_straggler_window(
        &mut self,
        rail: usize,
        stall_us: f64,
        sigma: f64,
        start_us: f64,
        end_us: f64,
    ) {
        self.degrade =
            std::mem::take(&mut self.degrade).stall(rail, start_us, end_us, stall_us, sigma);
    }

    /// Recompute `rail`'s precomputed stall entry from the straggler list
    /// (runs on inject/clear only, never on the per-message path).
    fn rebuild_stall(&mut self, rail: usize) {
        let entry = &mut self.stall_table[rail];
        entry.det_us = 0.0;
        entry.stoch.clear();
        for s in self.stragglers.iter().filter(|s| s.rail == rail) {
            if s.sigma > 0.0 {
                entry.stoch.push((s.stall_us, s.sigma));
            } else {
                entry.det_us += s.stall_us;
            }
        }
    }

    /// Disable stochastic jitter (deterministic analytic times).
    pub fn deterministic(mut self) -> Fabric {
        self.jitter_sigma = 0.0;
        self
    }

    /// Start a new op epoch: every rail's sampling stream is reseeded from
    /// `(seed, rail, epoch)`. The coordinator calls this once per
    /// allreduce, making each op's per-rail draw sequences a pure function
    /// of the epoch — independent of how many draws earlier ops made and
    /// of whether other rails execute before, after or concurrently.
    pub fn begin_op(&mut self) -> u64 {
        self.op_epoch += 1;
        for (r, s) in self.streams.iter_mut().enumerate() {
            s.rng = Pcg::for_stream(self.seed, r as u64, self.op_epoch);
        }
        self.op_epoch
    }

    /// The current op epoch (bumped by [`Fabric::begin_op`]).
    pub fn op_epoch(&self) -> u64 {
        self.op_epoch
    }

    pub fn now_us(&self) -> f64 {
        self.clock_us
    }

    pub fn advance(&mut self, dt_us: f64) {
        debug_assert!(dt_us >= 0.0);
        self.clock_us += dt_us;
    }

    pub fn reset_clock(&mut self) {
        self.clock_us = 0.0;
    }

    /// Rebind the fabric to a new participating-node count (elastic
    /// membership: the coordinator compacts the surviving set and the
    /// fabric only ever sees the contiguous count). Clock, rail state,
    /// shares and RNG streams are untouched — per-op streams are reseeded
    /// at the next [`Fabric::begin_op`] anyway.
    pub fn set_nodes(&mut self, nodes: usize) {
        assert!(nodes >= 2, "need at least 2 nodes");
        self.nodes = nodes;
    }

    /// True when `rail` currently has injected stragglers (failure-era
    /// state the readmit path must clear).
    pub fn has_straggler(&self, rail: usize) -> bool {
        self.stragglers.iter().any(|s| s.rail == rail)
    }

    /// Cores effectively granted to `rail` during `phase`.
    pub fn cores_for_rail(&self, rail: usize, phase: Phase) -> f64 {
        self.cpu.cores_for(self.rails[rail].kind(), phase)
    }

    /// Check the fault + degrade schedules at the current virtual time.
    /// Returns true if the rail is usable (in the dataplane and not
    /// crash-down or in a flap's down half-period).
    pub fn poll_health(&mut self, rail: usize) -> bool {
        self.rail_ctx(rail).poll_health()
    }

    /// Quarantine `rail` (remove it from the dataplane) and free its CPU
    /// cores for the survivors. Idempotent: an already-quarantined rail is
    /// left alone (no double unregister).
    pub fn deregister(&mut self, rail: usize) {
        if self.rails[rail].health == RailHealth::Quarantined {
            return;
        }
        self.rails[rail].health = RailHealth::Quarantined;
        // free this member thread's cores for the survivors
        self.cpu.unregister(self.rails[rail].kind());
    }

    /// Readmit a quarantined rail at full trust (the legacy
    /// trust-on-readmit path, used when the health monitor is off).
    pub fn readmit(&mut self, rail: usize) {
        if self.rails[rail].transition(RailHealth::Healthy) {
            self.cpu.register(self.rails[rail].kind());
        }
    }

    /// Readmit a quarantined rail on probation: it re-enters the dataplane
    /// (cores re-registered) but the coordinator routes only reduced-share
    /// canary traffic until it earns `Healthy` back.
    pub fn readmit_probation(&mut self, rail: usize) {
        if self.rails[rail].transition(RailHealth::Probation) {
            self.cpu.register(self.rails[rail].kind());
        }
    }

    /// Allocation-free form of [`Fabric::healthy_rails`] — the
    /// coordinator's per-op loop uses this (or
    /// [`Fabric::healthy_rails_into`] when a slice is needed). "Healthy"
    /// here means *usable*: Degraded and Probation rails still carry
    /// payload (at soft-demoted share); only Quarantined rails are out.
    pub fn healthy_rails_iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.rails
            .iter()
            .enumerate()
            .filter(|(_, r)| r.health.usable())
            .map(|(i, _)| i)
    }

    /// Collect the healthy rails into caller-owned scratch (cleared
    /// first).
    pub fn healthy_rails_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.healthy_rails_iter());
    }

    pub fn healthy_rails(&self) -> Vec<usize> {
        self.healthy_rails_iter().collect()
    }

    /// Deterministic (jitter-free) point-to-point message time on `rail`
    /// (us) at the current resource state — the α-β kernel shared by live
    /// transfers and the collective planner's cost model, so predictions
    /// and deterministic measurements agree by construction.
    ///
    /// The aggregation (computation-phase) share is what bounds the
    /// protocol's effective bandwidth; transfer-phase skeleton cores only
    /// drive the DMA engines. Cross-member contention (§5.3.2) inflates
    /// the TRANSFER component (memory-bandwidth/IRQ sharing), not the
    /// fixed setup.
    pub fn transfer_det_us(&self, rail: usize, bytes: f64) -> f64 {
        let r = &self.rails[rail];
        let cores = self.cpu.cores_for(r.kind(), Phase::Computation);
        det_msg_us(r, bytes, cores, self.cpu.contention_factor())
    }

    /// Single point-to-point message time on `rail` (us), with jitter.
    /// Fails if the rail is down at the current virtual time.
    pub fn transfer(&mut self, rail: usize, bytes: f64) -> Result<f64, RailDown> {
        self.rail_ctx(rail).transfer(bytes)
    }

    /// One lockstep collective round on `rail` (see
    /// [`RailCtx::ring_step`], which carries the single implementation).
    pub fn ring_step(&mut self, rail: usize, bytes: f64) -> Result<f64, RailDown> {
        self.rail_ctx(rail).ring_step(bytes)
    }

    /// In-network aggregation round (SHARP-style): one tree traversal of
    /// `bytes`, node-count dependence handled by the protocol model.
    pub fn tree_round(&mut self, rail: usize, bytes: f64) -> Result<f64, RailDown> {
        self.rail_ctx(rail).tree_round(bytes)
    }

    /// Analytic single-rail allreduce estimate at current resources (used
    /// by the Load Balancer for cold-start decisions before the Timer has
    /// live data). Contention inflates the transfer component only.
    pub fn estimate_allreduce_us(&self, rail: usize, bytes: f64) -> f64 {
        let r = &self.rails[rail];
        let cores = self.cpu.cores_for(r.kind(), Phase::Computation);
        det_allreduce_us(r, bytes, self.nodes, cores, self.cpu.contention_factor())
    }

    /// Borrow-split per-rail timing view: one rail's mutable sampling
    /// state (health, RNG stream) plus shared read-only op state (faults,
    /// clock, frozen CPU shares). Every [`Fabric`] sampling method
    /// delegates here, so a `RailCtx` driven on a worker thread samples
    /// exactly what the serial path would.
    pub fn rail_ctx(&mut self, rail: usize) -> RailCtx<'_> {
        let kind = self.rails[rail].kind();
        let cores = self.cpu.cores_for(kind, Phase::Computation);
        let contention = self.cpu.contention_factor();
        RailCtx {
            rail,
            state: &mut self.rails[rail],
            stream: &mut self.streams[rail],
            stall: &self.stall_table[rail],
            faults: &self.faults,
            degrade: &self.degrade,
            loss: self.degrade.loss_at(rail, self.clock_us),
            brownout: self.degrade.brownout_at(rail, self.clock_us),
            win_stall_us: self.degrade.stall_det_us(rail, self.clock_us),
            corrupt_p: self.corrupt.corrupt_at(rail, self.clock_us),
            integrity: self.integrity,
            pending_poison: 0,
            nodes: self.nodes,
            clock_us: self.clock_us,
            jitter_sigma: self.jitter_sigma,
            cores,
            contention,
            share: self.shares[rail],
            busy_us: &mut self.occupancy[rail],
            retries: &mut self.retries[rail],
            corruptions: &mut self.corruptions[rail],
        }
    }

    /// Simultaneous borrow-split views for a set of rails (ascending rail
    /// order) — what the coordinator hands the parallel executor's worker
    /// threads. Rails not in `wanted` are skipped.
    pub fn rail_ctxs(&mut self, wanted: &[usize]) -> Vec<RailCtx<'_>> {
        let contention = self.cpu.contention_factor();
        let cores: Vec<f64> = self
            .rails
            .iter()
            .map(|r| self.cpu.cores_for(r.kind(), Phase::Computation))
            .collect();
        let nodes = self.nodes;
        let clock_us = self.clock_us;
        let jitter_sigma = self.jitter_sigma;
        let faults = &self.faults;
        let degrade = &self.degrade;
        let corrupt = &self.corrupt;
        let integrity = self.integrity;
        let mut out = Vec::with_capacity(wanted.len());
        for ((((((i, state), stream), stall), busy), retries), corruptions) in self
            .rails
            .iter_mut()
            .enumerate()
            .zip(self.streams.iter_mut())
            .zip(self.stall_table.iter())
            .zip(self.occupancy.iter_mut())
            .zip(self.retries.iter_mut())
            .zip(self.corruptions.iter_mut())
        {
            if !wanted.contains(&i) {
                continue;
            }
            out.push(RailCtx {
                rail: i,
                state,
                stream,
                stall,
                faults,
                degrade,
                loss: degrade.loss_at(i, clock_us),
                brownout: degrade.brownout_at(i, clock_us),
                win_stall_us: degrade.stall_det_us(i, clock_us),
                corrupt_p: corrupt.corrupt_at(i, clock_us),
                integrity,
                pending_poison: 0,
                nodes,
                clock_us,
                jitter_sigma,
                cores: cores[i],
                contention,
                share: self.shares[i],
                busy_us: busy,
                retries,
                corruptions,
            });
        }
        out
    }
}

/// The α-β message-time kernel: protocol model at `cores`, contention
/// inflating the transfer component only (never the fixed setup).
fn det_msg_us(rail: &Rail, bytes: f64, cores: f64, contention: f64) -> f64 {
    let raw = rail.protocol.msg_time_us(bytes, cores, rail.wire_cap_mbps());
    rail.protocol.setup_us + (raw - rail.protocol.setup_us) / contention
}

/// The α-β single-rail allreduce kernel (same contention convention).
fn det_allreduce_us(rail: &Rail, bytes: f64, nodes: usize, cores: f64, contention: f64) -> f64 {
    let raw = rail
        .protocol
        .allreduce_time_us(bytes, nodes, cores, rail.wire_cap_mbps());
    let setup = rail
        .protocol
        .allreduce_time_us(0.0, nodes, cores, rail.wire_cap_mbps());
    setup + (raw - setup) / contention
}

/// Per-rail timing source for collective execution. Implemented by
/// [`RailCtx`]; every collective core is generic over it, so the serial
/// coordinator path (which builds a throwaway `RailCtx` per call through
/// [`Fabric::rail_ctx`]) and the parallel executor's long-lived worker
/// contexts share one timing implementation.
pub trait RailTimer {
    /// Nodes participating in the lockstep collective.
    fn nodes(&self) -> usize;
    /// The rail's native collective family (ring vs in-network tree).
    fn collective_kind(&self) -> CollectiveKind;
    /// One lockstep collective round: every node sends `bytes`.
    fn ring_step(&mut self, bytes: f64) -> Result<f64, RailDown>;
    /// One in-network aggregation traversal of `bytes`.
    fn tree_round(&mut self, bytes: f64) -> Result<f64, RailDown>;
    /// Is the checksum-verified data plane active on this timer? Cores
    /// compute/verify the per-window checksum only when it is (the
    /// clean-path overhead the hot-path bench records). Default: off —
    /// only [`RailCtx`] carries a fabric integrity setting.
    fn integrity_on(&self) -> bool {
        false
    }
    /// Take the corruption events that escaped wire verification during
    /// the timing calls since the last drain (nonzero only when the
    /// fabric's integrity verification is OFF). The collective core
    /// applies them to the payload between timing and numerics — timing
    /// always precedes numerics (§4.4), so an aborted op never poisons.
    /// Default: nothing pending (plain timers never corrupt).
    fn drain_corruption(&mut self) -> u64 {
        0
    }
}

/// One rail's complete timing state, borrow-split out of the [`Fabric`]:
/// mutable health + RNG stream for THIS rail only, shared read-only fault
/// schedule, and the CPU shares frozen at construction (the CpuPool is
/// only re-split between ops — on failover deregistration — never inside
/// one). `Send`, so the parallel executor can drive disjoint rails from
/// worker threads while numerics run over disjoint buffer views.
pub struct RailCtx<'a> {
    /// Rail id this context drives.
    pub rail: usize,
    state: &'a mut Rail,
    stream: &'a mut RailStream,
    stall: &'a RailStall,
    faults: &'a FaultSchedule,
    degrade: &'a DegradeSchedule,
    /// Packet-loss probability at the op's frozen clock (0 = lossless; a
    /// zero-loss op draws nothing extra, keeping fault-free sequences
    /// bit-exactly unchanged).
    loss: f64,
    /// Brownout bandwidth multiplier at the op's frozen clock (1 = full
    /// wire), composed with `share` under the same setup-preserving
    /// convention.
    brownout: f64,
    /// Deterministic windowed-stall component active at the frozen clock.
    win_stall_us: f64,
    /// Per-message silent-corruption probability at the op's frozen clock
    /// (0 = clean; a clean op draws nothing extra, keeping fault-free
    /// sequences bit-exactly unchanged).
    corrupt_p: f64,
    /// Checksum-verified data plane active (frozen at construction).
    integrity: bool,
    /// Corruption events that escaped wire verification (integrity off)
    /// since the last [`RailTimer::drain_corruption`] — the collective
    /// core turns these into deterministic payload poison.
    pending_poison: u64,
    nodes: usize,
    clock_us: f64,
    jitter_sigma: f64,
    cores: f64,
    contention: f64,
    /// Arbiter-granted bandwidth share, frozen at construction (grants
    /// only change between ops — the arbiter's window-boundary rule).
    share: f64,
    /// This rail's slot in the fabric's occupancy ledger.
    busy_us: &'a mut f64,
    /// This rail's slot in the fabric's retransmit ledger.
    retries: &'a mut u64,
    /// This rail's slot in the fabric's corruption-injection ledger.
    corruptions: &'a mut u64,
}

impl RailCtx<'_> {
    /// Stretch a sampled rail time by the granted share AND any active
    /// brownout: the transfer component pays `1/(share*brownout)`, the
    /// fixed `setup_us` does not (the same setup-preserving convention as
    /// cross-member CPU contention). A whole, un-browned rail returns
    /// `raw_us` bit-exactly.
    fn shared(&self, raw_us: f64, setup_us: f64) -> f64 {
        let f = self.share * self.brownout;
        if f >= 1.0 {
            return raw_us;
        }
        setup_us + (raw_us - setup_us) / f
    }

    /// Charge `t` microseconds to the rail's occupancy ledger.
    fn charge(&mut self, t: f64) -> f64 {
        *self.busy_us += t;
        t
    }

    /// Health poll at the op's frozen virtual time: usable state machine
    /// position AND neither crash-down (fault schedule) nor in a flap's
    /// down half-period. Pure — environmental downtime never mutates the
    /// state machine; quarantining is the Exception Handler's decision.
    pub fn poll_health(&mut self) -> bool {
        self.state.health.usable()
            && !self.faults.is_down(self.rail, self.clock_us)
            && !self.degrade.flap_down(self.rail, self.clock_us)
    }

    /// Sample the retransmit penalty for one message whose clean time is
    /// `msg_us` on a lossy link: each dropped attempt recharges the
    /// message plus an exponentially growing backoff pause, drawn from
    /// THIS rail's stream (serial ≡ parallel bit-exactly; lossless ops
    /// draw nothing). Past [`RETRY_CAP`] the link is declared dead and
    /// the §4.4 crash path takes over.
    fn retransmit_extra_us(&mut self, msg_us: f64) -> Result<f64, RailDown> {
        if self.loss <= 0.0 {
            return Ok(0.0);
        }
        let mut extra = 0.0;
        let mut attempt = 0u32;
        while self.stream.rng.f64() < self.loss {
            attempt += 1;
            if attempt > RETRY_CAP {
                *self.retries += attempt as u64;
                return Err(RailDown(self.rail));
            }
            extra += msg_us + RETRY_BACKOFF_US * (1u64 << (attempt - 1)) as f64;
        }
        *self.retries += attempt as u64;
        Ok(extra)
    }

    /// Sample the silent-corruption outcome for one message whose clean
    /// time is `msg_us`, drawn from THIS rail's stream (serial ≡ parallel
    /// bit-exactly; corruption-free ops draw nothing).
    ///
    /// With integrity ON every corrupted arrival is caught by the merge
    /// checksum and recharged exactly like a lost packet — message +
    /// exponential backoff, counted on the SAME retry ledger the
    /// `HealthMonitor` scores, with the same [`RETRY_CAP`] blowout into
    /// the §4.4 crash path (one accounting path, no second ledger). With
    /// integrity OFF the message arrives on time, costs nothing, and the
    /// corruption is queued as pending payload poison instead.
    fn corrupt_extra_us(&mut self, msg_us: f64) -> Result<f64, RailDown> {
        if self.corrupt_p <= 0.0 {
            return Ok(0.0);
        }
        if !self.integrity {
            if self.stream.rng.f64() < self.corrupt_p {
                *self.corruptions += 1;
                self.pending_poison += 1;
            }
            return Ok(0.0);
        }
        let mut extra = 0.0;
        let mut attempt = 0u32;
        while self.stream.rng.f64() < self.corrupt_p {
            attempt += 1;
            if attempt > RETRY_CAP {
                *self.retries += attempt as u64;
                *self.corruptions += attempt as u64;
                return Err(RailDown(self.rail));
            }
            extra += msg_us + RETRY_BACKOFF_US * (1u64 << (attempt - 1)) as f64;
        }
        *self.retries += attempt as u64;
        *self.corruptions += attempt as u64;
        Ok(extra)
    }

    /// Deterministic point-to-point message time (us) at the frozen
    /// resource state.
    pub fn transfer_det_us(&self, bytes: f64) -> f64 {
        det_msg_us(self.state, bytes, self.cores, self.contention)
    }

    /// Sampled extra stall for one message (0 when healthy): table read
    /// for the deterministic parts (persistent + windowed), one draw per
    /// stochastic entry — persistent first, then active windows.
    fn straggler_stall_us(&mut self) -> f64 {
        let mut stall = self.stall.det_us + self.win_stall_us;
        for &(stall_us, sigma) in &self.stall.stoch {
            stall += stall_us * self.stream.rng.jitter(sigma);
        }
        let degrade = self.degrade;
        for (stall_us, sigma) in degrade.stall_stoch_at(self.rail, self.clock_us) {
            stall += stall_us * self.stream.rng.jitter(sigma);
        }
        stall
    }

    /// Single point-to-point message time (us), with jitter and loss
    /// retransmits. Fails if the rail is down at the op's virtual time.
    pub fn transfer(&mut self, bytes: f64) -> Result<f64, RailDown> {
        if !self.poll_health() {
            return Err(RailDown(self.rail));
        }
        let base = self.shared(self.transfer_det_us(bytes), self.state.protocol.setup_us);
        let j = if self.jitter_sigma > 0.0 {
            self.stream.rng.jitter(self.jitter_sigma)
        } else {
            1.0
        };
        let mut t = base * j + self.straggler_stall_us();
        t += self.retransmit_extra_us(base * j)?;
        t += self.corrupt_extra_us(base * j)?;
        Ok(self.charge(t))
    }

    /// Analytic single-rail allreduce estimate at the frozen resources.
    pub fn estimate_allreduce_us(&self, bytes: f64) -> f64 {
        det_allreduce_us(self.state, bytes, self.nodes, self.cores, self.contention)
    }
}

impl RailTimer for RailCtx<'_> {
    fn nodes(&self) -> usize {
        self.nodes
    }

    fn collective_kind(&self) -> CollectiveKind {
        self.state.protocol.collective
    }

    /// One lockstep collective round: every node sends a message of
    /// `bytes`; the round lasts as long as the slowest node (straggler max
    /// over per-node jitter).
    ///
    /// Batched sampling: health is polled and the deterministic base time
    /// computed ONCE per round (they cannot change mid-round — the clock
    /// only advances between rounds), all `nodes` jitter multipliers are
    /// drawn through one [`Pcg::fill_jitter`] pass, and a fully
    /// deterministic round (no jitter, no stochastic straggler) samples
    /// nothing at all: its max over identical per-node times IS the single
    /// deterministic message time.
    fn ring_step(&mut self, bytes: f64) -> Result<f64, RailDown> {
        if !self.poll_health() {
            return Err(RailDown(self.rail));
        }
        let base = self.shared(self.transfer_det_us(bytes), self.state.protocol.setup_us);
        let det_stall = self.stall.det_us + self.win_stall_us;
        let degrade = self.degrade;
        let n_stoch =
            self.stall.stoch.len() + degrade.stall_stoch_at(self.rail, self.clock_us).count();
        if self.jitter_sigma == 0.0 && n_stoch == 0 && self.loss <= 0.0 && self.corrupt_p <= 0.0 {
            return Ok(self.charge(base + det_stall));
        }
        let nodes = self.nodes;
        let mut jit = std::mem::take(&mut self.stream.jitter_buf);
        jit.clear();
        jit.resize(nodes, 1.0);
        if self.jitter_sigma > 0.0 {
            self.stream.rng.fill_jitter(self.jitter_sigma, &mut jit);
        }
        let mut worst = 0.0f64;
        let mut down = None;
        for n in 0..nodes {
            let j = jit[n];
            let mut t = base * j + det_stall;
            for &(stall_us, sigma) in &self.stall.stoch {
                t += stall_us * self.stream.rng.jitter(sigma);
            }
            for (stall_us, sigma) in degrade.stall_stoch_at(self.rail, self.clock_us) {
                t += stall_us * self.stream.rng.jitter(sigma);
            }
            // lossy link: each node's message pays its retransmits; a
            // retry-cap blowout kills the whole round (deterministically —
            // the draw sequence is a pure function of the rail stream)
            match self.retransmit_extra_us(base * j) {
                Ok(extra) => t += extra,
                Err(e) => {
                    down = Some(e);
                    break;
                }
            }
            // corrupted link: checksum-detected corruption pays the same
            // retransmit shape on the same ledger; a cap blowout likewise
            // kills the round deterministically
            match self.corrupt_extra_us(base * j) {
                Ok(extra) => t += extra,
                Err(e) => {
                    down = Some(e);
                    break;
                }
            }
            worst = worst.max(t);
        }
        self.stream.jitter_buf = jit;
        if let Some(e) = down {
            return Err(e);
        }
        Ok(self.charge(worst))
    }

    fn tree_round(&mut self, bytes: f64) -> Result<f64, RailDown> {
        if !self.poll_health() {
            return Err(RailDown(self.rail));
        }
        let base = self.shared(self.estimate_allreduce_us(bytes), self.estimate_allreduce_us(0.0));
        let j = if self.jitter_sigma > 0.0 {
            self.stream.rng.jitter(self.jitter_sigma)
        } else {
            1.0
        };
        let mut t = base * j + self.straggler_stall_us();
        t += self.retransmit_extra_us(base * j)?;
        t += self.corrupt_extra_us(base * j)?;
        Ok(self.charge(t))
    }

    fn integrity_on(&self) -> bool {
        self.integrity
    }

    fn drain_corruption(&mut self) -> u64 {
        let n = self.pending_poison;
        self.pending_poison = 0;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::protocol::{ProtoKind, MB};
    use crate::net::rail::NicSpec;
    use crate::net::topology::ClusterSpec;

    fn dual_tcp(nodes: usize) -> Fabric {
        let rails = ClusterSpec::local()
            .build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp])
            .unwrap();
        Fabric::new(nodes, rails, CpuPool::default(), 42).deterministic()
    }

    #[test]
    fn transfer_time_positive_and_monotone() {
        let mut f = dual_tcp(4);
        let t1 = f.transfer(0, 1024.0).unwrap();
        let t2 = f.transfer(0, MB).unwrap();
        assert!(t1 > 0.0 && t2 > t1);
    }

    #[test]
    fn fault_interrupts_transfer() {
        let mut f = dual_tcp(4).with_faults(FaultSchedule::none().with(1, 0.0, 1000.0));
        assert!(f.transfer(1, 1024.0).is_err());
        assert!(f.transfer(0, 1024.0).is_ok());
        f.advance(2000.0);
        // window over: rail physically back
        assert!(f.transfer(1, 1024.0).is_ok());
    }

    #[test]
    fn deregistered_rail_stays_down() {
        let mut f = dual_tcp(4);
        f.deregister(1);
        f.advance(1e9);
        assert!(f.transfer(1, 1024.0).is_err());
        assert_eq!(f.healthy_rails(), vec![0]);
        f.readmit(1);
        assert!(f.transfer(1, 1024.0).is_ok());
    }

    #[test]
    fn jitter_reproducible() {
        let mk = || {
            let rails = ClusterSpec::local()
                .build_rails(&[ProtoKind::Tcp])
                .unwrap();
            Fabric::new(4, rails, CpuPool::default(), 7)
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..10 {
            assert_eq!(a.transfer(0, MB).unwrap(), b.transfer(0, MB).unwrap());
        }
    }

    #[test]
    fn per_rail_streams_are_order_independent() {
        // identical fabrics; draw rails in opposite interleavings — every
        // rail's sequence must be unaffected by the other rail's draws
        let (mut a, mut b) = (dual_tcp(4), dual_tcp(4));
        a.jitter_sigma = 0.05;
        b.jitter_sigma = 0.05;
        a.begin_op();
        b.begin_op();
        let mut a_seq = Vec::new();
        for _ in 0..6 {
            a_seq.push(a.ring_step(0, MB).unwrap());
            let _ = a.ring_step(1, MB).unwrap();
        }
        // b: rail 1 drained first, rail 0 after — same rail-0 sequence
        let mut b1 = Vec::new();
        for _ in 0..6 {
            b1.push(b.ring_step(1, MB).unwrap());
        }
        let b_seq: Vec<f64> = (0..6).map(|_| b.ring_step(0, MB).unwrap()).collect();
        assert_eq!(a_seq, b_seq, "rail 0 stream depends on rail 1 draws");
        assert!(!b1.is_empty());
    }

    #[test]
    fn begin_op_reseeds_streams_per_epoch() {
        let mut f = dual_tcp(4);
        f.jitter_sigma = 0.05;
        f.begin_op();
        let t1 = f.ring_step(0, MB).unwrap();
        let e = f.op_epoch();
        // drawing more does not disturb the next epoch's sequence
        for _ in 0..5 {
            let _ = f.ring_step(0, MB).unwrap();
        }
        f.begin_op();
        assert_eq!(f.op_epoch(), e + 1);
        let t2 = f.ring_step(0, MB).unwrap();
        // a fresh fabric skipped straight to epoch 2 samples the same t2
        let mut g = dual_tcp(4);
        g.jitter_sigma = 0.05;
        g.begin_op();
        g.begin_op();
        assert_eq!(g.ring_step(0, MB).unwrap(), t2);
        assert!(t1 > 0.0);
    }

    #[test]
    fn rail_ctx_samples_exactly_what_fabric_does() {
        let (mut a, mut b) = (dual_tcp(4), dual_tcp(4));
        a.jitter_sigma = 0.04;
        b.jitter_sigma = 0.04;
        a.inject_straggler(1, 250.0, 0.3);
        b.inject_straggler(1, 250.0, 0.3);
        a.begin_op();
        b.begin_op();
        let via_fab: Vec<f64> = (0..5).map(|_| a.ring_step(1, MB).unwrap()).collect();
        let mut ctxs = b.rail_ctxs(&[1]);
        assert_eq!(ctxs.len(), 1);
        let ctx = &mut ctxs[0];
        assert_eq!(ctx.rail, 1);
        assert_eq!(ctx.nodes(), 4);
        let via_ctx: Vec<f64> = (0..5).map(|_| ctx.ring_step(MB).unwrap()).collect();
        assert_eq!(via_fab, via_ctx);
    }

    #[test]
    fn virtual_channels_halve_wire_not_time_on_fast_nic() {
        // On 100 Gbps the CPU-bound protocol peak (353 MB/s) is far below
        // even half the wire, so virtual sharing must not change times.
        let spec = ClusterSpec::local();
        let vrails = spec.build_virtual_rails(ProtoKind::Tcp, 2).unwrap();
        let prails = spec.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp]).unwrap();
        let mut fv = Fabric::new(4, vrails, CpuPool::default(), 1).deterministic();
        let mut fp = Fabric::new(4, prails, CpuPool::default(), 1).deterministic();
        let tv = fv.transfer(0, 4.0 * MB).unwrap();
        let tp = fp.transfer(0, 4.0 * MB).unwrap();
        assert!((tv - tp).abs() / tp < 0.01, "tv={tv} tp={tp}");
    }

    #[test]
    fn one_gbps_virtual_channels_do_bottleneck() {
        let nic = NicSpec::BCM5720;
        let r0 = Rail::new(0, nic.clone(), ProtoKind::Tcp).virtual_channel(0, 2);
        let r1 = Rail::new(0, nic.clone(), ProtoKind::Tcp).virtual_channel(1, 2);
        let single = Rail::new(0, nic, ProtoKind::Tcp);
        let mut fv = Fabric::new(4, vec![r0, r1], CpuPool::default(), 1).deterministic();
        let mut fs = Fabric::new(4, vec![single], CpuPool::default(), 1).deterministic();
        let tv = fv.transfer(0, 4.0 * MB).unwrap();
        let ts = fs.transfer(0, 4.0 * MB).unwrap();
        assert!(tv > 1.8 * ts, "tv={tv} ts={ts}");
    }

    #[test]
    fn straggler_slows_measurements_but_not_the_model() {
        let mut f = dual_tcp(4).with_straggler(1, 500.0, 0.0);
        let clean = f.transfer(0, MB).unwrap();
        let slow = f.transfer(1, MB).unwrap();
        // rails are identical TCP planes: the stall is the whole gap
        assert!((slow - clean - 500.0).abs() < 1e-6, "clean {clean} slow {slow}");
        // the deterministic model path stays blind to the straggler
        assert_eq!(f.transfer_det_us(0, MB), f.transfer_det_us(1, MB));
        assert_eq!(
            f.estimate_allreduce_us(0, 8.0 * MB),
            f.estimate_allreduce_us(1, 8.0 * MB)
        );
        f.clear_straggler(1);
        assert_eq!(f.transfer(0, MB).unwrap(), f.transfer(1, MB).unwrap());
    }

    #[test]
    fn lognormal_straggler_is_reproducible() {
        let mk = || dual_tcp(4).with_straggler(0, 300.0, 0.4);
        let (mut a, mut b) = (mk(), mk());
        let mut widened = false;
        for _ in 0..16 {
            let ta = a.transfer(0, MB).unwrap();
            assert_eq!(ta, b.transfer(0, MB).unwrap());
            if (ta - a.transfer_det_us(0, MB) - 300.0).abs() > 1.0 {
                widened = true; // sigma actually spreads the stall
            }
        }
        assert!(widened);
    }

    #[test]
    fn ring_step_batched_sampling_reproducible() {
        // jitter ON: the batched per-round fill must be reproducible
        // across identically-seeded fabrics
        let mk = || {
            let rails = ClusterSpec::local().build_rails(&[ProtoKind::Tcp]).unwrap();
            Fabric::new(4, rails, CpuPool::default(), 21)
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..10 {
            assert_eq!(a.ring_step(0, MB).unwrap(), b.ring_step(0, MB).unwrap());
        }
        // deterministic mode: the no-sampling fast path equals the
        // analytic per-message time exactly
        let mut d = mk().deterministic();
        let base = d.transfer_det_us(0, MB);
        assert_eq!(d.ring_step(0, MB).unwrap(), base);
    }

    #[test]
    fn straggler_table_tracks_inject_and_clear() {
        let mut f = dual_tcp(4);
        f.inject_straggler(1, 200.0, 0.0);
        f.inject_straggler(1, 300.0, 0.0);
        let clean = f.transfer(0, MB).unwrap();
        // stalls stack: the precomputed table sums the deterministic parts
        assert!((f.transfer(1, MB).unwrap() - clean - 500.0).abs() < 1e-6);
        // the batched ring step pays the same stall
        let r0 = f.ring_step(0, MB).unwrap();
        let r1 = f.ring_step(1, MB).unwrap();
        assert!((r1 - r0 - 500.0).abs() < 1e-6, "r0={r0} r1={r1}");
        f.clear_straggler(1);
        assert_eq!(f.transfer(0, MB).unwrap(), f.transfer(1, MB).unwrap());
    }

    #[test]
    fn estimates_match_measured_when_deterministic() {
        let mut f = dual_tcp(4);
        let est = f.estimate_allreduce_us(0, 8.0 * MB);
        // reconstruct via ring steps
        let seg = 8.0 * MB / 4.0;
        let mut total = 0.0;
        for _ in 0..6 {
            total += f.ring_step(0, seg).unwrap();
        }
        assert!((est - total).abs() / est < 0.05, "est={est} total={total}");
    }

    #[test]
    fn rail_share_stretches_transfer_but_not_setup() {
        let mut f = dual_tcp(4);
        let full = f.ring_step(0, MB).unwrap();
        f.set_rail_share(0, 0.5);
        let half = f.ring_step(0, MB).unwrap();
        let setup = f.rails[0].protocol.setup_us;
        // setup-preserving inflation: setup + (full - setup) / share
        assert!((half - (setup + (full - setup) / 0.5)).abs() < 1e-9, "full {full} half {half}");
        // the analytic model path stays share-blind (contended pricing is
        // the planner's job)
        assert_eq!(f.transfer_det_us(0, MB), f.transfer_det_us(1, MB));
        // restoring the whole rail restores times bit-exactly
        f.set_rail_share(0, 1.0);
        assert_eq!(f.ring_step(0, MB).unwrap(), full);
        // shares clamp to the preemption floor
        f.set_rail_share(0, 0.0);
        assert_eq!(f.rail_share(0), MIN_RAIL_SHARE);
    }

    #[test]
    fn rail_share_does_not_perturb_rng_streams() {
        // same seed, different shares: jittered times must differ only by
        // the deterministic inflation, i.e. the jitter draws are identical
        let (mut a, mut b) = (dual_tcp(4), dual_tcp(4));
        a.jitter_sigma = 0.05;
        b.jitter_sigma = 0.05;
        b.set_rail_share(0, 0.25);
        a.begin_op();
        b.begin_op();
        let setup = a.rails[0].protocol.setup_us;
        for _ in 0..8 {
            let ta = a.transfer(0, MB).unwrap();
            let tb = b.transfer(0, MB).unwrap();
            // invert the inflation on the pre-jitter base: both sides drew
            // the same multiplier iff the ratio of (t) to base matches
            let base_a = a.transfer_det_us(0, MB);
            let base_b = setup + (b.transfer_det_us(0, MB) - setup) / 0.25;
            assert!((ta / base_a - tb / base_b).abs() < 1e-12);
        }
    }

    #[test]
    fn occupancy_ledger_accumulates_and_resets() {
        let mut f = dual_tcp(4);
        assert_eq!(f.occupancy_us(0), 0.0);
        let t0 = f.ring_step(0, MB).unwrap();
        let t1 = f.ring_step(0, MB).unwrap();
        let u = f.transfer(1, MB).unwrap();
        assert!((f.occupancy_us(0) - (t0 + t1)).abs() < 1e-9);
        assert!((f.occupancy_us(1) - u).abs() < 1e-9);
        f.reset_occupancy();
        assert_eq!(f.occupancy_us(0), 0.0);
        assert_eq!(f.occupancy_us(1), 0.0);
    }

    #[test]
    fn loss_charges_retransmits_reproducibly() {
        let mk = || dual_tcp(4).with_degrade(DegradeSchedule::none().loss(0, 0.0, 1e9, 0.3));
        let (mut a, mut b) = (mk(), mk());
        let mut retried = false;
        for _ in 0..32 {
            let ta = a.transfer(0, MB).unwrap();
            assert_eq!(ta, b.transfer(0, MB).unwrap());
            if ta > a.transfer_det_us(0, MB) {
                retried = true;
            }
        }
        assert!(retried, "0.3 loss over 32 messages must retransmit at least once");
        assert_eq!(a.retries_on(0), b.retries_on(0));
        assert!(a.retries_on(0) > 0);
        // the lossless rail drew nothing and charged nothing extra
        assert_eq!(a.retries_on(1), 0);
        assert_eq!(a.transfer(1, MB).unwrap(), a.transfer_det_us(1, MB));
    }

    #[test]
    fn zero_loss_leaves_sequences_bit_exact() {
        // a schedule whose windows are all elsewhere must not perturb the
        // RNG stream of an unaffected rail — fault-free runs stay bit-exact
        let mk = |sched: DegradeSchedule| {
            let mut f = dual_tcp(4).with_degrade(sched);
            f.jitter_sigma = 0.05;
            f
        };
        let mut clean = mk(DegradeSchedule::none());
        let mut other = mk(DegradeSchedule::none().loss(1, 0.0, 1e9, 0.5));
        clean.begin_op();
        other.begin_op();
        for _ in 0..8 {
            assert_eq!(clean.ring_step(0, MB).unwrap(), other.ring_step(0, MB).unwrap());
        }
    }

    #[test]
    fn brownout_stretches_transfer_not_setup_and_expires() {
        let mut f = dual_tcp(4);
        let full = f.ring_step(0, MB).unwrap();
        let now = f.now_us();
        f.set_degrade(DegradeSchedule::none().brownout(0, now, now + 1e6, 0.5));
        let dim = f.ring_step(0, MB).unwrap();
        let setup = f.rails[0].protocol.setup_us;
        // same setup-preserving algebra as set_rail_share
        assert!((dim - (setup + (full - setup) / 0.5)).abs() < 1e-9, "full {full} dim {dim}");
        // invisible to the static cost model
        assert_eq!(f.transfer_det_us(0, MB), f.transfer_det_us(1, MB));
        // window over: bit-exact restoration
        f.advance(2e6);
        assert_eq!(f.ring_step(0, MB).unwrap(), full);
    }

    #[test]
    fn flap_downs_rail_on_odd_half_periods() {
        let mut f = dual_tcp(4).with_degrade(DegradeSchedule::none().flap(1, 0.0, 1e9, 1e6));
        // first half-period: up
        assert!(f.transfer(1, MB).is_ok());
        f.advance(1.5e6 - f.now_us());
        // second half-period: down, crash-like
        assert!(f.transfer(1, MB).is_err());
        assert!(f.transfer(0, MB).is_ok(), "other rail unaffected");
        f.advance(2.5e6 - f.now_us());
        assert!(f.transfer(1, MB).is_ok(), "back up on the next period");
    }

    #[test]
    fn windowed_straggler_active_only_inside_window() {
        let mut f = dual_tcp(4);
        let clean = f.transfer(1, MB).unwrap();
        let now = f.now_us();
        f.inject_straggler_window(1, 400.0, 0.0, now + 1e5, now + 2e5);
        // before the window: untouched
        assert_eq!(f.transfer(1, MB).unwrap(), clean);
        f.advance(now + 1.5e5 - f.now_us());
        let stalled = f.transfer(1, MB).unwrap();
        assert!((stalled - clean - 400.0).abs() < 1e-6, "clean {clean} stalled {stalled}");
        // the batched ring step pays the same windowed stall
        let r0 = f.ring_step(0, MB).unwrap();
        let r1 = f.ring_step(1, MB).unwrap();
        assert!((r1 - r0 - 400.0).abs() < 1e-6);
        f.advance(now + 3e5 - f.now_us());
        assert_eq!(f.transfer(1, MB).unwrap(), clean);
    }

    #[test]
    fn retry_cap_blowout_declares_rail_down() {
        let mut f = dual_tcp(4).with_degrade(DegradeSchedule::none().loss(0, 0.0, 1e9, 0.999));
        // at 99.9% loss the cap is exhausted essentially immediately
        let mut died = false;
        for _ in 0..4 {
            if f.transfer(0, MB).is_err() {
                died = true;
                break;
            }
        }
        assert!(died, "retry cap must eventually declare the rail down");
        assert!(f.retries_on(0) > RETRY_CAP as u64);
    }

    #[test]
    fn corruption_charges_retransmits_reproducibly() {
        // integrity ON: every detected corruption is recharged like a lost
        // packet, on the SAME unified retry ledger (satellite: one
        // accounting path), plus the injection ledger for the ablation
        let mk = || dual_tcp(4).with_corrupt(CorruptSchedule::none().flip(0, 0.0, 1e9, 0.3));
        let (mut a, mut b) = (mk(), mk());
        let mut retried = false;
        for _ in 0..32 {
            let ta = a.transfer(0, MB).unwrap();
            assert_eq!(ta, b.transfer(0, MB).unwrap());
            if ta > a.transfer_det_us(0, MB) {
                retried = true;
            }
        }
        assert!(retried, "0.3 corruption over 32 messages must retransmit at least once");
        assert_eq!(a.retries_on(0), b.retries_on(0));
        assert_eq!(a.corruptions_on(0), b.corruptions_on(0));
        assert!(a.retries_on(0) > 0);
        assert_eq!(
            a.retries_on(0),
            a.corruptions_on(0),
            "with zero loss, every retry on the unified ledger is a corruption recharge"
        );
        // the clean rail drew nothing and charged nothing extra
        assert_eq!(a.retries_on(1), 0);
        assert_eq!(a.corruptions_on(1), 0);
        assert_eq!(a.transfer(1, MB).unwrap(), a.transfer_det_us(1, MB));
    }

    #[test]
    fn corruption_without_integrity_is_silent_but_counted() {
        // integrity OFF: messages arrive on time, nothing hits the retry
        // ledger, but the injection ledger still counts every event so the
        // ablation can measure the escape rate
        let mut f = dual_tcp(4)
            .with_corrupt(CorruptSchedule::none().flip(0, 0.0, 1e9, 0.5))
            .with_integrity(false);
        for _ in 0..32 {
            assert_eq!(f.transfer(0, MB).unwrap(), f.transfer_det_us(0, MB));
        }
        assert_eq!(f.retries_on(0), 0, "silent corruption must not charge retransmits");
        assert!(f.corruptions_on(0) > 0, "0.5 corruption over 32 messages must inject");
    }

    #[test]
    fn zero_corruption_leaves_sequences_bit_exact() {
        // a schedule whose windows are all elsewhere must not perturb the
        // RNG stream of an unaffected rail — clean runs stay bit-exact
        let mk = |sched: CorruptSchedule| {
            let mut f = dual_tcp(4).with_corrupt(sched);
            f.jitter_sigma = 0.05;
            f
        };
        let mut clean = mk(CorruptSchedule::none());
        let mut other = mk(CorruptSchedule::none().flip(1, 0.0, 1e9, 0.5));
        clean.begin_op();
        other.begin_op();
        for _ in 0..8 {
            assert_eq!(clean.ring_step(0, MB).unwrap(), other.ring_step(0, MB).unwrap());
        }
    }

    #[test]
    fn corruption_retry_cap_blowout_declares_rail_down() {
        let mut f = dual_tcp(4).with_corrupt(CorruptSchedule::none().stuck(0, 0.0, 1e9, 0.999));
        // at 99.9% corruption the unified cap is exhausted immediately
        let mut died = false;
        for _ in 0..4 {
            if f.transfer(0, MB).is_err() {
                died = true;
                break;
            }
        }
        assert!(died, "corruption recharges must hit the same retry-cap crash path");
        assert!(f.retries_on(0) > RETRY_CAP as u64);
    }

    #[test]
    fn pending_poison_drains_once_per_op() {
        let mut f = dual_tcp(4)
            .with_corrupt(CorruptSchedule::none().flip(0, 0.0, 1e9, 0.9))
            .with_integrity(false);
        f.begin_op();
        let mut ctxs = f.rail_ctxs(&[0]);
        let ctx = &mut ctxs[0];
        assert!(!ctx.integrity_on());
        for _ in 0..8 {
            let _ = ctx.ring_step(MB).unwrap();
        }
        let n = ctx.drain_corruption();
        assert!(n > 0, "0.9 corruption over 8 rounds must queue poison");
        assert_eq!(ctx.drain_corruption(), 0, "drain must clear the pending queue");
    }

    #[test]
    fn probation_rail_serves_traffic() {
        let mut f = dual_tcp(4);
        f.deregister(1);
        assert!(f.transfer(1, MB).is_err());
        assert_eq!(f.healthy_rails(), vec![0]);
        f.readmit_probation(1);
        assert_eq!(f.healthy_rails(), vec![0, 1], "canary is back in the dataplane");
        assert!(f.transfer(1, MB).is_ok());
        // double-deregister is idempotent (no double cpu.unregister)
        f.deregister(1);
        f.deregister(1);
        assert!(f.transfer(1, MB).is_err());
    }
}
