//! Cluster topologies from the paper's Table 2 (local / cloud /
//! supercomputer testbeds), rail-set construction rules, and the
//! multi-level [`TopologyTree`] the hierarchical collective planner
//! consumes (ordered node < rack < pod levels, non-uniform group sizes,
//! per-group rail-affinity masks).

use crate::net::protocol::ProtoKind;
use crate::net::rail::{NicSpec, Rail};
use crate::Result;
use crate::util::error::Error;

/// Per-node hardware inventory.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub cpu: &'static str,
    pub cores: f64,
    pub gpus: usize,
    pub nics: Vec<NicSpec>,
}

/// An intra-group interconnect: nodes are organised in groups of
/// `group_size` (a rack / pod / chassis) joined by a full-bisection local
/// fabric that is much faster than the inter-group rails. The legacy
/// single-level view of a [`TopologyTree`] level — the collective planner
/// (`coordinator::planner`) still prices its two-level schedules through
/// it, and a one-level tree degenerates to exactly this.
#[derive(Debug, Clone, PartialEq)]
pub struct IntraLink {
    /// Nodes per group; 1 disables grouping (degenerates to flat).
    pub group_size: usize,
    /// Effective intra-group bandwidth per node (MB/s).
    pub bw_mbps: f64,
    /// Per-message setup latency on the local fabric (us).
    pub setup_us: f64,
}

/// How one topology level's groups tile the node set.
#[derive(Debug, Clone, PartialEq)]
pub enum GroupShape {
    /// Every group at this level spans the same number of nodes.
    Uniform(usize),
    /// Explicit per-group node counts, in node order (a partially
    /// populated rack row, a mixed-chassis pod). Must sum to the node
    /// count the topology is bound to.
    Explicit(Vec<usize>),
}

/// One level of a hierarchical topology (innermost first): groups of
/// nodes joined by a local fabric that is faster than the inter-group
/// rails, optionally with per-group rail-affinity masks.
#[derive(Debug, Clone, PartialEq)]
pub struct TopoLevel {
    pub name: String,
    pub shape: GroupShape,
    /// Effective local-fabric bandwidth per node at this level (MB/s).
    pub bw_mbps: f64,
    /// Per-message setup latency on this level's fabric (us).
    pub setup_us: f64,
    /// Optional per-group rail-affinity bitmasks (one per group, bit `r`
    /// = rail `r` may carry this group's inter-level traffic). `None`
    /// means every rail is allowed. Because every rail-borne collective
    /// spans all nodes, a rail is usable for an op only if EVERY group at
    /// every level allows it — see [`TopologyTree::allowed_rail_mask`].
    pub affinity: Option<Vec<u64>>,
}

impl TopoLevel {
    pub fn uniform(name: &str, group: usize, bw_mbps: f64, setup_us: f64) -> TopoLevel {
        TopoLevel {
            name: name.to_string(),
            shape: GroupShape::Uniform(group),
            bw_mbps,
            setup_us,
            affinity: None,
        }
    }

    pub fn explicit(name: &str, sizes: Vec<usize>, bw_mbps: f64, setup_us: f64) -> TopoLevel {
        TopoLevel {
            name: name.to_string(),
            shape: GroupShape::Explicit(sizes),
            bw_mbps,
            setup_us,
            affinity: None,
        }
    }

    /// Number of groups when this level tiles `nodes` exactly; 0 when it
    /// cannot (non-dividing uniform size, explicit sizes not summing up).
    fn group_count(&self, nodes: usize) -> usize {
        match &self.shape {
            GroupShape::Uniform(g) => {
                if *g >= 1 && nodes % *g == 0 {
                    nodes / *g
                } else {
                    0
                }
            }
            GroupShape::Explicit(v) => {
                if !v.is_empty()
                    && v.iter().all(|&s| s >= 1)
                    && v.iter().sum::<usize>() == nodes
                {
                    v.len()
                } else {
                    0
                }
            }
        }
    }
}

/// Per-group size iterator for one level (allocation-free: the planner's
/// hot path walks explicit shapes with cursors, never a scratch vector).
enum SizeIter<'a> {
    Uniform { size: usize, left: usize },
    Explicit(std::slice::Iter<'a, usize>),
}

impl Iterator for SizeIter<'_> {
    type Item = usize;
    fn next(&mut self) -> Option<usize> {
        match self {
            SizeIter::Uniform { size, left } => {
                if *left == 0 {
                    None
                } else {
                    *left -= 1;
                    Some(*size)
                }
            }
            SizeIter::Explicit(it) => it.next().copied(),
        }
    }
}

/// A validated-on-bind multi-level topology: ordered levels, innermost
/// (smallest groups) first — e.g. node < rack < pod. No levels = flat
/// (all the paper's testbeds). The hierarchical planner cuts the tree at
/// any valid depth: cut 0 is the flat ring, cut 1 the legacy two-level
/// schedule, deeper cuts stack one reduce-scatter/allgather phase pair
/// per engaged level around the inter-group rail ring.
///
/// The tree itself is node-count agnostic (uniform levels describe any
/// cluster size); [`TopologyTree::validate`] binds it to a concrete
/// `(nodes, rails)` pair and is where non-dividing group sizes, broken
/// nesting and rail-emptying affinity masks are rejected with
/// `Error::Topology`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TopologyTree {
    pub levels: Vec<TopoLevel>,
}

impl TopologyTree {
    /// The flat (ungrouped) topology.
    pub fn flat() -> TopologyTree {
        TopologyTree { levels: Vec::new() }
    }

    /// Uniform levels, innermost first: `(name, group_size, bw, setup)`.
    pub fn uniform(levels: &[(&str, usize, f64, f64)]) -> TopologyTree {
        TopologyTree {
            levels: levels
                .iter()
                .map(|&(name, g, bw, setup)| TopoLevel::uniform(name, g, bw, setup))
                .collect(),
        }
    }

    /// The legacy single-level view (`group_size <= 1` stays flat).
    pub fn from_intra(intra: Option<IntraLink>) -> TopologyTree {
        match intra {
            Some(l) if l.group_size > 1 => TopologyTree {
                levels: vec![TopoLevel::uniform("group", l.group_size, l.bw_mbps, l.setup_us)],
            },
            _ => TopologyTree::flat(),
        }
    }

    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    pub fn is_flat(&self) -> bool {
        self.levels.is_empty()
    }

    fn size_iter(&self, level: usize, nodes: usize) -> SizeIter<'_> {
        match &self.levels[level].shape {
            GroupShape::Uniform(g) => SizeIter::Uniform {
                size: *g,
                left: if *g >= 1 { nodes / *g } else { 0 },
            },
            GroupShape::Explicit(v) => SizeIter::Explicit(v.iter()),
        }
    }

    /// Groups at `level` when it tiles `nodes` exactly, else 0.
    pub fn group_count(&self, level: usize, nodes: usize) -> usize {
        self.levels[level].group_count(nodes)
    }

    /// Largest group at `level` (the lockstep phase's critical path).
    pub fn max_group(&self, level: usize) -> usize {
        match &self.levels[level].shape {
            GroupShape::Uniform(g) => *g,
            GroupShape::Explicit(v) => v.iter().copied().max().unwrap_or(0),
        }
    }

    /// Largest number of level-`level - 1` subgroups (single nodes for
    /// level 0) inside any one group at `level` — the ring length of that
    /// level's lockstep phase.
    pub fn max_subgroups(&self, level: usize, nodes: usize) -> usize {
        if level == 0 {
            return self.max_group(0);
        }
        let mut inner = self.size_iter(level - 1, nodes);
        let mut best = 0usize;
        for outer in self.size_iter(level, nodes) {
            let mut consumed = 0usize;
            let mut count = 0usize;
            while consumed < outer {
                match inner.next() {
                    Some(s) => {
                        consumed += s;
                        count += 1;
                    }
                    None => return best.max(count),
                }
            }
            best = best.max(count);
        }
        best
    }

    /// `level` as the legacy [`IntraLink`] view — `Some` only for uniform
    /// shapes (the two-level schedule family cannot describe non-uniform
    /// groups; those go through the multi-level family instead).
    pub fn level_link(&self, level: usize) -> Option<IntraLink> {
        let lv = self.levels.get(level)?;
        match lv.shape {
            GroupShape::Uniform(g) => Some(IntraLink {
                group_size: g,
                bw_mbps: lv.bw_mbps,
                setup_us: lv.setup_us,
            }),
            GroupShape::Explicit(_) => None,
        }
    }

    /// True when cutting the tree after its innermost `depth` levels is a
    /// runnable hierarchical schedule on an `nodes`-node fabric: every
    /// engaged level tiles the node set, each strictly coarsens the one
    /// below, and at least two top-level groups remain for the inter ring.
    pub fn valid_cut_depth(&self, depth: usize, nodes: usize) -> bool {
        if depth == 0 || depth > self.levels.len() || nodes == 0 {
            return false;
        }
        let mut prev_groups = nodes;
        for lv in 0..depth {
            let g = self.group_count(lv, nodes);
            if g == 0 || g >= prev_groups {
                return false;
            }
            prev_groups = g;
        }
        prev_groups >= 2
    }

    /// Deepest valid cut for `nodes` (0 = only flat schedules apply).
    pub fn max_valid_depth(&self, nodes: usize) -> usize {
        (1..=self.levels.len())
            .filter(|&d| self.valid_cut_depth(d, nodes))
            .max()
            .unwrap_or(0)
    }

    /// True when any level carries affinity masks (the coordinator skips
    /// rail filtering entirely for unconstrained trees).
    pub fn has_affinity(&self) -> bool {
        self.levels.iter().any(|lv| lv.affinity.is_some())
    }

    /// Rails allowed by EVERY group at every level (missing affinity =
    /// all rails). Since a rail-borne collective spans all nodes, this is
    /// the set the coordinator may assign payload to; 0 means the masks
    /// are unsatisfiable together.
    pub fn allowed_rail_mask(&self, n_rails: usize) -> u64 {
        let mut allow = rails_mask(n_rails);
        for lv in &self.levels {
            if let Some(masks) = &lv.affinity {
                for &m in masks {
                    allow &= m;
                }
            }
        }
        allow
    }

    /// Fraction of affinity-carrying groups (across every level) that
    /// admit `rail` — the soft-affinity weight the Load Balancer scales a
    /// rail's bandwidth estimate by. 1.0 on unconstrained trees (no group
    /// objects to the rail) down to 0.0 when no group admits it.
    pub fn rail_admit_fraction(&self, rail: usize) -> f64 {
        let mut total = 0usize;
        let mut admit = 0usize;
        for lv in &self.levels {
            if let Some(masks) = &lv.affinity {
                for &m in masks {
                    total += 1;
                    if rail < 64 && m & (1u64 << rail) != 0 {
                        admit += 1;
                    }
                }
            }
        }
        if total == 0 {
            1.0
        } else {
            admit as f64 / total as f64
        }
    }

    /// Rails admitted by AT LEAST ONE group somewhere in the tree — the
    /// soft-affinity rail set. A rail only some groups admit still helps
    /// the groups that have it (the Load Balancer down-weights it by
    /// [`TopologyTree::rail_admit_fraction`] instead of banning it the
    /// way [`TopologyTree::allowed_rail_mask`]'s intersection does).
    pub fn union_rail_mask(&self, n_rails: usize) -> u64 {
        if !self.has_affinity() {
            return rails_mask(n_rails);
        }
        let mut union = 0u64;
        for lv in &self.levels {
            if let Some(masks) = &lv.affinity {
                for &m in masks {
                    union |= m;
                }
            }
        }
        union & rails_mask(n_rails)
    }

    /// Group start/end offsets at `level` (validation only — allocates).
    fn boundaries(&self, level: usize, nodes: usize) -> Vec<usize> {
        let mut b = vec![0usize];
        let mut acc = 0usize;
        for s in self.size_iter(level, nodes) {
            acc += s;
            b.push(acc);
        }
        b
    }

    /// Bind the tree to a concrete cluster: `nodes` participating nodes on
    /// `n_rails` rails (`n_rails == 0` = rail count unknown, affinity
    /// masks checked for non-emptiness only). Every structural invariant
    /// the planner later relies on is enforced here with a precise
    /// `Error::Topology`:
    ///
    /// * every level's groups cover all nodes exactly (uniform sizes must
    ///   divide the node count — the old `ClusterSpec::pods` silently
    ///   accepted non-dividing groups),
    /// * levels strictly nest (each level's boundaries align with the one
    ///   below and strictly coarsen it),
    /// * sane fabric parameters (positive bandwidth, non-negative setup),
    /// * affinity masks never empty a group's rail set, and some rail is
    ///   allowed by every group.
    pub fn validate(&self, nodes: usize, n_rails: usize) -> Result<()> {
        if nodes == 0 {
            return Err(Error::Topology("cluster has zero nodes".into()));
        }
        if n_rails > 64 {
            // Affinity masks are u64 bitmasks: rails beyond bit 63 cannot be
            // expressed, and every consumer used to silently treat them as
            // always-allowed, bypassing affinity on large fabrics.
            return Err(Error::Topology(format!(
                "{n_rails} rails exceed the 64-rail affinity-mask limit"
            )));
        }
        let mut prev_bounds: Vec<usize> = (0..=nodes).collect();
        let mut prev_groups = nodes;
        for (level_idx, lv) in self.levels.iter().enumerate() {
            if !lv.bw_mbps.is_finite() || lv.bw_mbps <= 0.0 {
                return Err(Error::Topology(format!(
                    "level `{}`: bandwidth must be positive, got {}",
                    lv.name, lv.bw_mbps
                )));
            }
            if !lv.setup_us.is_finite() || lv.setup_us < 0.0 {
                return Err(Error::Topology(format!(
                    "level `{}`: setup latency must be >= 0, got {}",
                    lv.name, lv.setup_us
                )));
            }
            match &lv.shape {
                GroupShape::Uniform(g) => {
                    if *g == 0 {
                        return Err(Error::Topology(format!(
                            "level `{}`: zero group size",
                            lv.name
                        )));
                    }
                    if nodes % *g != 0 {
                        return Err(Error::Topology(format!(
                            "level `{}`: group size {} does not divide the {}-node cluster",
                            lv.name, g, nodes
                        )));
                    }
                }
                GroupShape::Explicit(v) => {
                    if v.is_empty() || v.iter().any(|&s| s == 0) {
                        return Err(Error::Topology(format!(
                            "level `{}`: explicit group sizes must be non-empty and positive",
                            lv.name
                        )));
                    }
                    let sum: usize = v.iter().sum();
                    if sum != nodes {
                        return Err(Error::Topology(format!(
                            "level `{}`: group sizes sum to {}, cluster has {} nodes",
                            lv.name, sum, nodes
                        )));
                    }
                }
            }
            let bounds = self.boundaries(level_idx, nodes);
            let groups = bounds.len() - 1;
            if groups >= prev_groups {
                return Err(Error::Topology(format!(
                    "level `{}` must strictly coarsen the level below it ({} vs {} groups)",
                    lv.name, groups, prev_groups
                )));
            }
            for b in &bounds {
                if prev_bounds.binary_search(b).is_err() {
                    return Err(Error::Topology(format!(
                        "level `{}`: group boundary at node {} splits an inner group",
                        lv.name, b
                    )));
                }
            }
            if let Some(masks) = &lv.affinity {
                if masks.len() != groups {
                    return Err(Error::Topology(format!(
                        "level `{}`: {} affinity masks for {} groups",
                        lv.name,
                        masks.len(),
                        groups
                    )));
                }
                for (gi, &m) in masks.iter().enumerate() {
                    if m == 0 {
                        return Err(Error::Topology(format!(
                            "level `{}` group {}: affinity mask empties the group's rail set",
                            lv.name, gi
                        )));
                    }
                    if m & rails_mask(n_rails) == 0 {
                        return Err(Error::Topology(format!(
                            "level `{}` group {}: affinity mask names no existing rail (cluster has {})",
                            lv.name, gi, n_rails
                        )));
                    }
                }
            }
            prev_bounds = bounds;
            prev_groups = groups;
        }
        if self.allowed_rail_mask(n_rails) == 0 {
            return Err(Error::Topology(
                "affinity masks leave no rail usable by every group".into(),
            ));
        }
        Ok(())
    }

    /// Rebind this tree (bound to `nodes` nodes) over the surviving set
    /// after `departed` nodes (original numbering) leave. Group sizes
    /// shrink by their departed members; emptied groups are dropped along
    /// with their affinity masks; uniform levels whose groups no longer
    /// share one size degrade to explicit shapes instead of erroring; a
    /// level that stops coarsening the one below (every surviving group a
    /// singleton, or as many groups as the level below) is dropped
    /// entirely. The result is re-validated against the survivor count so
    /// every planner invariant holds on the new tree.
    ///
    /// Pure: `self` is untouched, so a failed rebind (e.g. affinity masks
    /// left unsatisfiable by the departures) leaves the caller free to
    /// keep running on the old membership.
    pub fn rebind(&self, nodes: usize, departed: &[usize], n_rails: usize) -> Result<TopologyTree> {
        let mut gone = vec![false; nodes];
        for &d in departed {
            if d >= nodes {
                return Err(Error::Topology(format!(
                    "departed node {d} outside the {nodes}-node cluster"
                )));
            }
            if gone[d] {
                return Err(Error::Topology(format!("node {d} departed twice")));
            }
            gone[d] = true;
        }
        let survivors = nodes - departed.len();
        if survivors == 0 {
            return Err(Error::Topology("membership change leaves zero nodes".into()));
        }
        let mut out = TopologyTree { levels: Vec::new() };
        let mut prev_groups = survivors;
        for (li, lv) in self.levels.iter().enumerate() {
            let bounds = self.boundaries(li, nodes);
            let mut sizes: Vec<usize> = Vec::new();
            let mut kept_masks: Vec<u64> = Vec::new();
            for (gi, w) in bounds.windows(2).enumerate() {
                let s = (w[0]..w[1]).filter(|&n| !gone[n]).count();
                if s == 0 {
                    continue;
                }
                sizes.push(s);
                if let Some(masks) = &lv.affinity {
                    kept_masks.push(masks[gi]);
                }
            }
            let groups = sizes.len();
            if groups >= prev_groups {
                // No longer coarsens what's below (all singletons, or as
                // many groups as subunits): the level carries no structure
                // over the surviving set.
                continue;
            }
            let uniform = sizes.windows(2).all(|p| p[0] == p[1]);
            let shape = if uniform {
                GroupShape::Uniform(sizes[0])
            } else {
                GroupShape::Explicit(sizes)
            };
            out.levels.push(TopoLevel {
                name: lv.name.clone(),
                shape,
                bw_mbps: lv.bw_mbps,
                setup_us: lv.setup_us,
                affinity: lv.affinity.as_ref().map(|_| kept_masks),
            });
            prev_groups = groups;
        }
        out.validate(survivors, n_rails)?;
        Ok(out)
    }
}

/// All-ones mask over the first `n_rails` rails (`0` = unknown count =
/// unconstrained).
fn rails_mask(n_rails: usize) -> u64 {
    if n_rails == 0 || n_rails >= 64 {
        u64::MAX
    } else {
        (1u64 << n_rails) - 1
    }
}

/// Parse a `topology=` spec string.
///
/// `flat`, or `<`-separated levels innermost first, each
/// `name:sizes[:bw_mbps:setup_us][@affinity]` where `sizes` is one uint
/// (uniform groups) or `+`-separated uints (explicit non-uniform sizes),
/// and `affinity` lists per-group rail sets — groups separated by `;`,
/// rail ids within a group by `.`. Omitted fabric parameters default by
/// level position (inner fabrics faster).
///
/// Examples: `rack:4<pod:16`, `group:2+6+4+4`, `pod:8@0.1;1.2`,
/// `rack:4:5000:8<pod:16:2000:12`.
pub fn parse_topology(s: &str) -> Result<TopologyTree> {
    let s = s.trim();
    if s.is_empty() || s.eq_ignore_ascii_case("flat") {
        return Ok(TopologyTree::flat());
    }
    let mut levels = Vec::new();
    for (li, part) in s.split('<').enumerate() {
        let part = part.trim();
        let (core, aff) = match part.split_once('@') {
            Some((c, a)) => (c.trim(), Some(a.trim())),
            None => (part, None),
        };
        let fields: Vec<&str> = core.split(':').map(|f| f.trim()).collect();
        if fields.len() != 2 && fields.len() != 4 {
            return Err(Error::Config(format!(
                "topology level `{part}`: expected name:sizes[:bw:setup]"
            )));
        }
        let name = fields[0];
        if name.is_empty() {
            return Err(Error::Config(format!("topology level `{part}`: empty name")));
        }
        let sizes = fields[1]
            .split('+')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .map_err(|_| Error::Config(format!("topology level `{name}`: bad size `{t}`")))
            })
            .collect::<Result<Vec<usize>>>()?;
        let (bw_mbps, setup_us) = if fields.len() == 4 {
            let bw = fields[2].parse::<f64>().map_err(|_| {
                Error::Config(format!("topology level `{name}`: bad bandwidth `{}`", fields[2]))
            })?;
            let setup = fields[3].parse::<f64>().map_err(|_| {
                Error::Config(format!("topology level `{name}`: bad setup `{}`", fields[3]))
            })?;
            (bw, setup)
        } else {
            default_level_params(li)
        };
        let shape = if sizes.len() == 1 {
            GroupShape::Uniform(sizes[0])
        } else {
            GroupShape::Explicit(sizes)
        };
        let affinity = match aff {
            None => None,
            Some(a) => {
                let mut masks = Vec::new();
                for grp in a.split(';') {
                    let mut mask = 0u64;
                    for r in grp.split('.') {
                        let r: usize = r.trim().parse().map_err(|_| {
                            Error::Config(format!(
                                "topology level `{name}`: bad affinity rail `{r}`"
                            ))
                        })?;
                        if r >= 64 {
                            return Err(Error::Config(format!(
                                "topology level `{name}`: affinity rail {r} exceeds the 64-rail mask"
                            )));
                        }
                        mask |= 1u64 << r;
                    }
                    masks.push(mask);
                }
                Some(masks)
            }
        };
        levels.push(TopoLevel {
            name: name.to_string(),
            shape,
            bw_mbps,
            setup_us,
            affinity,
        });
    }
    Ok(TopologyTree { levels })
}

/// Default per-level fabric parameters when the spec omits them (inner
/// fabrics are faster: NVLink-class rack, electrical pod, optical beyond).
fn default_level_params(level: usize) -> (f64, f64) {
    match level {
        0 => (5000.0, 15.0),
        1 => (2000.0, 12.0),
        _ => (1000.0, 20.0),
    }
}

/// A named testbed.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub node: NodeSpec,
    pub max_nodes: usize,
    /// Hierarchical grouping (empty = flat: the paper's testbeds).
    pub topo: TopologyTree,
}

impl ClusterSpec {
    /// Paper's 8-node local platform: Xeon 6230R, 2x V100, 3x Eth 100G,
    /// 1x IB 100G (SHARP), 1x TH 128G (GLEX).
    pub fn local() -> ClusterSpec {
        ClusterSpec {
            name: "local",
            node: NodeSpec {
                cpu: "Xeon Gold 6230R",
                cores: 52.0,
                gpus: 2,
                nics: vec![
                    NicSpec::MCX623106AN,
                    NicSpec::MCX623106AN,
                    NicSpec::MCX623106AN,
                    NicSpec::CONNECTX5,
                    NicSpec::TH_NIC,
                ],
            },
            max_nodes: 8,
            topo: TopologyTree::flat(),
        }
    }

    /// Grouped variant of the local testbed: same per-node NIC inventory,
    /// nodes organised in pods of `group` with a full-bisection intra-pod
    /// interconnect (NVLink-class pooled bandwidth, far faster than any
    /// single rail). This is the topology the hierarchical two-level
    /// planner targets; `group <= 1` keeps it flat. The group size must
    /// divide the node count the coordinator is built with —
    /// [`TopologyTree::validate`] rejects the rest.
    pub fn pods(group: usize) -> ClusterSpec {
        let mut c = ClusterSpec::local();
        c.name = "pods";
        c.max_nodes = 64;
        if group > 1 {
            c.topo = TopologyTree::uniform(&[("pod", group, 5000.0, 15.0)]);
        }
        c
    }

    /// Two-level hierarchy: racks of `rack` nodes (NVLink-class local
    /// fabric) inside pods of `pod` nodes (slower electrical pod fabric,
    /// still far above any rail's CPU-bound collective bandwidth), rails
    /// crossing pods — the node < rack < pod structure the paper's
    /// 128-node supercomputer results exploit. Degenerate sizes (≤ 1)
    /// drop their level.
    pub fn racked_pods(rack: usize, pod: usize) -> ClusterSpec {
        let mut c = ClusterSpec::local();
        c.name = "racked-pods";
        c.max_nodes = 128;
        let mut levels = Vec::new();
        if rack > 1 {
            levels.push(TopoLevel::uniform("rack", rack, 5000.0, 8.0));
        }
        if pod > 1 && pod > rack {
            levels.push(TopoLevel::uniform("pod", pod, 2000.0, 12.0));
        }
        c.topo = TopologyTree { levels };
        c
    }

    /// Non-uniform single-level variant: explicit per-group node counts
    /// (e.g. a partially populated rack row). The sizes must sum to the
    /// node count the coordinator is built with.
    pub fn grouped(sizes: Vec<usize>) -> ClusterSpec {
        let mut c = ClusterSpec::local();
        c.name = "grouped";
        c.max_nodes = 64;
        c.topo = TopologyTree {
            levels: vec![TopoLevel::explicit("group", sizes, 5000.0, 15.0)],
        };
        c
    }

    /// Attach per-group rail-affinity masks to topology level `level`
    /// (innermost = 0). Mask sanity is checked at
    /// [`TopologyTree::validate`] time, when the rail count is known.
    pub fn with_affinity(mut self, level: usize, masks: Vec<u64>) -> ClusterSpec {
        self.topo.levels[level].affinity = Some(masks);
        self
    }

    /// Legacy single-level view: the innermost topology level as an
    /// [`IntraLink`] (None on flat clusters and non-uniform levels).
    pub fn intra(&self) -> Option<IntraLink> {
        self.topo.level_link(0)
    }

    /// 16-node cloud platform: Xeon 5318Y, 1x V100, 1x Eth, 1x IB.
    pub fn cloud() -> ClusterSpec {
        ClusterSpec {
            name: "cloud",
            node: NodeSpec {
                cpu: "Xeon Gold 5318Y",
                cores: 48.0,
                gpus: 1,
                nics: vec![NicSpec::MCX623106AN, NicSpec::CONNECTX5],
            },
            max_nodes: 16,
            topo: TopologyTree::flat(),
        }
    }

    /// 128-node supercomputer: EPYC 7452, 1 Gbps Eth + 56 Gbps IB (the
    /// paper throttles the IB NIC to 1 Gbps for the GPT runs).
    pub fn supercomputer() -> ClusterSpec {
        ClusterSpec {
            name: "supercomputer",
            node: NodeSpec {
                cpu: "AMD EPYC 7452",
                cores: 64.0,
                gpus: 0,
                nics: vec![NicSpec::BCM5720, NicSpec::CONNECTX3],
            },
            max_nodes: 128,
            topo: TopologyTree::flat(),
        }
    }

    /// Build the rail set for a protocol combination, e.g. `[Tcp, Tcp]` or
    /// `[Tcp, Sharp]`.
    ///
    /// Mirrors the paper's constraints: each node has one SHARP-capable and
    /// one GLEX-capable device, so homogeneous SHARP-SHARP / GLEX-GLEX (and
    /// SHARP+GLEX heterogeneous pairs needing two RDMA planes of the same
    /// device) are rejected exactly as in §5.1 Baselines.
    pub fn build_rails(&self, kinds: &[ProtoKind]) -> Result<Vec<Rail>> {
        let n_sharp = kinds.iter().filter(|k| **k == ProtoKind::Sharp).count();
        let n_glex = kinds.iter().filter(|k| **k == ProtoKind::Glex).count();
        if n_sharp > 1 || n_glex > 1 {
            return Err(Error::Topology(
                "hardware conflict: one SHARP (IB) and one GLEX (TH) device per node".into(),
            ));
        }
        let mut eth_iter = self.node.nics.iter().filter(|n| !n.rdma);
        let ib = self.node.nics.iter().find(|n| n.rdma && n.model.contains("ConnectX"));
        let th = self.node.nics.iter().find(|n| n.model == "TH-NIC");
        let mut rails = Vec::new();
        for (i, &k) in kinds.iter().enumerate() {
            let nic = match k {
                ProtoKind::Tcp => eth_iter
                    .next()
                    .cloned()
                    .ok_or_else(|| Error::Topology("not enough Ethernet NICs".into()))?,
                ProtoKind::Sharp => ib
                    .cloned()
                    .ok_or_else(|| Error::Topology("no SHARP-capable IB NIC".into()))?,
                ProtoKind::Glex => th
                    .cloned()
                    .ok_or_else(|| Error::Topology("no GLEX-capable TH NIC".into()))?,
            };
            rails.push(Rail::new(i, nic, k));
        }
        Ok(rails)
    }

    /// Virtual multi-rail: `count` virtual channels of `kind` multiplexed
    /// on ONE physical NIC (paper §4.1, Fig. 13's TCP-TCP(Eth¹)).
    pub fn build_virtual_rails(&self, kind: ProtoKind, count: usize) -> Result<Vec<Rail>> {
        let nic = match kind {
            ProtoKind::Tcp => self
                .node
                .nics
                .iter()
                .find(|n| !n.rdma)
                .cloned()
                .ok_or_else(|| Error::Topology("no Ethernet NIC".into()))?,
            _ => return Err(Error::Topology("virtual channels supported on TCP only".into())),
        };
        Ok((0..count)
            .map(|i| Rail::new(0, nic.clone(), kind).virtual_channel(i, count))
            .collect())
    }
}

/// Parse "tcp-tcp", "tcp-sharp", "tcp-glex", "tcp" into protocol combos.
pub fn parse_combo(s: &str) -> Result<Vec<ProtoKind>> {
    s.split('-')
        .map(|p| match p.trim().to_ascii_lowercase().as_str() {
            "tcp" => Ok(ProtoKind::Tcp),
            "sharp" => Ok(ProtoKind::Sharp),
            "glex" => Ok(ProtoKind::Glex),
            other => Err(Error::Config(format!("unknown protocol `{other}`"))),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_combos() {
        let c = ClusterSpec::local();
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp]).unwrap().len(), 2);
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Sharp]).unwrap().len(), 2);
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Glex]).unwrap().len(), 2);
        // paper §5.1: SHARP-SHARP / GLEX-GLEX impossible (device conflict)
        assert!(c.build_rails(&[ProtoKind::Sharp, ProtoKind::Sharp]).is_err());
        assert!(c.build_rails(&[ProtoKind::Glex, ProtoKind::Glex]).is_err());
    }

    #[test]
    fn cloud_has_one_eth() {
        let c = ClusterSpec::cloud();
        assert!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp]).is_err());
        assert!(c.build_rails(&[ProtoKind::Tcp]).is_ok());
    }

    #[test]
    fn virtual_rails_share_nic() {
        let c = ClusterSpec::local();
        let rails = c.build_virtual_rails(ProtoKind::Tcp, 2).unwrap();
        assert_eq!(rails.len(), 2);
        assert_eq!(rails[0].nic_sharing, 2);
        assert!(rails[0].wire_cap_mbps() < NicSpec::MCX623106AN.usable_mbps());
    }

    #[test]
    fn combo_parsing() {
        assert_eq!(parse_combo("tcp-sharp").unwrap(), vec![ProtoKind::Tcp, ProtoKind::Sharp]);
        assert!(parse_combo("tcp-bogus").is_err());
    }

    #[test]
    fn pods_topology_declares_intra_link() {
        let c = ClusterSpec::pods(4);
        let link = c.intra().expect("pods must have an intra link");
        assert_eq!(link.group_size, 4);
        assert!(link.bw_mbps > NicSpec::MCX623106AN.usable_mbps() / 4.0);
        // same NIC inventory as local: a 4-rail heterogeneous combo builds
        assert_eq!(
            c.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp, ProtoKind::Tcp, ProtoKind::Glex])
                .unwrap()
                .len(),
            4
        );
        // degenerate group stays flat
        assert!(ClusterSpec::pods(1).intra().is_none());
        assert!(ClusterSpec::local().intra().is_none());
        assert!(ClusterSpec::local().topo.is_flat());
    }

    #[test]
    fn pods_group_must_divide_node_count() {
        // regression: `pods` used to silently accept non-dividing group
        // sizes; binding the tree to the cluster now rejects them
        let topo = &ClusterSpec::pods(4).topo;
        assert!(topo.validate(16, 2).is_ok());
        let err = topo.validate(6, 2).unwrap_err();
        match err {
            Error::Topology(msg) => {
                assert!(msg.contains("does not divide"), "{msg}");
                assert!(msg.contains('6'), "{msg}");
            }
            other => panic!("expected Error::Topology, got {other:?}"),
        }
        // a single full-cluster group is structurally fine (the planner
        // just has no valid cut there)
        assert!(topo.validate(4, 2).is_ok());
        assert!(!topo.valid_cut_depth(1, 4));
    }

    #[test]
    fn racked_pods_tree_nests_and_cuts() {
        let c = ClusterSpec::racked_pods(4, 16);
        assert_eq!(c.topo.depth(), 2);
        assert!(c.topo.validate(32, 2).is_ok());
        // 32 nodes: 8 racks of 4 inside 2 pods of 16
        assert_eq!(c.topo.group_count(0, 32), 8);
        assert_eq!(c.topo.group_count(1, 32), 2);
        assert_eq!(c.topo.max_subgroups(0, 32), 4);
        assert_eq!(c.topo.max_subgroups(1, 32), 4); // 4 racks per pod
        assert!(c.topo.valid_cut_depth(1, 32));
        assert!(c.topo.valid_cut_depth(2, 32));
        assert_eq!(c.topo.max_valid_depth(32), 2);
        // 16 nodes leave a single pod: depth 2 has no inter ring
        assert!(c.topo.valid_cut_depth(1, 16));
        assert!(!c.topo.valid_cut_depth(2, 16));
        // pods must not split racks
        let broken = TopologyTree::uniform(&[("rack", 4, 5000.0, 8.0), ("pod", 6, 2000.0, 12.0)]);
        assert!(matches!(broken.validate(12, 2), Err(Error::Topology(_))));
        // non-coarsening repeat level is rejected
        let flat2 = TopologyTree::uniform(&[("a", 4, 5000.0, 8.0), ("b", 4, 2000.0, 12.0)]);
        assert!(matches!(flat2.validate(16, 2), Err(Error::Topology(_))));
    }

    #[test]
    fn explicit_groups_validate_and_measure() {
        let c = ClusterSpec::grouped(vec![2, 6, 4, 4]);
        assert!(c.topo.validate(16, 2).is_ok());
        assert_eq!(c.topo.group_count(0, 16), 4);
        assert_eq!(c.topo.max_group(0), 6);
        assert_eq!(c.topo.max_subgroups(0, 16), 6);
        assert!(c.topo.valid_cut_depth(1, 16));
        // two-level schedules cannot describe non-uniform groups
        assert!(c.topo.level_link(0).is_none());
        assert!(c.intra().is_none());
        // sizes must sum to the node count
        let err = c.topo.validate(15, 2).unwrap_err();
        assert!(matches!(err, Error::Topology(ref m) if m.contains("sum to 16")), "{err:?}");
    }

    #[test]
    fn affinity_masks_validate_and_intersect() {
        let ok = ClusterSpec::pods(4).with_affinity(0, vec![0b11, 0b01, 0b11, 0b01]);
        assert!(ok.topo.validate(16, 2).is_ok());
        assert_eq!(ok.topo.allowed_rail_mask(2), 0b01);
        // a zero mask empties its group's rail set
        let empty = ClusterSpec::pods(4).with_affinity(0, vec![0b11, 0, 0b11, 0b11]);
        assert!(matches!(empty.topo.validate(16, 2), Err(Error::Topology(ref m)) if m.contains("empties")));
        // masks that name only nonexistent rails are rejected
        let ghost = ClusterSpec::pods(4).with_affinity(0, vec![0b100; 4]);
        assert!(matches!(ghost.topo.validate(16, 2), Err(Error::Topology(_))));
        // per-group masks with an empty intersection are unsatisfiable
        let disjoint = ClusterSpec::pods(4).with_affinity(0, vec![0b01, 0b10, 0b01, 0b10]);
        assert!(matches!(disjoint.topo.validate(16, 2), Err(Error::Topology(ref m)) if m.contains("no rail usable")));
        // mask count must equal the group count
        let short = ClusterSpec::pods(4).with_affinity(0, vec![0b11; 3]);
        assert!(matches!(short.topo.validate(16, 2), Err(Error::Topology(_))));
    }

    #[test]
    fn soft_affinity_fractions_and_union() {
        // 3 of 4 pods admit rail 1, all admit rail 0
        let c = ClusterSpec::pods(4).with_affinity(0, vec![0b11, 0b01, 0b11, 0b11]);
        assert_eq!(c.topo.rail_admit_fraction(0), 1.0);
        assert_eq!(c.topo.rail_admit_fraction(1), 0.75);
        assert_eq!(c.topo.union_rail_mask(2), 0b11);
        // strict intersection bans rail 1 outright
        assert_eq!(c.topo.allowed_rail_mask(2), 0b01);
        // disjoint per-group masks: intersection empty, union keeps both
        let d = ClusterSpec::pods(4).with_affinity(0, vec![0b01, 0b10, 0b01, 0b10]);
        assert_eq!(d.topo.allowed_rail_mask(2), 0);
        assert_eq!(d.topo.union_rail_mask(2), 0b11);
        assert_eq!(d.topo.rail_admit_fraction(0), 0.5);
        // unconstrained trees: everything is weight 1 on every rail
        let f = ClusterSpec::local();
        assert_eq!(f.topo.rail_admit_fraction(0), 1.0);
        assert_eq!(f.topo.union_rail_mask(2), 0b11);
    }

    #[test]
    fn topology_spec_string_parses() {
        let t = parse_topology("rack:4<pod:16").unwrap();
        assert_eq!(t.depth(), 2);
        assert_eq!(t.levels[0].name, "rack");
        assert_eq!(t.levels[0].shape, GroupShape::Uniform(4));
        assert_eq!(t.levels[1].shape, GroupShape::Uniform(16));
        assert!(t.levels[0].bw_mbps > t.levels[1].bw_mbps, "inner fabric faster by default");

        let t = parse_topology("group:2+6+4+4").unwrap();
        assert_eq!(t.levels[0].shape, GroupShape::Explicit(vec![2, 6, 4, 4]));

        let t = parse_topology("pod:8@0.1;1.2").unwrap();
        assert_eq!(t.levels[0].affinity, Some(vec![0b011, 0b110]));

        let t = parse_topology("rack:4:5000:8<pod:16:2000:12").unwrap();
        assert_eq!(t.levels[1].bw_mbps, 2000.0);
        assert_eq!(t.levels[1].setup_us, 12.0);

        assert!(parse_topology("flat").unwrap().is_flat());
        assert!(parse_topology("rack").is_err());
        assert!(parse_topology("rack:x").is_err());
        assert!(parse_topology("rack:4@0.99").is_err());
    }

    #[test]
    fn from_intra_round_trips() {
        let link = IntraLink { group_size: 4, bw_mbps: 5000.0, setup_us: 15.0 };
        let t = TopologyTree::from_intra(Some(link.clone()));
        assert_eq!(t.level_link(0), Some(link));
        assert!(TopologyTree::from_intra(None).is_flat());
        // group_size 1 degenerates to flat, like the old Option<IntraLink>
        let g1 = IntraLink { group_size: 1, bw_mbps: 5000.0, setup_us: 15.0 };
        assert!(TopologyTree::from_intra(Some(g1)).is_flat());
    }

    #[test]
    fn supercomputer_nics_are_slow() {
        let c = ClusterSpec::supercomputer();
        let eth = &c.node.nics[0];
        assert!(eth.usable_mbps() < 120.0);
    }

    #[test]
    fn validate_rejects_more_than_64_rails() {
        // regression: affinity consumers used to treat rails >= 64 as
        // always-allowed, silently bypassing masks on large fabrics
        let t = TopologyTree::flat();
        assert!(t.validate(8, 64).is_ok());
        let err = t.validate(8, 65).unwrap_err();
        assert!(
            matches!(err, Error::Topology(ref m) if m.contains("64-rail")),
            "{err:?}"
        );
        // and the soft-affinity weight no longer reports out-of-range
        // rails as universally admitted
        let c = ClusterSpec::pods(4).with_affinity(0, vec![0b11; 4]);
        assert_eq!(c.topo.rail_admit_fraction(64), 0.0);
    }

    #[test]
    fn rebind_degrades_uniform_to_explicit() {
        // 32 nodes as 8 racks of 4 in 2 pods of 16; node 2 departs
        let topo = ClusterSpec::racked_pods(4, 16).topo;
        let r = topo.rebind(32, &[2], 2).unwrap();
        assert_eq!(r.depth(), 2);
        assert_eq!(
            r.levels[0].shape,
            GroupShape::Explicit(vec![3, 4, 4, 4, 4, 4, 4, 4])
        );
        assert_eq!(r.levels[1].shape, GroupShape::Explicit(vec![15, 16]));
        assert!(r.validate(31, 2).is_ok());
        assert_eq!(r.max_valid_depth(31), 2);
    }

    #[test]
    fn rebind_drops_emptied_groups_and_masks() {
        // whole first rack [0..4) leaves: rack level stays uniform with one
        // fewer group, its affinity mask goes with it
        let topo = ClusterSpec::racked_pods(4, 16)
            .with_affinity(0, vec![0b01, 0b11, 0b11, 0b11, 0b11, 0b11, 0b11, 0b11])
            .topo;
        assert_eq!(topo.allowed_rail_mask(2), 0b01);
        let r = topo.rebind(32, &[0, 1, 2, 3], 2).unwrap();
        assert_eq!(r.levels[0].shape, GroupShape::Uniform(4));
        assert_eq!(r.levels[0].affinity.as_ref().unwrap().len(), 7);
        // the restrictive mask belonged to the departed rack
        assert_eq!(r.allowed_rail_mask(2), 0b11);
        assert_eq!(r.levels[1].shape, GroupShape::Explicit(vec![12, 16]));
    }

    #[test]
    fn rebind_drops_non_coarsening_levels() {
        // pods of 4 at 8 nodes; 3 of one pod's members leave -> groups
        // [1, 4]; then the other pod shrinks to singletons
        let topo = ClusterSpec::pods(4).topo;
        let r = topo.rebind(8, &[1, 2, 3], 2).unwrap();
        assert_eq!(r.levels[0].shape, GroupShape::Explicit(vec![1, 4]));
        // 6 of 8 leave, one survivor per pod: level carries no structure
        let r = topo.rebind(8, &[1, 2, 3, 5, 6, 7], 2).unwrap();
        assert!(r.is_flat());
    }

    #[test]
    fn rebind_rejects_bad_departures() {
        let topo = ClusterSpec::pods(4).topo;
        assert!(matches!(topo.rebind(8, &[8], 2), Err(Error::Topology(_))));
        assert!(matches!(topo.rebind(8, &[1, 1], 2), Err(Error::Topology(_))));
        assert!(matches!(
            topo.rebind(2, &[0, 1], 2),
            Err(Error::Topology(_))
        ));
        // failed rebinds leave the original untouched (pure)
        let before = topo.clone();
        let _ = topo.rebind(8, &[8], 2);
        assert_eq!(topo, before);
    }
}
