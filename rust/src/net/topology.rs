//! Cluster topologies from the paper's Table 2 (local / cloud /
//! supercomputer testbeds) and rail-set construction rules.

use crate::net::protocol::ProtoKind;
use crate::net::rail::{NicSpec, Rail};
use crate::Result;
use crate::util::error::Error;

/// Per-node hardware inventory.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub cpu: &'static str,
    pub cores: f64,
    pub gpus: usize,
    pub nics: Vec<NicSpec>,
}

/// An intra-group interconnect: nodes are organised in groups of
/// `group_size` (a rack / pod / chassis) joined by a full-bisection local
/// fabric that is much faster than the inter-group rails. The collective
/// planner (`coordinator::planner`) exploits it with hierarchical
/// two-level schedules; topologies without one (`intra: None`) always run
/// single-level collectives, preserving the paper's flat-cluster
/// behaviour.
#[derive(Debug, Clone, PartialEq)]
pub struct IntraLink {
    /// Nodes per group; 1 disables grouping (degenerates to flat).
    pub group_size: usize,
    /// Effective intra-group bandwidth per node (MB/s).
    pub bw_mbps: f64,
    /// Per-message setup latency on the local fabric (us).
    pub setup_us: f64,
}

/// A named testbed.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub node: NodeSpec,
    pub max_nodes: usize,
    /// Optional intra-group fast interconnect (None on the paper's flat
    /// testbeds).
    pub intra: Option<IntraLink>,
}

impl ClusterSpec {
    /// Paper's 8-node local platform: Xeon 6230R, 2x V100, 3x Eth 100G,
    /// 1x IB 100G (SHARP), 1x TH 128G (GLEX).
    pub fn local() -> ClusterSpec {
        ClusterSpec {
            name: "local",
            node: NodeSpec {
                cpu: "Xeon Gold 6230R",
                cores: 52.0,
                gpus: 2,
                nics: vec![
                    NicSpec::MCX623106AN,
                    NicSpec::MCX623106AN,
                    NicSpec::MCX623106AN,
                    NicSpec::CONNECTX5,
                    NicSpec::TH_NIC,
                ],
            },
            max_nodes: 8,
            intra: None,
        }
    }

    /// Rack-pod variant of the local testbed: same per-node NIC inventory,
    /// nodes organised in racks of `group` with a full-bisection intra-rack
    /// interconnect (NVLink-class pooled bandwidth, far faster than any
    /// single rail). This is the topology the hierarchical two-level
    /// planner targets; `group <= 1` keeps it flat.
    pub fn pods(group: usize) -> ClusterSpec {
        let mut c = ClusterSpec::local();
        c.name = "pods";
        c.max_nodes = 64;
        if group > 1 {
            c.intra = Some(IntraLink {
                group_size: group,
                bw_mbps: 5000.0,
                setup_us: 15.0,
            });
        }
        c
    }

    /// 16-node cloud platform: Xeon 5318Y, 1x V100, 1x Eth, 1x IB.
    pub fn cloud() -> ClusterSpec {
        ClusterSpec {
            name: "cloud",
            node: NodeSpec {
                cpu: "Xeon Gold 5318Y",
                cores: 48.0,
                gpus: 1,
                nics: vec![NicSpec::MCX623106AN, NicSpec::CONNECTX5],
            },
            max_nodes: 16,
            intra: None,
        }
    }

    /// 128-node supercomputer: EPYC 7452, 1 Gbps Eth + 56 Gbps IB (the
    /// paper throttles the IB NIC to 1 Gbps for the GPT runs).
    pub fn supercomputer() -> ClusterSpec {
        ClusterSpec {
            name: "supercomputer",
            node: NodeSpec {
                cpu: "AMD EPYC 7452",
                cores: 64.0,
                gpus: 0,
                nics: vec![NicSpec::BCM5720, NicSpec::CONNECTX3],
            },
            max_nodes: 128,
            intra: None,
        }
    }

    /// Build the rail set for a protocol combination, e.g. `[Tcp, Tcp]` or
    /// `[Tcp, Sharp]`.
    ///
    /// Mirrors the paper's constraints: each node has one SHARP-capable and
    /// one GLEX-capable device, so homogeneous SHARP-SHARP / GLEX-GLEX (and
    /// SHARP+GLEX heterogeneous pairs needing two RDMA planes of the same
    /// device) are rejected exactly as in §5.1 Baselines.
    pub fn build_rails(&self, kinds: &[ProtoKind]) -> Result<Vec<Rail>> {
        let n_sharp = kinds.iter().filter(|k| **k == ProtoKind::Sharp).count();
        let n_glex = kinds.iter().filter(|k| **k == ProtoKind::Glex).count();
        if n_sharp > 1 || n_glex > 1 {
            return Err(Error::Topology(
                "hardware conflict: one SHARP (IB) and one GLEX (TH) device per node".into(),
            ));
        }
        let mut eth_iter = self.node.nics.iter().filter(|n| !n.rdma);
        let ib = self.node.nics.iter().find(|n| n.rdma && n.model.contains("ConnectX"));
        let th = self.node.nics.iter().find(|n| n.model == "TH-NIC");
        let mut rails = Vec::new();
        for (i, &k) in kinds.iter().enumerate() {
            let nic = match k {
                ProtoKind::Tcp => eth_iter
                    .next()
                    .cloned()
                    .ok_or_else(|| Error::Topology("not enough Ethernet NICs".into()))?,
                ProtoKind::Sharp => ib
                    .cloned()
                    .ok_or_else(|| Error::Topology("no SHARP-capable IB NIC".into()))?,
                ProtoKind::Glex => th
                    .cloned()
                    .ok_or_else(|| Error::Topology("no GLEX-capable TH NIC".into()))?,
            };
            rails.push(Rail::new(i, nic, k));
        }
        Ok(rails)
    }

    /// Virtual multi-rail: `count` virtual channels of `kind` multiplexed
    /// on ONE physical NIC (paper §4.1, Fig. 13's TCP-TCP(Eth¹)).
    pub fn build_virtual_rails(&self, kind: ProtoKind, count: usize) -> Result<Vec<Rail>> {
        let nic = match kind {
            ProtoKind::Tcp => self
                .node
                .nics
                .iter()
                .find(|n| !n.rdma)
                .cloned()
                .ok_or_else(|| Error::Topology("no Ethernet NIC".into()))?,
            _ => return Err(Error::Topology("virtual channels supported on TCP only".into())),
        };
        Ok((0..count)
            .map(|i| Rail::new(0, nic.clone(), kind).virtual_channel(i, count))
            .collect())
    }
}

/// Parse "tcp-tcp", "tcp-sharp", "tcp-glex", "tcp" into protocol combos.
pub fn parse_combo(s: &str) -> Result<Vec<ProtoKind>> {
    s.split('-')
        .map(|p| match p.trim().to_ascii_lowercase().as_str() {
            "tcp" => Ok(ProtoKind::Tcp),
            "sharp" => Ok(ProtoKind::Sharp),
            "glex" => Ok(ProtoKind::Glex),
            other => Err(Error::Config(format!("unknown protocol `{other}`"))),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_combos() {
        let c = ClusterSpec::local();
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp]).unwrap().len(), 2);
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Sharp]).unwrap().len(), 2);
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Glex]).unwrap().len(), 2);
        // paper §5.1: SHARP-SHARP / GLEX-GLEX impossible (device conflict)
        assert!(c.build_rails(&[ProtoKind::Sharp, ProtoKind::Sharp]).is_err());
        assert!(c.build_rails(&[ProtoKind::Glex, ProtoKind::Glex]).is_err());
    }

    #[test]
    fn cloud_has_one_eth() {
        let c = ClusterSpec::cloud();
        assert!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp]).is_err());
        assert!(c.build_rails(&[ProtoKind::Tcp]).is_ok());
    }

    #[test]
    fn virtual_rails_share_nic() {
        let c = ClusterSpec::local();
        let rails = c.build_virtual_rails(ProtoKind::Tcp, 2).unwrap();
        assert_eq!(rails.len(), 2);
        assert_eq!(rails[0].nic_sharing, 2);
        assert!(rails[0].wire_cap_mbps() < NicSpec::MCX623106AN.usable_mbps());
    }

    #[test]
    fn combo_parsing() {
        assert_eq!(parse_combo("tcp-sharp").unwrap(), vec![ProtoKind::Tcp, ProtoKind::Sharp]);
        assert!(parse_combo("tcp-bogus").is_err());
    }

    #[test]
    fn pods_topology_declares_intra_link() {
        let c = ClusterSpec::pods(4);
        let link = c.intra.as_ref().expect("pods must have an intra link");
        assert_eq!(link.group_size, 4);
        assert!(link.bw_mbps > NicSpec::MCX623106AN.usable_mbps() / 4.0);
        // same NIC inventory as local: a 4-rail heterogeneous combo builds
        assert_eq!(
            c.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp, ProtoKind::Tcp, ProtoKind::Glex])
                .unwrap()
                .len(),
            4
        );
        // degenerate group stays flat
        assert!(ClusterSpec::pods(1).intra.is_none());
        assert!(ClusterSpec::local().intra.is_none());
    }

    #[test]
    fn supercomputer_nics_are_slow() {
        let c = ClusterSpec::supercomputer();
        let eth = &c.node.nics[0];
        assert!(eth.usable_mbps() < 120.0);
    }
}
