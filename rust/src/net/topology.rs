//! Cluster topologies from the paper's Table 2 (local / cloud /
//! supercomputer testbeds) and rail-set construction rules.

use crate::net::protocol::ProtoKind;
use crate::net::rail::{NicSpec, Rail};
use crate::Result;
use crate::util::error::Error;

/// Per-node hardware inventory.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub cpu: &'static str,
    pub cores: f64,
    pub gpus: usize,
    pub nics: Vec<NicSpec>,
}

/// A named testbed.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub node: NodeSpec,
    pub max_nodes: usize,
}

impl ClusterSpec {
    /// Paper's 8-node local platform: Xeon 6230R, 2x V100, 3x Eth 100G,
    /// 1x IB 100G (SHARP), 1x TH 128G (GLEX).
    pub fn local() -> ClusterSpec {
        ClusterSpec {
            name: "local",
            node: NodeSpec {
                cpu: "Xeon Gold 6230R",
                cores: 52.0,
                gpus: 2,
                nics: vec![
                    NicSpec::MCX623106AN,
                    NicSpec::MCX623106AN,
                    NicSpec::MCX623106AN,
                    NicSpec::CONNECTX5,
                    NicSpec::TH_NIC,
                ],
            },
            max_nodes: 8,
        }
    }

    /// 16-node cloud platform: Xeon 5318Y, 1x V100, 1x Eth, 1x IB.
    pub fn cloud() -> ClusterSpec {
        ClusterSpec {
            name: "cloud",
            node: NodeSpec {
                cpu: "Xeon Gold 5318Y",
                cores: 48.0,
                gpus: 1,
                nics: vec![NicSpec::MCX623106AN, NicSpec::CONNECTX5],
            },
            max_nodes: 16,
        }
    }

    /// 128-node supercomputer: EPYC 7452, 1 Gbps Eth + 56 Gbps IB (the
    /// paper throttles the IB NIC to 1 Gbps for the GPT runs).
    pub fn supercomputer() -> ClusterSpec {
        ClusterSpec {
            name: "supercomputer",
            node: NodeSpec {
                cpu: "AMD EPYC 7452",
                cores: 64.0,
                gpus: 0,
                nics: vec![NicSpec::BCM5720, NicSpec::CONNECTX3],
            },
            max_nodes: 128,
        }
    }

    /// Build the rail set for a protocol combination, e.g. `[Tcp, Tcp]` or
    /// `[Tcp, Sharp]`.
    ///
    /// Mirrors the paper's constraints: each node has one SHARP-capable and
    /// one GLEX-capable device, so homogeneous SHARP-SHARP / GLEX-GLEX (and
    /// SHARP+GLEX heterogeneous pairs needing two RDMA planes of the same
    /// device) are rejected exactly as in §5.1 Baselines.
    pub fn build_rails(&self, kinds: &[ProtoKind]) -> Result<Vec<Rail>> {
        let n_sharp = kinds.iter().filter(|k| **k == ProtoKind::Sharp).count();
        let n_glex = kinds.iter().filter(|k| **k == ProtoKind::Glex).count();
        if n_sharp > 1 || n_glex > 1 {
            return Err(Error::Topology(
                "hardware conflict: one SHARP (IB) and one GLEX (TH) device per node".into(),
            ));
        }
        let mut eth_iter = self.node.nics.iter().filter(|n| !n.rdma);
        let ib = self.node.nics.iter().find(|n| n.rdma && n.model.contains("ConnectX"));
        let th = self.node.nics.iter().find(|n| n.model == "TH-NIC");
        let mut rails = Vec::new();
        for (i, &k) in kinds.iter().enumerate() {
            let nic = match k {
                ProtoKind::Tcp => eth_iter
                    .next()
                    .cloned()
                    .ok_or_else(|| Error::Topology("not enough Ethernet NICs".into()))?,
                ProtoKind::Sharp => ib
                    .cloned()
                    .ok_or_else(|| Error::Topology("no SHARP-capable IB NIC".into()))?,
                ProtoKind::Glex => th
                    .cloned()
                    .ok_or_else(|| Error::Topology("no GLEX-capable TH NIC".into()))?,
            };
            rails.push(Rail::new(i, nic, k));
        }
        Ok(rails)
    }

    /// Virtual multi-rail: `count` virtual channels of `kind` multiplexed
    /// on ONE physical NIC (paper §4.1, Fig. 13's TCP-TCP(Eth¹)).
    pub fn build_virtual_rails(&self, kind: ProtoKind, count: usize) -> Result<Vec<Rail>> {
        let nic = match kind {
            ProtoKind::Tcp => self
                .node
                .nics
                .iter()
                .find(|n| !n.rdma)
                .cloned()
                .ok_or_else(|| Error::Topology("no Ethernet NIC".into()))?,
            _ => return Err(Error::Topology("virtual channels supported on TCP only".into())),
        };
        Ok((0..count)
            .map(|i| Rail::new(0, nic.clone(), kind).virtual_channel(i, count))
            .collect())
    }
}

/// Parse "tcp-tcp", "tcp-sharp", "tcp-glex", "tcp" into protocol combos.
pub fn parse_combo(s: &str) -> Result<Vec<ProtoKind>> {
    s.split('-')
        .map(|p| match p.trim().to_ascii_lowercase().as_str() {
            "tcp" => Ok(ProtoKind::Tcp),
            "sharp" => Ok(ProtoKind::Sharp),
            "glex" => Ok(ProtoKind::Glex),
            other => Err(Error::Config(format!("unknown protocol `{other}`"))),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_combos() {
        let c = ClusterSpec::local();
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp]).unwrap().len(), 2);
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Sharp]).unwrap().len(), 2);
        assert_eq!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Glex]).unwrap().len(), 2);
        // paper §5.1: SHARP-SHARP / GLEX-GLEX impossible (device conflict)
        assert!(c.build_rails(&[ProtoKind::Sharp, ProtoKind::Sharp]).is_err());
        assert!(c.build_rails(&[ProtoKind::Glex, ProtoKind::Glex]).is_err());
    }

    #[test]
    fn cloud_has_one_eth() {
        let c = ClusterSpec::cloud();
        assert!(c.build_rails(&[ProtoKind::Tcp, ProtoKind::Tcp]).is_err());
        assert!(c.build_rails(&[ProtoKind::Tcp]).is_ok());
    }

    #[test]
    fn virtual_rails_share_nic() {
        let c = ClusterSpec::local();
        let rails = c.build_virtual_rails(ProtoKind::Tcp, 2).unwrap();
        assert_eq!(rails.len(), 2);
        assert_eq!(rails[0].nic_sharing, 2);
        assert!(rails[0].wire_cap_mbps() < NicSpec::MCX623106AN.usable_mbps());
    }

    #[test]
    fn combo_parsing() {
        assert_eq!(parse_combo("tcp-sharp").unwrap(), vec![ProtoKind::Tcp, ProtoKind::Sharp]);
        assert!(parse_combo("tcp-bogus").is_err());
    }

    #[test]
    fn supercomputer_nics_are_slow() {
        let c = ClusterSpec::supercomputer();
        let eth = &c.node.nics[0];
        assert!(eth.usable_mbps() < 120.0);
    }
}
