//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime (shapes, dtypes, model parameter ABI).

use std::path::{Path, PathBuf};

use crate::util::error::Error;
use crate::util::json::Json;
use crate::Result;

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One input/output tensor spec.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Model ABI: parameter order/shapes + training-step shapes.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_params: usize,
    /// Parameter vector padded to the SGD/reduce kernel block size.
    pub padded: usize,
    pub param_shapes: Vec<(String, Vec<usize>)>,
    /// Raw f32 file with deterministic initial parameters.
    pub init_params_path: Option<PathBuf>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
    pub models: Vec<ModelSpec>,
}

fn io_from_json(j: &Json) -> Result<IoSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::msg("io spec missing shape"))?
        .iter()
        .map(|x| x.as_usize().unwrap_or(0))
        .collect();
    let dtype = match j.get("dtype").and_then(Json::as_str) {
        Some("f32") => Dtype::F32,
        Some("i32") => Dtype::I32,
        other => return Err(Error::msg(format!("bad dtype {other:?}"))),
    };
    Ok(IoSpec { shape, dtype })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|_| Error::MissingArtifact(path.display().to_string()))?;
        let j = Json::parse(&text)?;

        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::msg("artifact missing name"))?
                .to_string();
            let rel = a
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::msg("artifact missing path"))?;
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(io_from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(io_from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec { name, path: dir.join(rel), inputs, outputs });
        }

        // init-params lookup table
        let mut init_paths = std::collections::BTreeMap::new();
        for ip in j.get("init_params").and_then(Json::as_arr).unwrap_or(&[]) {
            if let (Some(m), Some(p)) = (
                ip.get("model").and_then(Json::as_str),
                ip.get("path").and_then(Json::as_str),
            ) {
                init_paths.insert(m.to_string(), dir.join(p));
            }
        }

        let mut models = Vec::new();
        for m in j.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::msg("model missing name"))?
                .to_string();
            let geti = |k: &str| -> Result<usize> {
                m.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::msg(format!("model {name} missing {k}")))
            };
            let param_shapes = m
                .get("param_shapes")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|e| {
                    let pair = e.as_arr()?;
                    Some((
                        pair[0].as_str()?.to_string(),
                        pair[1]
                            .as_arr()?
                            .iter()
                            .map(|x| x.as_usize().unwrap_or(0))
                            .collect(),
                    ))
                })
                .collect();
            models.push(ModelSpec {
                init_params_path: init_paths.get(&name).cloned(),
                name: name.clone(),
                vocab: geti("vocab")?,
                d_model: geti("d_model")?,
                n_layers: geti("n_layers")?,
                n_heads: geti("n_heads")?,
                d_ff: geti("d_ff")?,
                seq_len: geti("seq_len")?,
                batch: geti("batch")?,
                n_params: geti("n_params")?,
                padded: geti("padded")?,
                param_shapes,
            });
        }
        Ok(Manifest { dir, artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::MissingArtifact(name.to_string()))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| Error::MissingArtifact(format!("model {name}")))
    }

    /// Available pairwise-add reduce kernel lengths, ascending.
    pub fn add_pair_lengths(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter_map(|a| a.name.strip_prefix("add_pair_")?.parse().ok())
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn load_real_manifest() {
        if !manifest_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(!m.artifacts.is_empty());
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.d_model, 128);
        assert_eq!(tiny.padded % 65536, 0);
        assert!(tiny.init_params_path.is_some());
        let ts = m.artifact("train_step_tiny").unwrap();
        assert_eq!(ts.inputs.len(), 2);
        assert_eq!(ts.inputs[0].elems(), tiny.padded);
        assert_eq!(ts.outputs[1].elems(), tiny.padded);
        assert!(!m.add_pair_lengths().is_empty());
    }

    #[test]
    fn missing_dir_is_missing_artifact_error() {
        match Manifest::load("/nonexistent-dir") {
            Err(Error::MissingArtifact(_)) => {}
            other => panic!("{other:?}"),
        }
    }
}
