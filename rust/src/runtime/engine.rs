//! PJRT engine: client + compiled-executable cache.
//!
//! Follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. Executables
//! are compiled once per artifact and cached for the life of the process.
//!
//! The real engine needs the `xla` crate (offline registry) and is gated
//! behind the `pjrt` feature. Without it an API-compatible stub compiles in
//! whose `Engine::new` fails cleanly, so every artifact-gated caller
//! (trainer e2e, runtime tests, hotpath bench) keeps building and skips at
//! runtime exactly as it does when `make artifacts` has not run.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use crate::runtime::artifacts::Dtype;
use crate::runtime::artifacts::Manifest;
use crate::util::error::Error;
use crate::Result;

/// A typed host tensor crossing the runtime boundary.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>) -> HostTensor {
        let n = data.len();
        HostTensor::F32(data, vec![n])
    }

    pub fn i32_shaped(data: Vec<i32>, shape: Vec<usize>) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape)
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(d, shape) => {
                let l = xla::Literal::vec1(d.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                if dims.len() == 1 { l } else { l.reshape(&dims)? }
            }
            HostTensor::I32(d, shape) => {
                let l = xla::Literal::vec1(d.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                if dims.len() == 1 { l } else { l.reshape(&dims)? }
            }
        };
        Ok(lit)
    }
}

/// The engine: one CPU PJRT client + executable cache keyed by artifact
/// name.
#[cfg(feature = "pjrt")]
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("artifacts", &self.manifest.artifacts.len())
            .finish()
    }
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute a cached executable with caller-managed literals (the
    /// zero-allocation hot path used by [`crate::runtime::PjrtReducer`]).
    pub fn run_literals(
        &self,
        name: &str,
        inputs: &[&xla::Literal],
    ) -> Result<xla::Literal> {
        let exe = self.load_exe(name)?;
        Ok(exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?)
    }

    /// Pre-compile an artifact by manifest name. Same signature as the
    /// no-`pjrt` stub so code written against either build compiles
    /// against both.
    pub fn load(&self, name: &str) -> Result<()> {
        self.load_exe(name).map(|_| ())
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    fn load_exe(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let proto = xla::HloModuleProto::from_text_file(&spec.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with host tensors; returns output tensors.
    ///
    /// Inputs are validated against the manifest spec. The AOT path lowers
    /// with `return_tuple=True`, so the single result literal is a tuple
    /// that is decomposed into the manifest's output list.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            return Err(Error::msg(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let (len, dt) = match t {
                HostTensor::F32(d, _) => (d.len(), Dtype::F32),
                HostTensor::I32(d, _) => (d.len(), Dtype::I32),
            };
            if len != s.elems() || dt != s.dtype {
                return Err(Error::msg(format!(
                    "{name}: input {i} mismatch (got {len} elems, want {})",
                    s.elems()
                )));
            }
        }
        let exe = self.load_exe(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            return Err(Error::msg(format!(
                "{name}: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            )));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, os) in parts.into_iter().zip(&spec.outputs) {
            let t = match os.dtype {
                Dtype::F32 => HostTensor::F32(lit.to_vec::<f32>()?, os.shape.clone()),
                Dtype::I32 => HostTensor::I32(lit.to_vec::<i32>()?, os.shape.clone()),
            };
            out.push(t);
        }
        Ok(out)
    }
}

/// Stub engine compiled when the `pjrt` feature is off: construction fails
/// with a clear message after surfacing missing-artifact errors first, so
/// callers behave exactly as when artifacts are absent.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    fn disabled() -> Error {
        Error::msg(
            "PJRT runtime disabled: rebuild with `--features pjrt` \
             (requires the offline `xla` crate; see DESIGN.md)",
        )
    }

    /// Always fails (after artifact lookup, so a missing manifest still
    /// reports as [`Error::MissingArtifact`]).
    pub fn new(artifacts_dir: &str) -> Result<Engine> {
        let _manifest = Manifest::load(artifacts_dir)?;
        Err(Self::disabled())
    }

    pub fn platform(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn load(&self, _name: &str) -> Result<()> {
        Err(Self::disabled())
    }

    pub fn run(&self, _name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        Err(Self::disabled())
    }
}

/// Unwrap helpers for the common case.
pub fn as_f32(t: &HostTensor) -> &[f32] {
    match t {
        HostTensor::F32(d, _) => d,
        _ => panic!("expected f32 tensor"),
    }
}

pub fn scalar_f32(t: &HostTensor) -> f32 {
    as_f32(t)[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0]);
        assert_eq!(as_f32(&t), &[1.0, 2.0, 3.0]);
        assert_eq!(scalar_f32(&t), 1.0);
        let i = HostTensor::i32_shaped(vec![1, 2, 3, 4], vec![2, 2]);
        match i {
            HostTensor::I32(d, s) => {
                assert_eq!(d.len(), 4);
                assert_eq!(s, vec![2, 2]);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_fails_cleanly() {
        // no artifacts dir: MissingArtifact comes first
        match Engine::new("/nonexistent-artifacts-dir") {
            Err(Error::MissingArtifact(_)) => {}
            other => panic!("expected MissingArtifact, got {other:?}"),
        }
    }
}
