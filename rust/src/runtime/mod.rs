//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `python/compile/aot.py`) and executes them on the request path.
//!
//! HLO **text** is the interchange format (see aot.py / DESIGN.md): the
//! xla_extension 0.5.1 behind the `xla` 0.1.6 crate rejects jax ≥ 0.5's
//! 64-bit-id serialized protos, while the text parser reassigns ids.
//!
//! Python never runs here — after `make artifacts` the binary is
//! self-contained.
//!
//! The `xla`-backed engine/reducer are gated behind the `pjrt` cargo
//! feature (the only external dependency of the crate); the default build
//! substitutes API-compatible stubs whose `Engine::new` fails cleanly, so
//! artifact-gated tests and benches skip exactly as when artifacts are
//! missing. See DESIGN.md §runtime.

pub mod artifacts;
pub mod engine;
pub mod model;
pub mod reducer;

pub use artifacts::{ArtifactSpec, IoSpec, Manifest, ModelSpec};
pub use engine::Engine;
pub use model::ModelRunner;
pub use reducer::PjrtReducer;
