//! Model runner: executes the AOT-compiled L2 train step and the Pallas
//! fused-SGD update for one model config.

use std::sync::Arc;

use crate::runtime::artifacts::ModelSpec;
use crate::runtime::engine::{as_f32, scalar_f32, Engine, HostTensor};
use crate::util::error::Error;
use crate::Result;

/// Executes `train_step_<model>` / `sgd_update_<model>` against flat
/// parameter buffers (the ABI established by python/compile/configs.py).
pub struct ModelRunner {
    engine: Arc<Engine>,
    pub spec: ModelSpec,
    train_name: String,
    sgd_name: String,
}

impl std::fmt::Debug for ModelRunner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRunner").field("model", &self.spec.name).finish()
    }
}

impl ModelRunner {
    pub fn new(engine: Arc<Engine>, model: &str) -> Result<ModelRunner> {
        let spec = engine.manifest.model(model)?.clone();
        Ok(ModelRunner {
            engine,
            train_name: format!("train_step_{model}"),
            sgd_name: format!("sgd_update_{model}"),
            spec,
        })
    }

    /// Pre-compile both executables (first call otherwise pays it lazily).
    pub fn warmup(&self) -> Result<()> {
        self.engine.load(&self.train_name)?;
        self.engine.load(&self.sgd_name)?;
        Ok(())
    }

    /// Deterministic initial parameters exported by aot.py (padded).
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let path = self
            .spec
            .init_params_path
            .as_ref()
            .ok_or_else(|| Error::MissingArtifact(format!("init_params_{}", self.spec.name)))?;
        let bytes = std::fs::read(path)?;
        if bytes.len() != self.spec.padded * 4 {
            return Err(Error::msg(format!(
                "init params size mismatch: {} vs {}",
                bytes.len(),
                self.spec.padded * 4
            )));
        }
        let mut out = vec![0f32; self.spec.padded];
        for (i, c) in bytes.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(out)
    }

    /// Expected token batch length: batch * (seq_len + 1).
    pub fn batch_elems(&self) -> usize {
        self.spec.batch * (self.spec.seq_len + 1)
    }

    /// One forward+backward step: (loss, padded flat gradients).
    pub fn train_step(&self, params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        assert_eq!(params.len(), self.spec.padded);
        assert_eq!(tokens.len(), self.batch_elems());
        let out = self.engine.run(
            &self.train_name,
            &[
                HostTensor::f32(params.to_vec()),
                HostTensor::i32_shaped(
                    tokens.to_vec(),
                    vec![self.spec.batch, self.spec.seq_len + 1],
                ),
            ],
        )?;
        let loss = scalar_f32(&out[0]);
        let grads = as_f32(&out[1]).to_vec();
        Ok((loss, grads))
    }

    /// Fused momentum-SGD update (Pallas kernel): returns (params', vel').
    pub fn sgd_update(
        &self,
        params: &[f32],
        grads: &[f32],
        vel: &[f32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        assert_eq!(params.len(), self.spec.padded);
        let out = self.engine.run(
            &self.sgd_name,
            &[
                HostTensor::f32(vec![lr]),
                HostTensor::f32(vec![mu]),
                HostTensor::f32(params.to_vec()),
                HostTensor::f32(grads.to_vec()),
                HostTensor::f32(vel.to_vec()),
            ],
        )?;
        Ok((as_f32(&out[0]).to_vec(), as_f32(&out[1]).to_vec()))
    }
}
