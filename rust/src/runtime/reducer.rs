//! PJRT-backed reducer: the allreduce aggregation step executed by the
//! AOT-compiled **Pallas** `add_pair` kernel — the L1 hot-spot on the L3
//! request path.
//!
//! Slices are processed in kernel-sized blocks (65536/262144 f32, the
//! sizes exported by aot.py); the tail shorter than the smallest kernel
//! block falls back to the portable rust loop (identical f32 adds, so
//! numerics are bit-equal).
//!
//! Without the `pjrt` feature this compiles as a thin wrapper over
//! [`RustReducer`] (same API, same numerics) so the rest of the system
//! builds dependency-free.

use std::sync::Arc;

use crate::coordinator::collective::reducer::{Reducer, RustReducer};
use crate::runtime::engine::Engine;
use crate::Result;

#[cfg(feature = "pjrt")]
pub struct PjrtReducer {
    engine: Arc<Engine>,
    /// Per available kernel block length (descending): (len, name,
    /// persistent input literals a/b). Reusing literals avoids the
    /// three heap allocations + copies per call of the naive path —
    /// see EXPERIMENTS.md §Perf for the before/after.
    blocks: Vec<(usize, String, xla::Literal, xla::Literal)>,
    fallback: RustReducer,
    /// Ops dispatched to the Pallas kernel vs the tail fallback (metrics).
    pub kernel_elems: u64,
    pub fallback_elems: u64,
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for PjrtReducer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let lens: Vec<usize> = self.blocks.iter().map(|b| b.0).collect();
        f.debug_struct("PjrtReducer").field("blocks", &lens).finish()
    }
}

#[cfg(feature = "pjrt")]
impl PjrtReducer {
    pub fn new(engine: Arc<Engine>) -> Result<PjrtReducer> {
        let mut lens = engine.manifest.add_pair_lengths();
        lens.sort_unstable_by(|a, b| b.cmp(a));
        let mut blocks = Vec::with_capacity(lens.len());
        for len in lens {
            let name = format!("add_pair_{len}");
            engine.load(&name)?; // pre-compile
            let a = xla::Literal::vec1(&vec![0f32; len]);
            let b = xla::Literal::vec1(&vec![0f32; len]);
            blocks.push((len, name, a, b));
        }
        Ok(PjrtReducer {
            engine,
            blocks,
            fallback: RustReducer,
            kernel_elems: 0,
            fallback_elems: 0,
        })
    }

    fn add_block(&mut self, dst: &mut [f32], src: &[f32], idx: usize) -> Result<()> {
        let (_, name, a, b) = &mut self.blocks[idx];
        a.copy_raw_from(dst)?;
        b.copy_raw_from(src)?;
        let out = self.engine.run_literals(name, &[&*a, &*b])?;
        let result = out.to_tuple1()?;
        result.copy_raw_to(dst)?;
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
impl Reducer for PjrtReducer {
    fn add_into(&mut self, dst: &mut [f32], src: &[f32]) {
        assert_eq!(dst.len(), src.len());
        let lens: Vec<usize> = self.blocks.iter().map(|b| b.0).collect();
        let mut off = 0;
        'outer: while off < dst.len() {
            let remaining = dst.len() - off;
            for (idx, &blen) in lens.iter().enumerate() {
                if remaining >= blen
                    && self
                        .add_block(&mut dst[off..off + blen], &src[off..off + blen], idx)
                        .is_ok()
                {
                    self.kernel_elems += blen as u64;
                    off += blen;
                    continue 'outer;
                }
            }
            // tail (or kernel failure): portable fallback
            self.fallback.add_into(&mut dst[off..], &src[off..]);
            self.fallback_elems += remaining as u64;
            break;
        }
    }

    /// Forks share the AOT engine (`Arc`) but own fresh persistent
    /// literals — per-worker scratch, so concurrent `add_block` calls
    /// never race on the input buffers. Kernel adds are bit-identical to
    /// the parent's by construction (same compiled executable); a fork
    /// whose literal allocation fails reports `None` and the coordinator
    /// falls back to serial execution for the op.
    fn fork(&self) -> Option<Box<dyn Reducer + Send>> {
        PjrtReducer::new(self.engine.clone())
            .ok()
            .map(|r| Box::new(r) as Box<dyn Reducer + Send>)
    }

    fn name(&self) -> &'static str {
        "pjrt-pallas"
    }
}

/// Stub reducer compiled without the `pjrt` feature: every add runs the
/// portable rust loop. In practice unreachable through the public API
/// (the stub [`Engine::new`] fails first), but it keeps artifact-gated
/// call sites compiling unchanged.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct PjrtReducer {
    fallback: RustReducer,
    pub kernel_elems: u64,
    pub fallback_elems: u64,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtReducer {
    pub fn new(_engine: Arc<Engine>) -> Result<PjrtReducer> {
        Ok(PjrtReducer { fallback: RustReducer, kernel_elems: 0, fallback_elems: 0 })
    }
}

#[cfg(not(feature = "pjrt"))]
impl Reducer for PjrtReducer {
    fn add_into(&mut self, dst: &mut [f32], src: &[f32]) {
        self.fallback_elems += dst.len() as u64;
        self.fallback.add_into(dst, src);
    }

    /// The stub is stateless beyond its metrics, so a fork is just a
    /// fresh stub — keeps `exec = parallel` working in dependency-free
    /// builds exactly as [`RustReducer`] does.
    fn fork(&self) -> Option<Box<dyn Reducer + Send>> {
        Some(Box::new(PjrtReducer {
            fallback: RustReducer,
            kernel_elems: 0,
            fallback_elems: 0,
        }))
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_forks_and_matches_parent_numerics() {
        let mut parent = PjrtReducer { fallback: RustReducer, kernel_elems: 0, fallback_elems: 0 };
        let mut fork = parent.fork().expect("stub reducer must fork");
        assert_eq!(fork.name(), "pjrt-stub");
        let src: Vec<f32> = (0..515).map(|i| (i % 17) as f32 * 0.25).collect();
        let mut a: Vec<f32> = (0..515).map(|i| (i % 13) as f32).collect();
        let mut b = a.clone();
        parent.add_into(&mut a, &src);
        fork.add_into(&mut b, &src);
        assert_eq!(a, b);
        assert_eq!(parent.fallback_elems, 515);
    }
}
