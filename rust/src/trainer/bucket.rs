//! Gradient bucketing / tensor fusion (Horovod-style) for the real
//! training loop: the flat gradient vector is cut into fusion buckets that
//! are allreduced as separate operations, so the Load Balancer sees the
//! realistic per-op size distribution instead of one giant payload.
//!
//! Buckets can be annotated with the collective plan the coordinator
//! would execute for each window ([`Bucketizer::annotate`]): overlapping
//! buckets whose plans are multi-rail and chunked pipeline across rails
//! (see `coordinator::planner::pipeline` and `DdpSim`'s bucket
//! pipelining).

use crate::coordinator::buffer::{NodeWindows, Window};
use crate::coordinator::collective::integrity;
use crate::coordinator::multirail::MultiRail;
use crate::coordinator::planner::CollectivePlan;

/// Per-bucket gradient fingerprint: the integrity checksum of the bucket
/// payload across every node. Computed on the reduced buffer it is the
/// trainer-level containment check — corruption that slipped past the
/// wire checksums (integrity off, or a future hole) still changes the
/// fingerprint and is caught before the gradient touches weights.
pub fn bucket_fingerprint<V: NodeWindows + ?Sized>(buf: &V, w: Window) -> u64 {
    integrity::window_checksum(buf, w)
}

/// Trainer-level containment guard: expected per-bucket fingerprints from
/// a fault-free oracle (a twin run with no corruption schedule), plus the
/// count of buckets that failed the check and were recomputed and
/// retransmitted over the checksum-verified plane.
#[derive(Debug, Clone, Default)]
pub struct BucketGuard {
    /// Oracle fingerprints, one per bucket op in iteration order.
    pub expected: Vec<u64>,
    /// Buckets caught corrupted and recovered this run.
    pub recomputes: u64,
}

impl BucketGuard {
    pub fn new(expected: Vec<u64>) -> BucketGuard {
        BucketGuard { expected, recomputes: 0 }
    }
}

/// Layer-wise cross-iteration dependency of one fusion bucket: backward
/// produces buckets in issue order (output layers first), while the next
/// iteration's forward consumes them in *reverse* (input layers first).
/// The bucket produced LAST is therefore needed FIRST — its wire priority
/// is its consumption position, so the barrier-free scheduler drains
/// early-forward buckets ahead of late ones (DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketDep {
    /// Backward production index (bucket issue order, 0 = first produced).
    pub produced: usize,
    /// Forward step of the *next* iteration that consumes this bucket.
    pub consumed_at: usize,
    /// Wire priority (= `consumed_at`; 0 drains first).
    pub priority: u32,
}

/// Forward step of the next iteration that consumes the bucket produced
/// at backward index `produced` (of `n_buckets`): consumption order is
/// the reverse of production order.
pub fn consumed_at_step(produced: usize, n_buckets: usize) -> usize {
    n_buckets.saturating_sub(1).saturating_sub(produced)
}

/// Wire priority of the bucket produced at backward index `produced`
/// (lower drains first): its consumption position in the next forward.
pub fn consume_priority(produced: usize, n_buckets: usize) -> u32 {
    consumed_at_step(produced, n_buckets) as u32
}

/// The full dependency table for an `n_buckets`-bucket iteration, in
/// production order.
pub fn bucket_deps(n_buckets: usize) -> Vec<BucketDep> {
    (0..n_buckets)
        .map(|produced| BucketDep {
            produced,
            consumed_at: consumed_at_step(produced, n_buckets),
            priority: consume_priority(produced, n_buckets),
        })
        .collect()
}

/// Split a flat parameter/gradient vector of `total` elements into fusion
/// buckets of at most `bucket_elems` elements.
#[derive(Debug, Clone)]
pub struct Bucketizer {
    pub windows: Vec<Window>,
}

impl Bucketizer {
    pub fn new(total: usize, bucket_elems: usize) -> Bucketizer {
        Bucketizer { windows: Window::new(0, total).split_chunks(bucket_elems.max(1)) }
    }

    /// Buckets aligned to parameter boundaries: never splits one parameter
    /// tensor across buckets unless the tensor alone exceeds the cap.
    pub fn aligned(param_sizes: &[usize], bucket_elems: usize) -> Bucketizer {
        let cap = bucket_elems.max(1);
        let mut windows = Vec::new();
        let mut start = 0usize;
        let mut len = 0usize;
        let mut off = 0usize;
        for &p in param_sizes {
            if len > 0 && len + p > cap {
                windows.push(Window::new(start, len));
                start = off;
                len = 0;
            }
            if p >= cap {
                // oversized tensor: flush and chunk it
                if len > 0 {
                    windows.push(Window::new(start, len));
                    len = 0;
                }
                for w in Window::new(off, p).split_chunks(cap) {
                    windows.push(w);
                }
                off += p;
                start = off;
                continue;
            }
            len += p;
            off += p;
        }
        if len > 0 {
            windows.push(Window::new(start, len));
        }
        Bucketizer { windows }
    }

    pub fn n_buckets(&self) -> usize {
        self.windows.len()
    }

    pub fn total(&self) -> usize {
        self.windows.iter().map(|w| w.len).sum()
    }

    /// Annotate every bucket with the collective plan the coordinator
    /// would execute for it right now (`elem_bytes` scales window elements
    /// to modeled wire bytes; 4.0 = physical f32). Plans are `None` under
    /// MPTCP-style slicing policies.
    pub fn annotate(&self, mr: &mut MultiRail, elem_bytes: f64) -> Vec<BucketPlan> {
        let n = self.windows.len();
        self.windows
            .iter()
            .enumerate()
            .map(|(i, w)| BucketPlan {
                window: *w,
                plan: mr.plan_for((w.len as f64 * elem_bytes) as u64),
                dep: BucketDep {
                    produced: i,
                    consumed_at: consumed_at_step(i, n),
                    priority: consume_priority(i, n),
                },
            })
            .collect()
    }
}

/// One fusion bucket + the plan the coordinator would run for it.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    pub window: Window,
    pub plan: Option<CollectivePlan>,
    /// Cross-iteration consumption dependency (which next-forward step
    /// needs this bucket, and hence its wire priority).
    pub dep: BucketDep,
}

impl BucketPlan {
    /// Would this bucket engage ≥2 rails (and thus pipeline with its
    /// neighbours under cross-bucket chunk pipelining)?
    pub fn is_multirail(&self) -> bool {
        self.plan.as_ref().map(|p| p.active_rails() >= 2).unwrap_or(false)
    }

    /// Schedule-selection epoch the annotation was taken at (None under
    /// slicing policies). Buckets annotated across a replan boundary —
    /// e.g. after the coordinator's predicted-vs-measured error tripped
    /// `replan_error` — carry different epochs.
    pub fn plan_epoch(&self) -> Option<u64> {
        self.plan.as_ref().map(|p| p.epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_everything_in_order() {
        let b = Bucketizer::new(1000, 300);
        assert_eq!(b.n_buckets(), 4);
        assert_eq!(b.total(), 1000);
        assert_eq!(b.windows[0], Window::new(0, 300));
        assert_eq!(b.windows[3], Window::new(900, 100));
    }

    #[test]
    fn aligned_keeps_tensors_whole() {
        let b = Bucketizer::aligned(&[100, 100, 100, 100], 250);
        assert_eq!(b.total(), 400);
        // 100+100 fits in 250, adding the third would overflow
        assert_eq!(b.windows[0].len, 200);
        assert_eq!(b.windows[1].len, 200);
    }

    #[test]
    fn aligned_chunks_oversized_tensor() {
        let b = Bucketizer::aligned(&[50, 1000, 50], 256);
        assert_eq!(b.total(), 1100);
        // the 1000-elem tensor is chunked at 256
        assert!(b.windows.iter().any(|w| w.len == 256));
        // windows are contiguous and non-overlapping
        let mut off = 0;
        for w in &b.windows {
            assert_eq!(w.offset, off);
            off = w.end();
        }
        assert_eq!(off, 1100);
    }

    #[test]
    fn single_bucket_when_cap_large() {
        let b = Bucketizer::new(100, 1 << 30);
        assert_eq!(b.n_buckets(), 1);
    }

    #[test]
    fn consumption_order_reverses_production_order() {
        // 5 buckets: produced 0 (output layers) is consumed LAST next
        // forward; produced 4 (input layers) is consumed FIRST
        assert_eq!(consumed_at_step(0, 5), 4);
        assert_eq!(consumed_at_step(4, 5), 0);
        assert_eq!(consume_priority(4, 5), 0, "last-produced drains first");
        assert_eq!(consume_priority(0, 5), 4);
        let deps = bucket_deps(5);
        assert_eq!(deps.len(), 5);
        for d in &deps {
            assert_eq!(d.priority as usize, d.consumed_at);
            assert_eq!(d.produced + d.consumed_at, 4);
        }
        // every forward step is covered exactly once
        let mut steps: Vec<_> = deps.iter().map(|d| d.consumed_at).collect();
        steps.sort_unstable();
        assert_eq!(steps, vec![0, 1, 2, 3, 4]);
        // degenerate sizes don't underflow
        assert_eq!(consumed_at_step(0, 1), 0);
        assert!(bucket_deps(0).is_empty());
    }

    #[test]
    fn annotate_covers_all_buckets_with_plans() {
        use crate::config::{Config, Policy};
        use crate::net::protocol::ProtoKind;
        let cfg = Config {
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: true,
            ..Config::default()
        };
        let mut mr = MultiRail::new(&cfg).unwrap();
        // 16M elements (64MB modeled) in 4M-element buckets
        let b = Bucketizer::new(16 << 20, 4 << 20);
        let annotated = b.annotate(&mut mr, 4.0);
        assert_eq!(annotated.len(), b.n_buckets());
        for bp in &annotated {
            let plan = bp.plan.as_ref().expect("share policy must yield a plan");
            assert!(plan.conserves(bp.window));
            // 16MB hot buckets split across both rails
            assert!(bp.is_multirail(), "{plan:?}");
            // annotation previews never start a selection epoch
            assert_eq!(bp.plan_epoch(), Some(mr.plan_epoch()));
        }
        // the dependency annotation mirrors bucket_deps
        for (i, bp) in annotated.iter().enumerate() {
            assert_eq!(bp.dep.produced, i);
            assert_eq!(bp.dep.consumed_at, annotated.len() - 1 - i);
        }
    }

    #[test]
    fn annotate_under_mptcp_yields_none() {
        use crate::config::{Config, Policy};
        use crate::net::protocol::ProtoKind;
        let cfg = Config {
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Mptcp,
            deterministic: true,
            ..Config::default()
        };
        let mut mr = MultiRail::new(&cfg).unwrap();
        let b = Bucketizer::new(1 << 20, 1 << 19);
        let annotated = b.annotate(&mut mr, 4.0);
        assert!(annotated.iter().all(|bp| bp.plan.is_none()));
        assert!(!annotated[0].is_multirail());
    }
}
