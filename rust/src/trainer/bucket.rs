//! Gradient bucketing / tensor fusion (Horovod-style) for the real
//! training loop: the flat gradient vector is cut into fusion buckets that
//! are allreduced as separate operations, so the Load Balancer sees the
//! realistic per-op size distribution instead of one giant payload.
//!
//! Buckets can be annotated with the collective plan the coordinator
//! would execute for each window ([`Bucketizer::annotate`]): overlapping
//! buckets whose plans are multi-rail and chunked pipeline across rails
//! (see `coordinator::planner::pipeline` and `DdpSim`'s bucket
//! pipelining).

use crate::coordinator::buffer::{NodeWindows, Window};
use crate::coordinator::collective::integrity;
use crate::coordinator::multirail::MultiRail;
use crate::coordinator::planner::CollectivePlan;

/// Per-bucket gradient fingerprint: the integrity checksum of the bucket
/// payload across every node. Computed on the reduced buffer it is the
/// trainer-level containment check — corruption that slipped past the
/// wire checksums (integrity off, or a future hole) still changes the
/// fingerprint and is caught before the gradient touches weights.
pub fn bucket_fingerprint<V: NodeWindows + ?Sized>(buf: &V, w: Window) -> u64 {
    integrity::window_checksum(buf, w)
}

/// Trainer-level containment guard: expected per-bucket fingerprints from
/// a fault-free oracle (a twin run with no corruption schedule), plus the
/// count of buckets that failed the check and were recomputed and
/// retransmitted over the checksum-verified plane.
#[derive(Debug, Clone, Default)]
pub struct BucketGuard {
    /// Oracle fingerprints, one per bucket op in iteration order.
    pub expected: Vec<u64>,
    /// Buckets caught corrupted and recovered this run.
    pub recomputes: u64,
}

impl BucketGuard {
    pub fn new(expected: Vec<u64>) -> BucketGuard {
        BucketGuard { expected, recomputes: 0 }
    }
}

/// Split a flat parameter/gradient vector of `total` elements into fusion
/// buckets of at most `bucket_elems` elements.
#[derive(Debug, Clone)]
pub struct Bucketizer {
    pub windows: Vec<Window>,
}

impl Bucketizer {
    pub fn new(total: usize, bucket_elems: usize) -> Bucketizer {
        Bucketizer { windows: Window::new(0, total).split_chunks(bucket_elems.max(1)) }
    }

    /// Buckets aligned to parameter boundaries: never splits one parameter
    /// tensor across buckets unless the tensor alone exceeds the cap.
    pub fn aligned(param_sizes: &[usize], bucket_elems: usize) -> Bucketizer {
        let cap = bucket_elems.max(1);
        let mut windows = Vec::new();
        let mut start = 0usize;
        let mut len = 0usize;
        let mut off = 0usize;
        for &p in param_sizes {
            if len > 0 && len + p > cap {
                windows.push(Window::new(start, len));
                start = off;
                len = 0;
            }
            if p >= cap {
                // oversized tensor: flush and chunk it
                if len > 0 {
                    windows.push(Window::new(start, len));
                    len = 0;
                }
                for w in Window::new(off, p).split_chunks(cap) {
                    windows.push(w);
                }
                off += p;
                start = off;
                continue;
            }
            len += p;
            off += p;
        }
        if len > 0 {
            windows.push(Window::new(start, len));
        }
        Bucketizer { windows }
    }

    pub fn n_buckets(&self) -> usize {
        self.windows.len()
    }

    pub fn total(&self) -> usize {
        self.windows.iter().map(|w| w.len).sum()
    }

    /// Annotate every bucket with the collective plan the coordinator
    /// would execute for it right now (`elem_bytes` scales window elements
    /// to modeled wire bytes; 4.0 = physical f32). Plans are `None` under
    /// MPTCP-style slicing policies.
    pub fn annotate(&self, mr: &mut MultiRail, elem_bytes: f64) -> Vec<BucketPlan> {
        self.windows
            .iter()
            .map(|w| BucketPlan {
                window: *w,
                plan: mr.plan_for((w.len as f64 * elem_bytes) as u64),
            })
            .collect()
    }
}

/// One fusion bucket + the plan the coordinator would run for it.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    pub window: Window,
    pub plan: Option<CollectivePlan>,
}

impl BucketPlan {
    /// Would this bucket engage ≥2 rails (and thus pipeline with its
    /// neighbours under cross-bucket chunk pipelining)?
    pub fn is_multirail(&self) -> bool {
        self.plan.as_ref().map(|p| p.active_rails() >= 2).unwrap_or(false)
    }

    /// Schedule-selection epoch the annotation was taken at (None under
    /// slicing policies). Buckets annotated across a replan boundary —
    /// e.g. after the coordinator's predicted-vs-measured error tripped
    /// `replan_error` — carry different epochs.
    pub fn plan_epoch(&self) -> Option<u64> {
        self.plan.as_ref().map(|p| p.epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_everything_in_order() {
        let b = Bucketizer::new(1000, 300);
        assert_eq!(b.n_buckets(), 4);
        assert_eq!(b.total(), 1000);
        assert_eq!(b.windows[0], Window::new(0, 300));
        assert_eq!(b.windows[3], Window::new(900, 100));
    }

    #[test]
    fn aligned_keeps_tensors_whole() {
        let b = Bucketizer::aligned(&[100, 100, 100, 100], 250);
        assert_eq!(b.total(), 400);
        // 100+100 fits in 250, adding the third would overflow
        assert_eq!(b.windows[0].len, 200);
        assert_eq!(b.windows[1].len, 200);
    }

    #[test]
    fn aligned_chunks_oversized_tensor() {
        let b = Bucketizer::aligned(&[50, 1000, 50], 256);
        assert_eq!(b.total(), 1100);
        // the 1000-elem tensor is chunked at 256
        assert!(b.windows.iter().any(|w| w.len == 256));
        // windows are contiguous and non-overlapping
        let mut off = 0;
        for w in &b.windows {
            assert_eq!(w.offset, off);
            off = w.end();
        }
        assert_eq!(off, 1100);
    }

    #[test]
    fn single_bucket_when_cap_large() {
        let b = Bucketizer::new(100, 1 << 30);
        assert_eq!(b.n_buckets(), 1);
    }

    #[test]
    fn annotate_covers_all_buckets_with_plans() {
        use crate::config::{Config, Policy};
        use crate::net::protocol::ProtoKind;
        let cfg = Config {
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Nezha,
            deterministic: true,
            ..Config::default()
        };
        let mut mr = MultiRail::new(&cfg).unwrap();
        // 16M elements (64MB modeled) in 4M-element buckets
        let b = Bucketizer::new(16 << 20, 4 << 20);
        let annotated = b.annotate(&mut mr, 4.0);
        assert_eq!(annotated.len(), b.n_buckets());
        for bp in &annotated {
            let plan = bp.plan.as_ref().expect("share policy must yield a plan");
            assert!(plan.conserves(bp.window));
            // 16MB hot buckets split across both rails
            assert!(bp.is_multirail(), "{plan:?}");
            // annotation previews never start a selection epoch
            assert_eq!(bp.plan_epoch(), Some(mr.plan_epoch()));
        }
    }

    #[test]
    fn annotate_under_mptcp_yields_none() {
        use crate::config::{Config, Policy};
        use crate::net::protocol::ProtoKind;
        let cfg = Config {
            nodes: 4,
            combo: vec![ProtoKind::Tcp, ProtoKind::Tcp],
            policy: Policy::Mptcp,
            deterministic: true,
            ..Config::default()
        };
        let mut mr = MultiRail::new(&cfg).unwrap();
        let b = Bucketizer::new(1 << 20, 1 << 19);
        let annotated = b.annotate(&mut mr, 4.0);
        assert!(annotated.iter().all(|bp| bp.plan.is_none()));
        assert!(!annotated[0].is_multirail());
    }
}
