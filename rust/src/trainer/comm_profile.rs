//! Model communication profiles (paper Fig. 15): the per-iteration
//! allreduce sizes each model issues during data-parallel training.
//!
//! The paper records these with the Control Module while training on
//! ImageNet; we encode the same distributions (AlexNet communicates mostly
//! below 4 MB, VGG-11 is intensive in the 2–16 MB band) with total volume
//! matching each model's gradient size. From a communication perspective
//! this fully determines DDP behaviour (§5.3.1: "the differences between
//! models lie solely in the size of the parameters involved in
//! communication and the communication frequency").

use crate::util::stats::SizeHistogram;

const KB: u64 = 1024;
const MB: u64 = 1024 * KB;

/// A model's per-iteration allreduce workload.
#[derive(Debug, Clone)]
pub struct CommProfile {
    pub name: &'static str,
    /// Allreduce payloads (bytes) issued each training iteration, in
    /// issue order (backprop order: output layers first).
    pub ops: Vec<u64>,
    /// Model parameter count.
    pub n_params: u64,
    /// Single-V100 compute throughput (samples/s) by batch size — the
    /// compute side of the DDP simulator, anchored to the paper's G1N1
    /// baselines (Fig. 16).
    compute_sps: Vec<(usize, f64)>,
}

impl CommProfile {
    /// AlexNet (~61M params, 244 MB of gradients/iteration), traffic
    /// below 4 MB per Fig. 15.
    pub fn alexnet() -> CommProfile {
        let mut ops = Vec::new();
        push(&mut ops, 3, 64 * KB);
        push(&mut ops, 6, 256 * KB);
        push(&mut ops, 20, MB);
        push(&mut ops, 40, 2 * MB);
        push(&mut ops, 35, 4 * MB);
        CommProfile {
            name: "AlexNet",
            ops,
            n_params: 61_000_000,
            compute_sps: vec![(32, 380.0), (64, 700.0)],
        }
    }

    /// VGG-11 (~133M params, 531 MB of gradients/iteration), intensive in
    /// the 2–16 MB band per Fig. 15.
    pub fn vgg11() -> CommProfile {
        let mut ops = Vec::new();
        push(&mut ops, 4, 512 * KB);
        push(&mut ops, 30, 2 * MB);
        push(&mut ops, 40, 4 * MB);
        push(&mut ops, 20, 8 * MB);
        push(&mut ops, 9, 16 * MB);
        CommProfile {
            name: "VGG-11",
            ops,
            n_params: 132_900_000,
            compute_sps: vec![(32, 190.0), (64, 330.0)],
        }
    }

    /// A synthetic profile for tests and chaos harnesses: `ops` payloads
    /// issued in backprop order, with a flat samples/s compute anchor at
    /// batch 32.
    pub fn synthetic(name: &'static str, ops: Vec<u64>, sps: f64) -> CommProfile {
        let n_params = ops.iter().sum::<u64>() / 4;
        CommProfile { name, ops, n_params, compute_sps: vec![(32, sps)] }
    }

    pub fn by_name(name: &str) -> Option<CommProfile> {
        match name.to_ascii_lowercase().as_str() {
            "alexnet" | "alex" => Some(CommProfile::alexnet()),
            "vgg11" | "vgg-11" | "vgg" => Some(CommProfile::vgg11()),
            _ => None,
        }
    }

    /// Total gradient bytes per iteration.
    pub fn bytes_per_iter(&self) -> u64 {
        self.ops.iter().sum()
    }

    /// Single-GPU compute time per iteration (us) at `batch` per GPU.
    pub fn compute_us(&self, batch: usize) -> f64 {
        // interpolate/extrapolate samples/s linearly in batch size
        let sps = match self
            .compute_sps
            .iter()
            .find(|(b, _)| *b == batch)
        {
            Some((_, s)) => *s,
            None => {
                let (b0, s0) = self.compute_sps[0];
                let (b1, s1) = self.compute_sps[self.compute_sps.len() - 1];
                if b1 == b0 {
                    s0
                } else {
                    s0 + (s1 - s0) * (batch as f64 - b0 as f64) / (b1 as f64 - b0 as f64)
                }
            }
        };
        batch as f64 / sps * 1e6
    }

    /// ImageNet ILSVRC2012 iterations per epoch at a global batch size.
    pub fn iters_per_epoch(&self, global_batch: usize) -> u64 {
        (1_281_167usize.div_ceil(global_batch)) as u64
    }

    /// Fig. 15: allreduce count & volume per epoch.
    pub fn epoch_histogram(&self, global_batch: usize) -> SizeHistogram {
        let iters = self.iters_per_epoch(global_batch);
        let mut h = SizeHistogram::new();
        for _ in 0..iters.min(10_000) {
            // (histogram shape is iteration-invariant; cap the loop and
            // scale counts instead for huge epochs)
            for &b in &self.ops {
                h.add(b);
            }
        }
        h
    }
}

fn push(ops: &mut Vec<u64>, n: usize, bytes: u64) {
    ops.extend(std::iter::repeat(bytes).take(n));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volumes_match_model_sizes() {
        let a = CommProfile::alexnet();
        // gradient bytes = 4 * params, within 5%
        let expect = 4 * a.n_params;
        let got = a.bytes_per_iter();
        assert!(
            (got as f64 - expect as f64).abs() / (expect as f64) < 0.05,
            "alexnet {got} vs {expect}"
        );
        let v = CommProfile::vgg11();
        let expect = 4 * v.n_params;
        let got = v.bytes_per_iter();
        assert!(
            (got as f64 - expect as f64).abs() / (expect as f64) < 0.05,
            "vgg {got} vs {expect}"
        );
    }

    #[test]
    fn alexnet_ops_below_4mb_vgg_reaches_16mb() {
        assert!(CommProfile::alexnet().ops.iter().all(|&b| b <= 4 * MB));
        assert_eq!(
            CommProfile::vgg11().ops.iter().max().copied(),
            Some(16 * MB)
        );
    }

    #[test]
    fn vgg_dominated_by_2_to_16mb() {
        let v = CommProfile::vgg11();
        let band: u64 = v.ops.iter().filter(|&&b| (2 * MB..=16 * MB).contains(&b)).sum();
        assert!(band as f64 / v.bytes_per_iter() as f64 > 0.9);
    }

    #[test]
    fn compute_time_sane() {
        let a = CommProfile::alexnet();
        let t32 = a.compute_us(32);
        let t64 = a.compute_us(64);
        assert!(t32 > 0.0 && t64 > t32 * 0.8 && t64 < t32 * 2.5);
    }

    #[test]
    fn histogram_has_expected_buckets() {
        let h = CommProfile::alexnet().epoch_histogram(256);
        assert!(h.total_count() > 0);
        let rows = h.rows();
        assert!(rows.iter().all(|&(lb, _, _)| lb <= 4 * MB));
    }

    #[test]
    fn by_name_lookup() {
        assert!(CommProfile::by_name("AlexNet").is_some());
        assert!(CommProfile::by_name("vgg-11").is_some());
        assert!(CommProfile::by_name("resnet").is_none());
    }
}
